"""L2 JAX model: the LOTUS rebalance planner + batch key hash graphs.

These are the compute graphs the rust coordinator executes through PJRT at
run time (python is build-time only). Two exported entry points:

- ``rebalance_plan``: the two-level load balancer's decision function
  (paper section 4.3). Inputs are the per-CN/per-shard request-count matrix
  observed this interval, the previous EWMA heat state, and each CN's
  average latency over the last three 100 ms intervals (the paper's
  3-consecutive-interval overload rule). Outputs: new heat state, per-CN
  load, the overload mask, each CN's hottest shard (migration candidate),
  and the migration receiver (lowest-latency CN). The EWMA scoring runs in
  the L1 Pallas kernel; the arg-max/arg-min decision layer is plain jnp and
  fuses into the same HLO module.

- ``shard_hash_batch``: batched LOTUS key hashing (L1 kernel), exported so
  the rust side can cross-check its native hash implementation bit-for-bit
  against the artifact (layer-pinning test) and plan key batches.

Shapes are static per artifact: the coordinator is compiled for a fixed
CN-count / shard-count topology (matching the paper's fixed 9-CN testbed);
``aot.py`` can emit artifacts for several topologies.
"""

import jax
import jax.numpy as jnp

from .kernels import ewma_heat, shard_hash

# Overload rule (paper 4.3): latency > 50% above cluster average for three
# consecutive 100 ms intervals.
OVERLOAD_THRESHOLD = 1.5
N_INTERVALS = 3


def rebalance_plan(counts, prev_heat, latency3, alpha):
    """Two-level load-balancing decision function.

    Args:
      counts:    f32[C, S] requests per owner CN per shard this interval.
      prev_heat: f32[C, S] EWMA heat state.
      latency3:  f32[C, 3] per-CN avg latency, oldest..latest interval.
      alpha:     f32[1] EWMA factor.

    Returns (tuple):
      heat f32[C, S], load f32[C], overload i32[C], hottest i32[C],
      target i32[] (receiver CN id).
    """
    heat, load = ewma_heat(counts, prev_heat, alpha)
    avg = jnp.mean(latency3, axis=0, keepdims=True)
    overload = jnp.all(latency3 > OVERLOAD_THRESHOLD * avg, axis=1)
    hottest = jnp.argmax(heat, axis=1).astype(jnp.int32)
    target = jnp.argmin(latency3[:, -1]).astype(jnp.int32)
    return heat, load, overload.astype(jnp.int32), hottest, target


def shard_hash_batch(hi, lo):
    """Batched (fingerprint, bucket, shard) for u32[N] key halves."""
    return shard_hash(hi, lo)


def lower_rebalance(n_cns: int, n_shards: int):
    """Lower ``rebalance_plan`` for a fixed topology; returns jax Lowered."""
    spec_cs = jax.ShapeDtypeStruct((n_cns, n_shards), jnp.float32)
    spec_l3 = jax.ShapeDtypeStruct((n_cns, N_INTERVALS), jnp.float32)
    spec_a = jax.ShapeDtypeStruct((1,), jnp.float32)
    return jax.jit(rebalance_plan).lower(spec_cs, spec_cs, spec_l3, spec_a)


def lower_shard_hash(batch: int):
    """Lower ``shard_hash_batch`` for a fixed batch size."""
    spec = jax.ShapeDtypeStruct((batch,), jnp.uint32)
    return jax.jit(shard_hash_batch).lower(spec, spec)
