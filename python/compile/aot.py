"""AOT lowering: jax -> HLO TEXT artifacts for the rust PJRT runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. Lowered with ``return_tuple=True``; the rust side unwraps with
``to_tuple{N}``.

Usage (from ``make artifacts``):
    cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from . import model

# Default topology: mirrors the paper's testbed scale (9 CNs) with 4096
# shards (12-bit shard number space from fig. 7). Shard-hash batch of 1024.
DEFAULT_CNS = 9
DEFAULT_SHARDS = 4096
DEFAULT_HASH_BATCH = 1024


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_artifact(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>9} chars  {path}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifact directory")
    p.add_argument("--cns", type=int, default=DEFAULT_CNS)
    p.add_argument("--shards", type=int, default=DEFAULT_SHARDS)
    p.add_argument("--hash-batch", type=int, default=DEFAULT_HASH_BATCH)
    args = p.parse_args()

    os.makedirs(args.out, exist_ok=True)

    lowered = model.lower_rebalance(args.cns, args.shards)
    write_artifact(os.path.join(args.out, "rebalance.hlo.txt"), to_hlo_text(lowered))

    lowered = model.lower_shard_hash(args.hash_batch)
    write_artifact(os.path.join(args.out, "shard_hash.hlo.txt"), to_hlo_text(lowered))

    # Manifest so the rust runtime can validate topology at load time.
    manifest = {
        "rebalance": {
            "file": "rebalance.hlo.txt",
            "n_cns": args.cns,
            "n_shards": args.shards,
            "n_intervals": model.N_INTERVALS,
            "outputs": ["heat", "load", "overload", "hottest", "target"],
        },
        "shard_hash": {
            "file": "shard_hash.hlo.txt",
            "batch": args.hash_batch,
            "outputs": ["fingerprint", "bucket", "shard"],
        },
    }
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest       {mpath}")


if __name__ == "__main__":
    main()
