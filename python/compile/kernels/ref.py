"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references: straight-line jax.numpy with no
pallas, no tiling, no grids. pytest (and hypothesis sweeps) assert the
kernels match these bit-for-bit (integer kernels) or to float tolerance
(EWMA kernel).
"""

import jax.numpy as jnp

from .shard_hash import AVALANCHE, FNV_OFFSET, FNV_PRIME, SHARD_MASK


def ewma_heat_ref(counts, prev_heat, alpha):
    """Reference EWMA heat + per-CN load."""
    counts = counts.astype(jnp.float32)
    prev_heat = prev_heat.astype(jnp.float32)
    heat = alpha * counts + (1.0 - alpha) * prev_heat
    return heat, jnp.sum(heat, axis=1)


def mix32_ref(hi, lo):
    """Reference FNV-1a 2-round mix with xorshift avalanche (u32 wrap)."""
    hi = hi.astype(jnp.uint32)
    lo = lo.astype(jnp.uint32)
    h = (jnp.uint32(FNV_OFFSET) ^ lo) * jnp.uint32(FNV_PRIME)
    h = (h ^ hi) * jnp.uint32(FNV_PRIME)
    h = h ^ (h >> jnp.uint32(15))
    h = h * jnp.uint32(AVALANCHE)
    h = h ^ (h >> jnp.uint32(13))
    return h


def shard_hash_ref(hi, lo, n_buckets=65536):
    """Reference (fingerprint, bucket, shard) triple."""
    fp = mix32_ref(hi, lo)
    bucket = fp % jnp.uint32(n_buckets)
    shard = lo.astype(jnp.uint32) & jnp.uint32(SHARD_MASK)
    return fp, bucket, shard


def rebalance_plan_ref(counts, prev_heat, latency3, alpha=0.25, threshold=1.5):
    """Reference for the full L2 rebalance planner (model.py).

    Returns:
      (heat, load, overload, hottest, target):
        heat     f32[C, S] new EWMA state
        load     f32[C]    per-CN aggregate heat
        overload i32[C]    1 iff CN latency > threshold * cluster avg in all
                           3 intervals (paper's 3-consecutive rule)
        hottest  i32[C]    per-CN argmax shard of heat
        target   i32[]     CN with lowest latest-interval latency (receiver)
    """
    heat, load = ewma_heat_ref(counts, prev_heat, alpha)
    avg = jnp.mean(latency3, axis=0, keepdims=True)  # [1, 3]
    over = jnp.all(latency3 > threshold * avg, axis=1)
    hottest = jnp.argmax(heat, axis=1).astype(jnp.int32)
    target = jnp.argmin(latency3[:, -1]).astype(jnp.int32)
    return heat, load, over.astype(jnp.int32), hottest, target
