"""EWMA heat-scoring Pallas kernel (L1).

The LOTUS load balancer (paper section 4.3) tracks, per compute node (CN)
and per shard, an exponentially weighted moving average of request counts:

    heat[c, s] = alpha * counts[c, s] + (1 - alpha) * prev_heat[c, s]

and the per-CN aggregate load ``load[c] = sum_s heat[c, s]``. The matrix is
[C x S] with S up to a few thousand shards; the kernel tiles the shard axis
so each grid step streams one contiguous [C x TILE] block through VMEM —
on a real TPU this is a VPU-bound streaming op (no MXU), and the BlockSpec
schedule below makes each tile a single contiguous HBM read.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and the rust runtime runs on the CPU client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default EWMA smoothing factor. 0.25 gives ~4-interval memory, matching the
# paper's 3-consecutive-interval (300 ms) overload criterion granularity.
DEFAULT_ALPHA = 0.25

# Shard-axis tile. 512 f32 lanes * C rows stays far below VMEM budget while
# keeping the per-step HBM read contiguous and lane-aligned (512 % 128 == 0).
DEFAULT_TILE_S = 512


def _heat_kernel(counts_ref, prev_ref, alpha_ref, heat_ref, load_ref):
    """One [C x TILE_S] tile: EWMA update + partial per-CN load reduction."""
    alpha = alpha_ref[0]
    counts = counts_ref[...]
    prev = prev_ref[...]
    heat = alpha * counts + (1.0 - alpha) * prev
    heat_ref[...] = heat
    # Partial row-sum for this shard tile; the caller sums tiles on axis 1.
    load_ref[...] = jnp.sum(heat, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("tile_s",))
def ewma_heat(counts, prev_heat, alpha, tile_s=DEFAULT_TILE_S):
    """EWMA heat update, tiled over the shard axis.

    Args:
      counts:    f32[C, S] request counts observed this interval.
      prev_heat: f32[C, S] heat state from the previous interval.
      alpha:     f32[1] smoothing factor in (0, 1].
      tile_s:    static shard-axis tile (must divide S).

    Returns:
      (heat, load): f32[C, S] updated heat and f32[C] per-CN load.
    """
    c, s = counts.shape
    assert prev_heat.shape == (c, s), (counts.shape, prev_heat.shape)
    if s % tile_s != 0:
        # Degrade to a single tile for odd sizes (tests sweep these).
        tile_s = s
    n_tiles = s // tile_s

    heat, load_parts = pl.pallas_call(
        _heat_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((c, tile_s), lambda i: (0, i)),
            pl.BlockSpec((c, tile_s), lambda i: (0, i)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((c, tile_s), lambda i: (0, i)),
            pl.BlockSpec((c, 1), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, s), jnp.float32),
            jax.ShapeDtypeStruct((c, n_tiles), jnp.float32),
        ],
        interpret=True,
    )(counts, prev_heat, alpha)
    return heat, jnp.sum(load_parts, axis=1)
