"""L1 Pallas kernels for LOTUS (build-time only; never on the request path).

Two kernels implement the coordinator's numeric hot-spots:

- ``heat``: tiled EWMA heat scoring over the [CNs x shards] request-count
  matrix used by the two-level load balancer (paper section 4.3).
- ``shard_hash``: the vectorized LOTUS key hash (fingerprint / lock-table
  bucket / shard number, paper sections 4.1-4.2) for batched key planning.

Both are lowered with ``interpret=True`` so the emitted HLO runs on any
PJRT backend (the rust coordinator uses the CPU client). ``ref.py`` holds
the pure-jnp oracles that pytest checks the kernels against.
"""

from .heat import ewma_heat, DEFAULT_ALPHA
from .shard_hash import shard_hash, FNV_OFFSET, FNV_PRIME, SHARD_BITS, SHARD_MASK

__all__ = [
    "ewma_heat",
    "DEFAULT_ALPHA",
    "shard_hash",
    "FNV_OFFSET",
    "FNV_PRIME",
    "SHARD_BITS",
    "SHARD_MASK",
]
