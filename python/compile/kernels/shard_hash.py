"""Vectorized LOTUS key hash as a Pallas kernel (L1).

Paper sections 4.1-4.2: every data record is addressed by a 64-bit LOTUS
key whose *low 12 bits are the shard number* (taken from the critical
field); the lock table hashes the key to a 7B *fingerprint* plus a bucket
index. This kernel is the batched version used for key planning: given a
batch of keys split into (hi, lo) u32 halves it produces, per key,

    fingerprint = mix32(hi, lo)          (FNV-1a style 2-round mix)
    bucket      = fingerprint % n_buckets
    shard       = lo & 0xFFF

The EXACT same mix is implemented in rust (``sharding::key::mix32``); an
integration test executes this artifact through PJRT and asserts bit
equality against the rust implementation, pinning the two layers together.

All arithmetic is u32 with wrap-around semantics (matching rust
``u32::wrapping_mul`` / ``^``), so interpret-mode CPU lowering is exact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# FNV-1a 32-bit parameters (plain python ints: pallas kernels must not
# capture traced jax constants from module scope).
FNV_OFFSET = 2166136261
FNV_PRIME = 16777619
AVALANCHE = 2246822519

# Low 12 bits of the LOTUS key are the shard number (paper fig. 7).
SHARD_BITS = 12
SHARD_MASK = (1 << SHARD_BITS) - 1

# Lane-aligned batch tile.
DEFAULT_TILE = 256


def _mix32(hi, lo):
    """Two FNV-1a rounds over the 32-bit halves + xorshift avalanche."""
    h = (jnp.uint32(FNV_OFFSET) ^ lo) * jnp.uint32(FNV_PRIME)
    h = (h ^ hi) * jnp.uint32(FNV_PRIME)
    # Final avalanche (xorshift) so nearby keys spread across buckets.
    h = h ^ (h >> jnp.uint32(15))
    h = h * jnp.uint32(AVALANCHE)
    h = h ^ (h >> jnp.uint32(13))
    return h


def _hash_kernel(hi_ref, lo_ref, fp_ref, bucket_ref, shard_ref, *, n_buckets):
    hi = hi_ref[...]
    lo = lo_ref[...]
    fp = _mix32(hi, lo)
    fp_ref[...] = fp
    bucket_ref[...] = fp % jnp.uint32(n_buckets)
    shard_ref[...] = lo & jnp.uint32(SHARD_MASK)


@functools.partial(jax.jit, static_argnames=("n_buckets", "tile"))
def shard_hash(hi, lo, n_buckets=65536, tile=DEFAULT_TILE):
    """Batched LOTUS key hash.

    Args:
      hi, lo:    u32[N] high/low halves of the 64-bit LOTUS keys.
      n_buckets: static lock-table bucket count (power of two in practice).
      tile:      static batch tile (must divide N; degrades to N otherwise).

    Returns:
      (fingerprint, bucket, shard): three u32[N] arrays.
    """
    (n,) = hi.shape
    assert lo.shape == (n,)
    if n % tile != 0:
        tile = n
    kernel = functools.partial(_hash_kernel, n_buckets=n_buckets)
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ],
        interpret=True,
    )(hi, lo)
