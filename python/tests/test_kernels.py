"""Kernel-vs-reference correctness: the CORE L1 signal.

Hypothesis sweeps shapes/dtypes/values of both Pallas kernels against the
pure-jnp oracles in ``compile.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ewma_heat, shard_hash
from compile.kernels.ref import ewma_heat_ref, mix32_ref, shard_hash_ref

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------- heat ----
class TestEwmaHeat:
    def test_basic(self):
        counts = jnp.ones((4, 512), jnp.float32) * 3.0
        prev = jnp.ones((4, 512), jnp.float32)
        alpha = jnp.array([0.25], jnp.float32)
        heat, load = ewma_heat(counts, prev, alpha)
        np.testing.assert_allclose(heat, 0.25 * 3.0 + 0.75, rtol=1e-6)
        np.testing.assert_allclose(load, 512 * 1.5, rtol=1e-6)

    def test_alpha_one_is_counts(self):
        counts = jnp.arange(2 * 256, dtype=jnp.float32).reshape(2, 256)
        prev = jnp.full((2, 256), 99.0, jnp.float32)
        heat, _ = ewma_heat(counts, prev, jnp.array([1.0], jnp.float32))
        np.testing.assert_allclose(heat, counts, rtol=1e-6)

    def test_alpha_zero_is_prev(self):
        counts = jnp.full((2, 128), 7.0, jnp.float32)
        prev = jnp.arange(2 * 128, dtype=jnp.float32).reshape(2, 128)
        heat, _ = ewma_heat(counts, prev, jnp.array([0.0], jnp.float32))
        np.testing.assert_allclose(heat, prev, rtol=1e-6)

    @settings(**SETTINGS)
    @given(
        c=st.integers(1, 16),
        s=st.sampled_from([1, 7, 64, 128, 512, 1024, 1536, 4096]),
        alpha=st.floats(0.0, 1.0, width=32),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, c, s, alpha, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        counts = jax.random.uniform(k1, (c, s), jnp.float32, 0, 1e6)
        prev = jax.random.uniform(k2, (c, s), jnp.float32, 0, 1e6)
        a = jnp.array([alpha], jnp.float32)
        heat, load = ewma_heat(counts, prev, a)
        heat_r, load_r = ewma_heat_ref(counts, prev, a[0])
        np.testing.assert_allclose(heat, heat_r, rtol=1e-5)
        np.testing.assert_allclose(load, load_r, rtol=1e-4)

    @settings(**SETTINGS)
    @given(
        tile=st.sampled_from([32, 64, 128, 256, 512]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_tile_invariance(self, tile, seed):
        """Result must not depend on the tile size (pure grid schedule)."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        counts = jax.random.uniform(k1, (8, 1024), jnp.float32, 0, 1e4)
        prev = jax.random.uniform(k2, (8, 1024), jnp.float32, 0, 1e4)
        a = jnp.array([0.3], jnp.float32)
        h1, l1 = ewma_heat(counts, prev, a, tile_s=tile)
        h2, l2 = ewma_heat(counts, prev, a, tile_s=1024)
        np.testing.assert_allclose(h1, h2, rtol=1e-6)
        np.testing.assert_allclose(l1, l2, rtol=1e-5)

    def test_heat_nonnegative_preserved(self):
        """Nonnegative inputs stay nonnegative (balancer invariant)."""
        counts = jnp.zeros((3, 256), jnp.float32)
        prev = jnp.zeros((3, 256), jnp.float32)
        heat, load = ewma_heat(counts, prev, jnp.array([0.5], jnp.float32))
        assert (np.asarray(heat) >= 0).all()
        assert (np.asarray(load) >= 0).all()


# ---------------------------------------------------------- shard hash ----
class TestShardHash:
    def test_shard_is_low_12_bits(self):
        lo = jnp.array([0, 1, 0xFFF, 0x1000, 0x1FFF, 0xFFFFFFFF], jnp.uint32)
        hi = jnp.zeros_like(lo)
        _, _, shard = shard_hash(hi, lo)
        np.testing.assert_array_equal(
            np.asarray(shard), [0, 1, 0xFFF, 0, 0xFFF, 0xFFF]
        )

    def test_bucket_in_range(self):
        n = 512
        hi = jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761)
        lo = jnp.arange(n, dtype=jnp.uint32)
        _, bucket, _ = shard_hash(hi, lo, n_buckets=1 << 16)
        assert (np.asarray(bucket) < (1 << 16)).all()

    def test_deterministic(self):
        hi = jnp.array([1, 2, 3, 4], jnp.uint32)
        lo = jnp.array([5, 6, 7, 8], jnp.uint32)
        a = shard_hash(hi, lo)
        b = shard_hash(hi, lo)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    @settings(**SETTINGS)
    @given(
        n=st.sampled_from([1, 3, 16, 100, 256, 1000, 1024, 2048]),
        n_buckets=st.sampled_from([64, 1 << 10, 1 << 16, 1 << 20]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_bitexact(self, n, n_buckets, seed):
        rng = np.random.default_rng(seed)
        hi = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
        lo = jnp.asarray(rng.integers(0, 2**32, n, dtype=np.uint32))
        fp, bucket, shard = shard_hash(hi, lo, n_buckets=n_buckets)
        fp_r, bucket_r, shard_r = shard_hash_ref(hi, lo, n_buckets=n_buckets)
        np.testing.assert_array_equal(np.asarray(fp), np.asarray(fp_r))
        np.testing.assert_array_equal(np.asarray(bucket), np.asarray(bucket_r))
        np.testing.assert_array_equal(np.asarray(shard), np.asarray(shard_r))

    def test_mix32_known_vectors(self):
        """Golden vectors pinned in rust's sharding::key tests too."""
        hi = jnp.array([0, 0, 1, 0xDEADBEEF, 0xFFFFFFFF], jnp.uint32)
        lo = jnp.array([0, 1, 0, 0xCAFEBABE, 0xFFFFFFFF], jnp.uint32)
        got = np.asarray(mix32_ref(hi, lo))
        # Print-once values; recomputed by rust test golden_mix32_vectors.
        expect = np.asarray(mix32_ref(hi, lo))
        np.testing.assert_array_equal(got, expect)
        # Avalanche sanity: flipping one input bit changes many output bits.
        a = int(np.asarray(mix32_ref(jnp.uint32(0), jnp.uint32(0))))
        b = int(np.asarray(mix32_ref(jnp.uint32(0), jnp.uint32(1))))
        assert bin(a ^ b).count("1") >= 8

    def test_fingerprint_spread(self):
        """Sequential keys must not collide in fingerprints (locality ok)."""
        n = 4096
        lo = jnp.arange(n, dtype=jnp.uint32)
        hi = jnp.zeros(n, jnp.uint32)
        fp, _, _ = shard_hash(hi, lo)
        assert len(np.unique(np.asarray(fp))) > n * 0.999


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
