"""L2 model tests: rebalance planner semantics + lowering round-trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.aot import to_hlo_text
from compile.kernels.ref import rebalance_plan_ref

SETTINGS = dict(max_examples=20, deadline=None)


def _mk(c=4, s=256, seed=0):
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    counts = jax.random.uniform(k[0], (c, s), jnp.float32, 0, 1e4)
    prev = jax.random.uniform(k[1], (c, s), jnp.float32, 0, 1e4)
    lat3 = jax.random.uniform(k[2], (c, 3), jnp.float32, 1.0, 100.0)
    return counts, prev, lat3


class TestRebalancePlan:
    @settings(**SETTINGS)
    @given(
        c=st.integers(2, 12),
        s=st.sampled_from([64, 256, 512, 1024]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, c, s, seed):
        counts, prev, lat3 = _mk(c, s, seed)
        a = jnp.array([0.25], jnp.float32)
        got = model.rebalance_plan(counts, prev, lat3, a)
        ref = rebalance_plan_ref(counts, prev, lat3, 0.25, 1.5)
        names = ["heat", "load", "overload", "hottest", "target"]
        for name, g, r in zip(names, got, ref):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(r), rtol=1e-5, err_msg=name
            )

    def test_overload_rule_three_consecutive(self):
        """CN must exceed 1.5x cluster avg in ALL 3 intervals to trip."""
        # CN0 hot in all 3 intervals; CN1 hot in 2 of 3; CN2/CN3 cold.
        lat3 = jnp.array(
            [[100.0, 100.0, 100.0], [100.0, 100.0, 1.0], [1.0, 1.0, 1.0], [1.0, 1.0, 1.0]],
            jnp.float32,
        )
        counts, prev, _ = _mk(4, 64)
        _, _, over, _, _ = model.rebalance_plan(
            counts, prev, lat3, jnp.array([0.25], jnp.float32)
        )
        assert list(np.asarray(over)) == [1, 0, 0, 0]

    def test_target_is_lowest_latency_cn(self):
        lat3 = jnp.array(
            [[5.0, 5.0, 5.0], [5.0, 5.0, 2.0], [5.0, 5.0, 9.0]], jnp.float32
        )
        counts, prev, _ = _mk(3, 64)
        *_, target = model.rebalance_plan(
            counts, prev, lat3, jnp.array([0.25], jnp.float32)
        )
        assert int(np.asarray(target)) == 1

    def test_hottest_shard_argmax(self):
        counts = jnp.zeros((2, 128), jnp.float32)
        counts = counts.at[0, 17].set(1e6).at[1, 99].set(1e6)
        prev = jnp.zeros((2, 128), jnp.float32)
        lat3 = jnp.ones((2, 3), jnp.float32)
        _, _, _, hottest, _ = model.rebalance_plan(
            counts, prev, lat3, jnp.array([1.0], jnp.float32)
        )
        assert list(np.asarray(hottest)) == [17, 99]

    def test_no_overload_when_balanced(self):
        lat3 = jnp.ones((6, 3), jnp.float32) * 7.0
        counts, prev, _ = _mk(6, 64)
        _, _, over, _, _ = model.rebalance_plan(
            counts, prev, lat3, jnp.array([0.25], jnp.float32)
        )
        assert np.asarray(over).sum() == 0


class TestLowering:
    def test_rebalance_lowers_to_hlo_text(self):
        text = to_hlo_text(model.lower_rebalance(4, 512))
        assert "HloModule" in text
        assert len(text) > 500

    def test_shard_hash_lowers_to_hlo_text(self):
        text = to_hlo_text(model.lower_shard_hash(256))
        assert "HloModule" in text

    def test_lowered_executes_same_as_eager(self):
        """Compile the lowered module and compare against eager results."""
        lowered = model.lower_rebalance(3, 128)
        compiled = lowered.compile()
        counts, prev, lat3 = _mk(3, 128, seed=7)
        a = jnp.array([0.25], jnp.float32)
        got = compiled(counts, prev, lat3, a)
        ref = model.rebalance_plan(counts, prev, lat3, a)
        for g, r in zip(got, ref):
            np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=1e-6)

    def test_hlo_has_no_custom_calls(self):
        """interpret=True must lower to plain HLO (no Mosaic custom-call)."""
        for text in (
            to_hlo_text(model.lower_rebalance(2, 128)),
            to_hlo_text(model.lower_shard_hash(128)),
        ):
            assert "custom-call" not in text.lower(), "CPU PJRT cannot run this"


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
