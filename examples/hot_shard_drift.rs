//! Domain example: online lock-shard rebalancing under *moving* skew
//! (ISSUE 10).
//!
//! A skewed KVS workload's hot spot does not sit still: every 8 ms the
//! Zipf rank-to-key mapping rotates (`drift_interval_ns`), and at 24 ms
//! a flash crowd abruptly makes a cold key range the hot set
//! (`flash_crowd_at_ns`). Under hybrid routing the CN owning the current
//! hot head coordinates nearly all of its traffic, so the hot spot
//! *changes owner* as it moves. The same seeded run executes twice:
//!
//! - **static placement** (`balance_interval_ns = 0`): the initial
//!   contiguous shard map serves the whole run; whichever CN the hot
//!   head lands on thrashes while the others coast.
//! - **periodic rebalance tick** (`balance_interval_ns = 1 ms`,
//!   `max_moves_per_tick` bounded): the two-level balancer (paper §4.3)
//!   chases the hot spot, moving lock ownership of the hottest shard to
//!   the coldest CN — each move costs a short lock-service interruption
//!   (the dip) that the timeline curve shows recovering.
//!
//! ```sh
//! cargo run --release --example hot_shard_drift
//! ```

use lotus::config::{Config, SystemKind};
use lotus::metrics::RunReport;
use lotus::sim::Cluster;
use lotus::workloads::WorkloadKind;

const BUCKET: u64 = 1_000_000; // 1 ms timeline buckets
const DRIFT_NS: u64 = 8_000_000; // hot spot rotates every 8 ms
const FLASH_AT: u64 = 24_000_000; // flash crowd at 24 ms
const DURATION: u64 = 40_000_000; // 40 ms window

fn cfg_base() -> Config {
    let mut cfg = Config::small();
    cfg.n_cns = 3;
    cfg.coordinators_per_cn = 2;
    cfg.pipeline_depth = 4;
    cfg.duration_ns = DURATION;
    cfg.timeline_interval_ns = BUCKET;
    cfg.scale.kvs_keys = 100_000;
    cfg.drift_interval_ns = DRIFT_NS;
    cfg.flash_crowd_at_ns = FLASH_AT;
    cfg
}

fn run(balance_interval_ns: u64) -> lotus::Result<RunReport> {
    let mut cfg = cfg_base();
    cfg.balance_interval_ns = balance_interval_ns;
    cfg.max_moves_per_tick = 1;
    let cluster = Cluster::build(
        &cfg,
        WorkloadKind::Kvs {
            rw_pct: 100,
            skewed: true,
        },
    )?;
    let report = cluster.run(SystemKind::Lotus)?;
    let held: usize = cluster
        .shared
        .lock_services
        .iter()
        .map(|s| s.held_slots())
        .sum();
    assert_eq!(held, 0, "live resharding must strand no lock slots");
    Ok(report)
}

fn print_curve(label: &str, report: &RunReport) -> f64 {
    let t = &report.timeline;
    let to_mtps = |c: u64| c as f64 / (BUCKET as f64 / 1e9) / 1e6;
    let peak = t.iter().copied().max().unwrap_or(1).max(1);
    println!("\n{label} — committed throughput (1 ms buckets):");
    for (i, &c) in t.iter().enumerate() {
        let mark = match (i as u64 * BUCKET, (i as u64 + 1) * BUCKET) {
            (lo, hi) if lo <= FLASH_AT && FLASH_AT < hi => "  <- flash crowd",
            (lo, _) if lo > 0 && lo % DRIFT_NS == 0 => "  <- hot spot drifts",
            _ => "",
        };
        println!(
            "{:>4} ms  {:>7.3} Mtxn/s  {}{}",
            i,
            to_mtps(c),
            "#".repeat((c * 40 / peak) as usize),
            mark
        );
    }
    println!(
        "  total: {} commits / {} aborts; {} shard moves ({} txns doomed, \
         {:.1} us lock-service interruption), {} wrong-owner bounces",
        report.commits,
        report.aborts,
        report.reshard_moves,
        report.reshard_aborted_txns,
        report.reshard_interruption_ns as f64 / 1e3,
        report.wrong_owner_bounces
    );
    report.commits as f64
}

fn main() -> lotus::Result<()> {
    println!(
        "moving skew: Zipf head rotates every {} ms, flash crowd at {} ms, {} ms run",
        DRIFT_NS / 1_000_000,
        FLASH_AT / 1_000_000,
        DURATION / 1_000_000
    );

    let rebalanced = run(1_000_000)?; // 1 ms balance tick
    let static_map = run(0)?; // tick disabled: static placement

    let c_reb = print_curve("periodic rebalance tick (1 ms)", &rebalanced);
    let c_sta = print_curve("static placement", &static_map);

    // Dip-and-recovery: after the last move settles, the tail of the
    // rebalanced curve must climb back above its post-flash-crowd dip.
    let t = &rebalanced.timeline;
    let flash_bucket = (FLASH_AT / BUCKET) as usize;
    let dip = t[flash_bucket..flash_bucket + 8]
        .iter()
        .copied()
        .min()
        .unwrap_or(0);
    let tail: u64 = t[t.len() - 5..].iter().sum::<u64>() / 5;
    println!("\nverdict:");
    println!(
        "  rebalanced {} commits vs static {} commits ({:+.1}%)",
        c_reb,
        c_sta,
        (c_reb / c_sta - 1.0) * 100.0
    );
    println!("  post-flash dip {dip} commits/ms, tail {tail} commits/ms");
    assert!(
        rebalanced.reshard_moves > 0,
        "a moving hot spot must trigger shard moves"
    );
    assert!(
        c_reb > c_sta,
        "chasing the hot spot must beat static placement ({c_reb} vs {c_sta})"
    );
    assert!(
        tail >= dip,
        "throughput must recover after the post-move dip (dip {dip}, tail {tail})"
    );
    println!("  rebalancing chased the moving hot spot and won ✓");
    Ok(())
}
