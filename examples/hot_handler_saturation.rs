//! Domain example: the fixed-window dilemma under a hot lock handler,
//! and the adaptive controller that dissolves it (ISSUE 6).
//!
//! Six CNs run a fully skewed write-only KVS workload with load
//! balancing off, so Zipf routing concentrates remote lock traffic on a
//! few destination CNs' RPC handlers. A fixed coalescing window cannot
//! win both ways: too narrow and the hot handler drowns in per-message
//! overhead (messages/commit stays high); too wide and every staged
//! lock batch eats the full window in latency (p99 balloons). The
//! per-plane x per-destination congestion controller widens only the
//! congested destinations' windows — steered by the measured handler
//! queueing delay — and holds the idle ones near direct issue.
//!
//! ```sh
//! cargo run --release --example hot_handler_saturation
//! ```

use lotus::config::{Config, SystemKind};
use lotus::metrics::RunReport;
use lotus::sim::Cluster;
use lotus::workloads::WorkloadKind;

fn run(cfg: &Config, window_ns: u64, adaptive: bool) -> lotus::Result<(RunReport, Cluster)> {
    let mut c = cfg.clone();
    c.coalesce_window_ns = window_ns;
    c.adaptive_coalescing = adaptive;
    let cluster = Cluster::build(
        &c,
        WorkloadKind::Kvs {
            rw_pct: 100,
            skewed: true,
        },
    )?;
    let report = cluster.run(SystemKind::Lotus)?;
    Ok((report, cluster))
}

fn main() -> lotus::Result<()> {
    let mut cfg = Config::small();
    cfg.n_cns = 6;
    cfg.coordinators_per_cn = 2;
    cfg.pipeline_depth = 4;
    cfg.features.load_balancing = false; // keep the hot spot hot
    cfg.duration_ns = 4_000_000;
    cfg.scale.kvs_keys = 2_000;

    println!("hot-handler saturation study: 6 CNs, skewed write-only KVS");
    println!(
        "{:<22} {:>10} {:>12} {:>10} {:>12} {:>16}",
        "policy", "commits", "msgs/commit", "p99 (us)", "reqs/msg", "handler wait(ns)"
    );
    let mut rows = Vec::new();
    for (label, window, adaptive) in [
        ("fixed narrow (500)", 500u64, false),
        ("fixed wide (40000)", 40_000, false),
        ("adaptive (base 5000)", 5_000, true),
    ] {
        let (r, cluster) = run(&cfg, window, adaptive)?;
        println!(
            "{label:<22} {:>10} {:>12.3} {:>10} {:>12.2} {:>16.0}",
            r.commits,
            r.rpc_messages_per_commit(),
            r.p99_us(),
            r.reqs_per_rpc_message(),
            r.mean_handler_wait_ns()
        );
        if adaptive {
            // Per-destination queueing delays, straight off the fabric:
            // the skew shows up as a few hot handlers and many idle ones.
            for cn in 0..cfg.n_cns {
                println!(
                    "    dst cn{cn}: chunks={} mean_wait={:.0}ns",
                    cluster.shared.rpc.handler_chunks(cn),
                    cluster.shared.rpc.mean_handler_wait_ns(cn)
                );
            }
            println!(
                "    fabric-wide handler wait p99: {}ns",
                r.handler_wait_p99_ns
            );
        }
        rows.push((label, r));
    }

    let narrow = &rows[0].1;
    let wide = &rows[1].1;
    let adaptive = &rows[2].1;
    assert!(
        adaptive.rpc_messages_per_commit() < narrow.rpc_messages_per_commit(),
        "adaptive must out-coalesce the narrow window"
    );
    assert!(
        adaptive.p99_ns < wide.p99_ns,
        "adaptive must undercut the wide window's tail"
    );
    println!("adaptive beats narrow on messages/commit and wide on p99 ✓");
    Ok(())
}
