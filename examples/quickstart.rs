//! Quickstart: build a small LOTUS cluster, run transactions by hand
//! through the paper's interface (Begin/AddRO/AddRW/Execute/Commit, §7.3),
//! then run a short timed benchmark.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use lotus::config::{Config, SystemKind};
use lotus::sharding::key::LotusKey;
use lotus::sim::Cluster;
use lotus::txn::api::{RecordRef, TxnApi, TxnCtl};
use lotus::txn::coordinator::LotusCoordinator;
use lotus::workloads::WorkloadKind;

fn main() -> lotus::Result<()> {
    // A laptop-scale cluster: 2 memory nodes, 3 compute nodes.
    let mut cfg = Config::small();
    cfg.scale.kvs_keys = 10_000;
    cfg.duration_ns = 5_000_000; // 5 ms of virtual time

    println!("building cluster ({} MNs, {} CNs) and loading 10K KV pairs ...", cfg.n_mns, cfg.n_cns);
    let cluster = Cluster::build(
        &cfg,
        WorkloadKind::Kvs {
            rw_pct: 50,
            skewed: true,
        },
    )?;

    // --- Drive the transaction API by hand (paper §7.3). ---
    let mut co = LotusCoordinator::new(cluster.shared.clone(), 0, 0, 0);
    let alice = RecordRef::new(0, LotusKey::compose(42, 42));

    // A read-write transaction: read key 42, write a new value.
    co.begin(false); // Begin()
    co.txn().add_rw(alice); // AddRW()
    co.txn().execute()?; // Execute(): lock-first, then read
    let before = co.txn().value(alice).unwrap().to_vec();
    co.txn().stage_write(alice, b"hello from the quickstart".to_vec());
    co.txn().commit()?; // Commit(): write + visible + unlock
    println!(
        "updated key 42: {:?} -> \"hello from the quickstart\" ({} us virtual)",
        String::from_utf8_lossy(&before[..8.min(before.len())]),
        co.now() / 1000
    );

    // A read-only transaction sees the committed value.
    co.begin(true);
    co.txn().add_ro(alice);
    co.txn().execute()?;
    assert_eq!(co.txn().value(alice).unwrap(), b"hello from the quickstart");
    co.txn().commit()?;
    println!("read-only transaction observed the update");

    // --- A short timed benchmark: LOTUS vs Motor. ---
    println!("\nrunning 5 ms (virtual) of skewed 50% read-write KVS:");
    for system in [SystemKind::Lotus, SystemKind::Motor] {
        let report = cluster.run(system)?;
        println!(
            "  {:<8} {:>7.3} Mtxn/s   p50 {:>3} us   p99 {:>3} us   abort {:.2}%",
            system.name(),
            report.mtps(),
            report.p50_us(),
            report.p99_us(),
            report.abort_rate() * 100.0
        );
    }
    Ok(())
}
