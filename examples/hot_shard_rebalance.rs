//! Domain example: surviving a hot-key flash crowd with pass-by-range
//! resharding (paper §4.3) driven by the AOT-compiled rebalance planner.
//!
//! A skewed KVS workload concentrates write traffic on a few shards. The
//! two-level load balancer detects the overloaded CN (latency >50% above
//! the cluster average for 3 consecutive intervals — computed by the
//! L2 JAX model / L1 Pallas EWMA kernel running through PJRT) and moves
//! the hottest shard's **lock ownership** to the coldest CN. Only
//! ownership moves; no data is copied.
//!
//! ```sh
//! make artifacts && cargo run --release --example hot_shard_rebalance
//! ```

use lotus::balance::planner::{Planner, RustPlanner, XlaPlanner};
use lotus::config::Config;
use lotus::sharding::key::N_SHARDS;
use lotus::sharding::resharding::transfer_shard;
use lotus::sim::Cluster;
use lotus::workloads::WorkloadKind;

fn main() -> lotus::Result<()> {
    let mut cfg = Config::paper();
    cfg.scale.kvs_keys = 100_000;
    cfg.mn_capacity = 1 << 30;

    let cluster = Cluster::build(
        &cfg,
        WorkloadKind::Kvs {
            rw_pct: 100,
            skewed: true,
        },
    )?;
    let shared = &cluster.shared;

    // The production planner: the PJRT-compiled artifact if its topology
    // matches, otherwise the rust mirror.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut planner: Box<dyn Planner> = match XlaPlanner::load(&dir, cfg.n_cns, N_SHARDS) {
        Ok(p) => {
            println!("planner: XLA artifact via PJRT ({}x{})", cfg.n_cns, N_SHARDS);
            Box::new(p)
        }
        Err(e) => {
            println!("planner: rust mirror ({e})");
            Box::new(RustPlanner::new(cfg.n_cns, N_SHARDS))
        }
    };

    // Synthesize three intervals of metrics with CN 0 melting down on one
    // hot shard (as a skewed flash crowd would produce).
    let hot_shard = shared.router.shards_of(0)[7];
    println!("flash crowd on shard {hot_shard} (owner CN 0)");
    let mut counts = vec![0f32; cfg.n_cns * N_SHARDS];
    counts[hot_shard as usize] = 50_000.0; // CN 0's row
    let mut latency3 = vec![100.0f32; cfg.n_cns * 3];
    for i in 0..3 {
        latency3[i] = 900.0; // CN 0: 9x the cluster average, 3 intervals
    }

    let plan = planner.plan(&counts, &latency3)?;
    println!(
        "planner verdict: overload={:?} hottest[0]={} receiver=CN{}",
        plan.overload, plan.hottest[0], plan.target
    );
    assert!(plan.overload[0], "CN 0 must be flagged");
    assert_eq!(plan.hottest[0], hot_shard as u32);

    for (shard, from, to) in plan.moves() {
        let mut clk = lotus::dm::clock::VClock::zero();
        let report = transfer_shard(shared, shard, from, to, &mut clk)?;
        println!(
            "moved shard {} CN{} -> CN{}: {} txns aborted, lock service \
             interrupted {} us (paper: 0.19-4.67 ms)",
            report.shard,
            report.from,
            report.to,
            report.aborted_txns,
            report.interruption_ns / 1000
        );
        assert_eq!(shared.router.owner_of(shard), to);
    }
    println!("ownership moved; no data was copied ✓");
    Ok(())
}
