//! Domain example: a chaos storm and the recovery curve that retries buy
//! (ISSUE 7).
//!
//! Three CNs run SmallBank. At 20 ms the storm hits: CN 2 fail-stops
//! (fig. 15 style), the RPC fabric starts losing 20% of lock-class
//! messages for the rest of the run, and for 10 ms the surviving
//! handlers go gray (4x service time on half their messages). The same
//! deterministic [`FaultScript`] runs twice:
//!
//! - `rpc_max_retries = 3`: a lost lock message parks its lane in capped
//!   exponential backoff and reissues — transactions get slower, not
//!   dead, and cluster throughput climbs back to the pre-storm rate
//!   after the crashed CN restarts.
//! - `rpc_max_retries = 0` (the pre-retry default): every lost message
//!   is a timeout-abort, so the sustained loss keeps a bite out of
//!   throughput long after recovery finished — the degradation never
//!   ends.
//!
//! ```sh
//! cargo run --release --example chaos_storm
//! ```

use std::sync::Arc;

use lotus::config::{Config, SystemKind};
use lotus::dm::{FaultInjector, FaultRule};
use lotus::metrics::RunReport;
use lotus::sim::{Cluster, CrashEvent, FaultScript};
use lotus::workloads::WorkloadKind;

const STORM_AT: u64 = 20_000_000; // 20 ms
const BUCKET: u64 = 1_000_000; // 1 ms sampling (fig. 15)

fn storm(cfg: &Config) -> FaultScript {
    FaultScript {
        crashes: vec![CrashEvent {
            at_ns: STORM_AT,
            cns: vec![2],
        }],
        faults: Some(Arc::new(
            FaultInjector::new(cfg.seed)
                // Sustained lossy fabric: 20% of lock-class messages
                // vanish from the storm onward.
                .rule(FaultRule::drop(200).window(STORM_AT, u64::MAX))
                // Gray window: for 10 ms, half the surviving messages are
                // served at 4x handler time.
                .rule(FaultRule::gray_slow(4, 500).window(STORM_AT, STORM_AT + 10_000_000)),
        )),
        suspicions: vec![],
    }
}

fn run(cfg: &Config, retries: u32) -> lotus::Result<(RunReport, usize)> {
    let mut c = cfg.clone();
    c.rpc_max_retries = retries;
    let cluster = Cluster::build(&c, WorkloadKind::SmallBank)?;
    let report = cluster.run_with_faults(SystemKind::Lotus, &storm(&c))?;
    let held = cluster
        .shared
        .lock_services
        .iter()
        .map(|s| s.held_slots())
        .sum();
    Ok((report, held))
}

fn print_curve(label: &str, report: &RunReport) -> (f64, f64, f64, i64) {
    let t = &report.timeline;
    let to_mtps = |c: u64| c as f64 / (BUCKET as f64 / 1e9) / 1e6;
    let peak = t.iter().copied().max().unwrap_or(1).max(1);
    println!("\n{label} — timeline (1 ms buckets):");
    for (i, &c) in t.iter().enumerate() {
        println!(
            "{:>4} ms  {:>7.3} Mtxn/s  {}",
            i,
            to_mtps(c),
            "#".repeat((c * 48 / peak) as usize)
        );
    }
    let pre: f64 = t[10..20].iter().map(|&c| to_mtps(c)).sum::<f64>() / 10.0;
    let dip = t[20..35].iter().map(|&c| to_mtps(c)).fold(f64::MAX, f64::min);
    let post: f64 = t[45..55].iter().map(|&c| to_mtps(c)).sum::<f64>() / 10.0;
    let recover_ms = t
        .iter()
        .enumerate()
        .skip(21)
        .find(|(_, &c)| to_mtps(c) >= pre * 0.9)
        .map(|(i, _)| i as i64 - 20)
        .unwrap_or(-1);
    println!("  pre-storm  : {pre:.3} Mtxn/s");
    println!(
        "  dip        : {dip:.3} Mtxn/s ({:.1}% drop)",
        (1.0 - dip / pre) * 100.0
    );
    println!(
        "  post-storm : {post:.3} Mtxn/s ({:.1}% of pre-storm)",
        post / pre * 100.0
    );
    match recover_ms {
        -1 => println!("  recovery   : never reached 90% of the pre-storm rate"),
        ms => println!("  recovery   : ~{ms} ms after the storm to regain 90%"),
    }
    println!(
        "  fabric     : {} msgs lost, {} retries, {:.1} us backed off, {} commits / {} aborts",
        report.rpc_dropped,
        report.rpc_retries,
        report.backoff_ns as f64 / 1e3,
        report.commits,
        report.aborts
    );
    (pre, dip, post, recover_ms)
}

fn main() -> lotus::Result<()> {
    let mut cfg = Config::small();
    cfg.n_cns = 3;
    cfg.coordinators_per_cn = 4;
    cfg.pipeline_depth = 4;
    cfg.duration_ns = 60_000_000; // 60 ms window
    cfg.timeline_interval_ns = BUCKET;

    println!("chaos storm: CN 2 crashes at 20 ms + sustained 20% message loss + 10 ms gray window");

    let (with_retries, held_on) = run(&cfg, 3)?;
    let (without, held_off) = run(&cfg, 0)?;

    let (pre_on, _, post_on, rec_on) = print_curve("rpc_max_retries = 3", &with_retries);
    let (pre_off, _, post_off, _) = print_curve("rpc_max_retries = 0", &without);

    println!("\nverdict:");
    println!(
        "  retries on : post-storm at {:.1}% of pre-storm (recovered in ~{rec_on} ms)",
        post_on / pre_on * 100.0
    );
    println!(
        "  retries off: post-storm at {:.1}% of pre-storm (sustained degradation)",
        post_off / pre_off * 100.0
    );
    println!("  stale locks: {held_on} with retries, {held_off} without (must both be 0)");
    assert_eq!(held_on + held_off, 0, "a chaos storm must strand no locks");
    assert!(
        post_on / pre_on >= 0.9,
        "retries must recover to >= 90% of the pre-storm rate ({:.1}%)",
        post_on / pre_on * 100.0
    );
    assert!(
        post_on / pre_on > post_off / pre_off,
        "retries must beat the single-timeout-abort fabric after the storm"
    );
    Ok(())
}
