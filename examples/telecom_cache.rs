//! Domain example: a telecom profile store (TATP) and the version-table
//! cache (paper §4.4 + fig. 18).
//!
//! TATP is 80% read-only over small subscriber records — the regime where
//! the VT cache saves a CVT READ per access. This example sweeps the
//! cache size and reports hit rate, throughput, and P99 latency, then
//! shows the zero-overhead invalidation path by disabling the cache.
//!
//! ```sh
//! cargo run --release --example telecom_cache
//! ```

use lotus::config::{Config, SystemKind};
use lotus::sim::Cluster;
use lotus::workloads::WorkloadKind;

fn main() -> lotus::Result<()> {
    let mut cfg = Config::paper();
    cfg.scale.tatp_subscribers = 100_000;
    cfg.coordinators_per_cn = 4;
    cfg.duration_ns = 10_000_000;
    cfg.mn_capacity = 1 << 30;

    println!("== TATP ({} subscribers, 80% read-only) ==", cfg.scale.tatp_subscribers);
    println!(
        "\n{:>12} {:>10} {:>12} {:>10}",
        "vt-cache", "hit-rate", "Mtxn/s", "p99(us)"
    );
    for entries in [0usize, 16, 128, 1024, 16 * 1024] {
        let mut c = cfg.clone();
        if entries == 0 {
            c.features.vt_cache = false;
        } else {
            c.vt_cache_entries = entries;
        }
        let cluster = Cluster::build(&c, WorkloadKind::Tatp)?;
        let report = cluster.run(SystemKind::Lotus)?;
        let hit = if entries == 0 {
            0.0
        } else {
            cluster
                .shared
                .vt_caches
                .iter()
                .map(|vc| vc.hit_rate())
                .sum::<f64>()
                / c.n_cns as f64
        };
        println!(
            "{:>12} {:>9.1}% {:>12.3} {:>10}",
            if entries == 0 { "off".to_string() } else { format!("{entries}") },
            hit * 100.0,
            report.mtps(),
            report.p99_us()
        );
    }
    println!("\nlarger caches serve more CVT lookups locally (one RTT saved each).");
    Ok(())
}
