//! End-to-end driver: the full system on the paper's headline workload.
//!
//! Builds the paper-scale topology (3 MNs, 9 CNs), loads a SmallBank
//! dataset, and exercises every layer in one run:
//!
//! 1. throughput/latency comparison of LOTUS vs Motor vs FORD under
//!    rising concurrency (the fig. 2 / fig. 13 shape: the MN-RNIC
//!    atomics knee, which LOTUS's lock disaggregation removes);
//! 2. the two-level load balancer executing the AOT-compiled L2/L1 XLA
//!    artifact through PJRT on the live metrics stream;
//! 3. a 3-CN simultaneous crash with lock-rebuild-free recovery and the
//!    fig. 15 throughput timeline.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_smallbank
//! ```

use lotus::config::{Config, SystemKind};
use lotus::sim::{Cluster, CrashEvent};
use lotus::workloads::WorkloadKind;

fn main() -> lotus::Result<()> {
    let mut cfg = Config::paper();
    cfg.scale.smallbank_accounts = 200_000;
    cfg.duration_ns = 10_000_000; // 10 ms virtual per point
    cfg.mn_capacity = 1 << 30;

    println!("== LOTUS end-to-end: SmallBank on 3 MNs x 9 CNs ==\n");
    println!("loading {} accounts x 2 tables (3-way replicated) ...", cfg.scale.smallbank_accounts);

    // --- 1. Throughput-latency curve vs concurrency (fig. 2 / 13). ---
    println!("\n-- throughput vs concurrency (10 ms virtual per point) --");
    println!(
        "{:>5} {:>12} {:>12} {:>12}   (Mtxn/s)",
        "conc", "lotus", "motor", "ford"
    );
    for coords in [1usize, 2, 4, 6] {
        let mut c = cfg.clone();
        c.coordinators_per_cn = coords;
        let cluster = Cluster::build(&c, WorkloadKind::SmallBank)?;
        let mut row = format!("{:>5}", coords * c.n_cns);
        for system in [SystemKind::Lotus, SystemKind::Motor, SystemKind::Ford] {
            let r = cluster.run(system)?;
            row += &format!(" {:>8.3}/{:>3}", r.mtps(), r.p50_us());
        }
        println!("{row}   (tput/p50us)");
    }

    // --- 2 + 3. Crash + recovery timeline (fig. 15). ---
    println!("\n-- 3-CN simultaneous crash at t=20 ms (fig. 15) --");
    let mut c = cfg.clone();
    c.coordinators_per_cn = 4;
    c.duration_ns = 60_000_000;
    c.timeline_interval_ns = 2_000_000; // 2 ms buckets
    let cluster = Cluster::build(&c, WorkloadKind::SmallBank)?;
    let report = cluster.run_with_events(
        SystemKind::Lotus,
        &[CrashEvent {
            at_ns: 20_000_000,
            cns: vec![0, 1, 2],
        }],
    )?;
    println!(
        "total: {:.3} Mtxn/s, {} commits, abort {:.2}%",
        report.mtps(),
        report.commits,
        report.abort_rate() * 100.0
    );
    println!("timeline (Mtxn/s per 2 ms bucket):");
    let peak = report.timeline.iter().copied().max().unwrap_or(1).max(1);
    for (i, &c) in report.timeline.iter().enumerate() {
        let mtps = c as f64 / (report.timeline_interval_ns as f64 / 1e9) / 1e6;
        let bar = "#".repeat((c * 50 / peak) as usize);
        println!("  {:>3} ms  {:>7.3}  {}", i * 2, mtps, bar);
    }
    // Recovery sanity: no stale locks anywhere.
    let held: usize = cluster
        .shared
        .lock_services
        .iter()
        .map(|s| s.held_slots())
        .sum();
    assert_eq!(held, 0, "recovery must leave no stale locks");
    println!("\nrecovery left 0 stale locks; cluster serving again ✓");
    Ok(())
}
