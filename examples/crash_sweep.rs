//! Domain example: the exhaustive crash-point sweep (PR 8).
//!
//! A bank must balance no matter when its machines die. This example
//! replays one seeded transfers-only SmallBank run on a 3-CN / 2-MN
//! cluster, records every issue-point boundary CN 0 crosses, then
//! crashes CN 0 at each of them — once plain, once with the final
//! 60 µs of its doorbells landing **torn** (so the commit-log write in
//! flight at the crash tears mid-slot). After every crash, recovery
//! runs and the cluster-wide invariants are audited straight from
//! MN-resident bytes:
//!
//! - money conservation (`sum(balances)` == the initial total),
//! - zero held lock slots,
//! - byte-identical replicas.
//!
//! The whole sweep is deterministic: run it twice, get the same report.
//!
//! ```sh
//! cargo run --release --example crash_sweep
//! ```

use lotus::sim::crashsweep::{run_sweep, SweepOptions};
use lotus::workloads::smallbank::SmallBankWorkload;

fn main() -> lotus::Result<()> {
    let opts = SweepOptions::default();
    println!(
        "crash sweep: {} points max over [{} us, {} us), CN {} dies, torn-log variant {}",
        opts.max_points,
        opts.window.0 / 1000,
        opts.window.1 / 1000,
        opts.crash_cn,
        if opts.torn_log { "on" } else { "off" },
    );

    let rep = run_sweep(&opts)?;
    println!(
        "\n{} crash points enumerated, {} audited runs — all invariants held:\n",
        rep.crash_points.len(),
        rep.outcomes.len()
    );
    println!(
        "{:>10}  {:>4}  {:>8} {:>7}  {:>4} {:>9} {:>10}  {:>12}",
        "crash (ns)",
        "torn",
        "commits",
        "aborts",
        "torn",
        "log torn",
        "rolled",
        "bank total"
    );
    println!(
        "{:>10}  {:>4}  {:>8} {:>7}  {:>4} {:>9} {:>10}  {:>12}",
        "", "", "", "", "rings", "discarded", "fwd/back", ""
    );
    for o in &rep.outcomes {
        println!(
            "{:>10}  {:>4}  {:>8} {:>7}  {:>4} {:>9} {:>7}/{:<2}  {:>12}",
            o.t_ns,
            if o.torn_log { "yes" } else { "no" },
            o.commits,
            o.aborts,
            o.torn_batches,
            o.torn_slots_discarded,
            o.completed,
            o.rolled_back,
            o.total_balance,
        );
    }

    let initial = SmallBankWorkload::initial_total(opts.accounts);
    let discarded: usize = rep.outcomes.iter().map(|o| o.torn_slots_discarded).sum();
    let completed: usize = rep.outcomes.iter().map(|o| o.completed).sum();
    let rolled: usize = rep.outcomes.iter().map(|o| o.rolled_back).sum();
    println!("\nverdict:");
    println!("  bank total : {initial} at every single crash point (conserved)");
    println!("  recovery   : {completed} commits rolled forward, {rolled} rolled back");
    println!("  torn logs  : {discarded} sealed-slot tears detected and discarded");
    assert!(
        rep.outcomes.iter().all(|o| o.total_balance == initial),
        "money conservation violated somewhere in the sweep"
    );

    // Determinism: the same seed must replay the identical sweep.
    let rep2 = run_sweep(&opts)?;
    assert_eq!(rep, rep2, "same seed, different sweep");
    println!("  determinism: replaying the sweep reproduced it byte for byte");
    Ok(())
}
