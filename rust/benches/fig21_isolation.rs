//! Figure 21: isolation levels on TPC-C — SI (no read locks) vs SR.
//! The paper measures LOTUS-SI at +9.3% max throughput over LOTUS-SR,
//! with LOTUS ahead of Motor at both levels.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench_config, header, row};
use lotus::config::SystemKind;
use lotus::sim::Cluster;
use lotus::txn::api::Isolation;
use lotus::workloads::WorkloadKind;

fn main() -> lotus::Result<()> {
    header("Figure 21", "TPC-C under SR vs SI");
    let mut cfg = bench_config();
    cfg.coordinators_per_cn = if bench_util::full_scale() { 6 } else { 4 };
    let mut lotus_tput = [0.0f64; 2];
    for (i, (iso, label)) in [
        (Isolation::Serializable, "SR"),
        (Isolation::SnapshotIsolation, "SI"),
    ]
    .iter()
    .enumerate()
    {
        println!("\n-- {label} --");
        let mut c = cfg.clone();
        c.isolation = *iso;
        let cluster = Cluster::build(&c, WorkloadKind::Tpcc)?;
        for system in [SystemKind::Lotus, SystemKind::Motor] {
            let r = cluster.run(system)?;
            if system == SystemKind::Lotus {
                lotus_tput[i] = r.mtps();
            }
            println!("{}", row(&format!("{} {label}", system.name()), &r));
        }
    }
    println!(
        "\nlotus SI/SR = {:+.1}% (paper: +9.3%)",
        (lotus_tput[1] / lotus_tput[0] - 1.0) * 100.0
    );
    Ok(())
}
