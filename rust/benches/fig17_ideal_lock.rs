//! Figure 17: LOTUS vs the idealized RDMA lock (DecLock-style model —
//! single FAA per acquire/release, no queues or notifications; a strict
//! upper bound on CN-cooperative RDMA locking). The paper measures LOTUS
//! 1.3–1.9x ahead: even idealized RDMA locks keep global lock state in
//! the memory pool and pay the MN RNIC atomics pipeline.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench_config, concurrency_points, header, row};
use lotus::config::SystemKind;
use lotus::sim::Cluster;
use lotus::workloads::WorkloadKind;

fn main() -> lotus::Result<()> {
    header("Figure 17", "LOTUS vs idealized RDMA locking");
    let cfg = bench_config();
    for kind in [
        WorkloadKind::Kvs {
            rw_pct: 50,
            skewed: true,
        },
        WorkloadKind::SmallBank,
    ] {
        println!("\n===== {} =====", kind.name());
        let mut peak = [0.0f64; 2];
        for coords in concurrency_points() {
            let mut c = cfg.clone();
            c.coordinators_per_cn = coords;
            let cluster = Cluster::build(&c, kind)?;
            for (i, system) in [SystemKind::Lotus, SystemKind::IdealLock].iter().enumerate() {
                let r = cluster.run(*system)?;
                peak[i] = peak[i].max(r.mtps());
                println!(
                    "{}",
                    row(&format!("{} conc={}", system.name(), coords * c.n_cns), &r)
                );
            }
        }
        println!(
            "peak ratio lotus/ideal-lock = {:.2}x (paper: 1.3-1.9x)",
            peak[0] / peak[1]
        );
    }
    Ok(())
}
