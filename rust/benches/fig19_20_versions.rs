//! Figures 19 + 20: the number of MVCC versions per record, on TATP
//! (fig. 19: more versions only add bandwidth — throughput declines) and
//! TPC-C (fig. 20: 2-3 versions sharply cut StockLevel's abort rate, then
//! returns diminish). LOTUS and Motor are both swept.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench_config, header};
use lotus::config::SystemKind;
use lotus::sim::Cluster;
use lotus::workloads::WorkloadKind;

fn main() -> lotus::Result<()> {
    header("Figures 19/20", "versions-per-record sweep (TATP + TPCC)");
    let mut cfg = bench_config();
    cfg.coordinators_per_cn = 4;
    for kind in [WorkloadKind::Tatp, WorkloadKind::Tpcc] {
        println!("\n===== {} =====", kind.name());
        println!(
            "{:>9} | {:>24} | {:>24}",
            "versions", "lotus (tput p99 abort)", "motor"
        );
        for n_versions in [1u8, 2, 3, 4] {
            let mut c = cfg.clone();
            c.n_versions = n_versions;
            // Record-slot memory scales with the version count.
            c.mn_capacity = cfg.mn_capacity / 2 * (1 + n_versions as u64);
            let cluster = Cluster::build(&c, kind)?;
            let mut cells = Vec::new();
            for system in [SystemKind::Lotus, SystemKind::Motor] {
                let r = cluster.run(system)?;
                cells.push(format!(
                    "{:>7.3} {:>6}us {:>5.1}%",
                    r.mtps(),
                    r.p99_us(),
                    r.abort_rate() * 100.0
                ));
            }
            println!("{:>9} | {:>24} | {:>24}", n_versions, cells[0], cells[1]);
        }
    }
    println!("\npaper: TATP declines with versions (bandwidth); TPCC peaks at");
    println!("2-3 versions (StockLevel aborts drop from 51.3% to 4.4%).");
    Ok(())
}
