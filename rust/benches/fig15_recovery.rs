//! Figure 15: the CN-crash recovery timeline. The paper crashes 3 CNs
//! simultaneously on SmallBank, samples throughput at 1 ms intervals,
//! observes a ~30.6% cluster-throughput dip, and completes recovery in
//! ~233 ms (lock-rebuild-free: the lock tables are never reconstructed).

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench_config, header};
use lotus::config::SystemKind;
use lotus::sim::{Cluster, CrashEvent, FaultScript};
use lotus::workloads::WorkloadKind;

fn main() -> lotus::Result<()> {
    header("Figure 15", "3-CN simultaneous crash: throughput timeline");
    let mut cfg = bench_config();
    cfg.coordinators_per_cn = 4;
    cfg.duration_ns = 60_000_000; // 60 ms window
    cfg.timeline_interval_ns = 1_000_000; // 1 ms sampling (paper)
    let crash_at = 20_000_000;
    let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank)?;
    // The unified fault-scenario entry point (PR 7): a crash storm is
    // just a FaultScript with no message faults or suspicion windows.
    let script = FaultScript {
        crashes: vec![CrashEvent {
            at_ns: crash_at,
            cns: vec![0, 1, 2],
        }],
        ..FaultScript::default()
    };
    let report = cluster.run_with_faults(SystemKind::Lotus, &script)?;
    let t = &report.timeline;
    let to_mtps = |c: u64| c as f64 / (cfg.timeline_interval_ns as f64 / 1e9) / 1e6;
    let peak = t.iter().copied().max().unwrap_or(1).max(1);
    println!("\ntimeline (1 ms buckets):");
    for (i, &c) in t.iter().enumerate() {
        println!(
            "{:>4} ms  {:>7.3} Mtxn/s  {}",
            i,
            to_mtps(c),
            "#".repeat((c * 48 / peak) as usize)
        );
    }
    // Quantify the dip and the recovery point.
    let before: f64 = t[10..20].iter().map(|&c| to_mtps(c)).sum::<f64>() / 10.0;
    let dip = t[20..35].iter().map(|&c| to_mtps(c)).fold(f64::MAX, f64::min);
    let recover_ms = t
        .iter()
        .enumerate()
        .skip(21)
        .find(|(_, &c)| to_mtps(c) >= before * 0.9)
        .map(|(i, _)| i as i64 - 20)
        .unwrap_or(-1);
    println!("\npre-crash throughput : {before:.3} Mtxn/s");
    println!(
        "dip                  : {dip:.3} Mtxn/s ({:.1}% drop; paper: 30.6%)",
        (1.0 - dip / before) * 100.0
    );
    println!("recovery to 90%      : ~{recover_ms} ms after the crash (paper: 233 ms incl. restart)");
    // The recovery passes themselves (PR 8: pushed onto the cluster by
    // the recovery driver).
    for rec in cluster.shared.recovery_reports.lock().unwrap().iter() {
        println!(
            "recovery pass        : {} logs scanned, {} completed, {} rolled back, \
             {} torn slots discarded, {} locks released in {:.1} us",
            rec.scanned_logs,
            rec.completed,
            rec.rolled_back,
            rec.torn_slots_discarded,
            rec.released_locks,
            rec.duration_ns as f64 / 1e3
        );
    }
    let held: usize = cluster
        .shared
        .lock_services
        .iter()
        .map(|s| s.held_slots())
        .sum();
    println!("stale locks after run: {held} (must be 0)");
    Ok(())
}
