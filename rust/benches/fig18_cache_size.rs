//! Figure 18: version-table cache size sweep on TATP — cache hit rate,
//! throughput and P99 latency all improve with the cache (each hit saves
//! the CVT READ's round trip).

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench_config, header};
use lotus::config::SystemKind;
use lotus::sim::Cluster;
use lotus::workloads::WorkloadKind;

fn main() -> lotus::Result<()> {
    header("Figure 18", "TATP vs version-table cache size");
    let mut cfg = bench_config();
    cfg.coordinators_per_cn = 4;
    println!(
        "\n{:>10} {:>10} {:>10} {:>9} {:>9}",
        "entries", "hit-rate", "Mtxn/s", "p50(us)", "p99(us)"
    );
    for entries in [0usize, 1 << 4, 1 << 7, 1 << 10, 1 << 14] {
        let mut c = cfg.clone();
        if entries == 0 {
            c.features.vt_cache = false;
        } else {
            c.vt_cache_entries = entries;
        }
        let cluster = Cluster::build(&c, WorkloadKind::Tatp)?;
        let r = cluster.run(SystemKind::Lotus)?;
        let hit = cluster
            .shared
            .vt_caches
            .iter()
            .map(|vc| vc.hit_rate())
            .sum::<f64>()
            / c.n_cns as f64;
        println!(
            "{:>10} {:>9.1}% {:>10.3} {:>9} {:>9}",
            if entries == 0 { "off".into() } else { format!("{entries}") },
            hit * 100.0,
            r.mtps(),
            r.p50_us(),
            r.p99_us()
        );
    }
    println!("\npaper: hit rate and throughput rise with cache size; P99 falls.");
    Ok(())
}
