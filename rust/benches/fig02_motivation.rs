//! Figure 2: Motor and FORD throughput/latency vs concurrency on
//! SmallBank — the MN-RNIC atomics bottleneck. The paper observes ~45
//! concurrent transactions saturating 3 MNs, after which latency climbs
//! while throughput flattens.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench_config, concurrency_points, header, row};
use lotus::config::SystemKind;
use lotus::sim::Cluster;
use lotus::workloads::WorkloadKind;

fn main() -> lotus::Result<()> {
    header("Figure 2", "Motor/FORD on SmallBank vs concurrency (the MN-RNIC knee)");
    let cfg = bench_config();
    for system in [SystemKind::Motor, SystemKind::Ford] {
        println!("\n-- {} --", system.name());
        let mut last_tput = 0.0;
        for coords in concurrency_points() {
            let mut c = cfg.clone();
            c.coordinators_per_cn = coords;
            let cluster = Cluster::build(&c, WorkloadKind::SmallBank)?;
            let r = cluster.run(system)?;
            let conc = coords * c.n_cns;
            println!("{}", row(&format!("conc={conc}"), &r));
            if r.mtps() < last_tput * 1.05 && coords > 1 {
                println!("{:<18} ^ knee: throughput flattens, latency climbs", "");
            }
            last_tput = r.mtps();
        }
    }
    println!("\npaper shape: both systems hit an IOPS wall as CAS lock traffic");
    println!("saturates the MN RNICs; latency rises sharply past the knee.");
    Ok(())
}
