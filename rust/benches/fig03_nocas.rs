//! Figure 3: Motor and FORD with CAS abandoned (unsafe). The paper
//! measures Motor-no-CAS reaching 2.4x its lock-bound peak — the headroom
//! the MN-RNIC atomics bottleneck hides — while FORD gains less (it is
//! bandwidth-bound early).

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench_config, concurrency_points, header, row};
use lotus::config::SystemKind;
use lotus::sim::Cluster;
use lotus::workloads::WorkloadKind;

fn main() -> lotus::Result<()> {
    header("Figure 3", "abandoning CAS on SmallBank (unsafe upper bound)");
    let cfg = bench_config();
    let mut peaks = std::collections::HashMap::new();
    for system in [
        SystemKind::Motor,
        SystemKind::MotorNoCas,
        SystemKind::Ford,
        SystemKind::FordNoCas,
    ] {
        println!("\n-- {} --", system.name());
        let mut peak = 0.0f64;
        for coords in concurrency_points() {
            let mut c = cfg.clone();
            c.coordinators_per_cn = coords;
            let cluster = Cluster::build(&c, WorkloadKind::SmallBank)?;
            let r = cluster.run(system)?;
            println!("{}", row(&format!("conc={}", coords * c.n_cns), &r));
            peak = peak.max(r.mtps());
        }
        peaks.insert(system.name(), peak);
    }
    let motor_gain = peaks["motor-nocas"] / peaks["motor"];
    let ford_gain = peaks["ford-nocas"] / peaks["ford"];
    println!("\npeak gains from removing CAS:");
    println!("  motor: {motor_gain:.2}x   (paper: ~2.4x)");
    println!("  ford:  {ford_gain:.2}x    (paper: smaller — bandwidth-bound)");
    Ok(())
}
