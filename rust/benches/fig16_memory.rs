//! Figure 16: per-MN memory overhead of LOTUS vs Motor after running the
//! macro benchmarks. LOTUS stores every version as an independent full
//! record; Motor stores one full record plus deltas. The paper measures
//! LOTUS at only +10.3% / +4.7% / +8.5% (TATP/TPCC/SmallBank) thanks to
//! the timestamp-threshold GC.
//!
//! The simulator preallocates fixed slots, so live occupancy is computed
//! by scanning the CVTs after the run: LOTUS bytes = every valid cell at
//! full record size; Motor bytes = base version full + later versions at
//! delta size (half the record, the paper's layout).

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench_config, header};
use lotus::config::SystemKind;
use lotus::sim::Cluster;
use lotus::store::cvt::CvtSnapshot;
use lotus::workloads::WorkloadKind;

fn live_bytes(cluster: &Cluster) -> (u64, u64) {
    // (lotus_bytes, motor_equivalent_bytes) on the primary replicas.
    let mut lotus = 0u64;
    let mut motor = 0u64;
    for table in &cluster.shared.tables {
        let mn = &cluster.shared.mns[table.primary().mn];
        let sz = table.layout.cvt_size() as usize;
        let mut buf = vec![0u8; sz];
        for b in 0..table.layout.n_buckets {
            for slot in 0..table.spec.assoc {
                mn.read_bytes(table.cvt_addr(0, b, slot), &mut buf).unwrap();
                let cvt = CvtSnapshot::parse(&buf, &table.layout);
                if cvt.is_empty() {
                    continue;
                }
                let cvt_bytes = table.layout.cvt_size();
                lotus += cvt_bytes;
                motor += cvt_bytes;
                let mut first = true;
                for cell in cvt.cells.iter().filter(|c| c.valid) {
                    let full = table.layout.record_slot();
                    lotus += full;
                    motor += if first { full } else { full / 2 }; // delta
                    let _ = cell;
                    first = false;
                }
            }
        }
    }
    (lotus, motor)
}

fn main() -> lotus::Result<()> {
    header("Figure 16", "per-MN live memory: LOTUS vs Motor layout");
    let mut cfg = bench_config();
    cfg.coordinators_per_cn = 4;
    for kind in [WorkloadKind::Tatp, WorkloadKind::Tpcc, WorkloadKind::SmallBank] {
        let cluster = Cluster::build(&cfg, kind)?;
        cluster.run(SystemKind::Lotus)?;
        let (lotus, motor) = live_bytes(&cluster);
        println!(
            "{:<10} lotus {:>8.1} MB   motor-layout {:>8.1} MB   overhead {:+.1}%",
            kind.name(),
            lotus as f64 / 1e6,
            motor as f64 / 1e6,
            (lotus as f64 / motor as f64 - 1.0) * 100.0
        );
    }
    println!("\npaper: +10.3% (TATP), +4.7% (TPCC), +8.5% (SmallBank)");
    Ok(())
}
