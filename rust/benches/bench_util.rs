//! Shared helpers for the figure-reproduction benches.
//!
//! Every bench is a plain `harness = false` binary that regenerates one
//! paper table/figure: it builds the cluster, sweeps the figure's x-axis,
//! and prints the same rows/series the paper reports. Absolute numbers
//! come from the calibrated simulator (DESIGN.md §5), so the *shape* —
//! who wins, by what factor, where the knees fall — is the claim, not the
//! raw Mtxn/s.
//!
//! `LOTUS_BENCH_SCALE=full` runs closer-to-paper dataset sizes and longer
//! virtual durations (slower wall-clock); the default "quick" scale keeps
//! every bench to a couple of minutes on a small host.

#![allow(dead_code)]

use lotus::config::Config;
use lotus::metrics::RunReport;

/// Bench scale selected by `LOTUS_BENCH_SCALE` (quick | full).
pub fn full_scale() -> bool {
    std::env::var("LOTUS_BENCH_SCALE").map(|v| v == "full").unwrap_or(false)
}

/// The base configuration for figure benches.
pub fn bench_config() -> Config {
    let mut cfg = Config::paper();
    if full_scale() {
        cfg.duration_ns = 20_000_000;
        cfg.scale.kvs_keys = 1_000_000;
        cfg.scale.smallbank_accounts = 1_000_000;
        cfg.scale.tatp_subscribers = 300_000;
        cfg.scale.tpcc_warehouses = 8;
        cfg.mn_capacity = 6 << 30;
    } else {
        cfg.duration_ns = 8_000_000;
        cfg.scale.kvs_keys = 100_000;
        cfg.scale.smallbank_accounts = 100_000;
        cfg.scale.tatp_subscribers = 50_000;
        cfg.scale.tpcc_warehouses = 4;
        cfg.mn_capacity = 2 << 30;
    }
    cfg
}

/// Concurrency sweep (total concurrent transactions = n_cns x value).
pub fn concurrency_points() -> Vec<usize> {
    if full_scale() {
        vec![1, 2, 4, 6, 8, 12]
    } else {
        vec![1, 2, 4, 6]
    }
}

/// Minimal JSON object builder (the crate is dependency-free, so benches
/// hand-roll their machine-readable output). Values are emitted in
/// insertion order; floats with 3 decimals.
pub struct JsonObj {
    buf: String,
    first: bool,
}

impl Default for JsonObj {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonObj {
    /// Empty object.
    pub fn new() -> Self {
        Self {
            buf: String::from("{"),
            first: true,
        }
    }

    fn key(&mut self, k: &str) {
        if !self.first {
            self.buf.push(',');
        }
        self.first = false;
        self.buf.push('"');
        self.buf.push_str(k);
        self.buf.push_str("\":");
    }

    /// Add a float field (3 decimals; non-finite becomes null).
    pub fn num(&mut self, k: &str, v: f64) -> &mut Self {
        self.key(k);
        if v.is_finite() {
            self.buf.push_str(&format!("{v:.3}"));
        } else {
            self.buf.push_str("null");
        }
        self
    }

    /// Add an integer field.
    pub fn int(&mut self, k: &str, v: u64) -> &mut Self {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    /// Add a string field (caller guarantees no quotes/escapes needed).
    pub fn str(&mut self, k: &str, v: &str) -> &mut Self {
        self.key(k);
        self.buf.push('"');
        self.buf.push_str(v);
        self.buf.push('"');
        self
    }

    /// Add a nested object field.
    pub fn obj(&mut self, k: &str, inner: JsonObj) -> &mut Self {
        self.key(k);
        self.buf.push_str(&inner.finish());
        self
    }

    /// Close and return the JSON text.
    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

/// One formatted result row.
pub fn row(label: &str, r: &RunReport) -> String {
    format!(
        "{label:<18} {:>8.3} Mtxn/s  p50 {:>5} us  p99 {:>6} us  abort {:>5.2}%",
        r.mtps(),
        r.p50_us(),
        r.p99_us(),
        r.abort_rate() * 100.0
    )
}

/// Print the figure header.
pub fn header(fig: &str, what: &str) {
    println!("==============================================================");
    println!("{fig}: {what}");
    println!("scale: {}", if full_scale() { "full" } else { "quick (LOTUS_BENCH_SCALE=full for paper-scale)" });
    println!("==============================================================");
}
