//! §Perf: wall-clock microbenchmarks of the L3 hot paths (not a paper
//! figure — the performance-optimization deliverable). Reports real
//! nanoseconds per operation for the structures on the critical path:
//! the lock-table CAS, the LOTUS key hash, the VT cache, the RNIC queue,
//! and the end-to-end transaction rate the simulator sustains (virtual
//! transactions per wall second — the simulator's own efficiency).

#[path = "bench_util.rs"]
mod bench_util;

use std::time::Instant;

use lotus::cache::vtcache::{CachedCvt, VtCache};
use lotus::config::{Config, SystemKind};
use lotus::dm::rnic::Rnic;
use lotus::lock::table::{LockMode, LockTable};
use lotus::sharding::key::LotusKey;
use lotus::sim::Cluster;
use lotus::store::cvt::CvtSnapshot;
use lotus::workloads::WorkloadKind;

fn time<F: FnMut()>(label: &str, iters: u64, mut f: F) {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let el = t0.elapsed();
    println!(
        "{label:<44} {:>9.1} ns/op   ({iters} iters, {:?})",
        el.as_nanos() as f64 / iters as f64,
        el
    );
}

fn main() -> lotus::Result<()> {
    println!("== §Perf hot-path microbenchmarks (wall-clock) ==\n");

    // L3: lock-table acquire/release cycle (paper target: local lock on
    // CN CPUs — the op LOTUS substitutes for a 400ns+RTT MN CAS).
    let table = LockTable::with_capacity_bytes(32 << 20);
    let keys: Vec<LotusKey> = (0..1024u64).map(|i| LotusKey::compose(i, i)).collect();
    let mut i = 0usize;
    time("lock table: write acquire+release", 2_000_000, || {
        let k = keys[i & 1023];
        i += 1;
        let _ = table.acquire(k, LockMode::Write);
        table.release(k, LockMode::Write);
    });
    i = 0;
    time("lock table: read acquire+release", 2_000_000, || {
        let k = keys[i & 1023];
        i += 1;
        let _ = table.acquire(k, LockMode::Read);
        table.release(k, LockMode::Read);
    });

    // L1-pinned hash.
    let mut acc = 0u64;
    i = 0;
    time("lotus key: fingerprint56 + bucket", 10_000_000, || {
        let k = keys[i & 1023];
        i += 1;
        acc ^= k.fingerprint56() ^ k.lock_bucket(1 << 19) as u64;
    });
    std::hint::black_box(acc);

    // VT cache hit path.
    let cache = VtCache::new(64 * 1024);
    for &k in &keys {
        cache.put(
            k,
            CachedCvt {
                cvt: CvtSnapshot::empty(2),
                addr: 64,
            },
        );
    }
    i = 0;
    time("vt cache: hit (get)", 2_000_000, || {
        let k = keys[i & 1023];
        i += 1;
        std::hint::black_box(cache.get(k));
    });

    // RNIC queue charge (the per-verb accounting primitive).
    let rnic = Rnic::new();
    let mut t = 0u64;
    time("rnic: charge", 5_000_000, || {
        t += 50;
        std::hint::black_box(rnic.charge(t, 29));
    });

    // End-to-end simulator efficiency: virtual txns per wall second.
    let mut cfg = Config::small();
    cfg.duration_ns = 10_000_000;
    cfg.scale.kvs_keys = 20_000;
    let cluster = Cluster::build(
        &cfg,
        WorkloadKind::Kvs {
            rw_pct: 50,
            skewed: true,
        },
    )?;
    let t0 = Instant::now();
    let report = cluster.run(SystemKind::Lotus)?;
    let wall = t0.elapsed();
    println!(
        "\ne2e simulator: {} txns in {:?} wall = {:.0} txn/s wall ({:.3} Mtxn/s virtual)",
        report.commits,
        wall,
        report.commits as f64 / wall.as_secs_f64(),
        report.mtps()
    );
    Ok(())
}
