//! §Perf: wall-clock microbenchmarks of the L3 hot paths (not a paper
//! figure — the performance-optimization deliverable). Reports real
//! nanoseconds per operation for the structures on the critical path
//! (lock-table CAS, LOTUS key hash, VT cache, RNIC queue, `OpBatch`
//! planning, `TxnFrame` record lookup), the virtual throughput the
//! simulator sustains per system, and the pipelined coordinator's
//! doorbell accounting (depth 1 vs depth 4).
//!
//! Besides the human-readable table, the bench writes a machine-readable
//! **`BENCH_hotpath.json`** at the repository root (override the path
//! with `LOTUS_BENCH_OUT`) — the perf-trajectory baseline future PRs
//! compare against.

#[path = "bench_util.rs"]
mod bench_util;

use std::time::Instant;

use bench_util::JsonObj;
use lotus::cache::vtcache::{CachedCvt, VtCache};
use lotus::config::{Config, SystemKind};
use lotus::dm::rnic::Rnic;
use lotus::dm::OpBatch;
use lotus::lock::table::{LockMode, LockTable};
use lotus::metrics::RunReport;
use lotus::sharding::key::LotusKey;
use lotus::sim::Cluster;
use lotus::store::cvt::CvtSnapshot;
use lotus::txn::api::RecordRef;
use lotus::txn::phases::{TxnFrame, TxnRecord};
use lotus::workloads::WorkloadKind;

fn time<F: FnMut()>(label: &str, iters: u64, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let el = t0.elapsed();
    let ns_per_op = el.as_nanos() as f64 / iters as f64;
    println!("{label:<44} {ns_per_op:>9.1} ns/op   ({iters} iters, {el:?})");
    ns_per_op
}

/// One timed SmallBank LOTUS run at the given pipeline depth.
fn smallbank_run(depth: usize) -> lotus::Result<RunReport> {
    let mut cfg = Config::small();
    cfg.duration_ns = 8_000_000;
    cfg.scale.smallbank_accounts = 20_000;
    cfg.pipeline_depth = depth;
    cfg.coalesce_window_ns = 5_000;
    let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank)?;
    cluster.run(SystemKind::Lotus)
}

/// One wall-clock trajectory point: the cluster is built *outside* the
/// timed region, so the measurement covers the steady-state simulation
/// loop only. Under `--features alloc-count` the point also reports heap
/// allocations per committed transaction (global-allocator delta across
/// the run, all coordinator threads).
fn wall_point(label: &str, cfg: &Config, out: &mut JsonObj) -> lotus::Result<()> {
    let cluster = Cluster::build(cfg, WorkloadKind::SmallBank)?;
    #[cfg(feature = "alloc-count")]
    let a0 = lotus::alloc_count::total_allocs();
    let t0 = Instant::now();
    let rep = cluster.run(SystemKind::Lotus)?;
    let wall_s = t0.elapsed().as_secs_f64();
    let txns_per_s = rep.commits as f64 / wall_s.max(1e-9);
    #[cfg(feature = "alloc-count")]
    let allocs_per_txn = (lotus::alloc_count::total_allocs() - a0) as f64
        / rep.commits.max(1) as f64;
    // Without the counting allocator the field is emitted as JSON null.
    #[cfg(not(feature = "alloc-count"))]
    let allocs_per_txn = f64::NAN;
    let allocs_str = if allocs_per_txn.is_finite() {
        format!("{allocs_per_txn:.1} allocs/txn")
    } else {
        String::from("allocs/txn n/a (build with --features alloc-count)")
    };
    println!(
        "wall {label:<20} {wall_s:>7.3} s, {txns_per_s:>12.0} txn/wall-s ({} commits, {allocs_str})",
        rep.commits,
    );
    let mut p = JsonObj::new();
    p.num("wall_seconds", wall_s)
        .num("txns_per_wall_second", txns_per_s)
        .int("commits", rep.commits)
        .int("gate_publish_ns", cfg.gate_publish_ns)
        .num("allocs_per_txn", allocs_per_txn);
    out.obj(label, p);
    Ok(())
}

/// The wall-clock trajectory (ISSUE 9): real seconds and transactions
/// per wall-second — the quantity epoch-batched clock publication and
/// lane-arena reuse actually optimize — at depth 1 vs depth 4 on the
/// small topology, plus one paper-scale topology point (3 MNs x 9 CNs x
/// 4 coordinators, epoch publication at 20 us).
fn wall_clock_section() -> lotus::Result<JsonObj> {
    println!("\n== wall-clock trajectory (real seconds, not virtual) ==");
    let mut wall = JsonObj::new();
    let mut cfg = Config::small();
    cfg.duration_ns = 8_000_000;
    cfg.scale.smallbank_accounts = 20_000;
    cfg.coalesce_window_ns = 5_000;
    cfg.pipeline_depth = 1;
    wall_point("lotus_depth1", &cfg, &mut wall)?;
    cfg.pipeline_depth = 4;
    wall_point("lotus_depth4", &cfg, &mut wall)?;
    let mut paper = Config::paper();
    paper.duration_ns = 4_000_000;
    paper.scale.smallbank_accounts = 100_000;
    wall_point("lotus_paper_scale", &paper, &mut wall)?;
    Ok(wall)
}

/// Write the machine-readable output to `LOTUS_BENCH_OUT` (default:
/// `BENCH_hotpath.json` at the repository root).
fn write_json(json: String) -> lotus::Result<()> {
    let out = std::env::var("LOTUS_BENCH_OUT").unwrap_or_else(|_| {
        format!("{}/../BENCH_hotpath.json", env!("CARGO_MANIFEST_DIR"))
    });
    std::fs::write(&out, format!("{json}\n"))
        .map_err(|e| lotus::Error::Config(format!("write {out}: {e}")))?;
    println!("\nwrote {out}");
    Ok(())
}

fn main() -> lotus::Result<()> {
    // CI's `wall-clock-smoke` leg: run only the wall-clock trajectory
    // (release mode, under a time budget), skipping the microbenchmarks
    // and the virtual-throughput sections.
    if std::env::var("LOTUS_WALL_SMOKE").is_ok() {
        let wall = wall_clock_section()?;
        let mut root = JsonObj::new();
        root.str("bench", "hotpath-wall-smoke")
            .str("workload", "smallbank")
            .obj("wall_clock", wall);
        return write_json(root.finish());
    }

    println!("== §Perf hot-path microbenchmarks (wall-clock) ==\n");
    let mut structures = JsonObj::new();

    // L3: lock-table acquire/release cycle (paper target: local lock on
    // CN CPUs — the op LOTUS substitutes for a 400ns+RTT MN CAS).
    let table = LockTable::with_capacity_bytes(32 << 20);
    let keys: Vec<LotusKey> = (0..1024u64).map(|i| LotusKey::compose(i, i)).collect();
    let mut i = 0usize;
    let v = time("lock table: write acquire+release", 2_000_000, || {
        let k = keys[i & 1023];
        i += 1;
        let _ = table.acquire(k, LockMode::Write);
        table.release(k, LockMode::Write);
    });
    structures.num("lock_table_write_cycle", v);
    i = 0;
    let v = time("lock table: read acquire+release", 2_000_000, || {
        let k = keys[i & 1023];
        i += 1;
        let _ = table.acquire(k, LockMode::Read);
        table.release(k, LockMode::Read);
    });
    structures.num("lock_table_read_cycle", v);

    // L1-pinned hash.
    let mut acc = 0u64;
    i = 0;
    let v = time("lotus key: fingerprint56 + bucket", 10_000_000, || {
        let k = keys[i & 1023];
        i += 1;
        acc ^= k.fingerprint56() ^ k.lock_bucket(1 << 19) as u64;
    });
    std::hint::black_box(acc);
    structures.num("key_fingerprint_bucket", v);

    // VT cache hit path.
    let cache = VtCache::new(64 * 1024);
    for &k in &keys {
        cache.put(
            k,
            CachedCvt {
                cvt: CvtSnapshot::empty(2),
                addr: 64,
            },
        );
    }
    i = 0;
    let v = time("vt cache: hit (get)", 2_000_000, || {
        let k = keys[i & 1023];
        i += 1;
        std::hint::black_box(cache.get(k));
    });
    structures.num("vt_cache_hit", v);

    // RNIC queue charge (the per-verb accounting primitive).
    let rnic = Rnic::new();
    let mut t = 0u64;
    let v = time("rnic: charge", 5_000_000, || {
        t += 50;
        std::hint::black_box(rnic.charge(t, 29));
    });
    structures.num("rnic_charge", v);

    // OpBatch planning: 16 ops over 3 MNs per plan (the per-phase hot
    // loop; push is O(1) via the per-MN group index).
    let v = time("opbatch: plan 16 ops / 3 MNs", 200_000, || {
        let mut b = OpBatch::new();
        for j in 0..16u64 {
            b.read((j % 3) as usize, 64 + j * 8, 8);
        }
        std::hint::black_box(b.n_groups());
    });
    structures.num("opbatch_plan_16ops", v / 16.0);

    // TxnFrame record lookup at a TPC-C-sized read/write set (60
    // records): the bounded hash lookup that replaced the O(n) scan.
    let mut frame = TxnFrame::new();
    frame.reset(1, false, 1);
    let refs: Vec<RecordRef> = (0..60u64)
        .map(|j| RecordRef::new((j % 9) as u16, LotusKey::compose(j, j)))
        .collect();
    for &r in &refs {
        frame.records.push(TxnRecord::new(r, true));
    }
    i = 0;
    let v = time("txn frame: find in 60-record set", 2_000_000, || {
        let r = refs[i % 60];
        i += 1;
        std::hint::black_box(frame.find(r));
    });
    structures.num("frame_find_60rec", v);

    // End-to-end simulator efficiency + the pipelining acceptance
    // numbers: virtual Mtps and doorbells/txn at depth 1 vs depth 4.
    println!();
    let t0 = Instant::now();
    let d1 = smallbank_run(1)?;
    let wall_d1 = t0.elapsed();
    let t0 = Instant::now();
    let d4 = smallbank_run(4)?;
    let wall_d4 = t0.elapsed();
    let motor = {
        let mut cfg = Config::small();
        cfg.duration_ns = 8_000_000;
        cfg.scale.smallbank_accounts = 20_000;
        let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank)?;
        cluster.run(SystemKind::Motor)?
    };
    println!(
        "smallbank lotus depth=1: {:.3} Mtps virtual, {:.2} doorbells/txn ({} commits, {wall_d1:?} wall)",
        d1.mtps(),
        d1.doorbells_per_commit(),
        d1.commits
    );
    println!(
        "smallbank lotus depth=4: {:.3} Mtps virtual, {:.2} doorbells/txn ({} commits, {wall_d4:?} wall)",
        d4.mtps(),
        d4.doorbells_per_commit(),
        d4.commits
    );
    println!(
        "smallbank motor        : {:.3} Mtps virtual, {:.2} doorbells/txn",
        motor.mtps(),
        motor.doorbells_per_commit()
    );
    println!(
        "depth 4 / depth 1 speedup: {:.2}x; coalesced ops/doorbell at depth 4: {:.3}",
        d4.mtps() / d1.mtps().max(1e-12),
        d4.coalesced_ops as f64 / d4.doorbells.max(1) as f64
    );
    println!(
        "depth 4 step-machine: {} staged plans, {} overlap rings ({:.2} plans/ring, {:.0}% of stages), in-flight WQE hwm {}",
        d4.staged_plans,
        d4.overlap_rings,
        d4.mean_overlap_plans(),
        d4.overlap_rate() * 100.0,
        d4.inflight_wqes_hwm
    );
    println!(
        "depth 4 continuations: {} resume rings, {} lane resumes ({:.2} lanes/ring), mean ring gap {:.0} ns",
        d4.resumed_rings,
        d4.resumed_plans,
        d4.mean_resumed_lanes(),
        d4.mean_ring_gap_ns()
    );
    println!(
        "rpc plane depth=1: {:.3} messages/txn, {:.2} reqs/message; depth=4: {:.3} messages/txn, {:.2} reqs/message ({} coalesced reqs, {} lock waits, mean wait {:.0} ns)",
        d1.rpc_messages_per_commit(),
        d1.reqs_per_rpc_message(),
        d4.rpc_messages_per_commit(),
        d4.reqs_per_rpc_message(),
        d4.coalesced_rpc_reqs,
        d4.lock_waits,
        d4.mean_lock_wait_ns()
    );
    println!(
        "handler queue depth=1: {} chunks, mean wait {:.0} ns, p99 {} ns; depth=4: {} chunks, mean wait {:.0} ns, p99 {} ns",
        d1.handler_chunks,
        d1.mean_handler_wait_ns(),
        d1.handler_wait_p99_ns,
        d4.handler_chunks,
        d4.mean_handler_wait_ns(),
        d4.handler_wait_p99_ns
    );

    let mut systems = JsonObj::new();
    systems
        .num("lotus_smallbank_depth1", d1.mtps())
        .num("lotus_smallbank_depth4", d4.mtps())
        .num("motor_smallbank", motor.mtps());
    let mut doorbells = JsonObj::new();
    doorbells
        .num("lotus_depth1_per_commit", d1.doorbells_per_commit())
        .num("lotus_depth4_per_commit", d4.doorbells_per_commit())
        .int("lotus_depth4_coalesced_ops", d4.coalesced_ops)
        .num(
            "lotus_depth4_ops_per_doorbell",
            d4.ops_per_doorbell(),
        )
        .num(
            "lotus_depth4_speedup_over_depth1",
            d4.mtps() / d1.mtps().max(1e-12),
        );
    let mut overlap = JsonObj::new();
    overlap
        .int("lotus_depth4_staged_plans", d4.staged_plans)
        .int("lotus_depth4_overlap_rings", d4.overlap_rings)
        .int("lotus_depth4_overlap_plans", d4.overlap_plans)
        .num("lotus_depth4_mean_overlap_plans", d4.mean_overlap_plans())
        .num("lotus_depth4_overlap_rate", d4.overlap_rate())
        .int("lotus_depth4_inflight_wqes_hwm", d4.inflight_wqes_hwm)
        .int("lotus_depth4_resumed_rings", d4.resumed_rings)
        .int("lotus_depth4_resumed_plans", d4.resumed_plans)
        .num("lotus_depth4_mean_resumed_lanes", d4.mean_resumed_lanes())
        .num("lotus_depth4_mean_ring_gap_ns", d4.mean_ring_gap_ns());

    let mut rpc_plane = JsonObj::new();
    rpc_plane
        .num(
            "lotus_depth1_rpc_messages_per_commit",
            d1.rpc_messages_per_commit(),
        )
        .num(
            "lotus_depth4_rpc_messages_per_commit",
            d4.rpc_messages_per_commit(),
        )
        .num("lotus_depth1_reqs_per_message", d1.reqs_per_rpc_message())
        .num("lotus_depth4_reqs_per_message", d4.reqs_per_rpc_message())
        .int("lotus_depth4_rpc_messages", d4.rpc_messages)
        .int("lotus_depth4_coalesced_rpc_reqs", d4.coalesced_rpc_reqs)
        .int("lotus_depth4_lock_waits", d4.lock_waits)
        .num("lotus_depth4_mean_lock_wait_ns", d4.mean_lock_wait_ns());

    // The destination-side handler queueing delays (ISSUE 6): depth 4
    // coalesces more reqs per message, so the same load arrives in fewer,
    // larger chunks — the per-chunk wait is the congestion signal the
    // adaptive controller steers on.
    let mut handler_queue = JsonObj::new();
    handler_queue
        .int("lotus_depth1_handler_chunks", d1.handler_chunks)
        .num(
            "lotus_depth1_mean_handler_wait_ns",
            d1.mean_handler_wait_ns(),
        )
        .int("lotus_depth1_handler_wait_p99_ns", d1.handler_wait_p99_ns)
        .int("lotus_depth4_handler_chunks", d4.handler_chunks)
        .num(
            "lotus_depth4_mean_handler_wait_ns",
            d4.mean_handler_wait_ns(),
        )
        .int("lotus_depth4_handler_wait_p99_ns", d4.handler_wait_p99_ns);

    let wall_clock = wall_clock_section()?;

    let mut root = JsonObj::new();
    root.str("bench", "hotpath")
        .str("workload", "smallbank-quick")
        .obj("structures_ns_per_op", structures)
        .obj("systems_virtual_mtps", systems)
        .obj("doorbells", doorbells)
        .obj("step_machine", overlap)
        .obj("rpc_plane", rpc_plane)
        .obj("handler_queue", handler_queue)
        .obj("wall_clock", wall_clock);
    write_json(root.finish())
}
