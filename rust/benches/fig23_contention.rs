//! Figure 23: contention sweep — TPC-C with fewer warehouses raises
//! conflict rates. The paper: LOTUS keeps the highest throughput and the
//! lowest abort rate at every contention level.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench_config, header};
use lotus::config::SystemKind;
use lotus::sim::Cluster;
use lotus::workloads::WorkloadKind;

fn main() -> lotus::Result<()> {
    header("Figure 23", "TPC-C contention: warehouse-count sweep");
    let mut cfg = bench_config();
    cfg.coordinators_per_cn = 4;
    println!(
        "\n{:>11} | {:>20} | {:>20} | {:>20}",
        "warehouses", "lotus (tput abort)", "motor", "ford"
    );
    let max_wh = if bench_util::full_scale() { 8 } else { 4 };
    let mut wh = 1;
    while wh <= max_wh {
        let mut c = cfg.clone();
        c.scale.tpcc_warehouses = wh;
        let cluster = Cluster::build(&c, WorkloadKind::Tpcc)?;
        let mut cells = Vec::new();
        for system in [SystemKind::Lotus, SystemKind::Motor, SystemKind::Ford] {
            let r = cluster.run(system)?;
            cells.push(format!("{:>9.3} {:>7.2}%", r.mtps(), r.abort_rate() * 100.0));
        }
        println!(
            "{:>11} | {:>20} | {:>20} | {:>20}",
            wh, cells[0], cells[1], cells[2]
        );
        wh *= 2;
    }
    println!("\npaper: abort rates rise as warehouses shrink; LOTUS stays on top");
    println!("with the lowest abort rate at every contention level.");
    Ok(())
}
