//! Figure 12 (a–d): KVS microbenchmark — throughput and P50 latency vs
//! the read-write transaction ratio, under skewed (Zipf theta=0.99) and
//! uniform access, for LOTUS / Motor / FORD.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench_config, header};
use lotus::config::SystemKind;
use lotus::sim::Cluster;
use lotus::workloads::WorkloadKind;

fn main() -> lotus::Result<()> {
    header("Figure 12", "KVS tput + p50 vs read-write ratio (skewed / uniform)");
    let mut cfg = bench_config();
    cfg.coordinators_per_cn = if bench_util::full_scale() { 6 } else { 4 };
    let systems = [SystemKind::Lotus, SystemKind::Motor, SystemKind::Ford];
    for skewed in [true, false] {
        println!(
            "\n-- {} access (theta=0.99) --",
            if skewed { "skewed" } else { "uniform" }
        );
        println!(
            "{:>6} | {:>16} | {:>16} | {:>16}",
            "rw%", "lotus", "motor", "ford"
        );
        println!("{:->6}-+-{:->16}-+-{:->16}-+-{:->16}", "", "", "", "");
        for rw_pct in [0u32, 25, 50, 75, 100] {
            let cluster = Cluster::build(&cfg, WorkloadKind::Kvs { rw_pct, skewed })?;
            let mut cells = Vec::new();
            for system in systems {
                let r = cluster.run(system)?;
                cells.push(format!("{:>7.3}/{:>5}us", r.mtps(), r.p50_us()));
            }
            println!(
                "{:>6} | {:>16} | {:>16} | {:>16}",
                rw_pct, cells[0], cells[1], cells[2]
            );
        }
    }
    println!("\n(cell = Mtxn/s / p50)");
    println!("paper shape: LOTUS leads at every ratio; the gap widens with the");
    println!("write share (lock disaggregation removes the CAS bottleneck) and");
    println!("FORD trails due to bandwidth-heavy bucket reads + validation.");
    Ok(())
}
