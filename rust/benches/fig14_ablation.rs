//! Figure 14: the ablation — step-by-step impact of each LOTUS component
//! over a Motor baseline:
//!
//!   motor                      -> the baseline system
//!   +Full Record Store         -> motor with LOTUS's one-full-record-per-
//!                                 version layout (no delta reconstruction)
//!   +Lock Sharding (&Log/Vis)  -> LOTUS protocol: CN lock tables + the
//!                                 log/visible commit steps, but uniform
//!                                 routing and no VT cache
//!   +Two-Level Load Balancing  -> adds hybrid routing + resharding
//!   +Version Table Cache       -> full LOTUS

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench_config, header, row};
use lotus::config::{Config, SystemKind};
use lotus::sim::Cluster;
use lotus::workloads::WorkloadKind;

fn run_step(cfg: &Config, kind: WorkloadKind, system: SystemKind) -> lotus::Result<f64> {
    let cluster = Cluster::build(cfg, kind)?;
    let r = cluster.run(system)?;
    println!("{}", row(system.name(), &r));
    Ok(r.mtps())
}

fn main() -> lotus::Result<()> {
    header("Figure 14", "ablation: adding LOTUS components one at a time");
    let mut cfg = bench_config();
    cfg.coordinators_per_cn = if bench_util::full_scale() { 6 } else { 4 };
    for kind in [WorkloadKind::Tatp, WorkloadKind::Tpcc, WorkloadKind::SmallBank] {
        println!("\n===== {} =====", kind.name());
        let base = run_step(&cfg, kind, SystemKind::Motor)?;
        let full = run_step(&cfg, kind, SystemKind::MotorFullRecord)?;

        // +Lock Sharding (+ the log/visible steps): LOTUS protocol with
        // hybrid routing and the VT cache disabled.
        let mut c = cfg.clone();
        c.features.load_balancing = false;
        c.features.vt_cache = false;
        let cluster = Cluster::build(&c, kind)?;
        let r = cluster.run(SystemKind::Lotus)?;
        println!("{}", row("+lock-sharding", &r));
        let shard = r.mtps();

        // +Two-level load balancing.
        let mut c = cfg.clone();
        c.features.vt_cache = false;
        let cluster = Cluster::build(&c, kind)?;
        let r = cluster.run(SystemKind::Lotus)?;
        println!("{}", row("+load-balancing", &r));
        let lb = r.mtps();

        // +Version table cache (full LOTUS).
        let cluster = Cluster::build(&cfg, kind)?;
        let r = cluster.run(SystemKind::Lotus)?;
        println!("{}", row("+vt-cache", &r));
        let vt = r.mtps();

        println!(
            "step gains: full-record {:+.1}%, lock-sharding {:+.1}%, \
             load-balancing {:+.1}%, vt-cache {:+.1}%",
            (full / base - 1.0) * 100.0,
            (shard / full - 1.0) * 100.0,
            (lb / shard - 1.0) * 100.0,
            (vt / lb - 1.0) * 100.0
        );
    }
    println!("\npaper: +FullRecord 9-14%; +LockSharding +9.9%/+29.7% (TPCC/SB),");
    println!("-10.8% on TATP (RPC CPU); +2LLB 8-37%; +VTCache 6-20%.");
    Ok(())
}
