//! Figure 22: sensitivity to the critical-field choice on TPC-C —
//! warehouse id (default), district id, and customer id. The paper's
//! point: even a suboptimal critical field keeps LOTUS ahead, because any
//! sharding still avoids MN-side RDMA CAS.

#[path = "bench_util.rs"]
mod bench_util;

use bench_util::{bench_config, header, row};
use lotus::config::SystemKind;
use lotus::sim::Cluster;
use lotus::workloads::{CriticalField, WorkloadKind};

fn main() -> lotus::Result<()> {
    header("Figure 22", "TPC-C critical-field sensitivity (W_ID / D_ID / C_ID)");
    let mut cfg = bench_config();
    cfg.coordinators_per_cn = if bench_util::full_scale() { 6 } else { 4 };
    // Motor reference (no sharding at all).
    let cluster = Cluster::build(&cfg, WorkloadKind::Tpcc)?;
    let motor = cluster.run(SystemKind::Motor)?;
    println!("{}", row("motor (ref)", &motor));
    for (field, label) in [
        (CriticalField::Warehouse, "W_ID (default)"),
        (CriticalField::District, "D_ID"),
        (CriticalField::Customer, "C_ID"),
    ] {
        let cluster = Cluster::build(&cfg, WorkloadKind::TpccCritical(field))?;
        let r = cluster.run(SystemKind::Lotus)?;
        println!("{}", row(label, &r));
    }
    println!("\npaper: every choice beats the baseline; W_ID is best but even a");
    println!("suboptimal critical field avoids the MN-side CAS bottleneck.");
    Ok(())
}
