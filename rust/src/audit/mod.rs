//! Crash-consistency audits (PR 8).
//!
//! This module centralizes the *invariants* the crash-point sweep
//! ([`crate::sim::crashsweep`]) asserts after every crash/recovery
//! cycle, plus the [`RingTrace`] instrumentation the sweep uses to
//! enumerate its crash points (the virtual times at which a CN rings —
//! or has just completed — a doorbell, i.e. the boundaries where a
//! crash can tear distributed state).
//!
//! The invariants, checked directly against MN-resident bytes (not
//! against any coordinator-side bookkeeping):
//!
//! 1. **Money conservation** — `sum(balances) == initial + net_injected`.
//!    Under the [`transfers-only`](crate::workloads::smallbank::SmallBankWorkload::transfers_only)
//!    mix `net_injected == 0`, so this is exact at *arbitrary* crash
//!    points. A torn commit that recovery half-applied (some cells
//!    rolled forward, some back) or a resurrected aborted write shows
//!    up here as a sum drift — this one check subsumes both
//!    "committed-stays-committed" and "no resurrected aborts" for a
//!    conserving workload.
//! 2. **Zero held lock slots** — after recovery, no CN-side lock table
//!    retains a slot (orphaned locks would wedge the bank forever).
//! 3. **Replica agreement** — every account's record is present and
//!    byte-identical on every replica.
//!
//! Deliberately *not* an invariant: "no PREPARED log slot at rest".
//! Survivor CNs keep running during recovery; their in-flight commits
//! legitimately hold PREPARED slots at any instant we look.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::txn::coordinator::SharedCluster;
use crate::util::bytes::get_u64;
use crate::workloads::smallbank::{SmallBankWorkload, CHECKING, SAVINGS};
use crate::{Error, Result};

/// Issue-point boundary trace: records `(cn, t_ns)` on both sides of
/// every doorbell ring — immediately before the ring is issued and at
/// each lane's completion time. The crash-point sweep replays a
/// reference run with this enabled, then crashes a CN at each recorded
/// boundary in follow-up runs.
///
/// Disabled (the default) it is a single relaxed load per ring — the
/// hot path of normal runs stays unaffected.
#[derive(Default)]
pub struct RingTrace {
    enabled: AtomicBool,
    points: Mutex<Vec<(usize, u64)>>,
}

impl RingTrace {
    /// Start recording (clears any previously recorded points).
    pub fn enable(&self) {
        self.points.lock().unwrap().clear();
        self.enabled.store(true, Ordering::Release);
    }

    /// Stop recording (recorded points stay until [`RingTrace::take`]).
    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Release);
    }

    /// Record a ring boundary on `cn` at virtual time `t_ns`.
    #[inline]
    pub fn record(&self, cn: usize, t_ns: u64) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        self.points.lock().unwrap().push((cn, t_ns));
    }

    /// Drain the recorded `(cn, t_ns)` boundaries.
    pub fn take(&self) -> Vec<(usize, u64)> {
        std::mem::take(&mut *self.points.lock().unwrap())
    }
}

/// What [`Invariants::check`] measured (all checks already passed if
/// you hold one of these — failures return `Err`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AuditReport {
    /// Sum of all savings+checking balances read from the primaries.
    pub total_balance: u128,
    /// What the sum must equal: `initial + net_injected`.
    pub expected_balance: i128,
    /// Accounts audited (2 records each).
    pub accounts_checked: u64,
    /// Sum of held lock slots across all CN lock services (must be 0).
    pub held_lock_slots: usize,
}

/// The crash-consistency invariant checker.
pub struct Invariants;

impl Invariants {
    /// Audit `cluster` against `bank` after a quiesced run (all
    /// coordinators done, recovery — if any — complete). Returns the
    /// measurements on success; the *first* violated invariant as
    /// `Error::Runtime` otherwise.
    pub fn check(cluster: &SharedCluster, bank: &SmallBankWorkload) -> Result<AuditReport> {
        // (2) No orphaned lock slots anywhere.
        let held: usize = cluster.lock_services.iter().map(|s| s.held_slots()).sum();
        if held != 0 {
            return Err(Error::Runtime(format!(
                "audit: {held} lock slots still held after recovery"
            )));
        }

        // (1) + (3): sum balances off the primaries, byte-compare every
        // replica along the way.
        let n = bank.n_accounts();
        let replicas = cluster.cfg.replicas;
        let mut total: u128 = 0;
        for acc in 0..n {
            for table_id in [SAVINGS, CHECKING] {
                let key = SmallBankWorkload::key(table_id, acc);
                let table = cluster.table(table_id);
                let primary = table.load_get(&cluster.mns, 0, key).ok_or_else(|| {
                    Error::Runtime(format!(
                        "audit: account {acc} table {table_id} vanished from primary"
                    ))
                })?;
                for r in 1..replicas {
                    let backup = table.load_get(&cluster.mns, r, key);
                    if backup.as_deref() != Some(&primary[..]) {
                        return Err(Error::Runtime(format!(
                            "audit: account {acc} table {table_id} diverges on \
                             replica {r}: primary={primary:?} backup={backup:?}"
                        )));
                    }
                }
                total += get_u64(&primary, 0) as u128;
            }
        }

        let expected = SmallBankWorkload::initial_total(n) as i128 + bank.net_injected();
        if total as i128 != expected {
            return Err(Error::Runtime(format!(
                "audit: money not conserved: sum(balances)={total} but \
                 initial+net_injected={expected} (drift {})",
                total as i128 - expected
            )));
        }

        Ok(AuditReport {
            total_balance: total,
            expected_balance: expected,
            accounts_checked: n,
            held_lock_slots: held,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let t = RingTrace::default();
        t.record(0, 100);
        t.record(1, 200);
        assert!(t.take().is_empty());
    }

    #[test]
    fn enabled_trace_collects_and_take_drains() {
        let t = RingTrace::default();
        t.enable();
        t.record(0, 100);
        t.record(2, 250);
        t.disable();
        t.record(0, 300); // after disable: dropped
        assert_eq!(t.take(), vec![(0, 100), (2, 250)]);
        assert!(t.take().is_empty(), "take drains");
    }

    #[test]
    fn enable_clears_stale_points() {
        let t = RingTrace::default();
        t.enable();
        t.record(0, 1);
        t.disable();
        t.enable();
        t.record(1, 2);
        assert_eq!(t.take(), vec![(1, 2)]);
    }
}
