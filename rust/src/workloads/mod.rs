//! Benchmark workloads (paper 8.1): KVS, SmallBank, TATP, TPC-C.
//!
//! Every workload is written once against [`crate::txn::api::TxnApi`] and
//! runs unmodified on LOTUS and on every baseline system — exactly how
//! the paper's evaluation drives all systems with the same benchmarks.
//!
//! **Routing emulation.** The paper's routing layer sends each read-write
//! transaction to the CN owning its first record's shard and each
//! read-only transaction to a uniform-random CN (§4.3). The simulator has
//! no separate router process; instead each coordinator *conditions its
//! generated stream on the routing rule*: a read-write transaction is
//! accepted only if the routing layer would have delivered it to this CN
//! ([`RouteCtx::accept_rw`] — rejection sampling implements exactly the
//! conditional distribution). With hybrid routing disabled (the fig. 14
//! "+Two-Level Load Balancing" ablation, or non-LOTUS systems), every
//! draw is accepted, i.e. uniform routing.

pub mod kvs;
pub mod smallbank;
pub mod tatp;
pub mod tpcc;
pub mod zipf;

use std::sync::Arc;

use crate::config::Config;
use crate::sharding::key::LotusKey;
use crate::sharding::router::Router;
use crate::store::index::TableSpec;
use crate::txn::api::TxnApi;
use crate::txn::coordinator::SharedCluster;
use crate::txn::step::StepFut;
use crate::Result;

pub use kvs::KvsWorkload;
pub use smallbank::SmallBankWorkload;
pub use tatp::TatpWorkload;
pub use tpcc::{CriticalField, TpccWorkload};
pub use zipf::{AccessPattern, SkewDrift, Zipf};

/// Routing context a coordinator passes to the workload.
pub struct RouteCtx<'a> {
    /// The routing layer.
    pub router: &'a Router,
    /// The executing coordinator's CN.
    pub cn: usize,
    /// Hybrid routing active (LOTUS with load balancing on)?
    pub hybrid: bool,
}

/// Cap on rejection-sampling attempts: if a CN owns very few shards the
/// conditional draw may be rare; after this many rejections the draw is
/// accepted anyway (models routing-layer imprecision under resharding).
const MAX_ROUTE_ATTEMPTS: usize = 64;

impl<'a> RouteCtx<'a> {
    /// Would the routing layer deliver a RW transaction whose first
    /// record is `first_key` to this CN?
    #[inline]
    pub fn accept_rw(&self, first_key: LotusKey) -> bool {
        !self.hybrid || self.router.owner_of_key(first_key) == self.cn
    }

    /// Draw keys from `gen` until one routes here (bounded attempts).
    pub fn draw_routed<F: FnMut() -> LotusKey>(&self, mut gen: F) -> LotusKey {
        for _ in 0..MAX_ROUTE_ATTEMPTS {
            let k = gen();
            if self.accept_rw(k) {
                return k;
            }
        }
        gen()
    }
}

/// One benchmark workload.
pub trait Workload: Send + Sync {
    /// Display name.
    fn name(&self) -> &'static str;
    /// DB tables this workload needs (ids must be dense from 0).
    fn table_specs(&self) -> Vec<TableSpec>;
    /// Bulk-load initial data (init phase; MN CPU, uncharged).
    fn load(&self, cluster: &SharedCluster) -> Result<()>;
    /// One transaction through the API, reified as a step machine
    /// ([`StepFut`]): the driver awaits [`crate::txn::api::TxnCtl`]'s
    /// `execute_step` / `commit_step`, so the *same* workload code runs
    /// blocking on sequential conduits (every await completes within one
    /// poll — drive it with [`crate::txn::step::expect_ready`]) and
    /// parks at issue points under the pipelined scheduler. An `Err`
    /// that `is_abort()` counts as an abort; other errors are fatal.
    fn run_one<'a>(
        &'a self,
        api: &'a mut dyn TxnApi,
        route: &'a RouteCtx<'a>,
    ) -> StepFut<'a, Result<()>>;
    /// Fraction of read-only transactions in the mix (reporting).
    fn read_only_fraction(&self) -> f64;
}

/// Which benchmark to run (CLI / bench selection).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WorkloadKind {
    /// KVS microbenchmark: `rw_pct`% UpdateOne, rest ReadOne.
    Kvs {
        /// Percentage of read-write (UpdateOne) transactions.
        rw_pct: u32,
        /// Zipfian (theta=0.99) vs uniform access.
        skewed: bool,
    },
    /// SmallBank banking benchmark (85% read-write).
    SmallBank,
    /// TATP telecom benchmark (80% read-only).
    Tatp,
    /// TPC-C ordering benchmark (92% read-write).
    Tpcc,
    /// TPC-C with a chosen critical field (fig. 22).
    TpccCritical(CriticalField),
}

impl WorkloadKind {
    /// Instantiate the workload at the configured scale.
    pub fn instantiate(self, cfg: &Config) -> Arc<dyn Workload> {
        match self {
            // The moving-skew knobs (ISSUE 10) ride the config: drift
            // and flash crowd only remap the KVS rank-to-key mapping,
            // and the disabled mapping is the identity, so existing
            // configs instantiate the byte-identical legacy workload.
            WorkloadKind::Kvs { rw_pct, skewed } => Arc::new(
                KvsWorkload::new(cfg.scale.kvs_keys, rw_pct, skewed).with_drift(SkewDrift {
                    drift_interval_ns: cfg.drift_interval_ns,
                    flash_crowd_at_ns: cfg.flash_crowd_at_ns,
                }),
            ),
            WorkloadKind::SmallBank => {
                Arc::new(SmallBankWorkload::new(cfg.scale.smallbank_accounts))
            }
            WorkloadKind::Tatp => Arc::new(TatpWorkload::new(cfg.scale.tatp_subscribers)),
            WorkloadKind::Tpcc => Arc::new(TpccWorkload::new(
                cfg.scale.tpcc_warehouses,
                CriticalField::Warehouse,
            )),
            WorkloadKind::TpccCritical(f) => {
                Arc::new(TpccWorkload::new(cfg.scale.tpcc_warehouses, f))
            }
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "kvs" => WorkloadKind::Kvs {
                rw_pct: 50,
                skewed: true,
            },
            "smallbank" => WorkloadKind::SmallBank,
            "tatp" => WorkloadKind::Tatp,
            "tpcc" => WorkloadKind::Tpcc,
            other => {
                return Err(crate::Error::Config(format!(
                    "unknown workload '{other}' (kvs|smallbank|tatp|tpcc)"
                )))
            }
        })
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            WorkloadKind::Kvs { .. } => "kvs",
            WorkloadKind::SmallBank => "smallbank",
            WorkloadKind::Tatp => "tatp",
            WorkloadKind::Tpcc | WorkloadKind::TpccCritical(_) => "tpcc",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_ctx_hybrid_conditions_on_owner() {
        let router = Router::new(3);
        let ctx = RouteCtx {
            router: &router,
            cn: 1,
            hybrid: true,
        };
        let mut uid = 0u64;
        let k = ctx.draw_routed(|| {
            uid += 313; // step through the shard space
            LotusKey::compose(uid, uid)
        });
        assert_eq!(router.owner_of_key(k), 1);
    }

    #[test]
    fn route_ctx_uniform_accepts_everything() {
        let router = Router::new(3);
        let ctx = RouteCtx {
            router: &router,
            cn: 0,
            hybrid: false,
        };
        assert!(ctx.accept_rw(LotusKey::compose(4095, 1)));
    }

    #[test]
    fn workload_kind_parse() {
        assert_eq!(WorkloadKind::parse("tatp").unwrap(), WorkloadKind::Tatp);
        assert!(WorkloadKind::parse("bogus").is_err());
    }
}
