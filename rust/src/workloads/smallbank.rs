//! SmallBank banking benchmark (paper 8.1: 2 tables, 16B records, 85%
//! read-write; the workload where LOTUS gains most — small records make
//! it IOPS-bound, the regime lock disaggregation helps most).
//!
//! Standard H-Store mix:
//!   Amalgamate 15%, Balance 15% (read-only), DepositChecking 15%,
//!   SendPayment 25%, TransactSavings 15%, WriteCheck 15%.
//! => 85% read-write.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::sharding::key::LotusKey;
use crate::store::index::TableSpec;
use crate::txn::api::{RecordRef, TxnApi};
use crate::txn::coordinator::SharedCluster;
use crate::txn::step::StepFut;
use crate::util::bytes::{get_u64, put_u64};
use crate::workloads::{RouteCtx, Workload};
use crate::Result;

/// Savings table id.
pub const SAVINGS: u16 = 0;
/// Checking table id.
pub const CHECKING: u16 = 1;
/// Record: 8B balance + 8B pad = 16B (paper: "the record size is 16B").
pub const RECORD_LEN: u32 = 16;
/// Initial balance per account.
pub const INIT_BALANCE: u64 = 10_000;

/// The SmallBank workload.
pub struct SmallBankWorkload {
    n_accounts: u64,
    /// Restrict the mix to conserving operations (no deposit/withdraw
    /// class) — see [`SmallBankWorkload::transfers_only`].
    transfers_only: bool,
    /// Money created by committed deposits (audit bookkeeping).
    injected: AtomicU64,
    /// Money destroyed by committed withdrawals (audit bookkeeping).
    burned: AtomicU64,
}

impl SmallBankWorkload {
    /// Bank with `n_accounts` accounts.
    pub fn new(n_accounts: u64) -> Self {
        Self {
            n_accounts,
            transfers_only: false,
            injected: AtomicU64::new(0),
            burned: AtomicU64::new(0),
        }
    }

    /// Bank restricted to the *conserving* operations — Balance,
    /// SendPayment, Amalgamate — so `net_injected() == 0` always and
    /// the money-conservation audit is exact at **arbitrary** crash
    /// points (PR 8). The full mix cannot be audited that way: a
    /// deposit whose commit point landed but whose coordinator died
    /// before returning is completed by recovery yet never counted by
    /// the workload's `injected` bookkeeping, so the books drift by
    /// exactly the deposits lost in that gap.
    pub fn transfers_only(n_accounts: u64) -> Self {
        Self {
            transfers_only: true,
            ..Self::new(n_accounts)
        }
    }

    /// Number of accounts in the bank.
    pub fn n_accounts(&self) -> u64 {
        self.n_accounts
    }

    /// Net money committed deposits created minus withdrawals destroyed —
    /// the conservation audit: `sum(balances) == initial + net_injected`.
    pub fn net_injected(&self) -> i128 {
        self.injected.load(Ordering::Relaxed) as i128
            - self.burned.load(Ordering::Relaxed) as i128
    }

    /// Initial total balance for `n` accounts.
    pub fn initial_total(n_accounts: u64) -> u128 {
        n_accounts as u128 * 2 * INIT_BALANCE as u128
    }

    /// Account id -> LOTUS key (account id is the critical field — the
    /// paper's "payment system users transact within a small set of
    /// friend accounts" locality). The table id is folded into the unique
    /// bits so keys are globally unique across the two tables (both rows
    /// of one account still share a shard).
    #[inline]
    pub fn key(table: u16, account: u64) -> LotusKey {
        LotusKey::compose(account, account | ((table as u64 + 1) << 44))
    }

    fn balance_of(buf: &[u8]) -> u64 {
        get_u64(buf, 0)
    }

    fn encode_balance(balance: u64) -> Vec<u8> {
        let mut v = vec![0u8; RECORD_LEN as usize];
        put_u64(&mut v, 0, balance);
        v
    }

    /// A pair whose *first* account routes to the executing CN under
    /// hybrid routing (bounded rejection sampling, see module docs of
    /// [`crate::workloads`]).
    fn routed_pair(&self, api: &mut dyn TxnApi, route: &RouteCtx<'_>) -> (u64, u64) {
        let mut pair = self.two_accounts(api);
        for _ in 0..64 {
            if route.accept_rw(Self::key(CHECKING, pair.0)) {
                break;
            }
            pair = self.two_accounts(api);
        }
        pair
    }

    /// Two distinct accounts; the second is drawn near the first with
    /// high probability (the "friend set" locality of payment systems).
    fn two_accounts(&self, api: &mut dyn TxnApi) -> (u64, u64) {
        let rng = api.rng();
        let a = rng.below(self.n_accounts);
        let b = if rng.chance(0.9) {
            // Friend: within a window of 16 accounts around `a`.
            let off = rng.below(16) + 1;
            (a + off) % self.n_accounts
        } else {
            let mut b = rng.below(self.n_accounts);
            if b == a {
                b = (b + 1) % self.n_accounts;
            }
            b
        };
        (a, b)
    }
}

impl Workload for SmallBankWorkload {
    fn name(&self) -> &'static str {
        "smallbank"
    }

    fn table_specs(&self) -> Vec<TableSpec> {
        let mk = |id: u16, name: &str| TableSpec {
            id,
            name: name.into(),
            record_len: RECORD_LEN,
            ncells: 2,
            assoc: 4,
            expected_records: self.n_accounts,
        };
        vec![mk(SAVINGS, "savings"), mk(CHECKING, "checking")]
    }

    fn load(&self, cluster: &SharedCluster) -> Result<()> {
        let bal = Self::encode_balance(INIT_BALANCE);
        for acc in 0..self.n_accounts {
            cluster
                .table(SAVINGS)
                .load_insert(&cluster.mns, Self::key(SAVINGS, acc), &bal, 1)?;
            cluster
                .table(CHECKING)
                .load_insert(&cluster.mns, Self::key(CHECKING, acc), &bal, 1)?;
        }
        Ok(())
    }

    fn run_one<'a>(
        &'a self,
        api: &'a mut dyn TxnApi,
        route: &'a RouteCtx<'a>,
    ) -> StepFut<'a, Result<()>> {
        StepFut::from_future(async move {
        let dice = api.rng().percent();
        let dice = if self.transfers_only {
            // Conserving remap: Balance 15%, Amalgamate 25%,
            // SendPayment 60% (one RNG draw either way, so the stream
            // stays aligned with the full mix's).
            match dice {
                0..=14 => 0,
                15..=39 => 45,
                _ => 60,
            }
        } else {
            dice
        };
        match dice {
            // Balance (read-only, 15%): read both balances of one account.
            0..=14 => {
                let acc = api.rng().below(self.n_accounts);
                let (s, c) = (
                    RecordRef::new(SAVINGS, Self::key(SAVINGS, acc)),
                    RecordRef::new(CHECKING, Self::key(CHECKING, acc)),
                );
                api.begin(true);
                let txn = api.txn();
                txn.add_ro(s);
                txn.add_ro(c);
                txn.execute_step().await?;
                let _total = Self::balance_of(txn.value(s).unwrap_or(&[0; 16]))
                    + Self::balance_of(txn.value(c).unwrap_or(&[0; 16]));
                txn.commit_step().await
            }
            // DepositChecking (15%).
            15..=29 => {
                let key =
                    route.draw_routed(|| Self::key(CHECKING, api.rng().below(self.n_accounts)));
                let c = RecordRef::new(CHECKING, key);
                api.begin(false);
                let txn = api.txn();
                txn.add_rw(c);
                txn.execute_step().await?;
                let bal = Self::balance_of(txn.value(c).unwrap());
                txn.stage_write(c, Self::encode_balance(bal + 130));
                txn.commit_step().await?;
                self.injected.fetch_add(130, Ordering::Relaxed);
                Ok(())
            }
            // TransactSavings (15%).
            30..=44 => {
                let key =
                    route.draw_routed(|| Self::key(SAVINGS, api.rng().below(self.n_accounts)));
                let s = RecordRef::new(SAVINGS, key);
                api.begin(false);
                let txn = api.txn();
                txn.add_rw(s);
                txn.execute_step().await?;
                let bal = Self::balance_of(txn.value(s).unwrap());
                txn.stage_write(s, Self::encode_balance(bal.saturating_add(20)));
                txn.commit_step().await?;
                self.injected.fetch_add(20, Ordering::Relaxed);
                Ok(())
            }
            // Amalgamate (15%): move everything from a's savings+checking
            // into b's checking.
            45..=59 => {
                let (a, b) = self.routed_pair(api, route);
                let sa = RecordRef::new(SAVINGS, Self::key(SAVINGS, a));
                let ca = RecordRef::new(CHECKING, Self::key(CHECKING, a));
                let cb = RecordRef::new(CHECKING, Self::key(CHECKING, b));
                api.begin(false);
                let txn = api.txn();
                txn.add_rw(sa);
                txn.add_rw(ca);
                txn.add_rw(cb);
                txn.execute_step().await?;
                let total = Self::balance_of(txn.value(sa).unwrap())
                    + Self::balance_of(txn.value(ca).unwrap());
                let bb = Self::balance_of(txn.value(cb).unwrap());
                txn.stage_write(sa, Self::encode_balance(0));
                txn.stage_write(ca, Self::encode_balance(0));
                txn.stage_write(cb, Self::encode_balance(bb + total));
                txn.commit_step().await
            }
            // SendPayment (25%): checking a -> checking b.
            60..=84 => {
                let (a, b) = self.routed_pair(api, route);
                let ca = RecordRef::new(CHECKING, Self::key(CHECKING, a));
                let cb = RecordRef::new(CHECKING, Self::key(CHECKING, b));
                api.begin(false);
                let txn = api.txn();
                txn.add_rw(ca);
                txn.add_rw(cb);
                txn.execute_step().await?;
                let ba = Self::balance_of(txn.value(ca).unwrap());
                let bb = Self::balance_of(txn.value(cb).unwrap());
                let amount = 5.min(ba);
                txn.stage_write(ca, Self::encode_balance(ba - amount));
                txn.stage_write(cb, Self::encode_balance(bb + amount));
                txn.commit_step().await
            }
            // WriteCheck (15%): read savings, debit checking.
            _ => {
                let acc = {
                    let mut a = api.rng().below(self.n_accounts);
                    for _ in 0..64 {
                        if route.accept_rw(Self::key(CHECKING, a)) {
                            break;
                        }
                        a = api.rng().below(self.n_accounts);
                    }
                    a
                };
                let s = RecordRef::new(SAVINGS, Self::key(SAVINGS, acc));
                let c = RecordRef::new(CHECKING, Self::key(CHECKING, acc));
                api.begin(false);
                let txn = api.txn();
                txn.add_ro(s);
                txn.add_rw(c);
                txn.execute_step().await?;
                let total = Self::balance_of(txn.value(s).unwrap())
                    + Self::balance_of(txn.value(c).unwrap());
                let bal = Self::balance_of(txn.value(c).unwrap());
                let amount = 18.min(total).min(bal);
                txn.stage_write(c, Self::encode_balance(bal - amount));
                txn.commit_step().await?;
                self.burned.fetch_add(amount, Ordering::Relaxed);
                Ok(())
            }
        }
        })
    }

    fn read_only_fraction(&self) -> f64 {
        0.15
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_is_85_percent_rw() {
        let w = SmallBankWorkload::new(100);
        assert!((w.read_only_fraction() - 0.15).abs() < 1e-9);
    }

    #[test]
    fn two_tables_16b_records() {
        let w = SmallBankWorkload::new(100);
        let specs = w.table_specs();
        assert_eq!(specs.len(), 2);
        assert!(specs.iter().all(|s| s.record_len == 16));
    }

    #[test]
    fn balance_encoding_roundtrip() {
        let v = SmallBankWorkload::encode_balance(424242);
        assert_eq!(SmallBankWorkload::balance_of(&v), 424242);
        assert_eq!(v.len(), 16);
    }
}
