//! Zipfian key-popularity generator (YCSB-style).
//!
//! The KVS microbenchmark's skewed mode uses a Zipfian distribution with
//! theta = 0.99 (paper 8.1), the standard YCSB hot-key skew. This is the
//! Gray et al. "quickly generating billion-record synthetic databases"
//! algorithm: O(1) per draw after an O(N) zeta precomputation.

use crate::util::Xoshiro256;

/// Zipfian generator over `[0, n)`.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipf {
    /// Generator over `n` items with skew `theta` (0 < theta < 1).
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0);
        assert!((0.0..1.0).contains(&theta));
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Self {
            n,
            theta,
            alpha,
            zetan,
            eta,
            zeta2,
        }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // Direct sum; O(n) once at construction. For very large n this is
        // the dominant setup cost — benchmarks construct a Zipf per run.
        (1..=n).map(|i| 1.0 / (i as f64).powf(theta)).sum()
    }

    /// Number of items.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// Draw the next item (0 is the most popular).
    pub fn next(&self, rng: &mut Xoshiro256) -> u64 {
        let u = rng.next_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let v = ((self.eta * u - self.eta + 1.0).powf(self.alpha) * self.n as f64) as u64;
        v.min(self.n - 1)
    }

    /// Skew parameter.
    pub fn theta(&self) -> f64 {
        self.theta
    }

    /// `zeta(2, theta)` — exposed for tests.
    pub fn zeta2(&self) -> f64 {
        self.zeta2
    }
}

/// Uniform-or-zipfian access pattern.
#[derive(Debug, Clone)]
pub enum AccessPattern {
    /// Uniform over `[0, n)`.
    Uniform(u64),
    /// Zipfian.
    Zipf(Zipf),
}

impl AccessPattern {
    /// Build from a skew flag (theta = 0.99, the paper default).
    pub fn new(n: u64, skewed: bool) -> Self {
        if skewed {
            AccessPattern::Zipf(Zipf::new(n, 0.99))
        } else {
            AccessPattern::Uniform(n)
        }
    }

    /// Draw the next item.
    pub fn next(&self, rng: &mut Xoshiro256) -> u64 {
        match self {
            AccessPattern::Uniform(n) => rng.below(*n),
            AccessPattern::Zipf(z) => z.next(rng),
        }
    }

    /// Item count.
    pub fn n(&self) -> u64 {
        match self {
            AccessPattern::Uniform(n) => *n,
            AccessPattern::Zipf(z) => z.n(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_in_range() {
        let z = Zipf::new(1000, 0.99);
        let mut rng = Xoshiro256::new(1);
        for _ in 0..10_000 {
            assert!(z.next(&mut rng) < 1000);
        }
    }

    #[test]
    fn skew_concentrates_on_head() {
        let z = Zipf::new(10_000, 0.99);
        let mut rng = Xoshiro256::new(2);
        let mut head = 0u64;
        let draws = 100_000;
        for _ in 0..draws {
            if z.next(&mut rng) < 100 {
                head += 1;
            }
        }
        // With theta=.99, the top 1% of keys should get far more than 1%
        // of accesses (empirically ~60%+).
        assert!(
            head as f64 / draws as f64 > 0.4,
            "head share {}",
            head as f64 / draws as f64
        );
    }

    #[test]
    fn rank_popularity_monotone() {
        let z = Zipf::new(100, 0.9);
        let mut rng = Xoshiro256::new(3);
        let mut counts = [0u64; 100];
        for _ in 0..200_000 {
            counts[z.next(&mut rng) as usize] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn uniform_pattern_spreads() {
        let p = AccessPattern::new(10, false);
        let mut rng = Xoshiro256::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[p.next(&mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn deterministic_given_seed() {
        let z = Zipf::new(1000, 0.99);
        let mut a = Xoshiro256::new(7);
        let mut b = Xoshiro256::new(7);
        for _ in 0..100 {
            assert_eq!(z.next(&mut a), z.next(&mut b));
        }
    }
}
