//! Zipfian key-popularity generator (YCSB-style).
//!
//! The KVS microbenchmark's skewed mode uses a Zipfian distribution with
//! theta = 0.99 (paper 8.1), the standard YCSB hot-key skew. This is the
//! Gray et al. "quickly generating billion-record synthetic databases"
//! algorithm: O(1) per draw after an O(N) zeta precomputation.

use crate::util::Xoshiro256;

/// Zipfian generator over `[0, n)`.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipf {
    /// Generator over `n` items with skew `theta` (0 < theta < 1).
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0);
        assert!((0.0..1.0).contains(&theta));
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Self {
            n,
            theta,
            alpha,
            zetan,
            eta,
            zeta2,
        }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // Direct sum; O(n) once at construction. For very large n this is
        // the dominant setup cost — benchmarks construct a Zipf per run.
        (1..=n).map(|i| 1.0 / (i as f64).powf(theta)).sum()
    }

    /// Number of items.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// Draw the next item (0 is the most popular).
    pub fn next(&self, rng: &mut Xoshiro256) -> u64 {
        let u = rng.next_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let v = ((self.eta * u - self.eta + 1.0).powf(self.alpha) * self.n as f64) as u64;
        v.min(self.n - 1)
    }

    /// Skew parameter.
    pub fn theta(&self) -> f64 {
        self.theta
    }

    /// `zeta(2, theta)` — exposed for tests.
    pub fn zeta2(&self) -> f64 {
        self.zeta2
    }
}

/// Uniform-or-zipfian access pattern.
#[derive(Debug, Clone)]
pub enum AccessPattern {
    /// Uniform over `[0, n)`.
    Uniform(u64),
    /// Zipfian.
    Zipf(Zipf),
}

impl AccessPattern {
    /// Build from a skew flag (theta = 0.99, the paper default).
    pub fn new(n: u64, skewed: bool) -> Self {
        if skewed {
            AccessPattern::Zipf(Zipf::new(n, 0.99))
        } else {
            AccessPattern::Uniform(n)
        }
    }

    /// Draw the next item.
    pub fn next(&self, rng: &mut Xoshiro256) -> u64 {
        match self {
            AccessPattern::Uniform(n) => rng.below(*n),
            AccessPattern::Zipf(z) => z.next(rng),
        }
    }

    /// Item count.
    pub fn n(&self) -> u64 {
        match self {
            AccessPattern::Uniform(n) => *n,
            AccessPattern::Zipf(z) => z.n(),
        }
    }
}

/// Hot-key stride of one drift rotation. Odd (so it is coprime to the
/// 4096-shard space and the walk eventually visits every shard) and
/// about a third of it, so each rotation jumps the hot head by roughly
/// one CN's contiguous lock range under the default 3-CN owner map —
/// the hot spot *changes owner* nearly every rotation instead of
/// crawling within one CN's range.
pub const DRIFT_STRIDE: u64 = 1367;

/// Time-driven remap of access-pattern ranks onto keys (ISSUE 10).
///
/// The generators above are stationary: rank 0 is always the same key,
/// so a planner converges once and never works again. `SkewDrift` makes
/// the *mapping* from popularity rank to key id a pure function of
/// virtual time: a drifting hot-spot rotates the mapping by
/// [`DRIFT_STRIDE`] every `drift_interval_ns`, and a flash crowd
/// (`telecom_cache`-style) jumps it by half the key space at
/// `flash_crowd_at_ns` — a cold range abruptly becomes the hot set.
/// Both are deterministic given (seed, virtual time): no extra RNG
/// draws, and the disabled mapping is the identity, so a run with both
/// knobs at 0 is byte-identical to one that never heard of drift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SkewDrift {
    /// Rotate the rank-to-key mapping every this many virtual ns
    /// (0 = static).
    pub drift_interval_ns: u64,
    /// Virtual time at which the flash crowd arrives (0 = never).
    pub flash_crowd_at_ns: u64,
}

impl SkewDrift {
    /// The identity mapping (legacy stationary skew).
    pub fn disabled() -> Self {
        Self {
            drift_interval_ns: 0,
            flash_crowd_at_ns: 0,
        }
    }

    /// True when the mapping is the identity at every instant.
    pub fn is_static(&self) -> bool {
        self.drift_interval_ns == 0 && self.flash_crowd_at_ns == 0
    }

    /// Map a popularity rank (0 most popular) to a key id in `[0, n)`
    /// at virtual time `now_ns`.
    #[inline]
    pub fn map(&self, rank: u64, n: u64, now_ns: u64) -> u64 {
        if self.is_static() {
            return rank;
        }
        let mut off = 0u64;
        if self.drift_interval_ns > 0 {
            off = (now_ns / self.drift_interval_ns).wrapping_mul(DRIFT_STRIDE);
        }
        if self.flash_crowd_at_ns > 0 && now_ns >= self.flash_crowd_at_ns {
            off = off.wrapping_add(n / 2);
        }
        (rank + off % n) % n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_in_range() {
        let z = Zipf::new(1000, 0.99);
        let mut rng = Xoshiro256::new(1);
        for _ in 0..10_000 {
            assert!(z.next(&mut rng) < 1000);
        }
    }

    #[test]
    fn skew_concentrates_on_head() {
        let z = Zipf::new(10_000, 0.99);
        let mut rng = Xoshiro256::new(2);
        let mut head = 0u64;
        let draws = 100_000;
        for _ in 0..draws {
            if z.next(&mut rng) < 100 {
                head += 1;
            }
        }
        // With theta=.99, the top 1% of keys should get far more than 1%
        // of accesses (empirically ~60%+).
        assert!(
            head as f64 / draws as f64 > 0.4,
            "head share {}",
            head as f64 / draws as f64
        );
    }

    #[test]
    fn rank_popularity_monotone() {
        let z = Zipf::new(100, 0.9);
        let mut rng = Xoshiro256::new(3);
        let mut counts = [0u64; 100];
        for _ in 0..200_000 {
            counts[z.next(&mut rng) as usize] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn uniform_pattern_spreads() {
        let p = AccessPattern::new(10, false);
        let mut rng = Xoshiro256::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[p.next(&mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn static_drift_is_identity() {
        let d = SkewDrift::disabled();
        assert!(d.is_static());
        for now in [0, 1, 999_999, u64::MAX] {
            for rank in [0, 1, 17, 9_999] {
                assert_eq!(d.map(rank, 10_000, now), rank);
            }
        }
    }

    #[test]
    fn drift_rotates_every_interval() {
        let d = SkewDrift {
            drift_interval_ns: 1_000_000,
            flash_crowd_at_ns: 0,
        };
        let n = 20_000;
        // Within one interval the mapping is constant...
        assert_eq!(d.map(0, n, 0), d.map(0, n, 999_999));
        // ...and each interval boundary advances it by one stride.
        assert_eq!(d.map(0, n, 1_000_000), DRIFT_STRIDE % n);
        assert_eq!(d.map(0, n, 2_500_000), (2 * DRIFT_STRIDE) % n);
        // The rotation preserves rank order offsets (a pure shift).
        assert_eq!(
            d.map(5, n, 3_000_000),
            (d.map(0, n, 3_000_000) + 5) % n
        );
        // Deterministic: same (rank, n, now) -> same key, always.
        assert_eq!(d.map(7, n, 4_200_000), d.map(7, n, 4_200_000));
    }

    #[test]
    fn flash_crowd_jumps_half_the_key_space() {
        let d = SkewDrift {
            drift_interval_ns: 0,
            flash_crowd_at_ns: 5_000_000,
        };
        let n = 20_000;
        assert_eq!(d.map(0, n, 4_999_999), 0, "cold before the crowd hits");
        assert_eq!(d.map(0, n, 5_000_000), n / 2, "hot set jumps to the cold range");
        assert_eq!(d.map(0, n, 9_000_000), n / 2, "and stays there");
        // Composes with drift: both offsets apply after the trigger.
        let both = SkewDrift {
            drift_interval_ns: 1_000_000,
            flash_crowd_at_ns: 5_000_000,
        };
        assert_eq!(
            both.map(0, n, 6_000_000),
            (6 * DRIFT_STRIDE % n + n / 2) % n
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let z = Zipf::new(1000, 0.99);
        let mut a = Xoshiro256::new(7);
        let mut b = Xoshiro256::new(7);
        for _ in 0..100 {
            assert_eq!(z.next(&mut a), z.next(&mut b));
        }
    }
}
