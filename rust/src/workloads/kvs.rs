//! KVS microbenchmark (paper 8.2).
//!
//! One table of `(8B key, 40B value)` pairs. Two transaction types:
//! `UpdateOne` (read-write) and `ReadOne` (read-only), mixed by the
//! configured read-write percentage, with skewed (Zipfian theta=0.99) or
//! uniform access. This is the workload behind fig. 12's four panels.

use crate::sharding::key::LotusKey;
use crate::store::index::TableSpec;
use crate::txn::api::{RecordRef, TxnApi};
use crate::txn::coordinator::SharedCluster;
use crate::util::bytes::put_u64;
use crate::txn::step::StepFut;
use crate::workloads::zipf::{AccessPattern, SkewDrift};
use crate::workloads::{RouteCtx, Workload};
use crate::Result;

/// KVS value size (paper: 40 B).
pub const VALUE_LEN: u32 = 40;
/// Table id.
pub const TABLE: u16 = 0;

/// The KVS workload.
pub struct KvsWorkload {
    n_keys: u64,
    rw_pct: u32,
    pattern: AccessPattern,
    /// Moving-skew remap (ISSUE 10): identity when disabled, so the
    /// legacy stationary hot set stays byte-inert.
    drift: SkewDrift,
}

impl KvsWorkload {
    /// `n_keys` pairs, `rw_pct`% UpdateOne, skewed or uniform access.
    pub fn new(n_keys: u64, rw_pct: u32, skewed: bool) -> Self {
        assert!(rw_pct <= 100);
        Self {
            n_keys,
            rw_pct,
            pattern: AccessPattern::new(n_keys, skewed),
            drift: SkewDrift::disabled(),
        }
    }

    /// Arm a moving-skew remap (drifting hot-spot and/or flash crowd).
    pub fn with_drift(mut self, drift: SkewDrift) -> Self {
        self.drift = drift;
        self
    }

    /// Draw the next key id at virtual time `now_ns`: popularity rank
    /// from the stationary generator, remapped by the (possibly
    /// drifting) rank-to-key mapping.
    #[inline]
    fn draw(&self, rng: &mut crate::util::Xoshiro256, now_ns: u64) -> u64 {
        self.drift.map(self.pattern.next(rng), self.n_keys, now_ns)
    }

    /// The LOTUS key of logical key `i`: the key id is its own critical
    /// field (like a partition key on the primary key).
    #[inline]
    pub fn key(i: u64) -> LotusKey {
        LotusKey::compose(i, i)
    }

    fn value_of(i: u64, generation: u64) -> Vec<u8> {
        let mut v = vec![0u8; VALUE_LEN as usize];
        put_u64(&mut v, 0, i);
        put_u64(&mut v, 8, generation);
        v
    }
}

impl Workload for KvsWorkload {
    fn name(&self) -> &'static str {
        "kvs"
    }

    fn table_specs(&self) -> Vec<TableSpec> {
        vec![TableSpec {
            id: TABLE,
            name: "kv".into(),
            record_len: VALUE_LEN,
            ncells: 2, // overridden by the cluster builder to cfg.n_versions
            assoc: 4,
            expected_records: self.n_keys,
        }]
    }

    fn load(&self, cluster: &SharedCluster) -> Result<()> {
        let table = cluster.table(TABLE);
        for i in 0..self.n_keys {
            table.load_insert(&cluster.mns, Self::key(i), &Self::value_of(i, 0), 1)?;
        }
        Ok(())
    }

    fn run_one<'a>(
        &'a self,
        api: &'a mut dyn TxnApi,
        route: &'a RouteCtx<'a>,
    ) -> StepFut<'a, Result<()>> {
        StepFut::from_future(async move {
            let now = api.now();
            let is_rw = api.rng().percent() < self.rw_pct;
            if is_rw {
                let key = route.draw_routed(|| Self::key(self.draw(api.rng(), now)));
                let r = RecordRef::new(TABLE, key);
                api.begin(false);
                let txn = api.txn();
                txn.add_rw(r);
                txn.execute_step().await?;
                let generation = txn
                    .value(r)
                    .map(|v| crate::util::bytes::get_u64(v, 8))
                    .unwrap_or(0);
                txn.stage_write(r, Self::value_of(key.unique(), generation + 1));
                txn.commit_step().await
            } else {
                let key = Self::key(self.draw(api.rng(), now));
                let r = RecordRef::new(TABLE, key);
                api.begin(true);
                let txn = api.txn();
                txn.add_ro(r);
                txn.execute_step().await?;
                txn.commit_step().await
            }
        })
    }

    fn read_only_fraction(&self) -> f64 {
        1.0 - self.rw_pct as f64 / 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_embeds_id_in_shard_and_unique() {
        let k = KvsWorkload::key(0x1234);
        assert_eq!(k.shard(), 0x234);
        assert_eq!(k.unique(), 0x1234);
    }

    #[test]
    fn specs_shape() {
        let w = KvsWorkload::new(1000, 50, true);
        let specs = w.table_specs();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].record_len, 40);
        assert!((w.read_only_fraction() - 0.5).abs() < 1e-9);
    }
}
