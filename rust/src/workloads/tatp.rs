//! TATP telecom benchmark (paper 8.1: 4 tables, 80% read-only, records
//! up to 48B — the workload where LOTUS's version-table cache matters
//! most, fig. 18).
//!
//! Standard TATP mix:
//!   GetSubscriberData 35%, GetNewDestination 10%, GetAccessData 35%
//!   (read-only, 80% total); UpdateSubscriberData 2%, UpdateLocation 14%,
//!   InsertCallForwarding 2%, DeleteCallForwarding 2%.
//!
//! The subscriber id is the critical field (paper 4.2: "most transactions
//! involving a single subscriber are processed within one CN").

use crate::sharding::key::LotusKey;
use crate::store::index::TableSpec;
use crate::txn::api::{RecordRef, TxnApi};
use crate::txn::coordinator::SharedCluster;
use crate::txn::step::StepFut;
use crate::util::bytes::{get_u64, put_u64};
use crate::workloads::{RouteCtx, Workload};
use crate::{AbortReason, Result};

/// SUBSCRIBER table id (record: 48B of flags/locations).
pub const SUBSCRIBER: u16 = 0;
/// ACCESS_INFO table id (4 rows per subscriber).
pub const ACCESS_INFO: u16 = 1;
/// SPECIAL_FACILITY table id (4 rows per subscriber).
pub const SPECIAL_FACILITY: u16 = 2;
/// CALL_FORWARDING table id (0-3 rows per (subscriber, facility)).
pub const CALL_FORWARDING: u16 = 3;

/// Max record size (paper: 48B).
pub const SUB_RECORD_LEN: u32 = 48;
const SMALL_RECORD_LEN: u32 = 24;

/// The TATP workload.
pub struct TatpWorkload {
    n_subs: u64,
}

impl TatpWorkload {
    /// TATP with `n_subs` subscribers.
    pub fn new(n_subs: u64) -> Self {
        Self { n_subs }
    }

    /// Subscriber key: s_id is both critical field and unique id.
    #[inline]
    pub fn sub_key(s_id: u64) -> LotusKey {
        LotusKey::compose(s_id, s_id)
    }

    /// Per-subscriber sub-row key: critical field stays s_id so all of a
    /// subscriber's rows shard together; the row kind+index goes into the
    /// unique high bits.
    #[inline]
    pub fn row_key(s_id: u64, kind: u64, idx: u64) -> LotusKey {
        LotusKey::compose(s_id, s_id | (kind << 44) | (idx << 40))
    }

    /// Non-uniform subscriber pick (TATP spec uses a non-uniform random;
    /// a 65/35 hot-range split captures the same skew shape).
    fn pick_sub(&self, api: &mut dyn TxnApi) -> u64 {
        let rng = api.rng();
        if rng.chance(0.65) {
            rng.below((self.n_subs / 10).max(1))
        } else {
            rng.below(self.n_subs)
        }
    }

    fn sub_record(s_id: u64, generation: u64) -> Vec<u8> {
        let mut v = vec![0u8; SUB_RECORD_LEN as usize];
        put_u64(&mut v, 0, s_id);
        put_u64(&mut v, 8, generation);
        v
    }

    fn small_record(tag: u64) -> Vec<u8> {
        let mut v = vec![0u8; SMALL_RECORD_LEN as usize];
        put_u64(&mut v, 0, tag);
        v
    }
}

impl Workload for TatpWorkload {
    fn name(&self) -> &'static str {
        "tatp"
    }

    fn table_specs(&self) -> Vec<TableSpec> {
        vec![
            TableSpec {
                id: SUBSCRIBER,
                name: "subscriber".into(),
                record_len: SUB_RECORD_LEN,
                ncells: 2,
                assoc: 4,
                expected_records: self.n_subs,
            },
            TableSpec {
                id: ACCESS_INFO,
                name: "access_info".into(),
                record_len: SMALL_RECORD_LEN,
                ncells: 2,
                assoc: 4,
                expected_records: self.n_subs * 4,
            },
            TableSpec {
                id: SPECIAL_FACILITY,
                name: "special_facility".into(),
                record_len: SMALL_RECORD_LEN,
                ncells: 2,
                assoc: 4,
                expected_records: self.n_subs * 4,
            },
            TableSpec {
                id: CALL_FORWARDING,
                name: "call_forwarding".into(),
                record_len: SMALL_RECORD_LEN,
                ncells: 2,
                assoc: 4,
                expected_records: self.n_subs * 4,
            },
        ]
    }

    fn load(&self, cluster: &SharedCluster) -> Result<()> {
        for s in 0..self.n_subs {
            cluster.table(SUBSCRIBER).load_insert(
                &cluster.mns,
                Self::sub_key(s),
                &Self::sub_record(s, 0),
                1,
            )?;
            // Every subscriber gets ai_type/sf_type rows 0 and 1; a call
            // forwarding row exists for facility 0 (so reads mostly hit).
            for idx in 0..2 {
                cluster.table(ACCESS_INFO).load_insert(
                    &cluster.mns,
                    Self::row_key(s, 1, idx),
                    &Self::small_record(idx),
                    1,
                )?;
                cluster.table(SPECIAL_FACILITY).load_insert(
                    &cluster.mns,
                    Self::row_key(s, 2, idx),
                    &Self::small_record(idx),
                    1,
                )?;
            }
            cluster.table(CALL_FORWARDING).load_insert(
                &cluster.mns,
                Self::row_key(s, 3, 0),
                &Self::small_record(0),
                1,
            )?;
        }
        Ok(())
    }

    fn run_one<'a>(
        &'a self,
        api: &'a mut dyn TxnApi,
        route: &'a RouteCtx<'a>,
    ) -> StepFut<'a, Result<()>> {
        StepFut::from_future(async move {
        let dice = api.rng().percent();
        match dice {
            // GetSubscriberData (35%, RO).
            0..=34 => {
                let s = self.pick_sub(api);
                let r = RecordRef::new(SUBSCRIBER, Self::sub_key(s));
                api.begin(true);
                let txn = api.txn();
                txn.add_ro(r);
                txn.execute_step().await?;
                txn.commit_step().await
            }
            // GetNewDestination (10%, RO): special facility + forwarding.
            35..=44 => {
                let s = self.pick_sub(api);
                api.begin(true);
                let txn = api.txn();
                let sf = RecordRef::new(SPECIAL_FACILITY, Self::row_key(s, 2, 0));
                let cf = RecordRef::new(CALL_FORWARDING, Self::row_key(s, 3, 0));
                txn.add_ro(sf);
                txn.add_ro(cf);
                txn.execute_step().await?;
                txn.commit_step().await
            }
            // GetAccessData (35%, RO).
            45..=79 => {
                let s = self.pick_sub(api);
                let idx = api.rng().below(2);
                let r = RecordRef::new(ACCESS_INFO, Self::row_key(s, 1, idx));
                api.begin(true);
                let txn = api.txn();
                txn.add_ro(r);
                txn.execute_step().await?;
                txn.commit_step().await
            }
            // UpdateSubscriberData (2%): subscriber + special facility.
            80..=81 => {
                let s = self.routed_sub(api, route);
                let sub = RecordRef::new(SUBSCRIBER, Self::sub_key(s));
                let sf = RecordRef::new(SPECIAL_FACILITY, Self::row_key(s, 2, 0));
                api.begin(false);
                let txn = api.txn();
                txn.add_rw(sub);
                txn.add_rw(sf);
                txn.execute_step().await?;
                let generation = txn.value(sub).map(|v| get_u64(v, 8)).unwrap_or(0);
                txn.stage_write(sub, Self::sub_record(s, generation + 1));
                txn.stage_write(sf, Self::small_record(generation + 1));
                txn.commit_step().await
            }
            // UpdateLocation (14%).
            82..=95 => {
                let s = self.routed_sub(api, route);
                let sub = RecordRef::new(SUBSCRIBER, Self::sub_key(s));
                api.begin(false);
                let txn = api.txn();
                txn.add_rw(sub);
                txn.execute_step().await?;
                let generation = txn.value(sub).map(|v| get_u64(v, 8)).unwrap_or(0);
                txn.stage_write(sub, Self::sub_record(s, generation + 1));
                txn.commit_step().await
            }
            // InsertCallForwarding (2%).
            96..=97 => {
                let s = self.routed_sub(api, route);
                let idx = 1 + api.rng().below(3); // rows 1..3 may not exist
                let cf = RecordRef::new(CALL_FORWARDING, Self::row_key(s, 3, idx));
                api.begin(false);
                let txn = api.txn();
                txn.add_insert(cf, Self::small_record(idx));
                match txn.execute_step().await {
                    Ok(()) => txn.commit_step().await,
                    // TATP counts duplicate-insert as an expected outcome,
                    // not a system abort.
                    Err(e) if e.abort_reason() == Some(AbortReason::Duplicate) => {
                        txn.rollback();
                        Ok(())
                    }
                    Err(e) => Err(e),
                }
            }
            // DeleteCallForwarding (2%).
            _ => {
                let s = self.routed_sub(api, route);
                let idx = 1 + api.rng().below(3);
                let cf = RecordRef::new(CALL_FORWARDING, Self::row_key(s, 3, idx));
                api.begin(false);
                let txn = api.txn();
                txn.add_delete(cf);
                match txn.execute_step().await {
                    Ok(()) => txn.commit_step().await,
                    // Deleting a non-existent row is an expected outcome.
                    Err(e) if e.abort_reason() == Some(AbortReason::NotFound) => {
                        txn.rollback();
                        Ok(())
                    }
                    Err(e) => Err(e),
                }
            }
        }
        })
    }

    fn read_only_fraction(&self) -> f64 {
        0.80
    }
}

impl TatpWorkload {
    fn routed_sub(&self, api: &mut dyn TxnApi, route: &RouteCtx<'_>) -> u64 {
        let mut s = self.pick_sub(api);
        for _ in 0..64 {
            if route.accept_rw(Self::sub_key(s)) {
                break;
            }
            s = self.pick_sub(api);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_keys_share_subscriber_shard() {
        let s = 12345u64;
        let base = TatpWorkload::sub_key(s);
        for kind in 1..=3 {
            for idx in 0..3 {
                assert_eq!(TatpWorkload::row_key(s, kind, idx).shard(), base.shard());
            }
        }
    }

    #[test]
    fn row_keys_distinct() {
        let a = TatpWorkload::row_key(1, 1, 0);
        let b = TatpWorkload::row_key(1, 1, 1);
        let c = TatpWorkload::row_key(1, 2, 0);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(b, c);
    }

    #[test]
    fn four_tables_mix_80_ro() {
        let w = TatpWorkload::new(100);
        assert_eq!(w.table_specs().len(), 4);
        assert!((w.read_only_fraction() - 0.8).abs() < 1e-9);
        assert!(w.table_specs().iter().all(|s| s.record_len <= 48));
    }
}
