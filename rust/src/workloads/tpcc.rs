//! TPC-C ordering benchmark (paper 8.1: 9 tables, 92% read-write,
//! records up to 672B).
//!
//! Standard mix: NewOrder 45%, Payment 43%, OrderStatus 4%, Delivery 4%,
//! StockLevel 4%. Over 85% of transactions touch a single warehouse —
//! the locality LOTUS's application-aware sharding exploits (§4.2): the
//! **critical field** defaults to the warehouse id (fig. 22 evaluates
//! district id and customer id as suboptimal alternatives).
//!
//! Scale note: warehouses and the item catalog are scaled down from the
//! paper's 105 warehouses / 100K items so a full cluster fits one host;
//! the access *shape* (per-district order counters, 5–15 stock updates
//! per NewOrder, insert-heavy order tables) is preserved.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::sharding::key::LotusKey;
use crate::store::index::TableSpec;
use crate::txn::api::{RecordRef, TxnApi};
use crate::txn::coordinator::SharedCluster;
use crate::txn::step::StepFut;
use crate::util::bytes::{get_u64, put_u64};
use crate::workloads::{RouteCtx, Workload};
use crate::{AbortReason, Result};

/// WAREHOUSE table id.
pub const WAREHOUSE: u16 = 0;
/// DISTRICT table id.
pub const DISTRICT: u16 = 1;
/// CUSTOMER table id (672B records — the paper's max).
pub const CUSTOMER: u16 = 2;
/// HISTORY table id (insert-only).
pub const HISTORY: u16 = 3;
/// NEW_ORDER table id (insert + delete).
pub const NEW_ORDER: u16 = 4;
/// ORDER table id (insert).
pub const ORDER: u16 = 5;
/// ORDER_LINE table id (insert).
pub const ORDER_LINE: u16 = 6;
/// ITEM table id (read-only catalog).
pub const ITEM: u16 = 7;
/// STOCK table id.
pub const STOCK: u16 = 8;

/// Districts per warehouse (TPC-C spec).
pub const DISTRICTS: u64 = 10;
/// Customers per district (spec: 3000).
pub const CUSTOMERS: u64 = 3000;
/// Item catalog size (scaled from the spec's 100K).
pub const ITEMS: u64 = 10_000;
/// Orders preloaded per district.
pub const PRELOAD_ORDERS: u64 = 20;

/// Which primary-key field shards the data (fig. 22).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CriticalField {
    /// Warehouse id (default — best locality).
    Warehouse,
    /// District id.
    District,
    /// Customer id (poor locality for cross-customer transactions).
    Customer,
}

/// The TPC-C workload.
pub struct TpccWorkload {
    warehouses: u64,
    critical: CriticalField,
    next_history: AtomicU64,
}

impl TpccWorkload {
    /// TPC-C over `warehouses` warehouses.
    pub fn new(warehouses: u64, critical: CriticalField) -> Self {
        Self {
            warehouses: warehouses.max(1),
            critical,
            next_history: AtomicU64::new(1),
        }
    }

    /// Critical-field value for a (warehouse, district, customer) triple.
    #[inline]
    fn crit(&self, w: u64, d: u64, c: u64) -> u64 {
        match self.critical {
            CriticalField::Warehouse => w,
            CriticalField::District => w * DISTRICTS + d,
            CriticalField::Customer => c,
        }
    }

    /// Warehouse row key.
    pub fn warehouse_key(&self, w: u64) -> LotusKey {
        LotusKey::compose(self.crit(w, 0, 0), (1 << 47) | w)
    }

    /// District row key.
    pub fn district_key(&self, w: u64, d: u64) -> LotusKey {
        LotusKey::compose(self.crit(w, d, 0), (2 << 47) | (w * DISTRICTS + d))
    }

    /// Customer row key.
    pub fn customer_key(&self, w: u64, d: u64, c: u64) -> LotusKey {
        LotusKey::compose(
            self.crit(w, d, c),
            (3 << 47) | ((w * DISTRICTS + d) * CUSTOMERS + c),
        )
    }

    /// History row key (globally unique id).
    pub fn history_key(&self, w: u64, id: u64) -> LotusKey {
        LotusKey::compose(self.crit(w, 0, 0), (4 << 47) | id)
    }

    /// NEW_ORDER row key (distinct tag from ORDER: the two tables index
    /// the same logical order id but must not share LOTUS keys — caches
    /// and locks are keyed by LOTUS key alone).
    pub fn neworder_key(&self, w: u64, d: u64, o: u64) -> LotusKey {
        LotusKey::compose(
            self.crit(w, d, 0),
            (5 << 47) | ((w * DISTRICTS + d) << 24) | o,
        )
    }

    /// ORDER row key.
    pub fn order_key(&self, w: u64, d: u64, o: u64) -> LotusKey {
        LotusKey::compose(
            self.crit(w, d, 0),
            (6 << 47) | ((w * DISTRICTS + d) << 24) | o,
        )
    }

    /// Order-line row key.
    pub fn orderline_key(&self, w: u64, d: u64, o: u64, ol: u64) -> LotusKey {
        LotusKey::compose(
            self.crit(w, d, 0),
            (7 << 47) | ((((w * DISTRICTS + d) << 24) | o) << 4) | ol,
        )
    }

    /// Item row key (no warehouse affinity: sharded by item id).
    pub fn item_key(&self, i: u64) -> LotusKey {
        LotusKey::compose(i, (8 << 47) | i)
    }

    /// Stock row key (warehouse-local).
    pub fn stock_key(&self, w: u64, i: u64) -> LotusKey {
        LotusKey::compose(self.crit(w, 0, 0), (9 << 47) | (w * ITEMS + i))
    }

    // District record: [next_o_id, next_deliv_o_id, ytd, pad...] (96B).
    fn district_record(next_o: u64, next_deliv: u64, ytd: u64) -> Vec<u8> {
        let mut v = vec![0u8; 96];
        put_u64(&mut v, 0, next_o);
        put_u64(&mut v, 8, next_deliv);
        put_u64(&mut v, 16, ytd);
        v
    }

    fn filled(len: usize, tag: u64) -> Vec<u8> {
        let mut v = vec![0u8; len];
        put_u64(&mut v, 0, tag);
        v
    }

    fn pick_wdc(&self, api: &mut dyn TxnApi) -> (u64, u64, u64) {
        let rng = api.rng();
        (
            rng.below(self.warehouses),
            rng.below(DISTRICTS),
            rng.below(CUSTOMERS),
        )
    }

    /// A (w, d, c) whose *first record* routes to the executing CN.
    fn routed_wdc(&self, api: &mut dyn TxnApi, route: &RouteCtx<'_>) -> (u64, u64, u64) {
        let mut t = self.pick_wdc(api);
        for _ in 0..64 {
            if route.accept_rw(self.district_key(t.0, t.1)) {
                break;
            }
            t = self.pick_wdc(api);
        }
        t
    }
}

impl Workload for TpccWorkload {
    fn name(&self) -> &'static str {
        "tpcc"
    }

    fn table_specs(&self) -> Vec<TableSpec> {
        let w = self.warehouses;
        let order_capacity = (w * DISTRICTS * (PRELOAD_ORDERS + 4000)).max(4096);
        let mk = |id: u16, name: &str, record_len: u32, expected: u64| TableSpec {
            id,
            name: name.into(),
            record_len,
            ncells: 2,
            assoc: 4,
            expected_records: expected.max(64),
        };
        vec![
            mk(WAREHOUSE, "warehouse", 96, w),
            mk(DISTRICT, "district", 96, w * DISTRICTS),
            mk(CUSTOMER, "customer", 672, w * DISTRICTS * CUSTOMERS),
            mk(HISTORY, "history", 56, order_capacity),
            mk(NEW_ORDER, "new_order", 16, order_capacity),
            mk(ORDER, "order", 32, order_capacity),
            mk(ORDER_LINE, "order_line", 56, order_capacity * 10),
            mk(ITEM, "item", 88, ITEMS),
            mk(STOCK, "stock", 320, w * ITEMS),
        ]
    }

    fn load(&self, cluster: &SharedCluster) -> Result<()> {
        for w in 0..self.warehouses {
            cluster.table(WAREHOUSE).load_insert(
                &cluster.mns,
                self.warehouse_key(w),
                &Self::filled(96, w),
                1,
            )?;
            for d in 0..DISTRICTS {
                cluster.table(DISTRICT).load_insert(
                    &cluster.mns,
                    self.district_key(w, d),
                    &Self::district_record(PRELOAD_ORDERS, 0, 0),
                    1,
                )?;
                for c in 0..CUSTOMERS {
                    cluster.table(CUSTOMER).load_insert(
                        &cluster.mns,
                        self.customer_key(w, d, c),
                        &Self::filled(672, c),
                        1,
                    )?;
                }
                for o in 0..PRELOAD_ORDERS {
                    cluster.table(ORDER).load_insert(
                        &cluster.mns,
                        self.order_key(w, d, o),
                        &Self::filled(32, o),
                        1,
                    )?;
                    cluster.table(NEW_ORDER).load_insert(
                        &cluster.mns,
                        self.neworder_key(w, d, o),
                        &Self::filled(16, o),
                        1,
                    )?;
                    for ol in 0..5 {
                        cluster.table(ORDER_LINE).load_insert(
                            &cluster.mns,
                            self.orderline_key(w, d, o, ol),
                            &Self::filled(56, ol),
                            1,
                        )?;
                    }
                }
            }
            for i in 0..ITEMS {
                cluster.table(STOCK).load_insert(
                    &cluster.mns,
                    self.stock_key(w, i),
                    &Self::filled(320, 100),
                    1,
                )?;
            }
        }
        for i in 0..ITEMS {
            cluster
                .table(ITEM)
                .load_insert(&cluster.mns, self.item_key(i), &Self::filled(88, i), 1)?;
        }
        Ok(())
    }

    fn run_one<'a>(
        &'a self,
        api: &'a mut dyn TxnApi,
        route: &'a RouteCtx<'a>,
    ) -> StepFut<'a, Result<()>> {
        StepFut::from_future(async move {
            let dice = api.rng().percent();
            match dice {
                0..=44 => self.new_order(api, route).await,
                45..=87 => self.payment(api, route).await,
                88..=91 => self.order_status(api).await,
                92..=95 => self.delivery(api, route).await,
                _ => self.stock_level(api).await,
            }
        })
    }

    fn read_only_fraction(&self) -> f64 {
        0.08
    }
}

impl TpccWorkload {
    /// NewOrder (45%): read warehouse + customer, bump the district's
    /// order counter, update 5–15 stock rows, insert order + new-order +
    /// order lines. 1% abort by user error (spec 2.4.1.4).
    async fn new_order(&self, api: &mut dyn TxnApi, route: &RouteCtx<'_>) -> Result<()> {
        let (w, d, c) = self.routed_wdc(api, route);
        let ol_cnt = 5 + api.rng().below(6); // 5..=10 lines (log-slot cap)
        let user_abort = api.rng().percent() == 0;
        // 1% of lines reference a remote warehouse (spec: ~1%).
        let mut lines = Vec::with_capacity(ol_cnt as usize);
        for _ in 0..ol_cnt {
            let item = api.rng().below(ITEMS);
            let supply_w = if self.warehouses > 1 && api.rng().percent() == 0 {
                (w + 1 + api.rng().below(self.warehouses - 1)) % self.warehouses
            } else {
                w
            };
            if !lines.iter().any(|&(i, sw)| (i, sw) == (item, supply_w)) {
                lines.push((item, supply_w));
            }
        }
        let dist = RecordRef::new(DISTRICT, self.district_key(w, d));
        let wh = RecordRef::new(WAREHOUSE, self.warehouse_key(w));
        let cust = RecordRef::new(CUSTOMER, self.customer_key(w, d, c));
        api.begin(false);
        let txn = api.txn();
        txn.add_rw(dist);
        txn.add_ro(wh);
        txn.add_ro(cust);
        let stock_refs: Vec<RecordRef> = lines
            .iter()
            .map(|&(i, sw)| RecordRef::new(STOCK, self.stock_key(sw, i)))
            .collect();
        for (&(i, _), s) in lines.iter().zip(&stock_refs) {
            txn.add_ro(RecordRef::new(ITEM, self.item_key(i)));
            txn.add_rw(*s);
        }
        txn.execute_step().await?;
        if user_abort {
            txn.rollback();
            return Err(crate::abort(AbortReason::UserAbort));
        }
        // Bump the district's next order id.
        let dbuf = txn.value(dist).unwrap();
        let (next_o, next_deliv, ytd) = (get_u64(dbuf, 0), get_u64(dbuf, 8), get_u64(dbuf, 16));
        txn.stage_write(dist, Self::district_record(next_o + 1, next_deliv, ytd));
        // Decrement stock quantities.
        for s in &stock_refs {
            let q = txn.value(*s).map(|v| get_u64(v, 0)).unwrap_or(100);
            let q = if q > 10 { q - 1 } else { q + 91 };
            txn.stage_write(*s, Self::filled(320, q));
        }
        // Insert the order rows.
        let o = next_o;
        txn.add_insert(
            RecordRef::new(ORDER, self.order_key(w, d, o)),
            Self::filled(32, c),
        );
        txn.add_insert(
            RecordRef::new(NEW_ORDER, self.neworder_key(w, d, o)),
            Self::filled(16, o),
        );
        for (ol, &(i, _)) in lines.iter().enumerate() {
            txn.add_insert(
                RecordRef::new(ORDER_LINE, self.orderline_key(w, d, o, ol as u64)),
                Self::filled(56, i),
            );
        }
        txn.execute_step().await?; // second execution round locks + checks the inserts
        txn.commit_step().await
    }

    /// Payment (43%): warehouse + district + customer updates, history
    /// insert. 15% of payments are for a remote customer (spec).
    async fn payment(&self, api: &mut dyn TxnApi, route: &RouteCtx<'_>) -> Result<()> {
        let (w, d, c) = self.routed_wdc(api, route);
        let (cw, cd) = if self.warehouses > 1 && api.rng().percent() < 15 {
            (
                (w + 1 + api.rng().below(self.warehouses - 1)) % self.warehouses,
                api.rng().below(DISTRICTS),
            )
        } else {
            (w, d)
        };
        let wh = RecordRef::new(WAREHOUSE, self.warehouse_key(w));
        let dist = RecordRef::new(DISTRICT, self.district_key(w, d));
        let cust = RecordRef::new(CUSTOMER, self.customer_key(cw, cd, c));
        let hid = self.next_history.fetch_add(1, Ordering::Relaxed);
        let amount = 1 + api.rng().below(5000);
        api.begin(false);
        let txn = api.txn();
        txn.add_rw(dist);
        txn.add_rw(wh);
        txn.add_rw(cust);
        txn.add_insert(
            RecordRef::new(HISTORY, self.history_key(w, hid)),
            Self::filled(56, hid),
        );
        txn.execute_step().await?;
        let wbuf = txn.value(wh).unwrap();
        txn.stage_write(wh, Self::filled(96, get_u64(wbuf, 0).wrapping_add(amount)));
        let dbuf = txn.value(dist).unwrap();
        let (next_o, next_deliv, ytd) = (get_u64(dbuf, 0), get_u64(dbuf, 8), get_u64(dbuf, 16));
        txn.stage_write(dist, Self::district_record(next_o, next_deliv, ytd + amount));
        let cbuf = txn.value(cust).unwrap();
        txn.stage_write(cust, Self::filled(672, get_u64(cbuf, 0).wrapping_add(amount)));
        txn.commit_step().await
    }

    /// OrderStatus (4%, read-only): customer + their latest order + lines.
    async fn order_status(&self, api: &mut dyn TxnApi) -> Result<()> {
        let (w, d, c) = self.pick_wdc(api);
        let dist = RecordRef::new(DISTRICT, self.district_key(w, d));
        let cust = RecordRef::new(CUSTOMER, self.customer_key(w, d, c));
        api.begin(true);
        let txn = api.txn();
        txn.add_ro(dist);
        txn.add_ro(cust);
        txn.execute_step().await?;
        let next_o = txn.value(dist).map(|v| get_u64(v, 0)).unwrap_or(1);
        let o = next_o.saturating_sub(1);
        txn.add_ro(RecordRef::new(ORDER, self.order_key(w, d, o)));
        for ol in 0..3 {
            txn.add_ro(RecordRef::new(
                ORDER_LINE,
                self.orderline_key(w, d, o, ol),
            ));
        }
        match txn.execute_step().await {
            Ok(()) => txn.commit_step().await,
            // The latest order's lines may be fewer than 3 — expected.
            Err(e) if e.abort_reason() == Some(AbortReason::NotFound) => {
                txn.rollback();
                Ok(())
            }
            Err(e) => Err(e),
        }
    }

    /// Delivery (4%): pop the oldest new-order of a district, mark the
    /// order delivered, credit the customer.
    async fn delivery(&self, api: &mut dyn TxnApi, route: &RouteCtx<'_>) -> Result<()> {
        let (w, d, _) = self.routed_wdc(api, route);
        let dist = RecordRef::new(DISTRICT, self.district_key(w, d));
        api.begin(false);
        let txn = api.txn();
        txn.add_rw(dist);
        txn.execute_step().await?;
        let dbuf = txn.value(dist).unwrap();
        let (next_o, next_deliv, ytd) = (get_u64(dbuf, 0), get_u64(dbuf, 8), get_u64(dbuf, 16));
        if next_deliv >= next_o {
            // Nothing to deliver — commit the no-op (expected outcome).
            return txn.commit_step().await;
        }
        let o = next_deliv;
        let no = RecordRef::new(NEW_ORDER, self.neworder_key(w, d, o));
        let ord = RecordRef::new(ORDER, self.order_key(w, d, o));
        txn.add_delete(no);
        txn.add_rw(ord);
        match txn.execute_step().await {
            Ok(()) => {}
            // Another delivery raced us past this order id — expected.
            Err(e) if e.abort_reason() == Some(AbortReason::NotFound) => {
                txn.rollback();
                return Ok(());
            }
            Err(e) => return Err(e),
        }
        let cid = txn.value(ord).map(|v| get_u64(v, 0)).unwrap_or(0) % CUSTOMERS;
        txn.stage_write(ord, Self::filled(32, cid | (1 << 32)));
        txn.stage_write(dist, Self::district_record(next_o, next_deliv + 1, ytd));
        let cust = RecordRef::new(CUSTOMER, self.customer_key(w, d, cid));
        txn.add_rw(cust);
        txn.execute_step().await?;
        let cbuf = txn.value(cust).unwrap();
        txn.stage_write(cust, Self::filled(672, get_u64(cbuf, 0) + 1));
        txn.commit_step().await
    }

    /// StockLevel (4%, read-only): recent orders' lines + their stock.
    /// With few versions this is the high-abort transaction of figs 19/20
    /// (its long read set keeps missing a version at/below its snapshot).
    async fn stock_level(&self, api: &mut dyn TxnApi) -> Result<()> {
        let (w, d, _) = self.pick_wdc(api);
        let dist = RecordRef::new(DISTRICT, self.district_key(w, d));
        api.begin(true);
        let txn = api.txn();
        txn.add_ro(dist);
        txn.execute_step().await?;
        let next_o = txn.value(dist).map(|v| get_u64(v, 0)).unwrap_or(1);
        let from = next_o.saturating_sub(5);
        let mut line_refs = Vec::new();
        for o in from..next_o {
            for ol in 0..2 {
                line_refs.push(RecordRef::new(
                    ORDER_LINE,
                    self.orderline_key(w, d, o, ol),
                ));
            }
        }
        for r in &line_refs {
            txn.add_ro(*r);
        }
        match txn.execute_step().await {
            Ok(()) => {}
            Err(e) if e.abort_reason() == Some(AbortReason::NotFound) => {
                txn.rollback();
                return Ok(());
            }
            Err(e) => return Err(e),
        }
        // Check the stock of the referenced items.
        let items: Vec<u64> = line_refs
            .iter()
            .filter_map(|r| txn.value(*r).map(|v| get_u64(v, 0) % ITEMS))
            .collect();
        for i in items.into_iter().take(5) {
            txn.add_ro(RecordRef::new(STOCK, self.stock_key(w, i)));
        }
        txn.execute_step().await?;
        txn.commit_step().await
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warehouse_critical_field_groups_rows() {
        let t = TpccWorkload::new(4, CriticalField::Warehouse);
        let w = 3;
        let shard = t.warehouse_key(w).shard();
        assert_eq!(t.district_key(w, 5).shard(), shard);
        assert_eq!(t.customer_key(w, 5, 100).shard(), shard);
        assert_eq!(t.order_key(w, 5, 77).shard(), shard);
        assert_eq!(t.stock_key(w, 42).shard(), shard);
    }

    #[test]
    fn district_critical_field_separates_districts() {
        let t = TpccWorkload::new(4, CriticalField::District);
        assert_ne!(t.district_key(0, 1).shard(), t.district_key(0, 2).shard());
        // Rows of one district still group.
        assert_eq!(
            t.district_key(0, 1).shard(),
            t.customer_key(0, 1, 5).shard()
        );
    }

    #[test]
    fn keys_unique_across_tables() {
        let t = TpccWorkload::new(2, CriticalField::Warehouse);
        let keys = [
            t.warehouse_key(1),
            t.district_key(1, 2),
            t.customer_key(1, 2, 3),
            t.history_key(1, 9),
            t.order_key(1, 2, 9),
            t.orderline_key(1, 2, 9, 1),
            t.item_key(9),
            t.stock_key(1, 9),
        ];
        for (i, a) in keys.iter().enumerate() {
            for b in keys.iter().skip(i + 1) {
                assert_ne!(a.0, b.0, "key collision");
            }
        }
    }

    #[test]
    fn nine_tables() {
        let t = TpccWorkload::new(2, CriticalField::Warehouse);
        let specs = t.table_specs();
        assert_eq!(specs.len(), 9);
        assert_eq!(specs[CUSTOMER as usize].record_len, 672);
        assert!((t.read_only_fraction() - 0.08).abs() < 1e-9);
    }

    #[test]
    fn unique_ids_fit_52_bits() {
        let t = TpccWorkload::new(128, CriticalField::Warehouse);
        let k = t.orderline_key(127, 9, (1 << 24) - 1, 15);
        assert!(k.unique() < (1 << 52));
        let s = t.stock_key(127, ITEMS - 1);
        assert!(s.unique() < (1 << 52));
        let n = t.neworder_key(127, 9, (1 << 24) - 1);
        assert_ne!(n.0, t.order_key(127, 9, (1 << 24) - 1).0);
    }
}
