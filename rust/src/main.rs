//! `lotus` — the cluster launcher / benchmark CLI.
//!
//! ```text
//! lotus run      --system lotus --workload smallbank [--set k=v ...]
//! lotus compare  --workload tatp [--systems lotus,motor,ford]
//! lotus recovery [--crash-cns 3] [--at-ms 20]
//! lotus info
//! ```
//!
//! `--set key=value` overrides any [`lotus::config::Config`] field
//! (repeatable); `--config path` loads a `key = value` file first.

use std::process::ExitCode;

use lotus::config::{Config, SystemKind};
use lotus::metrics::RunReport;
use lotus::sim::{Cluster, CrashEvent};
use lotus::workloads::WorkloadKind;

fn usage() -> &'static str {
    "usage:\n  lotus run --system <lotus|motor|ford|motor-nocas|ford-nocas|ideal-lock> \\\n            --workload <kvs|smallbank|tatp|tpcc> [--config FILE] [--set k=v ...]\n  lotus compare --workload <w> [--systems a,b,c] [--config FILE] [--set k=v ...]\n  lotus recovery [--crash-cns N] [--at-ms T] [--config FILE] [--set k=v ...]\n  lotus info"
}

struct Args {
    cmd: String,
    system: String,
    systems: Option<String>,
    workload: String,
    crash_cns: usize,
    at_ms: u64,
    config: Option<String>,
    sets: Vec<(String, String)>,
}

fn parse_args() -> Result<Args, String> {
    let mut argv = std::env::args().skip(1);
    let cmd = argv.next().ok_or_else(|| usage().to_string())?;
    let mut args = Args {
        cmd,
        system: "lotus".into(),
        systems: None,
        workload: "smallbank".into(),
        crash_cns: 3,
        at_ms: 20,
        config: None,
        sets: Vec::new(),
    };
    while let Some(flag) = argv.next() {
        let mut need = |name: &str| -> Result<String, String> {
            argv.next().ok_or(format!("{name} needs a value"))
        };
        match flag.as_str() {
            "--system" => args.system = need("--system")?,
            "--systems" => args.systems = Some(need("--systems")?),
            "--workload" => args.workload = need("--workload")?,
            "--crash-cns" => {
                args.crash_cns = need("--crash-cns")?
                    .parse()
                    .map_err(|_| "--crash-cns: not a number".to_string())?
            }
            "--at-ms" => {
                args.at_ms = need("--at-ms")?
                    .parse()
                    .map_err(|_| "--at-ms: not a number".to_string())?
            }
            "--config" => args.config = Some(need("--config")?),
            "--set" => {
                let kv = need("--set")?;
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| "--set expects key=value".to_string())?;
                args.sets.push((k.trim().into(), v.trim().into()));
            }
            other => return Err(format!("unknown flag '{other}'\n{}", usage())),
        }
    }
    Ok(args)
}

fn build_config(args: &Args) -> Result<Config, lotus::Error> {
    let mut cfg = Config::paper();
    if let Some(path) = &args.config {
        let text = std::fs::read_to_string(path)?;
        cfg.load_overrides(&text)?;
    }
    for (k, v) in &args.sets {
        cfg.set(k, v)?;
    }
    cfg.validate()
}

fn print_report(label: &str, r: &RunReport) {
    println!(
        "{label:<14} {:>9.3} Mtxn/s  p50 {:>7} us  p99 {:>7} us  abort {:>5.1}%  {:>5.1} db/txn  ({} commits)",
        r.mtps(),
        r.p50_us(),
        r.p99_us(),
        r.abort_rate() * 100.0,
        r.doorbells_per_commit(),
        r.commits
    );
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return ExitCode::from(2);
        }
    };
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &Args) -> lotus::Result<()> {
    match args.cmd.as_str() {
        "run" => {
            let cfg = build_config(args)?;
            let system = SystemKind::parse(&args.system)?;
            let kind = WorkloadKind::parse(&args.workload)?;
            eprintln!(
                "building {} cluster: {} MNs, {} CNs x {} coordinators x depth {} ...",
                kind.name(),
                cfg.n_mns,
                cfg.n_cns,
                cfg.coordinators_per_cn,
                cfg.pipeline_depth
            );
            let cluster = Cluster::build(&cfg, kind)?;
            eprintln!("running {} for {} ms virtual ...", system.name(), cfg.duration_ns / 1_000_000);
            let report = cluster.run(system)?;
            print_report(system.name(), &report);
            for (reason, n) in &report.abort_reasons {
                println!("  abort[{reason}] = {n}");
            }
            Ok(())
        }
        "compare" => {
            let cfg = build_config(args)?;
            let kind = WorkloadKind::parse(&args.workload)?;
            let list = args
                .systems
                .clone()
                .unwrap_or_else(|| "lotus,motor,ford".into());
            let systems: Vec<SystemKind> = list
                .split(',')
                .map(SystemKind::parse)
                .collect::<lotus::Result<_>>()?;
            eprintln!("building {} cluster ...", kind.name());
            let cluster = Cluster::build(&cfg, kind)?;
            for system in systems {
                let report = cluster.run(system)?;
                print_report(system.name(), &report);
            }
            Ok(())
        }
        "recovery" => {
            let mut cfg = build_config(args)?;
            if cfg.timeline_interval_ns == 0 {
                cfg.timeline_interval_ns = 1_000_000;
            }
            let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank)?;
            let cns: Vec<usize> = (0..args.crash_cns.min(cfg.n_cns - 1)).collect();
            eprintln!(
                "crashing CNs {:?} at {} ms; duration {} ms",
                cns,
                args.at_ms,
                cfg.duration_ns / 1_000_000
            );
            let report = cluster.run_with_events(
                SystemKind::Lotus,
                &[CrashEvent {
                    at_ns: args.at_ms * 1_000_000,
                    cns,
                }],
            )?;
            print_report("lotus", &report);
            println!("timeline (Mtxn/s per {} ms):", report.timeline_interval_ns / 1_000_000);
            for (i, c) in report.timeline.iter().enumerate() {
                let mtps = *c as f64 / (report.timeline_interval_ns as f64 / 1e9) / 1e6;
                println!("  {:>4} ms  {:>8.3}", i as u64 * report.timeline_interval_ns / 1_000_000, mtps);
            }
            Ok(())
        }
        "info" => {
            println!("lotus {} — disaggregated transactions with disaggregated locks", env!("CARGO_PKG_VERSION"));
            let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            match lotus::runtime::Manifest::load(dir.join("manifest.json")) {
                Ok(m) => println!(
                    "artifacts: rebalance {}x{} ({}), shard_hash batch {} ({})",
                    m.n_cns, m.n_shards, m.rebalance_file, m.hash_batch, m.shard_hash_file
                ),
                Err(e) => println!("artifacts: not built ({e}); run `make artifacts`"),
            }
            match lotus::runtime::XlaRuntime::cpu() {
                Ok(rt) => println!("pjrt: {} client ready", rt.platform()),
                Err(e) => println!("pjrt: unavailable ({e})"),
            }
            Ok(())
        }
        other => Err(lotus::Error::Config(format!(
            "unknown command '{other}'\n{}",
            usage()
        ))),
    }
}
