//! Consecutive version tables: parse/serialize + version selection.
//!
//! A CVT is read from the memory pool in ONE one-sided READ (its raison
//! d'être, paper 4.4) and parsed into a [`CvtSnapshot`]. Cell encoding:
//!
//! ```text
//! word0: head_cv u8 | valid u8 | len u16 | pad4     word2: record addr u64
//! word1: version u64                      word3: tail_cv u8 | pad7
//! ```
//!
//! `version == u64::MAX` is the INVISIBLE marker a committing writer uses
//! between *Write Data* and *Write Visible* (paper 5.1). Head/tail CVs
//! bracket the cell so a torn cell overwrite is detectable, and the cell
//! CV must match the record slot's seqlock CV (section 7.1).

use crate::store::layout::{Layout, CELL_SIZE, CVT_HEADER};
use crate::util::bytes::{get_u16, get_u64, put_u16, put_u64};

/// Version marker for not-yet-visible data (64-bit max, paper 5.1).
pub const INVISIBLE: u64 = u64::MAX;

/// One parsed CVT cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellSnapshot {
    /// Seqlock CV of the record slot this cell points to.
    pub cv: u8,
    /// Is the cell occupied?
    pub valid: bool,
    /// Payload length of THIS version (versions may differ in length).
    pub len: u16,
    /// Commit timestamp ([`INVISIBLE`] while a commit is in flight).
    pub version: u64,
    /// Record slot address on the same MN.
    pub addr: u64,
    /// True iff head and tail CVs matched when parsed.
    pub consistent: bool,
}

/// One parsed CVT.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CvtSnapshot {
    /// The record's LOTUS key.
    pub key: u64,
    /// Is this CVT slot occupied? (explicit flag — key 0 is a legal key).
    pub occupied: bool,
    /// Owning table.
    pub table_id: u16,
    /// Record payload length.
    pub record_len: u16,
    /// Cells (version slots).
    pub cells: Vec<CellSnapshot>,
}

impl CvtSnapshot {
    /// An empty (unoccupied) CVT.
    pub fn empty(ncells: u8) -> Self {
        Self {
            key: 0,
            occupied: false,
            table_id: 0,
            record_len: 0,
            cells: vec![
                CellSnapshot {
                    cv: 0,
                    valid: false,
                    len: 0,
                    version: 0,
                    addr: 0,
                    consistent: true,
                };
                ncells as usize
            ],
        }
    }

    /// Is this CVT slot unoccupied?
    pub fn is_empty(&self) -> bool {
        !self.occupied
    }

    /// Parse from `layout.cvt_size()` bytes.
    pub fn parse(buf: &[u8], layout: &Layout) -> Self {
        debug_assert!(buf.len() as u64 >= layout.cvt_size());
        let key = get_u64(buf, 0);
        let table_id = get_u16(buf, 8);
        let record_len = get_u16(buf, 10);
        let ncells = buf[12].min(layout.ncells);
        let occupied = buf[13] != 0;
        let mut cells = Vec::with_capacity(layout.ncells as usize);
        for c in 0..layout.ncells {
            if c >= ncells {
                cells.push(CellSnapshot {
                    cv: 0,
                    valid: false,
                    len: 0,
                    version: 0,
                    addr: 0,
                    consistent: true,
                });
                continue;
            }
            let off = (CVT_HEADER + c as u64 * CELL_SIZE) as usize;
            let head_cv = buf[off];
            let valid = buf[off + 1] != 0;
            let len = get_u16(buf, off + 2);
            let version = get_u64(buf, off + 8);
            let addr = get_u64(buf, off + 16);
            let tail_cv = buf[off + 24];
            cells.push(CellSnapshot {
                cv: head_cv,
                valid,
                len,
                version,
                addr,
                consistent: head_cv == tail_cv,
            });
        }
        Self {
            key,
            occupied,
            table_id,
            record_len,
            cells,
        }
    }

    /// Serialize into `layout.cvt_size()` bytes.
    pub fn serialize(&self, layout: &Layout) -> Vec<u8> {
        let mut buf = vec![0u8; layout.cvt_size() as usize];
        put_u64(&mut buf, 0, self.key);
        put_u16(&mut buf, 8, self.table_id);
        put_u16(&mut buf, 10, self.record_len);
        buf[12] = self.cells.len() as u8;
        buf[13] = self.occupied as u8;
        for (c, cell) in self.cells.iter().enumerate() {
            let off = (CVT_HEADER + c as u64 * CELL_SIZE) as usize;
            buf[off] = cell.cv;
            buf[off + 1] = cell.valid as u8;
            put_u16(&mut buf, off + 2, cell.len);
            put_u64(&mut buf, off + 8, cell.version);
            put_u64(&mut buf, off + 16, cell.addr);
            buf[off + 24] = cell.cv; // tail CV mirrors head
        }
        buf
    }

    /// Serialize a single cell (the 32B written by *Write Data*).
    pub fn serialize_cell(cell: &CellSnapshot) -> [u8; CELL_SIZE as usize] {
        let mut buf = [0u8; CELL_SIZE as usize];
        buf[0] = cell.cv;
        buf[1] = cell.valid as u8;
        put_u16(&mut buf, 2, cell.len);
        put_u64(&mut buf, 8, cell.version);
        put_u64(&mut buf, 16, cell.addr);
        buf[24] = cell.cv;
        buf
    }

    /// MVCC read rule: the cell with the **largest version <= ts** among
    /// valid, visible, consistent cells. Also reports whether any visible
    /// version **> ts** exists (the serializability abort condition for
    /// read-write transactions, paper 5.1).
    pub fn select_version(&self, ts: u64) -> (Option<&CellSnapshot>, bool) {
        let mut best: Option<&CellSnapshot> = None;
        let mut newer = false;
        for c in &self.cells {
            if !c.valid || !c.consistent || c.version == INVISIBLE {
                continue;
            }
            if c.version > ts {
                newer = true;
            } else if best.is_none_or(|b| c.version > b.version) {
                best = Some(c);
            }
        }
        (best, newer)
    }

    /// Latest visible version, if any.
    pub fn latest(&self) -> Option<&CellSnapshot> {
        self.cells
            .iter()
            .filter(|c| c.valid && c.version != INVISIBLE && c.consistent)
            .max_by_key(|c| c.version)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> Layout {
        Layout {
            ncells: 3,
            assoc: 4,
            record_len: 40,
            n_buckets: 16,
        }
    }

    fn cell(version: u64, addr: u64, cv: u8) -> CellSnapshot {
        CellSnapshot {
            cv,
            valid: true,
            len: 8,
            version,
            addr,
            consistent: true,
        }
    }

    #[test]
    fn roundtrip() {
        let l = layout();
        let cvt = CvtSnapshot {
            key: 0xABCD_EF01_2345,
            occupied: true,
            table_id: 3,
            record_len: 40,
            cells: vec![cell(10, 0x100, 1), cell(20, 0x200, 2), cell(INVISIBLE, 0x300, 3)],
        };
        let buf = cvt.serialize(&l);
        assert_eq!(buf.len() as u64, l.cvt_size());
        let parsed = CvtSnapshot::parse(&buf, &l);
        assert_eq!(parsed, cvt);
    }

    #[test]
    fn empty_roundtrip() {
        let l = layout();
        let e = CvtSnapshot::empty(3);
        assert!(e.is_empty());
        let parsed = CvtSnapshot::parse(&e.serialize(&l), &l);
        assert!(parsed.is_empty());
    }

    #[test]
    fn select_version_rules() {
        let mut cvt = CvtSnapshot::empty(3);
        cvt.key = 1;
        cvt.cells = vec![cell(10, 0xA, 0), cell(30, 0xB, 0), cell(20, 0xC, 0)];
        // ts=25: best is 20, newer=true (30 exists).
        let (best, newer) = cvt.select_version(25);
        assert_eq!(best.unwrap().version, 20);
        assert!(newer);
        // ts=35: best is 30, no newer.
        let (best, newer) = cvt.select_version(35);
        assert_eq!(best.unwrap().version, 30);
        assert!(!newer);
        // ts=5: nothing visible at/below, newer=true.
        let (best, newer) = cvt.select_version(5);
        assert!(best.is_none());
        assert!(newer);
    }

    #[test]
    fn select_skips_invisible_and_invalid() {
        let mut cvt = CvtSnapshot::empty(3);
        cvt.key = 1;
        cvt.cells = vec![
            cell(INVISIBLE, 0xA, 0),
            CellSnapshot {
                valid: false,
                ..cell(5, 0xB, 0)
            },
            cell(7, 0xC, 0),
        ];
        let (best, newer) = cvt.select_version(100);
        assert_eq!(best.unwrap().version, 7);
        assert!(!newer, "INVISIBLE must not count as newer");
    }

    #[test]
    fn select_skips_torn_cells() {
        let mut cvt = CvtSnapshot::empty(2);
        cvt.key = 1;
        let mut torn = cell(50, 0xA, 1);
        torn.consistent = false;
        cvt.cells = vec![torn, cell(7, 0xC, 0)];
        let (best, _) = cvt.select_version(100);
        assert_eq!(best.unwrap().version, 7, "torn cell must be skipped");
    }

    #[test]
    fn torn_cell_detected_on_parse() {
        let l = layout();
        let cvt = CvtSnapshot {
            key: 9,
            occupied: true,
            table_id: 1,
            record_len: 8,
            cells: vec![cell(1, 0x10, 5), cell(2, 0x20, 6), cell(3, 0x30, 7)],
        };
        let mut buf = cvt.serialize(&l);
        // Corrupt the tail CV of cell 1.
        let off = (CVT_HEADER + CELL_SIZE + 24) as usize;
        buf[off] = 99;
        let parsed = CvtSnapshot::parse(&buf, &l);
        assert!(parsed.cells[0].consistent);
        assert!(!parsed.cells[1].consistent);
        assert!(parsed.cells[2].consistent);
    }

    #[test]
    fn prop_select_version_matches_naive() {
        crate::testing::prop(100, |g| {
            let n = g.usize(1, 6);
            let cells: Vec<CellSnapshot> = (0..n)
                .map(|i| {
                    let mut c = cell(g.u64(0, 100), i as u64 * 8, 0);
                    c.valid = g.bool(0.8);
                    if g.bool(0.1) {
                        c.version = INVISIBLE;
                    }
                    c
                })
                .collect();
            let cvt = CvtSnapshot {
                key: 1,
                occupied: true,
                table_id: 0,
                record_len: 8,
                cells: cells.clone(),
            };
            let ts = g.u64(0, 120);
            let (best, newer) = cvt.select_version(ts);
            // naive oracle
            let vis: Vec<&CellSnapshot> = cells
                .iter()
                .filter(|c| c.valid && c.version != INVISIBLE)
                .collect();
            let naive_best = vis.iter().filter(|c| c.version <= ts).max_by_key(|c| c.version);
            let naive_newer = vis.iter().any(|c| c.version > ts);
            assert_eq!(best.map(|c| c.version), naive_best.map(|c| c.version));
            assert_eq!(newer, naive_newer);
        });
    }
}
