//! MN-side data store (paper section 7.1, fig. 11).
//!
//! Layout on each memory node:
//!
//! ```text
//! DB table  =  hash index of buckets
//! bucket    =  ASSOC consecutive CVTs
//! CVT       =  header (key, table, len) + N cells
//! cell      =  { head_cv, valid, version, record addr, tail_cv }
//! record    =  seqlock-versioned full record (one per cell, fixed slot)
//! ```
//!
//! Each version is an **independent full record** (LOTUS's RDMA-friendly
//! store: one READ per version, no delta reconstruction), with cell-level
//! *cacheline versions* (CV) providing seqlock consistency for lock-free
//! readers, and a timestamp-threshold GC reusing the oldest cell + its
//! record slot in place (section 7.1, "lightweight garbage collection").
//!
//! Replication: a table is laid out identically on the primary and backup
//! MNs; commit-phase writes go to all replicas (paper 8.1: 3-way).

pub mod cvt;
pub mod gc;
pub mod index;
pub mod layout;
pub mod record;

pub use cvt::{CellSnapshot, CvtSnapshot, INVISIBLE};
pub use index::{TableSpec, TableStore};
pub use layout::Layout;
