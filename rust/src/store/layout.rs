//! Binary layout constants + address arithmetic for the memory store.
//!
//! All structures are 8B-aligned so every word belongs to exactly one
//! structure (see [`crate::dm::memnode`]).

use crate::util::bytes::align_up;

/// CVT header bytes: key u64 | table_id u16 | record_len u16 | ncells u8 | pad3.
pub const CVT_HEADER: u64 = 16;
/// Cell bytes: head word | version | addr | tail word.
pub const CELL_SIZE: u64 = 32;

/// Table geometry derived from a spec.
#[derive(Debug, Clone, Copy)]
pub struct Layout {
    /// Versions per record (cells per CVT).
    pub ncells: u8,
    /// CVTs per index bucket.
    pub assoc: u8,
    /// Max record payload bytes.
    pub record_len: u32,
    /// Number of index buckets.
    pub n_buckets: u64,
}

impl Layout {
    /// Bytes of one CVT.
    #[inline]
    pub fn cvt_size(&self) -> u64 {
        CVT_HEADER + CELL_SIZE * self.ncells as u64
    }

    /// Bytes of one index bucket.
    #[inline]
    pub fn bucket_size(&self) -> u64 {
        self.cvt_size() * self.assoc as u64
    }

    /// Bytes of the whole index region.
    #[inline]
    pub fn index_size(&self) -> u64 {
        self.bucket_size() * self.n_buckets
    }

    /// Bytes of one record slot: head word + aligned payload + tail word.
    #[inline]
    pub fn record_slot(&self) -> u64 {
        8 + align_up(self.record_len as u64, 8) + 8
    }

    /// Offset of bucket `b` within the index region.
    #[inline]
    pub fn bucket_off(&self, b: u64) -> u64 {
        debug_assert!(b < self.n_buckets);
        b * self.bucket_size()
    }

    /// Offset of CVT slot `slot` within a bucket.
    #[inline]
    pub fn cvt_off_in_bucket(&self, slot: u8) -> u64 {
        debug_assert!(slot < self.assoc);
        slot as u64 * self.cvt_size()
    }

    /// Offset of cell `c` within a CVT.
    #[inline]
    pub fn cell_off(&self, c: u8) -> u64 {
        debug_assert!(c < self.ncells);
        CVT_HEADER + c as u64 * CELL_SIZE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l() -> Layout {
        Layout {
            ncells: 2,
            assoc: 4,
            record_len: 40,
            n_buckets: 1024,
        }
    }

    #[test]
    fn sizes_are_aligned() {
        let l = l();
        assert_eq!(l.cvt_size() % 8, 0);
        assert_eq!(l.bucket_size() % 8, 0);
        assert_eq!(l.record_slot() % 8, 0);
        assert_eq!(l.cvt_size(), 16 + 2 * 32);
        assert_eq!(l.bucket_size(), 4 * 80);
    }

    #[test]
    fn offsets_disjoint() {
        let l = l();
        // Cells within a CVT don't overlap the header or each other.
        assert!(l.cell_off(0) >= CVT_HEADER);
        assert_eq!(l.cell_off(1) - l.cell_off(0), CELL_SIZE);
        assert!(l.cell_off(1) + CELL_SIZE <= l.cvt_size());
        // CVTs within a bucket are consecutive.
        assert_eq!(l.cvt_off_in_bucket(3), 3 * l.cvt_size());
    }

    #[test]
    fn record_slot_padding() {
        let mut l = l();
        l.record_len = 13;
        assert_eq!(l.record_slot(), 8 + 16 + 8);
        l.record_len = 672; // TPCC max
        assert_eq!(l.record_slot(), 8 + 672 + 8);
    }
}
