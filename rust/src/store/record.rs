//! Seqlock-versioned record slots (cacheline versions, paper section 7.1).
//!
//! A record slot holds one full version of a record:
//!
//! ```text
//! word0: head_cv u8 | pad7   |  payload (8B-aligned)  |  wordN: tail_cv u8
//! ```
//!
//! Writers bump the CV before rewriting a slot (GC reuse) and store the
//! same CV in the owning CVT cell; readers compare head CV, tail CV and
//! the cell CV — any mismatch means a concurrent overwrite and aborts the
//! (lock-free, read-only) reader. This is the paper's cacheline-version
//! mechanism with one CV per slot boundary instead of one per 64B line;
//! the simulator's word-atomic memory makes intra-line tearing impossible,
//! so boundary CVs detect exactly the same set of races.

use crate::util::bytes::align_up;

/// Encode a record slot image: `[cv | payload | cv]`, padded to the slot.
pub fn encode(cv: u8, payload: &[u8], record_len: u32) -> Vec<u8> {
    debug_assert!(payload.len() <= record_len as usize);
    let body = align_up(record_len as u64, 8) as usize;
    let mut buf = vec![0u8; 8 + body + 8];
    buf[0] = cv;
    buf[8..8 + payload.len()].copy_from_slice(payload);
    buf[8 + body] = cv;
    buf
}

/// Slot image size for a payload capacity.
pub fn slot_size(record_len: u32) -> usize {
    8 + align_up(record_len as u64, 8) as usize + 8
}

/// Decode a slot image read from the memory pool. Returns
/// `(cv, payload)` if head/tail CVs match, else `None` (torn read).
pub fn decode(buf: &[u8], payload_len: usize, record_len: u32) -> Option<(u8, Vec<u8>)> {
    let body = align_up(record_len as u64, 8) as usize;
    debug_assert!(buf.len() >= 8 + body + 8);
    debug_assert!(payload_len <= body);
    let head = buf[0];
    let tail = buf[8 + body];
    if head != tail {
        return None;
    }
    Some((head, buf[8..8 + payload_len].to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let payload = b"the quick brown fox jumps";
        let buf = encode(7, payload, 40);
        assert_eq!(buf.len(), slot_size(40));
        let (cv, got) = decode(&buf, payload.len(), 40).unwrap();
        assert_eq!(cv, 7);
        assert_eq!(got, payload);
    }

    #[test]
    fn torn_read_detected() {
        let mut buf = encode(3, b"data", 16);
        let body = align_up(16, 8) as usize;
        buf[8 + body] = 4; // tail cv differs
        assert!(decode(&buf, 4, 16).is_none());
    }

    #[test]
    fn empty_payload() {
        let buf = encode(1, b"", 8);
        let (cv, got) = decode(&buf, 0, 8).unwrap();
        assert_eq!(cv, 1);
        assert!(got.is_empty());
    }

    #[test]
    fn prop_roundtrip_arbitrary_sizes() {
        crate::testing::prop(50, |g| {
            let record_len = g.u64(1, 700) as u32;
            let payload_len = g.usize(0, record_len as usize);
            let payload: Vec<u8> = (0..payload_len).map(|_| g.u64(0, 255) as u8).collect();
            let cv = g.u64(0, 255) as u8;
            let buf = encode(cv, &payload, record_len);
            let (cv2, got) = decode(&buf, payload.len(), record_len).unwrap();
            assert_eq!(cv, cv2);
            assert_eq!(got, payload);
        });
    }
}
