//! DB tables: hash index of CVT buckets + record heaps (paper fig. 11).
//!
//! A [`TableStore`] describes one DB table laid out **identically** on
//! every replica MN (primary first): an index region of
//! `n_buckets x assoc` CVTs and a records region holding one fixed slot
//! per CVT cell. Identical layout means a primary address maps to a
//! backup address by pure offset arithmetic — exactly how primary-backup
//! replication on DM writes both copies with the same doorbell batch.
//!
//! The store itself performs **no network charging**; coordinators read
//! and write through [`crate::dm::Endpoint`] using the addresses computed
//! here. Init-time bulk loading uses the MN CPU directly (paper section 3:
//! "MNs utilize their limited CPUs to allocate memory ... application
//! data is loaded into DB tables").

use std::sync::Arc;

use crate::dm::memnode::MemNode;
use crate::sharding::key::LotusKey;
use crate::store::cvt::{CellSnapshot, CvtSnapshot};
use crate::store::layout::Layout;
use crate::store::record;
use crate::{Error, Result};

/// Max buckets probed on lookup/insert (home + 7 successors). Linear
/// probing induces clustering, so the chain is sized generously; lookups
/// stop at the first hit (usually the home bucket).
pub const PROBE_MAX: usize = 8;

/// Static description of a DB table.
#[derive(Debug, Clone)]
pub struct TableSpec {
    /// Table id (unique per cluster).
    pub id: u16,
    /// Human-readable name (reports).
    pub name: String,
    /// Max record payload bytes.
    pub record_len: u32,
    /// Versions per record (CVT cells).
    pub ncells: u8,
    /// CVTs per index bucket.
    pub assoc: u8,
    /// Expected record count (sizes the index).
    pub expected_records: u64,
}

impl TableSpec {
    /// Bucket count for a ~40% load factor, rounded to a power of two
    /// (headroom keeps probe chains short under linear-probing clustering).
    pub fn n_buckets(&self) -> u64 {
        let want = (self.expected_records as f64 / (self.assoc as f64 * 0.4)).ceil() as u64;
        want.max(1).next_power_of_two()
    }

    /// Derived geometry.
    pub fn layout(&self) -> Layout {
        Layout {
            ncells: self.ncells,
            assoc: self.assoc,
            record_len: self.record_len,
            n_buckets: self.n_buckets(),
        }
    }
}

/// One replica's placement of the table.
#[derive(Debug, Clone, Copy)]
pub struct TableReplica {
    /// MN id.
    pub mn: usize,
    /// Index region base address.
    pub index_base: u64,
    /// Records region base address.
    pub records_base: u64,
}

/// One DB table across its replicas.
pub struct TableStore {
    /// The table's spec.
    pub spec: TableSpec,
    /// Derived geometry.
    pub layout: Layout,
    /// Replicas, primary first.
    pub replicas: Vec<TableReplica>,
}

impl TableStore {
    /// Register the table's regions on `replica_mns` (primary first).
    pub fn create(spec: TableSpec, mns: &[Arc<MemNode>], replica_mns: &[usize]) -> Result<Self> {
        assert!(!replica_mns.is_empty());
        let layout = spec.layout();
        let records_size =
            layout.n_buckets * spec.assoc as u64 * spec.ncells as u64 * layout.record_slot();
        let mut replicas = Vec::with_capacity(replica_mns.len());
        for &mn_id in replica_mns {
            let mn = mns
                .get(mn_id)
                .ok_or_else(|| Error::NodeUnavailable(format!("mn{mn_id}")))?;
            let index = mn.register(layout.index_size())?;
            let records = mn.register(records_size)?;
            replicas.push(TableReplica {
                mn: mn_id,
                index_base: index.base,
                records_base: records.base,
            });
        }
        Ok(Self {
            spec,
            layout,
            replicas,
        })
    }

    /// The primary replica.
    #[inline]
    pub fn primary(&self) -> &TableReplica {
        &self.replicas[0]
    }

    /// Index bucket for a key (home bucket; see [`PROBE_MAX`]).
    #[inline]
    pub fn bucket_of(&self, key: LotusKey) -> u64 {
        key.index_bucket(self.layout.n_buckets)
    }

    /// The buckets a key may live in: its home bucket plus up to
    /// [`PROBE_MAX`]`- 1` linear-probe successors (wrapping). Bounded
    /// probing keeps bulk loads and inserts from failing on the rare
    /// over-full bucket while keeping lookups O(1).
    pub fn probe_buckets(&self, key: LotusKey) -> impl Iterator<Item = u64> + '_ {
        let home = self.bucket_of(key);
        let n = self.layout.n_buckets;
        (0..PROBE_MAX as u64).map(move |i| (home + i) % n)
    }

    /// Address of bucket `b` on replica `r`.
    #[inline]
    pub fn bucket_addr(&self, r: usize, b: u64) -> u64 {
        self.replicas[r].index_base + self.layout.bucket_off(b)
    }

    /// Address of CVT `(b, slot)` on replica `r`.
    #[inline]
    pub fn cvt_addr(&self, r: usize, b: u64, slot: u8) -> u64 {
        self.bucket_addr(r, b) + self.layout.cvt_off_in_bucket(slot)
    }

    /// Inverse of [`Self::cvt_addr`] for the primary: `(bucket, slot)`.
    pub fn locate_cvt(&self, primary_cvt_addr: u64) -> Result<(u64, u8)> {
        let base = self.primary().index_base;
        if primary_cvt_addr < base {
            return Err(Error::BadAddress(primary_cvt_addr, "below index"));
        }
        let off = primary_cvt_addr - base;
        let idx = off / self.layout.cvt_size();
        if off % self.layout.cvt_size() != 0 || idx >= self.layout.n_buckets * self.spec.assoc as u64
        {
            return Err(Error::BadAddress(primary_cvt_addr, "not a CVT address"));
        }
        Ok((idx / self.spec.assoc as u64, (idx % self.spec.assoc as u64) as u8))
    }

    /// Address of the fixed record slot for `(b, slot, cell)` on replica `r`.
    #[inline]
    pub fn record_addr(&self, r: usize, b: u64, slot: u8, cell: u8) -> u64 {
        let idx = (b * self.spec.assoc as u64 + slot as u64) * self.spec.ncells as u64
            + cell as u64;
        self.replicas[r].records_base + idx * self.layout.record_slot()
    }

    /// Translate any primary address into replica `r`'s copy (identical
    /// layout => identical offset).
    #[inline]
    pub fn to_replica_addr(&self, primary_addr: u64, r: usize) -> u64 {
        let p = self.primary();
        let rep = &self.replicas[r];
        if primary_addr >= p.records_base {
            rep.records_base + (primary_addr - p.records_base)
        } else {
            rep.index_base + (primary_addr - p.index_base)
        }
    }

    /// The lock key guarding an index bucket during inserts (paper 4.1:
    /// "using the index bucket address as a key to locate the lock").
    /// Unique across tables; shares the bucket's shard-routing semantics.
    pub fn bucket_lock_key(&self, b: u64) -> LotusKey {
        // unique = [tag 15 (reserved) : 5 | table : 12 | bucket : 35] —
        // tag 15 is reserved cluster-wide so bucket locks never collide
        // with data keys (workload key tags stay below 15).
        let unique = (15u64 << 47) | ((self.spec.id as u64) << 35) | (b & ((1 << 35) - 1));
        LotusKey::compose(b, unique)
    }

    /// Find the CVT matching `key` inside a parsed bucket image; returns
    /// `(slot, snapshot)`.
    pub fn find_in_bucket(&self, bucket_buf: &[u8], key: LotusKey) -> Option<(u8, CvtSnapshot)> {
        let sz = self.layout.cvt_size() as usize;
        for slot in 0..self.spec.assoc {
            let off = slot as usize * sz;
            let cvt = CvtSnapshot::parse(&bucket_buf[off..off + sz], &self.layout);
            if !cvt.is_empty() && cvt.key == key.0 {
                return Some((slot, cvt));
            }
        }
        None
    }

    /// Find an empty CVT slot inside a parsed bucket image.
    pub fn find_empty_in_bucket(&self, bucket_buf: &[u8]) -> Option<u8> {
        let sz = self.layout.cvt_size() as usize;
        (0..self.spec.assoc).find(|&slot| {
            let off = slot as usize * sz;
            CvtSnapshot::parse(&bucket_buf[off..off + sz], &self.layout).is_empty()
        })
    }

    // ------------------------------------------------------------------
    // Init-time bulk loading (MN CPU; no network cost).
    // ------------------------------------------------------------------

    /// Insert `(key, payload)` at version `version` on every replica.
    pub fn load_insert(
        &self,
        mns: &[Arc<MemNode>],
        key: LotusKey,
        payload: &[u8],
        version: u64,
    ) -> Result<()> {
        if payload.len() > self.spec.record_len as usize {
            return Err(Error::Config(format!(
                "payload {} exceeds record_len {}",
                payload.len(),
                self.spec.record_len
            )));
        }
        // Find the slot on the primary (identical on every replica),
        // probing the home bucket then its successors.
        let mn0 = &mns[self.primary().mn];
        let mut slot_found = None;
        for b in self.probe_buckets(key) {
            for slot in 0..self.spec.assoc {
                let addr = self.cvt_addr(0, b, slot);
                let existing_key = mn0.load_u64(addr)?;
                // Header word 1 carries the occupied flag at byte 13.
                let flags = mn0.load_u64(addr + 8)?;
                let occupied = (flags >> 40) & 0xFF != 0;
                if occupied && existing_key == key.0 {
                    return Err(crate::abort(crate::AbortReason::Duplicate));
                }
                if !occupied && slot_found.is_none() {
                    slot_found = Some((b, slot));
                }
            }
        }
        let Some((b, slot)) = slot_found else {
            return Err(Error::OutOfMemory(format!(
                "table {} probe chain of bucket {} full during load",
                self.spec.name,
                self.bucket_of(key)
            )));
        };
        let cv = 1u8;
        let mut cvt = CvtSnapshot::empty(self.spec.ncells);
        cvt.key = key.0;
        cvt.occupied = true;
        cvt.table_id = self.spec.id;
        cvt.record_len = payload.len() as u16;
        cvt.cells[0] = CellSnapshot {
            cv,
            valid: true,
            len: payload.len() as u16,
            version,
            addr: self.record_addr(0, b, slot, 0),
            consistent: true,
        };
        let slot_img = record::encode(cv, payload, self.spec.record_len);
        for (r, rep) in self.replicas.iter().enumerate() {
            let mn = &mns[rep.mn];
            // Cell addr in the CVT always names the *primary* record slot;
            // replicas translate by offset when reading/writing.
            mn.write_bytes(self.cvt_addr(r, b, slot), &cvt.serialize(&self.layout))?;
            mn.write_bytes(self.record_addr(r, b, slot, 0), &slot_img)?;
        }
        Ok(())
    }

    /// Read back the latest version of `key` from replica `r` via the MN
    /// CPU (tests + verification; not part of the transaction path).
    pub fn load_get(&self, mns: &[Arc<MemNode>], r: usize, key: LotusKey) -> Option<Vec<u8>> {
        let mn = &mns[self.replicas[r].mn];
        let mut found = None;
        for b in self.probe_buckets(key) {
            let mut bucket_buf = vec![0u8; self.layout.bucket_size() as usize];
            mn.read_bytes(self.bucket_addr(r, b), &mut bucket_buf).ok()?;
            if let Some(hit) = self.find_in_bucket(&bucket_buf, key) {
                found = Some(hit);
                break;
            }
        }
        let (_slot, cvt) = found?;
        let cell = cvt.latest()?;
        let addr = self.to_replica_addr(cell.addr, r);
        let mut slot_buf = vec![0u8; record::slot_size(self.spec.record_len)];
        mn.read_bytes(addr, &mut slot_buf).ok()?;
        let (_cv, payload) =
            record::decode(&slot_buf, cell.len as usize, self.spec.record_len)?;
        Some(payload)
    }

    /// Total bytes this table occupies per replica (memory accounting,
    /// fig. 16).
    pub fn bytes_per_replica(&self) -> u64 {
        self.layout.index_size()
            + self.layout.n_buckets
                * self.spec.assoc as u64
                * self.spec.ncells as u64
                * self.layout.record_slot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> (Vec<Arc<MemNode>>, TableStore) {
        let mns: Vec<Arc<MemNode>> = (0..3).map(|i| Arc::new(MemNode::new(i, 64 << 20))).collect();
        let spec = TableSpec {
            id: 1,
            name: "kv".into(),
            record_len: 40,
            ncells: 2,
            assoc: 4,
            expected_records: 1000,
        };
        let t = TableStore::create(spec, &mns, &[0, 1, 2]).unwrap();
        (mns, t)
    }

    #[test]
    fn create_places_identical_layout() {
        let (_mns, t) = mk();
        assert_eq!(t.replicas.len(), 3);
        // Same offsets on every replica.
        let a0 = t.cvt_addr(0, 5, 2) - t.replicas[0].index_base;
        let a1 = t.cvt_addr(1, 5, 2) - t.replicas[1].index_base;
        assert_eq!(a0, a1);
    }

    #[test]
    fn load_insert_and_get_roundtrip_all_replicas() {
        let (mns, t) = mk();
        let key = LotusKey::compose(7, 123);
        t.load_insert(&mns, key, b"forty-byte-payload", 100).unwrap();
        for r in 0..3 {
            assert_eq!(
                t.load_get(&mns, r, key).as_deref(),
                Some(b"forty-byte-payload".as_ref()),
                "replica {r}"
            );
        }
        assert!(t.load_get(&mns, 0, LotusKey::compose(7, 999)).is_none());
    }

    #[test]
    fn duplicate_load_insert_rejected() {
        let (mns, t) = mk();
        let key = LotusKey::compose(1, 1);
        t.load_insert(&mns, key, b"a", 1).unwrap();
        let err = t.load_insert(&mns, key, b"b", 2).unwrap_err();
        assert!(matches!(err, Error::Abort(crate::AbortReason::Duplicate)));
    }

    #[test]
    fn oversized_payload_rejected() {
        let (mns, t) = mk();
        let err = t
            .load_insert(&mns, LotusKey::compose(1, 2), &[0u8; 41], 1)
            .unwrap_err();
        assert!(matches!(err, Error::Config(_)));
    }

    #[test]
    fn locate_cvt_inverts_cvt_addr() {
        let (_mns, t) = mk();
        for (b, slot) in [(0u64, 0u8), (3, 1), (t.layout.n_buckets - 1, 3)] {
            let addr = t.cvt_addr(0, b, slot);
            assert_eq!(t.locate_cvt(addr).unwrap(), (b, slot));
        }
        assert!(t.locate_cvt(t.primary().index_base + 1).is_err());
    }

    #[test]
    fn replica_addr_translation() {
        let (_mns, t) = mk();
        let rec = t.record_addr(0, 2, 1, 1);
        let rec_r2 = t.to_replica_addr(rec, 2);
        assert_eq!(rec_r2, t.record_addr(2, 2, 1, 1));
        let cvt = t.cvt_addr(0, 2, 1);
        assert_eq!(t.to_replica_addr(cvt, 1), t.cvt_addr(1, 2, 1));
    }

    #[test]
    fn bucket_lock_keys_unique_per_table_and_bucket() {
        let (_mns, t) = mk();
        let a = t.bucket_lock_key(1);
        let b = t.bucket_lock_key(2);
        assert_ne!(a, b);
        // Distinct from any data key (reserved tag 15 in the top bits).
        assert_eq!(a.unique() >> 47, 15);
    }

    #[test]
    fn n_buckets_sizing() {
        let spec = TableSpec {
            id: 0,
            name: "t".into(),
            record_len: 8,
            ncells: 1,
            assoc: 4,
            expected_records: 1000,
        };
        let nb = spec.n_buckets();
        assert!(nb.is_power_of_two());
        assert!(nb * 4 * 6 / 10 >= 1000, "load factor too high: {nb}");
    }

    #[test]
    fn prop_load_many_then_get() {
        crate::testing::prop(5, |g| {
            let (mns, t) = mk();
            let n = g.usize(1, 300);
            let mut inserted = Vec::new();
            for i in 0..n {
                let key = LotusKey::compose(g.u64(0, 50), i as u64);
                let val = vec![(i % 251) as u8; g.usize(1, 40)];
                if t.load_insert(&mns, key, &val, i as u64 + 1).is_ok() {
                    inserted.push((key, val));
                }
            }
            for (key, val) in inserted {
                assert_eq!(t.load_get(&mns, 0, key), Some(val));
            }
        });
    }
}
