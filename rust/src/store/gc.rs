//! Lightweight garbage collection (paper section 7.1).
//!
//! Updates write the new version into a *free* CVT cell. If all cells are
//! occupied, the oldest version's cell (and its record slot) is reused.
//! Additionally, during writes the coordinator clears any cell whose
//! timestamp is older than a threshold relative to the (bounded-drift)
//! local clock — the paper's 500 ms default — reclaiming memory eagerly.
//!
//! Cells with `version == INVISIBLE` belong to an in-flight commit and
//! are never victims (the write lock guarantees at most one per CVT).

use crate::store::cvt::{CellSnapshot, INVISIBLE};
use crate::txn::timestamp::phys_of;

/// Default staleness threshold (500 ms, paper 7.1).
pub const DEFAULT_GC_THRESHOLD_NS: u64 = 500_000_000;

/// Pick the cell to hold a new version. Preference order:
/// 1. an invalid (never used / reclaimed) cell,
/// 2. the oldest cell past the GC threshold,
/// 3. the oldest visible cell.
///
/// Returns `None` only if every cell is INVISIBLE (cannot happen with the
/// write lock held, but callers treat it as an abort for safety).
pub fn choose_victim(cells: &[CellSnapshot], _now_phys_ns: u64, threshold_ns: u64) -> Option<usize> {
    // 1. free cell
    if let Some(i) = cells.iter().position(|c| !c.valid) {
        return Some(i);
    }
    // 2/3. oldest non-INVISIBLE cell (GC threshold only changes whether we
    // *also* clear other stale cells; the victim choice is the oldest).
    let _ = threshold_ns;
    cells
        .iter()
        .enumerate()
        .filter(|(_, c)| c.version != INVISIBLE)
        .min_by_key(|(_, c)| c.version)
        .map(|(i, _)| i)
}

/// Indices of cells that are valid, visible, and stale past the threshold
/// — reclaimed (set invalid) opportunistically during a write. The cell
/// holding the newest version is never reclaimed (a reader must always
/// find the latest committed version).
pub fn reclaimable(cells: &[CellSnapshot], now_phys_ns: u64, threshold_ns: u64) -> Vec<usize> {
    let newest = cells
        .iter()
        .enumerate()
        .filter(|(_, c)| c.valid && c.version != INVISIBLE)
        .max_by_key(|(_, c)| c.version)
        .map(|(i, _)| i);
    cells
        .iter()
        .enumerate()
        .filter(|(i, c)| {
            Some(*i) != newest
                && c.valid
                && c.version != INVISIBLE
                && phys_of(c.version).saturating_add(threshold_ns) < now_phys_ns
        })
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::txn::timestamp::compose_ts;

    fn cell(version: u64, valid: bool) -> CellSnapshot {
        CellSnapshot {
            cv: 0,
            valid,
            len: 8,
            version,
            addr: 0,
            consistent: true,
        }
    }

    #[test]
    fn prefers_free_cell() {
        let cells = [cell(compose_ts(10, 0), true), cell(0, false)];
        assert_eq!(choose_victim(&cells, 1000, 100), Some(1));
    }

    #[test]
    fn evicts_oldest_when_full() {
        let cells = [
            cell(compose_ts(30, 0), true),
            cell(compose_ts(10, 0), true),
            cell(compose_ts(20, 0), true),
        ];
        assert_eq!(choose_victim(&cells, 1000, 100), Some(1));
    }

    #[test]
    fn never_evicts_invisible() {
        let cells = [cell(INVISIBLE, true), cell(compose_ts(5, 0), true)];
        assert_eq!(choose_victim(&cells, 1000, 100), Some(1));
        let all_invisible = [cell(INVISIBLE, true), cell(INVISIBLE, true)];
        assert_eq!(choose_victim(&all_invisible, 1000, 100), None);
    }

    #[test]
    fn reclaimable_respects_threshold_and_keeps_newest() {
        let now = 10_000;
        let cells = [
            cell(compose_ts(100, 0), true),   // stale
            cell(compose_ts(9_990, 0), true), // fresh (within threshold)
            cell(compose_ts(200, 0), true),   // stale
            cell(compose_ts(9_999, 0), true), // newest — protected
        ];
        let r = reclaimable(&cells, now, 1_000);
        assert_eq!(r, vec![0, 2]);
    }

    #[test]
    fn reclaimable_never_includes_only_version() {
        let cells = [cell(compose_ts(1, 0), true)];
        assert!(reclaimable(&cells, u64::MAX / 2, 1).is_empty());
    }

    #[test]
    fn prop_victim_is_never_invisible_and_prefers_invalid() {
        crate::testing::prop(100, |g| {
            let n = g.usize(1, 8);
            let cells: Vec<CellSnapshot> = (0..n)
                .map(|_| {
                    let invisible = g.bool(0.2);
                    cell(
                        if invisible { INVISIBLE } else { compose_ts(g.u64(0, 1 << 30), 0) },
                        g.bool(0.8),
                    )
                })
                .collect();
            match choose_victim(&cells, 1 << 31, 500) {
                Some(i) => {
                    assert!(!cells[i].valid || cells[i].version != INVISIBLE);
                    if cells.iter().any(|c| !c.valid) {
                        assert!(!cells[i].valid, "must prefer a free cell");
                    }
                }
                None => assert!(cells.iter().all(|c| c.valid && c.version == INVISIBLE)),
            }
        });
    }
}
