//! A counting global allocator (feature `alloc-count`): wraps the
//! system allocator and tallies every allocation, so the zero-alloc
//! steady-state invariant of the frame scheduler (ISSUE 9) and the
//! `wall_clock` bench section's allocs-per-transaction trajectory are
//! *measured*, not asserted by inspection.
//!
//! Two counters, one per consumer:
//!
//! - a process-global [`total_allocs`] for the bench harness, which
//!   sums allocations across coordinator threads;
//! - a thread-local [`thread_allocs`] for unit tests, immune to the
//!   test harness running sibling tests on other threads.
//!
//! Both count `alloc` and `realloc` calls (a `realloc` that moves is a
//! fresh heap acquisition on the hot path; one that shrinks in place is
//! free in practice but counting it keeps the signal conservative).
//! Deallocations are not counted — the invariant under test is "no new
//! heap traffic per transaction", and frees pair with counted allocs.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    // const-init: reading the counter never allocates (a lazily-init
    // TLS slot could recurse into the allocator on first touch).
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// [`System`] with allocation counting; installed as the global
/// allocator whenever the `alloc-count` feature is on.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        TL_ALLOCS.with(|c| c.set(c.get() + 1));
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        TL_ALLOCS.with(|c| c.set(c.get() + 1));
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Process-wide allocation count (all threads) since start.
pub fn total_allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// This thread's allocation count since the thread started.
pub fn thread_allocs() -> u64 {
    TL_ALLOCS.with(|c| c.get())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_a_heap_allocation() {
        let t0 = thread_allocs();
        let g0 = total_allocs();
        let v: Vec<u64> = Vec::with_capacity(32);
        assert!(thread_allocs() > t0, "Vec::with_capacity must be counted");
        assert!(total_allocs() > g0);
        drop(v);
    }

    #[test]
    fn pure_arithmetic_allocates_nothing() {
        let mut acc = 0u64;
        let t0 = thread_allocs();
        for i in 0..1_000u64 {
            acc = acc.wrapping_mul(31).wrapping_add(i);
        }
        assert_eq!(thread_allocs(), t0, "no heap traffic in the loop");
        assert_ne!(acc, 0);
    }
}
