//! # LOTUS — disaggregated transactions with disaggregated locks
//!
//! Production-quality reproduction of *"LOTUS: Optimizing Disaggregated
//! Transactions with Disaggregated Locks"* (CS.DC 2025).
//!
//! LOTUS is a distributed transaction system for disaggregated memory (DM)
//! whose key idea is **lock disaggregation**: locks are decoupled from data
//! and live in the *compute pool* (CN lock tables), while data lives in the
//! *memory pool* (MN consecutive version tables + records). This removes
//! the MN-RNIC bottleneck caused by one-sided RDMA atomic (CAS/FAA) lock
//! traffic in prior systems (FORD, Motor).
//!
//! ## Crate layout (bottom-up)
//!
//! - [`dm`] — the disaggregated-memory fabric substrate: memory nodes,
//!   simulated RNICs with a calibrated queueing cost model, one-sided
//!   verbs (READ/WRITE/CAS/FAA, doorbell batching), CN-to-CN RPC, and
//!   per-coordinator virtual clocks. All data operations execute against
//!   real shared memory; all network operations are *also* charged against
//!   the cost model, reproducing the paper's RNIC-IOPS bottleneck. The
//!   [`dm::OpBatch`] planner is the single entry point for one-sided
//!   batches: callers enqueue READ/WRITE/CAS/FAA ops tagged by target MN
//!   and the planner groups them into per-MN doorbell batches, each
//!   charged one RTT — both the LOTUS commit path and every baseline
//!   coordinator issue their batches through it.
//! - [`store`] — MN-side data store: consecutive version tables (CVT),
//!   hash index, seqlock cacheline versions, GC, primary-backup replication.
//! - [`lock`] — CN-side distributed lock tables (8B fingerprint+counter
//!   slots, 8-slot buckets, holder state for idempotency).
//! - [`sharding`] — 64-bit LOTUS keys (low 12 bits = shard number from the
//!   application's critical field), the routing layer, pass-by-range
//!   resharding.
//! - [`cache`] — version-table cache (LRU sub-caches, zero-overhead
//!   consistency) and CVT address cache.
//! - [`txn`] — the lock-first transaction protocol. The protocol is
//!   **phase-structured**: each stage of the paper's pipeline (Lock →
//!   Read CVT → Read Data → Write+Log → Timestamp → Visible → Unlock)
//!   lives in its own module under [`txn::phases`], operating on a
//!   [`txn::phases::TxnFrame`] that threads the read/write sets,
//!   snapshots, and virtual clock through the pipeline. The
//!   [`txn::coordinator::LotusCoordinator`] is a thin orchestration
//!   shell over those phases. Plus the HLC timestamp oracle and commit
//!   logs.
//! - [`balance`] — two-level load balancing: metrics collection and the
//!   rebalance planner (executes the AOT-compiled XLA artifact via
//!   [`runtime`]).
//! - [`recovery`] — lease-based membership + lock-rebuild-free CN recovery.
//! - [`baselines`] — re-implementations of Motor, FORD, their no-CAS
//!   variants, and the idealized RDMA lock (paper figures 2/3/13/17).
//! - [`workloads`] — KVS, SmallBank, TATP, TPC-C generators.
//! - [`runtime`] — PJRT loader/executor for `artifacts/*.hlo.txt`.
//! - [`sim`] — the cluster harness that wires everything together.
//!
//! ## Quickstart
//!
//! ```no_run
//! use lotus::config::{Config, SystemKind};
//! use lotus::sim::Cluster;
//! use lotus::workloads::WorkloadKind;
//!
//! let cfg = Config::small();
//! let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
//! let report = cluster.run(SystemKind::Lotus).unwrap();
//! println!("tput = {:.2} Mtxn/s, p50 = {} us", report.mtps(), report.p50_us());
//! ```

#[cfg(feature = "alloc-count")]
pub mod alloc_count;
pub mod audit;
pub mod balance;
pub mod baselines;
pub mod cache;
pub mod config;
pub mod dm;
pub mod lock;
pub mod metrics;
pub mod recovery;
pub mod runtime;
pub mod sharding;
pub mod sim;
pub mod store;
pub mod testing;
pub mod txn;
pub mod util;
pub mod workloads;

/// Crate-wide error type.
///
/// The crate is dependency-free (offline/vendored builds), so `Display`
/// and `std::error::Error` are implemented by hand instead of through a
/// derive crate.
#[derive(Debug)]
pub enum Error {
    /// Transaction aborted (lock conflict, validation failure, ...).
    Abort(AbortReason),
    /// A memory-node address is out of range or misaligned.
    BadAddress(u64, &'static str),
    /// Requested node does not exist or has failed.
    NodeUnavailable(String),
    /// Lock table bucket is full — the key cannot be locked.
    LockBucketFull,
    /// Shard not managed by this CN (stale routing); retry with fresh map.
    WrongShardOwner {
        /// The shard the request named.
        shard: u16,
        /// The CN that received (and rejected) the request.
        cn: usize,
    },
    /// Memory-pool allocation failed.
    OutOfMemory(String),
    /// Configuration problem.
    Config(String),
    /// Artifact loading / PJRT problems.
    Runtime(String),
    /// XLA error bubbled up from the PJRT client.
    Xla(String),
    /// I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Abort(r) => write!(f, "transaction aborted: {r}"),
            Error::BadAddress(addr, why) => write!(f, "bad address: {addr:#x} ({why})"),
            Error::NodeUnavailable(who) => write!(f, "node unavailable: {who}"),
            Error::LockBucketFull => write!(f, "lock bucket full"),
            Error::WrongShardOwner { shard, cn } => {
                write!(f, "wrong shard owner: shard {shard} not owned by cn {cn}")
            }
            Error::OutOfMemory(what) => write!(f, "out of memory-pool space: {what}"),
            Error::Config(what) => write!(f, "config error: {what}"),
            Error::Runtime(what) => write!(f, "runtime error: {what}"),
            Error::Xla(what) => write!(f, "xla: {what}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Why a transaction aborted — recorded in metrics for abort-rate figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AbortReason {
    /// A lock could not be acquired (conflict or bucket full).
    LockConflict,
    /// A version newer than the start timestamp was found (SR violation).
    VersionTooNew,
    /// Seqlock cacheline-version mismatch on an unlocked read.
    InconsistentRead,
    /// No visible version at/below the read timestamp.
    NoVisibleVersion,
    /// Key not found in the index.
    NotFound,
    /// The lock owner CN failed (recovery in progress).
    OwnerFailed,
    /// Insert found the key already present.
    Duplicate,
    /// Explicit user abort (workload logic).
    UserAbort,
}

impl std::fmt::Display for AbortReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

/// Crate-wide result type.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Convenience: is this an abort (retryable) rather than a hard error?
    pub fn is_abort(&self) -> bool {
        matches!(self, Error::Abort(_))
    }

    /// The abort reason, if this is an abort.
    pub fn abort_reason(&self) -> Option<AbortReason> {
        match self {
            Error::Abort(r) => Some(*r),
            _ => None,
        }
    }
}

/// Shorthand constructor used across the protocol code.
pub fn abort(reason: AbortReason) -> Error {
    Error::Abort(reason)
}
