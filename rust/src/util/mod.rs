//! Small shared utilities: PRNGs and byte-level encoding helpers.
//!
//! Crates.io `rand` is unavailable in the offline vendor set, so the
//! simulator carries its own small, well-known generators (SplitMix64 for
//! seeding, xoshiro256** for streams). Both are deterministic and seedable
//! so every benchmark run is reproducible.

pub mod bytes;
pub mod rng;

pub use rng::{SplitMix64, Xoshiro256};
