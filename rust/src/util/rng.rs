//! Deterministic PRNGs: SplitMix64 (seeding) and xoshiro256** (streams).

/// SplitMix64 — used to expand a single seed into generator state.
/// Reference: Steele, Lea, Flood; same constants as `java.util.SplittableRandom`.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// New generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality stream generator.
/// Reference: Blackman & Vigna, <https://prng.di.unimi.it/>.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// New generator; the seed is expanded through SplitMix64 so any seed
    /// (including 0) yields a valid non-zero state.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit value.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for workload generation; bound is tiny relative to 2^64).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p` (clamped to [0,1]).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick one percent bucket in `[0, 100)` — convenient for txn mixes.
    #[inline]
    pub fn percent(&mut self) -> u32 {
        self.below(100) as u32
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n expected).
    pub fn distinct(&mut self, n: u64, k: usize) -> Vec<u64> {
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let v = self.below(n);
            if !out.contains(&v) {
                out.push(v);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // First output for seed 0 (well-known SplitMix64 vector).
        let mut g = SplitMix64::new(0);
        assert_eq!(g.next_u64(), 0xE220A8397B1DCDAF);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(1);
        let mut c = Xoshiro256::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn below_respects_bound() {
        let mut g = Xoshiro256::new(7);
        for _ in 0..10_000 {
            assert!(g.below(10) < 10);
        }
        // bound 1 is always 0
        assert_eq!(g.below(1), 0);
    }

    #[test]
    fn below_covers_full_range() {
        let mut g = Xoshiro256::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            seen[g.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut g = Xoshiro256::new(9);
        for _ in 0..10_000 {
            let v = g.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut g = Xoshiro256::new(11);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            match g.range_inclusive(3, 5) {
                3 => lo_seen = true,
                5 => hi_seen = true,
                4 => {}
                v => panic!("out of range: {v}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn distinct_returns_unique() {
        let mut g = Xoshiro256::new(5);
        let v = g.distinct(100, 10);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Xoshiro256::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        g.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
