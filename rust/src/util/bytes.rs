//! Little-endian byte encoding helpers for fixed-layout MN structures.
//!
//! All memory-pool structures (CVTs, records, logs) are encoded with these
//! helpers so the layout is explicit and testable, exactly as an
//! RDMA-addressable structure must be.

/// Read a `u64` (little-endian) at `off` from `buf`.
#[inline]
pub fn get_u64(buf: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(buf[off..off + 8].try_into().unwrap())
}

/// Write a `u64` (little-endian) at `off` into `buf`.
#[inline]
pub fn put_u64(buf: &mut [u8], off: usize, v: u64) {
    buf[off..off + 8].copy_from_slice(&v.to_le_bytes());
}

/// Read a `u32` at `off`.
#[inline]
pub fn get_u32(buf: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(buf[off..off + 4].try_into().unwrap())
}

/// Write a `u32` at `off`.
#[inline]
pub fn put_u32(buf: &mut [u8], off: usize, v: u32) {
    buf[off..off + 4].copy_from_slice(&v.to_le_bytes());
}

/// Read a `u16` at `off`.
#[inline]
pub fn get_u16(buf: &[u8], off: usize) -> u16 {
    u16::from_le_bytes(buf[off..off + 2].try_into().unwrap())
}

/// Write a `u16` at `off`.
#[inline]
pub fn put_u16(buf: &mut [u8], off: usize, v: u16) {
    buf[off..off + 2].copy_from_slice(&v.to_le_bytes());
}

/// Round `n` up to a multiple of `align` (power of two).
#[inline]
pub fn align_up(n: u64, align: u64) -> u64 {
    debug_assert!(align.is_power_of_two());
    (n + align - 1) & !(align - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_roundtrip() {
        let mut buf = [0u8; 24];
        put_u64(&mut buf, 8, 0xDEADBEEF_CAFEBABE);
        assert_eq!(get_u64(&buf, 8), 0xDEADBEEF_CAFEBABE);
        assert_eq!(get_u64(&buf, 0), 0);
        assert_eq!(get_u64(&buf, 16), 0);
    }

    #[test]
    fn u32_u16_roundtrip() {
        let mut buf = [0u8; 8];
        put_u32(&mut buf, 0, 0x12345678);
        put_u16(&mut buf, 4, 0xABCD);
        assert_eq!(get_u32(&buf, 0), 0x12345678);
        assert_eq!(get_u16(&buf, 4), 0xABCD);
    }

    #[test]
    fn align_up_cases() {
        assert_eq!(align_up(0, 8), 0);
        assert_eq!(align_up(1, 8), 8);
        assert_eq!(align_up(8, 8), 8);
        assert_eq!(align_up(9, 8), 16);
        assert_eq!(align_up(100, 64), 128);
    }
}
