//! Metrics: latency histograms, throughput accounting, abort counters.
//!
//! Latencies are recorded in **virtual nanoseconds** (see [`crate::dm::clock`]).
//! The histogram uses log-linear buckets (HdrHistogram-style: 64 major
//! log2 buckets x 32 linear sub-buckets) giving <= ~3% relative error,
//! plenty for P50/P99 reporting.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::AbortReason;

const SUB_BITS: u32 = 5; // 32 sub-buckets per power of two
const SUB: usize = 1 << SUB_BITS;
const MAJORS: usize = 64 - SUB_BITS as usize;
const BUCKETS: usize = MAJORS * SUB;

/// Lock-free log-linear latency histogram (values in ns).
pub struct Histogram {
    counts: Vec<AtomicU64>,
    total: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    fn index(value: u64) -> usize {
        let v = value.max(1);
        let major = (63 - v.leading_zeros()) as usize;
        if major < SUB_BITS as usize {
            // Small values land in the first linear region.
            return v as usize;
        }
        let sub = ((v >> (major - SUB_BITS as usize)) & (SUB as u64 - 1)) as usize;
        ((major - SUB_BITS as usize) * SUB + sub).min(BUCKETS - 1)
    }

    /// Bucket lower bound for an index (inverse of `index`, approximate).
    fn bucket_value(idx: usize) -> u64 {
        if idx < SUB {
            return idx as u64;
        }
        let major = idx / SUB + SUB_BITS as usize;
        let sub = (idx % SUB) as u64;
        (1u64 << major) + (sub << (major - SUB_BITS as usize))
    }

    /// Record one value (ns).
    #[inline]
    pub fn record(&self, value: u64) {
        self.counts[Self::index(value)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.max.fetch_max(value, Ordering::Relaxed);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Mean (ns), 0 if empty.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Maximum recorded value (ns).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Value at quantile `q` in [0, 1]. Returns 0 if empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((n as f64) * q).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            if acc >= target {
                return Self::bucket_value(i);
            }
        }
        self.max()
    }

    /// P50 in ns.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// P99 in ns.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Merge another histogram into this one.
    pub fn merge(&self, other: &Histogram) {
        for (a, b) in self.counts.iter().zip(other.counts.iter()) {
            a.fetch_add(b.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.total
            .fetch_add(other.total.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum
            .fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max
            .fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Reset all counters.
    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.total.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

/// Aggregated result of one benchmark run. `PartialEq` compares every
/// field — the chaos suite's determinism contract (same seed + same
/// fault script ⇒ byte-identical report) is asserted with plain `==`.
#[derive(Debug, Clone, PartialEq)]
pub struct RunReport {
    /// Committed transactions.
    pub commits: u64,
    /// Aborted transaction *attempts* (a txn retried N times counts N).
    pub aborts: u64,
    /// Virtual duration of the run (ns).
    pub duration_ns: u64,
    /// Commit latency percentiles (ns).
    pub p50_ns: u64,
    /// 99th percentile commit latency (ns).
    pub p99_ns: u64,
    /// Mean commit latency (ns).
    pub mean_ns: f64,
    /// Abort breakdown.
    pub abort_reasons: HashMap<String, u64>,
    /// Per-interval committed counts (for recovery timelines), interval ns.
    pub timeline: Vec<u64>,
    /// Timeline sampling interval (ns); 0 if no timeline.
    pub timeline_interval_ns: u64,
    /// One-sided doorbells rung across all CN NICs during the run.
    pub doorbells: u64,
    /// WQEs those doorbells carried (coalesced riders included).
    pub doorbell_ops: u64,
    /// WQEs that rode another frame's doorbell instead of ringing their
    /// own (cross-transaction coalescing; 0 without the pipelined
    /// scheduler).
    pub coalesced_ops: u64,
    /// Sync doorbell plans the step-machine staged in-flight (posted with
    /// the doorbell deferred while the lane yielded); 0 without the
    /// pipelined scheduler. Doorbell-plane only — staged RPC plans are
    /// visible through the `rpc_*` family instead.
    pub staged_plans: u64,
    /// High-water mark of WQEs posted but not yet rung on any single CN
    /// NIC — the in-flight depth the step-machine reached.
    pub inflight_wqes_hwm: u64,
    /// Merged doorbell issues that carried >= 2 frames' staged plans
    /// (intra-transaction stage overlap events).
    pub overlap_rings: u64,
    /// Frames' staged plans carried by those merged issues
    /// (>= 2 x `overlap_rings` whenever any overlap happened).
    pub overlap_plans: u64,
    /// Ring events that completed >= 1 staged *doorbell* plan,
    /// re-enqueueing its parked lane into the scheduler's ready queue
    /// (the continuation model's resume events; 0 at depth 1 — nothing
    /// stages). Paired with `staged_plans`, so `resumed_plans ==
    /// staged_plans` in a crash-free run; RPC-plane staging is reported
    /// by `coalesced_rpc_reqs`/`rpc_messages_per_commit()` instead.
    pub resumed_rings: u64,
    /// Staged doorbell plans completed by those ring events (lane
    /// resumptions).
    pub resumed_plans: u64,
    /// Cumulative virtual ns staged doorbell plans waited between
    /// posting and the ring that carried them (see
    /// [`RunReport::mean_ring_gap_ns`]).
    pub ring_gap_ns: u64,
    /// CN-to-CN RPC messages sent (remote lock / unlock traffic) — the
    /// RPC-plane mirror of `doorbells`.
    pub rpc_messages: u64,
    /// Lock-class requests those messages carried (coalesced riders
    /// included) — the RPC-plane mirror of `doorbell_ops`.
    pub rpc_reqs: u64,
    /// Requests that rode a message another lane's lock batch paid for
    /// instead of sending their own (cross-lane RPC coalescing; 0
    /// without the pipelined scheduler).
    pub coalesced_rpc_reqs: u64,
    /// Lock-wait wakeups: lanes parked behind an anachronistic sibling
    /// holder, woken by its release (0 at depth <= 1).
    pub lock_waits: u64,
    /// Cumulative virtual ns between those waiters' park times and the
    /// holders' releases (see [`RunReport::mean_lock_wait_ns`]).
    pub lock_wait_ns: u64,
    /// Cumulative virtual ns RPC chunks spent queued at their
    /// destination's handler before service began (arrival -> service
    /// start, charged to the destination CN's NIC; see
    /// [`RunReport::mean_handler_wait_ns`]).
    pub handler_wait_ns: u64,
    /// Handler chunks those waits were measured over (one per
    /// owner-chunk serviced, including zero-wait chunks).
    pub handler_chunks: u64,
    /// 99th percentile per-chunk handler queueing delay (ns) across all
    /// destinations — the tail the adaptive coalescing controller reacts
    /// to.
    pub handler_wait_p99_ns: u64,
    /// Lock-phase RPC reissues after lost/timed-out messages (0 with
    /// `rpc_max_retries = 0`).
    pub rpc_retries: u64,
    /// RPC messages lost by the fault injector (0 without one).
    pub rpc_dropped: u64,
    /// Cumulative virtual ns lanes spent in retry backoff.
    pub backoff_ns: u64,
    /// Lock-phase degradations whose suspected owner CN was alive.
    pub false_suspicions: u64,
    /// Transactions proactively aborted because their lock owner was
    /// under suspicion.
    pub degraded_aborts: u64,
    /// Doorbell-plane WQEs hit by an injected MN fault — unreachable
    /// window, ring delay, or the dropped tail of a torn batch (0
    /// without an injector; the one-sided mirror of `rpc_dropped`).
    pub mn_op_faults: u64,
    /// Doorbell rings of which only a WQE prefix landed at the MN
    /// (`FaultMode::TornBatch`; 0 without an injector).
    pub torn_batches: u64,
    /// Shard transfers the balance tick executed mid-run (0 with the
    /// tick disabled or a plan that never moves anything).
    pub reshard_moves: u64,
    /// Transactions doomed by those transfers (holders force-released
    /// while their shard migrated; they abort and retry).
    pub reshard_aborted_txns: u64,
    /// Cumulative virtual ns of shard-transfer interruption charged to
    /// coordinator clock floors (pause -> ownership flip -> resume).
    pub reshard_interruption_ns: u64,
    /// Lock acquisitions that bounced with `WrongShardOwner` while
    /// racing a transfer and retried against the fresh routing map
    /// instead of aborting (0 without concurrent transfers).
    pub wrong_owner_bounces: u64,
}

impl RunReport {
    /// Throughput in million transactions per second (virtual time).
    pub fn mtps(&self) -> f64 {
        if self.duration_ns == 0 {
            return 0.0;
        }
        self.commits as f64 / (self.duration_ns as f64 / 1e9) / 1e6
    }

    /// Abort rate: aborted attempts / all attempts.
    pub fn abort_rate(&self) -> f64 {
        let attempts = self.commits + self.aborts;
        if attempts == 0 {
            0.0
        } else {
            self.aborts as f64 / attempts as f64
        }
    }

    /// P50 latency in microseconds.
    pub fn p50_us(&self) -> u64 {
        self.p50_ns / 1000
    }

    /// P99 latency in microseconds.
    pub fn p99_us(&self) -> u64 {
        self.p99_ns / 1000
    }

    /// Doorbells rung per committed transaction (the coalescing win the
    /// pipelined coordinator is measured by).
    pub fn doorbells_per_commit(&self) -> f64 {
        if self.commits == 0 {
            0.0
        } else {
            self.doorbells as f64 / self.commits as f64
        }
    }

    /// Mean WQEs per rung doorbell (riders included).
    pub fn ops_per_doorbell(&self) -> f64 {
        if self.doorbells == 0 {
            0.0
        } else {
            self.doorbell_ops as f64 / self.doorbells as f64
        }
    }

    /// Mean staged plans per overlap ring (0 when nothing overlapped) —
    /// how deeply sibling frames' issue points merged.
    pub fn mean_overlap_plans(&self) -> f64 {
        if self.overlap_rings == 0 {
            0.0
        } else {
            self.overlap_plans as f64 / self.overlap_rings as f64
        }
    }

    /// Fraction of staged plans that shared a merged doorbell issue with
    /// at least one sibling frame's plan.
    pub fn overlap_rate(&self) -> f64 {
        if self.staged_plans == 0 {
            0.0
        } else {
            self.overlap_plans as f64 / self.staged_plans as f64
        }
    }

    /// Mean virtual ns a staged plan waited between its post and the
    /// merged ring that carried it (0 when nothing staged) — how long
    /// parked lane continuations sat in the in-flight table before being
    /// re-enqueued.
    pub fn mean_ring_gap_ns(&self) -> f64 {
        if self.resumed_plans == 0 {
            0.0
        } else {
            self.ring_gap_ns as f64 / self.resumed_plans as f64
        }
    }

    /// Mean parked lanes resumed per ring event (0 without staging).
    pub fn mean_resumed_lanes(&self) -> f64 {
        if self.resumed_rings == 0 {
            0.0
        } else {
            self.resumed_plans as f64 / self.resumed_rings as f64
        }
    }

    /// RPC messages sent per committed transaction — the IOPS the
    /// RPC-plane coalescing is measured by (the paper's §4.1 batching
    /// claim, generalized across sibling lanes).
    pub fn rpc_messages_per_commit(&self) -> f64 {
        if self.commits == 0 {
            0.0
        } else {
            self.rpc_messages as f64 / self.commits as f64
        }
    }

    /// Mean lock-class requests per RPC message (riders included).
    pub fn reqs_per_rpc_message(&self) -> f64 {
        if self.rpc_messages == 0 {
            0.0
        } else {
            self.rpc_reqs as f64 / self.rpc_messages as f64
        }
    }

    /// Mean virtual ns a lock-wait bridged between the waiter's park and
    /// the anachronistic holder's release (0 without waits).
    pub fn mean_lock_wait_ns(&self) -> f64 {
        if self.lock_waits == 0 {
            0.0
        } else {
            self.lock_wait_ns as f64 / self.lock_waits as f64
        }
    }

    /// Mean virtual ns an RPC chunk queued at its destination's handler
    /// before service began (0 without RPC traffic) — the per-message
    /// queueing delay of the handler model, destination-side.
    pub fn mean_handler_wait_ns(&self) -> f64 {
        if self.handler_chunks == 0 {
            0.0
        } else {
            self.handler_wait_ns as f64 / self.handler_chunks as f64
        }
    }
}

/// Per-coordinator counters folded into a [`RunReport`].
#[derive(Default)]
pub struct TxnStats {
    /// Committed count.
    pub commits: AtomicU64,
    /// Aborted attempts.
    pub aborts: AtomicU64,
    /// Abort reasons.
    pub reasons: std::sync::Mutex<HashMap<AbortReason, u64>>,
}

impl TxnStats {
    /// Record a commit.
    pub fn commit(&self) {
        self.commits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an abort attempt with its reason.
    pub fn abort(&self, reason: AbortReason) {
        self.aborts.fetch_add(1, Ordering::Relaxed);
        *self.reasons.lock().unwrap().entry(reason).or_insert(0) += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn histogram_single_value() {
        let h = Histogram::new();
        h.record(1000);
        assert_eq!(h.count(), 1);
        let p50 = h.p50();
        assert!((968..=1032).contains(&p50), "p50={p50}");
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for i in 1..=10_000u64 {
            h.record(i);
        }
        let p50 = h.p50();
        let p90 = h.quantile(0.90);
        let p99 = h.p99();
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        // ~3% relative error bound
        assert!((4800..=5300).contains(&p50), "p50={p50}");
        assert!((9500..=10200).contains(&p99), "p99={p99}");
    }

    #[test]
    fn histogram_small_values_exact() {
        let h = Histogram::new();
        for v in [0u64, 1, 2, 3, 7, 15, 31] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.max(), 31);
    }

    #[test]
    fn histogram_merge() {
        let a = Histogram::new();
        let b = Histogram::new();
        for i in 0..500 {
            a.record(i);
        }
        for i in 500..1000 {
            b.record(i);
        }
        a.merge(&b);
        assert_eq!(a.count(), 1000);
        let p50 = a.p50();
        assert!((450..=560).contains(&p50), "p50={p50}");
    }

    #[test]
    fn histogram_huge_values() {
        let h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX / 2);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(1.0) > 1u64 << 60);
    }

    #[test]
    fn report_mtps() {
        let r = RunReport {
            commits: 1_000_000,
            aborts: 0,
            duration_ns: 1_000_000_000,
            p50_ns: 0,
            p99_ns: 0,
            mean_ns: 0.0,
            abort_reasons: HashMap::new(),
            timeline: vec![],
            timeline_interval_ns: 0,
            doorbells: 4_000_000,
            doorbell_ops: 10_000_000,
            coalesced_ops: 2_000_000,
            staged_plans: 1_000_000,
            inflight_wqes_hwm: 12,
            overlap_rings: 200_000,
            overlap_plans: 600_000,
            resumed_rings: 250_000,
            resumed_plans: 1_000_000,
            ring_gap_ns: 2_000_000_000,
            rpc_messages: 500_000,
            rpc_reqs: 2_000_000,
            coalesced_rpc_reqs: 750_000,
            lock_waits: 10_000,
            lock_wait_ns: 30_000_000,
            handler_wait_ns: 1_000_000_000,
            handler_chunks: 2_000_000,
            handler_wait_p99_ns: 4_000,
            rpc_retries: 0,
            rpc_dropped: 0,
            backoff_ns: 0,
            false_suspicions: 0,
            degraded_aborts: 0,
            mn_op_faults: 0,
            torn_batches: 0,
            reshard_moves: 0,
            reshard_aborted_txns: 0,
            reshard_interruption_ns: 0,
            wrong_owner_bounces: 0,
        };
        assert!((r.mtps() - 1.0).abs() < 1e-9);
        assert!((r.doorbells_per_commit() - 4.0).abs() < 1e-9);
        assert!((r.ops_per_doorbell() - 2.5).abs() < 1e-9);
        assert!((r.mean_overlap_plans() - 3.0).abs() < 1e-9);
        assert!((r.overlap_rate() - 0.6).abs() < 1e-9);
        assert!((r.mean_ring_gap_ns() - 2_000.0).abs() < 1e-9);
        assert!((r.mean_resumed_lanes() - 4.0).abs() < 1e-9);
        assert!((r.rpc_messages_per_commit() - 0.5).abs() < 1e-9);
        assert!((r.reqs_per_rpc_message() - 4.0).abs() < 1e-9);
        assert!((r.mean_lock_wait_ns() - 3_000.0).abs() < 1e-9);
        assert!((r.mean_handler_wait_ns() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn prop_quantile_monotone() {
        crate::testing::prop(30, |g| {
            let h = Histogram::new();
            let n = g.usize(1, 2000);
            for _ in 0..n {
                h.record(g.u64(0, 1_000_000));
            }
            let mut last = 0;
            for q in [0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
                let v = h.quantile(q);
                assert!(v >= last, "quantile not monotone");
                last = v;
            }
        });
    }
}
