//! In-tree property-testing harness.
//!
//! `proptest` is not in the offline vendor set, so this module provides a
//! deliberately small equivalent: seeded random-input sweeps with
//! counterexample reporting and automatic input shrinking for integer
//! vectors. Property tests across the crate (`lock`, `store`, `sharding`,
//! `txn`, `recovery`) are written against this harness.
//!
//! ```no_run
//! use lotus::testing::{prop, Gen};
//! prop(100, |g| {
//!     let a = g.u64(0, 1000);
//!     let b = g.u64(0, 1000);
//!     assert_eq!(a + b, b + a, "addition commutes");
//! });
//! ```

use crate::util::Xoshiro256;

/// Random input generator handed to property closures.
pub struct Gen {
    rng: Xoshiro256,
    /// Trace of drawn values — printed on failure for reproduction.
    trace: Vec<u64>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::new(seed),
            trace: Vec::new(),
        }
    }

    /// Uniform u64 in `[lo, hi]`.
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        let v = self.rng.range_inclusive(lo, hi);
        self.trace.push(v);
        v
    }

    /// Uniform usize in `[lo, hi]`.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    /// Uniform u32.
    pub fn u32(&mut self) -> u32 {
        self.u64(0, u32::MAX as u64) as u32
    }

    /// Arbitrary u64 over the full range.
    pub fn any_u64(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.trace.push(v);
        v
    }

    /// Bernoulli with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        let v = self.rng.chance(p);
        self.trace.push(v as u64);
        v
    }

    /// f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        let v = self.rng.next_f64();
        self.trace.push(v.to_bits());
        v
    }

    /// Vector of `len` u64s in `[lo, hi]`.
    pub fn vec_u64(&mut self, len: usize, lo: u64, hi: u64) -> Vec<u64> {
        (0..len).map(|_| self.u64(lo, hi)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.usize(0, xs.len() - 1);
        &xs[i]
    }
}

/// Run `cases` random cases of `property`. Panics (with the failing seed)
/// on the first failure. Set `LOTUS_PROP_SEED` to reproduce a case, and
/// `LOTUS_PROP_CASES` to override the case count.
pub fn prop<F: FnMut(&mut Gen) + std::panic::UnwindSafe + Copy>(cases: usize, property: F) {
    let cases = std::env::var("LOTUS_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);
    if let Ok(seed) = std::env::var("LOTUS_PROP_SEED") {
        let seed: u64 = seed.parse().expect("LOTUS_PROP_SEED must be u64");
        let mut g = Gen::new(seed);
        let mut p = property;
        p(&mut g);
        return;
    }
    for case in 0..cases {
        let seed = 0x5EED_0000 + case as u64;
        let result = std::panic::catch_unwind(move || {
            let mut g = Gen::new(seed);
            let mut p = property;
            p(&mut g);
            g.trace
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed on case {case} (reproduce with \
                 LOTUS_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_passes_trivial_property() {
        prop(50, |g| {
            let a = g.u64(0, 100);
            assert!(a <= 100);
        });
    }

    #[test]
    fn prop_reports_failures() {
        let result = std::panic::catch_unwind(|| {
            prop(50, |g| {
                let a = g.u64(0, 100);
                assert!(a < 5, "value too large: {a}");
            });
        });
        let err = result.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("LOTUS_PROP_SEED="), "got: {msg}");
    }

    #[test]
    fn gen_bounds_respected() {
        prop(100, |g| {
            let lo = g.u64(0, 50);
            let hi = lo + g.u64(0, 50);
            let v = g.u64(lo, hi);
            assert!(v >= lo && v <= hi);
        });
    }
}
