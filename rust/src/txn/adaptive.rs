//! Adaptive coalescing controller (ISSUE 6): per-plane × per-destination
//! congestion control over the fabric's queueing signals.
//!
//! The fixed `coalesce_window_ns` is only right at one load point (the
//! paper's fig. 14 TATP ablation): too narrow when an MN RNIC or a hot
//! destination CN's lock-handler CPU is IOPS-bound, too wide when commits
//! are latency-bound. This controller closes the loop between the
//! counters the fabric already emits and the window each staged plan
//! waits — **per plane** (doorbell vs CN-to-CN RPC) and **per
//! destination** (MN id vs destination CN id), because the bottleneck is
//! a property of one destination queue, not of the cluster.
//!
//! # Signals
//!
//! Each merged issue feeds one [`Obs`] per destination it touched:
//!
//! - `queue_wait_ns` — the destination's booked backlog beyond the
//!   issue's arrival ([`crate::dm::RpcFabric::handler_backlog_ns`] on the
//!   RPC plane; MN `busy_until - t_ring` on the doorbell plane). This is
//!   the *pre-send* congestion signal: virtual ns this issue's requests
//!   will sit in the destination queue before service starts.
//! - `batch` — requests/WQEs the merged issue carried to the destination
//!   (the realized `reqs_per_rpc_message` / `ops_per_doorbell`).
//! - `gap_ns` — how long the issue's oldest plan sat staged
//!   (the realized per-issue `mean_ring_gap_ns`).
//! - `hwm` — posted-WQE high-water mark / merged-group depth, evidence
//!   there is actual concurrency for a wider window to harvest.
//!
//! All three continuous signals are EWMA-smoothed (α = 1/8, integer
//! shift arithmetic — deterministic and wrap-free by saturation).
//!
//! # Policy
//!
//! - **Widen** (destination IOPS/handler-bound): smoothed queue wait
//!   exceeds the smoothed staging gap by more than half the base window —
//!   waiting longer to merge is cheaper than queueing at the destination
//!   — and there is concurrency to merge (`hwm >= 2` or a multi-plan
//!   group) and batches are not already saturated. Step up by base/4,
//!   clamped at `cap_ns` (8 × base).
//! - **Shrink** (latency-bound): the destination queue is essentially
//!   drained (smoothed wait under base/8) — staging only adds latency.
//!   Step down by base/4, saturating at 0 (= direct issue).
//! - Otherwise hold.
//!
//! The controller is *inert until observed*: an unseen destination's
//! window is exactly the configured base, so a run where nothing stages
//! (depth 1) or nothing queues behaves byte-identically to the fixed
//! policy — the depth-1 equivalence anchor holds with
//! `adaptive_coalescing` enabled.

use std::cell::RefCell;

/// Effective-window cap as a multiple of the configured base window.
pub const CAP_MULT: u64 = 8;

/// EWMA smoothing shift: α = 1/2^EWMA_SHIFT = 1/8.
const EWMA_SHIFT: u32 = 3;

/// Batch-size fixed point (×16) above which a destination's merges are
/// considered saturated — widening further cannot buy more amortization.
const BATCH_SAT_X16: u64 = 16 * 16;

/// One merged issue's worth of congestion evidence for one destination.
#[derive(Debug, Clone, Copy, Default)]
pub struct Obs {
    /// Destination queue backlog beyond this issue's arrival (virtual ns).
    pub queue_wait_ns: u64,
    /// Requests/WQEs this merged issue carried to the destination.
    pub batch: u64,
    /// Staging delay of the issue's oldest plan (virtual ns).
    pub gap_ns: u64,
    /// Posted-WQE HWM / merged-group depth at issue time.
    pub hwm: u64,
}

/// The two fabric planes the scheduler coalesces on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Plane {
    /// One-sided doorbell batches; destinations are MN ids.
    Doorbell,
    /// CN-to-CN lock RPC messages; destinations are CN ids.
    Rpc,
}

/// Per-destination controller state.
#[derive(Debug, Clone, Copy)]
struct DestState {
    window_ns: u64,
    ewma_wait_ns: u64,
    ewma_gap_ns: u64,
    ewma_batch_x16: u64,
}

impl DestState {
    fn new(base_ns: u64) -> Self {
        Self {
            window_ns: base_ns,
            ewma_wait_ns: 0,
            ewma_gap_ns: 0,
            ewma_batch_x16: 0,
        }
    }
}

/// Saturating integer EWMA: `prev + (x - prev) / 2^EWMA_SHIFT`.
#[inline]
fn ewma(prev: u64, x: u64) -> u64 {
    prev.saturating_sub(prev >> EWMA_SHIFT)
        .saturating_add(x >> EWMA_SHIFT)
}

/// Per-plane × per-destination adaptive window controller.
///
/// Interior-mutable (`RefCell` per plane) so the `Coalescer` can consult
/// it from `&self` contexts; single-coordinator-thread discipline is the
/// same as the `Coalescer`'s own state.
#[derive(Debug)]
pub struct AdaptiveController {
    base_ns: u64,
    cap_ns: u64,
    db: RefCell<Vec<DestState>>,
    rpc: RefCell<Vec<DestState>>,
}

impl AdaptiveController {
    /// Controller anchored at the configured base window.
    pub fn new(base_ns: u64) -> Self {
        Self {
            base_ns,
            cap_ns: base_ns.saturating_mul(CAP_MULT),
            db: RefCell::new(Vec::new()),
            rpc: RefCell::new(Vec::new()),
        }
    }

    /// The configured base window (what fixed policy would use).
    pub fn base_ns(&self) -> u64 {
        self.base_ns
    }

    /// The widest window the controller will ever grant.
    pub fn cap_ns(&self) -> u64 {
        self.cap_ns
    }

    /// Current effective window for `(plane, dst)`; the base for
    /// destinations never observed.
    pub fn window(&self, plane: Plane, dst: usize) -> u64 {
        let states = match plane {
            Plane::Doorbell => self.db.borrow(),
            Plane::Rpc => self.rpc.borrow(),
        };
        states
            .get(dst)
            .map(|s| s.window_ns)
            .unwrap_or(self.base_ns)
    }

    /// Feed one merged issue's evidence for `(plane, dst)` and adjust
    /// that destination's window.
    pub fn observe(&self, plane: Plane, dst: usize, obs: Obs) {
        let mut states = match plane {
            Plane::Doorbell => self.db.borrow_mut(),
            Plane::Rpc => self.rpc.borrow_mut(),
        };
        if states.len() <= dst {
            states.resize(dst + 1, DestState::new(self.base_ns));
        }
        let s = &mut states[dst];
        s.ewma_wait_ns = ewma(s.ewma_wait_ns, obs.queue_wait_ns);
        s.ewma_gap_ns = ewma(s.ewma_gap_ns, obs.gap_ns);
        s.ewma_batch_x16 = ewma(s.ewma_batch_x16, obs.batch.saturating_mul(16));
        let step = (self.base_ns / 4).max(1);
        let bound = s.ewma_wait_ns > s.ewma_gap_ns.saturating_add(self.base_ns / 2);
        let drained = s.ewma_wait_ns < self.base_ns / 8;
        let saturated = s.ewma_batch_x16 >= BATCH_SAT_X16;
        if bound && obs.hwm >= 2 && !saturated {
            s.window_ns = s.window_ns.saturating_add(step).min(self.cap_ns);
        } else if drained {
            s.window_ns = s.window_ns.saturating_sub(step);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unseen_destination_gets_the_base_window() {
        let c = AdaptiveController::new(5_000);
        assert_eq!(c.base_ns(), 5_000);
        assert_eq!(c.cap_ns(), 40_000);
        assert_eq!(c.window(Plane::Doorbell, 0), 5_000);
        assert_eq!(c.window(Plane::Rpc, 17), 5_000);
    }

    #[test]
    fn planes_and_destinations_are_independent() {
        let c = AdaptiveController::new(1_000);
        // Drain signal on RPC dst 2 only.
        for _ in 0..20 {
            c.observe(Plane::Rpc, 2, Obs::default());
        }
        assert_eq!(c.window(Plane::Rpc, 2), 0, "drained dst shrinks to direct");
        assert_eq!(c.window(Plane::Rpc, 1), 1_000, "sibling dst untouched");
        assert_eq!(c.window(Plane::Doorbell, 2), 1_000, "other plane untouched");
    }

    #[test]
    fn hot_destination_widens_and_drained_destination_shrinks() {
        let c = AdaptiveController::new(5_000);
        let hot = Obs {
            queue_wait_ns: 100_000,
            batch: 4,
            gap_ns: 2_000,
            hwm: 4,
        };
        for _ in 0..100 {
            c.observe(Plane::Rpc, 0, hot);
        }
        assert_eq!(c.window(Plane::Rpc, 0), c.cap_ns(), "widens to the cap");
        let idle = Obs {
            queue_wait_ns: 0,
            batch: 1,
            gap_ns: 0,
            hwm: 1,
        };
        for _ in 0..100 {
            c.observe(Plane::Rpc, 0, idle);
        }
        assert_eq!(c.window(Plane::Rpc, 0), 0, "drains back to direct issue");
    }

    #[test]
    fn no_widening_without_concurrency_or_past_batch_saturation() {
        let c = AdaptiveController::new(5_000);
        // Huge wait but hwm < 2: nothing to merge, window must not grow.
        let lonely = Obs {
            queue_wait_ns: 1_000_000,
            batch: 1,
            gap_ns: 0,
            hwm: 1,
        };
        for _ in 0..50 {
            c.observe(Plane::Doorbell, 3, lonely);
        }
        assert_eq!(c.window(Plane::Doorbell, 3), 5_000);
        // Saturated batches: merges already amortize fully; once the batch
        // EWMA crosses the threshold (a few observations), widening stops.
        let saturated = Obs {
            queue_wait_ns: 1_000_000,
            batch: 64,
            gap_ns: 0,
            hwm: 8,
        };
        for _ in 0..5 {
            c.observe(Plane::Doorbell, 4, saturated);
        }
        let settled = c.window(Plane::Doorbell, 4);
        for _ in 0..50 {
            c.observe(Plane::Doorbell, 4, saturated);
        }
        assert_eq!(
            c.window(Plane::Doorbell, 4),
            settled,
            "saturated batches stop widening"
        );
        assert!(settled < c.cap_ns());
    }

    #[test]
    fn adversarial_inputs_never_escape_the_cap_or_wrap_below_zero() {
        let c = AdaptiveController::new(5_000);
        let worst = Obs {
            queue_wait_ns: u64::MAX,
            batch: 0, // ewma_batch stays 0 => never saturated
            gap_ns: 0,
            hwm: u64::MAX,
        };
        for _ in 0..10_000 {
            c.observe(Plane::Rpc, 0, worst);
            let w = c.window(Plane::Rpc, 0);
            assert!(w <= c.cap_ns(), "window {w} escaped cap {}", c.cap_ns());
        }
        assert_eq!(c.window(Plane::Rpc, 0), c.cap_ns());
        // Flood the other direction: all-zero observations forever.
        for _ in 0..10_000 {
            c.observe(Plane::Rpc, 0, Obs::default());
        }
        assert_eq!(c.window(Plane::Rpc, 0), 0, "saturates at 0, no wrap");
        // Alternating extremes stay clamped in [0, cap].
        for i in 0..10_000u64 {
            let obs = if i % 2 == 0 { worst } else { Obs::default() };
            c.observe(Plane::Doorbell, 1, obs);
            let w = c.window(Plane::Doorbell, 1);
            assert!(w <= c.cap_ns(), "window {w} escaped cap");
        }
        // A degenerate base of 0 pins the window at 0 (cap == 0).
        let z = AdaptiveController::new(0);
        for _ in 0..100 {
            z.observe(Plane::Rpc, 0, worst);
        }
        assert_eq!(z.window(Plane::Rpc, 0), 0);
        // u64::MAX base must not overflow the cap computation.
        let m = AdaptiveController::new(u64::MAX);
        assert_eq!(m.cap_ns(), u64::MAX);
        m.observe(Plane::Rpc, 0, worst);
        assert!(m.window(Plane::Rpc, 0) <= u64::MAX);
    }

    #[test]
    fn ewma_is_saturating_and_monotone_toward_input() {
        assert_eq!(ewma(0, 0), 0);
        assert_eq!(ewma(0, 800), 100);
        let big = ewma(u64::MAX, u64::MAX);
        assert!(big >= u64::MAX - (u64::MAX >> EWMA_SHIFT));
        // Repeated constant input converges near that constant.
        let mut v = 0u64;
        for _ in 0..200 {
            v = ewma(v, 10_000);
        }
        assert!((9_000..=10_000).contains(&v), "v={v}");
    }
}
