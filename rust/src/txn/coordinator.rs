//! The LOTUS coordinator: the lock-first transaction protocol (paper §5).
//!
//! One coordinator is one concurrent transaction stream on a CN. The
//! protocol is two-phase (fig. 10):
//!
//! **Execution** — 1) *Lock Data*: write locks for the read-write set,
//! read locks for the read-only set (SR only); local locks are CPU CAS on
//! the local lock table, remote locks are batched per owner CN into one
//! RPC. Any failure aborts immediately — before a single byte is read
//! from the memory pool. 2) *Read CVT*: served from the version table
//! cache (locally owned keys), the address cache (one CVT READ), or a
//! bucket READ + search. 3) *Read Data*: MVCC select the largest version
//! <= T_start; a newer visible version aborts an SR read-write
//! transaction.
//!
//! **Commit** — 1) *Write Data & Log*: new versions (INVISIBLE) + the
//! metadata log go to the memory pool, primaries and backups in the same
//! doorbell batches. 2) *Get Timestamp*. 3) *Write Visible*: the commit
//! timestamp overwrites INVISIBLE. 4) *Unlock*: local releases are CPU
//! ops; remote releases are fire-and-forget RPCs (the coordinator returns
//! without waiting, paper 5.1).
//!
//! [`SharedCluster`] is the cluster-wide shared state every coordinator
//! holds an `Arc` of; [`crate::sim::Cluster`] builds it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::balance::BalanceMetrics;
use crate::cache::vtcache::CachedCvt;
use crate::cache::{AddrCache, VtCache};
use crate::config::Config;
use crate::dm::clock::VClock;
use crate::dm::memnode::MemNode;
use crate::dm::rnic::Rnic;
use crate::dm::rpc::RpcFabric;
use crate::dm::verbs::{Endpoint, VerbOp};
use crate::dm::NetConfig;
use crate::lock::service::LockService;
use crate::lock::state::HolderId;
use crate::lock::table::LockMode;
use crate::recovery::membership::Membership;
use crate::sharding::key::LotusKey;
use crate::sharding::router::Router;
use crate::store::cvt::{CellSnapshot, CvtSnapshot, INVISIBLE};
use crate::store::index::TableStore;
use crate::store::{gc, record};
use crate::txn::api::{Isolation, RecordRef, TxnApi, TxnCtl};
use crate::txn::doomed::DoomedSet;
use crate::txn::log::{LogEntry, LogRecord, STATE_EMPTY};
use crate::txn::timestamp::{phys_of, TimestampOracle};
use crate::{abort, AbortReason, Error, Result};

/// Cluster-wide shared state (one per simulated cluster).
pub struct SharedCluster {
    /// Effective configuration.
    pub cfg: Config,
    /// Memory nodes.
    pub mns: Vec<Arc<MemNode>>,
    /// Per-CN NICs (shared by the CN's coordinators).
    pub cn_nics: Vec<Arc<Rnic>>,
    /// CN-to-CN RPC fabric.
    pub rpc: Arc<RpcFabric>,
    /// The routing layer.
    pub router: Arc<Router>,
    /// Timestamp oracle.
    pub oracle: Arc<TimestampOracle>,
    /// Cost model.
    pub net: Arc<NetConfig>,
    /// Per-CN lock services.
    pub lock_services: Vec<Arc<LockService>>,
    /// Per-CN version table caches.
    pub vt_caches: Vec<Arc<VtCache>>,
    /// Per-CN address caches.
    pub addr_caches: Vec<Arc<AddrCache>>,
    /// DB tables, indexed by table id.
    pub tables: Vec<Arc<TableStore>>,
    /// Doomed-transaction registry (resharding + recovery).
    pub doomed: Arc<DoomedSet>,
    /// Load-balancer metrics.
    pub metrics: Arc<BalanceMetrics>,
    /// CN membership (failure detection).
    pub membership: Arc<Membership>,
    /// Per-coordinator log slots: `(mn, addr)` by global coordinator id.
    pub log_slots: Vec<(usize, u64)>,
    /// Baseline systems' MN-side lock regions, aligned with `tables`:
    /// base address (on each table's primary MN) of one 8B lock word per
    /// CVT slot plus one per bucket (insert locks). Unused by LOTUS.
    pub baseline_lock_bases: Vec<u64>,
    /// Global transaction-id counter.
    pub txn_counter: AtomicU64,
}

impl SharedCluster {
    /// Next globally unique transaction id.
    pub fn next_txn_id(&self) -> u64 {
        self.txn_counter.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// The table with id `t` (panics on unknown id — a config error).
    #[inline]
    pub fn table(&self, t: u16) -> &TableStore {
        &self.tables[t as usize]
    }
}

/// Per-record transaction state.
#[derive(Debug, Clone)]
struct TxnRecord {
    r: RecordRef,
    /// Write intent (vs read-lock only).
    write: bool,
    /// Insert (vs update of an existing record).
    insert: bool,
    /// Delete (clears the CVT at commit).
    delete: bool,
    /// Value read by `execute` (update/read paths).
    value: Option<Vec<u8>>,
    /// Staged new value.
    new_value: Option<Vec<u8>>,
    /// The CVT observed at execute (fresh template for inserts).
    cvt: Option<CvtSnapshot>,
    /// Primary CVT address.
    cvt_addr: u64,
    /// Index bucket.
    bucket: u64,
    /// CVT slot within the bucket.
    slot: u8,
    /// True if the CVT came from this CN's VT cache.
    from_cache: bool,
    /// VT-cache epoch captured before a lock-free CVT read (RO fills).
    fill_epoch: Option<u64>,
}

impl TxnRecord {
    fn new(r: RecordRef, write: bool) -> Self {
        Self {
            r,
            write,
            insert: false,
            delete: false,
            value: None,
            new_value: None,
            cvt: None,
            cvt_addr: 0,
            bucket: 0,
            slot: 0,
            from_cache: false,
            fill_epoch: None,
        }
    }
}

/// A held lock (for release).
#[derive(Debug, Clone, Copy)]
struct Held {
    key: LotusKey,
    mode: LockMode,
    owner_cn: usize,
}

/// Transaction phase (assertion state machine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Idle,
    Building,
    Executed,
}

/// The LOTUS coordinator (one per concurrent transaction stream).
pub struct LotusCoordinator {
    /// Shared cluster state.
    pub cluster: Arc<SharedCluster>,
    /// This coordinator's CN.
    pub cn: usize,
    /// Coordinator slot within the CN (i-th coordinator RPC pairing, §4.1).
    pub slot: usize,
    /// Global coordinator id (log-slot index, time-gate id).
    pub global_id: usize,
    /// Virtual clock.
    pub clk: VClock,
    ep: Endpoint,
    rng: crate::util::Xoshiro256,
    // --- in-flight transaction state (reused across transactions) ---
    txn_id: u64,
    read_only: bool,
    start_ts: u64,
    phase: Phase,
    records: Vec<TxnRecord>,
    /// Records below this index were handled by a previous `execute` round
    /// (the paper: "execution may occur multiple times, dynamically adding
    /// new data to the read/write sets").
    executed_upto: usize,
    held: Vec<Held>,
}

impl LotusCoordinator {
    /// Coordinator `slot` on CN `cn`.
    pub fn new(cluster: Arc<SharedCluster>, cn: usize, slot: usize, global_id: usize) -> Self {
        let ep = Endpoint::new(cn, cluster.cn_nics[cn].clone(), cluster.net.clone());
        let seed = cluster.cfg.seed ^ (global_id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Self {
            cluster,
            cn,
            slot,
            global_id,
            clk: VClock::zero(),
            ep,
            rng: crate::util::Xoshiro256::new(seed),
            txn_id: 0,
            read_only: false,
            start_ts: 0,
            phase: Phase::Idle,
            records: Vec::new(),
            executed_upto: 0,
            held: Vec::new(),
        }
    }

    #[inline]
    fn holder(&self) -> HolderId {
        HolderId {
            cn: self.cn,
            txn: self.txn_id,
        }
    }

    #[inline]
    fn net(&self) -> &NetConfig {
        &self.cluster.net
    }

    /// Effective isolation level.
    #[inline]
    fn isolation(&self) -> Isolation {
        self.cluster.cfg.isolation
    }

    // ------------------------------------------------------------------
    // Lock phase
    // ------------------------------------------------------------------

    /// Every lock request records `[from..]` need: `(key, mode)`.
    fn lock_requests(&self, from: usize) -> Vec<(LotusKey, LockMode)> {
        let mut reqs = Vec::with_capacity(self.records.len() - from + 2);
        for rec in &self.records[from..] {
            if rec.write {
                reqs.push((rec.r.key, LockMode::Write));
                if rec.insert || rec.delete {
                    // Inserts/deletes also lock the index bucket (§4.1) —
                    // the whole probe chain, since placement (insert) or
                    // residence (delete) may be any bucket in it and the
                    // lock-first protocol locks before reading.
                    let table = self.cluster.table(rec.r.table);
                    for b in table.probe_buckets(rec.r.key) {
                        reqs.push((table.bucket_lock_key(b), LockMode::Write));
                    }
                }
            } else if self.isolation() == Isolation::Serializable {
                reqs.push((rec.r.key, LockMode::Read));
            }
        }
        reqs
    }

    /// Acquire all locks (lock-first step). On failure, everything already
    /// acquired is released and the transaction aborts.
    fn lock_phase(&mut self, from: usize) -> Result<()> {
        let reqs = self.lock_requests(from);
        if reqs.is_empty() {
            return Ok(());
        }
        let router = self.cluster.router.clone();
        let holder = self.holder();
        // Partition into local and per-remote-CN batches.
        let mut local: Vec<(LotusKey, LockMode)> = Vec::new();
        let mut remote: Vec<(usize, Vec<(LotusKey, LockMode)>)> = Vec::new();
        for (key, mode) in reqs {
            let owner = router.owner_of_key(key);
            self.cluster.metrics.record_request(owner, key.shard());
            if owner == self.cn {
                local.push((key, mode));
            } else {
                match remote.iter_mut().find(|(cn, _)| *cn == owner) {
                    Some((_, v)) => v.push((key, mode)),
                    None => remote.push((owner, vec![(key, mode)])),
                }
            }
        }
        // Local locks: CPU CAS (Algorithm 1).
        for &(key, mode) in &local {
            self.clk.advance(self.net().local_lock_ns);
            match self.cluster.lock_services[self.cn]
                .try_acquire(&router, key, mode, holder, false)
            {
                Ok(true) => self.held.push(Held {
                    key,
                    mode,
                    owner_cn: self.cn,
                }),
                Ok(false) => {
                    self.release_locks();
                    return Err(abort(AbortReason::LockConflict));
                }
                Err(Error::LockBucketFull) => {
                    self.release_locks();
                    return Err(abort(AbortReason::LockConflict));
                }
                Err(Error::WrongShardOwner { .. }) => {
                    // Stale route (shard migrating) — abort; the retry will
                    // see the fresh map.
                    self.release_locks();
                    return Err(abort(AbortReason::LockConflict));
                }
                Err(e) => return Err(e),
            }
        }
        // Remote locks: one batched RPC per target CN (§4.1).
        for (target, batch) in remote {
            self.ep.gate_sync(&self.clk);
            if let Err(e) = self
                .cluster
                .rpc
                .call(self.cn, target, self.slot, batch.len(), &mut self.clk)
            {
                // CN failed: the paper aborts transactions waiting on the
                // failed CN's locks (§6).
                let _ = e;
                self.release_locks();
                return Err(abort(AbortReason::OwnerFailed));
            }
            for &(key, mode) in &batch {
                match self.cluster.lock_services[target]
                    .try_acquire(&router, key, mode, holder, true)
                {
                    Ok(true) => self.held.push(Held {
                        key,
                        mode,
                        owner_cn: target,
                    }),
                    Ok(false) | Err(Error::LockBucketFull) | Err(Error::WrongShardOwner { .. }) => {
                        self.release_locks();
                        return Err(abort(AbortReason::LockConflict));
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        Ok(())
    }

    /// Release everything held (abort path or post-commit unlock).
    /// Local locks are CPU ops; remote locks batch into async RPCs.
    fn release_locks(&mut self) {
        if self.held.is_empty() {
            return;
        }
        let holder = self.holder();
        let mut remote: Vec<(usize, usize)> = Vec::new(); // (cn, count)
        for h in std::mem::take(&mut self.held) {
            if h.owner_cn == self.cn {
                self.clk.advance(self.net().local_lock_ns);
            } else {
                match remote.iter_mut().find(|(cn, _)| *cn == h.owner_cn) {
                    Some((_, n)) => *n += 1,
                    None => remote.push((h.owner_cn, 1)),
                }
            }
            self.cluster.lock_services[h.owner_cn].release(h.key, h.mode, holder);
        }
        for (target, n) in remote {
            // Fire-and-forget (paper 5.1): failures are ignored — recovery
            // releases the locks of failed CNs.
            self.ep.gate_sync(&self.clk);
            let _ = self
                .cluster
                .rpc
                .call_async(self.cn, target, self.slot, n, &mut self.clk);
        }
    }

    // ------------------------------------------------------------------
    // Read phase
    // ------------------------------------------------------------------

    /// Probe a key's bucket chain with charged READs; `skip` leading
    /// buckets are assumed already searched. Returns `(bucket, slot, cvt)`.
    fn probe_find(
        &mut self,
        table: &Arc<TableStore>,
        key: LotusKey,
        skip: usize,
    ) -> Result<Option<(u64, u8, CvtSnapshot)>> {
        let buckets: Vec<u64> = table.probe_buckets(key).skip(skip).collect();
        let mn = self.cluster.mns[table.primary().mn].clone();
        for b in buckets {
            let buf = self.ep.read(
                &mn,
                table.bucket_addr(0, b),
                table.layout.bucket_size() as usize,
                &mut self.clk,
            )?;
            if let Some((slot, cvt)) = table.find_in_bucket(&buf, key) {
                return Ok(Some((b, slot, cvt)));
            }
        }
        Ok(None)
    }

    /// Insert placement: read the whole probe chain in one doorbell,
    /// reject duplicates anywhere in it, pick the first empty slot.
    fn probe_place_insert(
        &mut self,
        table: &Arc<TableStore>,
        key: LotusKey,
    ) -> Result<(u64, u8)> {
        let buckets: Vec<u64> = table.probe_buckets(key).collect();
        let mn = self.cluster.mns[table.primary().mn].clone();
        let mut ops: Vec<VerbOp> = buckets
            .iter()
            .map(|&b| VerbOp::Read {
                addr: table.bucket_addr(0, b),
                out: vec![0u8; table.layout.bucket_size() as usize],
            })
            .collect();
        self.ep.doorbell(&mn, &mut ops, &mut self.clk)?;
        let mut placed = None;
        for (&b, op) in buckets.iter().zip(&ops) {
            let VerbOp::Read { out, .. } = op else { unreachable!() };
            if table.find_in_bucket(out, key).is_some() {
                self.rollback_internal();
                return Err(abort(AbortReason::Duplicate));
            }
            if placed.is_none() {
                if let Some(slot) = table.find_empty_in_bucket(out) {
                    placed = Some((b, slot));
                }
            }
        }
        placed.ok_or_else(|| {
            self.rollback_internal();
            Error::OutOfMemory(format!(
                "table {} probe chain of key {:#x} full",
                table.spec.name, key.0
            ))
        })
    }

    /// Step 2: obtain every record's CVT (cache / addr cache / bucket).
    fn read_cvt_phase(&mut self, from: usize) -> Result<()> {
        let use_vt_cache = self.cluster.cfg.features.vt_cache;
        let vt_cache = self.cluster.vt_caches[self.cn].clone();
        let addr_cache = self.cluster.addr_caches[self.cn].clone();
        let router = self.cluster.router.clone();

        // Pass 1: cache hits + collect the reads we must issue.
        // reads: (record idx, mn, addr, len, whole_bucket)
        let mut reads: Vec<(usize, usize, u64, usize, bool)> = Vec::new();
        for i in from..self.records.len() {
            let (r, is_insert) = {
                let rec = &self.records[i];
                (rec.r, rec.insert)
            };
            let table = self.cluster.tables[r.table as usize].clone();
            let bucket = table.bucket_of(r.key);
            let local = router.owner_of_key(r.key) == self.cn;
            if use_vt_cache && local && !is_insert {
                self.clk.advance(self.net().cache_op_ns);
                if let Some(hit) = vt_cache.get(r.key) {
                    let (b, s) = table.locate_cvt(hit.addr)?;
                    let rec = &mut self.records[i];
                    rec.cvt = Some(hit.cvt);
                    rec.cvt_addr = hit.addr;
                    rec.bucket = b;
                    rec.slot = s;
                    rec.from_cache = true;
                    continue;
                }
            }
            if is_insert {
                // Placement reads the whole probe chain in one doorbell.
                let (b, slot) = self.probe_place_insert(&table, r.key)?;
                let mut cvt = CvtSnapshot::empty(table.spec.ncells);
                cvt.key = r.key.0;
                cvt.occupied = true;
                cvt.table_id = table.spec.id;
                let rec = &mut self.records[i];
                rec.cvt_addr = table.cvt_addr(0, b, slot);
                rec.bucket = b;
                rec.slot = slot;
                rec.cvt = Some(cvt);
                continue;
            }
            if use_vt_cache && local && self.read_only {
                // Lock-free read: remember the invalidation epoch so the
                // fill below can be rejected if a writer raced us.
                self.records[i].fill_epoch = Some(vt_cache.epoch(r.key));
            }
            self.clk.advance(self.net().cache_op_ns);
            if let Some(addr) = addr_cache.get(r.key) {
                reads.push((
                    i,
                    table.primary().mn,
                    addr,
                    table.layout.cvt_size() as usize,
                    false,
                ));
            } else {
                reads.push((
                    i,
                    table.primary().mn,
                    table.bucket_addr(0, bucket),
                    table.layout.bucket_size() as usize,
                    true,
                ));
            }
        }

        // Pass 2: issue per-MN doorbell batches.
        let mut by_mn: Vec<(usize, Vec<usize>)> = Vec::new(); // mn -> read idxs
        for (ri, read) in reads.iter().enumerate() {
            match by_mn.iter_mut().find(|(mn, _)| *mn == read.1) {
                Some((_, v)) => v.push(ri),
                None => by_mn.push((read.1, vec![ri])),
            }
        }
        let mut results: Vec<Option<Vec<u8>>> = vec![None; reads.len()];
        for (mn_id, idxs) in by_mn {
            let mn = self.cluster.mns[mn_id].clone();
            let mut ops: Vec<VerbOp> = idxs
                .iter()
                .map(|&ri| VerbOp::Read {
                    addr: reads[ri].2,
                    out: vec![0u8; reads[ri].3],
                })
                .collect();
            self.ep.doorbell(&mn, &mut ops, &mut self.clk)?;
            for (&ri, op) in idxs.iter().zip(ops) {
                if let VerbOp::Read { out, .. } = op {
                    results[ri] = Some(out);
                }
            }
        }

        // Pass 3: parse, validate, retry stale addresses via bucket read.
        for (ri, &(i, mn_id, addr, _len, whole_bucket)) in reads.iter().enumerate() {
            let buf = results[ri].take().expect("read result missing");
            let table = self.cluster.tables[self.records[i].r.table as usize].clone();
            let key = self.records[i].r.key;
            let parsed = if whole_bucket {
                // Home bucket was read in the batch; probe successors on miss.
                let found = match table.find_in_bucket(&buf, key) {
                    Some((slot, cvt)) => Some((table.bucket_of(key), slot, cvt)),
                    None => self.probe_find(&table, key, 1)?,
                };
                let Some((b, slot, cvt)) = found else {
                    self.rollback_internal();
                    return Err(abort(AbortReason::NotFound));
                };
                let cvt_addr = table.cvt_addr(0, b, slot);
                self.cluster.addr_caches[self.cn].put(key, cvt_addr);
                (slot, cvt, cvt_addr)
            } else {
                let cvt = CvtSnapshot::parse(&buf, &table.layout);
                if cvt.is_empty() || cvt.key != key.0 {
                    // Stale cached address: fall back to a probe search.
                    self.cluster.addr_caches[self.cn].invalidate(key);
                    let _ = mn_id;
                    let Some((b, slot, cvt)) = self.probe_find(&table, key, 0)? else {
                        self.rollback_internal();
                        return Err(abort(AbortReason::NotFound));
                    };
                    let cvt_addr = table.cvt_addr(0, b, slot);
                    self.cluster.addr_caches[self.cn].put(key, cvt_addr);
                    (slot, cvt, cvt_addr)
                } else {
                    let (_b, s) = table.locate_cvt(addr)?;
                    (s, cvt, addr)
                }
            };
            let local = self.cluster.router.owner_of_key(key) == self.cn;
            let (slot, cvt, cvt_addr) = parsed;
            if use_vt_cache && local {
                let entry = CachedCvt {
                    cvt: cvt.clone(),
                    addr: cvt_addr,
                };
                if self.read_only {
                    // Epoch-checked fill (no lock held).
                    if let Some(e0) = self.records[i].fill_epoch {
                        self.cluster.vt_caches[self.cn].put_if_epoch(key, entry, e0);
                    }
                } else {
                    // Lock held: fill unconditionally.
                    self.cluster.vt_caches[self.cn].put(key, entry);
                }
            }
            let (b, _s) = table.locate_cvt(cvt_addr)?;
            let rec = &mut self.records[i];
            rec.cvt = Some(cvt);
            rec.cvt_addr = cvt_addr;
            rec.bucket = b;
            rec.slot = slot;
        }
        Ok(())
    }

    /// Step 3: MVCC version select + record reads.
    fn read_data_phase(&mut self, from: usize) -> Result<()> {
        // Collect reads: (record idx, mn, addr, payload_len, record_len, want_cv).
        let mut reads: Vec<(usize, usize, u64, usize, u32, u8)> = Vec::new();
        for i in from..self.records.len() {
            let (best, newer, table_id, record_len) = {
                let rec = &self.records[i];
                if rec.insert {
                    continue; // nothing to read
                }
                let cvt = rec.cvt.as_ref().expect("read_cvt_phase ran");
                let (best, newer) = cvt.select_version(self.start_ts);
                let len = best.map(|c| c.len).unwrap_or(0);
                (best.copied(), newer, rec.r.table, len)
            };
            if !self.read_only && newer && self.isolation() == Isolation::Serializable {
                // A committed version newer than T_start: abort (§5.1).
                self.rollback_internal();
                return Err(abort(AbortReason::VersionTooNew));
            }
            let Some(cell) = best else {
                self.rollback_internal();
                return Err(abort(AbortReason::NoVisibleVersion));
            };
            let table = self.cluster.table(table_id);
            reads.push((
                i,
                table.primary().mn,
                cell.addr,
                record_len as usize,
                table.spec.record_len,
                cell.cv,
            ));
        }
        // Per-MN doorbell batches.
        let mut by_mn: Vec<(usize, Vec<usize>)> = Vec::new();
        for (ri, read) in reads.iter().enumerate() {
            match by_mn.iter_mut().find(|(mn, _)| *mn == read.1) {
                Some((_, v)) => v.push(ri),
                None => by_mn.push((read.1, vec![ri])),
            }
        }
        let mut results: Vec<Option<Vec<u8>>> = vec![None; reads.len()];
        for (mn_id, idxs) in by_mn {
            let mn = self.cluster.mns[mn_id].clone();
            let mut ops: Vec<VerbOp> = idxs
                .iter()
                .map(|&ri| VerbOp::Read {
                    addr: reads[ri].2,
                    out: vec![0u8; record::slot_size(reads[ri].4)],
                })
                .collect();
            self.ep.doorbell(&mn, &mut ops, &mut self.clk)?;
            for (&ri, op) in idxs.iter().zip(ops) {
                if let VerbOp::Read { out, .. } = op {
                    results[ri] = Some(out);
                }
            }
        }
        for (ri, &(i, _mn, _addr, payload_len, record_len, want_cv)) in reads.iter().enumerate() {
            let buf = results[ri].take().expect("record read missing");
            let decoded = record::decode(&buf, payload_len, record_len);
            match decoded {
                Some((cv, payload)) if cv == want_cv => {
                    self.records[i].value = Some(payload);
                }
                _ => {
                    // Torn slot or CV mismatch: a concurrent overwrite.
                    // Locked reads never hit this; lock-free RO reads abort.
                    self.rollback_internal();
                    return Err(abort(AbortReason::InconsistentRead));
                }
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Commit phase
    // ------------------------------------------------------------------

    fn commit_rw(&mut self) -> Result<()> {
        // Doomed check: resharding/recovery may have force-released our
        // locks; such a transaction must not enter the commit phase (§6).
        if self.cluster.doomed.take(self.txn_id) {
            self.rollback_internal();
            return Err(abort(AbortReason::OwnerFailed));
        }
        let log_and_visible = self.cluster.cfg.features.log_and_visible;
        let now_phys = self.clk.now();
        let gc_thresh = self.cluster.cfg.gc_threshold_ns;

        let ts_svc = self.net().ts_oracle_ns;
        // Pre-draw the commit timestamp when running in the no-log mode
        // (UPS-backed DRAM assumption, the "+Log & Visible" ablation off).
        let early_ts = if log_and_visible {
            0
        } else {
            self.cluster
                .oracle
                .timestamp(&mut self.clk, ts_svc)
        };

        // --- Write Data (& Log) ---
        // Plan every write first, then issue per-MN doorbell batches.
        struct PlannedWrite {
            rec_idx: usize,
            cell: u8,
            cell_addr_primary: u64, // on the primary MN
            new_cvt: CvtSnapshot,
        }
        let mut plans: Vec<PlannedWrite> = Vec::new();
        let mut log_entries: Vec<LogEntry> = Vec::new();
        // (mn, addr, bytes) writes across all replicas.
        let mut writes: Vec<(usize, u64, Vec<u8>)> = Vec::new();
        for i in 0..self.records.len() {
            let rec = self.records[i].clone();
            if !rec.write {
                continue;
            }
            let table = self.cluster.tables[rec.r.table as usize].clone();
            let mut cvt = rec.cvt.clone().expect("executed");
            if rec.delete {
                // Clear the whole CVT (key=0 frees the index slot).
                let cleared = CvtSnapshot::empty(table.spec.ncells);
                for (r, rep) in table.replicas.iter().enumerate() {
                    writes.push((
                        rep.mn,
                        table.cvt_addr(r, rec.bucket, rec.slot),
                        cleared.serialize(&table.layout),
                    ));
                }
                continue;
            }
            let Some(new_value) = rec.new_value.clone() else {
                continue; // write-locked but not modified: nothing to write
            };
            // Choose the victim cell (free / oldest — §7.1 GC).
            let Some(cell_idx) = gc::choose_victim(&cvt.cells, phys_of(now_phys), gc_thresh)
            else {
                self.rollback_internal();
                return Err(abort(AbortReason::LockConflict));
            };
            // Opportunistic reclamation of stale cells (§7.1).
            for ridx in gc::reclaimable(&cvt.cells, phys_of(now_phys), gc_thresh) {
                if ridx != cell_idx {
                    cvt.cells[ridx].valid = false;
                }
            }
            let cell_idx = cell_idx as u8;
            let old_cv = cvt.cells[cell_idx as usize].cv;
            let new_cv = old_cv.wrapping_add(1);
            let rec_addr_primary = table.record_addr(0, rec.bucket, rec.slot, cell_idx);
            cvt.cells[cell_idx as usize] = CellSnapshot {
                cv: new_cv,
                valid: true,
                len: new_value.len() as u16,
                version: if log_and_visible { INVISIBLE } else { early_ts },
                addr: rec_addr_primary,
                consistent: true,
            };
            cvt.record_len = new_value.len() as u16;
            if rec.insert {
                cvt.key = rec.r.key.0;
                cvt.occupied = true;
                cvt.table_id = table.spec.id;
            }
            let slot_img = record::encode(new_cv, &new_value, table.spec.record_len);
            let cvt_img = cvt.serialize(&table.layout);
            let cell_addr_primary = table.cvt_addr(0, rec.bucket, rec.slot)
                + table.layout.cell_off(cell_idx);
            for (r, rep) in table.replicas.iter().enumerate() {
                writes.push((
                    rep.mn,
                    table.record_addr(r, rec.bucket, rec.slot, cell_idx),
                    slot_img.clone(),
                ));
                // Whole-CVT write (header may change for inserts; reclaimed
                // cells must be cleared) — still one WRITE op.
                writes.push((
                    rep.mn,
                    table.cvt_addr(r, rec.bucket, rec.slot),
                    cvt_img.clone(),
                ));
            }
            log_entries.push(LogEntry {
                table: rec.r.table,
                mn: table.primary().mn as u16,
                cell_addr: cell_addr_primary,
            });
            plans.push(PlannedWrite {
                rec_idx: i,
                cell: cell_idx,
                cell_addr_primary,
                new_cvt: cvt,
            });
        }
        if log_and_visible && !log_entries.is_empty() {
            let (log_mn, log_addr) = self.cluster.log_slots[self.global_id];
            let log_img = LogRecord::prepared(self.txn_id, log_entries)?.serialize();
            writes.push((log_mn, log_addr, log_img));
        }
        self.issue_writes(&writes)?;
        writes.clear();

        // --- Get Timestamp ---
        let commit_ts = if log_and_visible {
            self.cluster
                .oracle
                .timestamp(&mut self.clk, ts_svc)
        } else {
            early_ts
        };

        // --- Write Visible ---
        if log_and_visible {
            for plan in &plans {
                let table = self.cluster.table(self.records[plan.rec_idx].r.table);
                // The version word is the second word of the cell.
                for r in 0..table.replicas.len() {
                    let cell_addr = table.to_replica_addr(plan.cell_addr_primary, r);
                    writes.push((
                        table.replicas[r].mn,
                        cell_addr + 8,
                        commit_ts.to_le_bytes().to_vec(),
                    ));
                }
            }
            self.issue_writes(&writes)?;
            writes.clear();
        }

        // Synchronous VT-cache update for locally owned keys (§4.4 "zero
        // consistency overhead": we hold the write lock).
        if self.cluster.cfg.features.vt_cache {
            for plan in &plans {
                let rec = &self.records[plan.rec_idx];
                if self.cluster.router.owner_of_key(rec.r.key) == self.cn {
                    let mut cvt = plan.new_cvt.clone();
                    cvt.cells[plan.cell as usize].version = commit_ts;
                    self.cluster.vt_caches[self.cn].put(
                        rec.r.key,
                        CachedCvt {
                            cvt,
                            addr: {
                                let table = self.cluster.table(rec.r.table);
                                table.cvt_addr(0, rec.bucket, rec.slot)
                            },
                        },
                    );
                } else {
                    let _ = plan;
                }
            }
            for rec in &self.records {
                if rec.delete && self.cluster.router.owner_of_key(rec.r.key) == self.cn {
                    self.cluster.vt_caches[self.cn].invalidate(rec.r.key);
                }
            }
        }

        // Clear the log slot (async — not on the critical path).
        if log_and_visible && !plans.is_empty() {
            let (log_mn, log_addr) = self.cluster.log_slots[self.global_id];
            let mn = self.cluster.mns[log_mn].clone();
            let mut ops = [VerbOp::Write {
                addr: log_addr,
                data: STATE_EMPTY.to_le_bytes().to_vec(),
            }];
            self.ep.doorbell_async(&mn, &mut ops, &mut self.clk)?;
        }

        // --- Unlock ---
        self.release_locks();
        Ok(())
    }

    /// Issue `(mn, addr, bytes)` writes as one doorbell batch per MN.
    fn issue_writes(&mut self, writes: &[(usize, u64, Vec<u8>)]) -> Result<()> {
        let mut by_mn: Vec<(usize, Vec<VerbOp>)> = Vec::new();
        for (mn, addr, data) in writes {
            let op = VerbOp::Write {
                addr: *addr,
                data: data.clone(),
            };
            match by_mn.iter_mut().find(|(m, _)| m == mn) {
                Some((_, v)) => v.push(op),
                None => by_mn.push((*mn, vec![op])),
            }
        }
        for (mn_id, mut ops) in by_mn {
            let mn = self.cluster.mns[mn_id].clone();
            self.ep.doorbell(&mn, &mut ops, &mut self.clk)?;
        }
        Ok(())
    }

    /// Abort-path cleanup: release locks + reset state.
    fn rollback_internal(&mut self) {
        self.release_locks();
        self.phase = Phase::Idle;
    }

    fn find(&self, r: RecordRef) -> Option<usize> {
        self.records.iter().position(|rec| rec.r == r)
    }
}

impl TxnCtl for LotusCoordinator {
    fn add_ro(&mut self, r: RecordRef) {
        debug_assert_ne!(self.phase, Phase::Idle);
        self.records.push(TxnRecord::new(r, false));
    }

    fn add_rw(&mut self, r: RecordRef) {
        debug_assert_ne!(self.phase, Phase::Idle);
        debug_assert!(!self.read_only, "read-only txn cannot AddRW");
        self.records.push(TxnRecord::new(r, true));
    }

    fn add_insert(&mut self, r: RecordRef, payload: Vec<u8>) {
        debug_assert_ne!(self.phase, Phase::Idle);
        debug_assert!(!self.read_only);
        let mut rec = TxnRecord::new(r, true);
        rec.insert = true;
        rec.new_value = Some(payload);
        self.records.push(rec);
    }

    fn execute(&mut self) -> Result<()> {
        debug_assert_ne!(self.phase, Phase::Idle);
        let from = self.executed_upto;
        if !self.read_only {
            self.lock_phase(from)?;
        }
        self.read_cvt_phase(from)?;
        self.read_data_phase(from)?;
        self.executed_upto = self.records.len();
        self.phase = Phase::Executed;
        Ok(())
    }

    fn value(&self, r: RecordRef) -> Option<&[u8]> {
        self.find(r)
            .and_then(|i| self.records[i].value.as_deref())
    }

    fn stage_write(&mut self, r: RecordRef, payload: Vec<u8>) {
        let i = self.find(r).expect("stage_write on unknown record");
        debug_assert!(self.records[i].write, "stage_write needs AddRW");
        self.records[i].new_value = Some(payload);
    }

    fn commit(&mut self) -> Result<()> {
        debug_assert_eq!(self.phase, Phase::Executed);
        // Application logic between execute and commit.
        self.clk.advance(self.net().txn_logic_ns);
        if !self.read_only {
            self.commit_rw()?;
        }
        self.phase = Phase::Idle;
        Ok(())
    }

    fn add_delete(&mut self, r: RecordRef) {
        debug_assert_ne!(self.phase, Phase::Idle);
        let mut rec = TxnRecord::new(r, true);
        rec.delete = true;
        self.records.push(rec);
    }

    fn rollback(&mut self) {
        self.rollback_internal();
    }
}

impl TxnApi for LotusCoordinator {
    fn begin(&mut self, read_only: bool) {
        self.records.clear();
        self.held.clear();
        self.executed_upto = 0;
        self.read_only = read_only;
        self.txn_id = self.cluster.next_txn_id();
        let ts_svc = self.net().ts_oracle_ns;
        self.start_ts = self
            .cluster
            .oracle
            .timestamp(&mut self.clk, ts_svc);
        self.phase = Phase::Building;
    }

    fn txn(&mut self) -> &mut dyn TxnCtl {
        self
    }

    fn now(&self) -> u64 {
        self.clk.now()
    }

    fn rng(&mut self) -> &mut crate::util::Xoshiro256 {
        &mut self.rng
    }

    fn cn(&self) -> usize {
        self.cn
    }

    fn attach_gate(&mut self, gate: Arc<crate::dm::clock::TimeGate>, gid: usize) {
        self.ep.attach_gate(gate, gid);
    }

    fn crash(&mut self) {
        // Locks deliberately NOT released — recovery owns that (§6).
        self.records.clear();
        self.held.clear();
        self.executed_upto = 0;
        self.phase = Phase::Idle;
    }

    fn skip_to(&mut self, t_ns: u64) {
        self.clk.catch_up(t_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Cluster;
    use crate::store::index::TableSpec;

    /// Minimal single-table cluster for protocol unit tests.
    fn mini() -> (Arc<SharedCluster>, Vec<LotusCoordinator>) {
        let mut cfg = Config::small();
        cfg.n_cns = 2;
        cfg.coordinators_per_cn = 2;
        let specs = vec![TableSpec {
            id: 0,
            name: "t".into(),
            record_len: 40,
            ncells: 2,
            assoc: 4,
            expected_records: 16384,
        }];
        let cluster = Cluster::build_shared(&cfg, specs).unwrap();
        // Preload records across the whole shard space so every CN owns
        // some keys (remote-lock tests need owner != 0).
        for uid in 0..4096u64 {
            let key = LotusKey::compose(uid, uid);
            cluster.tables[0]
                .load_insert(&cluster.mns, key, format!("init-{uid}").as_bytes(), 1)
                .unwrap();
        }
        let coords = (0..4)
            .map(|g| LotusCoordinator::new(cluster.clone(), g / 2, g % 2, g))
            .collect();
        (cluster, coords)
    }

    fn rr(uid: u64) -> RecordRef {
        RecordRef::new(0, LotusKey::compose(uid, uid))
    }

    #[test]
    fn read_only_txn_reads_initial_value() {
        let (_c, mut coords) = mini();
        let co = &mut coords[0];
        co.begin(true);
        co.add_ro(rr(5));
        co.execute().unwrap();
        assert_eq!(co.value(rr(5)).unwrap(), b"init-5");
        co.commit().unwrap();
    }

    #[test]
    fn rw_txn_update_visible_to_next_reader() {
        let (_c, mut coords) = mini();
        {
            let co = &mut coords[0];
            co.begin(false);
            co.add_rw(rr(7));
            co.execute().unwrap();
            assert_eq!(co.value(rr(7)).unwrap(), b"init-7");
            co.stage_write(rr(7), b"updated!".to_vec());
            co.commit().unwrap();
        }
        let co = &mut coords[1];
        co.begin(true);
        co.add_ro(rr(7));
        co.execute().unwrap();
        assert_eq!(co.value(rr(7)).unwrap(), b"updated!");
        co.commit().unwrap();
    }

    #[test]
    fn all_locks_released_after_commit_and_abort() {
        let (c, mut coords) = mini();
        let held = || -> usize { c.lock_services.iter().map(|s| s.held_slots()).sum() };
        let co = &mut coords[0];
        co.begin(false);
        co.add_rw(rr(1));
        co.add_ro(rr(2));
        co.execute().unwrap();
        assert!(held() > 0);
        co.stage_write(rr(1), b"x".to_vec());
        co.commit().unwrap();
        assert_eq!(held(), 0, "commit must release all locks");
        co.begin(false);
        co.add_rw(rr(3));
        co.execute().unwrap();
        co.rollback();
        assert_eq!(held(), 0, "rollback must release all locks");
    }

    #[test]
    fn write_write_conflict_aborts_second() {
        let (_c, mut coords) = mini();
        let (a, rest) = coords.split_at_mut(1);
        let a = &mut a[0];
        let b = &mut rest[0];
        a.begin(false);
        a.add_rw(rr(9));
        a.execute().unwrap();
        b.begin(false);
        b.add_rw(rr(9));
        let err = b.execute().unwrap_err();
        assert_eq!(err.abort_reason(), Some(AbortReason::LockConflict));
        // A can still commit.
        a.stage_write(rr(9), b"winner".to_vec());
        a.commit().unwrap();
        // And b can retry.
        b.begin(false);
        b.add_rw(rr(9));
        b.execute().unwrap();
        assert_eq!(b.value(rr(9)).unwrap(), b"winner");
        b.rollback();
    }

    #[test]
    fn read_lock_blocks_writer_under_sr() {
        let (_c, mut coords) = mini();
        let (a, rest) = coords.split_at_mut(1);
        let a = &mut a[0];
        let b = &mut rest[0];
        a.begin(false);
        a.add_ro(rr(11)); // read lock under SR
        a.execute().unwrap();
        b.begin(false);
        b.add_rw(rr(11));
        assert_eq!(
            b.execute().unwrap_err().abort_reason(),
            Some(AbortReason::LockConflict)
        );
        a.commit().unwrap();
    }

    #[test]
    fn si_skips_read_locks() {
        let (c, mut coords) = mini();
        // Rebuild with SI via the shared config is fixed at build; emulate
        // by checking the lock-request computation instead.
        let co = &mut coords[0];
        co.begin(false);
        co.add_ro(rr(12));
        co.add_rw(rr(13));
        // Under SR: 2 lock requests.
        assert_eq!(co.lock_requests(0).len(), 2);
        let _ = c;
    }

    #[test]
    fn insert_then_read_roundtrip() {
        let (_c, mut coords) = mini();
        let key = RecordRef::new(0, LotusKey::compose(999, 5000));
        {
            let co = &mut coords[0];
            co.begin(false);
            co.add_insert(key, b"brand-new".to_vec());
            co.execute().unwrap();
            co.commit().unwrap();
        }
        let co = &mut coords[2];
        co.begin(true);
        co.add_ro(key);
        co.execute().unwrap();
        assert_eq!(co.value(key).unwrap(), b"brand-new");
        co.commit().unwrap();
    }

    #[test]
    fn duplicate_insert_aborts() {
        let (_c, mut coords) = mini();
        let co = &mut coords[0];
        co.begin(false);
        co.add_insert(rr(5), b"dup".to_vec());
        assert_eq!(
            co.execute().unwrap_err().abort_reason(),
            Some(AbortReason::Duplicate)
        );
    }

    #[test]
    fn delete_makes_record_unfindable() {
        let (_c, mut coords) = mini();
        {
            let co = &mut coords[0];
            co.begin(false);
            co.add_delete(rr(20));
            co.execute().unwrap();
            co.commit().unwrap();
        }
        let co = &mut coords[1];
        co.begin(true);
        co.add_ro(rr(20));
        assert_eq!(
            co.execute().unwrap_err().abort_reason(),
            Some(AbortReason::NotFound)
        );
    }

    #[test]
    fn missing_key_aborts_not_found() {
        let (_c, mut coords) = mini();
        let co = &mut coords[0];
        co.begin(true);
        co.add_ro(rr(100_000));
        assert_eq!(
            co.execute().unwrap_err().abort_reason(),
            Some(AbortReason::NotFound)
        );
    }

    #[test]
    fn doomed_txn_cannot_commit() {
        let (c, mut coords) = mini();
        let co = &mut coords[0];
        co.begin(false);
        co.add_rw(rr(30));
        co.execute().unwrap();
        co.stage_write(rr(30), b"nope".to_vec());
        c.doomed.doom(co.txn_id);
        assert_eq!(
            co.commit().unwrap_err().abort_reason(),
            Some(AbortReason::OwnerFailed)
        );
        // Locks released; value unchanged.
        let held: usize = c.lock_services.iter().map(|s| s.held_slots()).sum();
        assert_eq!(held, 0);
        co.begin(true);
        co.add_ro(rr(30));
        co.execute().unwrap();
        assert_eq!(co.value(rr(30)).unwrap(), b"init-30");
    }

    #[test]
    fn mvcc_keeps_old_version_readable_at_old_timestamp() {
        let (c, mut coords) = mini();
        // Reader draws its snapshot BEFORE the writer commits.
        let ro_ts_holder;
        {
            let co = &mut coords[1];
            co.begin(true);
            co.add_ro(rr(40));
            ro_ts_holder = co.start_ts;
        }
        {
            let co = &mut coords[0];
            co.begin(false);
            co.add_rw(rr(40));
            co.execute().unwrap();
            co.stage_write(rr(40), b"v2".to_vec());
            co.commit().unwrap();
        }
        // The old version (ncells=2) still serves the old snapshot.
        let co = &mut coords[1];
        co.execute().unwrap();
        assert_eq!(co.value(rr(40)).unwrap(), b"init-40");
        assert!(ro_ts_holder <= c.oracle.last());
        co.commit().unwrap();
    }

    #[test]
    fn version_too_new_aborts_sr_rw_txn() {
        let (c, mut coords) = mini();
        // Start a RW txn (draws T_start), then another txn commits a newer
        // version, then the first reads: must abort.
        let (a, rest) = coords.split_at_mut(1);
        let a = &mut a[0];
        let b = &mut rest[0];
        a.begin(false);
        a.add_rw(rr(50)); // T_start drawn now
        b.begin(false);
        b.add_rw(rr(50));
        b.execute().unwrap();
        b.stage_write(rr(50), b"newer".to_vec());
        b.commit().unwrap();
        assert_eq!(
            a.execute().unwrap_err().abort_reason(),
            Some(AbortReason::VersionTooNew)
        );
        let _ = c;
    }

    #[test]
    fn remote_lock_costs_an_rpc() {
        let (c, mut coords) = mini();
        // Find a key owned by CN 1; lock it from CN 0.
        let uid = (0..4096u64)
            .find(|&u| c.router.owner_of_key(LotusKey::compose(u, u)) == 1)
            .unwrap();
        let co = &mut coords[0]; // on CN 0
        assert_eq!(co.cn, 0);
        let t0 = co.clk.now();
        co.begin(false);
        co.add_rw(rr(uid));
        co.execute().unwrap();
        let elapsed = co.clk.now() - t0;
        assert!(
            elapsed >= c.net.rpc_rtt_ns,
            "remote lock must pay an RPC RTT: {elapsed}"
        );
        co.rollback();
    }

    #[test]
    fn vt_cache_hit_skips_cvt_read() {
        let (c, mut coords) = mini();
        // A local-keyed record, accessed twice by the owner CN.
        let uid = (0..4096u64)
            .find(|&u| c.router.owner_of_key(LotusKey::compose(u, u)) == 0)
            .unwrap();
        let co = &mut coords[0];
        co.begin(false);
        co.add_rw(rr(uid));
        co.execute().unwrap();
        co.stage_write(rr(uid), b"warm".to_vec());
        co.commit().unwrap();
        let (h0, _, _) = c.vt_caches[0].stats();
        co.begin(false);
        co.add_rw(rr(uid));
        co.execute().unwrap();
        assert_eq!(co.value(rr(uid)).unwrap(), b"warm");
        co.rollback();
        let (h1, _, _) = c.vt_caches[0].stats();
        assert!(h1 > h0, "second access must hit the VT cache");
    }

    #[test]
    fn log_slot_prepared_then_cleared() {
        let (c, mut coords) = mini();
        let co = &mut coords[0];
        co.begin(false);
        co.add_rw(rr(60));
        co.execute().unwrap();
        co.stage_write(rr(60), b"logged".to_vec());
        co.commit().unwrap();
        let (mn, addr) = c.log_slots[co.global_id];
        let mut buf = vec![0u8; crate::txn::log::slot_size() as usize];
        c.mns[mn].read_bytes(addr, &mut buf).unwrap();
        let rec = LogRecord::parse(&buf);
        assert!(!rec.is_prepared(), "log must be cleared after commit");
    }
}
