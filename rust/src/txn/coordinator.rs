//! The LOTUS coordinator: an orchestration shell over the phase pipeline.
//!
//! One coordinator is one concurrent transaction stream on a CN. The
//! protocol itself — lock-first Execute (Lock → Read CVT → Read Data) and
//! Commit (Write+Log → Timestamp → Visible → Unlock), paper fig. 10 —
//! lives in [`crate::txn::phases`], one module per phase, operating on a
//! [`TxnFrame`] through a [`PhaseCtx`]. The coordinator owns the frame,
//! the endpoint, and the virtual clock, maps the [`TxnApi`]/[`TxnCtl`]
//! surface onto the phases, and keeps the begin/execute/commit state
//! machine honest.
//!
//! [`SharedCluster`] is the cluster-wide shared state every coordinator
//! holds an `Arc` of; [`crate::sim::Cluster`] builds it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::balance::BalanceMetrics;
use crate::cache::{AddrCache, VtCache};
use crate::config::Config;
use crate::dm::clock::VClock;
use crate::dm::memnode::MemNode;
use crate::dm::rnic::Rnic;
use crate::dm::rpc::RpcFabric;
use crate::dm::verbs::Endpoint;
use crate::dm::NetConfig;
use crate::lock::service::LockService;
use crate::recovery::membership::Membership;
use crate::sharding::router::Router;
use crate::store::index::TableStore;
use crate::txn::api::{RecordRef, TxnApi, TxnCtl};
use crate::txn::doomed::DoomedSet;
use crate::txn::phases::{self, PhaseCtx, TxnFrame, TxnRecord};
use crate::txn::step::expect_ready;
use crate::txn::timestamp::TimestampOracle;
use crate::Result;

/// Cluster-wide shared state (one per simulated cluster).
pub struct SharedCluster {
    /// Effective configuration.
    pub cfg: Config,
    /// Memory nodes.
    pub mns: Vec<Arc<MemNode>>,
    /// Per-CN NICs (shared by the CN's coordinators).
    pub cn_nics: Vec<Arc<Rnic>>,
    /// CN-to-CN RPC fabric.
    pub rpc: Arc<RpcFabric>,
    /// The routing layer.
    pub router: Arc<Router>,
    /// Timestamp oracle.
    pub oracle: Arc<TimestampOracle>,
    /// Cost model.
    pub net: Arc<NetConfig>,
    /// Per-CN lock services.
    pub lock_services: Vec<Arc<LockService>>,
    /// Per-CN version table caches.
    pub vt_caches: Vec<Arc<VtCache>>,
    /// Per-CN address caches.
    pub addr_caches: Vec<Arc<AddrCache>>,
    /// DB tables, indexed by table id.
    pub tables: Vec<Arc<TableStore>>,
    /// Doomed-transaction registry (resharding + recovery).
    pub doomed: Arc<DoomedSet>,
    /// Load-balancer metrics.
    pub metrics: Arc<BalanceMetrics>,
    /// CN membership (failure detection).
    pub membership: Arc<Membership>,
    /// Per-coordinator log slots: `(mn, addr)` by global coordinator id.
    pub log_slots: Vec<(usize, u64)>,
    /// Baseline systems' MN-side lock regions, aligned with `tables`:
    /// base address (on each table's primary MN) of one 8B lock word per
    /// CVT slot plus one per bucket (insert locks). Unused by LOTUS.
    pub baseline_lock_bases: Vec<u64>,
    /// Doorbell-plane fault injector cell (PR 8): endpoints built from
    /// this cluster consult it per ring. Empty (the default) is
    /// byte-inert.
    pub doorbell_faults: Arc<crate::dm::FaultsCell>,
    /// Issue-point boundary trace for the crash-point sweep (PR 8):
    /// disabled (and free) outside sweep reference runs.
    pub ring_trace: crate::audit::RingTrace,
    /// Recovery reports of the run's crash-recovery passes, pushed by
    /// the simulator's recovery driver (cleared at run start) so audits
    /// can observe e.g. `torn_slots_discarded`.
    pub recovery_reports: std::sync::Mutex<Vec<crate::recovery::recovery::RecoveryReport>>,
    /// Global transaction-id counter.
    pub txn_counter: AtomicU64,
}

impl SharedCluster {
    /// Next globally unique transaction id.
    pub fn next_txn_id(&self) -> u64 {
        self.txn_counter.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// The table with id `t` (panics on unknown id — a config error).
    #[inline]
    pub fn table(&self, t: u16) -> &TableStore {
        &self.tables[t as usize]
    }
}

/// Transaction phase (assertion state machine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Idle,
    Building,
    Executed,
}

/// The LOTUS coordinator (one per concurrent transaction stream).
pub struct LotusCoordinator {
    /// Shared cluster state.
    pub cluster: Arc<SharedCluster>,
    /// This coordinator's CN.
    pub cn: usize,
    /// Coordinator slot within the CN (i-th coordinator RPC pairing, §4.1).
    pub slot: usize,
    /// Global coordinator id (log-slot index, time-gate id).
    pub global_id: usize,
    /// Virtual clock.
    pub clk: VClock,
    /// The in-flight transaction frame (reused across transactions).
    pub(crate) frame: TxnFrame,
    ep: Endpoint,
    rng: crate::util::Xoshiro256,
    phase: Phase,
    /// READ-buffer scratch reused across doorbell rings and transactions
    /// (ROADMAP #4 follow-on (b)).
    pool: crate::dm::BufPool,
}

impl LotusCoordinator {
    /// Coordinator `slot` on CN `cn`.
    pub fn new(cluster: Arc<SharedCluster>, cn: usize, slot: usize, global_id: usize) -> Self {
        let ep = Endpoint::new(cn, cluster.cn_nics[cn].clone(), cluster.net.clone())
            .with_faults(cluster.doorbell_faults.clone());
        let seed = cluster.cfg.seed ^ (global_id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Self {
            cluster,
            cn,
            slot,
            global_id,
            clk: VClock::zero(),
            frame: TxnFrame::new(),
            ep,
            rng: crate::util::Xoshiro256::new(seed),
            phase: Phase::Idle,
            pool: crate::dm::BufPool::new(),
        }
    }

    /// Split-borrow the coordinator into a phase context + the frame.
    fn parts(&mut self) -> (PhaseCtx<'_>, &mut TxnFrame) {
        (
            PhaseCtx {
                cluster: &self.cluster,
                cn: self.cn,
                slot: self.slot,
                global_id: self.global_id,
                ep: &self.ep,
                clk: &mut self.clk,
                // Sequential coordinator: one frame, direct issue, no
                // sibling frames to conflict with.
                lane: 0,
                sink: None,
                pool: &mut self.pool,
            },
            &mut self.frame,
        )
    }

    /// Abort-path cleanup: release locks + reset the state machine.
    fn rollback_internal(&mut self) {
        let (mut ctx, frame) = self.parts();
        phases::unlock::release(&mut ctx, frame);
        self.phase = Phase::Idle;
    }
}

impl TxnCtl for LotusCoordinator {
    fn add_ro(&mut self, r: RecordRef) {
        debug_assert_ne!(self.phase, Phase::Idle);
        self.frame.records.push(TxnRecord::new(r, false));
    }

    fn add_rw(&mut self, r: RecordRef) {
        debug_assert_ne!(self.phase, Phase::Idle);
        debug_assert!(!self.frame.read_only, "read-only txn cannot AddRW");
        self.frame.records.push(TxnRecord::new(r, true));
    }

    fn add_insert(&mut self, r: RecordRef, payload: Vec<u8>) {
        debug_assert_ne!(self.phase, Phase::Idle);
        debug_assert!(!self.frame.read_only);
        let mut rec = TxnRecord::new(r, true);
        rec.insert = true;
        rec.new_value = Some(payload);
        self.frame.records.push(rec);
    }

    fn add_delete(&mut self, r: RecordRef) {
        debug_assert_ne!(self.phase, Phase::Idle);
        let mut rec = TxnRecord::new(r, true);
        rec.delete = true;
        self.frame.records.push(rec);
    }

    fn execute(&mut self) -> Result<()> {
        debug_assert_ne!(self.phase, Phase::Idle);
        let res = {
            let (mut ctx, frame) = self.parts();
            // Direct conduit (no sink): the phase machine never parks,
            // one poll is the classic blocking call.
            expect_ready(phases::execute(&mut ctx, frame))
        };
        match res {
            Ok(()) => {
                self.phase = Phase::Executed;
                Ok(())
            }
            Err(e) => {
                // The failing phase already released every held lock.
                self.phase = Phase::Idle;
                Err(e)
            }
        }
    }

    fn value(&self, r: RecordRef) -> Option<&[u8]> {
        self.frame
            .find(r)
            .and_then(|i| self.frame.records[i].value.as_deref())
    }

    fn stage_write(&mut self, r: RecordRef, payload: Vec<u8>) {
        let i = self.frame.find(r).expect("stage_write on unknown record");
        debug_assert!(self.frame.records[i].write, "stage_write needs AddRW");
        self.frame.records[i].new_value = Some(payload);
    }

    fn commit(&mut self) -> Result<()> {
        debug_assert_eq!(self.phase, Phase::Executed);
        let res = {
            let (mut ctx, frame) = self.parts();
            expect_ready(phases::commit_txn(&mut ctx, frame))
        };
        self.phase = Phase::Idle;
        res
    }

    fn rollback(&mut self) {
        self.rollback_internal();
    }
}

impl TxnApi for LotusCoordinator {
    fn begin(&mut self, read_only: bool) {
        phases::begin(&self.cluster, &mut self.clk, &mut self.frame, read_only);
        self.phase = Phase::Building;
    }

    fn txn(&mut self) -> &mut dyn TxnCtl {
        self
    }

    fn now(&self) -> u64 {
        self.clk.now()
    }

    fn rng(&mut self) -> &mut crate::util::Xoshiro256 {
        &mut self.rng
    }

    fn cn(&self) -> usize {
        self.cn
    }

    fn attach_gate(&mut self, gate: Arc<crate::dm::clock::TimeGate>, gid: usize) {
        self.ep.attach_gate(gate, gid);
    }

    fn crash(&mut self) {
        // Locks deliberately NOT released — recovery owns that (§6).
        self.frame.crash();
        self.phase = Phase::Idle;
    }

    fn skip_to(&mut self, t_ns: u64) {
        self.clk.catch_up(t_ns);
    }
}
