//! The lock-first transaction protocol (paper section 5).
//!
//! LOTUS separates the **locking phase as the first step** of every
//! read-write transaction execution: all locks (write locks for the
//! read-write set, read locks for the read-only set under SR) are acquired
//! *before* any data is read, so conflicting transactions are detected and
//! aborted before a single byte crosses the network to the memory pool.
//!
//! Modules:
//! - [`timestamp`] — the HLC timestamp oracle (scalable service in the
//!   compute pool, paper section 5).
//! - [`log`] — small commit logs written to each coordinator's exclusive
//!   memory-pool region (paper 5.1 "Write Data & Log"; MVCC old versions
//!   are the undo log, so the log carries only metadata).
//! - [`api`] — the user-facing transaction interface
//!   (Begin/AddRO/AddRW/Execute/Commit, paper section 7.3), implemented by
//!   the LOTUS coordinator and by the baseline systems so every workload
//!   runs unmodified on every system.
//! - [`phases`] — the protocol pipeline itself, one module per phase
//!   (lock, read, write_log, commit, unlock): each phase is a resumable
//!   step machine over a [`phases::PhaseCtx`] (coordinator environment)
//!   and a [`phases::TxnFrame`] (per-transaction state), cut at its issue
//!   points, with every one-sided exchange planned through the shared
//!   [`crate::dm::OpBatch`] doorbell planner.
//! - [`step`] — the continuation plumbing: [`step::StepFut`] (the
//!   heap-reified machine type), the no-op waker, and the blocking-path
//!   driver [`step::expect_ready`].
//! - [`coordinator`] — the LOTUS coordinator: a thin orchestration shell
//!   mapping the [`api`] surface onto the phase pipeline, with SR and SI
//!   isolation.
//! - [`doomed`] — the doomed-transaction registry used by resharding and
//!   recovery to proactively abort transactions that must not commit.
//! - [`adaptive`] — the per-plane × per-destination congestion controller
//!   that turns the fixed coalescing window into an adaptive policy
//!   steered by the fabric's measured queueing delays (ISSUE 6).

pub mod adaptive;
pub mod api;
pub mod coordinator;
pub mod doomed;
pub mod log;
pub mod phases;
pub mod scheduler;
pub mod step;
pub mod timestamp;

pub use adaptive::{AdaptiveController, Obs, Plane, CAP_MULT};
pub use api::{Isolation, TxnApi, TxnCtl};
pub use coordinator::{LotusCoordinator, SharedCluster};
pub use doomed::DoomedSet;
pub use phases::{PhaseCtx, Plan, StepSink, TxnFrame};
pub use step::{expect_ready, StepFut};
pub use scheduler::{Coalescer, FrameScheduler, LaneOutcome, SiblingLocks};
pub use timestamp::{compose_ts, logical_of, phys_of, TimestampOracle};
