//! The user-facing transaction interface (paper section 7.3).
//!
//! ```text
//! Begin()    start a transaction and get a start timestamp
//! AddRO()    add a data record to the read-only set
//! AddRW()    add a data record to the read-write set
//! Execute()  acquire locks, read data
//! Commit()   get a commit timestamp, write data, release locks
//! ```
//!
//! [`TxnApi`] is implemented by the LOTUS coordinator
//! ([`crate::txn::coordinator`]) **and** by every baseline system
//! ([`crate::baselines`]), so each workload (KVS, TATP, SmallBank, TPC-C)
//! is written once and runs unmodified on every system under comparison —
//! exactly how the paper's evaluation drives all three systems with the
//! same benchmarks.
//!
//! Error contract: when `execute()` or `commit()` returns an abort, the
//! implementation has already rolled the transaction back (all locks
//! released, no partial writes visible); the caller may immediately
//! `begin()` a retry.

use crate::sharding::key::LotusKey;
use crate::txn::step::StepFut;
use crate::util::Xoshiro256;
use crate::Result;

/// Isolation level (paper section 5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Isolation {
    /// Serializability: write locks on the read-write set **and** read
    /// locks on the read-only set of read-write transactions.
    Serializable,
    /// Snapshot isolation: no read locks; write locks only.
    SnapshotIsolation,
}

/// A reference to one record in a DB table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RecordRef {
    /// DB table id.
    pub table: u16,
    /// The record's LOTUS key.
    pub key: LotusKey,
}

impl RecordRef {
    /// Convenience constructor.
    pub fn new(table: u16, key: LotusKey) -> Self {
        Self { table, key }
    }
}

/// Control interface of one in-flight transaction; see module docs.
pub trait TxnCtl {
    /// Add a record to the read-only set (must precede `execute`).
    fn add_ro(&mut self, r: RecordRef);
    /// Add a record to the read-write set (must precede `execute`).
    fn add_rw(&mut self, r: RecordRef);
    /// Add an insert of a new record (locks the key *and* the index
    /// bucket, paper 4.1).
    fn add_insert(&mut self, r: RecordRef, payload: Vec<u8>);
    /// Add a delete of an existing record (locks the key and the index
    /// bucket; the commit clears the record's CVT).
    fn add_delete(&mut self, r: RecordRef);
    /// Lock-first execution: acquire all locks, then read all data.
    /// On `Err` the transaction is already rolled back.
    ///
    /// Blocking form — valid only on direct conduits (the sequential
    /// coordinator, baselines, recovery); pipelined lanes must drive
    /// [`TxnCtl::execute_step`] instead.
    fn execute(&mut self) -> Result<()>;
    /// Resumable execution: the same lock-first round as
    /// [`TxnCtl::execute`], reified as a step machine that parks
    /// (`Poll::Pending`) at its issue points under the pipelined
    /// scheduler. Workloads drive this form exclusively, so the same
    /// workload code runs blocking on sequential conduits (every await
    /// completes within one poll) and parking on pipelined lanes.
    ///
    /// The default wraps the blocking [`TxnCtl::execute`] in an
    /// immediately-ready machine (sequential implementors need only the
    /// blocking form) — a [`StepFut::ready`] value, so the sequential
    /// and baseline paths pay no heap allocation for the step surface.
    fn execute_step(&mut self) -> StepFut<'_, Result<()>> {
        let r = self.execute();
        StepFut::ready(r)
    }
    /// Read a record's bytes fetched by `execute`.
    fn value(&self, r: RecordRef) -> Option<&[u8]>;
    /// Stage the new bytes for a read-write record (before `commit`).
    fn stage_write(&mut self, r: RecordRef, payload: Vec<u8>);
    /// Commit: write data + log, draw the commit timestamp, make data
    /// visible, unlock. On `Err` the transaction is already rolled back.
    ///
    /// Blocking form — direct conduits only (see [`TxnCtl::execute`]).
    fn commit(&mut self) -> Result<()>;
    /// Resumable commit (see [`TxnCtl::execute_step`] for the contract).
    fn commit_step(&mut self) -> StepFut<'_, Result<()>> {
        let r = self.commit();
        StepFut::ready(r)
    }
    /// Abort voluntarily (releases all locks; always succeeds).
    fn rollback(&mut self);
}

/// A transaction executor bound to one coordinator thread.
pub trait TxnApi {
    /// Begin a transaction. `read_only` transactions take no locks and
    /// read a consistent snapshot (paper 5.1 "Processing Read-Only
    /// Transactions").
    fn begin(&mut self, read_only: bool);
    /// The in-flight transaction's control interface.
    fn txn(&mut self) -> &mut dyn TxnCtl;
    /// The coordinator's virtual clock (ns).
    fn now(&self) -> u64;
    /// The coordinator's workload RNG.
    fn rng(&mut self) -> &mut Xoshiro256;
    /// Which CN this coordinator runs on.
    fn cn(&self) -> usize;
    /// Attach the benchmark run's time gate (conservative-PDES sync at
    /// every shared-queue charge; see [`crate::dm::clock::TimeGate`]).
    fn attach_gate(&mut self, gate: std::sync::Arc<crate::dm::clock::TimeGate>, gid: usize);
    /// Fail-stop: drop all in-flight transaction state **without
    /// releasing locks** (the locks die with the CN and are cleaned up by
    /// recovery, paper §6). Used by the fig. 15 crash-injection harness.
    fn crash(&mut self);
    /// Jump the coordinator's virtual clock forward (restart after a
    /// crash: the CN resumes at the recovery-completion time).
    fn skip_to(&mut self, t_ns: u64);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_ref_equality() {
        let a = RecordRef::new(1, LotusKey::compose(5, 10));
        let b = RecordRef::new(1, LotusKey::compose(5, 10));
        let c = RecordRef::new(2, LotusKey::compose(5, 10));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
