//! The pipelined frame scheduler: `pipeline_depth` concurrent
//! [`TxnFrame`]s per coordinator thread, with cross-transaction doorbell
//! coalescing.
//!
//! The sequential [`crate::txn::coordinator::LotusCoordinator`] runs one
//! transaction at a time and stalls a full RTT at every phase boundary.
//! The paper's CNs keep their RNICs busy by overlapping many in-flight
//! requests ("threads x coroutines"); the [`FrameScheduler`] models that:
//! one OS thread owns `depth` **lanes**, each a full transaction stream
//! (frame + virtual clock) sharing the coordinator's endpoint, RNG and
//! RPC slot. The scheduler always pumps the lane with the smallest
//! virtual clock, so lane transactions *overlap in virtual time* — while
//! lane A's Read Data phase occupies `[t, t+RTT]`, lane B's Lock phase
//! runs at `t+δ` — and all lanes charge the same simulated NICs, so
//! saturation effects of the deeper pipeline are faithful.
//!
//! Three mechanisms fall out of the lane model:
//!
//! - **Cross-transaction doorbell coalescing** ([`Coalescer`]): phases
//!   *plan* their one-sided ops into [`OpBatch`]es and hand them to the
//!   scheduler's conduit ([`crate::txn::phases::PhaseCtx::issue`]). The
//!   coalescer merges plans that reach an issue point within
//!   `coalesce_window_ns` of each other into one [`MergedBatch`] —
//!   deferred fire-and-forget plans (commit-log clears) park and ride a
//!   later frame's doorbell — and issues each per-MN group as **one**
//!   doorbell via the completion-driven
//!   [`Endpoint::doorbell_timed`][crate::dm::Endpoint::doorbell_timed]
//!   mode, so each frame's clock is charged only for its own ops'
//!   completions.
//! - **Sibling lock-first aborts** ([`SiblingLocks`]): lanes are pumped
//!   one transaction at a time (wall-clock), so a conflict between two
//!   lanes whose transactions overlap in *virtual* time would not be
//!   visible in the shared lock table. The scheduler therefore keeps the
//!   lock intervals of recently pumped lane transactions; the lock phase
//!   checks them first and aborts conflicting siblings locally — a CPU
//!   compare on the CN, before a single byte (or remote-lock RPC) leaves
//!   the node.
//! - **Parallel per-MN doorbells**: the merged issue rings every target
//!   MN at the same virtual instant (a coordinator posts to all QPs and
//!   then polls completions), where the sequential path issues per-MN
//!   groups back to back. This is part of the pipelined coordinator's
//!   latency win and is exactly what "the RNIC stays busy" means.
//!
//! With `depth == 1` there are no siblings and no coalescer: the
//! scheduler degenerates to the sequential coordinator's exact issue
//! order, clock charges and RNG stream (asserted by the
//! `pipeline_depth=1` invariant test in [`crate::sim`]).

use std::cell::RefCell;
use std::sync::Arc;

use crate::dm::clock::{TimeGate, VClock};
use crate::dm::memnode::MemNode;
use crate::dm::opbatch::{BatchResult, MergedBatch, OpBatch};
use crate::dm::verbs::Endpoint;
use crate::lock::table::LockMode;
use crate::sharding::key::LotusKey;
use crate::txn::api::{RecordRef, TxnApi, TxnCtl};
use crate::txn::coordinator::SharedCluster;
use crate::txn::phases::{self, PhaseCtx, TxnFrame, TxnRecord};
use crate::util::Xoshiro256;
use crate::workloads::{RouteCtx, Workload};
use crate::Result;

/// Decide whether a doorbell to `mn` at virtual time `t` can ride the
/// last doorbell rung to that MN (within `window`), or must ring its own
/// (recording `t` as the new ring anchor).
fn ride_or_ring(last_ring: &mut Vec<u64>, mn: usize, t: u64, window: u64) -> bool {
    if mn >= last_ring.len() {
        last_ring.resize(mn + 1, u64::MAX);
    }
    let last = last_ring[mn];
    if last != u64::MAX && t.abs_diff(last) <= window {
        true
    } else {
        last_ring[mn] = t;
        false
    }
}

/// Per-scheduler doorbell coalescer: merges the planned [`OpBatch`]es of
/// frames that reach an issue point within `coalesce_window_ns` of each
/// other into shared doorbell rings (see the module docs). One instance
/// per [`FrameScheduler`]; single-threaded by construction (interior
/// mutability only so the shared-reference [`PhaseCtx`] can reach it).
pub struct Coalescer {
    window_ns: u64,
    state: RefCell<CoalesceState>,
}

#[derive(Default)]
struct CoalesceState {
    /// Parked fire-and-forget plans: `(plan, park virtual time)`.
    pending: Vec<(OpBatch, u64)>,
    /// Per MN: virtual time of the last doorbell rung (`u64::MAX` never).
    last_ring: Vec<u64>,
}

impl Coalescer {
    /// Coalescer with the given pairing window (virtual ns).
    pub fn new(window_ns: u64) -> Self {
        Self {
            window_ns,
            state: RefCell::new(CoalesceState::default()),
        }
    }

    /// The pairing window (virtual ns).
    pub fn window_ns(&self) -> u64 {
        self.window_ns
    }

    /// Parked fire-and-forget plans not yet flushed.
    pub fn pending_plans(&self) -> usize {
        self.state.borrow().pending.len()
    }

    /// Park a fire-and-forget plan to ride a later doorbell. The plan
    /// waits at most `coalesce_window_ns` past the scheduler's slowest
    /// lane before [`Coalescer::flush_stale`] rings it out.
    pub fn defer(&self, plan: OpBatch, now: u64) {
        if plan.is_empty() {
            return;
        }
        self.state.borrow_mut().pending.push((plan, now));
    }

    /// Issue a frame's planned batch, merged with every parked plan that
    /// is not in this frame's virtual future (beyond the window). The
    /// caller's clock advances only to the completion of **its own** ops;
    /// parked riders are fire-and-forget.
    pub fn issue(
        &self,
        batch: OpBatch,
        ep: &Endpoint,
        mns: &[Arc<MemNode>],
        clk: &mut VClock,
    ) -> Result<BatchResult> {
        let t = clk.now();
        let mut st = self.state.borrow_mut();
        let mut merged = MergedBatch::new();
        // Per-MN op counts of absorbed riders (metrics only).
        let mut rider_mns: Vec<(usize, u64)> = Vec::new();
        let mut kept: Vec<(OpBatch, u64)> = Vec::new();
        for (plan, pt) in st.pending.drain(..) {
            if pt <= t.saturating_add(self.window_ns) {
                for mn in plan.mns() {
                    let n = plan.group_len(mn) as u64;
                    match rider_mns.iter_mut().find(|(m, _)| *m == mn) {
                        Some((_, c)) => *c += n,
                        None => rider_mns.push((mn, n)),
                    }
                }
                merged.absorb(plan);
            } else {
                kept.push((plan, pt));
            }
        }
        st.pending = kept;
        if batch.is_empty() && merged.n_plans() == 0 {
            // Nothing to do at all: stay free like the direct path.
            drop(st);
            return batch.issue(ep, mns, clk);
        }
        let me = merged.absorb(batch);
        ep.gate_sync(clk);
        let window = self.window_ns;
        let st_ref = &mut *st;
        let last_ring = &mut st_ref.last_ring;
        let mut rode: Vec<usize> = Vec::new();
        let mut res = merged.issue_timed(ep, mns, t, |mn| {
            let ride = ride_or_ring(last_ring, mn, t, window);
            if ride {
                rode.push(mn);
            }
            ride
        })?;
        // Parked ops that joined a doorbell rung *for this frame's plan*
        // are coalesced riders; ride-groups were already counted by the
        // endpoint itself.
        let rider_ops: u64 = rider_mns
            .iter()
            .filter(|(mn, _)| !rode.contains(mn))
            .map(|&(_, n)| n)
            .sum();
        if rider_ops > 0 {
            ep.nic.note_riders(rider_ops);
        }
        let (mine, done) = res.take(me);
        clk.catch_up(done);
        Ok(mine)
    }

    /// Ring out parked plans whose window expired before `horizon` (the
    /// scheduler's slowest lane): no doorbell came along to ride, so they
    /// ring their own, charged fire-and-forget at their park times.
    pub fn flush_stale(&self, ep: &Endpoint, mns: &[Arc<MemNode>], horizon: u64) -> Result<()> {
        self.flush_inner(ep, mns, Some(horizon))
    }

    /// Ring out every parked plan (orderly scheduler shutdown).
    pub fn flush_all(&self, ep: &Endpoint, mns: &[Arc<MemNode>]) -> Result<()> {
        self.flush_inner(ep, mns, None)
    }

    /// Drop every parked plan without issuing it (fail-stop crash: WQEs
    /// posted but not yet rung die with the CN; recovery completes or
    /// rolls back the affected transactions from their commit logs).
    pub fn discard_pending(&self) {
        self.state.borrow_mut().pending.clear();
    }

    fn flush_inner(&self, ep: &Endpoint, mns: &[Arc<MemNode>], horizon: Option<u64>) -> Result<()> {
        let mut st = self.state.borrow_mut();
        if st.pending.is_empty() {
            return Ok(());
        }
        let mut merged = MergedBatch::new();
        let mut t0 = u64::MAX;
        let mut kept: Vec<(OpBatch, u64)> = Vec::new();
        for (plan, pt) in st.pending.drain(..) {
            let stale = match horizon {
                Some(h) => pt.saturating_add(self.window_ns) < h,
                None => true,
            };
            if stale {
                t0 = t0.min(pt);
                merged.absorb(plan);
            } else {
                kept.push((plan, pt));
            }
        }
        st.pending = kept;
        if merged.n_plans() == 0 {
            return Ok(());
        }
        let window = self.window_ns;
        let st_ref = &mut *st;
        let last_ring = &mut st_ref.last_ring;
        // Fire-and-forget: completions and results are discarded.
        merged.issue_timed(ep, mns, t0, |mn| ride_or_ring(last_ring, mn, t0, window))?;
        Ok(())
    }
}

/// One lock held by a recently pumped sibling transaction, with its
/// virtual release time.
#[derive(Debug, Clone, Copy)]
pub struct LockStamp {
    /// Locked key.
    pub key: LotusKey,
    /// Held mode.
    pub mode: LockMode,
    /// Virtual time the holding transaction released it.
    pub until: u64,
}

/// Read view over all lanes' recent lock intervals, excluding the asking
/// lane — the lock phase's local sibling-conflict check.
pub struct SiblingLocks<'a> {
    logs: &'a [Vec<LockStamp>],
    me: usize,
}

impl<'a> SiblingLocks<'a> {
    /// View for lane `me` over `logs` (one entry per lane).
    pub fn new(logs: &'a [Vec<LockStamp>], me: usize) -> Self {
        Self { logs, me }
    }

    /// Would acquiring `mode` on `key` at virtual time `now` conflict
    /// with a sibling lane's transaction that still holds the key then?
    pub fn conflicts(&self, key: LotusKey, mode: LockMode, now: u64) -> bool {
        self.logs.iter().enumerate().any(|(i, log)| {
            i != self.me
                && log.iter().any(|s| {
                    s.key == key
                        && s.until > now
                        && (mode == LockMode::Write || s.mode == LockMode::Write)
                })
        })
    }
}

/// Transaction state machine of one lane (mirrors the sequential
/// coordinator's assertion states).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LanePhase {
    Idle,
    Building,
    Executed,
}

/// One concurrent transaction stream within a scheduler.
struct Lane {
    frame: TxnFrame,
    clk: VClock,
    phase: LanePhase,
}

/// `pipeline_depth` concurrent transaction streams multiplexed onto one
/// coordinator thread (see the module docs). Replaces the sequential
/// coordinator inside [`crate::sim`]'s `coordinator_thread` for LOTUS
/// runs with `pipeline_depth >= 1`.
pub struct FrameScheduler {
    cluster: Arc<SharedCluster>,
    cn: usize,
    slot: usize,
    global_id: usize,
    ep: Endpoint,
    rng: Xoshiro256,
    lanes: Vec<Lane>,
    /// Per lane: lock intervals of its recently pumped transactions
    /// (pruned once every lane's clock has passed them).
    lock_logs: Vec<Vec<LockStamp>>,
    coalescer: Option<Coalescer>,
}

impl FrameScheduler {
    /// Scheduler for coordinator `slot` on CN `cn` with `depth` lanes.
    /// Coalescing activates for `depth >= 2` when `coalesce_window_ns`
    /// is non-zero; `depth == 1` reproduces the sequential coordinator.
    pub fn new(cluster: Arc<SharedCluster>, cn: usize, slot: usize, global_id: usize) -> Self {
        let depth = cluster.cfg.pipeline_depth.max(1);
        let window = cluster.cfg.coalesce_window_ns;
        let ep = Endpoint::new(cn, cluster.cn_nics[cn].clone(), cluster.net.clone());
        let seed = cluster.cfg.seed ^ (global_id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Self {
            cn,
            slot,
            global_id,
            ep,
            rng: Xoshiro256::new(seed),
            lanes: (0..depth)
                .map(|_| Lane {
                    frame: TxnFrame::new(),
                    clk: VClock::zero(),
                    phase: LanePhase::Idle,
                })
                .collect(),
            lock_logs: (0..depth).map(|_| Vec::new()).collect(),
            coalescer: (depth > 1 && window > 0).then(|| Coalescer::new(window)),
            cluster,
        }
    }

    /// Number of lanes (the configured pipeline depth).
    pub fn depth(&self) -> usize {
        self.lanes.len()
    }

    /// The scheduler's frontier: the slowest lane's virtual clock. This
    /// is what the run loop compares against the duration and publishes
    /// to the [`TimeGate`] between transactions.
    pub fn now(&self) -> u64 {
        self.lanes
            .iter()
            .map(|l| l.clk.now())
            .min()
            .unwrap_or(u64::MAX)
    }

    /// Attach the run's time gate to the shared endpoint.
    pub fn attach_gate(&mut self, gate: Arc<TimeGate>, gid: usize) {
        self.ep.attach_gate(gate, gid);
    }

    /// Fail-stop: every lane drops its in-flight state without releasing
    /// locks (recovery owns them, paper §6). Parked fire-and-forget
    /// plans are WQEs posted but never rung — they die with the CN; a
    /// committed transaction's un-cleared log slot is completed
    /// idempotently by recovery's log scan.
    pub fn crash(&mut self) {
        if let Some(c) = &self.coalescer {
            c.discard_pending();
        }
        for lane in &mut self.lanes {
            lane.frame.crash();
            lane.phase = LanePhase::Idle;
        }
        for log in &mut self.lock_logs {
            log.clear();
        }
    }

    /// Orderly end of run: ring out every parked plan so no planned op
    /// (or its NIC charge) is silently dropped at the duration boundary.
    pub fn finish(&mut self) -> Result<()> {
        if let Some(c) = &self.coalescer {
            c.flush_all(&self.ep, &self.cluster.mns)?;
        }
        Ok(())
    }

    /// Jump every lane's clock forward (crash restart).
    pub fn skip_to(&mut self, t_ns: u64) {
        for lane in &mut self.lanes {
            lane.clk.catch_up(t_ns);
        }
    }

    fn min_lane(&self) -> usize {
        let mut li = 0;
        for i in 1..self.lanes.len() {
            if self.lanes[i].clk.now() < self.lanes[li].clk.now() {
                li = i;
            }
        }
        li
    }

    /// Pump the slowest lane through one transaction. Returns the lane's
    /// clock before and after, plus the transaction outcome — exactly the
    /// accounting the run loop needs for latency/commit bookkeeping.
    pub fn step(
        &mut self,
        workload: &dyn Workload,
        route: &RouteCtx<'_>,
    ) -> (u64, u64, Result<()>) {
        let li = self.min_lane();
        let t0 = self.lanes[li].clk.now();
        // Ring out parked plans no doorbell came along for, and drop
        // sibling lock intervals every lane has virtually passed.
        if let Some(c) = &self.coalescer {
            if let Err(e) = c.flush_stale(&self.ep, &self.cluster.mns, t0) {
                return (t0, t0, Err(e));
            }
        }
        for log in &mut self.lock_logs {
            log.retain(|s| s.until > t0);
        }
        let res = {
            let Self {
                cluster,
                ep,
                rng,
                lanes,
                lock_logs,
                coalescer,
                cn,
                slot,
                global_id,
            } = self;
            let mut api = LaneApi {
                cluster: &*cluster,
                ep: &*ep,
                rng,
                lane: &mut lanes[li],
                lane_idx: li,
                logs: &*lock_logs,
                coalescer: coalescer.as_ref(),
                cn: *cn,
                slot: *slot,
                global_id: *global_id,
            };
            workload.run_one(&mut api, route)
        };
        let t1 = self.lanes[li].clk.now();
        // Remember a *committed* transaction's lock set for the sibling
        // conflict check: any lane pumped later but virtually overlapping
        // `[t0, t1]` must see these as held (the lock set is a pure
        // function of the still-intact record set). Aborted transactions
        // are not stamped — they released whatever they briefly held, and
        // stamping them would cascade phantom aborts between siblings.
        if self.lanes.len() > 1 && res.is_ok() {
            let frame = &self.lanes[li].frame;
            if !frame.read_only && !frame.records.is_empty() {
                for (key, mode) in phases::lock::requests(&self.cluster, frame, 0) {
                    self.lock_logs[li].push(LockStamp {
                        key,
                        mode,
                        until: t1,
                    });
                }
            }
        }
        (t0, t1, res)
    }
}

/// The [`TxnApi`]/[`TxnCtl`] view the workload drives for one pumped
/// lane: the lane's frame and clock, the scheduler's shared endpoint,
/// RNG, coalescer and sibling lock intervals.
struct LaneApi<'a> {
    cluster: &'a Arc<SharedCluster>,
    ep: &'a Endpoint,
    rng: &'a mut Xoshiro256,
    lane: &'a mut Lane,
    lane_idx: usize,
    logs: &'a [Vec<LockStamp>],
    coalescer: Option<&'a Coalescer>,
    cn: usize,
    slot: usize,
    global_id: usize,
}

impl LaneApi<'_> {
    /// Split-borrow into a phase context + the lane's frame.
    fn parts(&mut self) -> (PhaseCtx<'_>, &mut TxnFrame) {
        let lane = &mut *self.lane;
        (
            PhaseCtx {
                cluster: self.cluster,
                cn: self.cn,
                slot: self.slot,
                global_id: self.global_id,
                ep: self.ep,
                clk: &mut lane.clk,
                coalescer: self.coalescer,
                siblings: if self.logs.len() > 1 {
                    Some(SiblingLocks::new(self.logs, self.lane_idx))
                } else {
                    None
                },
            },
            &mut lane.frame,
        )
    }
}

impl TxnCtl for LaneApi<'_> {
    fn add_ro(&mut self, r: RecordRef) {
        debug_assert_ne!(self.lane.phase, LanePhase::Idle);
        self.lane.frame.records.push(TxnRecord::new(r, false));
    }

    fn add_rw(&mut self, r: RecordRef) {
        debug_assert_ne!(self.lane.phase, LanePhase::Idle);
        debug_assert!(!self.lane.frame.read_only, "read-only txn cannot AddRW");
        self.lane.frame.records.push(TxnRecord::new(r, true));
    }

    fn add_insert(&mut self, r: RecordRef, payload: Vec<u8>) {
        debug_assert_ne!(self.lane.phase, LanePhase::Idle);
        debug_assert!(!self.lane.frame.read_only);
        let mut rec = TxnRecord::new(r, true);
        rec.insert = true;
        rec.new_value = Some(payload);
        self.lane.frame.records.push(rec);
    }

    fn add_delete(&mut self, r: RecordRef) {
        debug_assert_ne!(self.lane.phase, LanePhase::Idle);
        let mut rec = TxnRecord::new(r, true);
        rec.delete = true;
        self.lane.frame.records.push(rec);
    }

    fn execute(&mut self) -> Result<()> {
        debug_assert_ne!(self.lane.phase, LanePhase::Idle);
        let res = {
            let (mut ctx, frame) = self.parts();
            phases::execute(&mut ctx, frame)
        };
        match res {
            Ok(()) => {
                self.lane.phase = LanePhase::Executed;
                Ok(())
            }
            Err(e) => {
                // The failing phase already released every held lock.
                self.lane.phase = LanePhase::Idle;
                Err(e)
            }
        }
    }

    fn value(&self, r: RecordRef) -> Option<&[u8]> {
        self.lane
            .frame
            .find(r)
            .and_then(|i| self.lane.frame.records[i].value.as_deref())
    }

    fn stage_write(&mut self, r: RecordRef, payload: Vec<u8>) {
        let i = self
            .lane
            .frame
            .find(r)
            .expect("stage_write on unknown record");
        debug_assert!(self.lane.frame.records[i].write, "stage_write needs AddRW");
        self.lane.frame.records[i].new_value = Some(payload);
    }

    fn commit(&mut self) -> Result<()> {
        debug_assert_eq!(self.lane.phase, LanePhase::Executed);
        let res = {
            let (mut ctx, frame) = self.parts();
            phases::commit_txn(&mut ctx, frame)
        };
        self.lane.phase = LanePhase::Idle;
        res
    }

    fn rollback(&mut self) {
        let (mut ctx, frame) = self.parts();
        phases::unlock::release(&mut ctx, frame);
        self.lane.phase = LanePhase::Idle;
    }
}

impl TxnApi for LaneApi<'_> {
    fn begin(&mut self, read_only: bool) {
        phases::begin(
            self.cluster,
            &mut self.lane.clk,
            &mut self.lane.frame,
            read_only,
        );
        self.lane.phase = LanePhase::Building;
    }

    fn txn(&mut self) -> &mut dyn TxnCtl {
        self
    }

    fn now(&self) -> u64 {
        self.lane.clk.now()
    }

    fn rng(&mut self) -> &mut Xoshiro256 {
        self.rng
    }

    fn cn(&self) -> usize {
        self.cn
    }

    fn attach_gate(&mut self, _gate: Arc<TimeGate>, _gid: usize) {
        // The gate is attached at scheduler level (shared endpoint).
    }

    fn crash(&mut self) {
        self.lane.frame.crash();
        self.lane.phase = LanePhase::Idle;
    }

    fn skip_to(&mut self, t_ns: u64) {
        self.lane.clk.catch_up(t_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dm::netconfig::NetConfig;
    use crate::dm::rnic::Rnic;

    fn setup() -> (Vec<Arc<MemNode>>, Endpoint) {
        let mns = vec![Arc::new(MemNode::new(0, 1 << 16))];
        let ep = Endpoint::new(0, Arc::new(Rnic::new()), Arc::new(NetConfig::default()));
        (mns, ep)
    }

    #[test]
    fn deferred_plan_rides_the_next_sync_doorbell() {
        let (mns, ep) = setup();
        let r = mns[0].register(64).unwrap();
        let c = Coalescer::new(5_000);

        // A frame parks a fire-and-forget write...
        let mut park = OpBatch::new();
        park.write(0, r.base, 7u64.to_le_bytes().to_vec());
        c.defer(park, 100);
        assert_eq!(c.pending_plans(), 1);

        // ...and another frame's read batch comes along within the window.
        let mut clk = VClock(600);
        let mut sync = OpBatch::new();
        let tag = sync.read(0, r.base, 8);
        let res = c.issue(sync, &ep, &mns, &mut clk).unwrap();

        assert_eq!(c.pending_plans(), 0, "the parked plan rode along");
        assert_eq!(ep.nic.doorbells(), 1, "one merged ring, not two");
        assert_eq!(ep.nic.coalesced_ops(), 1, "the parked write was a rider");
        // The parked write executed before the rider's read in the same
        // doorbell group.
        assert_eq!(res.read_buf(tag), &7u64.to_le_bytes()[..]);
        assert_eq!(mns[0].load_u64(r.base).unwrap(), 7);
        assert!(clk.now() >= 600 + ep.net.rtt_ns, "sync caller waited its RTT");
    }

    #[test]
    fn stale_deferred_plan_rings_its_own_doorbell_on_flush() {
        let (mns, ep) = setup();
        let r = mns[0].register(64).unwrap();
        let c = Coalescer::new(1_000);
        let mut park = OpBatch::new();
        park.write(0, r.base, 9u64.to_le_bytes().to_vec());
        c.defer(park, 100);

        // Horizon still inside the window: nothing flushes.
        c.flush_stale(&ep, &mns, 900).unwrap();
        assert_eq!(c.pending_plans(), 1);
        assert_eq!(ep.nic.doorbells(), 0);

        // Window expired: the plan rings out fire-and-forget.
        c.flush_stale(&ep, &mns, 5_000).unwrap();
        assert_eq!(c.pending_plans(), 0);
        assert_eq!(ep.nic.doorbells(), 1);
        assert_eq!(mns[0].load_u64(r.base).unwrap(), 9);
    }

    #[test]
    fn sibling_lock_intervals_conflict_by_mode_and_time() {
        let k = LotusKey::compose(5, 5);
        let other = LotusKey::compose(6, 6);
        let logs = vec![
            vec![LockStamp {
                key: k,
                mode: LockMode::Write,
                until: 1_000,
            }],
            Vec::new(),
        ];
        let sib = SiblingLocks::new(&logs, 1);
        // Overlapping write-write and read-write conflict...
        assert!(sib.conflicts(k, LockMode::Write, 500));
        assert!(sib.conflicts(k, LockMode::Read, 500));
        // ...a different key, the past, or my own lane's locks don't.
        assert!(!sib.conflicts(other, LockMode::Write, 500));
        assert!(!sib.conflicts(k, LockMode::Write, 1_000));
        let mine = SiblingLocks::new(&logs, 0);
        assert!(!mine.conflicts(k, LockMode::Write, 500));
    }

    #[test]
    fn read_read_siblings_do_not_conflict() {
        let k = LotusKey::compose(7, 7);
        let logs = vec![
            vec![LockStamp {
                key: k,
                mode: LockMode::Read,
                until: 1_000,
            }],
            Vec::new(),
        ];
        let sib = SiblingLocks::new(&logs, 1);
        assert!(!sib.conflicts(k, LockMode::Read, 500));
        assert!(sib.conflicts(k, LockMode::Write, 500));
    }
}
