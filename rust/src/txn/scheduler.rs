//! The pipelined frame scheduler: `pipeline_depth` concurrent
//! [`TxnFrame`]s per coordinator thread, each reified as a poll-driven
//! **lane continuation** and multiplexed by a flat **ready-queue event
//! loop** that overlaps sibling frames' protocol stages and coalesces
//! their doorbells.
//!
//! The sequential [`crate::txn::coordinator::LotusCoordinator`] runs one
//! transaction at a time and stalls a full RTT at every phase boundary.
//! The paper's CNs keep their RNICs busy by overlapping many in-flight
//! requests ("threads x coroutines"); the [`FrameScheduler`] models that:
//! one OS thread owns `depth` **lanes**, each a full transaction stream
//! (frame + virtual clock + RNG) sharing the coordinator's endpoint and
//! RPC slot. All lanes charge the same simulated NICs, so saturation
//! effects of the deeper pipeline are faithful.
//!
//! # Reified lane continuations (ISSUE 4)
//!
//! A lane's whole transaction — the workload driver plus every protocol
//! phase — is one heap-allocated step machine
//! ([`crate::txn::step::StepFut`]), cut at its issue points. Phases
//! *plan* their one-sided ops into [`OpBatch`]es and hand them to the
//! conduit ([`crate::txn::phases::PhaseCtx::issue`], backed here by the
//! scheduler's [`StepSink`] implementation):
//!
//! 1. **Post / park** — the plan's WQEs are staged in the scheduler's
//!    in-flight table (`Flight::Staged`; the CN NIC tracks the
//!    posted-but-unrung depth) and the machine returns `Poll::Pending`.
//!    Nothing on the OS stack pins the lane: its entire state lives in
//!    the machine, so *any* lane can run next.
//! 2. **Pump** — the event loop polls the runnable lane with the
//!    smallest virtual clock: a lane whose doorbell completed
//!    (`Flight::Done`, ready at its own completion time), a lane whose
//!    lock wait ended (`Flight::WaitOver`), or an idle lane starting a
//!    fresh transaction. Each pumped lane runs to its own next issue
//!    point and parks in turn — so a frame's lock RPC, CVT read and log
//!    write overlap in virtual time with sibling frames' phases, at
//!    *every* issue point of every round, not just the innermost one.
//! 3. **Ring / re-enqueue** — when no runnable lane remains at or below
//!    `staged_min + coalesce_window_ns` (every lane is parked, or the
//!    next runnable lane lies beyond the oldest staged plan's window),
//!    the loop rings **one merged doorbell set** for every staged plan
//!    within the window of the oldest post time (plus parked
//!    fire-and-forget riders). Per-op completion times are routed back
//!    through the in-flight table (`Flight::Done`), and each completed
//!    lane re-enters the ready queue at its own completion time — lanes
//!    resume in **completion-clock order**, in any interleaving. Staged
//!    plans outside the window stay staged and ring in a later round, so
//!    a lane's merge wait is bounded by the window.
//!
//! The old step-machine (PR 3) suspended lanes by *stack unwind*: a
//! parked lane held an OS stack frame (and a `RefCell` borrow), so after
//! a merged ring only the innermost lane could keep issuing; ancestors
//! resumed LIFO and their later issue points mostly rang alone. The
//! continuation model deletes that shape entirely — there is no nested
//! pumping, no `MAX_PUMPS_PER_YIELD` bound, no per-lane `RefCell`
//! suspension trick; the scheduler pump is a flat loop. The new
//! [`crate::metrics::RunReport`] stats `resumed_rings` /
//! `mean_ring_gap_ns()` report how many rings re-enqueued parked lanes
//! and how long staged plans waited to merge.
//!
//! # Two planes, one issue-point fabric (ISSUE 5)
//!
//! A staged plan targets **either fabric** ([`crate::txn::phases::Plan`]):
//! one-sided doorbell batches against the memory pool, or batched
//! lock-class **CN-to-CN RPC messages** (the lock phase's per-remote-CN
//! batches). Both park in the same in-flight table and ride the same
//! ring trigger; when the loop rings, staged doorbell plans merge per
//! target MN into shared doorbell sets and staged RPC plans merge **per
//! destination CN** into single RPC messages
//! ([`crate::dm::RpcFabric::send_timed`] — one `rpc_send_ns` charge per
//! message, per-owner handler completions), so sibling lanes locking on
//! the same remote CN within the window pay one message instead of one
//! each. Fire-and-forget unlock messages defer exactly like commit-log
//! clears: they ride the next merged lock message to the same CN and
//! flush out alone when the window expires. RPC-plane accounting lives
//! on the CN [`crate::dm::rnic::Rnic`]
//! (`rpc_messages`/`rpc_reqs`/`coalesced_rpc_reqs`).
//!
//! Two further mechanisms ride on the lane model:
//!
//! - **Fire-and-forget parking** ([`Coalescer`]): deferred plans
//!   (commit-log clears, remote unlock messages) park and ride a later
//!   ring; stale ones are rung out by [`Coalescer::flush_stale`] /
//!   [`FrameScheduler::finish`] exactly once. With
//!   `coalesce_window_ns == 0` there is no coalescer and deferred plans
//!   issue immediately (fire-and-forget) instead of parking.
//! - **Sibling lock conflicts by virtual interval** ([`SiblingLocks`] +
//!   the live holdings of parked lanes): conflicts between lanes are
//!   decided against *recorded lock intervals* — a committed
//!   transaction's `[from, until)` stamps and a parked lane's live
//!   `[from, ..)` holdings — never against raw physical holder state. A
//!   requester whose clock precedes a suspended sibling's acquisition
//!   time is not in conflict in the modeled timeline: it *parks*
//!   (`Flight::WaitLock`) until the sibling releases and then retries at
//!   its unchanged virtual time, instead of taking the anachronistic
//!   abort the stack-unwind design had to take. Genuine interval
//!   overlaps abort lock-first, before any bytes leave the CN. Waits
//!   never target a lane that is itself waiting, so the wait graph is
//!   acyclic and the loop always progresses.
//!
//! With `depth == 1` there are no siblings, no coalescer and no staging:
//! every issue takes the direct path and a lane machine completes within
//! a single poll, reproducing the sequential coordinator's exact issue
//! order, clock charges and RNG stream (asserted by the
//! `pipeline_depth=1` invariant test in [`crate::sim`]).

use std::cell::{Cell, RefCell};
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::sync::Arc;
use std::task::{Context, Poll, Waker};

use crate::dm::clock::{TimeGate, VClock};
use crate::dm::memnode::MemNode;
use crate::dm::opbatch::{BatchResult, MergedBatch, OpBatch};
use crate::dm::rpc::RpcFabric;
use crate::dm::verbs::Endpoint;
use crate::lock::table::LockMode;
use crate::sharding::key::LotusKey;
use crate::txn::adaptive::{AdaptiveController, Obs, Plane};
use crate::txn::api::{RecordRef, TxnApi, TxnCtl};
use crate::txn::coordinator::SharedCluster;
use crate::txn::phases::{self, PhaseCtx, Plan, StepSink, TxnFrame, TxnRecord, WaitVerdict};
use crate::txn::step::{noop_waker, StepFut};
use crate::util::Xoshiro256;
use crate::workloads::{RouteCtx, Workload};
use crate::{Error, Result};

/// One completed transaction's accounting on the lane clock that ran it.
/// A fatal (non-abort) error never appears here — it fails the whole run
/// instead.
#[derive(Debug)]
pub struct LaneOutcome {
    /// The lane that ran the transaction.
    pub lane: usize,
    /// Lane clock at `begin`.
    pub t_begin: u64,
    /// Lane clock at completion (commit or abort).
    pub t_end: u64,
    /// Commit (`Ok`) or abort (`Err` with an abort reason).
    pub result: Result<()>,
}

/// Add `n` ops to `mn`'s tally in a small per-MN count list.
fn bump_mn(tally: &mut Vec<(usize, u64)>, mn: usize, n: u64) {
    match tally.iter_mut().find(|(m, _)| *m == mn) {
        Some((_, c)) => *c += n,
        None => tally.push((mn, n)),
    }
}

/// Decide whether a doorbell to `mn` at virtual time `t` can ride the
/// last doorbell rung to that MN (within `window`), or must ring its own
/// (recording `t` as the new ring anchor).
fn ride_or_ring(last_ring: &mut Vec<u64>, mn: usize, t: u64, window: u64) -> bool {
    if mn >= last_ring.len() {
        last_ring.resize(mn + 1, u64::MAX);
    }
    let last = last_ring[mn];
    if last != u64::MAX && t.abs_diff(last) <= window {
        true
    } else {
        last_ring[mn] = t;
        false
    }
}

/// The coalescer's window policy: the same base window everywhere
/// (byte-stable, the depth-1 equivalence anchor), or the ISSUE 6
/// congestion controller granting an *effective* window per fabric
/// plane × destination.
enum CoalescePolicy {
    /// The configured `coalesce_window_ns`, applied uniformly.
    Fixed(u64),
    /// Per-plane × per-destination adaptive windows anchored at the
    /// configured base (see [`crate::txn::adaptive`]).
    Adaptive(AdaptiveController),
}

/// Per-scheduler two-plane coalescer: merges staged sync plans and
/// parked fire-and-forget plans into shared doorbell rings (memory-pool
/// plane) and shared per-destination RPC messages (CN-to-CN plane; see
/// the module docs). One instance per [`FrameScheduler`]; single-threaded
/// by construction (interior mutability only so the shared-reference
/// [`StepSink`] can reach it).
pub struct Coalescer {
    policy: CoalescePolicy,
    state: RefCell<CoalesceState>,
}

#[derive(Default)]
struct CoalesceState {
    /// Parked fire-and-forget plans: `(plan, park virtual time)` — log
    /// clears (doorbell plane) and remote unlock messages (RPC plane).
    pending: Vec<(Plan, u64)>,
    /// Per MN: virtual time of the last doorbell rung (`u64::MAX` never).
    last_ring: Vec<u64>,
    // --- Reusable ring scratch (ISSUE 9). Cleared and refilled per
    // ring/flush so steady-state coalescing performs no heap
    // allocation; capacities grow once and stick. `kept` follows a
    // drain-and-swap discipline with `pending` and is empty between
    // calls.
    /// Keeper side of the `pending` drain.
    kept: Vec<(Plan, u64)>,
    /// Per MN rider-op tallies of the current ring.
    rider_mns: Vec<(usize, u64)>,
    /// MNs whose doorbell the current ring's first-touching plan pays.
    payer_mns: Vec<usize>,
    /// Per MN op tallies of later plans riding a payer's doorbell.
    extra_mns: Vec<(usize, u64)>,
    /// Per MN total op tallies (riders + sync) of the merged issue.
    all_mns: Vec<(usize, u64)>,
    /// `(owner, merged slice)` per absorbed sync plan.
    slices: Vec<(usize, usize)>,
    /// MNs whose groups rode an earlier doorbell this ring.
    rode: Vec<usize>,
    /// Distinct destination CNs of the current RPC ring.
    dsts: Vec<usize>,
    /// One destination's `(owner, n_reqs, post time)` plans.
    group: Vec<(usize, usize, u64)>,
    /// Per-chunk owner request counts handed to the RPC fabric.
    owners: Vec<usize>,
    /// Stale RPC plans merged per destination: `(dst, reqs, t0)`.
    rpc_flush: Vec<(usize, usize, u64)>,
}

impl Coalescer {
    /// Coalescer with the given fixed pairing window (virtual ns).
    pub fn new(window_ns: u64) -> Self {
        Self {
            policy: CoalescePolicy::Fixed(window_ns),
            state: RefCell::new(CoalesceState::default()),
        }
    }

    /// Coalescer steered by the adaptive congestion controller, anchored
    /// at `base_ns` (an unobserved destination's window IS the base, so
    /// the policy is inert until the fabric shows congestion).
    pub fn adaptive(base_ns: u64) -> Self {
        Self {
            policy: CoalescePolicy::Adaptive(AdaptiveController::new(base_ns)),
            state: RefCell::new(CoalesceState::default()),
        }
    }

    /// The base pairing window (virtual ns): the fixed window, or the
    /// adaptive controller's anchor.
    pub fn window_ns(&self) -> u64 {
        match &self.policy {
            CoalescePolicy::Fixed(w) => *w,
            CoalescePolicy::Adaptive(c) => c.base_ns(),
        }
    }

    /// Effective window for doorbell traffic to `mn`.
    fn window_db(&self, mn: usize) -> u64 {
        match &self.policy {
            CoalescePolicy::Fixed(w) => *w,
            CoalescePolicy::Adaptive(c) => c.window(Plane::Doorbell, mn),
        }
    }

    /// Effective window for RPC traffic to destination CN `dst`.
    fn window_rpc(&self, dst: usize) -> u64 {
        match &self.policy {
            CoalescePolicy::Fixed(w) => *w,
            CoalescePolicy::Adaptive(c) => c.window(Plane::Rpc, dst),
        }
    }

    /// Effective window of one plan: its destination's window on its
    /// plane (a multi-MN doorbell plan takes the tightest of its MNs' —
    /// the most latency-bound destination bounds the merge wait).
    pub fn eff_window(&self, plan: &Plan) -> u64 {
        match plan {
            Plan::Doorbell(b) => b
                .mns()
                .map(|mn| self.window_db(mn))
                .min()
                .unwrap_or_else(|| self.window_ns()),
            Plan::Rpc { dst_cn, .. } => self.window_rpc(*dst_cn),
        }
    }

    /// Parked fire-and-forget plans not yet flushed (both planes).
    pub fn pending_plans(&self) -> usize {
        self.state.borrow().pending.len()
    }

    /// Park a fire-and-forget plan to ride a later doorbell ring (log
    /// clears) or RPC message to the same destination CN (remote
    /// unlocks). The plan waits at most `coalesce_window_ns` past the
    /// scheduler's slowest lane before [`Coalescer::flush_stale`] rings
    /// it out.
    pub fn defer(&self, plan: Plan, now: u64) {
        if plan.is_empty() {
            return;
        }
        self.state.borrow_mut().pending.push((plan, now));
    }

    /// Ring one merged doorbell set carrying every staged sync plan in
    /// `plans` (`(owner tag, plan, post time)`) plus every parked
    /// fire-and-forget plan that is not in the ring's virtual future
    /// beyond the window. The ring fires at the latest post time; per-MN
    /// groups are issued completion-driven, and each owner gets back its
    /// own [`BatchResult`] plus the completion time of its slowest op —
    /// the only amount its clock must advance by — plus an `ok` flag
    /// (`false` == an injected doorbell fault hit one of the owner's
    /// rings; the owner must treat the batch as lost, PR 8).
    /// The caller's `plans` buffer is drained, not consumed, so hot
    /// callers keep its capacity across rings (ISSUE 9).
    pub fn ring(
        &self,
        plans: &mut Vec<(usize, OpBatch, u64)>,
        ep: &Endpoint,
        mns: &[Arc<MemNode>],
    ) -> Result<Vec<(usize, BatchResult, u64, bool)>> {
        // Earlier posts execute first within shared doorbell groups.
        plans.sort_by_key(|p| (p.2, p.0));
        let t_ring = plans.iter().map(|p| p.2).max().unwrap_or(0);
        let t_first = plans.iter().map(|p| p.2).min().unwrap_or(t_ring);
        let n_sync = plans.iter().filter(|p| !p.1.is_empty()).count() as u64;
        let mut guard = self.state.borrow_mut();
        let CoalesceState {
            pending,
            last_ring,
            kept,
            rider_mns,
            payer_mns,
            extra_mns,
            all_mns,
            slices,
            rode,
            ..
        } = &mut *guard;
        let mut merged = MergedBatch::new();
        // Parked doorbell riders first: their WQEs were posted earlier,
        // so they execute ahead of the sync plans in shared groups.
        // RPC-plane plans stay parked — they ride RPC messages
        // ([`Coalescer::ring_rpc`]), never doorbells.
        rider_mns.clear();
        if !pending.is_empty() {
            debug_assert!(kept.is_empty(), "kept scratch leaked between rings");
            for (plan, pt) in pending.drain(..) {
                let w = self.eff_window(&plan);
                match plan {
                    Plan::Doorbell(b) if pt <= t_ring.saturating_add(w) => {
                        for mn in b.mns() {
                            let n = b.group_len(mn) as u64;
                            bump_mn(rider_mns, mn, n);
                        }
                        merged.absorb(b);
                    }
                    other => kept.push((other, pt)),
                }
            }
            std::mem::swap(pending, kept);
            kept.clear();
        }
        // Sync plans in post order. The first plan touching an MN "pays"
        // that MN's doorbell; later plans' ops on it are coalesced riders.
        payer_mns.clear();
        extra_mns.clear();
        // Per-MN total op counts of this merged issue (riders + sync) —
        // the realized doorbell batch the controller observes.
        all_mns.clear();
        all_mns.extend_from_slice(rider_mns);
        slices.clear();
        for (owner, plan, _t) in plans.drain(..) {
            for mn in plan.mns() {
                let n = plan.group_len(mn) as u64;
                bump_mn(all_mns, mn, n);
                if payer_mns.contains(&mn) {
                    bump_mn(extra_mns, mn, n);
                } else {
                    payer_mns.push(mn);
                }
            }
            slices.push((owner, merged.absorb(plan)));
        }
        if merged.is_empty() {
            return Ok(slices
                .drain(..)
                .map(|(owner, _)| (owner, BatchResult::empty(), 0, true))
                .collect());
        }
        if n_sync >= 2 {
            ep.nic.note_overlap(n_sync);
        }
        ep.gate_sync(&VClock(t_ring));
        // Feed the congestion controller one observation per destination
        // MN this merged issue touches, *before* the issue charges the MN
        // RNICs: the pre-issue backlog (`busy_until - t_ring`) is the
        // doorbell-plane queueing-delay signal.
        if let CoalescePolicy::Adaptive(ctl) = &self.policy {
            let hwm = ep.nic.posted_wqes_hwm();
            for &(mn, n) in all_mns.iter() {
                ctl.observe(
                    Plane::Doorbell,
                    mn,
                    Obs {
                        queue_wait_ns: mns[mn].rnic.busy_until().saturating_sub(t_ring),
                        batch: n.max(1),
                        gap_ns: t_ring.saturating_sub(t_first),
                        hwm: hwm.max(n_sync),
                    },
                );
            }
        }
        rode.clear();
        let mut res = merged.issue_timed(ep, mns, t_ring, |mn| {
            let ride = ride_or_ring(last_ring, mn, t_ring, self.window_db(mn));
            if ride {
                rode.push(mn);
            }
            ride
        })?;
        // Ops that joined a doorbell rung for a payer plan without paying
        // the ring themselves are coalesced riders; whole groups that
        // extended an earlier doorbell were already counted by the
        // endpoint itself.
        let extra: u64 = rider_mns
            .iter()
            .chain(extra_mns.iter())
            .filter(|(mn, _)| payer_mns.contains(mn) && !rode.contains(mn))
            .map(|&(_, n)| n)
            .sum();
        if extra > 0 {
            ep.nic.note_riders(extra);
        }
        Ok(slices
            .drain(..)
            .map(|(owner, s)| {
                let (r, t, ok) = res.take(s);
                (owner, r, t, ok)
            })
            .collect())
    }

    /// Send every staged RPC plan in `plans` (`(owner lane, destination
    /// CN, request count, post time)`), merged into **one RPC message
    /// per destination CN** (plus parked fire-and-forget riders to that
    /// CN that are not in the message's virtual future beyond the
    /// window). Each message fires at the latest post time among its
    /// plans; each owner gets back `(reached the CN, completion time of
    /// its own handler chunk)` — `false` means the destination is failed
    /// and the owner burns the UD timeout from its own post time.
    /// Like [`Coalescer::ring`], the caller's `plans` buffer is drained
    /// in place so its capacity is reused across rings (ISSUE 9).
    pub fn ring_rpc(
        &self,
        plans: &mut Vec<(usize, usize, usize, u64)>,
        rpc: &RpcFabric,
        src_cn: usize,
        slot: usize,
        ep: &Endpoint,
    ) -> Vec<(usize, bool, u64)> {
        // Earlier posts execute first within a shared message.
        plans.sort_by_key(|p| (p.3, p.0));
        let mut out = Vec::with_capacity(plans.len());
        let mut guard = self.state.borrow_mut();
        let CoalesceState {
            pending,
            kept,
            dsts,
            group,
            owners,
            ..
        } = &mut *guard;
        dsts.clear();
        for p in plans.iter() {
            if !dsts.contains(&p.1) {
                dsts.push(p.1);
            }
        }
        for &dst in dsts.iter() {
            group.clear();
            group.extend(plans.iter().filter(|p| p.1 == dst).map(|p| (p.0, p.2, p.3)));
            let t_send = group.iter().map(|g| g.2).max().unwrap_or(0);
            if rpc.is_failed(dst) {
                // UD timeout: every owner burns the timeout interval from
                // its own post time; parked riders stay pending (they are
                // dropped when their window expires).
                for &(owner, _, tp) in group.iter() {
                    out.push((owner, false, rpc.timeout_done(tp)));
                }
                continue;
            }
            // Parked fire-and-forget riders to this CN absorb into the
            // message; posted earlier, so the handler serves them first.
            let w_dst = self.window_rpc(dst);
            let mut rider_reqs = 0usize;
            if !pending.is_empty() {
                debug_assert!(kept.is_empty(), "kept scratch leaked between rings");
                for (plan, pt) in pending.drain(..) {
                    match plan {
                        Plan::Rpc { dst_cn, n_reqs }
                            if dst_cn == dst && pt <= t_send.saturating_add(w_dst) =>
                        {
                            rider_reqs += n_reqs;
                        }
                        other => kept.push((other, pt)),
                    }
                }
                std::mem::swap(pending, kept);
                kept.clear();
            }
            owners.clear();
            if rider_reqs > 0 {
                owners.push(rider_reqs);
            }
            owners.extend(group.iter().map(|g| g.1));
            let total: usize = owners.iter().map(|&n| n.max(1)).sum();
            // Feed the controller this destination's evidence *before*
            // the send charges its queues: the booked handler backlog
            // beyond the message's arrival is the RPC-plane
            // queueing-delay signal.
            if let CoalescePolicy::Adaptive(ctl) = &self.policy {
                let t0 = group.iter().map(|g| g.2).min().unwrap_or(t_send);
                ctl.observe(
                    Plane::Rpc,
                    dst,
                    Obs {
                        queue_wait_ns: rpc.handler_backlog_ns(dst, slot, t_send),
                        batch: total as u64,
                        gap_ns: t_send.saturating_sub(t0),
                        hwm: ep.nic.posted_wqes_hwm().max(group.len() as u64),
                    },
                );
            }
            ep.gate_sync(&VClock(t_send));
            match rpc.send_timed(src_cn, dst, slot, owners, t_send) {
                Ok(times) => {
                    // The first sync plan pays the message; riders and
                    // later plans' requests are coalesced.
                    let first = group[0].1.max(1);
                    if total > first {
                        ep.nic.note_rpc_riders((total - first) as u64);
                    }
                    let skip = usize::from(rider_reqs > 0);
                    for (i, &(owner, _, _)) in group.iter().enumerate() {
                        out.push((owner, true, times[skip + i]));
                    }
                }
                Err(_) => {
                    // Failed between the check and the send (crash
                    // injection from another thread), or the message was
                    // lost by fault injection: same timeout path.
                    for &(owner, _, tp) in group.iter() {
                        out.push((owner, false, rpc.timeout_done(tp)));
                    }
                }
            }
        }
        out
    }

    /// Ring out parked plans whose window expired before `horizon` (the
    /// scheduler's slowest lane): no doorbell ring / RPC message came
    /// along to ride, so they issue their own, charged fire-and-forget
    /// at their park times.
    pub fn flush_stale(
        &self,
        ep: &Endpoint,
        mns: &[Arc<MemNode>],
        rpc: &RpcFabric,
        src_cn: usize,
        slot: usize,
        horizon: u64,
    ) -> Result<()> {
        self.flush_inner(ep, mns, rpc, src_cn, slot, Some(horizon))
    }

    /// Ring out every parked plan (orderly scheduler shutdown). A plan
    /// leaves `pending` the moment it is drained into the merged flush
    /// batch, so end-of-run flushes issue each parked plan exactly once
    /// no matter how often the flush paths run afterwards.
    pub fn flush_all(
        &self,
        ep: &Endpoint,
        mns: &[Arc<MemNode>],
        rpc: &RpcFabric,
        src_cn: usize,
        slot: usize,
    ) -> Result<()> {
        self.flush_inner(ep, mns, rpc, src_cn, slot, None)
    }

    /// Drop every parked plan without issuing it (fail-stop crash: WQEs
    /// posted but not yet rung die with the CN; recovery completes or
    /// rolls back the affected transactions from their commit logs).
    pub fn discard_pending(&self) {
        self.state.borrow_mut().pending.clear();
    }

    fn flush_inner(
        &self,
        ep: &Endpoint,
        mns: &[Arc<MemNode>],
        rpc: &RpcFabric,
        src_cn: usize,
        slot: usize,
        horizon: Option<u64>,
    ) -> Result<()> {
        let mut guard = self.state.borrow_mut();
        let CoalesceState {
            pending,
            last_ring,
            kept,
            rpc_flush,
            ..
        } = &mut *guard;
        if pending.is_empty() {
            return Ok(());
        }
        // Satellite fix (ISSUE 9): when nothing parked is stale yet —
        // the common case on every scheduler step — leave `pending`
        // untouched instead of draining and rebuilding it.
        if let Some(h) = horizon {
            if pending
                .iter()
                .all(|(plan, pt)| pt.saturating_add(self.eff_window(plan)) >= h)
            {
                return Ok(());
            }
        }
        let mut merged = MergedBatch::new();
        let mut t0 = u64::MAX;
        // Stale RPC plans merge per destination CN, sent at the earliest
        // park time among them: `(dst, reqs, t0)`.
        rpc_flush.clear();
        debug_assert!(kept.is_empty(), "kept scratch leaked between flushes");
        for (plan, pt) in pending.drain(..) {
            let stale = match horizon {
                Some(h) => pt.saturating_add(self.eff_window(&plan)) < h,
                None => true,
            };
            if !stale {
                kept.push((plan, pt));
                continue;
            }
            match plan {
                Plan::Doorbell(b) => {
                    t0 = t0.min(pt);
                    merged.absorb(b);
                }
                Plan::Rpc { dst_cn, n_reqs } => {
                    match rpc_flush.iter_mut().find(|e| e.0 == dst_cn) {
                        Some(e) => {
                            e.1 += n_reqs;
                            e.2 = e.2.min(pt);
                        }
                        None => rpc_flush.push((dst_cn, n_reqs, pt)),
                    }
                }
            }
        }
        std::mem::swap(pending, kept);
        kept.clear();
        for &(dst, n, t_send) in rpc_flush.iter() {
            ep.gate_sync(&VClock(t_send));
            // Fire-and-forget: a failed destination drops the message
            // (recovery releases the failed CN's locks).
            let _ = rpc.send_async_at(src_cn, dst, slot, n, t_send);
        }
        if merged.n_plans() == 0 {
            return Ok(());
        }
        // Fire-and-forget: completions and results are discarded.
        merged.issue_timed(ep, mns, t0, |mn| {
            ride_or_ring(last_ring, mn, t0, self.window_db(mn))
        })?;
        Ok(())
    }
}

/// One lock held by a sibling transaction over a recorded **virtual
/// interval** `[from, until)`.
#[derive(Debug, Clone, Copy)]
pub struct LockStamp {
    /// Locked key.
    pub key: LotusKey,
    /// Held mode.
    pub mode: LockMode,
    /// Virtual time the holding transaction acquired it (live holdings
    /// record the exact acquisition; committed stamps inherit it through
    /// the unlock hand-off, falling back to the transaction's begin).
    pub from: u64,
    /// Virtual time the holding transaction released it (`u64::MAX` for
    /// a live holding still held by an in-flight lane).
    pub until: u64,
}

/// Read view over all lanes' recent lock intervals, excluding the asking
/// lane — the lock phase's local sibling-conflict check. Interval-aware:
/// a stamp conflicts only if its `[from, until)` interval covers `now`.
pub struct SiblingLocks<'a> {
    logs: &'a [Vec<LockStamp>],
    me: usize,
}

impl<'a> SiblingLocks<'a> {
    /// View for lane `me` over `logs` (one entry per lane).
    pub fn new(logs: &'a [Vec<LockStamp>], me: usize) -> Self {
        Self { logs, me }
    }

    /// Would acquiring `mode` on `key` at virtual time `now` conflict
    /// with a sibling lane's transaction whose recorded holding interval
    /// covers `now`?
    pub fn conflicts(&self, key: LotusKey, mode: LockMode, now: u64) -> bool {
        self.logs.iter().enumerate().any(|(i, log)| {
            i != self.me
                && log.iter().any(|s| {
                    s.key == key
                        && s.from <= now
                        && s.until > now
                        && (mode == LockMode::Write || s.mode == LockMode::Write)
                })
        })
    }
}

/// Transaction state machine of one lane (mirrors the sequential
/// coordinator's assertion states).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LanePhase {
    Idle,
    Building,
    Executed,
}

/// In-flight state of one lane (the continuation model's parking table):
/// the *only* channel between a parked lane machine and the event loop.
enum Flight {
    /// No plan in flight (lane idle, or machine mid-poll).
    Idle,
    /// A plan posted with its doorbell ring / RPC send deferred:
    /// `(plan, post virtual time)`. The lane machine is parked
    /// (`Poll::Pending`).
    Staged(Plan, u64),
    /// Doorbell rung; the lane is in the ready queue at `t_done`.
    Done {
        /// The lane's own results.
        res: BatchResult,
        /// Completion time of the lane's slowest op (its resume time).
        t_done: u64,
        /// The lane's clock while parked (its post time) — the frontier
        /// value until the machine resumes and catches up.
        t_post: u64,
        /// Ring event that completed this plan (resume-order tracing).
        ring: u64,
        /// `false` == an injected doorbell fault hit one of the lane's
        /// rings: the batch is lost and the lane must abort (PR 8).
        ok: bool,
    },
    /// RPC message sent (possibly merged with sibling lanes' messages);
    /// the lane is in the ready queue at `t_done`.
    RpcDone {
        /// Reply arrived (`false` == destination CN failed; the lane
        /// burned the UD timeout).
        ok: bool,
        /// Completion time of the lane's own handler chunk.
        t_done: u64,
        /// The lane's clock while parked (its post time).
        t_post: u64,
        /// Ring event that sent this message (resume-order tracing).
        ring: u64,
    },
    /// Parked waiting for the sibling holding `key` to release (the
    /// anachronistic-holder triage; `t` is the unchanged virtual time).
    WaitLock(LotusKey, u64),
    /// The wait ended: ready to retry the acquisition at time `t`.
    WaitOver(u64),
    /// Parked in retry backoff after a lost/timed-out lock RPC: the lane
    /// re-enters the ready queue at its backoff deadline `t` and
    /// reissues its message (ISSUE 7).
    RetryAt(u64),
    /// The event loop handed the lane a new transaction's start clock;
    /// the parked machine consumes it on its next poll ([`StartGate`]).
    StartTxn(u64),
    /// The perpetual lane machine ([`lane_loop`]) is parked between
    /// transactions, waiting for the loop to hand it a start clock.
    AwaitStart,
}

/// One resume-trace entry: `(ring event id, lane, completion time)` —
/// recorded when a `Flight::Done` lane is actually re-polled.
pub type ResumeTrace = (u64, usize, u64);

/// State shared between the event loop and the lane machines (via `Rc`):
/// the machines reach it as their [`StepSink`] conduit, the loop as
/// plain scheduler state. Single-threaded by construction; `RefCell`
/// borrows are confined to single calls and never held across polls.
struct SchedShared {
    cluster: Arc<SharedCluster>,
    cn: usize,
    slot: usize,
    global_id: usize,
    depth: usize,
    ep: Endpoint,
    coalescer: Option<Coalescer>,
    /// The parking table, one slot per lane.
    flights: RefCell<Vec<Flight>>,
    /// Per lane: lock intervals of its recently *committed* transactions
    /// (pruned once every lane's clock has passed them).
    lock_logs: RefCell<Vec<Vec<LockStamp>>>,
    /// Per lane: locks its in-flight transaction currently holds, as
    /// open intervals (`until == u64::MAX`).
    live_locks: RefCell<Vec<Vec<LockStamp>>>,
    /// Per lane: the live set of the lane's most recently released
    /// transaction (moved out of `live_locks` at unlock) — the per-key
    /// acquisition times the committed stamps are built from.
    released: RefCell<Vec<Vec<LockStamp>>>,
    /// Per lane: the machine's final clock, written just before it
    /// completes (explicit hand-back; never derived from outcomes).
    lane_end: RefCell<Vec<u64>>,
    /// Transactions completed by lane machines, drained by the loop.
    outcomes: RefCell<Vec<LaneOutcome>>,
    /// A fatal (run-ending) error raised inside a lane machine.
    fatal: RefCell<Option<Error>>,
    /// Virtual-time floor from coordinator-level skips (shard transfers
    /// charged while lanes are parked); resumed machines catch up to it.
    clk_floor: Cell<u64>,
    /// The workload the perpetual lane machines drive, installed on
    /// every [`FrameScheduler::step`] — so a caller may swap workloads
    /// between steps, exactly as the old per-transaction machines
    /// captured it at spawn.
    workload: RefCell<Option<Arc<dyn Workload>>>,
    /// Hybrid-routing flag of the current step's route context.
    hybrid: Cell<bool>,
}

impl StepSink for SchedShared {
    fn stages(&self) -> bool {
        self.coalescer.is_some()
    }

    fn flush_riders(&self, lane: usize, now: u64) -> Result<()> {
        let Some(c) = &self.coalescer else {
            return Ok(());
        };
        if c.pending_plans() == 0 {
            return Ok(());
        }
        // Ring parked riders out anchored at the (empty) caller's time;
        // the caller's own slice is empty and free.
        let mut rung = c.ring(
            &mut vec![(lane, OpBatch::new(), now)],
            &self.ep,
            &self.cluster.mns,
        )?;
        let _ = rung.pop();
        Ok(())
    }

    fn post(&self, lane: usize, plan: Plan, t_post: u64) {
        // The posted-WQE gauge tracks one-sided send-queue depth; RPC
        // plans are SEND messages on the UD QP and have their own
        // counters (`rpc_messages`/`rpc_reqs`).
        if let Plan::Doorbell(b) = &plan {
            self.ep.post_wqes(b.len() as u64);
        }
        self.flights.borrow_mut()[lane] = Flight::Staged(plan, t_post);
    }

    fn try_take(&self, lane: usize) -> Option<(BatchResult, u64, bool)> {
        let mut fl = self.flights.borrow_mut();
        if !matches!(fl[lane], Flight::Done { .. }) {
            return None;
        }
        match std::mem::replace(&mut fl[lane], Flight::Idle) {
            Flight::Done { res, t_done, ok, .. } => Some((res, t_done, ok)),
            _ => unreachable!(),
        }
    }

    fn try_take_rpc(&self, lane: usize) -> Option<(bool, u64)> {
        let mut fl = self.flights.borrow_mut();
        if let Flight::RpcDone { ok, t_done, .. } = fl[lane] {
            fl[lane] = Flight::Idle;
            Some((ok, t_done))
        } else {
            None
        }
    }

    fn issue_deferred(&self, _lane: usize, plan: Plan, clk: &mut VClock) -> Result<()> {
        match &self.coalescer {
            Some(c) => {
                c.defer(plan, clk.now());
                Ok(())
            }
            // No coalescer (depth 1 or window 0): nothing may park — the
            // fire-and-forget plan issues immediately.
            None => match plan {
                Plan::Doorbell(b) => b.issue_async(&self.ep, &self.cluster.mns, clk),
                Plan::Rpc { dst_cn, n_reqs } => {
                    self.ep.gate_sync(clk);
                    // Fire-and-forget: a failed destination is ignored
                    // (recovery releases the failed CN's locks, §6).
                    let _ = self
                        .cluster
                        .rpc
                        .call_async(self.cn, dst_cn, self.slot, n_reqs, clk);
                    Ok(())
                }
            },
        }
    }

    fn sibling_conflict(&self, lane: usize, key: LotusKey, mode: LockMode, now: u64) -> bool {
        if self.depth <= 1 {
            return false;
        }
        // Committed siblings' recorded intervals, plus parked siblings'
        // live holdings (open intervals, `until == u64::MAX`) — one
        // predicate for both, so the overlap rule cannot diverge. A
        // sibling that acquired only in this lane's virtual future is an
        // anachronism, not a conflict.
        let logs = self.lock_logs.borrow();
        if SiblingLocks::new(&logs, lane).conflicts(key, mode, now) {
            return true;
        }
        let live = self.live_locks.borrow();
        SiblingLocks::new(&live, lane).conflicts(key, mode, now)
    }

    fn note_lock(&self, lane: usize, key: LotusKey, mode: LockMode, now: u64) {
        if self.depth > 1 {
            self.live_locks.borrow_mut()[lane].push(LockStamp {
                key,
                mode,
                from: now,
                until: u64::MAX,
            });
        }
    }

    fn note_unlock_all(&self, lane: usize, now: u64) {
        if self.depth <= 1 {
            return;
        }
        let released: Vec<LotusKey> = {
            let mut live = self.live_locks.borrow_mut();
            let mut set = std::mem::take(&mut live[lane]);
            if set.is_empty() {
                // A later no-op release (e.g. a rollback after an abort
                // path already released) must not clobber the saved set.
                return;
            }
            let keys = set.iter().map(|s| s.key).collect();
            // Close the live intervals at the actual release time and
            // keep them for the committed stamping at transaction end —
            // the stamp must cover `[acquired, released)`, not the whole
            // transaction (a voluntary rollback mid-transaction frees
            // the locks well before the machine finishes).
            for s in &mut set {
                s.until = now;
            }
            self.released.borrow_mut()[lane] = set;
            keys
        };
        // Wake lanes parked on any of the released keys: they re-check
        // the (now free) lock at their unchanged virtual time. Each
        // wakeup is a lock-wait stat: the span between the waiter's park
        // time and this release is the anachronism the wait bridged.
        let mut fl = self.flights.borrow_mut();
        for f in fl.iter_mut() {
            if let Flight::WaitLock(k, t) = *f {
                if released.contains(&k) {
                    self.ep.nic.note_lock_wait(now.saturating_sub(t));
                    *f = Flight::WaitOver(t);
                }
            }
        }
    }

    fn wait_verdict(&self, lane: usize, key: LotusKey, mode: LockMode, now: u64) -> WaitVerdict {
        if self.depth <= 1 {
            return WaitVerdict::Abort;
        }
        // Wait only if (a) some sibling lane holds `key` in a conflicting
        // mode, (b) *every* such holding lies in our virtual future (one
        // genuine interval overlap means lock-first abort), and (c)
        // every conflicting holder is parked making progress — staged,
        // ready to resume, or woken from its own wait (`WaitOver` is in
        // the ready queue, not blocked) — never a lane that is itself
        // still blocked on a lock, which keeps the wait graph acyclic
        // and the event loop deadlock-free.
        let live = self.live_locks.borrow();
        let fl = self.flights.borrow();
        let mut any_holder = false;
        for (i, holdings) in live.iter().enumerate() {
            if i == lane {
                continue;
            }
            let mut holds_key = false;
            for s in holdings.iter().filter(|s| {
                s.key == key && (mode == LockMode::Write || s.mode == LockMode::Write)
            }) {
                holds_key = true;
                if s.from <= now {
                    return WaitVerdict::Abort; // genuine overlap
                }
            }
            if holds_key {
                any_holder = true;
                // A holder backing off before an RPC retry (RetryAt) is
                // progressing: it re-enters the ready queue at its
                // deadline on its own, exactly like WaitOver.
                if !matches!(
                    fl[i],
                    Flight::Staged(..)
                        | Flight::Done { .. }
                        | Flight::RpcDone { .. }
                        | Flight::WaitOver(..)
                        | Flight::RetryAt(..)
                ) {
                    return WaitVerdict::Abort;
                }
            }
        }
        if any_holder {
            WaitVerdict::Wait
        } else {
            WaitVerdict::Abort
        }
    }

    fn park_wait(&self, lane: usize, key: LotusKey, t: u64) {
        self.flights.borrow_mut()[lane] = Flight::WaitLock(key, t);
    }

    fn try_wait_over(&self, lane: usize) -> bool {
        let mut fl = self.flights.borrow_mut();
        if matches!(fl[lane], Flight::WaitOver(_)) {
            fl[lane] = Flight::Idle;
            true
        } else {
            false
        }
    }

    fn park_retry(&self, lane: usize, t: u64) {
        self.flights.borrow_mut()[lane] = Flight::RetryAt(t);
    }

    fn try_retry_over(&self, lane: usize) -> bool {
        let mut fl = self.flights.borrow_mut();
        if matches!(fl[lane], Flight::RetryAt(_)) {
            fl[lane] = Flight::Idle;
            true
        } else {
            false
        }
    }

    fn clk_floor(&self) -> u64 {
        self.clk_floor.get()
    }
}

/// Hands the lane's RNG back to the scheduler-side slot when the machine
/// ends — including when a fail-stop crash *drops* the machine mid-poll,
/// so the lane's RNG stream survives crashes exactly as it did when the
/// scheduler owned it directly.
struct RngReturn {
    rng: Option<Xoshiro256>,
    slot: Rc<RefCell<Option<Xoshiro256>>>,
}

impl Drop for RngReturn {
    fn drop(&mut self) {
        if let Some(rng) = self.rng.take() {
            *self.slot.borrow_mut() = Some(rng);
        }
    }
}

/// The [`TxnApi`]/[`TxnCtl`] view a lane machine drives for one
/// transaction: the frame, clock and RNG live *inside the machine*, and
/// every issue point parks through the shared conduit.
struct LaneApi<'s> {
    shared: &'s SchedShared,
    lane: usize,
    frame: TxnFrame,
    clk: VClock,
    rng: RngReturn,
    phase: LanePhase,
    /// READ-buffer scratch reused across doorbell rings; the machine is
    /// recycled across transactions (ISSUE 9), so the capacity is too
    /// (ROADMAP #4 follow-on (b)).
    pool: crate::dm::BufPool,
}

impl<'s> LaneApi<'s> {
    /// Split-borrow into a phase context + the lane's frame.
    fn parts(&mut self) -> (PhaseCtx<'_>, &mut TxnFrame) {
        let lane = self.lane;
        let shared = self.shared;
        let LaneApi {
            frame, clk, pool, ..
        } = self;
        (
            PhaseCtx {
                cluster: &shared.cluster,
                cn: shared.cn,
                slot: shared.slot,
                global_id: shared.global_id,
                ep: &shared.ep,
                clk,
                lane,
                sink: Some(shared),
                pool,
            },
            frame,
        )
    }
}

impl TxnCtl for LaneApi<'_> {
    fn add_ro(&mut self, r: RecordRef) {
        debug_assert_ne!(self.phase, LanePhase::Idle);
        self.frame.records.push(TxnRecord::new(r, false));
    }

    fn add_rw(&mut self, r: RecordRef) {
        debug_assert_ne!(self.phase, LanePhase::Idle);
        debug_assert!(!self.frame.read_only, "read-only txn cannot AddRW");
        self.frame.records.push(TxnRecord::new(r, true));
    }

    fn add_insert(&mut self, r: RecordRef, payload: Vec<u8>) {
        debug_assert_ne!(self.phase, LanePhase::Idle);
        debug_assert!(!self.frame.read_only);
        let mut rec = TxnRecord::new(r, true);
        rec.insert = true;
        rec.new_value = Some(payload);
        self.frame.records.push(rec);
    }

    fn add_delete(&mut self, r: RecordRef) {
        debug_assert_ne!(self.phase, LanePhase::Idle);
        let mut rec = TxnRecord::new(r, true);
        rec.delete = true;
        self.frame.records.push(rec);
    }

    fn execute(&mut self) -> Result<()> {
        unreachable!("pipelined lanes drive execute_step, never the blocking form")
    }

    fn execute_step(&mut self) -> StepFut<'_, Result<()>> {
        StepFut::from_future(async move {
            debug_assert_ne!(self.phase, LanePhase::Idle);
            let res = {
                let (mut ctx, frame) = self.parts();
                phases::execute(&mut ctx, frame).await
            };
            match res {
                Ok(()) => {
                    self.phase = LanePhase::Executed;
                    Ok(())
                }
                Err(e) => {
                    // The failing phase already released every held lock.
                    self.phase = LanePhase::Idle;
                    Err(e)
                }
            }
        })
    }

    fn value(&self, r: RecordRef) -> Option<&[u8]> {
        self.frame
            .find(r)
            .and_then(|i| self.frame.records[i].value.as_deref())
    }

    fn stage_write(&mut self, r: RecordRef, payload: Vec<u8>) {
        let i = self.frame.find(r).expect("stage_write on unknown record");
        debug_assert!(self.frame.records[i].write, "stage_write needs AddRW");
        self.frame.records[i].new_value = Some(payload);
    }

    fn commit(&mut self) -> Result<()> {
        unreachable!("pipelined lanes drive commit_step, never the blocking form")
    }

    fn commit_step(&mut self) -> StepFut<'_, Result<()>> {
        StepFut::from_future(async move {
            debug_assert_eq!(self.phase, LanePhase::Executed);
            let res = {
                let (mut ctx, frame) = self.parts();
                phases::commit_txn(&mut ctx, frame).await
            };
            self.phase = LanePhase::Idle;
            res
        })
    }

    fn rollback(&mut self) {
        let (mut ctx, frame) = self.parts();
        phases::unlock::release(&mut ctx, frame);
        self.phase = LanePhase::Idle;
    }
}

impl TxnApi for LaneApi<'_> {
    fn begin(&mut self, read_only: bool) {
        let shared = self.shared;
        phases::begin(&shared.cluster, &mut self.clk, &mut self.frame, read_only);
        self.phase = LanePhase::Building;
    }

    fn txn(&mut self) -> &mut dyn TxnCtl {
        self
    }

    fn now(&self) -> u64 {
        self.clk.now()
    }

    fn rng(&mut self) -> &mut Xoshiro256 {
        self.rng.rng.as_mut().expect("lane RNG present while running")
    }

    fn cn(&self) -> usize {
        self.shared.cn
    }

    fn attach_gate(&mut self, _gate: Arc<TimeGate>, _gid: usize) {
        // The gate is attached at scheduler level (shared endpoint).
    }

    fn crash(&mut self) {
        self.frame.crash();
        self.phase = LanePhase::Idle;
    }

    fn skip_to(&mut self, t_ns: u64) {
        self.clk.catch_up(t_ns);
    }
}

/// Wakes a perpetual lane machine for its next transaction: pends until
/// the event loop hands a start clock through [`Flight::StartTxn`],
/// resolving to that clock. While pending the lane parks as
/// [`Flight::AwaitStart`] — the between-transactions state the loop
/// treats exactly like an idle (machineless) lane.
struct StartGate<'s> {
    shared: &'s SchedShared,
    lane: usize,
}

impl Future for StartGate<'_> {
    type Output = u64;

    fn poll(self: Pin<&mut Self>, _cx: &mut Context<'_>) -> Poll<u64> {
        let mut fl = self.shared.flights.borrow_mut();
        match fl[self.lane] {
            Flight::StartTxn(t) => {
                fl[self.lane] = Flight::Idle;
                Poll::Ready(t)
            }
            _ => {
                fl[self.lane] = Flight::AwaitStart;
                Poll::Pending
            }
        }
    }
}

/// A lane's perpetual transaction machine: parks on [`StartGate`]
/// between transactions and runs one workload transaction per hand-off,
/// reusing one [`LaneApi`] — and with it the lane's [`TxnFrame`] buffers
/// and RNG hand-back — across transactions, so steady-state scheduling
/// recycles the machine instead of boxing a fresh one per transaction
/// (ISSUE 9). All effects (outcomes, committed lock stamps, fatal
/// errors) flow through the shared state; the machine ends only on a
/// fatal error (or by being dropped on crash/shutdown, which hands the
/// RNG back through [`RngReturn`]).
async fn lane_loop(
    shared: Rc<SchedShared>,
    lane: usize,
    rng_slot: Rc<RefCell<Option<Xoshiro256>>>,
) {
    let rng = rng_slot
        .borrow_mut()
        .take()
        .expect("lane RNG free at machine start");
    let mut api = LaneApi {
        shared: &shared,
        lane,
        frame: TxnFrame::new(),
        clk: VClock::zero(),
        rng: RngReturn {
            rng: Some(rng),
            slot: rng_slot,
        },
        phase: LanePhase::Idle,
        pool: crate::dm::BufPool::new(),
    };
    loop {
        let clk0 = StartGate {
            shared: &shared,
            lane,
        }
        .await;
        api.clk = VClock(clk0);
        api.phase = LanePhase::Idle;
        let workload = shared
            .workload
            .borrow()
            .clone()
            .expect("workload installed before a lane starts");
        let route = RouteCtx {
            router: &shared.cluster.router,
            cn: shared.cn,
            hybrid: shared.hybrid.get(),
        };
        let res = workload.run_one(&mut api, &route).await;
        let t_end = api.clk.now();
        // Explicit clock hand-back: the scheduler reads this on completion
        // instead of deriving it from the outcome queue.
        shared.lane_end.borrow_mut()[lane] = t_end;
        // Remember a *committed* transaction's lock set for the sibling
        // conflict check: any lane pumped later whose virtual time falls
        // inside a lock's actual holding interval `[acquired, released)`
        // must see it as held (the lock set is a pure function of the still-
        // intact record set; acquisition AND release times were preserved by
        // the unlock hand-off — a transaction that voluntarily rolled back
        // and still returned Ok stamps only up to its rollback, not to the
        // machine's end). Failed transactions are not stamped — they
        // released whatever they briefly held, and stamping them would
        // cascade phantom aborts between siblings.
        let released = std::mem::take(&mut shared.released.borrow_mut()[lane]);
        if shared.depth > 1 && res.is_ok() {
            let frame = &api.frame;
            if !frame.read_only && !frame.records.is_empty() {
                let mut logs = shared.lock_logs.borrow_mut();
                for (key, mode) in phases::lock::requests(&shared.cluster, frame, 0) {
                    let from = released
                        .iter()
                        .filter(|s| s.key == key)
                        .map(|s| s.from)
                        .min()
                        .unwrap_or(clk0);
                    let until = released
                        .iter()
                        .filter(|s| s.key == key)
                        .map(|s| s.until)
                        .max()
                        .unwrap_or(t_end);
                    logs[lane].push(LockStamp {
                        key,
                        mode,
                        from,
                        until,
                    });
                }
            }
        }
        match res {
            Err(e) if !(e.is_abort() || matches!(e, Error::NodeUnavailable(_))) => {
                *shared.fatal.borrow_mut() = Some(e);
                return;
            }
            result => shared.outcomes.borrow_mut().push(LaneOutcome {
                lane,
                t_begin: clk0,
                t_end,
                result,
            }),
        }
    }
}

/// One concurrent transaction stream within a scheduler: the (possibly
/// parked) perpetual machine plus the state that outlives machines —
/// the clock snapshot between transactions and the RNG slot (lane 0's
/// RNG stream equals the sequential coordinator's, anchoring the
/// depth-1 equivalence).
struct Lane {
    /// The lane's [`lane_loop`] machine, boxed once and recycled across
    /// transactions; `None` before the first transaction and after a
    /// crash dropped it.
    task: Option<StepFut<'static, ()>>,
    /// Virtual clock between transactions (valid while `task` is None
    /// or the machine is parked at [`Flight::AwaitStart`]).
    clk: u64,
    /// RNG slot: `Some` between transactions, taken by a running
    /// machine, handed back on machine end or drop ([`RngReturn`]).
    rng: Rc<RefCell<Option<Xoshiro256>>>,
}

/// `pipeline_depth` concurrent transaction streams multiplexed onto one
/// coordinator thread by a flat ready-queue event loop (see the module
/// docs). Replaces the sequential coordinator inside [`crate::sim`]'s
/// `coordinator_thread` for LOTUS runs with `pipeline_depth >= 1`.
pub struct FrameScheduler {
    shared: Rc<SchedShared>,
    lanes: Vec<Lane>,
    /// Monotone ring-event counter (resume-order tracing).
    ring_seq: u64,
    trace_on: bool,
    trace: Vec<ResumeTrace>,
    /// The no-op waker, built once — machine readiness lives in the
    /// in-flight table, never in a reactor.
    waker: Waker,
    /// Reusable ring-staged scratch (ISSUE 9): plan buffers handed to
    /// the coalescer (which drains them in place) and the per-ring
    /// owner→post-time table, so steady-state rings allocate nothing.
    db_scratch: Vec<(usize, OpBatch, u64)>,
    rpc_scratch: Vec<(usize, usize, usize, u64)>,
    posts_scratch: Vec<(usize, u64)>,
}

impl FrameScheduler {
    /// Scheduler for coordinator `slot` on CN `cn` with `depth` lanes.
    /// Staging + coalescing activate for `depth >= 2` when
    /// `coalesce_window_ns` is non-zero; `depth == 1` reproduces the
    /// sequential coordinator exactly.
    pub fn new(cluster: Arc<SharedCluster>, cn: usize, slot: usize, global_id: usize) -> Self {
        let depth = cluster.cfg.pipeline_depth.max(1);
        let window = cluster.cfg.coalesce_window_ns;
        let ep = Endpoint::new(cn, cluster.cn_nics[cn].clone(), cluster.net.clone())
            .with_faults(cluster.doorbell_faults.clone());
        let seed = cluster.cfg.seed ^ (global_id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let shared = Rc::new(SchedShared {
            cn,
            slot,
            global_id,
            depth,
            ep,
            coalescer: (depth > 1 && window > 0).then(|| {
                if cluster.cfg.adaptive_coalescing {
                    Coalescer::adaptive(window)
                } else {
                    Coalescer::new(window)
                }
            }),
            flights: RefCell::new((0..depth).map(|_| Flight::Idle).collect()),
            lock_logs: RefCell::new((0..depth).map(|_| Vec::new()).collect()),
            live_locks: RefCell::new((0..depth).map(|_| Vec::new()).collect()),
            released: RefCell::new((0..depth).map(|_| Vec::new()).collect()),
            lane_end: RefCell::new(vec![0; depth]),
            outcomes: RefCell::new(Vec::new()),
            fatal: RefCell::new(None),
            clk_floor: Cell::new(0),
            workload: RefCell::new(None),
            hybrid: Cell::new(false),
            cluster,
        });
        let lanes = (0..depth)
            .map(|i| Lane {
                task: None,
                clk: 0,
                // Lane 0 keeps the sequential coordinator's seed.
                rng: Rc::new(RefCell::new(Some(Xoshiro256::new(
                    seed ^ (i as u64).wrapping_mul(0xA076_1D64_78BD_642F),
                )))),
            })
            .collect();
        Self {
            shared,
            lanes,
            ring_seq: 0,
            trace_on: false,
            trace: Vec::new(),
            waker: noop_waker(),
            db_scratch: Vec::new(),
            rpc_scratch: Vec::new(),
            posts_scratch: Vec::new(),
        }
    }

    /// Number of lanes (the configured pipeline depth).
    pub fn depth(&self) -> usize {
        self.lanes.len()
    }

    /// Record `(ring id, lane, completion time)` for every resumed lane
    /// (test instrumentation for the completion-clock-order invariant).
    pub fn enable_resume_trace(&mut self) {
        self.trace_on = true;
    }

    /// The recorded resume trace (empty unless enabled).
    pub fn resume_trace(&self) -> &[ResumeTrace] {
        &self.trace
    }

    /// The scheduler's frontier: the slowest lane's virtual clock —
    /// parked lanes count at their park time. This is what the run loop
    /// compares against the duration and publishes to the [`TimeGate`]
    /// between transactions.
    pub fn now(&self) -> u64 {
        let fl = self.shared.flights.borrow();
        (0..self.lanes.len())
            .map(|i| {
                if self.lanes[i].task.is_none() {
                    self.lanes[i].clk
                } else {
                    match &fl[i] {
                        // A RetryAt lane counts at its backoff deadline:
                        // on resume it catches its clock up to the
                        // deadline before doing anything else, so it can
                        // never charge earlier than that again.
                        Flight::Staged(_, t)
                        | Flight::WaitLock(_, t)
                        | Flight::WaitOver(t)
                        | Flight::RetryAt(t)
                        | Flight::StartTxn(t) => *t,
                        Flight::Done { t_post, .. } | Flight::RpcDone { t_post, .. } => *t_post,
                        // A machine parked between transactions counts
                        // at the lane clock, exactly like a machineless
                        // lane.
                        Flight::AwaitStart | Flight::Idle => self.lanes[i].clk,
                    }
                }
            })
            .min()
            .unwrap_or(u64::MAX)
    }

    /// Attach the run's time gate to the shared endpoint. Must run
    /// before the first step (no lane machine may exist yet).
    pub fn attach_gate(&mut self, gate: Arc<TimeGate>, gid: usize) {
        Rc::get_mut(&mut self.shared)
            .expect("attach_gate before the first step")
            .ep
            .attach_gate(gate, gid);
    }

    /// Fail-stop: every lane machine is dropped without releasing locks
    /// (recovery owns them, paper §6). Staged plans are WQEs posted but
    /// never rung — they die with the CN (the posted gauge is drained); a
    /// committed transaction's un-cleared log slot is completed
    /// idempotently by recovery's log scan. Each dropped machine hands
    /// its RNG stream back to the lane ([`RngReturn`]).
    pub fn crash(&mut self) {
        if let Some(c) = &self.shared.coalescer {
            c.discard_pending();
        }
        for f in self.shared.flights.borrow_mut().iter_mut() {
            if let Flight::Staged(plan, _) = std::mem::replace(f, Flight::Idle) {
                // Only doorbell plans hold posted-WQE gauge depth; a
                // staged RPC message simply dies with the CN.
                if let Plan::Doorbell(b) = plan {
                    self.shared.ep.ring_posted(b.len() as u64);
                }
            }
        }
        for lane in &mut self.lanes {
            lane.task = None; // drops the machine; RngReturn restores the RNG
            debug_assert!(lane.rng.borrow().is_some(), "crashed lane lost its RNG");
        }
        for log in self.shared.lock_logs.borrow_mut().iter_mut() {
            log.clear();
        }
        for live in self.shared.live_locks.borrow_mut().iter_mut() {
            live.clear();
        }
        for rel in self.shared.released.borrow_mut().iter_mut() {
            rel.clear();
        }
        self.shared.outcomes.borrow_mut().clear();
        *self.shared.fatal.borrow_mut() = None;
    }

    /// Orderly end of run: drain every in-flight lane machine to
    /// completion (no new transactions start; staged plans ring as their
    /// windows close), appending the finished transactions' outcomes to
    /// `out`, then ring out every parked fire-and-forget plan so no
    /// planned op (or its NIC charge) is silently dropped at the
    /// duration boundary.
    pub fn finish(&mut self, out: &mut Vec<LaneOutcome>) -> Result<()> {
        // A lane drains while its machine is mid-transaction; a
        // perpetual machine parked between transactions (`AwaitStart`)
        // is idle and is never polled here — the drain must not start
        // new transactions.
        loop {
            let busy = {
                let fl = self.shared.flights.borrow();
                self.lanes
                    .iter()
                    .enumerate()
                    .any(|(i, l)| l.task.is_some() && !matches!(fl[i], Flight::AwaitStart))
            };
            if !busy {
                break;
            }
            if let Some((li, _, _)) = self.next_runnable(false) {
                self.poll_lane(li)?;
            } else if let Some(t_init) = self.staged_min() {
                self.ring_staged(t_init)?;
            } else {
                unreachable!("scheduler drain stalled: in-flight lanes but nothing runnable");
            }
            out.append(&mut self.shared.outcomes.borrow_mut());
        }
        if let Some(c) = &self.shared.coalescer {
            c.flush_all(
                &self.shared.ep,
                &self.shared.cluster.mns,
                &self.shared.cluster.rpc,
                self.shared.cn,
                self.shared.slot,
            )?;
        }
        Ok(())
    }

    /// Jump the scheduler's virtual time forward: idle lanes catch up
    /// immediately; parked machines (whose clocks live inside the
    /// machine) catch up to the recorded floor at their next resume
    /// point. Used by the crash-restart path (all lanes idle after
    /// `crash`) and by the load balancer to charge shard-transfer time.
    pub fn skip_to(&mut self, t_ns: u64) {
        let floor = self.shared.clk_floor.get().max(t_ns);
        self.shared.clk_floor.set(floor);
        let fl = self.shared.flights.borrow();
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            // A machine parked between transactions is an idle lane:
            // its authoritative clock is the lane's, so it catches up
            // directly (a mid-transaction machine catches up to the
            // floor at its next resume point instead).
            let idle = lane.task.is_none() || matches!(fl[i], Flight::AwaitStart);
            if idle && lane.clk < t_ns {
                lane.clk = t_ns;
            }
        }
    }

    /// The oldest staged plan's post time, if any plan is staged.
    fn staged_min(&self) -> Option<u64> {
        self.shared
            .flights
            .borrow()
            .iter()
            .filter_map(|f| match f {
                Flight::Staged(_, t) => Some(*t),
                _ => None,
            })
            .min()
    }

    /// The earliest merge deadline among staged plans: each plan may wait
    /// until `post + eff_window(plan)` for siblings to merge with it.
    /// Under the fixed policy this is exactly `staged_min + window`;
    /// under the adaptive policy a latency-bound destination's shrunken
    /// window pulls its plans' deadline earlier (toward direct issue)
    /// while an IOPS-bound destination's widened window lets its plans
    /// wait longer for company.
    fn staged_deadline(&self) -> Option<u64> {
        let c = self.shared.coalescer.as_ref()?;
        self.shared
            .flights
            .borrow()
            .iter()
            .filter_map(|f| match f {
                Flight::Staged(plan, t) => Some(t.saturating_add(c.eff_window(plan))),
                _ => None,
            })
            .min()
    }

    /// The runnable lane with the smallest virtual time:
    /// `(lane, time, starts_new_transaction)`. Ready (Done / WaitOver)
    /// lanes win ties against idle lanes at the same time. With
    /// `include_idle` false, idle lanes are not candidates at all (the
    /// end-of-run drain must resume parked machines, never start new
    /// transactions — an idle lane with the smallest clock must not mask
    /// a resumable sibling).
    fn next_runnable(&self, include_idle: bool) -> Option<(usize, u64, bool)> {
        let fl = self.shared.flights.borrow();
        let mut best: Option<(u64, u8, usize, bool)> = None;
        for (i, lane) in self.lanes.iter().enumerate() {
            let cand = if lane.task.is_some() {
                match &fl[i] {
                    Flight::Done { t_done, .. } | Flight::RpcDone { t_done, .. } => {
                        Some((*t_done, 0u8, false))
                    }
                    Flight::WaitOver(t) => Some((*t, 0, false)),
                    // Backoff served in clock order: the lane re-enters
                    // the ready queue at its deadline.
                    Flight::RetryAt(t) => Some((*t, 0, false)),
                    // A machine parked between transactions is an idle
                    // lane: it may only be woken to start a transaction.
                    Flight::AwaitStart => include_idle.then_some((lane.clk, 1, true)),
                    _ => None,
                }
            } else if include_idle {
                Some((lane.clk, 1, true))
            } else {
                None
            };
            if let Some((t, pref, start)) = cand {
                let better = match best {
                    None => true,
                    Some((bt, bp, bi, _)) => (t, pref, i) < (bt, bp, bi),
                };
                if better {
                    best = Some((t, pref, i, start));
                }
            }
        }
        best.map(|(t, _, i, start)| (i, t, start))
    }

    /// Ring every staged plan within `coalesce_window_ns` of the oldest
    /// post time `t_init`: doorbell plans merge into one doorbell set
    /// per MN (plus parked doorbell riders) and complete as
    /// [`Flight::Done`]; RPC plans merge into one message per
    /// destination CN (plus parked unlock riders) and complete as
    /// [`Flight::RpcDone`] — every owner re-enters the ready queue at
    /// its own completion time. Staged plans outside the window stay
    /// staged for a later round.
    fn ring_staged(&mut self, t_init: u64) -> Result<()> {
        let shared = &self.shared;
        let c = shared
            .coalescer
            .as_ref()
            .expect("staged plans require a coalescer");
        let db_plans = &mut self.db_scratch;
        let rpc_plans = &mut self.rpc_scratch;
        db_plans.clear();
        rpc_plans.clear();
        {
            let mut fl = shared.flights.borrow_mut();
            for (i, f) in fl.iter_mut().enumerate() {
                // A staged plan joins the ring anchored at `t_init` (the
                // oldest post) if its own effective window reaches back
                // to it; a direct-issue (window 0) plan only rings when
                // it IS the anchor.
                let take = match &*f {
                    Flight::Staged(plan, t) => *t <= t_init.saturating_add(c.eff_window(plan)),
                    _ => false,
                };
                if take {
                    if let Flight::Staged(plan, t) = std::mem::replace(f, Flight::Idle) {
                        match plan {
                            Plan::Doorbell(b) => db_plans.push((i, b, t)),
                            Plan::Rpc { dst_cn, n_reqs } => {
                                rpc_plans.push((i, dst_cn, n_reqs, t))
                            }
                        }
                    }
                }
            }
        }
        if db_plans.is_empty() && rpc_plans.is_empty() {
            return Ok(());
        }
        self.ring_seq += 1;
        let ring = self.ring_seq;
        if !db_plans.is_empty() {
            let posted: u64 = db_plans.iter().map(|(_, b, _)| b.len() as u64).sum();
            let t_ring = db_plans.iter().map(|p| p.2).max().unwrap_or(t_init);
            let gap: u64 = db_plans.iter().map(|p| t_ring - p.2).sum();
            let posts = &mut self.posts_scratch;
            posts.clear();
            posts.extend(db_plans.iter().map(|(i, _, t)| (*i, *t)));
            let n_plans = db_plans.len() as u64;
            // Both sides of the issue boundary are crash-sweep points:
            // the ring time (WQEs posted, doorbell about to fire) and
            // each completion (results back, machine not yet resumed).
            shared.cluster.ring_trace.record(shared.cn, t_ring);
            let results = c.ring(db_plans, &shared.ep, &shared.cluster.mns)?;
            shared.ep.ring_posted(posted);
            shared.ep.nic.note_resumed(n_plans, gap);
            let mut fl = shared.flights.borrow_mut();
            for (lane, res, t_done, ok) in results {
                // Every result owner came from the plans; a miss here is
                // a routing bug and must not be papered over.
                let t_post = posts
                    .iter()
                    .find(|(l, _)| *l == lane)
                    .map(|&(_, t)| t)
                    .expect("ring returned a result for a lane that staged no plan");
                shared.cluster.ring_trace.record(shared.cn, t_done);
                fl[lane] = Flight::Done {
                    res,
                    t_done,
                    t_post,
                    ring,
                    ok,
                };
            }
        }
        if !rpc_plans.is_empty() {
            let posts = &mut self.posts_scratch;
            posts.clear();
            posts.extend(rpc_plans.iter().map(|p| (p.0, p.3)));
            let results =
                c.ring_rpc(rpc_plans, &shared.cluster.rpc, shared.cn, shared.slot, &shared.ep);
            let mut fl = shared.flights.borrow_mut();
            for (lane, ok, t_done) in results {
                let t_post = posts
                    .iter()
                    .find(|(l, _)| *l == lane)
                    .map(|&(_, t)| t)
                    .expect("rpc ring returned a result for a lane that staged no plan");
                fl[lane] = Flight::RpcDone {
                    ok,
                    t_done,
                    t_post,
                    ring,
                };
            }
        }
        Ok(())
    }

    /// Poll lane `li`'s machine once; harvest completion and fatal
    /// errors.
    fn poll_lane(&mut self, li: usize) -> Result<()> {
        if self.trace_on {
            let entry = match &self.shared.flights.borrow()[li] {
                Flight::Done { t_done, ring, .. } | Flight::RpcDone { t_done, ring, .. } => {
                    Some((*ring, li, *t_done))
                }
                _ => None,
            };
            if let Some(e) = entry {
                self.trace.push(e);
            }
        }
        let mut cx = Context::from_waker(&self.waker);
        let task = self.lanes[li].task.as_mut().expect("polled lane has a machine");
        match Pin::new(task).poll(&mut cx) {
            Poll::Ready(()) => {
                self.lanes[li].task = None;
                self.lanes[li].clk = self.shared.lane_end.borrow()[li];
                debug_assert!(
                    matches!(self.shared.flights.borrow()[li], Flight::Idle),
                    "finished lane left a parked flight"
                );
            }
            Poll::Pending => {
                if matches!(self.shared.flights.borrow()[li], Flight::AwaitStart) {
                    // The perpetual machine completed a transaction and
                    // parked for the next start: harvest its final
                    // clock into the lane (the recycled-machine
                    // equivalent of the old machine-end harvest above).
                    self.lanes[li].clk = self.shared.lane_end.borrow()[li];
                } else {
                    debug_assert!(
                        matches!(
                            self.shared.flights.borrow()[li],
                            Flight::Staged(..) | Flight::WaitLock(..) | Flight::RetryAt(..)
                        ),
                        "a parked lane must be staged, lock-waiting, backing off, \
                         or awaiting a start"
                    );
                }
            }
        }
        if let Some(e) = self.shared.fatal.borrow_mut().take() {
            return Err(e);
        }
        Ok(())
    }

    /// Run the ready-queue event loop until at least one transaction
    /// completes, appending every finished transaction's
    /// [`LaneOutcome`] to `out`. The returned `Err` is a fatal
    /// (run-ending) error only.
    ///
    /// Parked lanes persist across calls: a step may resume machines
    /// parked by earlier steps, and may leave newly parked machines
    /// behind for later steps (or [`FrameScheduler::finish`]).
    pub fn step(
        &mut self,
        workload: &Arc<dyn Workload>,
        route: &RouteCtx<'_>,
        out: &mut Vec<LaneOutcome>,
    ) -> Result<()> {
        debug_assert_eq!(route.cn, self.shared.cn, "route context for another CN");
        // Lane machines build their own RouteCtx from the cluster router
        // (they outlive this call); a caller passing a different router
        // would be silently ignored — reject it loudly instead.
        debug_assert!(
            std::ptr::eq(route.router, &*self.shared.cluster.router),
            "route context carries a router other than the cluster's"
        );
        // Install this step's workload for the perpetual lane machines
        // (a refcount bump, not an allocation).
        *self.shared.workload.borrow_mut() = Some(workload.clone());
        self.shared.hybrid.set(route.hybrid);
        let t0 = self.now();
        // Ring out parked plans no doorbell came along for, and drop
        // committed sibling lock intervals every lane has passed.
        if let Some(c) = &self.shared.coalescer {
            c.flush_stale(
                &self.shared.ep,
                &self.shared.cluster.mns,
                &self.shared.cluster.rpc,
                self.shared.cn,
                self.shared.slot,
                t0,
            )?;
        }
        for log in self.shared.lock_logs.borrow_mut().iter_mut() {
            log.retain(|s| s.until > t0);
        }
        loop {
            let cand = self.next_runnable(true);
            let staged_min = self.staged_min();
            // Ring when a staged plan cannot wait for the next runnable
            // lane: either nothing is runnable, or the next runnable lane
            // lies beyond the earliest staged plan's merge deadline
            // (`post + eff_window` — per destination under the adaptive
            // policy, `staged_min + window` under the fixed one).
            let ring_now = match (&cand, staged_min) {
                (None, Some(_)) => true,
                (Some((_, t, _)), Some(_)) => {
                    *t > self.staged_deadline().expect("staged implies a deadline")
                }
                _ => false,
            };
            if ring_now {
                self.ring_staged(staged_min.expect("ring without staged plans"))?;
                continue;
            }
            let Some((li, _t, start_new)) = cand else {
                unreachable!("scheduler stalled: no runnable lane and nothing staged");
            };
            if start_new {
                // The lane's machine is boxed once and recycled: later
                // transactions reuse the parked machine, handed their
                // start clock through the parking table (ISSUE 9).
                if self.lanes[li].task.is_none() {
                    let machine =
                        lane_loop(self.shared.clone(), li, self.lanes[li].rng.clone());
                    self.lanes[li].task = Some(StepFut::from_future(machine));
                }
                self.shared.flights.borrow_mut()[li] = Flight::StartTxn(self.lanes[li].clk);
            }
            self.poll_lane(li)?;
            let mut done = self.shared.outcomes.borrow_mut();
            if !done.is_empty() {
                out.append(&mut done);
                return Ok(());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::dm::netconfig::NetConfig;
    use crate::dm::rnic::Rnic;
    use crate::sim::Cluster;
    use crate::txn::log::LogRecord;
    use crate::workloads::WorkloadKind;

    fn setup() -> (Vec<Arc<MemNode>>, Endpoint) {
        let mns = vec![Arc::new(MemNode::new(0, 1 << 16))];
        let ep = Endpoint::new(0, Arc::new(Rnic::new()), Arc::new(NetConfig::default()));
        (mns, ep)
    }

    /// Like [`setup`], plus an RPC fabric sharing the endpoint's CN NIC
    /// (CN 0 is the source, as in a real scheduler).
    fn rpc_setup(n_cns: usize) -> (Vec<Arc<MemNode>>, Endpoint, Arc<RpcFabric>) {
        let mns = vec![Arc::new(MemNode::new(0, 1 << 16))];
        let net = Arc::new(NetConfig::default());
        let nics: Vec<Arc<Rnic>> = (0..n_cns).map(|_| Arc::new(Rnic::new())).collect();
        let ep = Endpoint::new(0, nics[0].clone(), net.clone());
        let rpc = Arc::new(RpcFabric::new(nics, 1, net));
        (mns, ep, rpc)
    }

    #[test]
    fn deferred_plan_rides_the_next_staged_ring() {
        let (mns, ep) = setup();
        let r = mns[0].register(64).unwrap();
        let c = Coalescer::new(5_000);

        // A frame parks a fire-and-forget write...
        let mut park = OpBatch::new();
        park.write(0, r.base, 7u64.to_le_bytes().to_vec());
        c.defer(Plan::Doorbell(park), 100);
        assert_eq!(c.pending_plans(), 1);

        // ...and another frame's staged read rings within the window.
        let mut sync = OpBatch::new();
        let tag = sync.read(0, r.base, 8);
        let mut out = c.ring(&mut vec![(0, sync, 600)], &ep, &mns).unwrap();
        let (owner, res, done, ok) = out.pop().unwrap();

        assert_eq!(owner, 0);
        assert!(ok, "no injector: the ring cannot fault");
        assert_eq!(c.pending_plans(), 0, "the parked plan rode along");
        assert_eq!(ep.nic.doorbells(), 1, "one merged ring, not two");
        assert_eq!(ep.nic.coalesced_ops(), 1, "the parked write was a rider");
        // The parked write executed before the rider's read in the same
        // doorbell group.
        assert_eq!(res.read_buf(tag), &7u64.to_le_bytes()[..]);
        assert_eq!(mns[0].load_u64(r.base).unwrap(), 7);
        assert!(done >= 600 + ep.net.rtt_ns, "sync caller waited its RTT");
    }

    #[test]
    fn staged_sibling_plans_share_one_doorbell_ring() {
        // The continuation model's payoff in miniature: two lanes' staged
        // sync plans to one MN ring a single doorbell, each lane gets its
        // own results, and the overlap counters see the merge.
        let (mns, ep) = setup();
        let r = mns[0].register(128).unwrap();
        mns[0].store_u64(r.base, 11).unwrap();
        mns[0].store_u64(r.base + 8, 22).unwrap();
        let c = Coalescer::new(5_000);
        let mut a = OpBatch::new();
        let ta = a.read(0, r.base, 8);
        let mut b = OpBatch::new();
        let tb = b.read(0, r.base + 8, 8);

        let mut out = c
            .ring(&mut vec![(0, a, 1_000), (1, b, 1_400)], &ep, &mns)
            .unwrap();
        assert_eq!(ep.nic.doorbells(), 1, "two frames, one MN, one doorbell");
        assert_eq!(ep.nic.overlap_rings(), 1);
        assert_eq!(ep.nic.overlap_plans(), 2);
        assert_eq!(ep.nic.coalesced_ops(), 1, "the later plan's op rode");
        let (l1, r1, d1, ok1) = out.pop().unwrap();
        let (l0, r0, d0, ok0) = out.pop().unwrap();
        assert_eq!((l0, l1), (0, 1), "results route back per owner");
        assert!(ok0 && ok1, "no injector: neither owner faulted");
        assert_eq!(r0.read_buf(ta), &11u64.to_le_bytes()[..]);
        assert_eq!(r1.read_buf(tb), &22u64.to_le_bytes()[..]);
        // The ring fires at the latest post time; the earlier-posted
        // plan's op is served first.
        assert!(d0 >= 1_400 + ep.net.rtt_ns, "d0={d0}");
        assert!(d1 >= d0, "FIFO completions: d0={d0} d1={d1}");
    }

    #[test]
    fn stale_deferred_plan_rings_its_own_doorbell_on_flush() {
        let (mns, ep, rpc) = rpc_setup(1);
        let r = mns[0].register(64).unwrap();
        let c = Coalescer::new(1_000);
        let mut park = OpBatch::new();
        park.write(0, r.base, 9u64.to_le_bytes().to_vec());
        c.defer(Plan::Doorbell(park), 100);

        // Horizon still inside the window: nothing flushes.
        c.flush_stale(&ep, &mns, &rpc, 0, 0, 900).unwrap();
        assert_eq!(c.pending_plans(), 1);
        assert_eq!(ep.nic.doorbells(), 0);

        // Window expired: the plan rings out fire-and-forget.
        c.flush_stale(&ep, &mns, &rpc, 0, 0, 5_000).unwrap();
        assert_eq!(c.pending_plans(), 0);
        assert_eq!(ep.nic.doorbells(), 1);
        assert_eq!(mns[0].load_u64(r.base).unwrap(), 9);
    }

    #[test]
    fn parked_plan_just_before_finish_flushes_exactly_once() {
        // ISSUE 3 regression: a fire-and-forget plan parked right before
        // `finish()` must be flushed exactly once and charged to the
        // right NIC counters — later flush calls must not re-issue it.
        let (mns, ep, rpc) = rpc_setup(1);
        let r = mns[0].register(64).unwrap();
        let c = Coalescer::new(5_000);
        let mut park = OpBatch::new();
        // Non-idempotent op: a double flush would be visible in memory.
        park.faa(0, r.base, 1);
        c.defer(Plan::Doorbell(park), 4_900);

        // End-of-run flush (what `FrameScheduler::finish` runs).
        c.flush_all(&ep, &mns, &rpc, 0, 0).unwrap();
        assert_eq!(c.pending_plans(), 0);
        assert_eq!(mns[0].load_u64(r.base).unwrap(), 1, "applied exactly once");
        assert_eq!(ep.nic.doorbells(), 1, "one doorbell for the flush");
        assert_eq!(ep.nic.doorbell_ops(), 1);
        assert_eq!(ep.nic.coalesced_ops(), 0, "own ring, not a rider");

        // Any further flush — stale-horizon or full — is a no-op.
        c.flush_stale(&ep, &mns, &rpc, 0, 0, u64::MAX).unwrap();
        c.flush_all(&ep, &mns, &rpc, 0, 0).unwrap();
        assert_eq!(mns[0].load_u64(r.base).unwrap(), 1, "no double flush");
        assert_eq!(ep.nic.doorbells(), 1, "no extra doorbell charged");
    }

    #[test]
    fn staged_rpc_plans_to_one_cn_share_one_message() {
        // The RPC-plane mirror of the doorbell merge: two lanes' staged
        // lock batches to the same destination CN send ONE message, each
        // lane resumes at its own handler completion, and the later
        // lane's requests count as coalesced riders.
        let (_mns, ep, rpc) = rpc_setup(2);
        let c = Coalescer::new(5_000);
        let out = c.ring_rpc(
            &mut vec![(0, 1, 2, 1_000), (1, 1, 3, 1_400)],
            &rpc,
            0,
            0,
            &ep,
        );
        assert_eq!(ep.nic.rpc_messages(), 1, "two lanes, one CN, one message");
        assert_eq!(ep.nic.rpc_reqs(), 5);
        assert_eq!(
            ep.nic.coalesced_rpc_reqs(),
            3,
            "the later lane's batch rode the first lane's message"
        );
        assert_eq!(out.len(), 2);
        let (l0, ok0, d0) = out[0];
        let (l1, ok1, d1) = out[1];
        assert_eq!((l0, l1), (0, 1), "results route back per owner");
        assert!(ok0 && ok1);
        // The message fires at the latest post time; the earlier-posted
        // lane's chunk is handled first.
        assert!(d0 >= 1_400 + ep.net.rpc_rtt_ns, "d0={d0}");
        assert!(d1 > d0, "FIFO handler chunks: d0={d0} d1={d1}");
        assert_eq!(
            d1 - d0,
            ep.net.rpc_handle_ns * 3,
            "the later lane waits exactly its own handler time"
        );
    }

    #[test]
    fn staged_rpc_plans_to_different_cns_send_separate_messages() {
        let (_mns, ep, rpc) = rpc_setup(3);
        let out = Coalescer::new(5_000).ring_rpc(
            &mut vec![(0, 1, 1, 500), (1, 2, 1, 700)],
            &rpc,
            0,
            0,
            &ep,
        );
        assert_eq!(ep.nic.rpc_messages(), 2, "one message per destination");
        assert_eq!(ep.nic.coalesced_rpc_reqs(), 0, "nothing merged across CNs");
        assert!(out.iter().all(|&(_, ok, _)| ok));
    }

    #[test]
    fn adaptive_window_widens_on_hot_destination_and_shrinks_idle() {
        // Per-destination congestion control over the RPC plane: a
        // destination whose handler queue keeps a backlog (cross traffic
        // plus multi-lane rings) earns a wider merge window; an idle
        // destination drains toward direct issue. Windows never escape
        // [0, base * CAP_MULT].
        let (_mns, ep, rpc) = rpc_setup(3);
        let c = Coalescer::adaptive(5_000);
        let probe = |dst| c.eff_window(&Plan::Rpc { dst_cn: dst, n_reqs: 1 });
        assert_eq!(probe(1), 5_000, "unseen destination uses the base window");

        for round in 0..50u64 {
            let t = round * 1_000;
            // Cross traffic from CN 2 keeps destination 1's handler busy
            // (64 reqs * rpc_handle_ns per 1_000 ns round >> service rate).
            rpc.send_async_at(2, 1, 0, 64, t).unwrap();
            // Two lanes ring destination 1 together; destination 2 idles.
            c.ring_rpc(&mut vec![(0, 1, 2, t), (1, 1, 2, t + 500)], &rpc, 0, 0, &ep);
            c.ring_rpc(&mut vec![(0, 2, 1, t)], &rpc, 0, 0, &ep);
        }

        let hot = probe(1);
        let idle = probe(2);
        assert!(hot > 5_000, "hot destination widened: {hot}");
        assert!(
            hot <= 5_000 * crate::txn::adaptive::CAP_MULT,
            "window stays under the cap: {hot}"
        );
        assert!(idle < 5_000, "idle destination shrank: {idle}");
    }

    #[test]
    fn deferred_unlock_rides_a_sibling_lock_message() {
        // A parked fire-and-forget unlock plan to CN 1 absorbs into the
        // next staged lock message to CN 1 — exactly like a commit-log
        // clear riding a doorbell ring.
        let (_mns, ep, rpc) = rpc_setup(2);
        let c = Coalescer::new(5_000);
        c.defer(Plan::Rpc { dst_cn: 1, n_reqs: 2 }, 100);
        assert_eq!(c.pending_plans(), 1);
        let out = c.ring_rpc(&mut vec![(0, 1, 4, 600)], &rpc, 0, 0, &ep);
        assert_eq!(c.pending_plans(), 0, "the parked unlock rode along");
        assert_eq!(ep.nic.rpc_messages(), 1, "one merged message, not two");
        assert_eq!(ep.nic.rpc_reqs(), 6);
        assert_eq!(ep.nic.coalesced_rpc_reqs(), 2, "the unlock reqs were riders");
        // The rider's chunk is handled before the sync owner's.
        let (_, ok, done) = out[0];
        assert!(ok);
        assert!(
            done >= 600 + ep.net.rpc_rtt_ns + ep.net.rpc_handle_ns * 6,
            "sync owner waited for the rider's chunk too: {done}"
        );
    }

    #[test]
    fn stale_rpc_plan_flushes_as_its_own_message() {
        let (mns, ep, rpc) = rpc_setup(2);
        let c = Coalescer::new(1_000);
        c.defer(Plan::Rpc { dst_cn: 1, n_reqs: 3 }, 100);

        // Horizon still inside the window: nothing flushes.
        c.flush_stale(&ep, &mns, &rpc, 0, 0, 900).unwrap();
        assert_eq!(c.pending_plans(), 1);
        assert_eq!(ep.nic.rpc_messages(), 0);

        // Window expired: the plan sends its own message fire-and-forget.
        c.flush_stale(&ep, &mns, &rpc, 0, 0, 5_000).unwrap();
        assert_eq!(c.pending_plans(), 0);
        assert_eq!(ep.nic.rpc_messages(), 1);
        assert_eq!(ep.nic.rpc_reqs(), 3);
        assert!(rpc.handler_busy_ns(1) > 0, "the handler really got the reqs");

        // Further flushes are no-ops (flushed exactly once).
        c.flush_all(&ep, &mns, &rpc, 0, 0).unwrap();
        assert_eq!(ep.nic.rpc_messages(), 1);
    }

    #[test]
    fn rpc_ring_to_failed_cn_times_out_every_owner() {
        let (_mns, ep, rpc) = rpc_setup(2);
        rpc.set_failed(1, true);
        let out = Coalescer::new(5_000).ring_rpc(
            &mut vec![(0, 1, 1, 1_000), (1, 1, 2, 1_200)],
            &rpc,
            0,
            0,
            &ep,
        );
        assert_eq!(ep.nic.rpc_messages(), 0, "nothing charged on timeout");
        assert_eq!(out.len(), 2);
        for &(owner, ok, t_done) in &out {
            assert!(!ok, "owner {owner} must see the failure");
            let t_post = if owner == 0 { 1_000 } else { 1_200 };
            assert_eq!(t_done, t_post + rpc.timeout_ns(), "timeout from own post");
        }
    }

    #[test]
    fn retry_backoff_parks_and_resumes_through_the_flight_table() {
        let mut cfg = Config::small();
        cfg.pipeline_depth = 4;
        cfg.n_cns = 1;
        cfg.coordinators_per_cn = 1;
        let cluster = Cluster::build(
            &cfg,
            WorkloadKind::Kvs {
                rw_pct: 100,
                skewed: false,
            },
        )
        .unwrap();
        let sched = FrameScheduler::new(cluster.shared.clone(), 0, 0, 0);
        let shared = &sched.shared;

        // Park a lane at its backoff deadline and consume it exactly once.
        shared.park_retry(2, 7_000);
        assert!(matches!(shared.flights.borrow()[2], Flight::RetryAt(7_000)));
        assert!(shared.try_retry_over(2));
        assert!(matches!(shared.flights.borrow()[2], Flight::Idle));
        assert!(!shared.try_retry_over(2), "consumed exactly once");

        // A waiter triaging a conflicting future holder that is backing
        // off sees it as *progressing* (Wait, not Abort): the holder
        // re-enters the ready queue at its deadline on its own.
        let k = LotusKey::compose(9, 9);
        shared.note_lock(1, k, LockMode::Write, 5_000);
        shared.park_retry(1, 6_000);
        assert_eq!(
            shared.wait_verdict(0, k, LockMode::Write, 1_000),
            WaitVerdict::Wait
        );
        // The same holder stuck in a lock wait of its own must not be
        // waited on (the wait graph stays acyclic).
        shared.flights.borrow_mut()[1] = Flight::WaitLock(k, 5_500);
        assert_eq!(
            shared.wait_verdict(0, k, LockMode::Write, 1_000),
            WaitVerdict::Abort
        );
        // When the backing-off holder gives up (retries exhausted, lock
        // phase releases), the release wakes parked waiters at their
        // unchanged virtual time — the satellite regression: a waiter
        // must never be stranded by a holder that aborted out of backoff.
        shared.flights.borrow_mut()[1] = Flight::RetryAt(6_000);
        shared.park_wait(0, k, 1_000);
        shared.note_unlock_all(1, 6_000);
        assert!(matches!(shared.flights.borrow()[0], Flight::WaitOver(1_000)));
        assert_eq!(
            shared.ep.nic.lock_wait_ns(),
            5_000,
            "the bridged wait span is the release time minus the park time"
        );
        assert!(shared.try_wait_over(0));
    }

    #[test]
    fn window_zero_deferred_plans_issue_immediately() {
        // ISSUE 4 regression (alongside the flushed-exactly-once test
        // above): with `coalesce_window_ns = 0` and `pipeline_depth >= 2`
        // there is no coalescer, so a committed transaction's deferred
        // log-clear must issue immediately — the coordinator's log slot
        // is already EMPTY before `finish()` runs, and nothing is parked
        // that `finish()` would have to flush.
        let mut cfg = Config::small();
        cfg.pipeline_depth = 4;
        cfg.coalesce_window_ns = 0;
        cfg.duration_ns = 2_000_000;
        cfg.n_cns = 1;
        cfg.coordinators_per_cn = 1;
        cfg.scale.kvs_keys = 2_000;
        let cluster = Cluster::build(
            &cfg,
            WorkloadKind::Kvs {
                rw_pct: 100,
                skewed: false,
            },
        )
        .unwrap();
        let workload = cluster.workload.clone();
        let mut sched = FrameScheduler::new(cluster.shared.clone(), 0, 0, 0);
        let route = RouteCtx {
            router: &cluster.shared.router,
            cn: 0,
            hybrid: false,
        };
        let mut out = Vec::new();
        while !out.iter().any(|o: &LaneOutcome| o.result.is_ok()) {
            sched.step(&workload, &route, &mut out).unwrap();
        }
        // The committed update wrote its log slot and must have cleared
        // it already — WITHOUT finish() having run.
        let (mn, addr) = cluster.shared.log_slots[0];
        let mut buf = vec![0u8; crate::txn::log::slot_size() as usize];
        cluster.shared.mns[mn].read_bytes(addr, &mut buf).unwrap();
        assert!(
            !LogRecord::parse(&buf).is_prepared(),
            "window 0: the deferred log clear parked instead of issuing"
        );
        // Nothing staged, nothing parked, posted gauge drained.
        assert_eq!(cluster.shared.cn_nics[0].staged_plans(), 0);
        assert_eq!(cluster.shared.cn_nics[0].posted_wqes(), 0);
        let mut fin = Vec::new();
        sched.finish(&mut fin).unwrap();
    }

    #[test]
    fn sibling_lock_intervals_conflict_by_mode_and_interval() {
        let k = LotusKey::compose(5, 5);
        let other = LotusKey::compose(6, 6);
        let logs = vec![
            vec![LockStamp {
                key: k,
                mode: LockMode::Write,
                from: 200,
                until: 1_000,
            }],
            Vec::new(),
        ];
        let sib = SiblingLocks::new(&logs, 1);
        // Overlapping write-write and read-write conflict...
        assert!(sib.conflicts(k, LockMode::Write, 500));
        assert!(sib.conflicts(k, LockMode::Read, 500));
        // ...a different key, the past, the future (anachronism!), or my
        // own lane's locks don't.
        assert!(!sib.conflicts(other, LockMode::Write, 500));
        assert!(!sib.conflicts(k, LockMode::Write, 1_000));
        assert!(
            !sib.conflicts(k, LockMode::Write, 100),
            "a holder that acquires only in the requester's virtual future must not conflict"
        );
        let mine = SiblingLocks::new(&logs, 0);
        assert!(!mine.conflicts(k, LockMode::Write, 500));
    }

    #[test]
    fn read_read_siblings_do_not_conflict() {
        let k = LotusKey::compose(7, 7);
        let logs = vec![
            vec![LockStamp {
                key: k,
                mode: LockMode::Read,
                from: 0,
                until: 1_000,
            }],
            Vec::new(),
        ];
        let sib = SiblingLocks::new(&logs, 1);
        assert!(!sib.conflicts(k, LockMode::Read, 500));
        assert!(sib.conflicts(k, LockMode::Write, 500));
    }

    #[test]
    fn lane_machines_are_recycled_across_transactions() {
        // ISSUE 9: a lane's step machine is boxed once and parked
        // between transactions (`Flight::AwaitStart`) instead of being
        // re-created — and re-boxed — for every transaction.
        let mut cfg = Config::small();
        cfg.pipeline_depth = 2;
        cfg.duration_ns = 2_000_000;
        cfg.n_cns = 1;
        cfg.coordinators_per_cn = 1;
        cfg.scale.kvs_keys = 2_000;
        let cluster = Cluster::build(
            &cfg,
            WorkloadKind::Kvs {
                rw_pct: 50,
                skewed: false,
            },
        )
        .unwrap();
        let workload = cluster.workload.clone();
        let mut sched = FrameScheduler::new(cluster.shared.clone(), 0, 0, 0);
        let route = RouteCtx {
            router: &cluster.shared.router,
            cn: 0,
            hybrid: false,
        };
        let mut out = Vec::new();
        sched.step(&workload, &route, &mut out).unwrap();
        let lane = out.last().expect("step returns an outcome").lane;
        assert!(
            sched.lanes[lane].task.is_some(),
            "the completed lane kept its machine"
        );
        assert!(
            matches!(sched.shared.flights.borrow()[lane], Flight::AwaitStart),
            "the completed lane parked between transactions"
        );
        // The parked machine is reused: later steps hand it new start
        // clocks and it keeps producing outcomes.
        for _ in 0..24 {
            sched.step(&workload, &route, &mut out).unwrap();
        }
        assert!(
            out.iter().filter(|o| o.lane == lane).count() >= 2,
            "the recycled machine ran further transactions"
        );
    }

    /// A workload whose transactions touch no tables and issue no ops:
    /// `run_one` returns an already-ready future, so the allocations
    /// measured below are the scheduler machinery's own.
    #[cfg(feature = "alloc-count")]
    struct NoopWorkload;

    #[cfg(feature = "alloc-count")]
    impl Workload for NoopWorkload {
        fn name(&self) -> &'static str {
            "noop"
        }

        fn table_specs(&self) -> Vec<crate::store::index::TableSpec> {
            Vec::new()
        }

        fn load(&self, _cluster: &SharedCluster) -> Result<()> {
            Ok(())
        }

        fn run_one<'a>(
            &'a self,
            api: &'a mut dyn TxnApi,
            _route: &'a RouteCtx<'a>,
        ) -> StepFut<'a, Result<()>> {
            api.skip_to(api.now() + 1_000);
            StepFut::ready(Ok(()))
        }

        fn read_only_fraction(&self) -> f64 {
            1.0
        }
    }

    /// Tentpole invariant (ISSUE 9): once warm, the scheduler's own
    /// event-loop path — lane selection, machine hand-off, poll, park,
    /// outcome routing — performs ZERO heap allocations per transaction.
    /// The no-op workload isolates the machinery proper; the protocol
    /// phases' remaining per-call boxing is a documented follow-on
    /// (ROADMAP item 4).
    #[cfg(feature = "alloc-count")]
    #[test]
    fn steady_state_scheduler_path_allocates_nothing() {
        let mut cfg = Config::small();
        cfg.pipeline_depth = 4;
        cfg.coalesce_window_ns = 5_000;
        cfg.adaptive_coalescing = false;
        cfg.n_cns = 1;
        cfg.coordinators_per_cn = 1;
        cfg.scale.kvs_keys = 1_000;
        let cluster = Cluster::build(
            &cfg,
            WorkloadKind::Kvs {
                rw_pct: 0,
                skewed: false,
            },
        )
        .unwrap();
        let workload: Arc<dyn Workload> = Arc::new(NoopWorkload);
        let mut sched = FrameScheduler::new(cluster.shared.clone(), 0, 0, 0);
        let route = RouteCtx {
            router: &cluster.shared.router,
            cn: 0,
            hybrid: false,
        };
        let mut out = Vec::with_capacity(2_048);
        // Warm up: machines boxed once, scratch capacities grown.
        for _ in 0..64 {
            sched.step(&workload, &route, &mut out).unwrap();
        }
        out.clear();
        let before = crate::alloc_count::thread_allocs();
        for _ in 0..1_000 {
            sched.step(&workload, &route, &mut out).unwrap();
        }
        let delta = crate::alloc_count::thread_allocs() - before;
        assert!(out.len() >= 1_000, "every step completed a transaction");
        assert_eq!(
            delta, 0,
            "steady-state scheduler path must not allocate \
             ({delta} allocs across 1000 transactions)"
        );
    }
}
