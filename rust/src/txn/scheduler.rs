//! The pipelined frame scheduler: `pipeline_depth` concurrent
//! [`TxnFrame`]s per coordinator thread, with a split-phase **step-machine**
//! that overlaps sibling frames' protocol stages and coalesces their
//! doorbells.
//!
//! The sequential [`crate::txn::coordinator::LotusCoordinator`] runs one
//! transaction at a time and stalls a full RTT at every phase boundary.
//! The paper's CNs keep their RNICs busy by overlapping many in-flight
//! requests ("threads x coroutines"); the [`FrameScheduler`] models that:
//! one OS thread owns `depth` **lanes**, each a full transaction stream
//! (frame + virtual clock + RNG) sharing the coordinator's endpoint and
//! RPC slot. The scheduler always pumps the lane with the smallest
//! virtual clock, so lane transactions *overlap in virtual time* — and
//! all lanes charge the same simulated NICs, so saturation effects of the
//! deeper pipeline are faithful.
//!
//! # The step-machine (intra-transaction stage overlap)
//!
//! Phases *plan* their one-sided ops into [`OpBatch`]es and hand them to
//! the conduit ([`crate::txn::phases::PhaseCtx::issue`], backed here by
//! [`StepSink`]). Where the transaction-granular scheduler of PR 2
//! blocked a lane from its doorbell ring to the last completion, the
//! step-machine splits every issue point into **post** and **ring**
//! halves:
//!
//! 1. **Post / yield** — the plan's WQEs are staged in the scheduler's
//!    in-flight table ([`Flight::Staged`]; the CN NIC tracks the
//!    posted-but-unrung depth) and the lane *yields*.
//! 2. **Pump** — the scheduler immediately pumps the next-smallest-clock
//!    idle lane. That lane runs until *its* first issue point, stages its
//!    own plan, and pumps in turn — so a frame's lock RPC, CVT read and
//!    log write overlap in virtual time with sibling frames' phases, and
//!    more plans land inside `coalesce_window_ns` than transaction-level
//!    pumping could ever pair.
//! 3. **Ring / resume** — whichever lane finds no sibling left inside its
//!    window rings **one merged doorbell set** for every staged plan
//!    within `coalesce_window_ns` of its own post time (plus every parked
//!    fire-and-forget plan riding along). Per-op completion times are
//!    routed back through the in-flight table ([`Flight::Done`], keyed by
//!    doorbell completion time); each suspended lane resumes with *its
//!    own* results and charges its clock only to its own slowest
//!    completion.
//!
//! Staged plans outside the initiator's window stay staged and ring at
//! their own post times when their owner resumes — a lane's merge wait is
//! bounded by the window, never by a sibling's whole transaction.
//!
//! Two further mechanisms ride on the lane model:
//!
//! - **Fire-and-forget parking** ([`Coalescer`]): deferred plans
//!   (commit-log clears) park and ride a later ring; stale ones are
//!   rung out by [`Coalescer::flush_stale`] / [`FrameScheduler::finish`]
//!   exactly once.
//! - **Sibling lock-first aborts** ([`SiblingLocks`]): conflicts between
//!   lanes whose transactions overlap in *virtual* time are detected
//!   against recorded lock intervals and abort locally — a CPU compare on
//!   the CN, before a single byte (or the remote-lock RPC) leaves the
//!   node. A *suspended* lane additionally holds its real lock-table
//!   locks while siblings pump, so a nested lane can also abort on a
//!   physical conflict whose virtual-time order is inverted (the holder
//!   acquired "later" in virtual time). That abort is conservative —
//!   real shared memory needs real mutual exclusion while the holder is
//!   suspended — and the inversion window is bounded by the pump chain
//!   (~`coalesce_window_ns` + one lock phase).
//!
//! With `depth == 1` there are no siblings, no coalescer and no staging:
//! every issue takes the direct path, reproducing the sequential
//! coordinator's exact issue order, clock charges and RNG stream
//! (asserted by the `pipeline_depth=1` invariant test in [`crate::sim`]).

use std::cell::RefCell;
use std::sync::Arc;

use crate::dm::clock::{TimeGate, VClock};
use crate::dm::memnode::MemNode;
use crate::dm::opbatch::{BatchResult, MergedBatch, OpBatch};
use crate::dm::verbs::Endpoint;
use crate::lock::table::LockMode;
use crate::sharding::key::LotusKey;
use crate::txn::api::{RecordRef, TxnApi, TxnCtl};
use crate::txn::coordinator::SharedCluster;
use crate::txn::phases::{self, PhaseCtx, StepSink, TxnFrame, TxnRecord};
use crate::util::Xoshiro256;
use crate::workloads::{RouteCtx, Workload};
use crate::{Error, Result};

/// One pumped transaction's accounting: `(t_begin, t_end, outcome)` on
/// the lane clock that ran it. A fatal (non-abort) error never appears
/// here — it fails the whole run instead.
pub type LaneOutcome = (u64, u64, Result<()>);

/// Defensive bound on nested pumps per yield point: a yield may pump the
/// same sibling several times (short transactions inside one window), but
/// a failure of virtual time to advance must not spin the thread.
const MAX_PUMPS_PER_YIELD: usize = 64;

/// Add `n` ops to `mn`'s tally in a small per-MN count list.
fn bump_mn(tally: &mut Vec<(usize, u64)>, mn: usize, n: u64) {
    match tally.iter_mut().find(|(m, _)| *m == mn) {
        Some((_, c)) => *c += n,
        None => tally.push((mn, n)),
    }
}

/// Decide whether a doorbell to `mn` at virtual time `t` can ride the
/// last doorbell rung to that MN (within `window`), or must ring its own
/// (recording `t` as the new ring anchor).
fn ride_or_ring(last_ring: &mut Vec<u64>, mn: usize, t: u64, window: u64) -> bool {
    if mn >= last_ring.len() {
        last_ring.resize(mn + 1, u64::MAX);
    }
    let last = last_ring[mn];
    if last != u64::MAX && t.abs_diff(last) <= window {
        true
    } else {
        last_ring[mn] = t;
        false
    }
}

/// Per-scheduler doorbell coalescer: merges staged sync plans and parked
/// fire-and-forget plans into shared doorbell rings (see the module
/// docs). One instance per [`FrameScheduler`]; single-threaded by
/// construction (interior mutability only so the shared-reference
/// [`StepSink`] can reach it).
pub struct Coalescer {
    window_ns: u64,
    state: RefCell<CoalesceState>,
}

#[derive(Default)]
struct CoalesceState {
    /// Parked fire-and-forget plans: `(plan, park virtual time)`.
    pending: Vec<(OpBatch, u64)>,
    /// Per MN: virtual time of the last doorbell rung (`u64::MAX` never).
    last_ring: Vec<u64>,
}

impl Coalescer {
    /// Coalescer with the given pairing window (virtual ns).
    pub fn new(window_ns: u64) -> Self {
        Self {
            window_ns,
            state: RefCell::new(CoalesceState::default()),
        }
    }

    /// The pairing window (virtual ns).
    pub fn window_ns(&self) -> u64 {
        self.window_ns
    }

    /// Parked fire-and-forget plans not yet flushed.
    pub fn pending_plans(&self) -> usize {
        self.state.borrow().pending.len()
    }

    /// Park a fire-and-forget plan to ride a later doorbell. The plan
    /// waits at most `coalesce_window_ns` past the scheduler's slowest
    /// lane before [`Coalescer::flush_stale`] rings it out.
    pub fn defer(&self, plan: OpBatch, now: u64) {
        if plan.is_empty() {
            return;
        }
        self.state.borrow_mut().pending.push((plan, now));
    }

    /// Ring one merged doorbell set carrying every staged sync plan in
    /// `plans` (`(owner tag, plan, post time)`) plus every parked
    /// fire-and-forget plan that is not in the ring's virtual future
    /// beyond the window. The ring fires at the latest post time; per-MN
    /// groups are issued completion-driven, and each owner gets back its
    /// own [`BatchResult`] plus the completion time of its slowest op —
    /// the only amount its clock must advance by.
    pub fn ring(
        &self,
        mut plans: Vec<(usize, OpBatch, u64)>,
        ep: &Endpoint,
        mns: &[Arc<MemNode>],
    ) -> Result<Vec<(usize, BatchResult, u64)>> {
        // Earlier posts execute first within shared doorbell groups.
        plans.sort_by_key(|p| (p.2, p.0));
        let t_ring = plans.iter().map(|p| p.2).max().unwrap_or(0);
        let n_sync = plans.iter().filter(|p| !p.1.is_empty()).count() as u64;
        let mut st = self.state.borrow_mut();
        let mut merged = MergedBatch::new();
        // Parked riders first: their WQEs were posted earlier, so they
        // execute ahead of the sync plans in shared groups.
        let mut rider_mns: Vec<(usize, u64)> = Vec::new();
        let mut kept: Vec<(OpBatch, u64)> = Vec::new();
        for (plan, pt) in st.pending.drain(..) {
            if pt <= t_ring.saturating_add(self.window_ns) {
                for mn in plan.mns() {
                    let n = plan.group_len(mn) as u64;
                    bump_mn(&mut rider_mns, mn, n);
                }
                merged.absorb(plan);
            } else {
                kept.push((plan, pt));
            }
        }
        st.pending = kept;
        // Sync plans in post order. The first plan touching an MN "pays"
        // that MN's doorbell; later plans' ops on it are coalesced riders.
        let mut payer_mns: Vec<usize> = Vec::new();
        let mut extra_mns: Vec<(usize, u64)> = Vec::new();
        let mut slices: Vec<(usize, usize)> = Vec::with_capacity(plans.len());
        for (owner, plan, _t) in plans {
            for mn in plan.mns() {
                let n = plan.group_len(mn) as u64;
                if payer_mns.contains(&mn) {
                    bump_mn(&mut extra_mns, mn, n);
                } else {
                    payer_mns.push(mn);
                }
            }
            slices.push((owner, merged.absorb(plan)));
        }
        if merged.is_empty() {
            return Ok(slices
                .into_iter()
                .map(|(owner, _)| (owner, BatchResult::empty(), 0))
                .collect());
        }
        if n_sync >= 2 {
            ep.nic.note_overlap(n_sync);
        }
        ep.gate_sync(&VClock(t_ring));
        let window = self.window_ns;
        let st_ref = &mut *st;
        let last_ring = &mut st_ref.last_ring;
        let mut rode: Vec<usize> = Vec::new();
        let mut res = merged.issue_timed(ep, mns, t_ring, |mn| {
            let ride = ride_or_ring(last_ring, mn, t_ring, window);
            if ride {
                rode.push(mn);
            }
            ride
        })?;
        // Ops that joined a doorbell rung for a payer plan without paying
        // the ring themselves are coalesced riders; whole groups that
        // extended an earlier doorbell were already counted by the
        // endpoint itself.
        let extra: u64 = rider_mns
            .iter()
            .chain(extra_mns.iter())
            .filter(|(mn, _)| payer_mns.contains(mn) && !rode.contains(mn))
            .map(|&(_, n)| n)
            .sum();
        if extra > 0 {
            ep.nic.note_riders(extra);
        }
        Ok(slices
            .into_iter()
            .map(|(owner, s)| {
                let (r, t) = res.take(s);
                (owner, r, t)
            })
            .collect())
    }

    /// Ring out parked plans whose window expired before `horizon` (the
    /// scheduler's slowest lane): no doorbell came along to ride, so they
    /// ring their own, charged fire-and-forget at their park times.
    pub fn flush_stale(&self, ep: &Endpoint, mns: &[Arc<MemNode>], horizon: u64) -> Result<()> {
        self.flush_inner(ep, mns, Some(horizon))
    }

    /// Ring out every parked plan (orderly scheduler shutdown). A plan
    /// leaves `pending` the moment it is drained into the merged flush
    /// batch, so end-of-run flushes issue each parked plan exactly once
    /// no matter how often the flush paths run afterwards.
    pub fn flush_all(&self, ep: &Endpoint, mns: &[Arc<MemNode>]) -> Result<()> {
        self.flush_inner(ep, mns, None)
    }

    /// Drop every parked plan without issuing it (fail-stop crash: WQEs
    /// posted but not yet rung die with the CN; recovery completes or
    /// rolls back the affected transactions from their commit logs).
    pub fn discard_pending(&self) {
        self.state.borrow_mut().pending.clear();
    }

    fn flush_inner(&self, ep: &Endpoint, mns: &[Arc<MemNode>], horizon: Option<u64>) -> Result<()> {
        let mut st = self.state.borrow_mut();
        if st.pending.is_empty() {
            return Ok(());
        }
        let mut merged = MergedBatch::new();
        let mut t0 = u64::MAX;
        let mut kept: Vec<(OpBatch, u64)> = Vec::new();
        for (plan, pt) in st.pending.drain(..) {
            let stale = match horizon {
                Some(h) => pt.saturating_add(self.window_ns) < h,
                None => true,
            };
            if stale {
                t0 = t0.min(pt);
                merged.absorb(plan);
            } else {
                kept.push((plan, pt));
            }
        }
        st.pending = kept;
        if merged.n_plans() == 0 {
            return Ok(());
        }
        let window = self.window_ns;
        let st_ref = &mut *st;
        let last_ring = &mut st_ref.last_ring;
        // Fire-and-forget: completions and results are discarded.
        merged.issue_timed(ep, mns, t0, |mn| ride_or_ring(last_ring, mn, t0, window))?;
        Ok(())
    }
}

/// One lock held by a recently pumped sibling transaction, with its
/// virtual release time.
#[derive(Debug, Clone, Copy)]
pub struct LockStamp {
    /// Locked key.
    pub key: LotusKey,
    /// Held mode.
    pub mode: LockMode,
    /// Virtual time the holding transaction released it.
    pub until: u64,
}

/// Read view over all lanes' recent lock intervals, excluding the asking
/// lane — the lock phase's local sibling-conflict check.
pub struct SiblingLocks<'a> {
    logs: &'a [Vec<LockStamp>],
    me: usize,
}

impl<'a> SiblingLocks<'a> {
    /// View for lane `me` over `logs` (one entry per lane).
    pub fn new(logs: &'a [Vec<LockStamp>], me: usize) -> Self {
        Self { logs, me }
    }

    /// Would acquiring `mode` on `key` at virtual time `now` conflict
    /// with a sibling lane's transaction that still holds the key then?
    pub fn conflicts(&self, key: LotusKey, mode: LockMode, now: u64) -> bool {
        self.logs.iter().enumerate().any(|(i, log)| {
            i != self.me
                && log.iter().any(|s| {
                    s.key == key
                        && s.until > now
                        && (mode == LockMode::Write || s.mode == LockMode::Write)
                })
        })
    }
}

/// Transaction state machine of one lane (mirrors the sequential
/// coordinator's assertion states).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LanePhase {
    Idle,
    Building,
    Executed,
}

/// One concurrent transaction stream within a scheduler. Each lane owns
/// its frame, virtual clock and workload RNG so a suspended lane's state
/// is untouched while siblings pump (lane 0's RNG stream equals the
/// sequential coordinator's, anchoring the depth-1 equivalence).
struct Lane {
    frame: TxnFrame,
    clk: VClock,
    rng: Xoshiro256,
    phase: LanePhase,
}

/// In-flight state of one lane's issue point (the step-machine's table).
enum Flight {
    /// No plan in flight.
    Idle,
    /// WQEs posted, doorbell not yet rung: `(plan, post virtual time)`.
    Staged(OpBatch, u64),
    /// Doorbell rung; results await the owner's resume:
    /// `(results, completion time of the owner's slowest op)`.
    Done(BatchResult, u64),
}

/// `pipeline_depth` concurrent transaction streams multiplexed onto one
/// coordinator thread (see the module docs). Replaces the sequential
/// coordinator inside [`crate::sim`]'s `coordinator_thread` for LOTUS
/// runs with `pipeline_depth >= 1`.
pub struct FrameScheduler {
    cluster: Arc<SharedCluster>,
    cn: usize,
    slot: usize,
    global_id: usize,
    ep: Endpoint,
    /// Lanes behind `RefCell`s: a lane suspended at an issue point keeps
    /// its borrow on the pump stack, which is exactly what excludes it
    /// from the idle-lane scan.
    lanes: Vec<RefCell<Lane>>,
    /// Per lane: lock intervals of its recently pumped transactions
    /// (pruned once every lane's clock has passed them).
    lock_logs: RefCell<Vec<Vec<LockStamp>>>,
    /// The step-machine's in-flight table, one slot per lane.
    inflight: RefCell<Vec<Flight>>,
    /// Transactions completed by nested pumps inside the current step.
    done: RefCell<Vec<LaneOutcome>>,
    coalescer: Option<Coalescer>,
}

impl FrameScheduler {
    /// Scheduler for coordinator `slot` on CN `cn` with `depth` lanes.
    /// The step-machine (staging + coalescing) activates for `depth >= 2`
    /// when `coalesce_window_ns` is non-zero; `depth == 1` reproduces the
    /// sequential coordinator exactly.
    pub fn new(cluster: Arc<SharedCluster>, cn: usize, slot: usize, global_id: usize) -> Self {
        let depth = cluster.cfg.pipeline_depth.max(1);
        let window = cluster.cfg.coalesce_window_ns;
        let ep = Endpoint::new(cn, cluster.cn_nics[cn].clone(), cluster.net.clone());
        let seed = cluster.cfg.seed ^ (global_id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Self {
            cn,
            slot,
            global_id,
            ep,
            lanes: (0..depth)
                .map(|i| {
                    RefCell::new(Lane {
                        frame: TxnFrame::new(),
                        clk: VClock::zero(),
                        // Lane 0 keeps the sequential coordinator's seed.
                        rng: Xoshiro256::new(
                            seed ^ (i as u64).wrapping_mul(0xA076_1D64_78BD_642F),
                        ),
                        phase: LanePhase::Idle,
                    })
                })
                .collect(),
            lock_logs: RefCell::new((0..depth).map(|_| Vec::new()).collect()),
            inflight: RefCell::new((0..depth).map(|_| Flight::Idle).collect()),
            done: RefCell::new(Vec::new()),
            coalescer: (depth > 1 && window > 0).then(|| Coalescer::new(window)),
            cluster,
        }
    }

    /// Number of lanes (the configured pipeline depth).
    pub fn depth(&self) -> usize {
        self.lanes.len()
    }

    /// The scheduler's frontier: the slowest lane's virtual clock. This
    /// is what the run loop compares against the duration and publishes
    /// to the [`TimeGate`] between transactions.
    pub fn now(&self) -> u64 {
        self.lanes
            .iter()
            .map(|l| l.borrow().clk.now())
            .min()
            .unwrap_or(u64::MAX)
    }

    /// Attach the run's time gate to the shared endpoint.
    pub fn attach_gate(&mut self, gate: Arc<TimeGate>, gid: usize) {
        self.ep.attach_gate(gate, gid);
    }

    /// Fail-stop: every lane drops its in-flight state without releasing
    /// locks (recovery owns them, paper §6). Staged plans are WQEs posted
    /// but never rung — they die with the CN (the posted gauge is
    /// drained); a committed transaction's un-cleared log slot is
    /// completed idempotently by recovery's log scan.
    pub fn crash(&mut self) {
        if let Some(c) = &self.coalescer {
            c.discard_pending();
        }
        for f in self.inflight.borrow_mut().iter_mut() {
            if let Flight::Staged(b, _) = std::mem::replace(f, Flight::Idle) {
                self.ep.ring_posted(b.len() as u64);
            }
        }
        for lane in &self.lanes {
            let mut l = lane.borrow_mut();
            l.frame.crash();
            l.phase = LanePhase::Idle;
        }
        for log in self.lock_logs.borrow_mut().iter_mut() {
            log.clear();
        }
        self.done.borrow_mut().clear();
    }

    /// Orderly end of run: ring out every parked plan so no planned op
    /// (or its NIC charge) is silently dropped at the duration boundary.
    pub fn finish(&mut self) -> Result<()> {
        if let Some(c) = &self.coalescer {
            c.flush_all(&self.ep, &self.cluster.mns)?;
        }
        Ok(())
    }

    /// Jump every lane's clock forward (crash restart).
    pub fn skip_to(&mut self, t_ns: u64) {
        for lane in &self.lanes {
            lane.borrow_mut().clk.catch_up(t_ns);
        }
    }

    /// The idle (not currently pumping) lane with the smallest clock.
    /// Lanes suspended at an issue point hold their `RefCell` borrow on
    /// the pump stack and are skipped automatically.
    fn idle_min_lane(&self) -> Option<(usize, u64)> {
        let mut best: Option<(usize, u64)> = None;
        for (i, cell) in self.lanes.iter().enumerate() {
            if let Ok(l) = cell.try_borrow() {
                let t = l.clk.now();
                let better = match best {
                    None => true,
                    Some((_, bt)) => t < bt,
                };
                if better {
                    best = Some((i, t));
                }
            }
        }
        best
    }

    /// Post a lane's plan: WQEs staged, doorbell deferred (yield point).
    fn stage(&self, lane: usize, batch: OpBatch, t_post: u64) {
        self.ep.post_wqes(batch.len() as u64);
        self.inflight.borrow_mut()[lane] = Flight::Staged(batch, t_post);
    }

    /// Has some sibling's ring already completed this lane's plan?
    fn is_done(&self, lane: usize) -> bool {
        matches!(self.inflight.borrow()[lane], Flight::Done(..))
    }

    /// Take a resumed lane's results out of the in-flight table.
    fn take_done(&self, lane: usize) -> (BatchResult, u64) {
        match std::mem::replace(&mut self.inflight.borrow_mut()[lane], Flight::Idle) {
            Flight::Done(res, t_done) => (res, t_done),
            _ => unreachable!("lane resumed without a completed doorbell"),
        }
    }

    /// Ring every staged plan within `coalesce_window_ns` of the
    /// initiator's post time `t_init` as one merged doorbell set (plus
    /// parked riders), and file each owner's results as [`Flight::Done`].
    /// Staged plans outside the window stay staged — their owners ring
    /// them at their own post times when they resume.
    fn ring_staged(&self, c: &Coalescer, t_init: u64) -> Result<()> {
        let window = c.window_ns();
        let mut plans: Vec<(usize, OpBatch, u64)> = Vec::new();
        {
            let mut infl = self.inflight.borrow_mut();
            for (i, f) in infl.iter_mut().enumerate() {
                let take = matches!(*f, Flight::Staged(_, t) if t.abs_diff(t_init) <= window);
                if take {
                    if let Flight::Staged(b, t) = std::mem::replace(f, Flight::Idle) {
                        plans.push((i, b, t));
                    }
                }
            }
        }
        if plans.is_empty() {
            return Ok(());
        }
        let posted: u64 = plans.iter().map(|(_, b, _)| b.len() as u64).sum();
        let results = c.ring(plans, &self.ep, &self.cluster.mns)?;
        self.ep.ring_posted(posted);
        let mut infl = self.inflight.borrow_mut();
        for (lane, res, t_done) in results {
            infl[lane] = Flight::Done(res, t_done);
        }
        Ok(())
    }

    /// Pump the slowest lane through one transaction (nested pumps may
    /// complete sibling transactions along the way). Outcomes of every
    /// transaction finished during the step — `(t_begin, t_end, result)`
    /// per transaction — are appended to `out`; the returned `Err` is a
    /// fatal (non-abort) error only.
    pub fn step(
        &mut self,
        workload: &dyn Workload,
        route: &RouteCtx<'_>,
        out: &mut Vec<LaneOutcome>,
    ) -> Result<()> {
        let (li, t0) = self
            .idle_min_lane()
            .expect("scheduler has at least one lane");
        // Ring out parked plans no doorbell came along for, and drop
        // sibling lock intervals every lane has virtually passed.
        if let Some(c) = &self.coalescer {
            c.flush_stale(&self.ep, &self.cluster.mns, t0)?;
        }
        for log in self.lock_logs.borrow_mut().iter_mut() {
            log.retain(|s| s.until > t0);
        }
        let res = {
            let pump = PumpCtx {
                sched: &*self,
                workload,
                route,
            };
            pump.pump_lane(li)
        };
        out.append(&mut self.done.borrow_mut());
        res
    }
}

/// One [`FrameScheduler::step`] invocation's pump context: the conduit
/// lanes issue through, carrying the workload reference so a yielding
/// lane can hand the thread to a sibling.
struct PumpCtx<'a> {
    sched: &'a FrameScheduler,
    workload: &'a dyn Workload,
    route: &'a RouteCtx<'a>,
}

impl PumpCtx<'_> {
    /// Run lane `li` through one full transaction and record its outcome.
    /// Returns `Err` only for fatal (run-ending) errors.
    fn pump_lane(&self, li: usize) -> Result<()> {
        let sched = self.sched;
        let mut lane = sched.lanes[li]
            .try_borrow_mut()
            .expect("pumped lane is already on the pump stack");
        let t0 = lane.clk.now();
        let res = {
            let mut api = LaneApi {
                pump: self,
                lane: &mut *lane,
                li,
            };
            self.workload.run_one(&mut api, self.route)
        };
        let t1 = lane.clk.now();
        // Remember a *committed* transaction's lock set for the sibling
        // conflict check: any lane pumped later but virtually overlapping
        // `[t0, t1]` must see these as held (the lock set is a pure
        // function of the still-intact record set). Aborted transactions
        // are not stamped — they released whatever they briefly held, and
        // stamping them would cascade phantom aborts between siblings.
        if sched.lanes.len() > 1 && res.is_ok() {
            let frame = &lane.frame;
            if !frame.read_only && !frame.records.is_empty() {
                let mut logs = sched.lock_logs.borrow_mut();
                for (key, mode) in phases::lock::requests(&sched.cluster, frame, 0) {
                    logs[li].push(LockStamp {
                        key,
                        mode,
                        until: t1,
                    });
                }
            }
        }
        drop(lane);
        match res {
            Err(e) if !(e.is_abort() || matches!(e, Error::NodeUnavailable(_))) => Err(e),
            r => {
                sched.done.borrow_mut().push((t0, t1, r));
                Ok(())
            }
        }
    }
}

impl StepSink for PumpCtx<'_> {
    fn issue(&self, lane: usize, batch: OpBatch, clk: &mut VClock) -> Result<BatchResult> {
        let sched = self.sched;
        let mns = &sched.cluster.mns;
        // Depth 1 or coalescing disabled: the exact sequential path.
        let Some(c) = &sched.coalescer else {
            return batch.issue(&sched.ep, mns, clk);
        };
        if batch.is_empty() {
            if c.pending_plans() == 0 {
                return batch.issue(&sched.ep, mns, clk); // free
            }
            // Ring parked riders out now; the empty caller stays free
            // (its own completion time is zero).
            let mut rung = c.ring(vec![(lane, batch, clk.now())], &sched.ep, mns)?;
            let (_, res, t_done) = rung.pop().expect("ring returns the caller's slice");
            clk.catch_up(t_done);
            return Ok(res);
        }
        // Post / yield.
        let t_post = clk.now();
        sched.stage(lane, batch, t_post);
        // Pump siblings that are behind this frame's window; one of them
        // may ring our plan as part of its own merged issue.
        let window = c.window_ns();
        let mut pumps = 0usize;
        while !sched.is_done(lane) {
            let Some((j, tj)) = sched.idle_min_lane() else {
                break;
            };
            if tj > t_post.saturating_add(window) {
                break;
            }
            self.pump_lane(j)?;
            pumps += 1;
            if pumps >= MAX_PUMPS_PER_YIELD {
                break;
            }
        }
        // Nobody rang our doorbell: ring now, merging every staged plan
        // within the window plus parked fire-and-forget riders.
        if !sched.is_done(lane) {
            sched.ring_staged(c, t_post)?;
        }
        // Resume.
        let (res, t_done) = sched.take_done(lane);
        clk.catch_up(t_done);
        Ok(res)
    }

    fn issue_deferred(&self, _lane: usize, batch: OpBatch, clk: &mut VClock) -> Result<()> {
        match &self.sched.coalescer {
            Some(c) => {
                c.defer(batch, clk.now());
                Ok(())
            }
            None => batch.issue_async(&self.sched.ep, &self.sched.cluster.mns, clk),
        }
    }

    fn sibling_conflict(&self, lane: usize, key: LotusKey, mode: LockMode, now: u64) -> bool {
        let logs = self.sched.lock_logs.borrow();
        if logs.len() <= 1 {
            return false;
        }
        SiblingLocks::new(&logs, lane).conflicts(key, mode, now)
    }
}

/// The [`TxnApi`]/[`TxnCtl`] view the workload drives for one pumped
/// lane: the lane's frame, clock and RNG, plus the pump context the
/// lane's issue points yield through.
struct LaneApi<'a> {
    pump: &'a PumpCtx<'a>,
    lane: &'a mut Lane,
    li: usize,
}

impl LaneApi<'_> {
    /// Split-borrow into a phase context + the lane's frame.
    fn parts(&mut self) -> (PhaseCtx<'_>, &mut TxnFrame) {
        let sched = self.pump.sched;
        let Lane { frame, clk, .. } = &mut *self.lane;
        (
            PhaseCtx {
                cluster: &*sched.cluster,
                cn: sched.cn,
                slot: sched.slot,
                global_id: sched.global_id,
                ep: &sched.ep,
                clk,
                lane: self.li,
                sink: Some(self.pump),
            },
            frame,
        )
    }
}

impl TxnCtl for LaneApi<'_> {
    fn add_ro(&mut self, r: RecordRef) {
        debug_assert_ne!(self.lane.phase, LanePhase::Idle);
        self.lane.frame.records.push(TxnRecord::new(r, false));
    }

    fn add_rw(&mut self, r: RecordRef) {
        debug_assert_ne!(self.lane.phase, LanePhase::Idle);
        debug_assert!(!self.lane.frame.read_only, "read-only txn cannot AddRW");
        self.lane.frame.records.push(TxnRecord::new(r, true));
    }

    fn add_insert(&mut self, r: RecordRef, payload: Vec<u8>) {
        debug_assert_ne!(self.lane.phase, LanePhase::Idle);
        debug_assert!(!self.lane.frame.read_only);
        let mut rec = TxnRecord::new(r, true);
        rec.insert = true;
        rec.new_value = Some(payload);
        self.lane.frame.records.push(rec);
    }

    fn add_delete(&mut self, r: RecordRef) {
        debug_assert_ne!(self.lane.phase, LanePhase::Idle);
        let mut rec = TxnRecord::new(r, true);
        rec.delete = true;
        self.lane.frame.records.push(rec);
    }

    fn execute(&mut self) -> Result<()> {
        debug_assert_ne!(self.lane.phase, LanePhase::Idle);
        let res = {
            let (mut ctx, frame) = self.parts();
            phases::execute(&mut ctx, frame)
        };
        match res {
            Ok(()) => {
                self.lane.phase = LanePhase::Executed;
                Ok(())
            }
            Err(e) => {
                // The failing phase already released every held lock.
                self.lane.phase = LanePhase::Idle;
                Err(e)
            }
        }
    }

    fn value(&self, r: RecordRef) -> Option<&[u8]> {
        self.lane
            .frame
            .find(r)
            .and_then(|i| self.lane.frame.records[i].value.as_deref())
    }

    fn stage_write(&mut self, r: RecordRef, payload: Vec<u8>) {
        let i = self
            .lane
            .frame
            .find(r)
            .expect("stage_write on unknown record");
        debug_assert!(self.lane.frame.records[i].write, "stage_write needs AddRW");
        self.lane.frame.records[i].new_value = Some(payload);
    }

    fn commit(&mut self) -> Result<()> {
        debug_assert_eq!(self.lane.phase, LanePhase::Executed);
        let res = {
            let (mut ctx, frame) = self.parts();
            phases::commit_txn(&mut ctx, frame)
        };
        self.lane.phase = LanePhase::Idle;
        res
    }

    fn rollback(&mut self) {
        let (mut ctx, frame) = self.parts();
        phases::unlock::release(&mut ctx, frame);
        self.lane.phase = LanePhase::Idle;
    }
}

impl TxnApi for LaneApi<'_> {
    fn begin(&mut self, read_only: bool) {
        let sched = self.pump.sched;
        let Lane { frame, clk, .. } = &mut *self.lane;
        phases::begin(&sched.cluster, clk, frame, read_only);
        self.lane.phase = LanePhase::Building;
    }

    fn txn(&mut self) -> &mut dyn TxnCtl {
        self
    }

    fn now(&self) -> u64 {
        self.lane.clk.now()
    }

    fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.lane.rng
    }

    fn cn(&self) -> usize {
        self.pump.sched.cn
    }

    fn attach_gate(&mut self, _gate: Arc<TimeGate>, _gid: usize) {
        // The gate is attached at scheduler level (shared endpoint).
    }

    fn crash(&mut self) {
        self.lane.frame.crash();
        self.lane.phase = LanePhase::Idle;
    }

    fn skip_to(&mut self, t_ns: u64) {
        self.lane.clk.catch_up(t_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dm::netconfig::NetConfig;
    use crate::dm::rnic::Rnic;

    fn setup() -> (Vec<Arc<MemNode>>, Endpoint) {
        let mns = vec![Arc::new(MemNode::new(0, 1 << 16))];
        let ep = Endpoint::new(0, Arc::new(Rnic::new()), Arc::new(NetConfig::default()));
        (mns, ep)
    }

    #[test]
    fn deferred_plan_rides_the_next_staged_ring() {
        let (mns, ep) = setup();
        let r = mns[0].register(64).unwrap();
        let c = Coalescer::new(5_000);

        // A frame parks a fire-and-forget write...
        let mut park = OpBatch::new();
        park.write(0, r.base, 7u64.to_le_bytes().to_vec());
        c.defer(park, 100);
        assert_eq!(c.pending_plans(), 1);

        // ...and another frame's staged read rings within the window.
        let mut sync = OpBatch::new();
        let tag = sync.read(0, r.base, 8);
        let mut out = c.ring(vec![(0, sync, 600)], &ep, &mns).unwrap();
        let (owner, res, done) = out.pop().unwrap();

        assert_eq!(owner, 0);
        assert_eq!(c.pending_plans(), 0, "the parked plan rode along");
        assert_eq!(ep.nic.doorbells(), 1, "one merged ring, not two");
        assert_eq!(ep.nic.coalesced_ops(), 1, "the parked write was a rider");
        // The parked write executed before the rider's read in the same
        // doorbell group.
        assert_eq!(res.read_buf(tag), &7u64.to_le_bytes()[..]);
        assert_eq!(mns[0].load_u64(r.base).unwrap(), 7);
        assert!(done >= 600 + ep.net.rtt_ns, "sync caller waited its RTT");
    }

    #[test]
    fn staged_sibling_plans_share_one_doorbell_ring() {
        // The step-machine's payoff in miniature: two lanes' staged sync
        // plans to one MN ring a single doorbell, each lane gets its own
        // results, and the overlap counters see the merge.
        let (mns, ep) = setup();
        let r = mns[0].register(128).unwrap();
        mns[0].store_u64(r.base, 11).unwrap();
        mns[0].store_u64(r.base + 8, 22).unwrap();
        let c = Coalescer::new(5_000);
        let mut a = OpBatch::new();
        let ta = a.read(0, r.base, 8);
        let mut b = OpBatch::new();
        let tb = b.read(0, r.base + 8, 8);

        let mut out = c
            .ring(vec![(0, a, 1_000), (1, b, 1_400)], &ep, &mns)
            .unwrap();
        assert_eq!(ep.nic.doorbells(), 1, "two frames, one MN, one doorbell");
        assert_eq!(ep.nic.overlap_rings(), 1);
        assert_eq!(ep.nic.overlap_plans(), 2);
        assert_eq!(ep.nic.coalesced_ops(), 1, "the later plan's op rode");
        let (l1, r1, d1) = out.pop().unwrap();
        let (l0, r0, d0) = out.pop().unwrap();
        assert_eq!((l0, l1), (0, 1), "results route back per owner");
        assert_eq!(r0.read_buf(ta), &11u64.to_le_bytes()[..]);
        assert_eq!(r1.read_buf(tb), &22u64.to_le_bytes()[..]);
        // The ring fires at the latest post time; the earlier-posted
        // plan's op is served first.
        assert!(d0 >= 1_400 + ep.net.rtt_ns, "d0={d0}");
        assert!(d1 >= d0, "FIFO completions: d0={d0} d1={d1}");
    }

    #[test]
    fn stale_deferred_plan_rings_its_own_doorbell_on_flush() {
        let (mns, ep) = setup();
        let r = mns[0].register(64).unwrap();
        let c = Coalescer::new(1_000);
        let mut park = OpBatch::new();
        park.write(0, r.base, 9u64.to_le_bytes().to_vec());
        c.defer(park, 100);

        // Horizon still inside the window: nothing flushes.
        c.flush_stale(&ep, &mns, 900).unwrap();
        assert_eq!(c.pending_plans(), 1);
        assert_eq!(ep.nic.doorbells(), 0);

        // Window expired: the plan rings out fire-and-forget.
        c.flush_stale(&ep, &mns, 5_000).unwrap();
        assert_eq!(c.pending_plans(), 0);
        assert_eq!(ep.nic.doorbells(), 1);
        assert_eq!(mns[0].load_u64(r.base).unwrap(), 9);
    }

    #[test]
    fn parked_plan_just_before_finish_flushes_exactly_once() {
        // ISSUE 3 regression: a fire-and-forget plan parked right before
        // `finish()` must be flushed exactly once and charged to the
        // right NIC counters — later flush calls must not re-issue it.
        let (mns, ep) = setup();
        let r = mns[0].register(64).unwrap();
        let c = Coalescer::new(5_000);
        let mut park = OpBatch::new();
        // Non-idempotent op: a double flush would be visible in memory.
        park.faa(0, r.base, 1);
        c.defer(park, 4_900);

        // End-of-run flush (what `FrameScheduler::finish` runs).
        c.flush_all(&ep, &mns).unwrap();
        assert_eq!(c.pending_plans(), 0);
        assert_eq!(mns[0].load_u64(r.base).unwrap(), 1, "applied exactly once");
        assert_eq!(ep.nic.doorbells(), 1, "one doorbell for the flush");
        assert_eq!(ep.nic.doorbell_ops(), 1);
        assert_eq!(ep.nic.coalesced_ops(), 0, "own ring, not a rider");

        // Any further flush — stale-horizon or full — is a no-op.
        c.flush_stale(&ep, &mns, u64::MAX).unwrap();
        c.flush_all(&ep, &mns).unwrap();
        assert_eq!(mns[0].load_u64(r.base).unwrap(), 1, "no double flush");
        assert_eq!(ep.nic.doorbells(), 1, "no extra doorbell charged");
    }

    #[test]
    fn sibling_lock_intervals_conflict_by_mode_and_time() {
        let k = LotusKey::compose(5, 5);
        let other = LotusKey::compose(6, 6);
        let logs = vec![
            vec![LockStamp {
                key: k,
                mode: LockMode::Write,
                until: 1_000,
            }],
            Vec::new(),
        ];
        let sib = SiblingLocks::new(&logs, 1);
        // Overlapping write-write and read-write conflict...
        assert!(sib.conflicts(k, LockMode::Write, 500));
        assert!(sib.conflicts(k, LockMode::Read, 500));
        // ...a different key, the past, or my own lane's locks don't.
        assert!(!sib.conflicts(other, LockMode::Write, 500));
        assert!(!sib.conflicts(k, LockMode::Write, 1_000));
        let mine = SiblingLocks::new(&logs, 0);
        assert!(!mine.conflicts(k, LockMode::Write, 500));
    }

    #[test]
    fn read_read_siblings_do_not_conflict() {
        let k = LotusKey::compose(7, 7);
        let logs = vec![
            vec![LockStamp {
                key: k,
                mode: LockMode::Read,
                until: 1_000,
            }],
            Vec::new(),
        ];
        let sib = SiblingLocks::new(&logs, 1);
        assert!(!sib.conflicts(k, LockMode::Read, 500));
        assert!(sib.conflicts(k, LockMode::Write, 500));
    }
}
