//! Protocol unit tests for the phase pipeline (moved here from the old
//! coordinator monolith, plus phase-boundary tests: lock-first ordering,
//! per-MN batch grouping, fire-and-forget unlock accounting).

use std::sync::Arc;

use crate::config::Config;
use crate::sharding::key::LotusKey;
use crate::sim::Cluster;
use crate::store::index::TableSpec;
use crate::txn::api::{RecordRef, TxnApi, TxnCtl};
use crate::txn::coordinator::{LotusCoordinator, SharedCluster};
use crate::txn::log::LogRecord;
use crate::txn::phases::lock;
use crate::AbortReason;

/// Minimal single-table cluster for protocol unit tests.
fn mini() -> (Arc<SharedCluster>, Vec<LotusCoordinator>) {
    let mut cfg = Config::small();
    cfg.n_cns = 2;
    cfg.coordinators_per_cn = 2;
    // The protocol tests need ~15 MB per MN; a small pool keeps the
    // (parallel) test suite's memory footprint down.
    cfg.mn_capacity = 64 << 20;
    let specs = vec![TableSpec {
        id: 0,
        name: "t".into(),
        record_len: 40,
        ncells: 2,
        assoc: 4,
        expected_records: 16384,
    }];
    let cluster = Cluster::build_shared(&cfg, specs).unwrap();
    // Preload records across the whole shard space so every CN owns
    // some keys (remote-lock tests need owner != 0).
    for uid in 0..4096u64 {
        let key = LotusKey::compose(uid, uid);
        cluster.tables[0]
            .load_insert(&cluster.mns, key, format!("init-{uid}").as_bytes(), 1)
            .unwrap();
    }
    let coords = (0..4)
        .map(|g| LotusCoordinator::new(cluster.clone(), g / 2, g % 2, g))
        .collect();
    (cluster, coords)
}

fn rr(uid: u64) -> RecordRef {
    RecordRef::new(0, LotusKey::compose(uid, uid))
}

#[test]
fn read_only_txn_reads_initial_value() {
    let (_c, mut coords) = mini();
    let co = &mut coords[0];
    co.begin(true);
    co.add_ro(rr(5));
    co.execute().unwrap();
    assert_eq!(co.value(rr(5)).unwrap(), b"init-5");
    co.commit().unwrap();
}

#[test]
fn rw_txn_update_visible_to_next_reader() {
    let (_c, mut coords) = mini();
    {
        let co = &mut coords[0];
        co.begin(false);
        co.add_rw(rr(7));
        co.execute().unwrap();
        assert_eq!(co.value(rr(7)).unwrap(), b"init-7");
        co.stage_write(rr(7), b"updated!".to_vec());
        co.commit().unwrap();
    }
    let co = &mut coords[1];
    co.begin(true);
    co.add_ro(rr(7));
    co.execute().unwrap();
    assert_eq!(co.value(rr(7)).unwrap(), b"updated!");
    co.commit().unwrap();
}

#[test]
fn all_locks_released_after_commit_and_abort() {
    let (c, mut coords) = mini();
    let held = || -> usize { c.lock_services.iter().map(|s| s.held_slots()).sum() };
    let co = &mut coords[0];
    co.begin(false);
    co.add_rw(rr(1));
    co.add_ro(rr(2));
    co.execute().unwrap();
    assert!(held() > 0);
    co.stage_write(rr(1), b"x".to_vec());
    co.commit().unwrap();
    assert_eq!(held(), 0, "commit must release all locks");
    co.begin(false);
    co.add_rw(rr(3));
    co.execute().unwrap();
    co.rollback();
    assert_eq!(held(), 0, "rollback must release all locks");
}

#[test]
fn write_write_conflict_aborts_second() {
    let (_c, mut coords) = mini();
    let (a, rest) = coords.split_at_mut(1);
    let a = &mut a[0];
    let b = &mut rest[0];
    a.begin(false);
    a.add_rw(rr(9));
    a.execute().unwrap();
    b.begin(false);
    b.add_rw(rr(9));
    let err = b.execute().unwrap_err();
    assert_eq!(err.abort_reason(), Some(AbortReason::LockConflict));
    // A can still commit.
    a.stage_write(rr(9), b"winner".to_vec());
    a.commit().unwrap();
    // And b can retry.
    b.begin(false);
    b.add_rw(rr(9));
    b.execute().unwrap();
    assert_eq!(b.value(rr(9)).unwrap(), b"winner");
    b.rollback();
}

#[test]
fn lock_first_conflict_aborts_before_any_memory_pool_read() {
    // The paper's core ordering claim: a conflicting transaction is
    // detected and aborted in the Lock phase — before a single byte is
    // READ from the memory pool. Locks live on CN CPUs (local CAS or
    // CN-to-CN RPC), so the aborting execute must leave every MN RNIC's
    // op counter untouched.
    let (c, mut coords) = mini();
    let (a, rest) = coords.split_at_mut(1);
    let a = &mut a[0];
    let b = &mut rest[0];
    a.begin(false);
    a.add_rw(rr(70));
    a.execute().unwrap();
    let mn_ops_before: u64 = c.mns.iter().map(|m| m.rnic.op_count()).sum();
    b.begin(false);
    b.add_rw(rr(70));
    assert_eq!(
        b.execute().unwrap_err().abort_reason(),
        Some(AbortReason::LockConflict)
    );
    let mn_ops_after: u64 = c.mns.iter().map(|m| m.rnic.op_count()).sum();
    assert_eq!(
        mn_ops_before, mn_ops_after,
        "lock-first: the aborted txn must not have touched the memory pool"
    );
    a.rollback();
}

#[test]
fn remote_unlock_is_fire_and_forget() {
    // Paper 5.1: the coordinator "returns the result immediately after
    // issuing remote unlock requests" — releasing a remote lock costs
    // the send, never a round trip. The lock is still really released.
    let (c, mut coords) = mini();
    let uid = (0..4096u64)
        .find(|&u| c.router.owner_of_key(LotusKey::compose(u, u)) == 1)
        .unwrap();
    let co = &mut coords[0]; // on CN 0; the lock lives on CN 1
    assert_eq!(co.cn, 0);
    co.begin(false);
    co.add_rw(rr(uid));
    co.execute().unwrap();
    let held: usize = c.lock_services.iter().map(|s| s.held_slots()).sum();
    assert!(held > 0);
    let t0 = co.clk.now();
    co.rollback();
    let dt = co.clk.now() - t0;
    assert!(
        dt < c.net.rpc_rtt_ns / 2,
        "remote unlock must be fire-and-forget, not a round trip: {dt} ns"
    );
    let held_after: usize = c.lock_services.iter().map(|s| s.held_slots()).sum();
    assert_eq!(held_after, 0, "the remote lock must really be released");
}

#[test]
fn read_lock_blocks_writer_under_sr() {
    let (_c, mut coords) = mini();
    let (a, rest) = coords.split_at_mut(1);
    let a = &mut a[0];
    let b = &mut rest[0];
    a.begin(false);
    a.add_ro(rr(11)); // read lock under SR
    a.execute().unwrap();
    b.begin(false);
    b.add_rw(rr(11));
    assert_eq!(
        b.execute().unwrap_err().abort_reason(),
        Some(AbortReason::LockConflict)
    );
    a.commit().unwrap();
}

#[test]
fn si_skips_read_locks() {
    let (c, mut coords) = mini();
    // Rebuild with SI via the shared config is fixed at build; emulate
    // by checking the lock-request computation instead.
    let co = &mut coords[0];
    co.begin(false);
    co.add_ro(rr(12));
    co.add_rw(rr(13));
    // Under SR: 2 lock requests.
    assert_eq!(lock::requests(&c, &co.frame, 0).len(), 2);
}

#[test]
fn insert_then_read_roundtrip() {
    let (_c, mut coords) = mini();
    let key = RecordRef::new(0, LotusKey::compose(999, 5000));
    {
        let co = &mut coords[0];
        co.begin(false);
        co.add_insert(key, b"brand-new".to_vec());
        co.execute().unwrap();
        co.commit().unwrap();
    }
    let co = &mut coords[2];
    co.begin(true);
    co.add_ro(key);
    co.execute().unwrap();
    assert_eq!(co.value(key).unwrap(), b"brand-new");
    co.commit().unwrap();
}

#[test]
fn duplicate_insert_aborts() {
    let (_c, mut coords) = mini();
    let co = &mut coords[0];
    co.begin(false);
    co.add_insert(rr(5), b"dup".to_vec());
    assert_eq!(
        co.execute().unwrap_err().abort_reason(),
        Some(AbortReason::Duplicate)
    );
}

#[test]
fn delete_makes_record_unfindable() {
    let (_c, mut coords) = mini();
    {
        let co = &mut coords[0];
        co.begin(false);
        co.add_delete(rr(20));
        co.execute().unwrap();
        co.commit().unwrap();
    }
    let co = &mut coords[1];
    co.begin(true);
    co.add_ro(rr(20));
    assert_eq!(
        co.execute().unwrap_err().abort_reason(),
        Some(AbortReason::NotFound)
    );
}

#[test]
fn missing_key_aborts_not_found() {
    let (_c, mut coords) = mini();
    let co = &mut coords[0];
    co.begin(true);
    co.add_ro(rr(100_000));
    assert_eq!(
        co.execute().unwrap_err().abort_reason(),
        Some(AbortReason::NotFound)
    );
}

#[test]
fn doomed_txn_cannot_commit() {
    let (c, mut coords) = mini();
    let co = &mut coords[0];
    co.begin(false);
    co.add_rw(rr(30));
    co.execute().unwrap();
    co.stage_write(rr(30), b"nope".to_vec());
    c.doomed.doom(co.frame.txn_id);
    assert_eq!(
        co.commit().unwrap_err().abort_reason(),
        Some(AbortReason::OwnerFailed)
    );
    // Locks released; value unchanged.
    let held: usize = c.lock_services.iter().map(|s| s.held_slots()).sum();
    assert_eq!(held, 0);
    co.begin(true);
    co.add_ro(rr(30));
    co.execute().unwrap();
    assert_eq!(co.value(rr(30)).unwrap(), b"init-30");
}

#[test]
fn mvcc_keeps_old_version_readable_at_old_timestamp() {
    let (c, mut coords) = mini();
    // Reader draws its snapshot BEFORE the writer commits.
    let ro_ts_holder;
    {
        let co = &mut coords[1];
        co.begin(true);
        co.add_ro(rr(40));
        ro_ts_holder = co.frame.start_ts;
    }
    {
        let co = &mut coords[0];
        co.begin(false);
        co.add_rw(rr(40));
        co.execute().unwrap();
        co.stage_write(rr(40), b"v2".to_vec());
        co.commit().unwrap();
    }
    // The old version (ncells=2) still serves the old snapshot.
    let co = &mut coords[1];
    co.execute().unwrap();
    assert_eq!(co.value(rr(40)).unwrap(), b"init-40");
    assert!(ro_ts_holder <= c.oracle.last());
    co.commit().unwrap();
}

#[test]
fn version_too_new_aborts_sr_rw_txn() {
    let (c, mut coords) = mini();
    // Start a RW txn (draws T_start), then another txn commits a newer
    // version, then the first reads: must abort.
    let (a, rest) = coords.split_at_mut(1);
    let a = &mut a[0];
    let b = &mut rest[0];
    a.begin(false);
    a.add_rw(rr(50)); // T_start drawn now
    b.begin(false);
    b.add_rw(rr(50));
    b.execute().unwrap();
    b.stage_write(rr(50), b"newer".to_vec());
    b.commit().unwrap();
    assert_eq!(
        a.execute().unwrap_err().abort_reason(),
        Some(AbortReason::VersionTooNew)
    );
    let _ = c;
}

#[test]
fn remote_lock_costs_an_rpc() {
    let (c, mut coords) = mini();
    // Find a key owned by CN 1; lock it from CN 0.
    let uid = (0..4096u64)
        .find(|&u| c.router.owner_of_key(LotusKey::compose(u, u)) == 1)
        .unwrap();
    let co = &mut coords[0]; // on CN 0
    assert_eq!(co.cn, 0);
    let t0 = co.clk.now();
    co.begin(false);
    co.add_rw(rr(uid));
    co.execute().unwrap();
    let elapsed = co.clk.now() - t0;
    assert!(
        elapsed >= c.net.rpc_rtt_ns,
        "remote lock must pay an RPC RTT: {elapsed}"
    );
    co.rollback();
}

#[test]
fn vt_cache_hit_skips_cvt_read() {
    let (c, mut coords) = mini();
    // A local-keyed record, accessed twice by the owner CN.
    let uid = (0..4096u64)
        .find(|&u| c.router.owner_of_key(LotusKey::compose(u, u)) == 0)
        .unwrap();
    let co = &mut coords[0];
    co.begin(false);
    co.add_rw(rr(uid));
    co.execute().unwrap();
    co.stage_write(rr(uid), b"warm".to_vec());
    co.commit().unwrap();
    let (h0, _, _) = c.vt_caches[0].stats();
    co.begin(false);
    co.add_rw(rr(uid));
    co.execute().unwrap();
    assert_eq!(co.value(rr(uid)).unwrap(), b"warm");
    co.rollback();
    let (h1, _, _) = c.vt_caches[0].stats();
    assert!(h1 > h0, "second access must hit the VT cache");
}

#[test]
fn log_slot_prepared_then_cleared() {
    let (c, mut coords) = mini();
    let co = &mut coords[0];
    co.begin(false);
    co.add_rw(rr(60));
    co.execute().unwrap();
    co.stage_write(rr(60), b"logged".to_vec());
    co.commit().unwrap();
    let (mn, addr) = c.log_slots[co.global_id];
    let mut buf = vec![0u8; crate::txn::log::slot_size() as usize];
    c.mns[mn].read_bytes(addr, &mut buf).unwrap();
    let rec = LogRecord::parse(&buf);
    assert!(!rec.is_prepared(), "log must be cleared after commit");
}
