//! Phase 1 — *Lock Data* (lock-first, paper §5.1 + Algorithm 1).
//!
//! Write locks for the read-write set, read locks for the read-only set
//! (SR only); inserts and deletes also lock the index bucket's probe
//! chain (§4.1). Locally owned keys are CPU CAS on the local lock table;
//! remote keys are batched **per owner CN** into one RPC each. Any
//! failure releases everything already acquired and aborts — before a
//! single byte is read from the memory pool.
//!
//! # Virtual-interval conflict triage (pipelined lanes)
//!
//! Under the pipelined scheduler a suspended sibling lane keeps its real
//! lock-table locks while this lane runs, so a *physical* acquisition
//! failure is not automatically a conflict of the modeled timeline: the
//! holder may have acquired the lock at a virtual time **after** the
//! requester's clock (the scheduler executed its segment first). Such an
//! anachronistic failure is triaged through the sink's recorded lock
//! intervals ([`crate::txn::phases::StepSink::wait_verdict`]): the
//! requester **parks** until the sibling releases and then retries at
//! its *unchanged* virtual time — in the modeled timeline the lock was
//! free at that instant, so neither transaction aborts. Genuine overlaps
//! (the holder's interval covers the requester's now) abort lock-first
//! exactly as before, and a holder that is itself wait-parked is never
//! waited on (the wait graph stays acyclic).
//!
//! # Wrong-owner bounce-and-retry (live resharding)
//!
//! A request racing a shard transfer bounces with `WrongShardOwner`
//! (stale route, or the shard paused mid-transfer). That is not an
//! abort (ISSUE 10): the lane parks-and-retries at its unchanged
//! virtual time ([`PhaseCtx::bounce_park`] — a first-class scheduler
//! event like `Flight::RetryAt`), re-resolves the owner from the fresh
//! routing map and re-dispatches, charging a single-request message to
//! the new owner (or a CPU acquisition if the key came home). Sibling
//! lanes need no special handling: each lock phase partitions against
//! the live router, so they pick up the new owner on their next pass.
//! Bounces are bounded by [`MAX_OWNER_BOUNCES`], then degrade to the
//! legacy abort.

use crate::lock::table::LockMode;
use crate::sharding::key::LotusKey;
use crate::txn::api::Isolation;
use crate::txn::coordinator::SharedCluster;
use crate::txn::phases::{unlock, Held, PhaseCtx, TxnFrame, WaitVerdict};
use crate::{abort, AbortReason, Error, Result};

/// Bound on wait-park/retry rounds per lock request: spurious wakeups
/// (the woken key was re-taken by another anachronistic sibling) are
/// harmless, but a pathological re-lock storm must degrade to the abort
/// path rather than loop.
const MAX_LOCK_WAITS: usize = 16;

/// Bound on `WrongShardOwner` bounce-and-retry rounds per lock request
/// (ISSUE 10): a request racing a shard transfer re-resolves the owner
/// from the fresh routing map and retries; a shard that stays paused (or
/// keeps migrating) across this many bounces degrades to the abort path
/// — the pre-bounce behavior.
const MAX_OWNER_BOUNCES: usize = 4;

/// The lock set for `frame.records[from..]`: `(key, mode)` per request.
pub fn requests(
    cluster: &SharedCluster,
    frame: &TxnFrame,
    from: usize,
) -> Vec<(LotusKey, LockMode)> {
    let mut reqs = Vec::with_capacity(frame.records.len() - from + 2);
    for rec in &frame.records[from..] {
        if rec.write {
            reqs.push((rec.r.key, LockMode::Write));
            if rec.insert || rec.delete {
                // Inserts/deletes also lock the index bucket (§4.1) —
                // the whole probe chain, since placement (insert) or
                // residence (delete) may be any bucket in it and the
                // lock-first protocol locks before reading.
                let table = cluster.table(rec.r.table);
                for b in table.probe_buckets(rec.r.key) {
                    reqs.push((table.bucket_lock_key(b), LockMode::Write));
                }
            }
        } else if cluster.cfg.isolation == Isolation::Serializable {
            reqs.push((rec.r.key, LockMode::Read));
        }
    }
    reqs
}

/// One physical acquisition with wait-park triage and wrong-owner
/// bounce-and-retry. `Ok(Some(owner_cn))` acquired — at `owner_cn`,
/// which may differ from the initial `target` if the request bounced to
/// a fresh owner mid-transfer; `Ok(None)` conflict (abort), `Err` fatal.
async fn acquire_one(
    ctx: &mut PhaseCtx<'_>,
    key: LotusKey,
    mode: LockMode,
    holder: crate::lock::state::HolderId,
    target: usize,
    from_remote: bool,
) -> Result<Option<usize>> {
    let router = ctx.cluster.router.clone();
    let mut target = target;
    let mut from_remote = from_remote;
    let mut waits = 0usize;
    let mut bounces = 0usize;
    loop {
        // Interval check per acquisition attempt, not just once per
        // phase: the lane's clock advances between acquisitions, and
        // whole sibling transactions may run while this lane is parked
        // at a wait — either can move a recorded interval over `now`.
        if ctx.sibling_conflict(key, mode) {
            return Ok(None);
        }
        match ctx.cluster.lock_services[target].try_acquire(&router, key, mode, holder, from_remote)
        {
            Ok(true) => {
                ctx.note_lock(key, mode);
                return Ok(Some(target));
            }
            Ok(false) => {
                if waits < MAX_LOCK_WAITS && ctx.wait_verdict(key, mode) == WaitVerdict::Wait {
                    // Anachronistic holder (a suspended sibling that
                    // acquired in our virtual future): park until it
                    // releases, retry at the unchanged virtual time.
                    // The loop head re-runs the interval check before
                    // the retry touches the lock table.
                    waits += 1;
                    ctx.wait_unlock(key).await;
                    continue;
                }
                return Ok(None);
            }
            Err(Error::LockBucketFull) => {
                // Bucket-full — abort; the retry hashes elsewhere.
                return Ok(None);
            }
            Err(Error::WrongShardOwner { .. }) => {
                // Stale route: the shard migrated (or is paused mid-
                // transfer) between routing and acquisition. Not an
                // abort (ISSUE 10): park-and-retry at the unchanged
                // virtual time, re-resolve the owner from the fresh
                // map, and re-dispatch — charging a fresh single-
                // request message if the key re-routes to a different
                // remote CN, or a CPU acquisition if it came home. A
                // shard that keeps bouncing degrades to the abort path
                // after `MAX_OWNER_BOUNCES`.
                if bounces >= MAX_OWNER_BOUNCES {
                    return Ok(None);
                }
                bounces += 1;
                ctx.ep.nic.note_wrong_owner_bounce();
                ctx.bounce_park().await;
                let fresh = router.owner_of_key(key);
                if fresh != target {
                    ctx.cluster.metrics.record_request(fresh, key.shard());
                    if fresh == ctx.cn {
                        ctx.clk.advance(ctx.net().local_lock_ns);
                        from_remote = false;
                    } else {
                        if ctx.issue_rpc(fresh, 1).await.is_err() {
                            return Ok(None);
                        }
                        from_remote = true;
                    }
                    target = fresh;
                }
                continue;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Acquire all locks for `frame.records[from..]` (the lock-first step).
/// On failure, everything already acquired is released and the
/// transaction aborts.
pub async fn acquire(ctx: &mut PhaseCtx<'_>, frame: &mut TxnFrame, from: usize) -> Result<()> {
    let reqs = requests(ctx.cluster, frame, from);
    if reqs.is_empty() {
        return Ok(());
    }
    // Pipelined scheduler: a sibling frame on this coordinator whose
    // in-flight transaction overlaps this one in virtual time may hold a
    // conflicting lock. That conflict is resolved *locally* — a CPU check
    // through the scheduler sink against the recorded lock intervals
    // (committed stamps and suspended lanes' live holdings) — and aborts
    // lock-first, before any bytes leave the CN (not even the remote-lock
    // RPC is sent). Interval-aware: a sibling holding only in this
    // frame's virtual future does not conflict.
    let sibling_conflict = reqs.iter().any(|&(k, m)| ctx.sibling_conflict(k, m));
    if sibling_conflict {
        unlock::release(ctx, frame);
        return Err(abort(AbortReason::LockConflict));
    }
    let router = ctx.cluster.router.clone();
    let holder = frame.holder(ctx.cn);
    // Partition into local and per-remote-CN batches.
    let mut local: Vec<(LotusKey, LockMode)> = Vec::new();
    let mut remote: Vec<(usize, Vec<(LotusKey, LockMode)>)> = Vec::new();
    for (key, mode) in reqs {
        let owner = router.owner_of_key(key);
        ctx.cluster.metrics.record_request(owner, key.shard());
        if owner == ctx.cn {
            local.push((key, mode));
        } else {
            match remote.iter_mut().find(|(cn, _)| *cn == owner) {
                Some((_, v)) => v.push((key, mode)),
                None => remote.push((owner, vec![(key, mode)])),
            }
        }
    }
    // Local locks: CPU CAS (Algorithm 1). A bounce may hand the key to
    // a fresh remote owner mid-acquire — `Held.owner_cn` records where
    // the lock really landed, so the unlock goes to the right CN.
    for &(key, mode) in &local {
        ctx.clk.advance(ctx.net().local_lock_ns);
        let cn = ctx.cn;
        match acquire_one(ctx, key, mode, holder, cn, false).await {
            Ok(Some(owner_cn)) => frame.held.push(Held {
                key,
                mode,
                owner_cn,
            }),
            Ok(None) => {
                unlock::release(ctx, frame);
                return Err(abort(AbortReason::LockConflict));
            }
            Err(e) => return Err(e),
        }
    }
    // Remote locks: one batched RPC per target CN (§4.1) — an RPC-plane
    // issue point. Under the pipelined scheduler the message is staged
    // and the lane parks; sibling lanes' lock batches to the same target
    // CN within the coalescing window share ONE message (each lane's
    // clock charged only to the handler completing its own batch).
    for (target, batch) in remote {
        // Lease-driven suspicion, degraded gracefully (ISSUE 7): a
        // target under suspicion is proactively aborted against instead
        // of burning timeouts toward a node that may be gone. A
        // suspected-but-alive target makes this a *false* suspicion
        // (counted); it rejoins by simply outliving its window — its
        // ephemeral lock table is never rebuilt or cleared for a mere
        // suspicion.
        if ctx.cluster.membership.is_suspected(target, ctx.clk.now()) {
            ctx.ep.nic.note_degraded_abort();
            if ctx.cluster.membership.is_serving(target) {
                ctx.ep.nic.note_false_suspicion();
            }
            unlock::release(ctx, frame);
            return Err(abort(AbortReason::OwnerFailed));
        }
        // A lost or timed-out lock message reissues with capped
        // exponential backoff up to `rpc_max_retries`, parking the lane
        // (`Flight::RetryAt`) between attempts so siblings keep running.
        // With retries disabled (the default) a single timeout aborts —
        // the pre-retry behavior: the paper aborts transactions waiting
        // on a failed CN's locks (§6).
        let mut attempt = 0u32;
        loop {
            match ctx.issue_rpc(target, batch.len()).await {
                Ok(()) => break,
                Err(_) if attempt < ctx.cluster.cfg.rpc_max_retries => {
                    ctx.ep.nic.note_rpc_retry();
                    let base = ctx.cluster.cfg.rpc_backoff_base_ns;
                    ctx.retry_backoff(base << attempt.min(4)).await;
                    attempt += 1;
                }
                Err(_) => {
                    unlock::release(ctx, frame);
                    return Err(abort(AbortReason::OwnerFailed));
                }
            }
        }
        for &(key, mode) in &batch {
            match acquire_one(ctx, key, mode, holder, target, true).await {
                Ok(Some(owner_cn)) => frame.held.push(Held {
                    key,
                    mode,
                    owner_cn,
                }),
                Ok(None) => {
                    unlock::release(ctx, frame);
                    return Err(abort(AbortReason::LockConflict));
                }
                Err(e) => return Err(e),
            }
        }
    }
    Ok(())
}
