//! Phase 1 — *Lock Data* (lock-first, paper §5.1 + Algorithm 1).
//!
//! Write locks for the read-write set, read locks for the read-only set
//! (SR only); inserts and deletes also lock the index bucket's probe
//! chain (§4.1). Locally owned keys are CPU CAS on the local lock table;
//! remote keys are batched **per owner CN** into one RPC each. Any
//! failure releases everything already acquired and aborts — before a
//! single byte is read from the memory pool.

use crate::lock::table::LockMode;
use crate::sharding::key::LotusKey;
use crate::txn::api::Isolation;
use crate::txn::coordinator::SharedCluster;
use crate::txn::phases::{unlock, Held, PhaseCtx, TxnFrame};
use crate::{abort, AbortReason, Error, Result};

/// The lock set for `frame.records[from..]`: `(key, mode)` per request.
pub fn requests(
    cluster: &SharedCluster,
    frame: &TxnFrame,
    from: usize,
) -> Vec<(LotusKey, LockMode)> {
    let mut reqs = Vec::with_capacity(frame.records.len() - from + 2);
    for rec in &frame.records[from..] {
        if rec.write {
            reqs.push((rec.r.key, LockMode::Write));
            if rec.insert || rec.delete {
                // Inserts/deletes also lock the index bucket (§4.1) —
                // the whole probe chain, since placement (insert) or
                // residence (delete) may be any bucket in it and the
                // lock-first protocol locks before reading.
                let table = cluster.table(rec.r.table);
                for b in table.probe_buckets(rec.r.key) {
                    reqs.push((table.bucket_lock_key(b), LockMode::Write));
                }
            }
        } else if cluster.cfg.isolation == Isolation::Serializable {
            reqs.push((rec.r.key, LockMode::Read));
        }
    }
    reqs
}

/// Acquire all locks for `frame.records[from..]` (the lock-first step).
/// On failure, everything already acquired is released and the
/// transaction aborts.
pub fn acquire(ctx: &mut PhaseCtx<'_>, frame: &mut TxnFrame, from: usize) -> Result<()> {
    let reqs = requests(ctx.cluster, frame, from);
    if reqs.is_empty() {
        return Ok(());
    }
    // Pipelined scheduler: a sibling frame on this coordinator whose
    // in-flight transaction overlaps this one in virtual time may hold a
    // conflicting lock. That conflict is resolved *locally* — a CPU check
    // through the scheduler sink against the sibling lock intervals —
    // and aborts lock-first, before any bytes leave the CN (not even the
    // remote-lock RPC is sent).
    let sibling_conflict = reqs.iter().any(|&(k, m)| ctx.sibling_conflict(k, m));
    if sibling_conflict {
        unlock::release(ctx, frame);
        return Err(abort(AbortReason::LockConflict));
    }
    let router = ctx.cluster.router.clone();
    let holder = frame.holder(ctx.cn);
    // Partition into local and per-remote-CN batches.
    let mut local: Vec<(LotusKey, LockMode)> = Vec::new();
    let mut remote: Vec<(usize, Vec<(LotusKey, LockMode)>)> = Vec::new();
    for (key, mode) in reqs {
        let owner = router.owner_of_key(key);
        ctx.cluster.metrics.record_request(owner, key.shard());
        if owner == ctx.cn {
            local.push((key, mode));
        } else {
            match remote.iter_mut().find(|(cn, _)| *cn == owner) {
                Some((_, v)) => v.push((key, mode)),
                None => remote.push((owner, vec![(key, mode)])),
            }
        }
    }
    // Local locks: CPU CAS (Algorithm 1).
    for &(key, mode) in &local {
        ctx.clk.advance(ctx.net().local_lock_ns);
        match ctx.cluster.lock_services[ctx.cn].try_acquire(&router, key, mode, holder, false) {
            Ok(true) => frame.held.push(Held {
                key,
                mode,
                owner_cn: ctx.cn,
            }),
            Ok(false) => {
                unlock::release(ctx, frame);
                return Err(abort(AbortReason::LockConflict));
            }
            Err(Error::LockBucketFull) => {
                unlock::release(ctx, frame);
                return Err(abort(AbortReason::LockConflict));
            }
            Err(Error::WrongShardOwner { .. }) => {
                // Stale route (shard migrating) — abort; the retry will
                // see the fresh map.
                unlock::release(ctx, frame);
                return Err(abort(AbortReason::LockConflict));
            }
            Err(e) => return Err(e),
        }
    }
    // Remote locks: one batched RPC per target CN (§4.1).
    for (target, batch) in remote {
        ctx.ep.gate_sync(ctx.clk);
        if let Err(e) = ctx
            .cluster
            .rpc
            .call(ctx.cn, target, ctx.slot, batch.len(), ctx.clk)
        {
            // CN failed: the paper aborts transactions waiting on the
            // failed CN's locks (§6).
            let _ = e;
            unlock::release(ctx, frame);
            return Err(abort(AbortReason::OwnerFailed));
        }
        for &(key, mode) in &batch {
            match ctx.cluster.lock_services[target].try_acquire(&router, key, mode, holder, true) {
                Ok(true) => frame.held.push(Held {
                    key,
                    mode,
                    owner_cn: target,
                }),
                Ok(false) | Err(Error::LockBucketFull) | Err(Error::WrongShardOwner { .. }) => {
                    unlock::release(ctx, frame);
                    return Err(abort(AbortReason::LockConflict));
                }
                Err(e) => return Err(e),
            }
        }
    }
    Ok(())
}
