//! The LOTUS protocol pipeline, one module per phase (paper fig. 10).
//!
//! The paper's protocol is explicitly staged:
//!
//! ```text
//! Execution:  Lock  ->  Read CVT  ->  Read Data
//! Commit:     Write Data & Log  ->  Timestamp  ->  Visible  ->  Unlock
//! ```
//!
//! Each stage lives in its own module —
//!
//! - [`lock`] — the lock-first step: CPU CAS for locally owned keys, one
//!   batched RPC per remote owner CN; any failure aborts before a single
//!   byte is read from the memory pool.
//! - [`read`] — CVT resolution (VT cache / address cache / bucket probe)
//!   and MVCC record reads, doorbell-batched per MN.
//! - [`write_log`] — new versions (INVISIBLE) + the metadata commit log,
//!   planned into one [`crate::dm::OpBatch`] covering primaries and
//!   backups; also the commit-timestamp *Write Visible* sweep.
//! - [`commit`] — the commit orchestration: doomed check, timestamp
//!   draw, VT-cache synchronization, async log clear, unlock.
//! - [`unlock`] — release of all held locks: local CPU ops, remote
//!   fire-and-forget RPCs (the coordinator does not wait, paper 5.1).
//!
//! — and operates on a [`TxnFrame`] (the per-transaction state: read and
//! write sets, CVT snapshots, held locks) through a [`PhaseCtx`] (the
//! coordinator's environment: cluster state, endpoint, virtual clock).
//! Phases **plan** their one-sided ops into [`crate::dm::OpBatch`]es and
//! hand them to [`PhaseCtx::issue`] / [`PhaseCtx::issue_deferred`]: the
//! sequential coordinator issues them directly, while the pipelined
//! [`crate::txn::scheduler::FrameScheduler`] merges plans from multiple
//! in-flight frames into shared doorbell rings and routes each frame its
//! own results (cross-transaction doorbell coalescing).

pub mod commit;
pub mod lock;
pub mod read;
pub mod unlock;
pub mod write_log;

#[cfg(test)]
mod tests;

use std::cell::RefCell;

use crate::dm::clock::VClock;
use crate::dm::opbatch::{BatchResult, OpBatch};
use crate::dm::verbs::Endpoint;
use crate::dm::NetConfig;
use crate::lock::state::HolderId;
use crate::lock::table::LockMode;
use crate::sharding::key::LotusKey;
use crate::store::cvt::CvtSnapshot;
use crate::txn::api::{Isolation, RecordRef};
use crate::txn::coordinator::SharedCluster;
use crate::txn::scheduler::{Coalescer, SiblingLocks};

/// Per-record transaction state (one entry of the read/write set).
#[derive(Debug, Clone)]
pub struct TxnRecord {
    /// The record reference.
    pub r: RecordRef,
    /// Write intent (vs read-lock only).
    pub write: bool,
    /// Insert (vs update of an existing record).
    pub insert: bool,
    /// Delete (clears the CVT at commit).
    pub delete: bool,
    /// Value read by `execute` (update/read paths).
    pub value: Option<Vec<u8>>,
    /// Staged new value.
    pub new_value: Option<Vec<u8>>,
    /// The CVT observed at execute (fresh template for inserts).
    pub cvt: Option<CvtSnapshot>,
    /// Primary CVT address.
    pub cvt_addr: u64,
    /// Index bucket.
    pub bucket: u64,
    /// CVT slot within the bucket.
    pub slot: u8,
    /// True if the CVT came from this CN's VT cache.
    pub from_cache: bool,
    /// VT-cache epoch captured before a lock-free CVT read (RO fills).
    pub fill_epoch: Option<u64>,
}

impl TxnRecord {
    /// A fresh set entry for `r` with the given write intent.
    pub fn new(r: RecordRef, write: bool) -> Self {
        Self {
            r,
            write,
            insert: false,
            delete: false,
            value: None,
            new_value: None,
            cvt: None,
            cvt_addr: 0,
            bucket: 0,
            slot: 0,
            from_cache: false,
            fill_epoch: None,
        }
    }
}

/// A held lock (everything needed to release it).
#[derive(Debug, Clone, Copy)]
pub struct Held {
    /// Locked key.
    pub key: LotusKey,
    /// Held mode.
    pub mode: LockMode,
    /// CN whose lock table holds the lock.
    pub owner_cn: usize,
}

/// The per-transaction state threaded through the phase pipeline.
///
/// A frame is reused across transactions (a coordinator runs one at a
/// time); [`TxnFrame::reset`] rearms it at `begin`.
#[derive(Debug, Default)]
pub struct TxnFrame {
    /// Transaction id (globally unique; 0 before the first `begin`).
    pub txn_id: u64,
    /// Read-only transaction (no locks, snapshot reads)?
    pub read_only: bool,
    /// Start timestamp (HLC).
    pub start_ts: u64,
    /// The read/write set in declaration order.
    pub records: Vec<TxnRecord>,
    /// Records below this index were handled by a previous `execute`
    /// round (the paper: "execution may occur multiple times, dynamically
    /// adding new data to the read/write sets").
    pub executed_upto: usize,
    /// Locks currently held by this transaction.
    pub held: Vec<Held>,
    /// Lazily built hash index over `records` backing [`TxnFrame::find`]
    /// (a linear scan is quadratic over TPC-C-sized read/write sets).
    index: RefCell<RecordIndex>,
}

/// Open-addressed `(hash, position+1)` index over a frame's records.
/// Built lazily by [`TxnFrame::find`], so records may keep being pushed
/// straight onto `TxnFrame::records`; `sync` indexes the new tail.
#[derive(Debug, Default)]
struct RecordIndex {
    /// Power-of-two slot array; `(_, 0)` means empty.
    slots: Vec<(u64, u32)>,
    /// `records[..built]` are reflected in `slots`.
    built: usize,
}

/// SplitMix64 over (table, key) — the record-set hash.
#[inline]
fn hash_ref(r: RecordRef) -> u64 {
    let mut z = r.key.0 ^ ((r.table as u64) << 48) ^ 0x9E37_79B9_7F4A_7C15;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl RecordIndex {
    fn clear(&mut self) {
        self.slots.clear();
        self.built = 0;
    }

    fn capacity_for(n: usize) -> usize {
        (n.max(4) * 4).next_power_of_two()
    }

    /// Index any records appended since the last sync (rebuilding on
    /// growth so the load factor stays below 1/2).
    fn sync(&mut self, records: &[TxnRecord]) {
        if records.len() == self.built {
            return;
        }
        if self.slots.len() < Self::capacity_for(records.len()) {
            self.slots = vec![(0, 0); Self::capacity_for(records.len())];
            self.built = 0;
        }
        for i in self.built..records.len() {
            self.insert(records, i);
        }
        self.built = records.len();
    }

    fn insert(&mut self, records: &[TxnRecord], i: usize) {
        let r = records[i].r;
        let h = hash_ref(r);
        let mask = self.slots.len() - 1;
        let mut pos = (h as usize) & mask;
        loop {
            let (sh, sp) = self.slots[pos];
            if sp == 0 {
                self.slots[pos] = (h, (i + 1) as u32);
                return;
            }
            if sh == h && records[(sp - 1) as usize].r == r {
                return; // keep the first occurrence (`position` semantics)
            }
            pos = (pos + 1) & mask;
        }
    }

    fn get(&self, records: &[TxnRecord], r: RecordRef) -> Option<usize> {
        if self.slots.is_empty() {
            return None;
        }
        let h = hash_ref(r);
        let mask = self.slots.len() - 1;
        let mut pos = (h as usize) & mask;
        loop {
            let (sh, sp) = self.slots[pos];
            if sp == 0 {
                return None;
            }
            if sh == h && records[(sp - 1) as usize].r == r {
                return Some((sp - 1) as usize);
            }
            pos = (pos + 1) & mask;
        }
    }
}

impl TxnFrame {
    /// An empty frame (no transaction in flight).
    pub fn new() -> Self {
        Self::default()
    }

    /// Rearm for a new transaction.
    pub fn reset(&mut self, txn_id: u64, read_only: bool, start_ts: u64) {
        self.records.clear();
        self.held.clear();
        self.index.borrow_mut().clear();
        self.executed_upto = 0;
        self.read_only = read_only;
        self.txn_id = txn_id;
        self.start_ts = start_ts;
    }

    /// Drop all in-flight state **without releasing locks** (fail-stop
    /// crash; recovery owns the locks, paper §6).
    pub fn crash(&mut self) {
        self.records.clear();
        self.held.clear();
        self.index.borrow_mut().clear();
        self.executed_upto = 0;
    }

    /// Index of `r` in the set, if present (first occurrence). O(1)
    /// expected: served from a lazily synced hash index, not a scan.
    pub fn find(&self, r: RecordRef) -> Option<usize> {
        let mut ix = self.index.borrow_mut();
        ix.sync(&self.records);
        ix.get(&self.records, r)
    }

    /// This transaction's lock-holder identity on CN `cn`.
    #[inline]
    pub fn holder(&self, cn: usize) -> HolderId {
        HolderId {
            cn,
            txn: self.txn_id,
        }
    }
}

/// The coordinator-side environment a phase executes in.
///
/// Borrowed fresh from the coordinator for each phase call; separate from
/// [`TxnFrame`] so a phase can mutate the frame and charge the clock at
/// the same time.
pub struct PhaseCtx<'a> {
    /// Cluster-wide shared state.
    pub cluster: &'a SharedCluster,
    /// The executing coordinator's CN.
    pub cn: usize,
    /// Coordinator slot within the CN (RPC pairing, §4.1).
    pub slot: usize,
    /// Global coordinator id (log-slot index).
    pub global_id: usize,
    /// The coordinator's verb endpoint.
    pub ep: &'a Endpoint,
    /// The executing frame's virtual clock (the lane clock under the
    /// pipelined scheduler, the coordinator clock otherwise).
    pub clk: &'a mut VClock,
    /// Cross-transaction doorbell coalescer — `Some` under the pipelined
    /// [`crate::txn::scheduler::FrameScheduler`]; `None` issues planned
    /// batches directly (sequential coordinator, recovery, baselines).
    pub coalescer: Option<&'a Coalescer>,
    /// Lock intervals of sibling frames on the same scheduler, used by
    /// the lock phase to abort lock-first conflicts between pipelined
    /// frames locally — before any bytes leave the CN.
    pub siblings: Option<SiblingLocks<'a>>,
}

impl PhaseCtx<'_> {
    /// Cost model shorthand.
    #[inline]
    pub fn net(&self) -> &NetConfig {
        &self.cluster.net
    }

    /// Effective isolation level.
    #[inline]
    pub fn isolation(&self) -> Isolation {
        self.cluster.cfg.isolation
    }

    /// Issue a phase's planned batch and wait for this frame's results:
    /// through the [`Coalescer`] when pipelined (the plan merges into a
    /// shared doorbell ring with sibling frames' plans and only this
    /// frame's op completions charge `clk`), directly otherwise.
    pub fn issue(&mut self, batch: OpBatch) -> crate::Result<BatchResult> {
        match self.coalescer {
            Some(c) => c.issue(batch, self.ep, &self.cluster.mns, self.clk),
            None => batch.issue(self.ep, &self.cluster.mns, self.clk),
        }
    }

    /// Issue a fire-and-forget plan off the critical path (remote log
    /// clears): parked with the [`Coalescer`] to ride a sibling frame's
    /// next doorbell when pipelined, `issue_async` otherwise.
    pub fn issue_deferred(&mut self, batch: OpBatch) -> crate::Result<()> {
        match self.coalescer {
            Some(c) => {
                c.defer(batch, self.clk.now());
                Ok(())
            }
            None => batch.issue_async(self.ep, &self.cluster.mns, self.clk),
        }
    }
}

/// Shared *Begin*: draw the transaction id and start timestamp (charging
/// the oracle access to `clk`) and rearm the frame. One implementation
/// for the sequential coordinator and every scheduler lane, so their
/// accounting cannot drift.
pub fn begin(cluster: &SharedCluster, clk: &mut VClock, frame: &mut TxnFrame, read_only: bool) {
    let txn_id = cluster.next_txn_id();
    let start_ts = cluster.oracle.timestamp(clk, cluster.net.ts_oracle_ns);
    frame.reset(txn_id, read_only, start_ts);
}

/// Shared *Commit* entry: charge the application-logic CPU window, then
/// run the read-write commit pipeline (read-only transactions have
/// nothing to write). Same single-implementation rationale as [`begin`].
pub fn commit_txn(ctx: &mut PhaseCtx<'_>, frame: &mut TxnFrame) -> crate::Result<()> {
    // Application logic between execute and commit.
    ctx.clk.advance(ctx.net().txn_logic_ns);
    if frame.read_only {
        Ok(())
    } else {
        commit::commit_rw(ctx, frame)
    }
}

/// One full execution round over `frame.records[frame.executed_upto..]`:
/// lock-first (read-write transactions only), then Read CVT, then Read
/// Data. On `Err` the transaction is already rolled back (locks freed).
pub fn execute(ctx: &mut PhaseCtx<'_>, frame: &mut TxnFrame) -> crate::Result<()> {
    let from = frame.executed_upto;
    if !frame.read_only {
        lock::acquire(ctx, frame, from)?;
    }
    read::read_cvt(ctx, frame, from)?;
    read::read_data(ctx, frame, from)?;
    frame.executed_upto = frame.records.len();
    Ok(())
}
