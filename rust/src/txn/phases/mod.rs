//! The LOTUS protocol pipeline, one module per phase (paper fig. 10).
//!
//! The paper's protocol is explicitly staged:
//!
//! ```text
//! Execution:  Lock  ->  Read CVT  ->  Read Data
//! Commit:     Write Data & Log  ->  Timestamp  ->  Visible  ->  Unlock
//! ```
//!
//! Each stage lives in its own module —
//!
//! - [`lock`] — the lock-first step: CPU CAS for locally owned keys, one
//!   batched RPC per remote owner CN; any failure aborts before a single
//!   byte is read from the memory pool.
//! - [`read`] — CVT resolution (VT cache / address cache / bucket probe)
//!   and MVCC record reads, doorbell-batched per MN.
//! - [`write_log`] — new versions (INVISIBLE) + the metadata commit log,
//!   planned into one [`crate::dm::OpBatch`] covering primaries and
//!   backups; also the commit-timestamp *Write Visible* sweep.
//! - [`commit`] — the commit orchestration: doomed check, timestamp
//!   draw, VT-cache synchronization, async log clear, unlock.
//! - [`unlock`] — release of all held locks: local CPU ops, remote
//!   fire-and-forget RPCs (the coordinator does not wait, paper 5.1).
//!
//! — and operates on a [`TxnFrame`] (the per-transaction state: read and
//! write sets, CVT snapshots, held locks) through a [`PhaseCtx`] (the
//! coordinator's environment: cluster state, endpoint, virtual clock).
//! The split is what later work batches and pipelines across: a phase is
//! a function of `(ctx, frame)`, so frames from different transactions
//! can be staged through the same phase back to back.

pub mod commit;
pub mod lock;
pub mod read;
pub mod unlock;
pub mod write_log;

#[cfg(test)]
mod tests;

use crate::dm::clock::VClock;
use crate::dm::verbs::Endpoint;
use crate::dm::NetConfig;
use crate::lock::state::HolderId;
use crate::lock::table::LockMode;
use crate::sharding::key::LotusKey;
use crate::store::cvt::CvtSnapshot;
use crate::txn::api::{Isolation, RecordRef};
use crate::txn::coordinator::SharedCluster;

/// Per-record transaction state (one entry of the read/write set).
#[derive(Debug, Clone)]
pub struct TxnRecord {
    /// The record reference.
    pub r: RecordRef,
    /// Write intent (vs read-lock only).
    pub write: bool,
    /// Insert (vs update of an existing record).
    pub insert: bool,
    /// Delete (clears the CVT at commit).
    pub delete: bool,
    /// Value read by `execute` (update/read paths).
    pub value: Option<Vec<u8>>,
    /// Staged new value.
    pub new_value: Option<Vec<u8>>,
    /// The CVT observed at execute (fresh template for inserts).
    pub cvt: Option<CvtSnapshot>,
    /// Primary CVT address.
    pub cvt_addr: u64,
    /// Index bucket.
    pub bucket: u64,
    /// CVT slot within the bucket.
    pub slot: u8,
    /// True if the CVT came from this CN's VT cache.
    pub from_cache: bool,
    /// VT-cache epoch captured before a lock-free CVT read (RO fills).
    pub fill_epoch: Option<u64>,
}

impl TxnRecord {
    /// A fresh set entry for `r` with the given write intent.
    pub fn new(r: RecordRef, write: bool) -> Self {
        Self {
            r,
            write,
            insert: false,
            delete: false,
            value: None,
            new_value: None,
            cvt: None,
            cvt_addr: 0,
            bucket: 0,
            slot: 0,
            from_cache: false,
            fill_epoch: None,
        }
    }
}

/// A held lock (everything needed to release it).
#[derive(Debug, Clone, Copy)]
pub struct Held {
    /// Locked key.
    pub key: LotusKey,
    /// Held mode.
    pub mode: LockMode,
    /// CN whose lock table holds the lock.
    pub owner_cn: usize,
}

/// The per-transaction state threaded through the phase pipeline.
///
/// A frame is reused across transactions (a coordinator runs one at a
/// time); [`TxnFrame::reset`] rearms it at `begin`.
#[derive(Debug, Default)]
pub struct TxnFrame {
    /// Transaction id (globally unique; 0 before the first `begin`).
    pub txn_id: u64,
    /// Read-only transaction (no locks, snapshot reads)?
    pub read_only: bool,
    /// Start timestamp (HLC).
    pub start_ts: u64,
    /// The read/write set in declaration order.
    pub records: Vec<TxnRecord>,
    /// Records below this index were handled by a previous `execute`
    /// round (the paper: "execution may occur multiple times, dynamically
    /// adding new data to the read/write sets").
    pub executed_upto: usize,
    /// Locks currently held by this transaction.
    pub held: Vec<Held>,
}

impl TxnFrame {
    /// An empty frame (no transaction in flight).
    pub fn new() -> Self {
        Self::default()
    }

    /// Rearm for a new transaction.
    pub fn reset(&mut self, txn_id: u64, read_only: bool, start_ts: u64) {
        self.records.clear();
        self.held.clear();
        self.executed_upto = 0;
        self.read_only = read_only;
        self.txn_id = txn_id;
        self.start_ts = start_ts;
    }

    /// Drop all in-flight state **without releasing locks** (fail-stop
    /// crash; recovery owns the locks, paper §6).
    pub fn crash(&mut self) {
        self.records.clear();
        self.held.clear();
        self.executed_upto = 0;
    }

    /// Index of `r` in the set, if present.
    pub fn find(&self, r: RecordRef) -> Option<usize> {
        self.records.iter().position(|rec| rec.r == r)
    }

    /// This transaction's lock-holder identity on CN `cn`.
    #[inline]
    pub fn holder(&self, cn: usize) -> HolderId {
        HolderId {
            cn,
            txn: self.txn_id,
        }
    }
}

/// The coordinator-side environment a phase executes in.
///
/// Borrowed fresh from the coordinator for each phase call; separate from
/// [`TxnFrame`] so a phase can mutate the frame and charge the clock at
/// the same time.
pub struct PhaseCtx<'a> {
    /// Cluster-wide shared state.
    pub cluster: &'a SharedCluster,
    /// The executing coordinator's CN.
    pub cn: usize,
    /// Coordinator slot within the CN (RPC pairing, §4.1).
    pub slot: usize,
    /// Global coordinator id (log-slot index).
    pub global_id: usize,
    /// The coordinator's verb endpoint.
    pub ep: &'a Endpoint,
    /// The coordinator's virtual clock.
    pub clk: &'a mut VClock,
}

impl PhaseCtx<'_> {
    /// Cost model shorthand.
    #[inline]
    pub fn net(&self) -> &NetConfig {
        &self.cluster.net
    }

    /// Effective isolation level.
    #[inline]
    pub fn isolation(&self) -> Isolation {
        self.cluster.cfg.isolation
    }
}

/// One full execution round over `frame.records[frame.executed_upto..]`:
/// lock-first (read-write transactions only), then Read CVT, then Read
/// Data. On `Err` the transaction is already rolled back (locks freed).
pub fn execute(ctx: &mut PhaseCtx<'_>, frame: &mut TxnFrame) -> crate::Result<()> {
    let from = frame.executed_upto;
    if !frame.read_only {
        lock::acquire(ctx, frame, from)?;
    }
    read::read_cvt(ctx, frame, from)?;
    read::read_data(ctx, frame, from)?;
    frame.executed_upto = frame.records.len();
    Ok(())
}
