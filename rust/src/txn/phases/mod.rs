//! The LOTUS protocol pipeline, one module per phase (paper fig. 10).
//!
//! The paper's protocol is explicitly staged:
//!
//! ```text
//! Execution:  Lock  ->  Read CVT  ->  Read Data
//! Commit:     Write Data & Log  ->  Timestamp  ->  Visible  ->  Unlock
//! ```
//!
//! Each stage lives in its own module —
//!
//! - [`lock`] — the lock-first step: CPU CAS for locally owned keys, one
//!   batched RPC per remote owner CN; any failure aborts before a single
//!   byte is read from the memory pool.
//! - [`read`] — CVT resolution (VT cache / address cache / bucket probe)
//!   and MVCC record reads, doorbell-batched per MN.
//! - [`write_log`] — new versions (INVISIBLE) + the metadata commit log,
//!   planned into one [`crate::dm::OpBatch`] covering primaries and
//!   backups; also the commit-timestamp *Write Visible* sweep.
//! - [`commit`] — the commit orchestration: doomed check, timestamp
//!   draw, VT-cache synchronization, async log clear, unlock.
//! - [`unlock`] — release of all held locks: local CPU ops, remote
//!   fire-and-forget RPCs (the coordinator does not wait, paper 5.1).
//!
//! — and operates on a [`TxnFrame`] (the per-transaction state: read and
//! write sets, CVT snapshots, held locks) through a [`PhaseCtx`] (the
//! coordinator's environment: cluster state, endpoint, virtual clock).
//!
//! # The reified continuation contract (ISSUE 4 + ISSUE 5)
//!
//! Phases **plan** their fabric work as [`Plan`]s — a one-sided
//! [`crate::dm::OpBatch`] against the memory pool (the **doorbell
//! plane**) or a batched lock-class RPC message to a sibling CN (the
//! **RPC plane**) — and hand them to [`PhaseCtx::issue`] /
//! [`PhaseCtx::issue_rpc`] / the deferred variants: the only points at
//! which a phase touches either fabric. Every phase (and the workload
//! driver above it) is a **resumable step machine**
//! ([`crate::txn::step::StepFut`]), cut at exactly those issue points;
//! `Poll::Pending` is the *Issued* state, `Poll::Ready` is *Done*. The
//! conduit behind the issue point decides how execution proceeds:
//!
//! - **Direct** (`sink: None`, or a non-staging sink — the sequential
//!   coordinator, recovery, baselines, `pipeline_depth == 1`,
//!   `coalesce_window_ns == 0`): the planned batch is issued immediately
//!   and the machine runs straight through the await — a single poll is
//!   the classic blocking phase call ([`crate::txn::step::expect_ready`]).
//! - **Staging** ([`StepSink`] with [`StepSink::stages`] true — the
//!   pipelined [`crate::txn::scheduler::FrameScheduler`]): the plan is
//!   *posted* to the scheduler's in-flight table (`Flight::Staged` —
//!   doorbell WQEs with the ring deferred, or an RPC message with the
//!   SEND deferred) and the machine returns `Poll::Pending` — the lane
//!   is parked on the heap with no OS stack frame pinning it. The
//!   scheduler's ready-queue loop keeps polling other runnable lanes;
//!   when it rings, staged doorbell plans merge into one doorbell set
//!   per MN and staged RPC plans to the **same destination CN** merge
//!   into one RPC message (within `coalesce_window_ns`), every covered
//!   lane's in-flight slot flips to its Done state and the lane
//!   re-enters the ready queue at its own completion time, to be
//!   resumed in completion-clock order — in *any* interleaving, not the
//!   stack-unwind (LIFO) order of the old nested-pump design. On resume
//!   the machine receives its own results (never a sibling's), and its
//!   virtual clock is charged only to its own slowest completion.
//!
//! The phase code is identical under every conduit — park/resume is
//! entirely the sink's concern — which is what keeps the
//! `pipeline_depth=0` legacy shell and the depth-1 exact-equivalence
//! invariant alive as correctness anchors.
//!
//! The sink also carries the lock phase's sibling-conflict machinery:
//! recorded **virtual lock intervals** (committed transactions' `[from,
//! until)` stamps plus suspended lanes' live `[from, ..)` holdings), so
//! conflicts between lanes are decided by virtual-time overlap, never by
//! raw physical holder state (see [`crate::txn::scheduler`] docs).
//!
//! Knobs: `pipeline_depth` (lanes per coordinator thread; 0 = legacy
//! sequential shell, 1 = scheduler with direct issue — bit-for-bit equal
//! accounting to the shell — and >= 2 enables staging) and
//! `coalesce_window_ns` (how far apart, in virtual ns, two frames' issue
//! points may be and still share a doorbell ring; 0 disables staging and
//! coalescing entirely — deferred fire-and-forget plans then issue
//! immediately instead of parking).

pub mod commit;
pub mod lock;
pub mod read;
pub mod unlock;
pub mod write_log;

#[cfg(test)]
mod tests;

use std::cell::RefCell;
use std::future::Future;
use std::pin::Pin;
use std::task::{Context, Poll};

use crate::dm::clock::VClock;
use crate::dm::opbatch::{BatchResult, BufPool, OpBatch};
use crate::dm::verbs::Endpoint;
use crate::dm::NetConfig;
use crate::lock::state::HolderId;
use crate::lock::table::LockMode;
use crate::sharding::key::LotusKey;
use crate::store::cvt::CvtSnapshot;
use crate::txn::api::{Isolation, RecordRef};
use crate::txn::coordinator::SharedCluster;

/// The lock phase's triage when a *physical* acquisition fails (see
/// [`StepSink::wait_verdict`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WaitVerdict {
    /// Genuine conflict in virtual time: abort lock-first.
    Abort,
    /// The physical holder is a suspended sibling lane that acquired the
    /// lock in the requester's virtual *future* (an anachronism of the
    /// simulation, not a conflict of the modeled timeline): park until
    /// the sibling releases, then retry at the unchanged virtual time.
    Wait,
}

/// A staged unit of fabric work — what a phase machine posts at an issue
/// point. The two planes of the disaggregated design (ISSUE 5):
///
/// - [`Plan::Doorbell`] — one-sided verbs against the memory pool,
///   merged per target MN into shared doorbell rings.
/// - [`Plan::Rpc`] — a batched lock-class CN-to-CN message, merged per
///   destination CN into shared RPC sends (the paper's "multiple remote
///   lock requests ... batched into a single RDMA message", §4.1,
///   generalized across sibling lanes).
#[derive(Debug)]
pub enum Plan {
    /// A planned one-sided doorbell batch (memory-pool plane).
    Doorbell(OpBatch),
    /// `n_reqs` lock-class requests for `dst_cn`'s lock service
    /// (CN-to-CN RPC plane).
    Rpc {
        /// Destination CN (owner of the locks).
        dst_cn: usize,
        /// Lock/unlock requests carried by the message.
        n_reqs: usize,
    },
}

impl Plan {
    /// Nothing to issue?
    pub fn is_empty(&self) -> bool {
        match self {
            Plan::Doorbell(b) => b.is_empty(),
            Plan::Rpc { n_reqs, .. } => *n_reqs == 0,
        }
    }

    /// Destination CN of an RPC-plane plan (`None` for doorbell plans) —
    /// the key the adaptive coalescing controller tracks congestion
    /// under on the RPC plane.
    pub fn rpc_dst(&self) -> Option<usize> {
        match self {
            Plan::Doorbell(_) => None,
            Plan::Rpc { dst_cn, .. } => Some(*dst_cn),
        }
    }
}

/// The conduit behind a phase machine's issue points (see the module
/// docs). Implemented by the pipelined scheduler's shared state; poll
/// driven — no method ever blocks or pumps sibling lanes, the machine
/// parks (`Poll::Pending`) and the scheduler's ready-queue loop resumes
/// it.
pub trait StepSink {
    /// Does this conduit stage plans (`pipeline_depth >= 2` with a
    /// nonzero coalescing window)? When false, every issue is direct and
    /// phase machines never park.
    fn stages(&self) -> bool;

    /// Ring out any parked fire-and-forget riders at virtual time `now`
    /// (an empty sync plan reached an issue point: it costs nothing
    /// itself but gives waiting riders their doorbell). No-op without
    /// riders.
    fn flush_riders(&self, lane: usize, now: u64) -> crate::Result<()>;

    /// Post a plan into the in-flight table (`Flight::Staged`) with its
    /// doorbell ring / RPC send deferred. The machine returns
    /// `Poll::Pending` right after.
    fn post(&self, lane: usize, plan: Plan, t_post: u64);

    /// Take the lane's results if its staged doorbell plan has completed
    /// (`Flight::Done`): `(results, completion time of the lane's
    /// slowest op, ok)` — `ok == false` means an injected doorbell fault
    /// hit one of the lane's rings and the batch must count as lost.
    fn try_take(&self, lane: usize) -> Option<(BatchResult, u64, bool)>;

    /// Take the lane's RPC reply if its staged RPC plan has completed:
    /// `(reply arrived (false == destination CN failed), completion
    /// time)`.
    fn try_take_rpc(&self, lane: usize) -> Option<(bool, u64)>;

    /// Park a fire-and-forget plan (commit-log clears, remote unlock
    /// messages) to ride a later doorbell ring / RPC send to the same
    /// destination; `clk` advances only if the plan is issued inline (no
    /// coalescer: immediate fire-and-forget issue).
    fn issue_deferred(&self, lane: usize, plan: Plan, clk: &mut VClock) -> crate::Result<()>;

    /// Would acquiring `mode` on `key` at virtual time `now` conflict
    /// with a sibling lane's transaction whose recorded lock interval
    /// (committed or live) *covers* `now`? Interval-aware: a sibling
    /// holding only in the requester's virtual future does not conflict.
    fn sibling_conflict(&self, lane: usize, key: LotusKey, mode: LockMode, now: u64) -> bool;

    /// Record a physical lock acquisition (live interval `[now, ..)`).
    fn note_lock(&self, lane: usize, key: LotusKey, mode: LockMode, now: u64);

    /// All of `lane`'s locks were physically released at virtual time
    /// `now`: drop its live intervals and wake lanes parked waiting on
    /// them (recording each woken lane's wait span, `now - park time`).
    fn note_unlock_all(&self, lane: usize, now: u64);

    /// Triage a failed physical acquisition of `key` (requested in
    /// `mode`) at time `now`.
    fn wait_verdict(&self, lane: usize, key: LotusKey, mode: LockMode, now: u64) -> WaitVerdict;

    /// Virtual-time floor the owning coordinator has skipped to (shard
    /// transfers charge their time here while lanes are parked); resumed
    /// machines catch their clocks up to it.
    fn clk_floor(&self) -> u64;

    /// Park the lane until the sibling holding `key` releases
    /// (`Flight::WaitLock`); `t` is the lane's (unchanged) virtual time.
    fn park_wait(&self, lane: usize, key: LotusKey, t: u64);

    /// Consume a completed wait (`Flight::WaitOver`): true once the
    /// holder released and the lane may retry its acquisition.
    fn try_wait_over(&self, lane: usize) -> bool;

    /// Park the lane in retry backoff until virtual time `t`
    /// (`Flight::RetryAt`): a lost/timed-out lock RPC is waiting out its
    /// capped exponential backoff before reissuing, and sibling lanes
    /// keep running meanwhile.
    fn park_retry(&self, lane: usize, t: u64);

    /// Consume a completed retry backoff: true once the scheduler's
    /// ready-queue loop has reached the lane's backoff deadline and the
    /// lane may reissue its lock RPC.
    fn try_retry_over(&self, lane: usize) -> bool;
}

/// The *Issued -> Done* machine step behind [`PhaseCtx::issue`]: first
/// poll parks the machine (the plan was just posted), every later poll
/// checks the in-flight table for the rung results.
struct TakeIssue<'a> {
    sink: &'a dyn StepSink,
    lane: usize,
    parked: bool,
}

impl Future for TakeIssue<'_> {
    type Output = (BatchResult, u64, bool);

    fn poll(mut self: Pin<&mut Self>, _cx: &mut Context<'_>) -> Poll<Self::Output> {
        if !self.parked {
            self.parked = true;
            return Poll::Pending;
        }
        match self.sink.try_take(self.lane) {
            Some(done) => Poll::Ready(done),
            None => Poll::Pending,
        }
    }
}

/// The *Issued -> Done* machine step behind [`PhaseCtx::issue_rpc`]:
/// first poll parks the machine, every later poll checks the in-flight
/// table for the RPC reply.
struct TakeRpc<'a> {
    sink: &'a dyn StepSink,
    lane: usize,
    parked: bool,
}

impl Future for TakeRpc<'_> {
    type Output = (bool, u64);

    fn poll(mut self: Pin<&mut Self>, _cx: &mut Context<'_>) -> Poll<Self::Output> {
        if !self.parked {
            self.parked = true;
            return Poll::Pending;
        }
        match self.sink.try_take_rpc(self.lane) {
            Some(done) => Poll::Ready(done),
            None => Poll::Pending,
        }
    }
}

/// The *wait for a sibling's unlock* step behind [`PhaseCtx::wait_unlock`].
struct WaitUnlock<'a> {
    sink: &'a dyn StepSink,
    lane: usize,
    key: LotusKey,
    t: u64,
    parked: bool,
}

impl Future for WaitUnlock<'_> {
    type Output = ();

    fn poll(mut self: Pin<&mut Self>, _cx: &mut Context<'_>) -> Poll<()> {
        if !self.parked {
            self.parked = true;
            self.sink.park_wait(self.lane, self.key, self.t);
            return Poll::Pending;
        }
        if self.sink.try_wait_over(self.lane) {
            Poll::Ready(())
        } else {
            Poll::Pending
        }
    }
}

/// The *retry backoff* step behind [`PhaseCtx::retry_backoff`]: first
/// poll parks the machine at its backoff deadline (`Flight::RetryAt`),
/// every later poll asks whether the scheduler has reached it.
struct RetryPark<'a> {
    sink: &'a dyn StepSink,
    lane: usize,
    t: u64,
    parked: bool,
}

impl Future for RetryPark<'_> {
    type Output = ();

    fn poll(mut self: Pin<&mut Self>, _cx: &mut Context<'_>) -> Poll<()> {
        if !self.parked {
            self.parked = true;
            self.sink.park_retry(self.lane, self.t);
            return Poll::Pending;
        }
        if self.sink.try_retry_over(self.lane) {
            Poll::Ready(())
        } else {
            Poll::Pending
        }
    }
}

/// Per-record transaction state (one entry of the read/write set).
#[derive(Debug, Clone)]
pub struct TxnRecord {
    /// The record reference.
    pub r: RecordRef,
    /// Write intent (vs read-lock only).
    pub write: bool,
    /// Insert (vs update of an existing record).
    pub insert: bool,
    /// Delete (clears the CVT at commit).
    pub delete: bool,
    /// Value read by `execute` (update/read paths).
    pub value: Option<Vec<u8>>,
    /// Staged new value.
    pub new_value: Option<Vec<u8>>,
    /// The CVT observed at execute (fresh template for inserts).
    pub cvt: Option<CvtSnapshot>,
    /// Primary CVT address.
    pub cvt_addr: u64,
    /// Index bucket.
    pub bucket: u64,
    /// CVT slot within the bucket.
    pub slot: u8,
    /// True if the CVT came from this CN's VT cache.
    pub from_cache: bool,
    /// VT-cache epoch captured before a lock-free CVT read (RO fills).
    pub fill_epoch: Option<u64>,
}

impl TxnRecord {
    /// A fresh set entry for `r` with the given write intent.
    pub fn new(r: RecordRef, write: bool) -> Self {
        Self {
            r,
            write,
            insert: false,
            delete: false,
            value: None,
            new_value: None,
            cvt: None,
            cvt_addr: 0,
            bucket: 0,
            slot: 0,
            from_cache: false,
            fill_epoch: None,
        }
    }
}

/// A held lock (everything needed to release it).
#[derive(Debug, Clone, Copy)]
pub struct Held {
    /// Locked key.
    pub key: LotusKey,
    /// Held mode.
    pub mode: LockMode,
    /// CN whose lock table holds the lock.
    pub owner_cn: usize,
}

/// The per-transaction state threaded through the phase pipeline.
///
/// A frame is reused across transactions (a coordinator runs one at a
/// time); [`TxnFrame::reset`] rearms it at `begin`.
#[derive(Debug, Default)]
pub struct TxnFrame {
    /// Transaction id (globally unique; 0 before the first `begin`).
    pub txn_id: u64,
    /// Read-only transaction (no locks, snapshot reads)?
    pub read_only: bool,
    /// Start timestamp (HLC).
    pub start_ts: u64,
    /// The read/write set in declaration order.
    pub records: Vec<TxnRecord>,
    /// Records below this index were handled by a previous `execute`
    /// round (the paper: "execution may occur multiple times, dynamically
    /// adding new data to the read/write sets").
    pub executed_upto: usize,
    /// Locks currently held by this transaction.
    pub held: Vec<Held>,
    /// Lazily built hash index over `records` backing [`TxnFrame::find`]
    /// (a linear scan is quadratic over TPC-C-sized read/write sets).
    index: RefCell<RecordIndex>,
}

/// Open-addressed `(hash, position+1)` index over a frame's records.
/// Built lazily by [`TxnFrame::find`], so records may keep being pushed
/// straight onto `TxnFrame::records`; `sync` indexes the new tail.
#[derive(Debug, Default)]
struct RecordIndex {
    /// Power-of-two slot array; `(_, 0)` means empty.
    slots: Vec<(u64, u32)>,
    /// `records[..built]` are reflected in `slots`.
    built: usize,
}

/// SplitMix64 over (table, key) — the record-set hash.
#[inline]
fn hash_ref(r: RecordRef) -> u64 {
    let mut z = r.key.0 ^ ((r.table as u64) << 48) ^ 0x9E37_79B9_7F4A_7C15;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl RecordIndex {
    fn clear(&mut self) {
        self.slots.clear();
        self.built = 0;
    }

    fn capacity_for(n: usize) -> usize {
        (n.max(4) * 4).next_power_of_two()
    }

    /// Index any records appended since the last sync (rebuilding on
    /// growth so the load factor stays below 1/2).
    fn sync(&mut self, records: &[TxnRecord]) {
        if records.len() == self.built {
            return;
        }
        if self.slots.len() < Self::capacity_for(records.len()) {
            self.slots = vec![(0, 0); Self::capacity_for(records.len())];
            self.built = 0;
        }
        for i in self.built..records.len() {
            self.insert(records, i);
        }
        self.built = records.len();
    }

    fn insert(&mut self, records: &[TxnRecord], i: usize) {
        let r = records[i].r;
        let h = hash_ref(r);
        let mask = self.slots.len() - 1;
        let mut pos = (h as usize) & mask;
        loop {
            let (sh, sp) = self.slots[pos];
            if sp == 0 {
                self.slots[pos] = (h, (i + 1) as u32);
                return;
            }
            if sh == h && records[(sp - 1) as usize].r == r {
                return; // keep the first occurrence (`position` semantics)
            }
            pos = (pos + 1) & mask;
        }
    }

    fn get(&self, records: &[TxnRecord], r: RecordRef) -> Option<usize> {
        if self.slots.is_empty() {
            return None;
        }
        let h = hash_ref(r);
        let mask = self.slots.len() - 1;
        let mut pos = (h as usize) & mask;
        loop {
            let (sh, sp) = self.slots[pos];
            if sp == 0 {
                return None;
            }
            if sh == h && records[(sp - 1) as usize].r == r {
                return Some((sp - 1) as usize);
            }
            pos = (pos + 1) & mask;
        }
    }
}

impl TxnFrame {
    /// An empty frame (no transaction in flight).
    pub fn new() -> Self {
        Self::default()
    }

    /// Rearm for a new transaction.
    pub fn reset(&mut self, txn_id: u64, read_only: bool, start_ts: u64) {
        self.records.clear();
        self.held.clear();
        self.index.borrow_mut().clear();
        self.executed_upto = 0;
        self.read_only = read_only;
        self.txn_id = txn_id;
        self.start_ts = start_ts;
    }

    /// Drop all in-flight state **without releasing locks** (fail-stop
    /// crash; recovery owns the locks, paper §6).
    pub fn crash(&mut self) {
        self.records.clear();
        self.held.clear();
        self.index.borrow_mut().clear();
        self.executed_upto = 0;
    }

    /// Index of `r` in the set, if present (first occurrence). O(1)
    /// expected: served from a lazily synced hash index, not a scan.
    pub fn find(&self, r: RecordRef) -> Option<usize> {
        let mut ix = self.index.borrow_mut();
        ix.sync(&self.records);
        ix.get(&self.records, r)
    }

    /// This transaction's lock-holder identity on CN `cn`.
    #[inline]
    pub fn holder(&self, cn: usize) -> HolderId {
        HolderId {
            cn,
            txn: self.txn_id,
        }
    }
}

/// The coordinator-side environment a phase executes in.
///
/// Borrowed fresh from the coordinator for each phase call; separate from
/// [`TxnFrame`] so a phase can mutate the frame and charge the clock at
/// the same time.
pub struct PhaseCtx<'a> {
    /// Cluster-wide shared state.
    pub cluster: &'a SharedCluster,
    /// The executing coordinator's CN.
    pub cn: usize,
    /// Coordinator slot within the CN (RPC pairing, §4.1).
    pub slot: usize,
    /// Global coordinator id (log-slot index).
    pub global_id: usize,
    /// The coordinator's verb endpoint.
    pub ep: &'a Endpoint,
    /// The executing frame's virtual clock (the lane clock under the
    /// pipelined scheduler, the coordinator clock otherwise).
    pub clk: &'a mut VClock,
    /// Lane index within the owning scheduler (0 when sequential).
    pub lane: usize,
    /// The step-machine conduit — `Some` under the pipelined
    /// [`crate::txn::scheduler::FrameScheduler`]; `None` issues planned
    /// batches directly (sequential coordinator, recovery, baselines).
    pub sink: Option<&'a dyn StepSink>,
    /// Caller-owned READ-buffer scratch, reused across doorbell rings
    /// (ROADMAP #4 follow-on (b)). Owned by the sequential coordinator
    /// or the pipelined lane machine — either way it outlives the
    /// transaction, so capacity recycles across frames.
    pub pool: &'a mut BufPool,
}

impl PhaseCtx<'_> {
    /// Cost model shorthand.
    #[inline]
    pub fn net(&self) -> &NetConfig {
        &self.cluster.net
    }

    /// Effective isolation level.
    #[inline]
    pub fn isolation(&self) -> Isolation {
        self.cluster.cfg.isolation
    }

    /// Issue a phase's planned batch and wait for this frame's results —
    /// the machine's *issue point*. Under a staging sink the plan is
    /// *posted* (`Flight::Staged`) and the machine **parks**
    /// (`Poll::Pending`); the scheduler's ready-queue loop rings a merged
    /// doorbell set and resumes the machine at `Flight::Done`, charging
    /// `clk` only to this frame's own slowest op completion. Under a
    /// direct conduit (no sink, depth 1, window 0) the batch issues
    /// immediately and the await completes within the same poll — the
    /// classic blocking phase call.
    pub async fn issue(&mut self, batch: OpBatch) -> crate::Result<BatchResult> {
        // No sink and a non-staging sink are contractually the same
        // direct conduit.
        let Some(sink) = self.sink.filter(|s| s.stages()) else {
            return batch.issue(self.ep, &self.cluster.mns, self.clk);
        };
        if batch.is_empty() {
            // Nothing to post; give any parked riders their doorbell.
            // The empty caller itself stays free.
            sink.flush_riders(self.lane, self.clk.now())?;
            return Ok(BatchResult::empty());
        }
        sink.post(self.lane, Plan::Doorbell(batch), self.clk.now());
        let (res, t_done, ok) = TakeIssue {
            sink,
            lane: self.lane,
            parked: false,
        }
        .await;
        // The owning coordinator may have skipped time forward (shard
        // transfer) while this machine was parked.
        self.clk.catch_up(t_done.max(sink.clk_floor()));
        if !ok {
            // An injected doorbell fault hit one of this lane's rings
            // (MN unreachable or a torn batch, PR 8): the batch is lost,
            // exactly as the direct conduit's `Endpoint::doorbell` error.
            return Err(crate::Error::NodeUnavailable(
                "mn (doorbell fault)".to_string(),
            ));
        }
        Ok(res)
    }

    /// Issue a batched lock-class RPC to `dst_cn` and wait for this
    /// frame's reply — the RPC plane's *issue point* (ISSUE 5). Under a
    /// staging sink the message is *posted* (`Flight::Staged`) and the
    /// machine **parks**; the scheduler merges sibling lanes' messages
    /// to the same destination CN (within `coalesce_window_ns`) into one
    /// RPC send and resumes each owner at the handler completing its own
    /// chunk. Under a direct conduit this is exactly the classic
    /// synchronous [`crate::dm::RpcFabric::call`].
    ///
    /// `Err(NodeUnavailable)` means the destination CN is failed and the
    /// caller burned the UD timeout (clock already charged).
    pub async fn issue_rpc(&mut self, dst_cn: usize, n_reqs: usize) -> crate::Result<()> {
        let Some(sink) = self.sink.filter(|s| s.stages()) else {
            self.ep.gate_sync(self.clk);
            return self
                .cluster
                .rpc
                .call(self.cn, dst_cn, self.slot, n_reqs, self.clk);
        };
        sink.post(self.lane, Plan::Rpc { dst_cn, n_reqs }, self.clk.now());
        let (ok, t_done) = TakeRpc {
            sink,
            lane: self.lane,
            parked: false,
        }
        .await;
        self.clk.catch_up(t_done.max(sink.clk_floor()));
        if ok {
            Ok(())
        } else {
            Err(crate::Error::NodeUnavailable(format!(
                "cn{dst_cn} (rpc timeout)"
            )))
        }
    }

    /// Issue a fire-and-forget plan off the critical path (remote log
    /// clears): parked with the sink to ride a later doorbell when
    /// staging, issued immediately (`issue_async`) otherwise — including
    /// under `coalesce_window_ns == 0`, where nothing may park.
    pub fn issue_deferred(&mut self, batch: OpBatch) -> crate::Result<()> {
        match self.sink {
            Some(sink) => sink.issue_deferred(self.lane, Plan::Doorbell(batch), self.clk),
            None => batch.issue_async(self.ep, &self.cluster.mns, self.clk),
        }
    }

    /// Fire-and-forget RPC off the critical path (remote unlocks, paper
    /// 5.1: the coordinator "returns the result immediately after
    /// issuing remote unlock requests"): parked with the sink to ride a
    /// later merged RPC message to the same destination CN when staging,
    /// sent immediately otherwise. Failures are ignored — recovery
    /// releases the locks of failed CNs (§6).
    pub fn issue_rpc_deferred(&mut self, dst_cn: usize, n_reqs: usize) {
        match self.sink {
            Some(sink) => {
                let _ = sink.issue_deferred(self.lane, Plan::Rpc { dst_cn, n_reqs }, self.clk);
            }
            None => {
                self.ep.gate_sync(self.clk);
                let _ = self
                    .cluster
                    .rpc
                    .call_async(self.cn, dst_cn, self.slot, n_reqs, self.clk);
            }
        }
    }

    /// Lock-phase sibling check: would acquiring `mode` on `key` now
    /// conflict with another lane's transaction whose recorded lock
    /// interval covers now? Always false without a scheduler sink.
    pub fn sibling_conflict(&self, key: LotusKey, mode: LockMode) -> bool {
        match self.sink {
            Some(sink) => sink.sibling_conflict(self.lane, key, mode, self.clk.now()),
            None => false,
        }
    }

    /// Record a physical lock acquisition with the sink (live interval).
    pub fn note_lock(&self, key: LotusKey, mode: LockMode) {
        if let Some(sink) = self.sink {
            sink.note_lock(self.lane, key, mode, self.clk.now());
        }
    }

    /// All locks released: drop live intervals, wake waiting siblings
    /// (their wait spans are recorded against this release time).
    pub fn note_unlock_all(&self) {
        if let Some(sink) = self.sink {
            sink.note_unlock_all(self.lane, self.clk.now());
        }
    }

    /// Triage a failed physical acquisition (see [`WaitVerdict`]).
    pub fn wait_verdict(&self, key: LotusKey, mode: LockMode) -> WaitVerdict {
        match self.sink {
            Some(sink) => sink.wait_verdict(self.lane, key, mode, self.clk.now()),
            None => WaitVerdict::Abort,
        }
    }

    /// Park until the sibling holding `key` releases, then resume at the
    /// *unchanged* virtual time (the wait is a scheduling artifact; in
    /// the modeled timeline the lock was free at `now`) — except for
    /// coordinator-level time skips (shard transfers), which apply as a
    /// floor, and a small CPU re-check charge: the woken lane re-probes
    /// the (now free) lock table before retrying, which is real work on
    /// the modeled CN CPU (closes the ROADMAP "wait is free" open item).
    pub async fn wait_unlock(&mut self, key: LotusKey) {
        let sink = self.sink.expect("wait_unlock requires a scheduler sink");
        WaitUnlock {
            sink,
            lane: self.lane,
            key,
            t: self.clk.now(),
            parked: false,
        }
        .await;
        self.clk.catch_up(sink.clk_floor());
        let recheck = self.net().local_lock_ns;
        self.clk.advance(recheck);
    }

    /// Wait out a retry backoff of `backoff` virtual ns before reissuing
    /// a lost/timed-out lock RPC. Under a staging sink the lane parks
    /// (`Flight::RetryAt`) so siblings keep running while it backs off;
    /// under a direct conduit the backoff is charged straight to the
    /// clock. Either way the time lands on the lane clock and the CN's
    /// `backoff_ns` counter.
    pub async fn retry_backoff(&mut self, backoff: u64) {
        self.ep.nic.note_backoff(backoff);
        match self.sink.filter(|s| s.stages()) {
            Some(sink) => {
                let until = self.clk.now() + backoff;
                RetryPark {
                    sink,
                    lane: self.lane,
                    t: until,
                    parked: false,
                }
                .await;
                self.clk.catch_up(until.max(sink.clk_floor()));
            }
            None => self.clk.advance(backoff),
        }
    }

    /// Park-and-retry at the lane's *unchanged* virtual time (ISSUE 10):
    /// the first-class scheduler event behind a `WrongShardOwner`
    /// bounce. Like [`Self::retry_backoff`] with a zero deadline — the
    /// lane parks (`Flight::RetryAt` at its own clock) so runnable
    /// siblings are served first, then resumes and catches up to any
    /// coordinator-level clock floor (a shard transfer's interruption
    /// charged via `skip_to` while it was parked). In the modeled
    /// timeline the retry happens at the same instant the bounce did;
    /// only the re-routed acquisition itself charges time. A no-op
    /// under a direct conduit (nothing to yield to).
    pub async fn bounce_park(&mut self) {
        if let Some(sink) = self.sink.filter(|s| s.stages()) {
            let now = self.clk.now();
            RetryPark {
                sink,
                lane: self.lane,
                t: now,
                parked: false,
            }
            .await;
            self.clk.catch_up(now.max(sink.clk_floor()));
        }
    }
}

/// Shared *Begin*: draw the transaction id and start timestamp (charging
/// the oracle access to `clk`) and rearm the frame. One implementation
/// for the sequential coordinator and every scheduler lane, so their
/// accounting cannot drift.
pub fn begin(cluster: &SharedCluster, clk: &mut VClock, frame: &mut TxnFrame, read_only: bool) {
    let txn_id = cluster.next_txn_id();
    let start_ts = cluster.oracle.timestamp(clk, cluster.net.ts_oracle_ns);
    frame.reset(txn_id, read_only, start_ts);
}

/// Shared *Commit* entry: charge the application-logic CPU window, then
/// run the read-write commit pipeline (read-only transactions have
/// nothing to write). Same single-implementation rationale as [`begin`].
pub async fn commit_txn(ctx: &mut PhaseCtx<'_>, frame: &mut TxnFrame) -> crate::Result<()> {
    // Application logic between execute and commit.
    ctx.clk.advance(ctx.net().txn_logic_ns);
    if frame.read_only {
        Ok(())
    } else {
        commit::commit_rw(ctx, frame).await
    }
}

/// One full execution round over `frame.records[frame.executed_upto..]`:
/// lock-first (read-write transactions only), then Read CVT, then Read
/// Data. On `Err` the transaction is already rolled back (locks freed).
pub async fn execute(ctx: &mut PhaseCtx<'_>, frame: &mut TxnFrame) -> crate::Result<()> {
    let from = frame.executed_upto;
    if !frame.read_only {
        lock::acquire(ctx, frame, from).await?;
    }
    read::read_cvt(ctx, frame, from).await?;
    read::read_data(ctx, frame, from).await?;
    frame.executed_upto = frame.records.len();
    Ok(())
}
