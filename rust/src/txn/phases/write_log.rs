//! Phases 4 + 6 — *Write Data & Log* and *Write Visible* (paper §5.1).
//!
//! New versions go to the memory pool with version = INVISIBLE, primaries
//! and backups planned into the **same** [`OpBatch`] (identical replica
//! layout lets one doorbell batch per MN carry both); the metadata commit
//! log rides in the same batch. After the commit timestamp is drawn,
//! *Write Visible* overwrites INVISIBLE with the timestamp on every
//! replica — again one `OpBatch`. Each phase is a resumable machine
//! issuing exactly once through [`PhaseCtx::issue`] — the park point
//! where the pipelined scheduler may merge the plan with sibling
//! frames' doorbell rings before it rings.

use crate::dm::opbatch::OpBatch;
use crate::store::cvt::{CellSnapshot, CvtSnapshot, INVISIBLE};
use crate::store::{gc, record};
use crate::txn::log::{LogEntry, LogRecord};
use crate::txn::phases::{unlock, PhaseCtx, TxnFrame};
use crate::txn::timestamp::phys_of;
use crate::{abort, AbortReason, Result};

/// One planned version write (needed again by *Write Visible* and the
/// VT-cache synchronization).
pub struct PlannedWrite {
    /// Index into `frame.records`.
    pub rec_idx: usize,
    /// The CVT cell chosen for the new version.
    pub cell: u8,
    /// The cell's address on the primary MN.
    pub cell_addr_primary: u64,
    /// The CVT image as written (INVISIBLE version for the log mode).
    pub new_cvt: CvtSnapshot,
}

/// Phase 4: plan and issue every data/CVT/log write of the commit in
/// per-MN doorbell batches. `early_ts` is the pre-drawn commit timestamp
/// of the no-log mode (UPS-backed DRAM, "+Log & Visible" ablation off);
/// it is ignored when the log mode is on (versions start INVISIBLE).
pub async fn write_data_and_log(
    ctx: &mut PhaseCtx<'_>,
    frame: &mut TxnFrame,
    early_ts: u64,
) -> Result<Vec<PlannedWrite>> {
    let log_and_visible = ctx.cluster.cfg.features.log_and_visible;
    let now_phys = ctx.clk.now();
    let gc_thresh = ctx.cluster.cfg.gc_threshold_ns;

    let mut plans: Vec<PlannedWrite> = Vec::new();
    let mut log_entries: Vec<LogEntry> = Vec::new();
    let mut batch = OpBatch::new();
    for i in 0..frame.records.len() {
        let rec = frame.records[i].clone();
        if !rec.write {
            continue;
        }
        let table = ctx.cluster.tables[rec.r.table as usize].clone();
        let mut cvt = rec.cvt.clone().expect("executed");
        if rec.delete {
            // Clear the whole CVT (key=0 frees the index slot).
            let cleared = CvtSnapshot::empty(table.spec.ncells);
            for (r, rep) in table.replicas.iter().enumerate() {
                batch.write(
                    rep.mn,
                    table.cvt_addr(r, rec.bucket, rec.slot),
                    cleared.serialize(&table.layout),
                );
            }
            continue;
        }
        let Some(new_value) = rec.new_value.clone() else {
            continue; // write-locked but not modified: nothing to write
        };
        // Choose the victim cell (free / oldest — §7.1 GC).
        let Some(cell_idx) = gc::choose_victim(&cvt.cells, phys_of(now_phys), gc_thresh) else {
            unlock::release(ctx, frame);
            return Err(abort(AbortReason::LockConflict));
        };
        // Opportunistic reclamation of stale cells (§7.1).
        for ridx in gc::reclaimable(&cvt.cells, phys_of(now_phys), gc_thresh) {
            if ridx != cell_idx {
                cvt.cells[ridx].valid = false;
            }
        }
        let cell_idx = cell_idx as u8;
        let old_cv = cvt.cells[cell_idx as usize].cv;
        let new_cv = old_cv.wrapping_add(1);
        let rec_addr_primary = table.record_addr(0, rec.bucket, rec.slot, cell_idx);
        cvt.cells[cell_idx as usize] = CellSnapshot {
            cv: new_cv,
            valid: true,
            len: new_value.len() as u16,
            version: if log_and_visible { INVISIBLE } else { early_ts },
            addr: rec_addr_primary,
            consistent: true,
        };
        cvt.record_len = new_value.len() as u16;
        if rec.insert {
            cvt.key = rec.r.key.0;
            cvt.occupied = true;
            cvt.table_id = table.spec.id;
        }
        let slot_img = record::encode(new_cv, &new_value, table.spec.record_len);
        let cvt_img = cvt.serialize(&table.layout);
        let cell_addr_primary =
            table.cvt_addr(0, rec.bucket, rec.slot) + table.layout.cell_off(cell_idx);
        for (r, rep) in table.replicas.iter().enumerate() {
            batch.write(
                rep.mn,
                table.record_addr(r, rec.bucket, rec.slot, cell_idx),
                slot_img.clone(),
            );
            // Whole-CVT write (header may change for inserts; reclaimed
            // cells must be cleared) — still one WRITE op.
            batch.write(rep.mn, table.cvt_addr(r, rec.bucket, rec.slot), cvt_img.clone());
        }
        log_entries.push(LogEntry {
            table: rec.r.table,
            mn: table.primary().mn as u16,
            cv: new_cv,
            cell_addr: cell_addr_primary,
        });
        plans.push(PlannedWrite {
            rec_idx: i,
            cell: cell_idx,
            cell_addr_primary,
            new_cvt: cvt,
        });
    }
    if log_and_visible && !log_entries.is_empty() {
        let (log_mn, log_addr) = ctx.cluster.log_slots[ctx.global_id];
        let log_img = LogRecord::prepared(frame.txn_id, log_entries)?.serialize();
        batch.write(log_mn, log_addr, log_img);
    }
    if let Err(e) = ctx.issue(batch).await {
        // The batch is lost (MN unreachable / torn doorbell): nothing is
        // committed yet — the log write IS the commit point and it did
        // not land intact — so this is a pre-commit abort and the held
        // locks must be released, not leaked until recovery.
        unlock::release(ctx, frame);
        return Err(e);
    }
    Ok(plans)
}

/// Phase 6: overwrite INVISIBLE with the commit timestamp on every
/// replica (one WRITE of the cell's version word each).
pub async fn write_visible(
    ctx: &mut PhaseCtx<'_>,
    frame: &TxnFrame,
    plans: &[PlannedWrite],
    commit_ts: u64,
) -> Result<()> {
    let mut batch = OpBatch::new();
    for plan in plans {
        let table = ctx.cluster.table(frame.records[plan.rec_idx].r.table);
        // The version word is the second word of the cell.
        for r in 0..table.replicas.len() {
            let cell_addr = table.to_replica_addr(plan.cell_addr_primary, r);
            batch.write(
                table.replicas[r].mn,
                cell_addr + 8,
                commit_ts.to_le_bytes().to_vec(),
            );
        }
    }
    ctx.issue(batch).await?;
    Ok(())
}
