//! Phases 2 + 3 — *Read CVT* and *Read Data* (paper §5.1).
//!
//! CVT resolution is served from the version table cache (locally owned
//! keys), the address cache (one CVT READ), or a bucket READ + probe
//! search; all memory-pool READs of a round are planned into one
//! [`OpBatch`] and issued as per-MN doorbell batches. Record reads MVCC-
//! select the largest version `<= T_start`; a newer visible version
//! aborts an SR read-write transaction.
//!
//! Both phases are resumable machines cut at their issue points: plan
//! the round's READs, then hand the plan to [`PhaseCtx::issue`] — under
//! the pipelined scheduler the machine parks there (`Poll::Pending`) and
//! sibling frames' plans may share the doorbell ring (see
//! [`crate::txn::phases`] docs).

use std::sync::Arc;

use crate::cache::vtcache::CachedCvt;
use crate::dm::opbatch::{OpBatch, OpTag};
use crate::store::cvt::CvtSnapshot;
use crate::store::index::TableStore;
use crate::store::record;
use crate::txn::api::Isolation;
use crate::txn::phases::{unlock, PhaseCtx, TxnFrame};
use crate::{abort, AbortReason, Error, Result};

/// Probe a key's bucket chain with charged READs; `skip` leading buckets
/// are assumed already searched. Returns `(bucket, slot, cvt)`.
///
/// Reads are sequential single-op doorbells on purpose: the chain stops
/// at the first hit, and almost every lookup hits the home bucket.
fn probe_find(
    ctx: &mut PhaseCtx<'_>,
    table: &Arc<TableStore>,
    key: crate::sharding::key::LotusKey,
    skip: usize,
) -> Result<Option<(u64, u8, CvtSnapshot)>> {
    let buckets: Vec<u64> = table.probe_buckets(key).skip(skip).collect();
    let mn = ctx.cluster.mns[table.primary().mn].clone();
    for b in buckets {
        let buf = ctx.ep.read(
            &mn,
            table.bucket_addr(0, b),
            table.layout.bucket_size() as usize,
            ctx.clk,
        )?;
        if let Some((slot, cvt)) = table.find_in_bucket(&buf, key) {
            return Ok(Some((b, slot, cvt)));
        }
    }
    Ok(None)
}

/// Insert placement: read the whole probe chain in one doorbell, reject
/// duplicates anywhere in it, pick the first empty slot.
async fn probe_place_insert(
    ctx: &mut PhaseCtx<'_>,
    frame: &mut TxnFrame,
    table: &Arc<TableStore>,
    key: crate::sharding::key::LotusKey,
) -> Result<(u64, u8)> {
    let buckets: Vec<u64> = table.probe_buckets(key).collect();
    let mn_id = table.primary().mn;
    let mut batch = OpBatch::new();
    let mut tags: Vec<OpTag> = Vec::with_capacity(buckets.len());
    for &b in &buckets {
        tags.push(batch.read_pooled(
            mn_id,
            table.bucket_addr(0, b),
            table.layout.bucket_size() as usize,
            ctx.pool,
        ));
    }
    let res = match ctx.issue(batch).await {
        Ok(r) => r,
        Err(e) => {
            // Lost doorbell (injected fault): abort, never leak locks.
            unlock::release(ctx, frame);
            return Err(e);
        }
    };
    let mut placed = None;
    let mut duplicate = false;
    for (&b, &tag) in buckets.iter().zip(&tags) {
        let out = res.read_buf(tag);
        if table.find_in_bucket(out, key).is_some() {
            duplicate = true;
            break;
        }
        if placed.is_none() {
            if let Some(slot) = table.find_empty_in_bucket(out) {
                placed = Some((b, slot));
            }
        }
    }
    res.recycle(ctx.pool);
    if duplicate {
        unlock::release(ctx, frame);
        return Err(abort(AbortReason::Duplicate));
    }
    match placed {
        Some(p) => Ok(p),
        None => {
            unlock::release(ctx, frame);
            Err(Error::OutOfMemory(format!(
                "table {} probe chain of key {:#x} full",
                table.spec.name, key.0
            )))
        }
    }
}

/// Phase 2: obtain every record's CVT (cache / addr cache / bucket).
pub async fn read_cvt(ctx: &mut PhaseCtx<'_>, frame: &mut TxnFrame, from: usize) -> Result<()> {
    let use_vt_cache = ctx.cluster.cfg.features.vt_cache;
    let vt_cache = ctx.cluster.vt_caches[ctx.cn].clone();
    let addr_cache = ctx.cluster.addr_caches[ctx.cn].clone();
    let router = ctx.cluster.router.clone();

    // Pass 1: cache hits + collect the reads we must issue.
    // reads: (record idx, mn, addr, len, whole_bucket)
    let mut reads: Vec<(usize, usize, u64, usize, bool)> = Vec::new();
    for i in from..frame.records.len() {
        let (r, is_insert) = {
            let rec = &frame.records[i];
            (rec.r, rec.insert)
        };
        let table = ctx.cluster.tables[r.table as usize].clone();
        let bucket = table.bucket_of(r.key);
        let local = router.owner_of_key(r.key) == ctx.cn;
        if use_vt_cache && local && !is_insert {
            ctx.clk.advance(ctx.net().cache_op_ns);
            if let Some(hit) = vt_cache.get(r.key) {
                let (b, s) = table.locate_cvt(hit.addr)?;
                let rec = &mut frame.records[i];
                rec.cvt = Some(hit.cvt);
                rec.cvt_addr = hit.addr;
                rec.bucket = b;
                rec.slot = s;
                rec.from_cache = true;
                continue;
            }
        }
        if is_insert {
            // Placement reads the whole probe chain in one doorbell.
            let (b, slot) = probe_place_insert(ctx, frame, &table, r.key).await?;
            let mut cvt = CvtSnapshot::empty(table.spec.ncells);
            cvt.key = r.key.0;
            cvt.occupied = true;
            cvt.table_id = table.spec.id;
            let rec = &mut frame.records[i];
            rec.cvt_addr = table.cvt_addr(0, b, slot);
            rec.bucket = b;
            rec.slot = slot;
            rec.cvt = Some(cvt);
            continue;
        }
        if use_vt_cache && local && frame.read_only {
            // Lock-free read: remember the invalidation epoch so the
            // fill below can be rejected if a writer raced us.
            frame.records[i].fill_epoch = Some(vt_cache.epoch(r.key));
        }
        ctx.clk.advance(ctx.net().cache_op_ns);
        if let Some(addr) = addr_cache.get(r.key) {
            reads.push((
                i,
                table.primary().mn,
                addr,
                table.layout.cvt_size() as usize,
                false,
            ));
        } else {
            reads.push((
                i,
                table.primary().mn,
                table.bucket_addr(0, bucket),
                table.layout.bucket_size() as usize,
                true,
            ));
        }
    }

    // Pass 2: plan per-MN doorbell batches through OpBatch; the conduit
    // issues them (possibly merged with sibling frames' plans). Result
    // buffers come from the coordinator's pool — parsed into owned
    // snapshots below and recycled, never kept.
    let mut batch = OpBatch::new();
    let mut tags: Vec<OpTag> = Vec::with_capacity(reads.len());
    for &(_, mn, addr, len, _) in &reads {
        tags.push(batch.read_pooled(mn, addr, len, ctx.pool));
    }
    let mut results = match ctx.issue(batch).await {
        Ok(r) => r,
        Err(e) => {
            // Lost doorbell (injected fault): abort, never leak locks.
            unlock::release(ctx, frame);
            return Err(e);
        }
    };

    // Pass 3: parse, validate, retry stale addresses via bucket read.
    for (ri, &(i, _mn_id, addr, _len, whole_bucket)) in reads.iter().enumerate() {
        let buf = results.take_read(tags[ri]);
        let table = ctx.cluster.tables[frame.records[i].r.table as usize].clone();
        let key = frame.records[i].r.key;
        let parsed = if whole_bucket {
            // Home bucket was read in the batch; probe successors on miss.
            let found = match table.find_in_bucket(&buf, key) {
                Some((slot, cvt)) => Some((table.bucket_of(key), slot, cvt)),
                None => match probe_find(ctx, &table, key, 1) {
                    Ok(f) => f,
                    Err(e) => {
                        unlock::release(ctx, frame);
                        return Err(e);
                    }
                },
            };
            let Some((b, slot, cvt)) = found else {
                unlock::release(ctx, frame);
                return Err(abort(AbortReason::NotFound));
            };
            let cvt_addr = table.cvt_addr(0, b, slot);
            ctx.cluster.addr_caches[ctx.cn].put(key, cvt_addr);
            (slot, cvt, cvt_addr)
        } else {
            let cvt = CvtSnapshot::parse(&buf, &table.layout);
            if cvt.is_empty() || cvt.key != key.0 {
                // Stale cached address: fall back to a probe search.
                ctx.cluster.addr_caches[ctx.cn].invalidate(key);
                let probed = match probe_find(ctx, &table, key, 0) {
                    Ok(f) => f,
                    Err(e) => {
                        unlock::release(ctx, frame);
                        return Err(e);
                    }
                };
                let Some((b, slot, cvt)) = probed else {
                    unlock::release(ctx, frame);
                    return Err(abort(AbortReason::NotFound));
                };
                let cvt_addr = table.cvt_addr(0, b, slot);
                ctx.cluster.addr_caches[ctx.cn].put(key, cvt_addr);
                (slot, cvt, cvt_addr)
            } else {
                let (_b, s) = table.locate_cvt(addr)?;
                (s, cvt, addr)
            }
        };
        // The CVT/bucket bytes are parsed into owned snapshots above —
        // the scratch goes back to the pool for the next ring.
        ctx.pool.put(buf);
        let local = ctx.cluster.router.owner_of_key(key) == ctx.cn;
        let (slot, cvt, cvt_addr) = parsed;
        if use_vt_cache && local {
            let entry = CachedCvt {
                cvt: cvt.clone(),
                addr: cvt_addr,
            };
            if frame.read_only {
                // Epoch-checked fill (no lock held).
                if let Some(e0) = frame.records[i].fill_epoch {
                    ctx.cluster.vt_caches[ctx.cn].put_if_epoch(key, entry, e0);
                }
            } else {
                // Lock held: fill unconditionally.
                ctx.cluster.vt_caches[ctx.cn].put(key, entry);
            }
        }
        let (b, _s) = table.locate_cvt(cvt_addr)?;
        let rec = &mut frame.records[i];
        rec.cvt = Some(cvt);
        rec.cvt_addr = cvt_addr;
        rec.bucket = b;
        rec.slot = slot;
    }
    Ok(())
}

/// Phase 3: MVCC version select + record reads.
pub async fn read_data(ctx: &mut PhaseCtx<'_>, frame: &mut TxnFrame, from: usize) -> Result<()> {
    // Collect reads: (record idx, mn, addr, payload_len, record_len, want_cv).
    let mut reads: Vec<(usize, usize, u64, usize, u32, u8)> = Vec::new();
    for i in from..frame.records.len() {
        let (best, newer, table_id, record_len) = {
            let rec = &frame.records[i];
            if rec.insert {
                continue; // nothing to read
            }
            let cvt = rec.cvt.as_ref().expect("read_cvt phase ran");
            let (best, newer) = cvt.select_version(frame.start_ts);
            let len = best.map(|c| c.len).unwrap_or(0);
            (best.copied(), newer, rec.r.table, len)
        };
        if !frame.read_only && newer && ctx.isolation() == Isolation::Serializable {
            // A committed version newer than T_start: abort (§5.1).
            unlock::release(ctx, frame);
            return Err(abort(AbortReason::VersionTooNew));
        }
        let Some(cell) = best else {
            unlock::release(ctx, frame);
            return Err(abort(AbortReason::NoVisibleVersion));
        };
        let table = ctx.cluster.table(table_id);
        reads.push((
            i,
            table.primary().mn,
            cell.addr,
            record_len as usize,
            table.spec.record_len,
            cell.cv,
        ));
    }
    // Per-MN doorbell batches through OpBatch, issued via the conduit;
    // slot-sized result buffers come from the coordinator's pool.
    let mut batch = OpBatch::new();
    let mut tags: Vec<OpTag> = Vec::with_capacity(reads.len());
    for &(_, mn, addr, _, record_len, _) in &reads {
        tags.push(batch.read_pooled(mn, addr, record::slot_size(record_len), ctx.pool));
    }
    let mut results = match ctx.issue(batch).await {
        Ok(r) => r,
        Err(e) => {
            // Lost doorbell (injected fault): abort, never leak locks.
            unlock::release(ctx, frame);
            return Err(e);
        }
    };
    for (ri, &(i, _mn, _addr, payload_len, record_len, want_cv)) in reads.iter().enumerate() {
        let buf = results.take_read(tags[ri]);
        let decoded = record::decode(&buf, payload_len, record_len);
        // decode copies the payload out; the slot scratch recycles.
        ctx.pool.put(buf);
        match decoded {
            Some((cv, payload)) if cv == want_cv => {
                frame.records[i].value = Some(payload);
            }
            _ => {
                // Torn slot or CV mismatch: a concurrent overwrite.
                // Locked reads never hit this; lock-free RO reads abort.
                unlock::release(ctx, frame);
                return Err(abort(AbortReason::InconsistentRead));
            }
        }
    }
    Ok(())
}
