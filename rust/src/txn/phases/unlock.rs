//! Phase 7 — *Unlock* (paper §5.1).
//!
//! Local locks are CPU ops on the local lock table. Remote locks batch
//! into **fire-and-forget** RPCs per owner CN: the coordinator "returns
//! the result immediately after issuing remote unlock requests" — its
//! clock advances only by the send cost, never a round trip. Failures
//! are ignored; recovery releases the locks of failed CNs (§6). The same
//! routine is the abort path's rollback.
//!
//! Under the pipelined scheduler the unlock messages are deferred
//! [`crate::txn::phases::Plan::Rpc`] plans: they park with the coalescer
//! and ride a sibling lane's next lock message to the same CN (exactly
//! like commit-log clears ride doorbell rings), falling back to their
//! own send when the window expires. The lock-table release itself is
//! immediate either way — only the message's *cost* is deferred, so
//! waiting siblings are woken without delay.

use crate::txn::phases::{PhaseCtx, TxnFrame};

/// Release everything held by `frame` (post-commit unlock or abort).
pub fn release(ctx: &mut PhaseCtx<'_>, frame: &mut TxnFrame) {
    if frame.held.is_empty() {
        return;
    }
    let holder = frame.holder(ctx.cn);
    let mut remote: Vec<(usize, usize)> = Vec::new(); // (cn, count)
    for h in std::mem::take(&mut frame.held) {
        if h.owner_cn == ctx.cn {
            ctx.clk.advance(ctx.net().local_lock_ns);
        } else {
            match remote.iter_mut().find(|(cn, _)| *cn == h.owner_cn) {
                Some((_, n)) => *n += 1,
                None => remote.push((h.owner_cn, 1)),
            }
        }
        ctx.cluster.lock_services[h.owner_cn].release(h.key, h.mode, holder);
    }
    for (target, n) in remote {
        // Fire-and-forget (paper 5.1): failures are ignored — recovery
        // releases the locks of failed CNs.
        ctx.issue_rpc_deferred(target, n);
    }
    // Drop this lane's live lock intervals with the scheduler sink and
    // wake sibling lanes parked waiting on them (anachronistic-holder
    // triage, see the lock phase docs).
    ctx.note_unlock_all();
}
