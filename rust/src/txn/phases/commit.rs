//! Phase 5 + orchestration — the commit pipeline (paper §5.1).
//!
//! `commit_rw` drives a read-write transaction's commit end to end:
//! doomed check, *Write Data & Log* ([`write_log`]), *Get Timestamp*,
//! *Write Visible*, synchronous VT-cache update for locally owned keys
//! (§4.4 — the write lock is still held, so the fill costs no extra
//! consistency work), async log-slot clear, and *Unlock* ([`unlock`]).

use crate::cache::vtcache::CachedCvt;
use crate::dm::opbatch::OpBatch;
use crate::txn::log::STATE_EMPTY;
use crate::txn::phases::{unlock, write_log, PhaseCtx, TxnFrame};
use crate::{abort, AbortReason, Result};

/// Commit a read-write transaction. On `Err` the transaction has been
/// rolled back (all locks released).
pub async fn commit_rw(ctx: &mut PhaseCtx<'_>, frame: &mut TxnFrame) -> Result<()> {
    // Doomed check: resharding/recovery may have force-released our
    // locks; such a transaction must not enter the commit phase (§6).
    if ctx.cluster.doomed.take(frame.txn_id) {
        unlock::release(ctx, frame);
        return Err(abort(AbortReason::OwnerFailed));
    }
    let log_and_visible = ctx.cluster.cfg.features.log_and_visible;
    let ts_svc = ctx.net().ts_oracle_ns;
    // Pre-draw the commit timestamp when running in the no-log mode
    // (UPS-backed DRAM assumption, the "+Log & Visible" ablation off).
    let early_ts = if log_and_visible {
        0
    } else {
        ctx.cluster.oracle.timestamp(ctx.clk, ts_svc)
    };

    // --- Write Data (& Log) ---
    let plans = write_log::write_data_and_log(ctx, frame, early_ts).await?;

    // --- Get Timestamp ---
    let commit_ts = if log_and_visible {
        ctx.cluster.oracle.timestamp(ctx.clk, ts_svc)
    } else {
        early_ts
    };

    // --- Write Visible ---
    //
    // The log write above was the commit point: once the PREPARED slot
    // is sealed on its MN, this transaction is committed and must roll
    // *forward*. A doorbell fault here (MN unreachable / torn batch,
    // PR 8) therefore cannot abort — the visibility sweep is retried
    // with capped exponential backoff until the MN answers again; the
    // gray-failure windows the injector models are finite by contract.
    // Exhaustion is a fatal error (a committed transaction would
    // otherwise be silently lost), never a silent abort.
    if log_and_visible {
        let mut attempt = 0u32;
        loop {
            match write_log::write_visible(ctx, frame, &plans, commit_ts).await {
                Ok(()) => break,
                Err(crate::Error::NodeUnavailable(who)) if attempt < 16 => {
                    let base = ctx.net().rtt_ns.max(1);
                    ctx.retry_backoff(base << attempt.min(4)).await;
                    attempt += 1;
                    let _ = who;
                }
                Err(crate::Error::NodeUnavailable(who)) => {
                    return Err(crate::Error::Runtime(format!(
                        "roll-forward failed: write_visible of committed txn {} \
                         could not reach {who} after {attempt} retries",
                        frame.txn_id
                    )));
                }
                Err(e) => return Err(e),
            }
        }
    }

    // Synchronous VT-cache update for locally owned keys (§4.4 "zero
    // consistency overhead": we hold the write lock).
    if ctx.cluster.cfg.features.vt_cache {
        for plan in &plans {
            let rec = &frame.records[plan.rec_idx];
            if ctx.cluster.router.owner_of_key(rec.r.key) == ctx.cn {
                let mut cvt = plan.new_cvt.clone();
                cvt.cells[plan.cell as usize].version = commit_ts;
                let addr = {
                    let table = ctx.cluster.table(rec.r.table);
                    table.cvt_addr(0, rec.bucket, rec.slot)
                };
                ctx.cluster.vt_caches[ctx.cn].put(rec.r.key, CachedCvt { cvt, addr });
            }
        }
        for rec in &frame.records {
            if rec.delete && ctx.cluster.router.owner_of_key(rec.r.key) == ctx.cn {
                ctx.cluster.vt_caches[ctx.cn].invalidate(rec.r.key);
            }
        }
    }

    // Clear the log slot (async — not on the critical path). Under the
    // pipelined scheduler the plan is parked with the step-machine's
    // coalescer and rides a sibling frame's next doorbell ring instead
    // of ringing its own.
    if log_and_visible && !plans.is_empty() {
        let (log_mn, log_addr) = ctx.cluster.log_slots[ctx.global_id];
        let mut batch = OpBatch::new();
        batch.write(log_mn, log_addr, STATE_EMPTY.to_le_bytes().to_vec());
        ctx.issue_deferred(batch)?;
    }

    // --- Unlock ---
    unlock::release(ctx, frame);
    Ok(())
}
