//! Doomed-transaction registry.
//!
//! Resharding (paper 4.3: the sender "proactively aborts the running
//! transactions using the transaction and CN IDs recorded in the lock
//! state") and recovery (section 6: surviving CNs "stop all transactions
//! whose locks are held on the failed CN") must abort transactions that
//! are running *on other coordinator threads*. A doomed transaction may
//! not enter its commit phase: the coordinator checks the registry at the
//! commit boundary and aborts if listed. Transactions already in the
//! commit phase are allowed to finish (the paper's rule), which the
//! coordinator enforces by checking *before* the first commit write.

use std::collections::HashSet;
use std::sync::Mutex;

/// Shared set of transaction ids that must abort before commit.
#[derive(Debug, Default)]
pub struct DoomedSet {
    inner: Mutex<HashSet<u64>>,
}

impl DoomedSet {
    /// Empty set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Doom a transaction (idempotent).
    pub fn doom(&self, txn: u64) {
        self.inner.lock().unwrap().insert(txn);
    }

    /// Doom many.
    pub fn doom_all<I: IntoIterator<Item = u64>>(&self, txns: I) {
        let mut set = self.inner.lock().unwrap();
        set.extend(txns);
    }

    /// Check-and-clear: returns true (and forgets the id) if doomed.
    /// Clearing keeps the set from growing with txn-id churn.
    pub fn take(&self, txn: u64) -> bool {
        self.inner.lock().unwrap().remove(&txn)
    }

    /// Non-destructive check.
    pub fn contains(&self, txn: u64) -> bool {
        self.inner.lock().unwrap().contains(&txn)
    }

    /// Number of doomed transactions pending.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// Is the set empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doom_take_roundtrip() {
        let d = DoomedSet::new();
        assert!(!d.take(7));
        d.doom(7);
        assert!(d.contains(7));
        assert!(d.take(7));
        assert!(!d.take(7), "take must clear");
    }

    #[test]
    fn doom_all_extends() {
        let d = DoomedSet::new();
        d.doom_all([1, 2, 3]);
        assert_eq!(d.len(), 3);
        assert!(d.take(2));
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn idempotent_doom() {
        let d = DoomedSet::new();
        d.doom(9);
        d.doom(9);
        assert_eq!(d.len(), 1);
    }
}
