//! Commit logs (paper 5.1, "Write Data & Log").
//!
//! Because LOTUS is multi-versioned, old versions already act as undo
//! logs; the commit log carries only **metadata** — the addresses of the
//! CVT cells the transaction is making visible — so it stays small. Each
//! coordinator owns one exclusive, pre-allocated log slot in the memory
//! pool (it runs one transaction at a time), written before the commit
//! timestamp is drawn and cleared after unlock.
//!
//! Recovery (section 6) scans a failed CN's log slots: a slot with
//! `state == PREPARED` names a transaction in its commit phase; the
//! recovery coordinator reads the listed CVT cells and either completes
//! the commit (all cells already visible) or rolls it back (any cell
//! still INVISIBLE).

use crate::util::bytes::{get_u16, get_u64, put_u16, put_u64};
use crate::{Error, Result};

/// Maximum write-set entries a log slot can describe.
pub const MAX_LOG_ENTRIES: usize = 32;

/// Slot state: empty / fully released.
pub const STATE_EMPTY: u64 = 0;
/// Slot state: log written, commit in flight.
pub const STATE_PREPARED: u64 = 1;

/// One logged write: where the new version's CVT cell lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LogEntry {
    /// DB table id.
    pub table: u16,
    /// Primary MN id.
    pub mn: u16,
    /// CVT cell address on the primary MN.
    pub cell_addr: u64,
}

/// A parsed log slot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogRecord {
    /// Transaction id (0 is reserved / invalid).
    pub txn: u64,
    /// Slot state ([`STATE_EMPTY`] / [`STATE_PREPARED`]).
    pub state: u64,
    /// Logged writes.
    pub entries: Vec<LogEntry>,
}

/// Byte size of one log slot in the memory pool.
pub const fn slot_size() -> u64 {
    // state | txn | n | entries * (cell_addr, table|mn)
    8 * 3 + (MAX_LOG_ENTRIES as u64) * 16
}

impl LogRecord {
    /// A prepared record for `txn` covering `entries`.
    pub fn prepared(txn: u64, entries: Vec<LogEntry>) -> Result<Self> {
        if entries.len() > MAX_LOG_ENTRIES {
            return Err(Error::Config(format!(
                "write set of {} exceeds MAX_LOG_ENTRIES={}",
                entries.len(),
                MAX_LOG_ENTRIES
            )));
        }
        Ok(Self {
            txn,
            state: STATE_PREPARED,
            entries,
        })
    }

    /// Serialize to the slot image. The state word is written **last**
    /// positionally (offset 0 still works because the whole image goes in
    /// a single WRITE; the word-atomic memory keeps the state word
    /// consistent).
    pub fn serialize(&self) -> Vec<u8> {
        let mut buf = vec![0u8; slot_size() as usize];
        put_u64(&mut buf, 0, self.state);
        put_u64(&mut buf, 8, self.txn);
        put_u64(&mut buf, 16, self.entries.len() as u64);
        for (i, e) in self.entries.iter().enumerate() {
            let off = 24 + i * 16;
            put_u64(&mut buf, off, e.cell_addr);
            put_u16(&mut buf, off + 8, e.table);
            put_u16(&mut buf, off + 10, e.mn);
        }
        buf
    }

    /// Parse a slot image.
    pub fn parse(buf: &[u8]) -> Self {
        let state = get_u64(buf, 0);
        let txn = get_u64(buf, 8);
        let n = (get_u64(buf, 16) as usize).min(MAX_LOG_ENTRIES);
        let entries = (0..n)
            .map(|i| {
                let off = 24 + i * 16;
                LogEntry {
                    cell_addr: get_u64(buf, off),
                    table: get_u16(buf, off + 8),
                    mn: get_u16(buf, off + 10),
                }
            })
            .collect();
        Self { txn, state, entries }
    }

    /// Is this slot describing an in-flight commit?
    pub fn is_prepared(&self) -> bool {
        self.state == STATE_PREPARED && self.txn != 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(i: u64) -> LogEntry {
        LogEntry {
            table: i as u16,
            mn: (i % 3) as u16,
            cell_addr: 0x1000 + i * 32,
        }
    }

    #[test]
    fn roundtrip() {
        let rec = LogRecord::prepared(77, (0..5).map(entry).collect()).unwrap();
        let buf = rec.serialize();
        assert_eq!(buf.len() as u64, slot_size());
        assert_eq!(LogRecord::parse(&buf), rec);
        assert!(rec.is_prepared());
    }

    #[test]
    fn empty_slot_not_prepared() {
        let buf = vec![0u8; slot_size() as usize];
        let rec = LogRecord::parse(&buf);
        assert!(!rec.is_prepared());
        assert_eq!(rec.state, STATE_EMPTY);
    }

    #[test]
    fn oversized_write_set_rejected() {
        let entries: Vec<LogEntry> = (0..MAX_LOG_ENTRIES as u64 + 1).map(entry).collect();
        assert!(LogRecord::prepared(1, entries).is_err());
    }

    #[test]
    fn max_entries_fit() {
        let entries: Vec<LogEntry> = (0..MAX_LOG_ENTRIES as u64).map(entry).collect();
        let rec = LogRecord::prepared(1, entries).unwrap();
        let parsed = LogRecord::parse(&rec.serialize());
        assert_eq!(parsed.entries.len(), MAX_LOG_ENTRIES);
    }

    #[test]
    fn prop_roundtrip() {
        crate::testing::prop(50, |g| {
            let n = g.usize(0, MAX_LOG_ENTRIES);
            let rec = LogRecord::prepared(
                g.u64(1, u64::MAX / 2),
                (0..n)
                    .map(|_| LogEntry {
                        table: g.u64(0, u16::MAX as u64) as u16,
                        mn: g.u64(0, 255) as u16,
                        cell_addr: g.u64(0, 1 << 40),
                    })
                    .collect(),
            )
            .unwrap();
            assert_eq!(LogRecord::parse(&rec.serialize()), rec);
        });
    }
}
