//! Commit logs (paper 5.1, "Write Data & Log").
//!
//! Because LOTUS is multi-versioned, old versions already act as undo
//! logs; the commit log carries only **metadata** — the addresses of the
//! CVT cells the transaction is making visible — so it stays small. Each
//! coordinator owns one exclusive, pre-allocated log slot in the memory
//! pool (it runs one transaction at a time), written before the commit
//! timestamp is drawn and cleared after unlock.
//!
//! Recovery (section 6) scans a failed CN's log slots: a slot with
//! `state == PREPARED` names a transaction in its commit phase; the
//! recovery coordinator reads the listed CVT cells and either completes
//! the commit (all cells already visible) or rolls it back (any cell
//! still INVISIBLE).
//!
//! # Torn-write safety (PR 8)
//!
//! The commit-log write rides a doorbell batch that can tear: a crash —
//! or an injected [`crate::dm::FaultMode::TornBatch`] fault — may land
//! only a prefix of the slot image, leaving a state word that *reads* as
//! PREPARED over garbage entries. Every serialized slot therefore ends
//! with a **seal**: a checksum over the entire meaningful prefix (state,
//! txn, entry count, entries), with every seal byte forced nonzero so no
//! strict-prefix tear (trailing bytes still old/zero) can reproduce it.
//! [`LogRecord::parse`] verifies the seal; a PREPARED slot whose seal
//! does not verify is **torn** ([`LogRecord::is_torn`]) and must be
//! discarded by recovery — the transaction never reached its commit
//! point intact, so the old versions stand. An out-of-range entry count
//! is handled the same way (never clamped into a plausible parse).

use crate::util::bytes::{get_u16, get_u64, put_u16, put_u64};
use crate::{Error, Result};

/// Maximum write-set entries a log slot can describe.
pub const MAX_LOG_ENTRIES: usize = 32;

/// Slot state: empty / fully released.
pub const STATE_EMPTY: u64 = 0;
/// Slot state: log written, commit in flight.
pub const STATE_PREPARED: u64 = 1;

/// Offset of the seal word within the slot image (after the last entry).
const SEAL_OFF: usize = 8 * 3 + MAX_LOG_ENTRIES * 16;

/// One logged write: where the new version's CVT cell lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LogEntry {
    /// DB table id.
    pub table: u16,
    /// Primary MN id.
    pub mn: u16,
    /// The cell-version byte the new version was written under: recovery
    /// compares it against the live cell's `cv` to detect that the cell
    /// has since been recycled by a *later* transaction — rolling back a
    /// recycled cell would destroy that transaction's committed data.
    pub cv: u8,
    /// CVT cell address on the primary MN.
    pub cell_addr: u64,
}

/// A parsed log slot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogRecord {
    /// Transaction id (0 is reserved / invalid).
    pub txn: u64,
    /// Slot state ([`STATE_EMPTY`] / [`STATE_PREPARED`]).
    pub state: u64,
    /// Logged writes.
    pub entries: Vec<LogEntry>,
    /// Did the slot's seal verify? Always true for freshly built
    /// records; false after parsing a torn or corrupt image.
    pub sealed: bool,
}

/// Byte size of one log slot in the memory pool.
pub const fn slot_size() -> u64 {
    // state | txn | n | entries * (cell_addr, table|mn|cv) | seal
    (SEAL_OFF as u64) + 8
}

/// SplitMix64 finalizer (a bijection on u64).
#[inline]
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The slot seal: a mix-fold over the image's meaningful prefix
/// (`[0, 24 + n*16)` — state, txn, entry count, entries). Every byte of
/// the result is forced nonzero, so a strict-prefix tear of the image
/// (whose un-landed tail is old/zero bytes) can never reproduce it.
fn seal_of(buf: &[u8], n: usize) -> u64 {
    let mut h = 0x5EA1_0F1A_B10C_D00Bu64 ^ ((n as u64) << 1);
    let end = 24 + n * 16;
    let mut off = 0;
    while off < end {
        h = mix(h ^ get_u64(buf, off));
        off += 8;
    }
    let mut b = h.to_le_bytes();
    for x in &mut b {
        if *x == 0 {
            *x = 0xA5;
        }
    }
    u64::from_le_bytes(b)
}

impl LogRecord {
    /// A prepared record for `txn` covering `entries`.
    pub fn prepared(txn: u64, entries: Vec<LogEntry>) -> Result<Self> {
        if entries.len() > MAX_LOG_ENTRIES {
            return Err(Error::Config(format!(
                "write set of {} exceeds MAX_LOG_ENTRIES={}",
                entries.len(),
                MAX_LOG_ENTRIES
            )));
        }
        Ok(Self {
            txn,
            state: STATE_PREPARED,
            entries,
            sealed: true,
        })
    }

    /// Serialize to the slot image, seal last. The whole image goes in a
    /// single WRITE; the seal makes a *partially landed* WRITE (torn
    /// doorbell, crash mid-transfer) detectable at parse time.
    pub fn serialize(&self) -> Vec<u8> {
        let mut buf = vec![0u8; slot_size() as usize];
        put_u64(&mut buf, 0, self.state);
        put_u64(&mut buf, 8, self.txn);
        put_u64(&mut buf, 16, self.entries.len() as u64);
        for (i, e) in self.entries.iter().enumerate() {
            let off = 24 + i * 16;
            put_u64(&mut buf, off, e.cell_addr);
            put_u16(&mut buf, off + 8, e.table);
            put_u16(&mut buf, off + 10, e.mn);
            buf[off + 12] = e.cv;
        }
        put_u64(&mut buf, SEAL_OFF, seal_of(&buf, self.entries.len()));
        buf
    }

    /// Parse a slot image, verifying the seal. A short buffer, an
    /// out-of-range entry count, or a seal mismatch all parse as
    /// *unsealed* — such a slot is never prepared, and a PREPARED state
    /// word over an unsealed image is a torn write.
    pub fn parse(buf: &[u8]) -> Self {
        if buf.len() < slot_size() as usize {
            return Self {
                txn: 0,
                state: STATE_EMPTY,
                entries: Vec::new(),
                sealed: false,
            };
        }
        let state = get_u64(buf, 0);
        let txn = get_u64(buf, 8);
        let n = get_u64(buf, 16) as usize;
        if n > MAX_LOG_ENTRIES {
            // A corrupt count must surface as torn, never be clamped
            // into a plausible-looking record.
            return Self {
                txn,
                state,
                entries: Vec::new(),
                sealed: false,
            };
        }
        let entries = (0..n)
            .map(|i| {
                let off = 24 + i * 16;
                LogEntry {
                    cell_addr: get_u64(buf, off),
                    table: get_u16(buf, off + 8),
                    mn: get_u16(buf, off + 10),
                    cv: buf[off + 12],
                }
            })
            .collect();
        let sealed = get_u64(buf, SEAL_OFF) == seal_of(buf, n);
        Self {
            txn,
            state,
            entries,
            sealed,
        }
    }

    /// Is this slot describing an intact in-flight commit?
    pub fn is_prepared(&self) -> bool {
        self.state == STATE_PREPARED && self.txn != 0 && self.sealed
    }

    /// A PREPARED state word over an image whose seal does not verify:
    /// the slot write tore. The transaction never reached its commit
    /// point intact; recovery must discard the slot (old versions are
    /// the undo log).
    pub fn is_torn(&self) -> bool {
        self.state == STATE_PREPARED && !self.sealed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(i: u64) -> LogEntry {
        LogEntry {
            table: i as u16,
            mn: (i % 3) as u16,
            cv: (i % 251) as u8,
            cell_addr: 0x1000 + i * 32,
        }
    }

    #[test]
    fn roundtrip() {
        let rec = LogRecord::prepared(77, (0..5).map(entry).collect()).unwrap();
        let buf = rec.serialize();
        assert_eq!(buf.len() as u64, slot_size());
        assert_eq!(LogRecord::parse(&buf), rec);
        assert!(rec.is_prepared());
        assert!(!rec.is_torn());
    }

    #[test]
    fn empty_slot_not_prepared() {
        let buf = vec![0u8; slot_size() as usize];
        let rec = LogRecord::parse(&buf);
        assert!(!rec.is_prepared());
        assert!(!rec.is_torn(), "an EMPTY slot is not torn, just empty");
        assert_eq!(rec.state, STATE_EMPTY);
    }

    #[test]
    fn oversized_write_set_rejected() {
        let entries: Vec<LogEntry> = (0..MAX_LOG_ENTRIES as u64 + 1).map(entry).collect();
        assert!(LogRecord::prepared(1, entries).is_err());
    }

    #[test]
    fn max_entries_fit() {
        let entries: Vec<LogEntry> = (0..MAX_LOG_ENTRIES as u64).map(entry).collect();
        let rec = LogRecord::prepared(1, entries).unwrap();
        let parsed = LogRecord::parse(&rec.serialize());
        assert_eq!(parsed.entries.len(), MAX_LOG_ENTRIES);
        assert!(parsed.is_prepared());
    }

    #[test]
    fn every_strict_prefix_tear_parses_as_not_prepared() {
        // The torn-doorbell image: a strict prefix of the slot landed,
        // the tail still holds the slot's prior bytes. Recovery must
        // never see such an image as prepared — over an EMPTY prior
        // image (the common case: slots are cleared after commit)...
        let rec = LogRecord::prepared(0xDEAD_BEEF, (0..7).map(entry).collect()).unwrap();
        let img = rec.serialize();
        for k in 0..img.len() {
            let mut torn = vec![0u8; img.len()];
            torn[..k].copy_from_slice(&img[..k]);
            let parsed = LogRecord::parse(&torn);
            assert!(
                !parsed.is_prepared(),
                "prefix of {k} bytes parsed as prepared"
            );
            // A tear that landed the PREPARED state word is *torn*, not
            // merely empty (the distinction recovery counts).
            if k >= 8 {
                assert!(parsed.is_torn(), "prefix of {k} bytes not flagged torn");
            }
        }
        // ...and over a PREVIOUS transaction's stale image (slot reuse:
        // the clear raced the crash), where the tail bytes are valid
        // pieces of an older sealed record.
        let old = LogRecord::prepared(41, (0..MAX_LOG_ENTRIES as u64).map(entry).collect())
            .unwrap()
            .serialize();
        for k in 1..img.len() {
            let mut torn = old.clone();
            torn[..k].copy_from_slice(&img[..k]);
            let parsed = LogRecord::parse(&torn);
            assert!(
                !(parsed.is_prepared() && parsed.txn == 0xDEAD_BEEF),
                "prefix of {k} bytes over a stale image resurrected the new txn"
            );
        }
    }

    #[test]
    fn every_single_byte_seal_corruption_fails_the_seal() {
        let rec = LogRecord::prepared(99, (0..4).map(entry).collect()).unwrap();
        let img = rec.serialize();
        let seal_off = slot_size() as usize - 8;
        for i in seal_off..img.len() {
            for flip in [0x01u8, 0x80, 0xFF] {
                let mut bad = img.clone();
                bad[i] ^= flip;
                let parsed = LogRecord::parse(&bad);
                assert!(!parsed.is_prepared(), "seal byte {i}^{flip:#x} verified");
                assert!(parsed.is_torn());
            }
        }
    }

    #[test]
    fn corrupt_entry_count_is_torn_not_clamped() {
        // Regression (PR 8 satellite): a count beyond MAX_LOG_ENTRIES
        // used to be silently clamped into a "valid" record.
        let rec = LogRecord::prepared(7, (0..3).map(entry).collect()).unwrap();
        let mut img = rec.serialize();
        for bogus in [MAX_LOG_ENTRIES as u64 + 1, u64::MAX, 1 << 40] {
            put_u64(&mut img, 16, bogus);
            let parsed = LogRecord::parse(&img);
            assert!(!parsed.is_prepared());
            assert!(parsed.is_torn());
            assert!(parsed.entries.is_empty(), "no garbage entries surfaced");
        }
    }

    #[test]
    fn prop_roundtrip() {
        crate::testing::prop(50, |g| {
            let n = g.usize(0, MAX_LOG_ENTRIES);
            let rec = LogRecord::prepared(
                g.u64(1, u64::MAX / 2),
                (0..n)
                    .map(|_| LogEntry {
                        table: g.u64(0, u16::MAX as u64) as u16,
                        mn: g.u64(0, 255) as u16,
                        cv: g.u64(0, 255) as u8,
                        cell_addr: g.u64(0, 1 << 40),
                    })
                    .collect(),
            )
            .unwrap();
            assert_eq!(LogRecord::parse(&rec.serialize()), rec);
        });
    }

    #[test]
    fn prop_random_prefix_tears_never_parse_prepared() {
        // Property form of the exhaustive test above: random records,
        // random tear points, random prior images.
        crate::testing::prop(100, |g| {
            let n = g.usize(1, MAX_LOG_ENTRIES);
            let rec = LogRecord::prepared(
                g.u64(1, u64::MAX / 2),
                (0..n)
                    .map(|_| LogEntry {
                        table: g.u64(0, u16::MAX as u64) as u16,
                        mn: g.u64(0, 255) as u16,
                        cv: g.u64(0, 255) as u8,
                        cell_addr: g.u64(0, 1 << 40),
                    })
                    .collect(),
            )
            .unwrap();
            let img = rec.serialize();
            let k = g.usize(0, img.len() - 1);
            let mut torn = if g.bool(500) {
                vec![0u8; img.len()]
            } else {
                LogRecord::prepared(g.u64(1, 1 << 30), vec![entry(1), entry(2)])
                    .unwrap()
                    .serialize()
            };
            torn[..k].copy_from_slice(&img[..k]);
            let parsed = LogRecord::parse(&torn);
            assert!(
                !(parsed.is_prepared() && parsed.txn == rec.txn && parsed.entries == rec.entries),
                "a strict-prefix tear at {k} reproduced the full record"
            );
        });
    }
}
