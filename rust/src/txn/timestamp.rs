//! Hybrid-logical-clock timestamp oracle (paper section 5).
//!
//! LOTUS assumes "a scalable timestamp service deployed in the compute
//! pool" [10, 48, 59, 72, 89]. We implement it as a hybrid logical clock:
//! each timestamp packs a 48-bit physical component (virtual nanoseconds,
//! required by the GC threshold rule of section 7.1) and a 16-bit logical
//! counter that disambiguates timestamps drawn within the same nanosecond.
//! The oracle itself is a shared atomic: every draw is monotone across all
//! coordinators, and the caller's virtual clock is charged the service's
//! access latency ([`crate::dm::NetConfig::ts_oracle_ns`]) — the paper's
//! assumption that the service is scalable means there is no queueing term.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::dm::clock::VClock;

/// Bits of the logical counter in a composed timestamp.
pub const LOGICAL_BITS: u32 = 16;
const LOGICAL_MASK: u64 = (1 << LOGICAL_BITS) - 1;

/// Compose a timestamp from a physical time (ns) and a logical counter.
#[inline]
pub fn compose_ts(phys_ns: u64, logical: u64) -> u64 {
    debug_assert!(logical <= LOGICAL_MASK);
    (phys_ns << LOGICAL_BITS) | (logical & LOGICAL_MASK)
}

/// Physical (ns) component of a timestamp.
#[inline]
pub fn phys_of(ts: u64) -> u64 {
    ts >> LOGICAL_BITS
}

/// Logical component of a timestamp.
#[inline]
pub fn logical_of(ts: u64) -> u64 {
    ts & LOGICAL_MASK
}

/// The compute-pool timestamp service.
#[derive(Debug, Default)]
pub struct TimestampOracle {
    last: AtomicU64,
}

impl TimestampOracle {
    /// Fresh oracle at time zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Draw a monotone HLC timestamp; charges the oracle access latency
    /// (`ts_oracle_ns`) to the caller's virtual clock.
    pub fn timestamp(&self, clk: &mut VClock, ts_oracle_ns: u64) -> u64 {
        clk.advance(ts_oracle_ns);
        self.timestamp_at(clk.now())
    }

    /// Draw a timestamp for physical time `now_ns` without touching a
    /// clock (init-time loads, tests).
    pub fn timestamp_at(&self, now_ns: u64) -> u64 {
        let candidate = compose_ts(now_ns, 0);
        let mut prev = self.last.load(Ordering::Relaxed);
        loop {
            let next = candidate.max(prev + 1);
            match self
                .last
                .compare_exchange_weak(prev, next, Ordering::AcqRel, Ordering::Relaxed)
            {
                Ok(_) => return next,
                Err(v) => prev = v,
            }
        }
    }

    /// Last issued timestamp.
    pub fn last(&self) -> u64 {
        self.last.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn compose_roundtrip() {
        let ts = compose_ts(123_456, 7);
        assert_eq!(phys_of(ts), 123_456);
        assert_eq!(logical_of(ts), 7);
    }

    #[test]
    fn timestamps_strictly_monotone() {
        let o = TimestampOracle::new();
        let mut last = 0;
        for _ in 0..1000 {
            let ts = o.timestamp_at(5); // same physical instant
            assert!(ts > last);
            last = ts;
        }
    }

    #[test]
    fn physical_component_tracks_clock() {
        let o = TimestampOracle::new();
        let mut clk = VClock::zero();
        clk.advance(1_000_000);
        let ts = o.timestamp(&mut clk, 1_200);
        assert_eq!(phys_of(ts), 1_001_200);
        assert!(clk.now() == 1_001_200, "oracle latency must be charged");
    }

    #[test]
    fn later_physical_time_dominates_logical() {
        let o = TimestampOracle::new();
        let a = o.timestamp_at(100);
        let b = o.timestamp_at(200);
        assert!(b > a);
        assert_eq!(phys_of(b), 200);
    }

    #[test]
    fn concurrent_draws_are_unique() {
        let o = Arc::new(TimestampOracle::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let o = o.clone();
                std::thread::spawn(move || (0..1000).map(|_| o.timestamp_at(42)).collect::<Vec<u64>>())
            })
            .collect();
        let mut all: Vec<u64> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        let before = all.len();
        all.dedup();
        assert_eq!(all.len(), before, "duplicate timestamps issued");
    }

    #[test]
    fn prop_monotone_under_arbitrary_phys() {
        crate::testing::prop(30, |g| {
            let o = TimestampOracle::new();
            let mut last = 0;
            let mut t = 0u64;
            for _ in 0..g.usize(1, 200) {
                t += g.u64(0, 1000);
                let ts = o.timestamp_at(t);
                assert!(ts > last);
                assert!(phys_of(ts) >= t);
                last = ts;
            }
        });
    }
}
