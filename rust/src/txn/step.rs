//! Poll-driven continuation plumbing for the phase pipeline.
//!
//! Since ISSUE 4 every phase (and the workload driver above it) is a
//! **reified state machine**: transaction code is written in direct style
//! but compiled into a pollable machine ([`StepFut`]), cut at exactly its
//! issue points. The two poll outcomes map onto the step-machine
//! contract:
//!
//! - `Poll::Pending` == **Issued** — the machine posted a plan into the
//!   scheduler's in-flight table (`Flight::Staged`) and parked. Nothing
//!   on the OS stack holds the lane's state; it lives entirely inside the
//!   machine.
//! - `Poll::Ready` == **Done** — the machine ran to the end of its
//!   transaction.
//!
//! The pipelined [`crate::txn::scheduler::FrameScheduler`] keeps one
//! machine per lane and re-polls whichever runnable machine has the
//! smallest virtual clock (a flat ready-queue event loop — no nested
//! pumping, no recursion). Sequential conduits (the legacy coordinator
//! shell, baselines, recovery) drive the *same* machines with
//! [`expect_ready`]: without a scheduler sink no issue point ever parks,
//! so a single poll runs the machine to completion and the classic
//! blocking call semantics fall out for free.
//!
//! # Allocation shape (ISSUE 5)
//!
//! [`StepFut`] is a two-variant machine, not always a box:
//!
//! - [`StepFut::ready`] wraps an already-computed value with **no heap
//!   allocation** — the blocking `execute`/`commit` defaults on
//!   sequential and baseline paths, which used to pay a `Box::pin` per
//!   call just to satisfy the step surface.
//! - [`StepFut::from_future`] heap-reifies a real machine (workload
//!   drivers, the pipelined lanes' phase machines) — the variant that
//!   must survive parking, so the allocation is the point.
//!
//! Since ISSUE 9 the scheduler boxes one *perpetual* machine per lane
//! (`lane_loop`), parked between transactions and handed each new start
//! clock through the in-flight table — so the per-transaction driver box
//! is paid once per lane, not once per transaction. The phase-level
//! `execute_step`/`commit_step` machines still box per call (a
//! documented follow-on).
//!
//! The machines are never woken by a reactor — the scheduler knows
//! exactly which lanes completed (it rang their doorbells itself), so the
//! waker is a no-op and readiness is tracked in the in-flight table.

use std::future::Future;
use std::pin::Pin;
use std::sync::Arc;
use std::task::{Context, Poll, Wake, Waker};

/// A transaction step machine: an immediately-ready value (no
/// allocation) or a boxed, heap-reified continuation.
pub enum StepFut<'a, T> {
    /// An already-computed result — one poll yields it, nothing parks,
    /// nothing allocates. The blocking conduits' default shape.
    Ready(Option<T>),
    /// A heap-reified machine that may park at its issue points.
    Boxed(Pin<Box<dyn Future<Output = T> + 'a>>),
}

impl<'a, T> StepFut<'a, T> {
    /// Wrap an already-computed value (no heap allocation).
    pub fn ready(v: T) -> Self {
        StepFut::Ready(Some(v))
    }

    /// Heap-reify a machine (the parkable variant).
    pub fn from_future<F: Future<Output = T> + 'a>(f: F) -> Self {
        StepFut::Boxed(Box::pin(f))
    }
}

// Safe: the `Ready` payload is moved out on completion, never pinned —
// only the boxed machine's contents are behind a `Pin`, and `Pin<Box<_>>`
// is itself `Unpin`.
impl<T> Unpin for StepFut<'_, T> {}

impl<T> Future for StepFut<'_, T> {
    type Output = T;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<T> {
        match self.get_mut() {
            StepFut::Ready(v) => {
                Poll::Ready(v.take().expect("StepFut polled after completion"))
            }
            StepFut::Boxed(f) => f.as_mut().poll(cx),
        }
    }
}

/// No-op wake target: readiness lives in the scheduler's in-flight
/// table, not in a reactor, so waking is meaningless.
struct NoopWake;

impl Wake for NoopWake {
    fn wake(self: Arc<Self>) {}
}

/// The scheduler's waker (see [`NoopWake`]).
pub fn noop_waker() -> Waker {
    Waker::from(Arc::new(NoopWake))
}

/// Poll `fut` once and return its result, panicking if it parks.
///
/// This is the *blocking conduit* driver: sequential coordinators,
/// baselines and recovery run phase machines whose issue points are
/// direct (no [`crate::txn::phases::StepSink`]), so the machine can
/// never return `Poll::Pending` — one poll runs the whole transaction
/// step. A panic here means a suspending conduit leaked into a blocking
/// path, which is a programming error, not a runtime condition.
pub fn expect_ready<F: Future>(fut: F) -> F::Output {
    let waker = noop_waker();
    let mut cx = Context::from_waker(&waker);
    let mut fut = std::pin::pin!(fut);
    match fut.as_mut().poll(&mut cx) {
        Poll::Ready(v) => v,
        Poll::Pending => unreachable!(
            "a blocking (sink-less) phase machine parked at an issue point"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expect_ready_drives_a_straight_line_machine() {
        let v = expect_ready(async { 7 + 35 });
        assert_eq!(v, 42);
    }

    #[test]
    fn expect_ready_crosses_ready_await_points() {
        // Multiple immediately-ready awaits complete within one poll —
        // the property the sequential conduits rely on.
        async fn inner(x: u64) -> u64 {
            std::future::ready(x).await + std::future::ready(1).await
        }
        let v = expect_ready(async { inner(1).await + inner(2).await });
        assert_eq!(v, 7);
    }

    #[test]
    fn ready_variant_completes_without_boxing() {
        let fut: StepFut<'static, u64> = StepFut::ready(9);
        assert!(matches!(fut, StepFut::Ready(_)));
        assert_eq!(expect_ready(fut), 9);
    }

    #[test]
    fn boxed_variant_awaits_inside_ready_machines() {
        // A ready-wrapped step composes with a boxed driver exactly like
        // the old always-boxed shape did.
        let drive = StepFut::from_future(async {
            let a = StepFut::ready(20u64).await;
            let b = StepFut::from_future(std::future::ready(22u64)).await;
            a + b
        });
        assert_eq!(expect_ready(drive), 42);
    }

    #[test]
    #[should_panic(expected = "parked")]
    fn expect_ready_panics_on_a_parking_machine() {
        struct Park;
        impl Future for Park {
            type Output = ();
            fn poll(self: Pin<&mut Self>, _cx: &mut Context<'_>) -> Poll<()> {
                Poll::Pending
            }
        }
        expect_ready(Park);
    }
}
