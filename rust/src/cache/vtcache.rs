//! The version table cache (paper section 4.4, fig. 9).
//!
//! Caches CVT snapshots of records **within the CN's managed lock range**
//! so coordinators can select the version and its address locally, saving
//! the CVT READ (one RTT). Hash-partitioned into independent LRU
//! sub-caches to minimize thread contention, exactly as fig. 9 shows.
//!
//! Consistency (zero overhead, section 4.4):
//! - local write transactions hold the write lock and update the cached
//!   CVT synchronously with the memory pool ([`VtCache::put`]);
//! - remote write locks invalidate the entry during lock-request
//!   processing ([`VtCache::invalidate`], Algorithm 1 line 15);
//! - resharding clears the shard's entries before ownership moves
//!   ([`VtCache::invalidate_shard`], section 4.3).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::sharding::key::LotusKey;
use crate::store::cvt::CvtSnapshot;

/// Number of independent LRU sub-caches.
const SUB_CACHES: usize = 16;

/// An entry: the cached CVT plus the address it was read from.
#[derive(Debug, Clone)]
pub struct CachedCvt {
    /// The CVT snapshot.
    pub cvt: CvtSnapshot,
    /// Primary-MN address of the CVT.
    pub addr: u64,
}

struct SubCache {
    map: HashMap<u64, (CachedCvt, u64)>, // key -> (entry, lru tick)
    tick: u64,
    capacity: usize,
    /// Bumped on every invalidation — lets lock-free readers fill the
    /// cache safely: a fill is rejected if an invalidation ran between
    /// the CVT read and the fill (see [`VtCache::put_if_epoch`]).
    epoch: u64,
}

impl SubCache {
    fn touch(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    fn evict_if_full(&mut self) {
        if self.map.len() < self.capacity {
            return;
        }
        // Evict the least recently used entry.
        if let Some(&victim) = self
            .map
            .iter()
            .min_by_key(|(_, (_, tick))| *tick)
            .map(|(k, _)| k)
        {
            self.map.remove(&victim);
        }
    }
}

/// The per-CN version table cache.
pub struct VtCache {
    subs: Vec<Mutex<SubCache>>,
    hits: AtomicU64,
    misses: AtomicU64,
    invalidations: AtomicU64,
}

impl VtCache {
    /// Cache holding at most `capacity` CVTs (paper default 64K ~ 4.5 MB).
    pub fn new(capacity: usize) -> Self {
        let per_sub = (capacity / SUB_CACHES).max(1);
        Self {
            subs: (0..SUB_CACHES)
                .map(|_| {
                    Mutex::new(SubCache {
                        map: HashMap::new(),
                        tick: 0,
                        capacity: per_sub,
                        epoch: 0,
                    })
                })
                .collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            invalidations: AtomicU64::new(0),
        }
    }

    #[inline]
    fn sub(&self, key: LotusKey) -> &Mutex<SubCache> {
        &self.subs[(key.fingerprint32() as usize >> 4) % SUB_CACHES]
    }

    /// Look up a CVT; counts hit/miss and refreshes LRU order.
    pub fn get(&self, key: LotusKey) -> Option<CachedCvt> {
        let mut sub = self.sub(key).lock().unwrap();
        let tick = sub.touch();
        match sub.map.get_mut(&key.0) {
            Some((entry, t)) => {
                *t = tick;
                let hit = entry.clone();
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(hit)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert / refresh a CVT (local writer — safe, the write lock is
    /// held, so no invalidation can race this fill).
    pub fn put(&self, key: LotusKey, entry: CachedCvt) {
        let mut sub = self.sub(key).lock().unwrap();
        let tick = sub.touch();
        if !sub.map.contains_key(&key.0) {
            sub.evict_if_full();
        }
        sub.map.insert(key.0, (entry, tick));
    }

    /// Invalidation epoch of the key's sub-cache. Capture before issuing
    /// a lock-free CVT read; pass to [`Self::put_if_epoch`] afterwards.
    pub fn epoch(&self, key: LotusKey) -> u64 {
        self.sub(key).lock().unwrap().epoch
    }

    /// Fill from a lock-free reader: only lands if no invalidation ran
    /// since `seen_epoch` (otherwise the fetched CVT may be stale).
    pub fn put_if_epoch(&self, key: LotusKey, entry: CachedCvt, seen_epoch: u64) -> bool {
        let mut sub = self.sub(key).lock().unwrap();
        if sub.epoch != seen_epoch {
            return false;
        }
        let tick = sub.touch();
        if !sub.map.contains_key(&key.0) {
            sub.evict_if_full();
        }
        sub.map.insert(key.0, (entry, tick));
        true
    }

    /// Invalidate one key (remote write lock, Algorithm 1 line 15).
    pub fn invalidate(&self, key: LotusKey) {
        let mut sub = self.sub(key).lock().unwrap();
        sub.epoch += 1;
        if sub.map.remove(&key.0).is_some() {
            self.invalidations.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Invalidate every entry of one shard (resharding sender, 4.3).
    pub fn invalidate_shard(&self, shard: u16) {
        for sub in &self.subs {
            let mut sub = sub.lock().unwrap();
            sub.epoch += 1;
            sub.map.retain(|k, _| LotusKey(*k).shard() != shard);
        }
    }

    /// Drop everything (CN restart).
    pub fn clear(&self) {
        for sub in &self.subs {
            let mut sub = sub.lock().unwrap();
            sub.epoch += 1;
            sub.map.clear();
        }
    }

    /// Number of cached CVTs.
    pub fn len(&self) -> usize {
        self.subs.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    /// Is the cache empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses, invalidations).
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.invalidations.load(Ordering::Relaxed),
        )
    }

    /// Hit rate in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        let (h, m, _) = self.stats();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Reset the hit/miss counters (not the contents).
    pub fn reset_stats(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.invalidations.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(addr: u64) -> CachedCvt {
        let mut cvt = CvtSnapshot::empty(2);
        cvt.key = addr; // arbitrary
        CachedCvt { cvt, addr }
    }

    fn k(i: u64) -> LotusKey {
        LotusKey::compose(i, i)
    }

    #[test]
    fn put_get_invalidate() {
        let c = VtCache::new(64);
        assert!(c.get(k(1)).is_none());
        c.put(k(1), entry(0x100));
        let got = c.get(k(1)).unwrap();
        assert_eq!(got.addr, 0x100);
        c.invalidate(k(1));
        assert!(c.get(k(1)).is_none());
        let (h, m, inv) = c.stats();
        assert_eq!((h, m, inv), (1, 2, 1));
    }

    #[test]
    fn capacity_enforced_with_lru_eviction() {
        let c = VtCache::new(SUB_CACHES * 4); // 4 per sub-cache
        for i in 0..1000 {
            c.put(k(i), entry(i));
        }
        assert!(c.len() <= SUB_CACHES * 4, "len={}", c.len());
    }

    #[test]
    fn lru_keeps_recently_used() {
        let c = VtCache::new(SUB_CACHES); // capacity 1 per sub-cache
        // Find two keys landing in the same sub-cache.
        let base = k(0);
        let mut other = None;
        for i in 1..10_000 {
            if (k(i).fingerprint32() as usize >> 4) % SUB_CACHES
                == (base.fingerprint32() as usize >> 4) % SUB_CACHES
            {
                other = Some(k(i));
                break;
            }
        }
        let other = other.expect("no colliding key found");
        c.put(base, entry(1));
        c.get(base); // touch
        c.put(other, entry(2)); // must evict... capacity 1, so base evicted
        assert!(c.get(other).is_some());
    }

    #[test]
    fn invalidate_shard_clears_only_that_shard() {
        let c = VtCache::new(1024);
        for uid in 0..20 {
            c.put(LotusKey::compose(3, uid), entry(uid));
            c.put(LotusKey::compose(4, uid), entry(uid));
        }
        c.invalidate_shard(3);
        for uid in 0..20 {
            assert!(c.get(LotusKey::compose(3, uid)).is_none());
            assert!(c.get(LotusKey::compose(4, uid)).is_some());
        }
    }

    #[test]
    fn hit_rate_math() {
        let c = VtCache::new(64);
        c.put(k(1), entry(1));
        c.get(k(1));
        c.get(k(2));
        assert!((c.hit_rate() - 0.5).abs() < 1e-9);
        c.reset_stats();
        assert_eq!(c.stats(), (0, 0, 0));
    }

    #[test]
    fn concurrent_access_smoke() {
        use std::sync::Arc;
        let c = Arc::new(VtCache::new(256));
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        let key = k(i % 64);
                        if (i + t) % 3 == 0 {
                            c.put(key, entry(i));
                        } else if (i + t) % 3 == 1 {
                            c.get(key);
                        } else {
                            c.invalidate(key);
                        }
                    }
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        assert!(c.len() <= 256);
    }
}
