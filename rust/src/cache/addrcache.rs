//! The version table **address** cache (paper section 5).
//!
//! Maps key -> CVT address on the primary MN. Unlike the version table
//! cache it "requires no active consistency maintenance, since CNs can
//! detect stale cached addresses by validating the retrieved CVTs" (the
//! fetched CVT's key field must equal the requested key). Unbounded, like
//! the address caches in FORD/Motor (paper 8.1: "we do not impose a size
//! limit ... consistent with the previous studies").

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::sharding::key::LotusKey;

const SHARDS: usize = 32;

/// key -> primary CVT address.
pub struct AddrCache {
    shards: Vec<Mutex<HashMap<u64, u64>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for AddrCache {
    fn default() -> Self {
        Self::new()
    }
}

impl AddrCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    #[inline]
    fn shard(&self, key: LotusKey) -> &Mutex<HashMap<u64, u64>> {
        &self.shards[(key.fingerprint32() as usize >> 8) % SHARDS]
    }

    /// Cached CVT address for a key.
    pub fn get(&self, key: LotusKey) -> Option<u64> {
        let found = self.shard(key).lock().unwrap().get(&key.0).copied();
        match found {
            Some(a) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(a)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record a key's CVT address.
    pub fn put(&self, key: LotusKey, addr: u64) {
        self.shard(key).lock().unwrap().insert(key.0, addr);
    }

    /// Drop a stale address (validation failed).
    pub fn invalidate(&self, key: LotusKey) {
        self.shard(key).lock().unwrap().remove(&key.0);
    }

    /// Entry count.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Is the cache empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses).
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Drop everything (CN restart).
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(i: u64) -> LotusKey {
        LotusKey::compose(i, i)
    }

    #[test]
    fn put_get_invalidate() {
        let c = AddrCache::new();
        assert_eq!(c.get(k(1)), None);
        c.put(k(1), 0xAB);
        assert_eq!(c.get(k(1)), Some(0xAB));
        c.invalidate(k(1));
        assert_eq!(c.get(k(1)), None);
        assert_eq!(c.stats(), (1, 2));
    }

    #[test]
    fn overwrite_updates() {
        let c = AddrCache::new();
        c.put(k(2), 1);
        c.put(k(2), 2);
        assert_eq!(c.get(k(2)), Some(2));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn clear_empties() {
        let c = AddrCache::new();
        for i in 0..100 {
            c.put(k(i), i);
        }
        assert_eq!(c.len(), 100);
        c.clear();
        assert!(c.is_empty());
    }
}
