//! CN-side caches (paper sections 4.4 and 5).
//!
//! - [`vtcache`] — the **version table cache**: LRU sub-caches of CVT
//!   snapshots for keys within the CN's managed lock range. Consistency
//!   costs nothing extra: local writers update the cached CVT while they
//!   update the memory pool (they hold the write lock), and remote write
//!   locks invalidate the entry as part of lock-request processing
//!   (Algorithm 1 line 15).
//! - [`addrcache`] — the **version table address cache**: key -> CVT
//!   address. Needs no consistency maintenance at all: a stale address is
//!   detected when the fetched CVT's key does not match.

pub mod addrcache;
pub mod vtcache;

pub use addrcache::AddrCache;
pub use vtcache::VtCache;
