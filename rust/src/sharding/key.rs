//! The 64-bit LOTUS key and its hash (paper fig. 7, sections 4.1-4.2).
//!
//! Layout: `[ unique:52 | shard:12 ]` — the low [`SHARD_BITS`] bits are the
//! shard number, copied from the low bits of the *critical field* the
//! application designates (warehouse id for TPCC, subscriber id for TATP,
//! account id for SmallBank); the upper 52 bits are derived from the full
//! primary key and keep records unique within a table.
//!
//! [`mix32`] is the EXACT function implemented by the L1 Pallas kernel
//! (`python/compile/kernels/shard_hash.py`); an integration test runs the
//! AOT artifact through PJRT and asserts bit equality, pinning the rust
//! and kernel layers together.

/// Shard-number width (paper: lowest 12 bits of the critical field).
pub const SHARD_BITS: u32 = 12;
/// Total shards in the key space.
pub const N_SHARDS: usize = 1 << SHARD_BITS;
const SHARD_MASK: u64 = (N_SHARDS - 1) as u64;

/// FNV-1a 32-bit parameters — keep in sync with the Pallas kernel.
pub const FNV_OFFSET: u32 = 2166136261;
/// FNV-1a prime.
pub const FNV_PRIME: u32 = 16777619;
/// Final-avalanche multiplier.
pub const AVALANCHE: u32 = 2246822519;

/// Two FNV-1a rounds over the key halves + xorshift avalanche.
/// Bit-identical to `kernels.shard_hash._mix32`.
#[inline]
pub fn mix32(hi: u32, lo: u32) -> u32 {
    let mut h = (FNV_OFFSET ^ lo).wrapping_mul(FNV_PRIME);
    h = (h ^ hi).wrapping_mul(FNV_PRIME);
    h ^= h >> 15;
    h = h.wrapping_mul(AVALANCHE);
    h ^= h >> 13;
    h
}

/// A 64-bit LOTUS key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LotusKey(pub u64);

impl LotusKey {
    /// Compose a key from the critical field and a unique record id
    /// (`unique` must fit in 52 bits; asserted in debug builds).
    #[inline]
    pub fn compose(critical_field: u64, unique: u64) -> Self {
        debug_assert!(unique < (1 << 52), "unique id overflows 52 bits");
        LotusKey((unique << SHARD_BITS) | (critical_field & SHARD_MASK))
    }

    /// The shard number (low 12 bits).
    #[inline]
    pub fn shard(self) -> u16 {
        (self.0 & SHARD_MASK) as u16
    }

    /// The unique (upper-52-bit) part.
    #[inline]
    pub fn unique(self) -> u64 {
        self.0 >> SHARD_BITS
    }

    /// High/low u32 halves (the Pallas kernel's input format).
    #[inline]
    pub fn halves(self) -> (u32, u32) {
        ((self.0 >> 32) as u32, self.0 as u32)
    }

    /// 32-bit fingerprint (identical to the kernel output).
    #[inline]
    pub fn fingerprint32(self) -> u32 {
        let (hi, lo) = self.halves();
        mix32(hi, lo)
    }

    /// 56-bit fingerprint for the lock-table slot (7B in the paper): the
    /// kernel's 32-bit mix in the high bits plus 24 extra mixed bits.
    #[inline]
    pub fn fingerprint56(self) -> u64 {
        let (hi, lo) = self.halves();
        ((mix32(hi, lo) as u64) << 24) | ((mix32(lo, hi) as u64) & 0xFF_FFFF)
    }

    /// Lock-table bucket for `n_buckets` (matches the kernel's
    /// `fingerprint % n_buckets`).
    #[inline]
    pub fn lock_bucket(self, n_buckets: u32) -> u32 {
        self.fingerprint32() % n_buckets
    }

    /// Index bucket in a hash index of `n_buckets` (uses independent bits
    /// so index placement does not correlate with lock placement: the low
    /// word — which dominates `% n_buckets` for power-of-two counts — is
    /// a *different* mix than the lock fingerprint).
    #[inline]
    pub fn index_bucket(self, n_buckets: u64) -> u64 {
        let (hi, lo) = self.halves();
        let h = ((mix32(hi, lo) as u64) << 32) | mix32(lo ^ 0x9E37_79B9, hi) as u64;
        h % n_buckets
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compose_extracts_shard_and_unique() {
        let k = LotusKey::compose(0xABCD, 42);
        assert_eq!(k.shard(), 0xBCD); // low 12 bits of the critical field
        assert_eq!(k.unique(), 42);
    }

    #[test]
    fn same_critical_field_same_shard() {
        // TPCC semantics: all records of one warehouse share a shard.
        let w_id = 17u64;
        for uid in 0..100 {
            assert_eq!(
                LotusKey::compose(w_id, uid).shard(),
                LotusKey::compose(w_id, 7777).shard()
            );
        }
    }

    #[test]
    fn distinct_uniques_distinct_keys() {
        let a = LotusKey::compose(5, 1);
        let b = LotusKey::compose(5, 2);
        assert_ne!(a, b);
        assert_eq!(a.shard(), b.shard());
    }

    #[test]
    fn mix32_avalanche() {
        // Flipping one input bit flips many output bits.
        let a = mix32(0, 0);
        let b = mix32(0, 1);
        assert!((a ^ b).count_ones() >= 8, "weak avalanche: {a:#x} vs {b:#x}");
    }

    #[test]
    fn mix32_reference_vectors() {
        // Golden vectors — the python test suite checks the same function.
        // (Computed once from the reference implementation.)
        fn slow_mix(hi: u32, lo: u32) -> u32 {
            let mut h = (2166136261u32 ^ lo).wrapping_mul(16777619);
            h = (h ^ hi).wrapping_mul(16777619);
            h ^= h >> 15;
            h = h.wrapping_mul(2246822519);
            h ^= h >> 13;
            h
        }
        for (hi, lo) in [
            (0u32, 0u32),
            (0, 1),
            (1, 0),
            (0xDEADBEEF, 0xCAFEBABE),
            (u32::MAX, u32::MAX),
        ] {
            assert_eq!(mix32(hi, lo), slow_mix(hi, lo));
        }
    }

    #[test]
    fn fingerprint56_fits_7_bytes() {
        crate::testing::prop(100, |g| {
            let k = LotusKey(g.any_u64());
            assert!(k.fingerprint56() < (1u64 << 56));
        });
    }

    #[test]
    fn fingerprint56_top_bits_match_kernel_mix() {
        crate::testing::prop(100, |g| {
            let k = LotusKey(g.any_u64());
            assert_eq!((k.fingerprint56() >> 24) as u32, k.fingerprint32());
        });
    }

    #[test]
    fn lock_bucket_in_range() {
        crate::testing::prop(100, |g| {
            let k = LotusKey(g.any_u64());
            let n = g.u64(1, 1 << 20) as u32;
            assert!(k.lock_bucket(n) < n);
        });
    }

    #[test]
    fn fingerprint_spread_over_sequential_keys() {
        use std::collections::HashSet;
        let fps: HashSet<u64> = (0..10_000u64)
            .map(|uid| LotusKey::compose(3, uid).fingerprint56())
            .collect();
        assert!(fps.len() >= 9_995, "collisions: {}", 10_000 - fps.len());
    }

    #[test]
    fn index_bucket_decorrelated_from_lock_bucket() {
        // Keys in one lock bucket should spread over index buckets.
        let n = 1024u64;
        let keys: Vec<LotusKey> = (0..100_000u64)
            .map(|uid| LotusKey::compose(uid, uid))
            .filter(|k| k.lock_bucket(n as u32) == 0)
            .take(50)
            .collect();
        let distinct: std::collections::HashSet<u64> =
            keys.iter().map(|k| k.index_bucket(n)).collect();
        assert!(distinct.len() > keys.len() / 2);
    }
}
