//! Pass-by-range resharding (paper section 4.3).
//!
//! Moves **lock ownership** of one shard between CNs — never the data.
//! The sender stops serving the shard, drains (or proactively aborts) the
//! transactions still holding locks in it, clears its cached state for
//! the shard, and hands ownership to the receiver with one RPC; finally
//! the routing layer is updated. Requests racing the window bounce with
//! `WrongShardOwner` and retry against the fresh map, so the lock service
//! is only briefly interrupted (paper: 0.19–4.67 ms measured).

use crate::dm::clock::VClock;
use crate::txn::coordinator::SharedCluster;
use crate::Result;

/// Outcome of one shard transfer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReshardReport {
    /// Shard moved.
    pub shard: u16,
    /// Previous owner.
    pub from: usize,
    /// New owner.
    pub to: usize,
    /// Transactions proactively aborted (lock drain timeout path).
    pub aborted_txns: usize,
    /// Virtual ns the shard's lock service was interrupted.
    pub interruption_ns: u64,
}

/// Transfer `shard` from CN `from` to CN `to`. Executed by a coordinator
/// thread of the sender (its `clk` is charged).
pub fn transfer_shard(
    cluster: &SharedCluster,
    shard: u16,
    from: usize,
    to: usize,
    clk: &mut VClock,
) -> Result<ReshardReport> {
    debug_assert_ne!(from, to);
    debug_assert_eq!(cluster.router.owner_of(shard), from);
    let t0 = clk.now();
    let sender = &cluster.lock_services[from];

    // 1. Stop serving lock requests for the shard.
    sender.pause_shard(shard);

    // 2. Drain: the paper waits up to ~10 ms for in-flight holders, then
    //    proactively aborts them via the (txn, CN) ids in the lock state.
    //    The simulator cannot block a virtual-time window across threads,
    //    so it takes the proactive path directly whenever holders exist —
    //    a conservative (worst-case) model of the drain.
    let holders = sender.holders_in_shard(shard);
    let aborted = if holders.is_empty() {
        0
    } else {
        cluster.doomed.doom_all(holders.iter().map(|h| h.txn));
        let txns = sender.force_release_shard(shard);
        txns.len()
    };

    // 3. Clear shard-local cached state (the receiver owns it now).
    cluster.vt_caches[from].invalidate_shard(shard);

    // 4. Hand over via RPC (SEND/RECV, paper 4.3).
    cluster.rpc.call(from, to, 0, 1, clk)?;
    cluster.lock_services[to].resume_shard(shard); // defensive: fresh start

    // 5. Publish the new mapping to the routing layer.
    cluster.router.set_owner(shard, to);
    sender.resume_shard(shard); // sender no longer owns it; unpause

    Ok(ReshardReport {
        shard,
        from,
        to,
        aborted_txns: aborted,
        interruption_ns: clk.now() - t0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::lock::state::HolderId;
    use crate::lock::table::LockMode;
    use crate::sharding::key::LotusKey;
    use crate::sim::Cluster;
    use crate::store::index::TableSpec;
    use std::sync::Arc;

    fn mini() -> Arc<SharedCluster> {
        let mut cfg = Config::small();
        cfg.n_cns = 3;
        let specs = vec![TableSpec {
            id: 0,
            name: "t".into(),
            record_len: 16,
            ncells: 2,
            assoc: 4,
            expected_records: 1024,
        }];
        Cluster::build_shared(&cfg, specs).unwrap()
    }

    #[test]
    fn ownership_moves_and_requests_follow() {
        let c = mini();
        let shard = c.router.shards_of(0)[0];
        let key = LotusKey::compose(shard as u64, 42);
        let mut clk = VClock::zero();
        let rep = transfer_shard(&c, shard, 0, 1, &mut clk).unwrap();
        assert_eq!(rep.aborted_txns, 0);
        assert!(rep.interruption_ns > 0);
        assert_eq!(c.router.owner_of(shard), 1);
        // The old owner bounces, the new owner serves.
        let h = HolderId { cn: 2, txn: 1 };
        assert!(c.lock_services[0]
            .try_acquire(&c.router, key, LockMode::Write, h, true)
            .is_err());
        assert!(c.lock_services[1]
            .try_acquire(&c.router, key, LockMode::Write, h, true)
            .unwrap());
    }

    #[test]
    fn holders_are_aborted_and_locks_freed() {
        let c = mini();
        let shard = c.router.shards_of(0)[1];
        let key = LotusKey::compose(shard as u64, 7);
        let h = HolderId { cn: 2, txn: 555 };
        assert!(c.lock_services[0]
            .try_acquire(&c.router, key, LockMode::Write, h, true)
            .unwrap());
        let mut clk = VClock::zero();
        let rep = transfer_shard(&c, shard, 0, 2, &mut clk).unwrap();
        assert_eq!(rep.aborted_txns, 1);
        assert!(c.doomed.contains(555), "holder must be doomed");
        assert_eq!(c.lock_services[0].held_slots(), 0);
    }

    #[test]
    fn vt_cache_entries_of_shard_cleared_on_sender() {
        let c = mini();
        let shard = c.router.shards_of(0)[0];
        let key = LotusKey::compose(shard as u64, 3);
        c.vt_caches[0].put(
            key,
            crate::cache::vtcache::CachedCvt {
                cvt: crate::store::cvt::CvtSnapshot::empty(1),
                addr: 8,
            },
        );
        let mut clk = VClock::zero();
        transfer_shard(&c, shard, 0, 1, &mut clk).unwrap();
        assert!(c.vt_caches[0].get(key).is_none());
    }
}
