//! The routing layer: shard-to-CN mapping + hybrid transaction routing.
//!
//! Paper section 4.2-4.3: upper-layer applications submit transactions to
//! a routing layer that caches the latest shard-to-CN mapping. Read-only
//! transactions go to a uniformly random CN; read-write transactions go to
//! the CN owning the shard of their *first* record, so most lock requests
//! are local. CNs validate ownership on every lock request and return
//! [`crate::Error::WrongShardOwner`] on staleness, prompting a refresh.
//!
//! The paper assumes the routing layer is scalable and fault-tolerant
//! (replicated, read-mostly) and orthogonal to the contribution; here it
//! is an atomic array, which satisfies the same interface.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::sharding::key::{LotusKey, N_SHARDS};
use crate::util::Xoshiro256;
use crate::{Error, Result};

/// Where a transaction should run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteDecision {
    /// Run on this CN.
    Cn(usize),
}

/// Shard-to-CN routing table.
pub struct Router {
    owner: Vec<AtomicUsize>,
    n_cns: usize,
    /// Bumped on every remap (lets CNs cheaply notice staleness).
    epoch: AtomicU64,
}

impl Router {
    /// Initial mapping: key range evenly distributed among CNs
    /// (shard `s` -> CN `s * n_cns / N_SHARDS`, contiguous ranges).
    pub fn new(n_cns: usize) -> Self {
        assert!(n_cns > 0);
        let owner = (0..N_SHARDS)
            .map(|s| AtomicUsize::new(s * n_cns / N_SHARDS))
            .collect();
        Self {
            owner,
            n_cns,
            epoch: AtomicU64::new(0),
        }
    }

    /// Number of CNs.
    pub fn n_cns(&self) -> usize {
        self.n_cns
    }

    /// Current owner of a shard.
    #[inline]
    pub fn owner_of(&self, shard: u16) -> usize {
        self.owner[shard as usize].load(Ordering::Acquire)
    }

    /// Owner of a key's shard.
    #[inline]
    pub fn owner_of_key(&self, key: LotusKey) -> usize {
        self.owner_of(key.shard())
    }

    /// Remap a shard to a new owner (resharding commits through here).
    pub fn set_owner(&self, shard: u16, cn: usize) {
        assert!(cn < self.n_cns);
        self.owner[shard as usize].store(cn, Ordering::Release);
        self.epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Routing-table epoch (bumps on every remap).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Hybrid routing: read-write transactions go to the owner of the
    /// first record's shard.
    #[inline]
    pub fn route_rw(&self, first_key: LotusKey) -> RouteDecision {
        RouteDecision::Cn(self.owner_of_key(first_key))
    }

    /// Hybrid routing: read-only transactions go to a uniform random CN.
    #[inline]
    pub fn route_ro(&self, rng: &mut Xoshiro256) -> RouteDecision {
        RouteDecision::Cn(rng.below_usize(self.n_cns))
    }

    /// CN-side ownership check for an incoming lock request.
    #[inline]
    pub fn assert_owner(&self, cn: usize, shard: u16) -> Result<()> {
        let owner = self.owner_of(shard);
        if owner == cn {
            Ok(())
        } else {
            Err(Error::WrongShardOwner { shard, cn })
        }
    }

    /// All shards currently owned by `cn` (used by resharding + recovery).
    pub fn shards_of(&self, cn: usize) -> Vec<u16> {
        (0..N_SHARDS as u16)
            .filter(|&s| self.owner_of(s) == cn)
            .collect()
    }

    /// Shard-count balance: (min, max) shards per CN.
    pub fn balance(&self) -> (usize, usize) {
        let mut counts = vec![0usize; self.n_cns];
        for s in 0..N_SHARDS as u16 {
            counts[self.owner_of(s)] += 1;
        }
        (
            *counts.iter().min().unwrap(),
            *counts.iter().max().unwrap(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_mapping_covers_all_cns_evenly() {
        let r = Router::new(9);
        let (min, max) = r.balance();
        assert!(max - min <= 1, "uneven initial split: {min}..{max}");
        // Every CN owns something.
        for cn in 0..9 {
            assert!(!r.shards_of(cn).is_empty());
        }
    }

    #[test]
    fn initial_mapping_is_contiguous_ranges() {
        let r = Router::new(4);
        // Owners must be monotone over shard ids.
        let mut last = 0;
        for s in 0..N_SHARDS as u16 {
            let o = r.owner_of(s);
            assert!(o >= last, "non-contiguous mapping at shard {s}");
            last = o;
        }
    }

    #[test]
    fn rw_routing_follows_owner() {
        let r = Router::new(4);
        let k = LotusKey::compose(100, 5);
        let RouteDecision::Cn(cn) = r.route_rw(k);
        assert_eq!(cn, r.owner_of(k.shard()));
    }

    #[test]
    fn ro_routing_is_spread() {
        let r = Router::new(8);
        let mut rng = Xoshiro256::new(1);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let RouteDecision::Cn(cn) = r.route_ro(&mut rng);
            seen[cn] = true;
        }
        assert!(seen.iter().all(|&s| s), "RO routing misses CNs: {seen:?}");
    }

    #[test]
    fn remap_bumps_epoch_and_moves_ownership() {
        let r = Router::new(3);
        let e0 = r.epoch();
        r.set_owner(7, 2);
        assert_eq!(r.owner_of(7), 2);
        assert!(r.epoch() > e0);
        assert!(r.assert_owner(2, 7).is_ok());
        let err = r.assert_owner(0, 7).unwrap_err();
        assert!(matches!(err, Error::WrongShardOwner { shard: 7, cn: 0 }));
    }

    #[test]
    fn shards_of_consistent_with_owner_of() {
        crate::testing::prop(20, |g| {
            let n = g.usize(1, 12);
            let r = Router::new(n);
            // random remaps
            for _ in 0..g.usize(0, 50) {
                let s = g.u64(0, N_SHARDS as u64 - 1) as u16;
                let cn = g.usize(0, n - 1);
                r.set_owner(s, cn);
            }
            let mut total = 0;
            for cn in 0..n {
                for s in r.shards_of(cn) {
                    assert_eq!(r.owner_of(s), cn);
                    total += 1;
                }
            }
            assert_eq!(total, N_SHARDS, "shards lost or duplicated");
        });
    }
}
