//! Application-aware lock sharding (paper section 4.2) and the routing
//! layer (sections 3, 4.3).
//!
//! - [`key`] — the 64-bit LOTUS key: low 12 bits are the *shard number*
//!   taken from the application's critical field; the upper 52 bits keep
//!   the record unique. Also the fingerprint hash shared bit-for-bit with
//!   the L1 Pallas kernel.
//! - [`router`] — the shard-to-CN map + hybrid transaction routing
//!   (read-only: uniform random CN; read-write: the CN owning the first
//!   record's shard).

pub mod key;
pub mod resharding;
pub mod router;

pub use key::{LotusKey, N_SHARDS, SHARD_BITS};
pub use resharding::{transfer_shard, ReshardReport};
pub use router::{Router, RouteDecision};
