//! Motor-like baseline (paper [97]): MVCC on DM with MN-side CAS locks.
//!
//! Multi-versioned CVTs, doorbell-batched CAS+READ locking, delta-store
//! record layout (one full record plus deltas — non-latest reads pay a
//! reconstruction READ), and the UPS-backed-DRAM durability assumption
//! (no commit log, no separate write-visible step).

use crate::baselines::common::BaselineStyle;

/// Motor's style parameters.
pub fn style() -> BaselineStyle {
    BaselineStyle {
        mvcc: true,
        use_cas: true,
        delta_store: true,
        value_in_bucket: false,
        ideal_faa: false,
        name: "motor",
    }
}

/// Motor with the "+Full Record Store" ablation applied (fig. 14): every
/// version an independent full record, no delta reconstruction reads.
pub fn full_record_style() -> BaselineStyle {
    BaselineStyle {
        delta_store: false,
        name: "motor-full-record",
        ..style()
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn style_is_mvcc_with_cas() {
        let s = super::style();
        assert!(s.mvcc && s.use_cas && s.delta_store);
        assert!(!s.value_in_bucket && !s.ideal_faa);
    }
}
