//! FORD-like baseline (paper [98]): single-versioned transactions on DM.
//!
//! One version per record (readers abort while a write is in flight),
//! values stored beside the versions in the hash bucket (bucket and CVT
//! reads carry full values — the bandwidth-bound behaviour fig. 3 calls
//! out), CAS+READ doorbell locking, read-set validation before commit.

use crate::baselines::common::BaselineStyle;

/// FORD's style parameters.
pub fn style() -> BaselineStyle {
    BaselineStyle {
        mvcc: false,
        use_cas: true,
        delta_store: false,
        value_in_bucket: true,
        ideal_faa: false,
        name: "ford",
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn style_is_single_version_value_in_bucket() {
        let s = super::style();
        assert!(!s.mvcc && s.use_cas && s.value_in_bucket);
    }
}
