//! The idealized RDMA lock model (paper fig. 17, modelled after
//! DecLock [96]).
//!
//! Each acquisition/release is a single FAA-priced MN round trip — no
//! retry loops, no queues, no notification traffic — "a strict upper
//! bound" on CN-cooperative RDMA locking. LOTUS still wins 1.3–1.9x
//! because these designs keep the lock's *global state* in the memory
//! pool: every transition crosses the MN RNIC's atomics pipeline, while
//! LOTUS's locks never leave the compute pool.

use crate::baselines::common::BaselineStyle;

/// Idealized-lock style: LOTUS-equivalent MVCC data path, FAA locking.
pub fn style() -> BaselineStyle {
    BaselineStyle {
        mvcc: true,
        use_cas: true,
        delta_store: false,
        value_in_bucket: false,
        ideal_faa: true,
        name: "ideal-lock",
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn style_uses_faa() {
        let s = super::style();
        assert!(s.ideal_faa && s.mvcc && !s.delta_store);
    }
}
