//! Baseline transaction systems (paper §8: Motor, FORD, their unsafe
//! no-CAS variants, and the idealized RDMA lock).
//!
//! All baselines co-locate locks with data in the memory pool: locking is
//! a one-sided **RDMA CAS to the MN RNIC** — the 2.5 Mops bottleneck the
//! paper identifies — while LOTUS handles locks on CN CPUs. The baselines
//! share one protocol engine ([`common::BaselineCoordinator`])
//! parameterized by a [`common::BaselineStyle`]:
//!
//! - [`motor`] — Motor-like: MVCC over CVTs, doorbell-batched CAS+READ,
//!   delta-store layout (full record + deltas: old-version reads pay an
//!   extra READ), UPS-backed DRAM assumption (no log / visible steps).
//! - [`ford`] — FORD-like: single-versioning (in-flight writes block
//!   readers), read validation before commit, value stored with the
//!   version in the hash bucket (bucket reads carry full values, making
//!   FORD bandwidth-bound early — fig. 3's observation).
//! - [`nolock`] — fig. 3: Motor/FORD with CAS abandoned (unsafe), showing
//!   the headroom the MN-RNIC atomics bottleneck hides.
//! - [`ideal_rdma_lock`] — fig. 17: locks stay logically global but an
//!   RDMA FAA reaches the MN only when key ownership *transfers* between
//!   CNs — a strict upper bound on CN-cooperative RDMA locking
//!   (DSLR/ShiftLock/DecLock-style).

pub mod common;
pub mod ford;
pub mod ideal_rdma_lock;
pub mod motor;
pub mod nolock;

pub use common::{BaselineCoordinator, BaselineStyle};
