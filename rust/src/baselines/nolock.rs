//! The fig. 3 "abandon CAS" variants: Motor and FORD with every RDMA
//! atomic removed (operating **unsafely** — no mutual exclusion). The
//! paper uses these to expose how much headroom the MN-RNIC atomics
//! bottleneck hides: Motor-no-CAS reaches 2.4x its lock-bound peak.

use crate::baselines::common::BaselineStyle;
use crate::baselines::{ford, motor};

/// Motor without CAS.
pub fn motor_nocas_style() -> BaselineStyle {
    BaselineStyle {
        use_cas: false,
        name: "motor-nocas",
        ..motor::style()
    }
}

/// FORD without CAS.
pub fn ford_nocas_style() -> BaselineStyle {
    BaselineStyle {
        use_cas: false,
        name: "ford-nocas",
        ..ford::style()
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn nocas_styles_disable_cas_only() {
        let m = super::motor_nocas_style();
        assert!(!m.use_cas && m.mvcc);
        let f = super::ford_nocas_style();
        assert!(!f.use_cas && !f.mvcc && f.value_in_bucket);
    }
}
