//! The shared baseline protocol engine.
//!
//! Implements the Motor/FORD-style transaction flow in which locks are
//! **one-sided RDMA CAS on the memory nodes** (vs LOTUS's CN-resident
//! lock tables). The same [`crate::txn::api::TxnApi`] surface as the
//! LOTUS coordinator, so every workload runs unmodified.
//!
//! Protocol (fig. 2's systems):
//!
//! 1. *Resolve*: find each record's CVT (address cache, else bucket READ).
//! 2. *Lock + read*: doorbell-batched `CAS(lock) + READ(CVT)` per MN —
//!    the paper's 1-RTT lock-and-read optimization. A failed CAS aborts
//!    the transaction and releases every lock already acquired (the
//!    wasted-work pattern §2.2 highlights). All one-sided batches are
//!    planned through the shared [`crate::dm::OpBatch`] doorbell planner
//!    (the same one the LOTUS phases use).
//! 3. *Read data*: MVCC select (Motor) or single-version (FORD); the
//!    delta store charges an extra READ for non-latest versions.
//! 4. *Commit*: validate the read set (re-read version words), draw the
//!    commit timestamp, write records + CVT cells to primary and backups
//!    (UPS-backed DRAM assumption: no log, no separate visible step),
//!    release locks with async WRITEs.
//!
//! Style axes (see [`BaselineStyle`]) select Motor vs FORD vs the no-CAS
//! and idealized-lock variants.

use std::sync::Arc;

use crate::dm::clock::VClock;
use crate::dm::opbatch::{OpBatch, OpTag};
use crate::dm::verbs::Endpoint;
use crate::dm::NetConfig;
use crate::store::cvt::{CellSnapshot, CvtSnapshot, INVISIBLE};
use crate::store::{gc, record};
use crate::txn::api::{Isolation, RecordRef, TxnApi, TxnCtl};
use crate::txn::coordinator::SharedCluster;
use crate::txn::timestamp::phys_of;
use crate::{abort, AbortReason, Result};

/// Which baseline flavour the engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BaselineStyle {
    /// MVCC over the CVT cells (Motor) vs single-versioning (FORD).
    pub mvcc: bool,
    /// Issue RDMA CAS locks (false = the unsafe fig. 3 mode).
    pub use_cas: bool,
    /// Motor's delta store: reading a non-latest version costs an extra
    /// READ of the base record (reconstruction).
    pub delta_store: bool,
    /// FORD's bucket layout: values live beside versions in the hash
    /// bucket, so bucket/CVT reads carry full values (bandwidth-bound)
    /// and the data read piggybacks on the lock round.
    pub value_in_bucket: bool,
    /// Fig. 17 idealized lock: acquire/release are FAA-priced single ops
    /// (no retry loops, no queues) — still MN RNIC atomics.
    pub ideal_faa: bool,
    /// Display name.
    pub name: &'static str,
}

/// Per-record transaction state.
#[derive(Debug, Clone)]
struct Rec {
    r: RecordRef,
    write: bool,
    insert: bool,
    delete: bool,
    value: Option<Vec<u8>>,
    new_value: Option<Vec<u8>>,
    cvt: Option<CvtSnapshot>,
    bucket: u64,
    slot: u8,
    /// Version observed at execute (read-set validation).
    seen_version: u64,
}

impl Rec {
    fn new(r: RecordRef, write: bool) -> Self {
        Self {
            r,
            write,
            insert: false,
            delete: false,
            value: None,
            new_value: None,
            cvt: None,
            bucket: 0,
            slot: 0,
            seen_version: 0,
        }
    }
}

/// An MN-side lock word we hold.
#[derive(Debug, Clone, Copy)]
struct HeldWord {
    mn: usize,
    addr: u64,
}

/// The baseline coordinator.
pub struct BaselineCoordinator {
    /// Shared cluster state.
    pub cluster: Arc<SharedCluster>,
    /// This coordinator's CN.
    pub cn: usize,
    /// Virtual clock.
    pub clk: VClock,
    /// The flavour.
    pub style: BaselineStyle,
    ep: Endpoint,
    rng: crate::util::Xoshiro256,
    txn_id: u64,
    read_only: bool,
    start_ts: u64,
    records: Vec<Rec>,
    executed_upto: usize,
    held: Vec<HeldWord>,
}

impl BaselineCoordinator {
    /// Coordinator on CN `cn` with a globally unique id (seeds the RNG).
    pub fn new(
        cluster: Arc<SharedCluster>,
        cn: usize,
        global_id: usize,
        style: BaselineStyle,
    ) -> Self {
        let ep = Endpoint::new(cn, cluster.cn_nics[cn].clone(), cluster.net.clone());
        let seed = cluster.cfg.seed ^ (global_id as u64 + 1).wrapping_mul(0xD1B5_4A32_D192_ED03);
        Self {
            cluster,
            cn,
            clk: VClock::zero(),
            style,
            ep,
            rng: crate::util::Xoshiro256::new(seed),
            txn_id: 0,
            read_only: false,
            start_ts: 0,
            records: Vec::new(),
            executed_upto: 0,
            held: Vec::new(),
        }
    }

    #[inline]
    fn net(&self) -> &NetConfig {
        &self.cluster.net
    }

    /// MN-side lock word of a CVT slot.
    fn slot_lock_addr(&self, table: u16, bucket: u64, slot: u8) -> (usize, u64) {
        let t = self.cluster.table(table);
        let base = self.cluster.baseline_lock_bases[table as usize];
        (
            t.primary().mn,
            base + (bucket * t.spec.assoc as u64 + slot as u64) * 8,
        )
    }

    /// MN-side lock word of an index bucket (inserts).
    fn bucket_lock_addr(&self, table: u16, bucket: u64) -> (usize, u64) {
        let t = self.cluster.table(table);
        let base = self.cluster.baseline_lock_bases[table as usize];
        (
            t.primary().mn,
            base + (t.layout.n_buckets * t.spec.assoc as u64 + bucket) * 8,
        )
    }

    /// Release every held lock word (async WRITE 0 / FAA-priced for the
    /// idealized model; free in the no-CAS mode).
    fn release_locks(&mut self) {
        let held = std::mem::take(&mut self.held);
        if held.is_empty() {
            return;
        }
        let mut batch = OpBatch::new();
        for h in held {
            // Really clear the word so other coordinators can lock.
            let _ = self.cluster.mns[h.mn].store_u64(h.addr, 0);
            if !self.style.use_cas {
                continue;
            }
            if self.style.ideal_faa {
                batch.faa(h.mn, h.addr, 0);
            } else {
                batch.write(h.mn, h.addr, 0u64.to_le_bytes().to_vec());
            }
        }
        // Charge-only, fire-and-forget (the words were already cleared
        // above; FAA of 0 and rewriting 0 are idempotent).
        let _ = batch.issue_async(&self.ep, &self.cluster.mns, &mut self.clk);
    }

    fn fail(&mut self, reason: AbortReason) -> crate::Error {
        self.release_locks();
        abort(reason)
    }

    /// Resolve (bucket, slot, cvt) for records `[from..]`. Charges bucket
    /// READs (FORD's carry full values).
    fn resolve_phase(&mut self, from: usize) -> Result<()> {
        let addr_cache = self.cluster.addr_caches[self.cn].clone();
        for i in from..self.records.len() {
            let (r, is_insert) = {
                let rec = &self.records[i];
                (rec.r, rec.insert)
            };
            let table = self.cluster.tables[r.table as usize].clone();
            let bucket = table.bucket_of(r.key);
            self.clk.advance(self.net().cache_op_ns);
            let cached = if is_insert { None } else { addr_cache.get(r.key) };
            if let Some(addr) = cached {
                if let Ok((b, s)) = table.locate_cvt(addr) {
                    let rec = &mut self.records[i];
                    rec.bucket = b;
                    rec.slot = s;
                    continue; // CVT itself is read in the lock round.
                }
                addr_cache.invalidate(r.key);
            }
            // Bucket READs over the probe chain (one doorbell). FORD's
            // buckets embed full values, inflating every byte read.
            let extra = if self.style.value_in_bucket {
                table.spec.assoc as usize * table.spec.record_len as usize
            } else {
                0
            };
            let buckets: Vec<u64> = table.probe_buckets(r.key).collect();
            let mn_id = table.primary().mn;
            let mut batch = OpBatch::new();
            let tags: Vec<OpTag> = buckets
                .iter()
                .map(|&b| {
                    batch.read(
                        mn_id,
                        table.bucket_addr(0, b),
                        table.layout.bucket_size() as usize + extra,
                    )
                })
                .collect();
            let res = batch.issue(&self.ep, &self.cluster.mns, &mut self.clk)?;
            let bufs: Vec<&[u8]> = tags
                .iter()
                .map(|&t| &res.read_buf(t)[..table.layout.bucket_size() as usize])
                .collect();
            if is_insert {
                let mut placed = None;
                for (&b, buf) in buckets.iter().zip(&bufs) {
                    if table.find_in_bucket(buf, r.key).is_some() {
                        return Err(self.fail(AbortReason::Duplicate));
                    }
                    if placed.is_none() {
                        if let Some(slot) = table.find_empty_in_bucket(buf) {
                            placed = Some((b, slot));
                        }
                    }
                }
                let Some((b, slot)) = placed else {
                    self.release_locks();
                    return Err(crate::Error::OutOfMemory(format!(
                        "table {} probe chain of bucket {bucket} full",
                        table.spec.name
                    )));
                };
                let mut cvt = CvtSnapshot::empty(table.spec.ncells);
                cvt.key = r.key.0;
                cvt.occupied = true;
                cvt.table_id = table.spec.id;
                let rec = &mut self.records[i];
                rec.bucket = b;
                rec.slot = slot;
                rec.cvt = Some(cvt);
            } else {
                let mut found = None;
                for (&b, buf) in buckets.iter().zip(&bufs) {
                    if let Some((slot, cvt)) = table.find_in_bucket(buf, r.key) {
                        found = Some((b, slot, cvt));
                        break;
                    }
                }
                let Some((b, slot, cvt)) = found else {
                    return Err(self.fail(AbortReason::NotFound));
                };
                addr_cache.put(r.key, table.cvt_addr(0, b, slot));
                let rec = &mut self.records[i];
                rec.bucket = b;
                rec.slot = slot;
                rec.cvt = Some(cvt);
            }
        }
        Ok(())
    }

    /// Lock (CAS) + CVT READ in one doorbell per MN for `[from..]`.
    fn lock_read_phase(&mut self, from: usize) -> Result<()> {
        // Plan ops: per record, optional CAS word(s) + a CVT read (when
        // not already fetched by a bucket read this round).
        struct Planned {
            rec_idx: usize,
            mn: usize,
            cas_addrs: Vec<u64>,
            read_cvt: Option<u64>, // cvt addr
        }
        let mut plans: Vec<Planned> = Vec::new();
        for i in from..self.records.len() {
            let rec = &self.records[i];
            let table = self.cluster.table(rec.r.table);
            let mut cas_addrs = Vec::new();
            if rec.write && !self.read_only && self.style.use_cas {
                cas_addrs.push(self.slot_lock_addr(rec.r.table, rec.bucket, rec.slot).1);
                if rec.insert || rec.delete {
                    let chain: Vec<u64> = table
                        .probe_buckets(rec.r.key)
                        .map(|b| self.bucket_lock_addr(rec.r.table, b).1)
                        .collect();
                    cas_addrs.extend(chain);
                }
            }
            let read_cvt = if rec.cvt.is_some() && !rec.write {
                None // fresh from this round's bucket read
            } else if rec.insert {
                None
            } else {
                Some(table.cvt_addr(0, rec.bucket, rec.slot))
            };
            plans.push(Planned {
                rec_idx: i,
                mn: table.primary().mn,
                cas_addrs,
                read_cvt,
            });
        }
        // Plan one OpBatch per MN (CAS ops then the CVT READ, per record)
        // and issue each as a single doorbell.
        let mut by_mn: Vec<usize> = Vec::new();
        for p in &plans {
            if !by_mn.contains(&p.mn) {
                by_mn.push(p.mn);
            }
        }
        for mn_id in by_mn {
            let mut batch = OpBatch::new();
            // (plan idx, cas addr if atomic else None, tag)
            let mut op_map: Vec<(usize, Option<u64>, OpTag)> = Vec::new();
            for (pi, p) in plans.iter().enumerate() {
                if p.mn != mn_id {
                    continue;
                }
                for &a in &p.cas_addrs {
                    let tag = if self.style.ideal_faa {
                        // FAA-priced single-shot acquisition; the real
                        // mutual exclusion runs below.
                        batch.faa(mn_id, a, 0)
                    } else {
                        batch.cas(mn_id, a, 0, self.txn_id)
                    };
                    op_map.push((pi, Some(a), tag));
                }
                if let Some(addr) = p.read_cvt {
                    let table = self.cluster.table(self.records[p.rec_idx].r.table);
                    let extra = if self.style.value_in_bucket {
                        table.spec.record_len as usize
                    } else {
                        0
                    };
                    let tag = batch.read(mn_id, addr, table.layout.cvt_size() as usize + extra);
                    op_map.push((pi, None, tag));
                }
            }
            if batch.is_empty() {
                continue;
            }
            // For the idealized model the FAA op above is cost-only; take
            // the real lock word by CAS through the MN directly.
            if self.style.ideal_faa {
                for &(_pi, cas_addr, _tag) in &op_map {
                    let Some(addr) = cas_addr else { continue };
                    let got = self.cluster.mns[mn_id].cas_u64(addr, 0, self.txn_id)?;
                    if got != 0 {
                        // Conflict: charge the round, then abort.
                        let mut cost_only = OpBatch::new();
                        cost_only.faa(mn_id, addr, 0);
                        cost_only.issue(&self.ep, &self.cluster.mns, &mut self.clk)?;
                        return Err(self.fail(AbortReason::LockConflict));
                    }
                    self.held.push(HeldWord { mn: mn_id, addr });
                }
            }
            let res = batch.issue(&self.ep, &self.cluster.mns, &mut self.clk)?;
            // Harvest results in op order (CAS outcomes + CVT parses).
            for &(pi, cas_addr, tag) in &op_map {
                match cas_addr {
                    Some(addr) => {
                        if self.style.ideal_faa {
                            continue; // lock taken in the pre-pass above
                        }
                        if res.old(tag) != 0 {
                            return Err(self.fail(AbortReason::LockConflict));
                        }
                        self.held.push(HeldWord { mn: mn_id, addr });
                    }
                    None => {
                        let i = plans[pi].rec_idx;
                        let table = self.cluster.tables[self.records[i].r.table as usize].clone();
                        let cvt = CvtSnapshot::parse(
                            &res.read_buf(tag)[..table.layout.cvt_size() as usize],
                            &table.layout,
                        );
                        if cvt.is_empty() || cvt.key != self.records[i].r.key.0 {
                            // Stale cached address.
                            self.cluster.addr_caches[self.cn].invalidate(self.records[i].r.key);
                            return Err(self.fail(AbortReason::NotFound));
                        }
                        self.records[i].cvt = Some(cvt);
                    }
                }
            }
        }
        Ok(())
    }

    /// Version select + record reads for `[from..]`.
    fn read_data_phase(&mut self, from: usize) -> Result<()> {
        let mut reads: Vec<(usize, usize, u64, usize, u32, u8, bool)> = Vec::new();
        for i in from..self.records.len() {
            let (sel, table_id) = {
                let rec = &self.records[i];
                if rec.insert {
                    continue;
                }
                let cvt = rec.cvt.as_ref().expect("resolved");
                let sel = if self.style.mvcc {
                    let (best, newer) = cvt.select_version(self.start_ts);
                    if !self.read_only
                        && newer
                        && self.cluster.cfg.isolation == Isolation::Serializable
                    {
                        None // forces VersionTooNew below
                    } else {
                        best.copied().map(|c| (c, newer))
                    }
                } else {
                    // FORD single-versioning: cell 0 only; an in-flight
                    // write (INVISIBLE) blocks readers.
                    match cvt.cells.first() {
                        Some(c) if c.valid && c.version != INVISIBLE => Some((*c, false)),
                        _ => None,
                    }
                };
                (sel, rec.r.table)
            };
            let Some((cell, _newer)) = sel else {
                let reason = if self.style.mvcc {
                    AbortReason::VersionTooNew
                } else {
                    AbortReason::NoVisibleVersion
                };
                return Err(self.fail(reason));
            };
            let table = self.cluster.table(table_id);
            // Motor delta store: non-latest versions need the base too.
            let is_latest = self.records[i]
                .cvt
                .as_ref()
                .and_then(|c| c.latest())
                .map(|l| l.addr == cell.addr)
                .unwrap_or(true);
            let extra_read = self.style.delta_store && !is_latest;
            {
                let rec = &mut self.records[i];
                rec.seen_version = cell.version;
            }
            reads.push((
                i,
                table.primary().mn,
                cell.addr,
                cell.len as usize,
                table.spec.record_len,
                cell.cv,
                extra_read,
            ));
        }
        // FORD already carried values with the CVT reads — the data READ
        // is free (charge-wise); still execute it for real bytes.
        let mut by_mn: Vec<(usize, Vec<usize>)> = Vec::new();
        for (ri, rd) in reads.iter().enumerate() {
            match by_mn.iter_mut().find(|(mn, _)| *mn == rd.1) {
                Some((_, v)) => v.push(ri),
                None => by_mn.push((rd.1, vec![ri])),
            }
        }
        for (mn_id, idxs) in by_mn {
            let mn = self.cluster.mns[mn_id].clone();
            if !self.style.value_in_bucket {
                let mut batch = OpBatch::new();
                for &ri in &idxs {
                    let (_, _, addr, _, record_len, _, extra) = reads[ri];
                    batch.read(mn_id, addr, record::slot_size(record_len));
                    if extra {
                        // Delta reconstruction: base record read.
                        batch.read(mn_id, addr, record::slot_size(record_len));
                    }
                }
                batch.issue(&self.ep, &self.cluster.mns, &mut self.clk)?;
            }
            for &ri in &idxs {
                let (i, _, addr, payload_len, record_len, want_cv, _) = reads[ri];
                let mut buf = vec![0u8; record::slot_size(record_len)];
                mn.read_bytes(addr, &mut buf)?;
                match record::decode(&buf, payload_len, record_len) {
                    Some((cv, payload)) if cv == want_cv => {
                        self.records[i].value = Some(payload);
                    }
                    _ => return Err(self.fail(AbortReason::InconsistentRead)),
                }
            }
        }
        Ok(())
    }

    /// OCC read-set validation: re-read each read-only record's CVT and
    /// abort if any version newer than T_start appeared (the validation
    /// LOTUS's read locks make unnecessary). FORD runs this even for
    /// read-only transactions (single-versioning, paper §8.3).
    fn validate_read_set(&mut self) -> Result<()> {
        {
            let mut checks: Vec<(usize, usize, u64)> = Vec::new(); // (i, mn, cvt addr)
            for i in 0..self.records.len() {
                let rec = &self.records[i];
                if rec.write || rec.insert || rec.cvt.is_none() {
                    continue;
                }
                let table = self.cluster.table(rec.r.table);
                checks.push((
                    i,
                    table.primary().mn,
                    table.cvt_addr(0, rec.bucket, rec.slot),
                ));
            }
            let mut by_mn: Vec<(usize, Vec<usize>)> = Vec::new();
            for (ci, c) in checks.iter().enumerate() {
                match by_mn.iter_mut().find(|(mn, _)| *mn == c.1) {
                    Some((_, v)) => v.push(ci),
                    None => by_mn.push((c.1, vec![ci])),
                }
            }
            for (mn_id, idxs) in by_mn {
                let mut batch = OpBatch::new();
                let tags: Vec<OpTag> = idxs
                    .iter()
                    .map(|&ci| {
                        let table = self.cluster.table(self.records[checks[ci].0].r.table);
                        batch.read(mn_id, checks[ci].2, table.layout.cvt_size() as usize)
                    })
                    .collect();
                let res = batch.issue(&self.ep, &self.cluster.mns, &mut self.clk)?;
                for (&ci, &tag) in idxs.iter().zip(&tags) {
                    let i = checks[ci].0;
                    let table = self.cluster.tables[self.records[i].r.table as usize].clone();
                    let cvt = CvtSnapshot::parse(res.read_buf(tag), &table.layout);
                    let (best, newer) = cvt.select_version(self.start_ts);
                    let changed = best
                        .map(|c| c.version != self.records[i].seen_version)
                        .unwrap_or(true);
                    if newer || changed {
                        return Err(self.fail(AbortReason::VersionTooNew));
                    }
                }
            }
        }

        Ok(())
    }

    /// Commit a read-write transaction.
    fn commit_rw(&mut self) -> Result<()> {
        if self.cluster.doomed.take(self.txn_id) {
            return Err(self.fail(AbortReason::OwnerFailed));
        }
        if self.cluster.cfg.isolation == Isolation::Serializable {
            self.validate_read_set()?;
        }
        // --- Commit timestamp (UPS assumption: drawn before the write,
        //     data becomes visible in the data write itself). ---
        let ts_svc = self.net().ts_oracle_ns;
        let commit_ts = self
            .cluster
            .oracle
            .timestamp(&mut self.clk, ts_svc);
        let now_phys = phys_of(self.clk.now());
        let gc_thresh = self.cluster.cfg.gc_threshold_ns;

        // --- Write data + CVT cells to every replica. ---
        let mut writes: Vec<(usize, u64, Vec<u8>)> = Vec::new();
        for i in 0..self.records.len() {
            let rec = self.records[i].clone();
            if !rec.write {
                continue;
            }
            let table = self.cluster.tables[rec.r.table as usize].clone();
            let mut cvt = rec.cvt.clone().expect("resolved");
            if rec.delete {
                let cleared = CvtSnapshot::empty(table.spec.ncells);
                for (r, rep) in table.replicas.iter().enumerate() {
                    writes.push((
                        rep.mn,
                        table.cvt_addr(r, rec.bucket, rec.slot),
                        cleared.serialize(&table.layout),
                    ));
                }
                continue;
            }
            let Some(new_value) = rec.new_value.clone() else {
                continue;
            };
            let cell_idx = if self.style.mvcc {
                match gc::choose_victim(&cvt.cells, now_phys, gc_thresh) {
                    Some(c) => c as u8,
                    None => return Err(self.fail(AbortReason::LockConflict)),
                }
            } else {
                // FORD: single version updated in place — an undo log of
                // the old value must be persisted first (full record).
                let (log_mn, log_addr) = self.cluster.log_slots
                    [self.cn * self.cluster.cfg.coordinators_per_cn % self.cluster.log_slots.len()];
                let old_len = rec.value.as_ref().map(|v| v.len()).unwrap_or(8).max(8);
                writes.push((log_mn, log_addr, vec![0u8; old_len.min(64)]));
                0
            };
            let old_cv = cvt.cells[cell_idx as usize].cv;
            let new_cv = old_cv.wrapping_add(1);
            let rec_addr = table.record_addr(0, rec.bucket, rec.slot, cell_idx);
            cvt.cells[cell_idx as usize] = CellSnapshot {
                cv: new_cv,
                valid: true,
                len: new_value.len() as u16,
                version: commit_ts,
                addr: rec_addr,
                consistent: true,
            };
            cvt.record_len = new_value.len() as u16;
            if rec.insert {
                cvt.key = rec.r.key.0;
                cvt.occupied = true;
                cvt.table_id = table.spec.id;
            }
            let slot_img = record::encode(new_cv, &new_value, table.spec.record_len);
            let cvt_img = cvt.serialize(&table.layout);
            for (r, rep) in table.replicas.iter().enumerate() {
                writes.push((
                    rep.mn,
                    table.record_addr(r, rec.bucket, rec.slot, cell_idx),
                    slot_img.clone(),
                ));
                writes.push((rep.mn, table.cvt_addr(r, rec.bucket, rec.slot), cvt_img.clone()));
            }
        }
        let mut batch = OpBatch::new();
        for (mn, addr, data) in writes {
            batch.write(mn, addr, data);
        }
        batch.issue(&self.ep, &self.cluster.mns, &mut self.clk)?;

        // --- Unlock. ---
        self.release_locks();
        Ok(())
    }
}

impl TxnCtl for BaselineCoordinator {
    fn add_ro(&mut self, r: RecordRef) {
        self.records.push(Rec::new(r, false));
    }

    fn add_rw(&mut self, r: RecordRef) {
        self.records.push(Rec::new(r, true));
    }

    fn add_insert(&mut self, r: RecordRef, payload: Vec<u8>) {
        let mut rec = Rec::new(r, true);
        rec.insert = true;
        rec.new_value = Some(payload);
        self.records.push(rec);
    }

    fn add_delete(&mut self, r: RecordRef) {
        let mut rec = Rec::new(r, true);
        rec.delete = true;
        self.records.push(rec);
    }

    fn execute(&mut self) -> Result<()> {
        let from = self.executed_upto;
        self.resolve_phase(from)?;
        // Read-only transactions take no locks, but still fetch CVTs for
        // address-cached records in this round (the CAS ops are gated on
        // write intent inside).
        self.lock_read_phase(from)?;
        self.read_data_phase(from)?;
        self.executed_upto = self.records.len();
        Ok(())
    }

    fn value(&self, r: RecordRef) -> Option<&[u8]> {
        self.records
            .iter()
            .find(|rec| rec.r == r)
            .and_then(|rec| rec.value.as_deref())
    }

    fn stage_write(&mut self, r: RecordRef, payload: Vec<u8>) {
        let rec = self
            .records
            .iter_mut()
            .find(|rec| rec.r == r)
            .expect("stage_write on unknown record");
        rec.new_value = Some(payload);
    }

    fn commit(&mut self) -> Result<()> {
        self.clk.advance(self.net().txn_logic_ns);
        if self.read_only {
            // FORD's single-versioning: "even read-only transactions
            // require validation before commit" (paper §8.3).
            if !self.style.mvcc
                && self.cluster.cfg.isolation == Isolation::Serializable
            {
                self.validate_read_set()?;
            }
        } else {
            self.commit_rw()?;
        }
        Ok(())
    }

    fn rollback(&mut self) {
        self.release_locks();
    }
}

impl TxnApi for BaselineCoordinator {
    fn begin(&mut self, read_only: bool) {
        self.records.clear();
        self.held.clear();
        self.executed_upto = 0;
        self.read_only = read_only;
        self.txn_id = self.cluster.next_txn_id();
        let ts_svc = self.net().ts_oracle_ns;
        self.start_ts = self
            .cluster
            .oracle
            .timestamp(&mut self.clk, ts_svc);
    }

    fn txn(&mut self) -> &mut dyn TxnCtl {
        self
    }

    fn now(&self) -> u64 {
        self.clk.now()
    }

    fn rng(&mut self) -> &mut crate::util::Xoshiro256 {
        &mut self.rng
    }

    fn cn(&self) -> usize {
        self.cn
    }

    fn attach_gate(&mut self, gate: Arc<crate::dm::clock::TimeGate>, gid: usize) {
        self.ep.attach_gate(gate, gid);
    }

    fn crash(&mut self) {
        self.records.clear();
        self.held.clear();
        self.executed_upto = 0;
    }

    fn skip_to(&mut self, t_ns: u64) {
        self.clk.catch_up(t_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{ford, motor, nolock};
    use crate::sharding::key::LotusKey;
    use crate::config::Config;
    use crate::sim::Cluster;
    use crate::store::index::TableSpec;

    fn mini(style: BaselineStyle) -> (Arc<SharedCluster>, Vec<BaselineCoordinator>) {
        let mut cfg = Config::small();
        cfg.n_cns = 2;
        let specs = vec![TableSpec {
            id: 0,
            name: "t".into(),
            record_len: 40,
            ncells: 2,
            assoc: 4,
            expected_records: 2048,
        }];
        let cluster = Cluster::build_shared(&cfg, specs).unwrap();
        for uid in 0..64u64 {
            cluster.tables[0]
                .load_insert(
                    &cluster.mns,
                    LotusKey::compose(uid, uid),
                    format!("base-{uid}").as_bytes(),
                    1,
                )
                .unwrap();
        }
        let coords = (0..4)
            .map(|g| BaselineCoordinator::new(cluster.clone(), g / 2, g, style))
            .collect();
        (cluster, coords)
    }

    fn rr(uid: u64) -> RecordRef {
        RecordRef::new(0, LotusKey::compose(uid, uid))
    }

    fn smoke(style: BaselineStyle) {
        let (_c, mut coords) = mini(style);
        // Update.
        {
            let co = &mut coords[0];
            co.begin(false);
            co.txn().add_rw(rr(3));
            co.txn().execute().unwrap();
            assert_eq!(co.txn().value(rr(3)).unwrap(), b"base-3");
            co.txn().stage_write(rr(3), b"updated".to_vec());
            co.txn().commit().unwrap();
        }
        // Read back from another CN.
        let co = &mut coords[2];
        co.begin(true);
        co.txn().add_ro(rr(3));
        co.txn().execute().unwrap();
        assert_eq!(co.txn().value(rr(3)).unwrap(), b"updated");
        co.txn().commit().unwrap();
    }

    #[test]
    fn motor_update_roundtrip() {
        smoke(motor::style());
    }

    #[test]
    fn ford_update_roundtrip() {
        smoke(ford::style());
    }

    #[test]
    fn nocas_update_roundtrip() {
        smoke(nolock::motor_nocas_style());
    }

    #[test]
    fn ideal_lock_update_roundtrip() {
        smoke(crate::baselines::ideal_rdma_lock::style());
    }

    #[test]
    fn write_write_conflict_detected_via_mn_cas() {
        let (_c, mut coords) = mini(motor::style());
        let (a, rest) = coords.split_at_mut(2);
        let a = &mut a[0];
        let b = &mut rest[0];
        a.begin(false);
        a.txn().add_rw(rr(5));
        a.txn().execute().unwrap();
        b.begin(false);
        b.txn().add_rw(rr(5));
        let err = b.txn().execute().unwrap_err();
        assert_eq!(err.abort_reason(), Some(AbortReason::LockConflict));
        a.txn().rollback();
        // After release, b can lock.
        b.begin(false);
        b.txn().add_rw(rr(5));
        b.txn().execute().unwrap();
        b.txn().rollback();
    }

    #[test]
    fn nocas_ignores_conflicts_unsafely() {
        let (_c, mut coords) = mini(nolock::motor_nocas_style());
        let (a, rest) = coords.split_at_mut(2);
        let a = &mut a[0];
        let b = &mut rest[0];
        a.begin(false);
        a.txn().add_rw(rr(6));
        a.txn().execute().unwrap();
        b.begin(false);
        b.txn().add_rw(rr(6));
        b.txn().execute().unwrap(); // no lock, no conflict — unsafe mode
        a.txn().rollback();
        b.txn().rollback();
    }

    #[test]
    fn ford_read_blocked_by_inflight_write_version() {
        // Single-versioning: an INVISIBLE cell 0 blocks readers.
        let (c, mut coords) = mini(ford::style());
        let table = c.table(0);
        let key = LotusKey::compose(8, 8);
        let b = table.bucket_of(key);
        let mut buf = vec![0u8; table.layout.bucket_size() as usize];
        c.mns[table.primary().mn]
            .read_bytes(table.bucket_addr(0, b), &mut buf)
            .unwrap();
        let (slot, mut cvt) = table.find_in_bucket(&buf, key).unwrap();
        cvt.cells[0].version = INVISIBLE;
        c.mns[table.primary().mn]
            .write_bytes(table.cvt_addr(0, b, slot), &cvt.serialize(&table.layout))
            .unwrap();
        let co = &mut coords[0];
        co.begin(true);
        co.txn().add_ro(rr(8));
        let err = co.txn().execute().unwrap_err();
        assert_eq!(err.abort_reason(), Some(AbortReason::NoVisibleVersion));
    }

    #[test]
    fn read_validation_catches_concurrent_update() {
        let (_c, mut coords) = mini(motor::style());
        let (a, rest) = coords.split_at_mut(2);
        let a = &mut a[0];
        let b = &mut rest[0];
        // a reads key 9 (read set), b updates it, a commits a write on 10.
        a.begin(false);
        a.txn().add_ro(rr(9));
        a.txn().add_rw(rr(10));
        a.txn().execute().unwrap();
        b.begin(false);
        b.txn().add_rw(rr(9));
        b.txn().execute().unwrap();
        b.txn().stage_write(rr(9), b"changed".to_vec());
        b.txn().commit().unwrap();
        a.txn().stage_write(rr(10), b"mine".to_vec());
        let err = a.txn().commit().unwrap_err();
        assert_eq!(err.abort_reason(), Some(AbortReason::VersionTooNew));
    }

    #[test]
    fn cas_lock_costs_more_than_lotus_local_lock() {
        // The core premise: an MN CAS round trip dwarfs a CN-local CAS.
        let (_c, mut coords) = mini(motor::style());
        let co = &mut coords[0];
        let t0 = co.clk.now();
        co.begin(false);
        co.txn().add_rw(rr(11));
        co.txn().execute().unwrap();
        co.txn().rollback();
        let elapsed = co.clk.now() - t0;
        assert!(
            elapsed > co.cluster.net.rtt_ns,
            "MN lock must cost at least an RTT: {elapsed}"
        );
    }
}
