//! Interval metrics for the two-level load balancer (paper 4.3).
//!
//! Each CN measures its transaction execution latency and per-shard
//! request rates, "writing these metrics to a preallocated region in the
//! memory pool every fixed interval (e.g., 100 ms)". The collector here
//! is that region's in-memory face: lock-free per-(CN, shard) request
//! counters plus a per-CN 3-interval latency ring matching the paper's
//! 3-consecutive-interval overload rule.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::sharding::key::N_SHARDS;

/// Number of latency intervals retained (the paper's 3 x 100 ms rule).
pub const N_INTERVALS: usize = 3;

struct CnLatency {
    /// Sum of latencies this interval (ns).
    sum: u64,
    /// Samples this interval.
    n: u64,
    /// Ring of the last [`N_INTERVALS`] interval averages, oldest first.
    ring: [f64; N_INTERVALS],
    /// Completed intervals so far.
    sealed: u64,
}

/// Cluster-wide balance metrics.
pub struct BalanceMetrics {
    n_cns: usize,
    /// Request counts, `[cn * N_SHARDS + shard]`, drained per interval.
    counts: Vec<AtomicU64>,
    latency: Vec<Mutex<CnLatency>>,
}

impl BalanceMetrics {
    /// Metrics for `n_cns` compute nodes.
    pub fn new(n_cns: usize) -> Self {
        Self {
            n_cns,
            counts: (0..n_cns * N_SHARDS).map(|_| AtomicU64::new(0)).collect(),
            latency: (0..n_cns)
                .map(|_| {
                    Mutex::new(CnLatency {
                        sum: 0,
                        n: 0,
                        ring: [0.0; N_INTERVALS],
                        sealed: 0,
                    })
                })
                .collect(),
        }
    }

    /// Number of CNs.
    pub fn n_cns(&self) -> usize {
        self.n_cns
    }

    /// Record one lock/transaction request against `(cn, shard)`.
    #[inline]
    pub fn record_request(&self, cn: usize, shard: u16) {
        self.counts[cn * N_SHARDS + shard as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a committed transaction's latency on `cn`.
    pub fn record_latency(&self, cn: usize, latency_ns: u64) {
        let mut l = self.latency[cn].lock().unwrap();
        l.sum += latency_ns;
        l.n += 1;
    }

    /// Seal the current interval on `cn`: pushes the interval average into
    /// the ring (an idle interval repeats the previous average, so a CN
    /// that stops receiving work does not look overloaded).
    pub fn seal_interval(&self, cn: usize) {
        let mut l = self.latency[cn].lock().unwrap();
        let avg = if l.n > 0 {
            l.sum as f64 / l.n as f64
        } else {
            l.ring[N_INTERVALS - 1]
        };
        l.ring.rotate_left(1);
        l.ring[N_INTERVALS - 1] = avg;
        l.sum = 0;
        l.n = 0;
        l.sealed += 1;
    }

    /// Completed intervals on `cn`.
    pub fn sealed_intervals(&self, cn: usize) -> u64 {
        self.latency[cn].lock().unwrap().sealed
    }

    /// Drain the request-count matrix into `out` (f32 `[n_cns * N_SHARDS]`,
    /// row-major) resetting the counters; the planner's `counts` input.
    pub fn drain_counts(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.n_cns * N_SHARDS);
        for (o, c) in out.iter_mut().zip(self.counts.iter()) {
            *o = c.swap(0, Ordering::Relaxed) as f32;
        }
    }

    /// Copy the latency rings into `out` (f32 `[n_cns * N_INTERVALS]`,
    /// oldest..latest per CN); the planner's `latency3` input.
    pub fn latency_matrix(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.n_cns * N_INTERVALS);
        for cn in 0..self.n_cns {
            let l = self.latency[cn].lock().unwrap();
            for i in 0..N_INTERVALS {
                out[cn * N_INTERVALS + i] = l.ring[i] as f32;
            }
        }
    }

    /// Current interval-average latency of `cn` (latest sealed, ns).
    pub fn latest_latency(&self, cn: usize) -> f64 {
        self.latency[cn].lock().unwrap().ring[N_INTERVALS - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate_and_drain() {
        let m = BalanceMetrics::new(2);
        m.record_request(0, 5);
        m.record_request(0, 5);
        m.record_request(1, 7);
        let mut out = vec![0f32; 2 * N_SHARDS];
        m.drain_counts(&mut out);
        assert_eq!(out[5], 2.0);
        assert_eq!(out[N_SHARDS + 7], 1.0);
        // Drained: second read is zero.
        m.drain_counts(&mut out);
        assert_eq!(out[5], 0.0);
    }

    #[test]
    fn latency_ring_rotates() {
        let m = BalanceMetrics::new(1);
        for (interval, lat) in [(1u64, 100u64), (2, 200), (3, 300), (4, 400)] {
            m.record_latency(0, lat);
            m.seal_interval(0);
            assert_eq!(m.sealed_intervals(0), interval);
        }
        let mut out = vec![0f32; N_INTERVALS];
        m.latency_matrix(&mut out);
        assert_eq!(out, vec![200.0, 300.0, 400.0]);
        assert_eq!(m.latest_latency(0), 400.0);
    }

    #[test]
    fn idle_interval_repeats_last_average() {
        let m = BalanceMetrics::new(1);
        m.record_latency(0, 500);
        m.seal_interval(0);
        m.seal_interval(0); // no samples
        assert_eq!(m.latest_latency(0), 500.0);
    }

    #[test]
    fn interval_average_is_mean() {
        let m = BalanceMetrics::new(1);
        m.record_latency(0, 100);
        m.record_latency(0, 300);
        m.seal_interval(0);
        assert_eq!(m.latest_latency(0), 200.0);
    }
}
