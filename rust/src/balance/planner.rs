//! The rebalance planner (paper 4.3: pass-by-range resharding decision).
//!
//! Decision function (the L2 JAX model in `python/compile/model.py`):
//!
//! 1. EWMA heat: `heat = alpha * counts + (1 - alpha) * prev_heat`
//!    (the L1 Pallas kernel `kernels/heat.py`), plus per-CN load.
//! 2. Overload: a CN whose latency exceeded 1.5x the cluster average in
//!    **all three** retained intervals.
//! 3. Migration candidate: each CN's hottest shard (arg-max heat).
//! 4. Receiver: the CN with the lowest latest-interval latency.
//!
//! [`XlaPlanner`] executes the AOT artifact through PJRT (the production
//! path — the rust binary never re-derives the model); [`RustPlanner`] is
//! the bit-equivalent mirror used by tests and artifact-less library
//! consumers, and the integration suite cross-checks the two.

use crate::runtime::{InValue, LoadedExec, Manifest, XlaRuntime};
use crate::{Error, Result};

/// Overload threshold: >50% above cluster average (paper 4.3).
pub const OVERLOAD_THRESHOLD: f32 = 1.5;
/// Consecutive intervals required (paper: 3 x 100 ms).
pub const N_INTERVALS: usize = 3;
/// Default EWMA smoothing factor (matches `kernels/heat.py`).
pub const DEFAULT_ALPHA: f32 = 0.25;

/// One planning decision.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanOutput {
    /// Per-CN aggregate heat (diagnostics).
    pub load: Vec<f32>,
    /// Per-CN overload flag.
    pub overload: Vec<bool>,
    /// Per-CN hottest shard.
    pub hottest: Vec<u32>,
    /// Migration receiver (lowest-latency CN).
    pub target: usize,
}

impl PlanOutput {
    /// The shard moves this plan implies: `(shard, from, to)` for every
    /// overloaded CN other than the receiver itself.
    pub fn moves(&self) -> Vec<(u16, usize, usize)> {
        self.overload
            .iter()
            .enumerate()
            .filter(|&(cn, &over)| over && cn != self.target)
            .map(|(cn, _)| (self.hottest[cn] as u16, cn, self.target))
            .collect()
    }
}

/// A rebalance decision function over `[n_cns x n_shards]` matrices.
pub trait Planner {
    /// Plan one interval. `counts` is row-major `[n_cns * n_shards]`,
    /// `latency3` is row-major `[n_cns * 3]` (oldest..latest).
    fn plan(&mut self, counts: &[f32], latency3: &[f32]) -> Result<PlanOutput>;
    /// Topology.
    fn shape(&self) -> (usize, usize);
}

/// Pure-rust mirror of the L2 model (see module docs).
pub struct RustPlanner {
    n_cns: usize,
    n_shards: usize,
    alpha: f32,
    heat: Vec<f32>,
}

impl RustPlanner {
    /// Planner for a fixed topology.
    pub fn new(n_cns: usize, n_shards: usize) -> Self {
        Self {
            n_cns,
            n_shards,
            alpha: DEFAULT_ALPHA,
            heat: vec![0.0; n_cns * n_shards],
        }
    }

    /// Override the EWMA factor.
    pub fn with_alpha(mut self, alpha: f32) -> Self {
        self.alpha = alpha;
        self
    }
}

impl Planner for RustPlanner {
    fn plan(&mut self, counts: &[f32], latency3: &[f32]) -> Result<PlanOutput> {
        let (c, s) = (self.n_cns, self.n_shards);
        debug_assert_eq!(counts.len(), c * s);
        debug_assert_eq!(latency3.len(), c * N_INTERVALS);
        // 1. EWMA heat + load (mirror of kernels/heat.py).
        let mut load = vec![0.0f32; c];
        for cn in 0..c {
            let row = &mut self.heat[cn * s..(cn + 1) * s];
            let mut acc = 0.0f32;
            for (h, &x) in row.iter_mut().zip(&counts[cn * s..(cn + 1) * s]) {
                *h = self.alpha * x + (1.0 - self.alpha) * *h;
                acc += *h;
            }
            load[cn] = acc;
        }
        // 2. Overload rule (per-interval cluster averages).
        let mut avg = [0.0f32; N_INTERVALS];
        for i in 0..N_INTERVALS {
            avg[i] = (0..c).map(|cn| latency3[cn * N_INTERVALS + i]).sum::<f32>() / c as f32;
        }
        let overload: Vec<bool> = (0..c)
            .map(|cn| {
                (0..N_INTERVALS)
                    .all(|i| latency3[cn * N_INTERVALS + i] > OVERLOAD_THRESHOLD * avg[i])
            })
            .collect();
        // 3. Hottest shard per CN (first max, matching jnp.argmax).
        let hottest: Vec<u32> = (0..c)
            .map(|cn| {
                let row = &self.heat[cn * s..(cn + 1) * s];
                let mut best = 0usize;
                for (i, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = i;
                    }
                }
                best as u32
            })
            .collect();
        // 4. Receiver: lowest latest-interval latency (first min).
        let mut target = 0usize;
        for cn in 1..c {
            if latency3[cn * N_INTERVALS + N_INTERVALS - 1]
                < latency3[target * N_INTERVALS + N_INTERVALS - 1]
            {
                target = cn;
            }
        }
        Ok(PlanOutput {
            load,
            overload,
            hottest,
            target,
        })
    }

    fn shape(&self) -> (usize, usize) {
        (self.n_cns, self.n_shards)
    }
}

/// Production planner: executes `artifacts/rebalance.hlo.txt` via PJRT.
pub struct XlaPlanner {
    exe: LoadedExec,
    n_cns: usize,
    n_shards: usize,
    alpha: [f32; 1],
    heat: Vec<f32>,
}

impl XlaPlanner {
    /// Load the artifact named by `dir/manifest.json` and validate its
    /// compiled topology against `(n_cns, n_shards)`.
    pub fn load(dir: &std::path::Path, n_cns: usize, n_shards: usize) -> Result<Self> {
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        if manifest.n_cns != n_cns || manifest.n_shards != n_shards {
            return Err(Error::Runtime(format!(
                "artifact topology {}x{} != cluster {}x{}; re-run `make artifacts` \
                 with --cns {} --shards {}",
                manifest.n_cns, manifest.n_shards, n_cns, n_shards, n_cns, n_shards
            )));
        }
        let rt = XlaRuntime::cpu()?;
        let exe = rt.load_hlo_text(dir.join(&manifest.rebalance_file))?;
        Ok(Self {
            exe,
            n_cns,
            n_shards,
            alpha: [DEFAULT_ALPHA],
            heat: vec![0.0; n_cns * n_shards],
        })
    }
}

impl Planner for XlaPlanner {
    fn plan(&mut self, counts: &[f32], latency3: &[f32]) -> Result<PlanOutput> {
        let (c, s) = (self.n_cns as i64, self.n_shards as i64);
        let out = self.exe.run(&[
            InValue::F32(counts, &[c, s]),
            InValue::F32(&self.heat, &[c, s]),
            InValue::F32(latency3, &[c, N_INTERVALS as i64]),
            InValue::F32(&self.alpha, &[1]),
        ])?;
        if out.len() != 5 {
            return Err(Error::Runtime(format!(
                "rebalance artifact returned {} outputs, expected 5",
                out.len()
            )));
        }
        // Carry the heat state forward (the artifact is pure).
        self.heat.copy_from_slice(out[0].as_f32());
        Ok(PlanOutput {
            load: out[1].as_f32().to_vec(),
            overload: out[2].as_i32().iter().map(|&v| v != 0).collect(),
            hottest: out[3].as_i32().iter().map(|&v| v as u32).collect(),
            target: out[4].as_i32()[0] as usize,
        })
    }

    fn shape(&self) -> (usize, usize) {
        (self.n_cns, self.n_shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lat(rows: &[[f32; 3]]) -> Vec<f32> {
        rows.iter().flatten().copied().collect()
    }

    #[test]
    fn no_overload_when_balanced() {
        let mut p = RustPlanner::new(3, 8);
        let counts = vec![1.0; 24];
        let out = p
            .plan(&counts, &lat(&[[100.0; 3], [100.0; 3], [100.0; 3]]))
            .unwrap();
        assert!(out.overload.iter().all(|&o| !o));
        assert!(out.moves().is_empty());
    }

    #[test]
    fn sustained_high_latency_triggers_move_to_coldest() {
        let mut p = RustPlanner::new(3, 8);
        let mut counts = vec![0.0; 24];
        counts[5] = 100.0; // CN0's hottest shard is 5
        let lat3 = lat(&[[900.0; 3], [100.0; 3], [50.0; 3]]);
        let out = p.plan(&counts, &lat3).unwrap();
        assert!(out.overload[0]);
        assert!(!out.overload[1] && !out.overload[2]);
        assert_eq!(out.target, 2, "receiver must be the lowest-latency CN");
        assert_eq!(out.moves(), vec![(5u16, 0usize, 2usize)]);
    }

    #[test]
    fn single_hot_interval_does_not_trigger() {
        let mut p = RustPlanner::new(2, 4);
        // High latency only in the latest interval: rule needs all 3.
        let lat3 = lat(&[[100.0, 100.0, 900.0], [100.0; 3]]);
        let out = p.plan(&vec![1.0; 8], &lat3).unwrap();
        assert!(!out.overload[0]);
    }

    #[test]
    fn ewma_state_accumulates_across_plans() {
        let mut p = RustPlanner::new(1, 4).with_alpha(0.5);
        let lat3 = lat(&[[1.0; 3]]);
        p.plan(&[8.0, 0.0, 0.0, 0.0], &lat3).unwrap();
        let out = p.plan(&[0.0, 0.0, 0.0, 0.0], &lat3).unwrap();
        // heat[0] = 0.5*0 + 0.5*(0.5*8) = 2.0
        assert!((out.load[0] - 2.0).abs() < 1e-6);
        assert_eq!(out.hottest[0], 0);
    }

    #[test]
    fn receiver_never_moves_to_itself() {
        let p = RustPlanner::new(2, 4);
        // Both overloaded relative to... impossible; make CN1 the target
        // and CN1 overloaded — its move must be filtered out.
        let out = PlanOutput {
            load: vec![0.0, 0.0],
            overload: vec![true, true],
            hottest: vec![1, 2],
            target: 1,
        };
        assert_eq!(out.moves(), vec![(1u16, 0usize, 1usize)]);
        let _ = p; // silence
    }

    #[test]
    fn prop_rust_planner_matches_naive_overload_rule() {
        crate::testing::prop(30, |g| {
            let c = g.usize(1, 6);
            let s = g.usize(1, 32);
            let mut p = RustPlanner::new(c, s);
            let counts: Vec<f32> = (0..c * s).map(|_| g.u64(0, 100) as f32).collect();
            let lat3: Vec<f32> = (0..c * 3).map(|_| g.u64(1, 1000) as f32).collect();
            let out = p.plan(&counts, &lat3).unwrap();
            for cn in 0..c {
                let naive = (0..3).all(|i| {
                    let avg: f32 = (0..c).map(|x| lat3[x * 3 + i]).sum::<f32>() / c as f32;
                    lat3[cn * 3 + i] > 1.5 * avg
                });
                assert_eq!(out.overload[cn], naive, "cn={cn}");
                assert!((out.hottest[cn] as usize) < s);
            }
            assert!(out.target < c);
        });
    }

    #[test]
    fn xla_planner_matches_rust_planner() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let manifest = Manifest::load(dir.join("manifest.json")).unwrap();
        let (c, s) = (manifest.n_cns, manifest.n_shards);
        let mut xp = XlaPlanner::load(&dir, c, s).unwrap();
        let mut rp = RustPlanner::new(c, s);
        let mut rng = crate::util::Xoshiro256::new(7);
        for round in 0..3 {
            let counts: Vec<f32> = (0..c * s).map(|_| rng.below(50) as f32).collect();
            let lat3: Vec<f32> = (0..c * 3).map(|_| rng.below(900) as f32 + 100.0).collect();
            let a = xp.plan(&counts, &lat3).unwrap();
            let b = rp.plan(&counts, &lat3).unwrap();
            assert_eq!(a.overload, b.overload, "round {round}");
            assert_eq!(a.hottest, b.hottest, "round {round}");
            assert_eq!(a.target, b.target, "round {round}");
            for (x, y) in a.load.iter().zip(&b.load) {
                assert!((x - y).abs() < 1e-2 * (1.0 + y.abs()), "round {round}: {x} vs {y}");
            }
        }
    }
}
