//! Two-level load balancing (paper section 4.3).
//!
//! Level 1 is the hybrid transaction routing in [`crate::sharding::router`]
//! (read-only: uniform random CN; read-write: first record's shard owner).
//! Level 2 is **pass-by-range resharding**: every CN posts its latency and
//! per-shard request counts to a pre-allocated memory-pool region each
//! interval (100 ms); a CN whose latency stays >50% above the cluster
//! average for three consecutive intervals transfers its hottest shard to
//! the lowest-latency CN — only lock *ownership* moves, never the data.
//!
//! - [`metrics`] — interval collection of per-shard request counts + the
//!   3-interval latency ring.
//! - [`planner`] — the rebalance decision function. The production path
//!   executes the AOT-compiled XLA artifact (`artifacts/rebalance.hlo.txt`,
//!   the L2 JAX model whose EWMA scoring is the L1 Pallas kernel) through
//!   [`crate::runtime`]; a bit-equivalent rust mirror backs tests and
//!   artifact-less builds and is cross-checked against the artifact in the
//!   integration suite.

pub mod metrics;
pub mod planner;

pub use metrics::BalanceMetrics;
pub use planner::{PlanOutput, Planner, RustPlanner, XlaPlanner};
