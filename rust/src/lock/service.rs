//! The per-CN lock service: Algorithm 1 end-to-end.
//!
//! Combines the slot [`LockTable`], the holder [`LockState`], the CN's
//! [`VtCache`] (invalidated on remote write locks, Algorithm 1 line 15)
//! and the routing-layer ownership check (a request for a shard this CN
//! no longer owns returns [`crate::Error::WrongShardOwner`], prompting
//! the caller to retry with a fresh map — paper section 4.2).
//!
//! Resharding pauses a shard ([`LockService::pause_shard`]) so the sender
//! can drain or abort its holders before ownership moves (section 4.3).

use std::collections::HashSet;
use std::sync::{Arc, Mutex};

use crate::cache::VtCache;
use crate::lock::state::{HolderId, LockState};
use crate::lock::table::{AcquireOutcome, LockMode, LockTable};
use crate::sharding::key::LotusKey;
use crate::sharding::router::Router;
use crate::{Error, Result};

/// One lock request inside a (possibly batched) acquisition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LockRequest {
    /// Key to lock.
    pub key: LotusKey,
    /// Requested mode.
    pub mode: LockMode,
}

/// A successfully acquired lock (needed to release it later).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AcquiredLock {
    /// Locked key.
    pub key: LotusKey,
    /// Held mode.
    pub mode: LockMode,
    /// CN whose lock table holds the lock.
    pub owner_cn: usize,
}

/// The lock service running on one CN.
pub struct LockService {
    /// This CN's id.
    pub cn: usize,
    table: LockTable,
    state: LockState,
    vt_cache: Arc<VtCache>,
    /// Shards paused for migration (reject requests with WrongShardOwner).
    paused: Mutex<HashSet<u16>>,
}

impl LockService {
    /// Service with a lock table of `table_bytes` and this CN's VT cache.
    pub fn new(cn: usize, table_bytes: usize, vt_cache: Arc<VtCache>) -> Self {
        Self {
            cn,
            table: LockTable::with_capacity_bytes(table_bytes),
            state: LockState::new(),
            vt_cache,
            paused: Mutex::new(HashSet::new()),
        }
    }

    /// The raw slot table (diagnostics, memory accounting).
    pub fn table(&self) -> &LockTable {
        &self.table
    }

    /// The holder state (recovery + resharding scans).
    pub fn state(&self) -> &LockState {
        &self.state
    }

    /// Algorithm 1: try to acquire `mode` on `key` for `holder`.
    ///
    /// `from_remote` marks requests arriving by RPC from another CN; a
    /// remote *write* lock invalidates this CN's cached CVT for the key
    /// (line 15). Returns `Ok(true)` acquired (or already held — the
    /// idempotency check of line 5), `Ok(false)` on conflict, and `Err`
    /// for bucket-full or stale routing.
    pub fn try_acquire(
        &self,
        router: &Router,
        key: LotusKey,
        mode: LockMode,
        holder: HolderId,
        from_remote: bool,
    ) -> Result<bool> {
        router.assert_owner(self.cn, key.shard())?;
        if self.paused.lock().unwrap().contains(&key.shard()) {
            return Err(Error::WrongShardOwner {
                shard: key.shard(),
                cn: self.cn,
            });
        }
        // Line 5: the holder already has a satisfying lock.
        if self.state.already_holds(key, mode, holder) {
            return Ok(true);
        }
        match self.table.acquire(key, mode)? {
            AcquireOutcome::Conflict => Ok(false),
            AcquireOutcome::Acquired => {
                if from_remote && mode == LockMode::Write {
                    self.vt_cache.invalidate(key); // line 15
                }
                self.state.record(key, mode, holder); // line 21
                Ok(true)
            }
        }
    }

    /// Release a lock held by `holder`; idempotent (recovery may race a
    /// normal unlock).
    pub fn release(&self, key: LotusKey, mode: LockMode, holder: HolderId) {
        if self.state.erase(key, mode, holder) {
            self.table.release(key, mode);
        }
    }

    /// Release **all** locks held by CN `cn` (recovery, section 6);
    /// returns the released holders' transaction ids.
    pub fn release_all_of_cn(&self, cn: usize) -> Vec<u64> {
        let held = self.state.held_by_cn(cn);
        let mut txns: Vec<u64> = held.iter().map(|(_, _, h)| h.txn).collect();
        for (key, mode, holder) in held {
            self.release(key, mode, holder);
        }
        txns.sort_unstable();
        txns.dedup();
        txns
    }

    /// Pause a shard before migration (new requests bounce).
    pub fn pause_shard(&self, shard: u16) {
        self.paused.lock().unwrap().insert(shard);
    }

    /// Resume a shard (migration receiver side, or aborted migration).
    pub fn resume_shard(&self, shard: u16) {
        self.paused.lock().unwrap().remove(&shard);
    }

    /// Is the shard paused?
    pub fn is_paused(&self, shard: u16) -> bool {
        self.paused.lock().unwrap().contains(&shard)
    }

    /// Holders with live locks in `shard` (resharding abort scan).
    pub fn holders_in_shard(&self, shard: u16) -> Vec<HolderId> {
        self.state.holders_in_shard(shard)
    }

    /// Force-release every lock in `shard` (resharding timeout path);
    /// returns the affected transaction ids.
    pub fn force_release_shard(&self, shard: u16) -> Vec<u64> {
        let mut txns = Vec::new();
        for (key, mode, holder) in self
            .state
            .held_by_cn_filter(|k| k.shard() == shard)
        {
            txns.push(holder.txn);
            self.release(key, mode, holder);
        }
        txns.sort_unstable();
        txns.dedup();
        txns
    }

    /// Wipe the table + state (restarted CN begins empty — the
    /// lock-rebuild-free path, section 6).
    pub fn clear(&self) {
        self.table.clear();
        self.state.clear();
        self.paused.lock().unwrap().clear();
    }

    /// Count of live lock slots (diagnostics).
    pub fn held_slots(&self) -> usize {
        self.table.held_slots()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n_cns: usize) -> (Router, Vec<LockService>) {
        let router = Router::new(n_cns);
        let services = (0..n_cns)
            .map(|cn| LockService::new(cn, 64 * 1024, Arc::new(VtCache::new(64))))
            .collect();
        (router, services)
    }

    fn holder(cn: usize, txn: u64) -> HolderId {
        HolderId { cn, txn }
    }

    #[test]
    fn local_acquire_release_cycle() {
        let (router, svcs) = setup(1);
        let k = LotusKey::compose(1, 1);
        let h = holder(0, 1);
        assert!(svcs[0].try_acquire(&router, k, LockMode::Write, h, false).unwrap());
        // Idempotent re-acquire by the same txn.
        assert!(svcs[0].try_acquire(&router, k, LockMode::Write, h, false).unwrap());
        // Conflicting holder.
        assert!(!svcs[0]
            .try_acquire(&router, k, LockMode::Write, holder(0, 2), false)
            .unwrap());
        svcs[0].release(k, LockMode::Write, h);
        assert!(svcs[0]
            .try_acquire(&router, k, LockMode::Write, holder(0, 2), false)
            .unwrap());
    }

    #[test]
    fn wrong_owner_rejected() {
        let (router, svcs) = setup(2);
        // Find a shard owned by CN 1.
        let shard = (0..4096u16).find(|&s| router.owner_of(s) == 1).unwrap();
        let k = LotusKey::compose(shard as u64, 9);
        let err = svcs[0]
            .try_acquire(&router, k, LockMode::Write, holder(0, 1), false)
            .unwrap_err();
        assert!(matches!(err, Error::WrongShardOwner { .. }));
        assert!(svcs[1].try_acquire(&router, k, LockMode::Write, holder(0, 1), true).unwrap());
    }

    #[test]
    fn remote_write_lock_invalidates_vt_cache() {
        let cache = Arc::new(VtCache::new(64));
        let svc = LockService::new(0, 64 * 1024, cache.clone());
        let router = Router::new(1);
        let k = LotusKey::compose(3, 3);
        cache.put(
            k,
            crate::cache::vtcache::CachedCvt {
                cvt: crate::store::cvt::CvtSnapshot::empty(1),
                addr: 0x10,
            },
        );
        // Local write lock does NOT invalidate (local writer updates it).
        assert!(svc.try_acquire(&router, k, LockMode::Write, holder(0, 1), false).unwrap());
        assert!(cache.get(k).is_some());
        svc.release(k, LockMode::Write, holder(0, 1));
        // Remote write lock DOES invalidate.
        assert!(svc.try_acquire(&router, k, LockMode::Write, holder(1, 2), true).unwrap());
        assert!(cache.get(k).is_none());
    }

    #[test]
    fn paused_shard_bounces() {
        let (router, svcs) = setup(1);
        let k = LotusKey::compose(5, 5);
        svcs[0].pause_shard(k.shard());
        let err = svcs[0]
            .try_acquire(&router, k, LockMode::Read, holder(0, 1), false)
            .unwrap_err();
        assert!(matches!(err, Error::WrongShardOwner { .. }));
        svcs[0].resume_shard(k.shard());
        assert!(svcs[0].try_acquire(&router, k, LockMode::Read, holder(0, 1), false).unwrap());
    }

    #[test]
    fn release_all_of_cn_frees_everything() {
        let (router, svcs) = setup(1);
        for i in 0..20 {
            let k = LotusKey::compose(i, i);
            let h = holder((i % 2) as usize, i);
            svcs[0].try_acquire(&router, k, LockMode::Write, h, false).unwrap();
        }
        assert_eq!(svcs[0].held_slots(), 20);
        let txns = svcs[0].release_all_of_cn(1);
        assert_eq!(txns.len(), 10);
        assert_eq!(svcs[0].held_slots(), 10);
        svcs[0].release_all_of_cn(0);
        assert_eq!(svcs[0].held_slots(), 0);
    }

    #[test]
    fn force_release_shard_returns_txns() {
        let (router, svcs) = setup(1);
        let k1 = LotusKey::compose(7, 1);
        let k2 = LotusKey::compose(7, 2);
        let k3 = LotusKey::compose(8, 3);
        svcs[0].try_acquire(&router, k1, LockMode::Write, holder(0, 11), false).unwrap();
        svcs[0].try_acquire(&router, k2, LockMode::Read, holder(0, 12), false).unwrap();
        svcs[0].try_acquire(&router, k3, LockMode::Write, holder(0, 13), false).unwrap();
        let txns = svcs[0].force_release_shard(7);
        assert_eq!(txns, vec![11, 12]);
        assert_eq!(svcs[0].held_slots(), 1); // k3 survives
    }

    #[test]
    fn clear_resets_everything() {
        let (router, svcs) = setup(1);
        let k = LotusKey::compose(1, 1);
        svcs[0].try_acquire(&router, k, LockMode::Write, holder(0, 1), false).unwrap();
        svcs[0].pause_shard(2);
        svcs[0].clear();
        assert_eq!(svcs[0].held_slots(), 0);
        assert!(!svcs[0].is_paused(2));
        assert!(svcs[0].try_acquire(&router, k, LockMode::Write, holder(0, 9), false).unwrap());
    }
}
