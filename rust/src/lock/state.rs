//! Lock state: who holds what (paper 4.1).
//!
//! The lock table's slots encode only *that* a lock is held; the lock
//! state records *who* holds it — (CN id, transaction id, mode) per key —
//! and is used for:
//!
//! 1. **idempotency**: re-acquisition by the same transaction succeeds
//!    without touching the slot (Algorithm 1 line 5);
//! 2. **recovery**: surviving CNs scan their lock states and release all
//!    locks held by a failed CN (section 6);
//! 3. **resharding**: the shard sender proactively aborts transactions
//!    still holding locks in a migrating shard (section 4.3).
//!
//! Sharded mutexed maps keep contention negligible next to the slot CAS.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::lock::table::LockMode;
use crate::sharding::key::LotusKey;

const STATE_SHARDS: usize = 64;

/// A lock holder: which coordinator of which CN, running which txn.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HolderId {
    /// Holder's CN.
    pub cn: usize,
    /// Transaction id (globally unique).
    pub txn: u64,
}

#[derive(Debug, Default)]
struct KeyHolders {
    /// Write holder, if any.
    writer: Option<HolderId>,
    /// Read holders.
    readers: Vec<HolderId>,
}

/// Per-CN lock state map.
pub struct LockState {
    shards: Vec<Mutex<HashMap<u64, KeyHolders>>>,
}

impl Default for LockState {
    fn default() -> Self {
        Self::new()
    }
}

impl LockState {
    /// Empty state.
    pub fn new() -> Self {
        Self {
            shards: (0..STATE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, key: LotusKey) -> &Mutex<HashMap<u64, KeyHolders>> {
        &self.shards[(key.fingerprint32() as usize) % STATE_SHARDS]
    }

    /// Does `holder` already hold `key` in a mode satisfying `mode`?
    /// (A writer satisfies a read request; a reader does not satisfy a
    /// write request.)
    pub fn already_holds(&self, key: LotusKey, mode: LockMode, holder: HolderId) -> bool {
        let map = self.shard(key).lock().unwrap();
        let Some(h) = map.get(&key.0) else {
            return false;
        };
        match mode {
            LockMode::Read => h.writer == Some(holder) || h.readers.contains(&holder),
            LockMode::Write => h.writer == Some(holder),
        }
    }

    /// Record an acquisition.
    pub fn record(&self, key: LotusKey, mode: LockMode, holder: HolderId) {
        let mut map = self.shard(key).lock().unwrap();
        let h = map.entry(key.0).or_default();
        match mode {
            LockMode::Write => h.writer = Some(holder),
            LockMode::Read => h.readers.push(holder),
        }
    }

    /// Erase a holder's entry for `key`; returns true if it was present.
    pub fn erase(&self, key: LotusKey, mode: LockMode, holder: HolderId) -> bool {
        let mut map = self.shard(key).lock().unwrap();
        let Some(h) = map.get_mut(&key.0) else {
            return false;
        };
        let present = match mode {
            LockMode::Write => {
                if h.writer == Some(holder) {
                    h.writer = None;
                    true
                } else {
                    false
                }
            }
            LockMode::Read => {
                if let Some(pos) = h.readers.iter().position(|&r| r == holder) {
                    h.readers.swap_remove(pos);
                    true
                } else {
                    false
                }
            }
        };
        if h.writer.is_none() && h.readers.is_empty() {
            map.remove(&key.0);
        }
        present
    }

    /// All (key, mode, holder) entries held by CN `cn` — the recovery scan.
    pub fn held_by_cn(&self, cn: usize) -> Vec<(LotusKey, LockMode, HolderId)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let map = shard.lock().unwrap();
            for (k, h) in map.iter() {
                if let Some(w) = h.writer {
                    if w.cn == cn {
                        out.push((LotusKey(*k), LockMode::Write, w));
                    }
                }
                for &r in &h.readers {
                    if r.cn == cn {
                        out.push((LotusKey(*k), LockMode::Read, r));
                    }
                }
            }
        }
        out
    }

    /// All (key, mode, holder) entries whose key satisfies `pred`
    /// (resharding's force-release scan).
    pub fn held_by_cn_filter<F: Fn(LotusKey) -> bool>(
        &self,
        pred: F,
    ) -> Vec<(LotusKey, LockMode, HolderId)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let map = shard.lock().unwrap();
            for (k, h) in map.iter() {
                let key = LotusKey(*k);
                if !pred(key) {
                    continue;
                }
                if let Some(w) = h.writer {
                    out.push((key, LockMode::Write, w));
                }
                for &r in &h.readers {
                    out.push((key, LockMode::Read, r));
                }
            }
        }
        out
    }

    /// All holders with locks in `shard_id` — resharding's abort scan.
    pub fn holders_in_shard(&self, shard_id: u16) -> Vec<HolderId> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let map = shard.lock().unwrap();
            for (k, h) in map.iter() {
                if LotusKey(*k).shard() == shard_id {
                    if let Some(w) = h.writer {
                        out.push(w);
                    }
                    out.extend(h.readers.iter().copied());
                }
            }
        }
        out.sort_unstable_by_key(|h| (h.cn, h.txn));
        out.dedup();
        out
    }

    /// Total tracked keys (diagnostics / memory accounting).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Is the state empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop everything (restarted CN starts empty).
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(i: u64) -> LotusKey {
        LotusKey::compose(i, i)
    }

    const H1: HolderId = HolderId { cn: 0, txn: 100 };
    const H2: HolderId = HolderId { cn: 1, txn: 200 };

    #[test]
    fn record_and_query() {
        let s = LockState::new();
        assert!(!s.already_holds(k(1), LockMode::Write, H1));
        s.record(k(1), LockMode::Write, H1);
        assert!(s.already_holds(k(1), LockMode::Write, H1));
        // Writer satisfies read re-acquisition.
        assert!(s.already_holds(k(1), LockMode::Read, H1));
        // A different holder does not.
        assert!(!s.already_holds(k(1), LockMode::Write, H2));
    }

    #[test]
    fn reader_does_not_satisfy_write() {
        let s = LockState::new();
        s.record(k(2), LockMode::Read, H1);
        assert!(s.already_holds(k(2), LockMode::Read, H1));
        assert!(!s.already_holds(k(2), LockMode::Write, H1));
    }

    #[test]
    fn erase_removes_and_cleans_up() {
        let s = LockState::new();
        s.record(k(3), LockMode::Read, H1);
        s.record(k(3), LockMode::Read, H2);
        assert!(s.erase(k(3), LockMode::Read, H1));
        assert!(!s.erase(k(3), LockMode::Read, H1), "double erase");
        assert!(s.already_holds(k(3), LockMode::Read, H2));
        assert!(s.erase(k(3), LockMode::Read, H2));
        assert_eq!(s.len(), 0, "empty entries must be dropped");
    }

    #[test]
    fn held_by_cn_scans_across_shards() {
        let s = LockState::new();
        for i in 0..50 {
            let holder = if i % 2 == 0 { H1 } else { H2 };
            let mode = if i % 3 == 0 { LockMode::Write } else { LockMode::Read };
            s.record(k(i), mode, holder);
        }
        let cn0 = s.held_by_cn(0);
        let cn1 = s.held_by_cn(1);
        assert_eq!(cn0.len(), 25);
        assert_eq!(cn1.len(), 25);
        assert!(cn0.iter().all(|(_, _, h)| h.cn == 0));
    }

    #[test]
    fn holders_in_shard_finds_only_that_shard() {
        let s = LockState::new();
        // shard = critical_field & 0xFFF
        s.record(LotusKey::compose(5, 1), LockMode::Write, H1);
        s.record(LotusKey::compose(5, 2), LockMode::Read, H2);
        s.record(LotusKey::compose(9, 3), LockMode::Write, H2);
        let holders = s.holders_in_shard(5);
        assert_eq!(holders.len(), 2);
        assert_eq!(s.holders_in_shard(9), vec![H2]);
        assert!(s.holders_in_shard(100).is_empty());
    }

    #[test]
    fn prop_record_erase_balanced() {
        crate::testing::prop(30, |g| {
            let s = LockState::new();
            let mut live: Vec<(LotusKey, LockMode, HolderId)> = Vec::new();
            for _ in 0..g.usize(1, 100) {
                if g.bool(0.6) || live.is_empty() {
                    let key = k(g.u64(0, 20));
                    let mode = if g.bool(0.5) { LockMode::Read } else { LockMode::Write };
                    let h = HolderId {
                        cn: g.usize(0, 3),
                        txn: g.u64(0, 1000),
                    };
                    // The state holds at most one writer per key (the slot
                    // table guarantees exclusivity); mirror that here.
                    if mode == LockMode::Write
                        && live.iter().any(|&(lk, lm, _)| lk == key && lm == LockMode::Write)
                    {
                        continue;
                    }
                    s.record(key, mode, h);
                    live.push((key, mode, h));
                } else {
                    let i = g.usize(0, live.len() - 1);
                    let (key, mode, h) = live.swap_remove(i);
                    assert!(s.erase(key, mode, h), "recorded lock must erase");
                }
            }
            for (key, mode, h) in live.drain(..) {
                s.erase(key, mode, h);
            }
            assert_eq!(s.len(), 0);
        });
    }
}
