//! The fixed-length lock hash table (paper fig. 6 + Algorithm 1).
//!
//! Each slot is 8 bytes: a 7-byte (56-bit) key fingerprint and a 1-byte
//! counter. Counter encoding (paper 4.1):
//!
//! - `0`   — free (the whole slot is zero; unlock clears freed slots so a
//!           write-lock CAS can always compare against 0);
//! - `1`   — write-locked;
//! - even `>= 2` — read-locked by counter/2 readers.
//!
//! Every 8 slots form a *lock bucket*; a key hashes to exactly one bucket
//! (no probing — if the bucket is full the acquisition fails and the
//! transaction aborts, a deliberate paper design point). Two keys with
//! equal bucket + fingerprint alias to the same lock; with 56-bit
//! fingerprints this is vanishingly rare and merely over-serializes.
//!
//! All mutation is CAS on the slot word, exactly the instruction the
//! paper uses on CN CPUs after disaggregating locks away from MN RNICs.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::sharding::key::LotusKey;
use crate::{Error, Result};

/// Slots per bucket (paper: "every 8 slots form a lock bucket").
pub const SLOTS_PER_BUCKET: usize = 8;
/// Max readers per slot: counter is 1 byte, even values => 127 readers.
pub const MAX_READERS: u8 = 126; // counter 252; +2 would overflow at 254

const COUNTER_MASK: u64 = 0xFF;
const WRITE_LOCKED: u64 = 1;

/// Lock mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LockMode {
    /// Shared (read) lock.
    Read,
    /// Exclusive (write) lock.
    Write,
}

#[inline]
fn pack(fp56: u64, counter: u64) -> u64 {
    (fp56 << 8) | counter
}

#[inline]
fn slot_fp(slot: u64) -> u64 {
    slot >> 8
}

#[inline]
fn slot_counter(slot: u64) -> u64 {
    slot & COUNTER_MASK
}

/// A CN's lock table.
pub struct LockTable {
    slots: Vec<AtomicU64>,
    n_buckets: u32,
}

/// Outcome of a lock attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AcquireOutcome {
    /// Lock acquired.
    Acquired,
    /// Conflicting lock held (read-write / write-write / reader overflow).
    Conflict,
}

impl LockTable {
    /// Table with `n_buckets` buckets (8 slots each, 8B per slot).
    /// A 32 MB table (paper default) is `n_buckets = 512 * 1024`.
    pub fn new(n_buckets: u32) -> Self {
        assert!(n_buckets > 0);
        Self {
            slots: (0..n_buckets as usize * SLOTS_PER_BUCKET)
                .map(|_| AtomicU64::new(0))
                .collect(),
            n_buckets,
        }
    }

    /// Table sized to approximately `bytes` of slot memory.
    pub fn with_capacity_bytes(bytes: usize) -> Self {
        let buckets = (bytes / (SLOTS_PER_BUCKET * 8)).max(1);
        Self::new(buckets as u32)
    }

    /// Slot memory footprint in bytes.
    pub fn mem_bytes(&self) -> usize {
        self.slots.len() * 8
    }

    /// Number of buckets.
    pub fn n_buckets(&self) -> u32 {
        self.n_buckets
    }

    #[inline]
    fn bucket_range(&self, key: LotusKey) -> std::ops::Range<usize> {
        let b = key.lock_bucket(self.n_buckets) as usize;
        let start = b * SLOTS_PER_BUCKET;
        start..start + SLOTS_PER_BUCKET
    }

    /// Algorithm 1 core: try to acquire `mode` on `key`. Returns
    /// `Conflict` for lock conflicts, `Err(LockBucketFull)` when the
    /// bucket has no slot for this fingerprint.
    pub fn acquire(&self, key: LotusKey, mode: LockMode) -> Result<AcquireOutcome> {
        let fp = key.fingerprint56();
        let range = self.bucket_range(key);
        'retry: loop {
            // FINDMATCH: first matching-fingerprint slot, else first empty.
            let mut empty: Option<usize> = None;
            let mut matched: Option<(usize, u64)> = None;
            for i in range.clone() {
                let v = self.slots[i].load(Ordering::Acquire);
                if v == 0 {
                    if empty.is_none() {
                        empty = Some(i);
                    }
                } else if slot_fp(v) == fp {
                    matched = Some((i, v));
                    break;
                }
            }
            let (idx, cur) = match (matched, empty) {
                (Some(m), _) => m,
                (None, Some(e)) => (e, 0),
                (None, None) => return Err(Error::LockBucketFull),
            };
            let counter = slot_counter(cur);
            let new = match mode {
                LockMode::Write => {
                    if cur != 0 {
                        // Any existing holder conflicts with a writer.
                        return Ok(AcquireOutcome::Conflict);
                    }
                    pack(fp, WRITE_LOCKED)
                }
                LockMode::Read => {
                    if counter == WRITE_LOCKED {
                        return Ok(AcquireOutcome::Conflict);
                    }
                    if counter >= (MAX_READERS as u64) * 2 {
                        // Counter would overflow — treated as a conflict.
                        return Ok(AcquireOutcome::Conflict);
                    }
                    pack(fp, counter + 2)
                }
            };
            match self.slots[idx].compare_exchange(cur, new, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return Ok(AcquireOutcome::Acquired),
                // Slot changed under us (another coordinator on this CN or
                // an RPC-handled remote request): recompute — the state may
                // still be compatible (e.g. another reader arrived).
                Err(_) => continue 'retry,
            }
        }
    }

    /// Release a lock previously acquired with `mode`. Clears the slot
    /// when the last holder leaves so future write CAS can compare 0.
    pub fn release(&self, key: LotusKey, mode: LockMode) {
        let fp = key.fingerprint56();
        let range = self.bucket_range(key);
        loop {
            let mut found: Option<(usize, u64)> = None;
            for i in range.clone() {
                let v = self.slots[i].load(Ordering::Acquire);
                if v != 0 && slot_fp(v) == fp {
                    found = Some((i, v));
                    break;
                }
            }
            let Some((idx, cur)) = found else {
                // Already released (idempotent unlock during recovery).
                return;
            };
            let counter = slot_counter(cur);
            let new = match mode {
                LockMode::Write => 0,
                LockMode::Read => {
                    let c = counter.saturating_sub(2);
                    if c == 0 {
                        0
                    } else {
                        pack(fp, c)
                    }
                }
            };
            if self.slots[idx]
                .compare_exchange(cur, new, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return;
            }
        }
    }

    /// Inspect a key's lock: `None` if unlocked, else the raw counter.
    pub fn peek(&self, key: LotusKey) -> Option<u64> {
        let fp = key.fingerprint56();
        for i in self.bucket_range(key) {
            let v = self.slots[i].load(Ordering::Acquire);
            if v != 0 && slot_fp(v) == fp {
                return Some(slot_counter(v));
            }
        }
        None
    }

    /// Clear the entire table (used when a restarted CN starts empty —
    /// the lock-rebuild-free recovery path).
    pub fn clear(&self) {
        for s in &self.slots {
            s.store(0, Ordering::Release);
        }
    }

    /// Count of currently held slots (diagnostics).
    pub fn held_slots(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.load(Ordering::Relaxed) != 0)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn key(i: u64) -> LotusKey {
        LotusKey::compose(i, i)
    }

    #[test]
    fn write_lock_excludes_everyone() {
        let t = LockTable::new(64);
        assert_eq!(t.acquire(key(1), LockMode::Write).unwrap(), AcquireOutcome::Acquired);
        assert_eq!(t.acquire(key(1), LockMode::Write).unwrap(), AcquireOutcome::Conflict);
        assert_eq!(t.acquire(key(1), LockMode::Read).unwrap(), AcquireOutcome::Conflict);
        t.release(key(1), LockMode::Write);
        assert_eq!(t.acquire(key(1), LockMode::Read).unwrap(), AcquireOutcome::Acquired);
    }

    #[test]
    fn read_locks_share() {
        let t = LockTable::new(64);
        for _ in 0..10 {
            assert_eq!(t.acquire(key(2), LockMode::Read).unwrap(), AcquireOutcome::Acquired);
        }
        assert_eq!(t.peek(key(2)), Some(20)); // 10 readers * 2
        // Writer blocked while readers hold.
        assert_eq!(t.acquire(key(2), LockMode::Write).unwrap(), AcquireOutcome::Conflict);
        for _ in 0..10 {
            t.release(key(2), LockMode::Read);
        }
        assert_eq!(t.peek(key(2)), None);
        assert_eq!(t.acquire(key(2), LockMode::Write).unwrap(), AcquireOutcome::Acquired);
    }

    #[test]
    fn reader_overflow_is_conflict() {
        let t = LockTable::new(64);
        for _ in 0..MAX_READERS {
            assert_eq!(t.acquire(key(3), LockMode::Read).unwrap(), AcquireOutcome::Acquired);
        }
        assert_eq!(t.acquire(key(3), LockMode::Read).unwrap(), AcquireOutcome::Conflict);
    }

    #[test]
    fn bucket_full_fails() {
        let t = LockTable::new(1); // single bucket, 8 slots
        let mut locked = 0;
        let mut full = false;
        for i in 0..100 {
            match t.acquire(key(i), LockMode::Write) {
                Ok(AcquireOutcome::Acquired) => locked += 1,
                Ok(AcquireOutcome::Conflict) => {}
                Err(Error::LockBucketFull) => {
                    full = true;
                    break;
                }
                Err(e) => panic!("{e}"),
            }
        }
        assert_eq!(locked, SLOTS_PER_BUCKET);
        assert!(full);
    }

    #[test]
    fn release_clears_slot_for_reuse() {
        let t = LockTable::new(1);
        // Fill the bucket, release everything, refill with new keys.
        let first: Vec<u64> = (0..8).collect();
        for &i in &first {
            t.acquire(key(i), LockMode::Write).unwrap();
        }
        for &i in &first {
            t.release(key(i), LockMode::Write);
        }
        assert_eq!(t.held_slots(), 0);
        for i in 100..108 {
            assert_eq!(t.acquire(key(i), LockMode::Write).unwrap(), AcquireOutcome::Acquired);
        }
    }

    #[test]
    fn release_unheld_is_idempotent() {
        let t = LockTable::new(16);
        t.release(key(9), LockMode::Write); // no-op
        t.release(key(9), LockMode::Read);
        assert_eq!(t.peek(key(9)), None);
    }

    #[test]
    fn concurrent_writers_one_winner() {
        let t = Arc::new(LockTable::new(256));
        let k = key(42);
        let threads: Vec<_> = (0..16)
            .map(|_| {
                let t = t.clone();
                std::thread::spawn(move || {
                    matches!(t.acquire(k, LockMode::Write).unwrap(), AcquireOutcome::Acquired)
                })
            })
            .collect();
        let wins: usize = threads.into_iter().map(|h| h.join().unwrap()).filter(|&w| w).count();
        assert_eq!(wins, 1, "exactly one writer must win");
    }

    #[test]
    fn concurrent_readers_all_win_then_counter_returns_to_zero() {
        let t = Arc::new(LockTable::new(256));
        let k = key(43);
        let threads: Vec<_> = (0..32)
            .map(|_| {
                let t = t.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        while !matches!(
                            t.acquire(k, LockMode::Read).unwrap(),
                            AcquireOutcome::Acquired
                        ) {
                            std::hint::spin_loop();
                        }
                        t.release(k, LockMode::Read);
                    }
                })
            })
            .collect();
        for h in threads {
            h.join().unwrap();
        }
        assert_eq!(t.peek(k), None, "counter must return to zero");
    }

    #[test]
    fn prop_lock_counter_algebra() {
        // Random acquire/release sequences: the table's counter always
        // equals 2*readers (or 1 for a writer), and never goes negative.
        crate::testing::prop(50, |g| {
            let t = LockTable::new(4);
            let k = key(g.u64(0, 3));
            let mut readers = 0u64;
            let mut writer = false;
            for _ in 0..g.usize(1, 200) {
                if g.bool(0.5) {
                    // try acquire
                    let mode = if g.bool(0.3) { LockMode::Write } else { LockMode::Read };
                    match t.acquire(k, mode) {
                        Ok(AcquireOutcome::Acquired) => match mode {
                            LockMode::Write => {
                                assert!(!writer && readers == 0);
                                writer = true;
                            }
                            LockMode::Read => {
                                assert!(!writer);
                                readers += 1;
                            }
                        },
                        Ok(AcquireOutcome::Conflict) => match mode {
                            LockMode::Write => assert!(writer || readers > 0),
                            LockMode::Read => assert!(writer || readers >= MAX_READERS as u64),
                        },
                        Err(_) => {}
                    }
                } else {
                    // release if held
                    if writer {
                        t.release(k, LockMode::Write);
                        writer = false;
                    } else if readers > 0 {
                        t.release(k, LockMode::Read);
                        readers -= 1;
                    }
                }
                let expect = if writer { Some(1) } else if readers > 0 { Some(readers * 2) } else { None };
                assert_eq!(t.peek(k), expect);
            }
        });
    }
}
