//! CN-side distributed lock tables (paper section 4.1, Algorithm 1).
//!
//! LOTUS disaggregates locks from data: every CN hosts a fixed-length
//! hash [`table::LockTable`] of 8-byte slots (7B fingerprint + 1B
//! counter, 8 slots per bucket) and a [`state::LockState`] side map
//! recording holders (txn id, CN id, mode) for idempotency, recovery and
//! resharding. [`service::LockService`] dispatches a transaction's lock
//! set: local requests execute as CPU CAS on the local table; remote
//! requests are batched per target CN into a single RPC.

pub mod service;
pub mod state;
pub mod table;

pub use service::{AcquiredLock, LockRequest, LockService};
pub use state::{HolderId, LockState};
pub use table::{LockMode, LockTable};
