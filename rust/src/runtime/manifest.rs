//! Artifact manifest: topology metadata written by `python/compile/aot.py`.
//!
//! The manifest lets the runtime validate at load time that the compiled
//! artifact's static shapes (CN count, shard count, hash batch) match the
//! cluster configuration — a mismatch is a build error, not a silent
//! mis-execution. The file is a small fixed-schema JSON document; the
//! extractor here is deliberately minimal (no serde in the dependency
//! set) and rejects anything it does not understand.

use std::path::Path;

use crate::{Error, Result};

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// Rebalance artifact file name.
    pub rebalance_file: String,
    /// CN count the rebalance artifact was compiled for.
    pub n_cns: usize,
    /// Shard count the rebalance artifact was compiled for.
    pub n_shards: usize,
    /// Shard-hash artifact file name.
    pub shard_hash_file: String,
    /// Shard-hash batch size.
    pub hash_batch: usize,
}

/// Extract `"key": <number>` from a JSON fragment.
fn num_field(json: &str, key: &str) -> Result<usize> {
    let needle = format!("\"{key}\"");
    let at = json
        .find(&needle)
        .ok_or_else(|| Error::Runtime(format!("manifest missing field '{key}'")))?;
    let rest = &json[at + needle.len()..];
    let rest = rest.trim_start().strip_prefix(':').ok_or_else(|| {
        Error::Runtime(format!("manifest field '{key}' malformed"))
    })?;
    let digits: String = rest.trim_start().chars().take_while(|c| c.is_ascii_digit()).collect();
    digits
        .parse()
        .map_err(|_| Error::Runtime(format!("manifest field '{key}' is not a number")))
}

/// Extract `"key": "<string>"` from a JSON fragment.
fn str_field(json: &str, key: &str) -> Result<String> {
    let needle = format!("\"{key}\"");
    let at = json
        .find(&needle)
        .ok_or_else(|| Error::Runtime(format!("manifest missing field '{key}'")))?;
    let rest = &json[at + needle.len()..];
    let rest = rest.trim_start().strip_prefix(':').ok_or_else(|| {
        Error::Runtime(format!("manifest field '{key}' malformed"))
    })?;
    let rest = rest.trim_start();
    let rest = rest
        .strip_prefix('"')
        .ok_or_else(|| Error::Runtime(format!("manifest field '{key}' is not a string")))?;
    let end = rest
        .find('"')
        .ok_or_else(|| Error::Runtime(format!("manifest field '{key}' unterminated")))?;
    Ok(rest[..end].to_string())
}

/// Slice out one top-level object section (`"name": { ... }`).
fn section<'a>(json: &'a str, name: &str) -> Result<&'a str> {
    let needle = format!("\"{name}\"");
    let at = json
        .find(&needle)
        .ok_or_else(|| Error::Runtime(format!("manifest missing section '{name}'")))?;
    let open = json[at..]
        .find('{')
        .ok_or_else(|| Error::Runtime(format!("manifest section '{name}' malformed")))?;
    let start = at + open;
    let mut depth = 0usize;
    for (i, c) in json[start..].char_indices() {
        match c {
            '{' => depth += 1,
            '}' => {
                depth -= 1;
                if depth == 0 {
                    return Ok(&json[start..start + i + 1]);
                }
            }
            _ => {}
        }
    }
    Err(Error::Runtime(format!("manifest section '{name}' unterminated")))
}

impl Manifest {
    /// Parse the manifest text.
    pub fn parse(json: &str) -> Result<Self> {
        let rb = section(json, "rebalance")?;
        let sh = section(json, "shard_hash")?;
        Ok(Self {
            rebalance_file: str_field(rb, "file")?,
            n_cns: num_field(rb, "n_cns")?,
            n_shards: num_field(rb, "n_shards")?,
            shard_hash_file: str_field(sh, "file")?,
            hash_batch: num_field(sh, "batch")?,
        })
    }

    /// Load + parse from a path.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "rebalance": {
        "file": "rebalance.hlo.txt",
        "n_cns": 9,
        "n_shards": 4096,
        "n_intervals": 3,
        "outputs": ["heat", "load", "overload", "hottest", "target"]
      },
      "shard_hash": {
        "file": "shard_hash.hlo.txt",
        "batch": 1024,
        "outputs": ["fingerprint", "bucket", "shard"]
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.rebalance_file, "rebalance.hlo.txt");
        assert_eq!(m.n_cns, 9);
        assert_eq!(m.n_shards, 4096);
        assert_eq!(m.shard_hash_file, "shard_hash.hlo.txt");
        assert_eq!(m.hash_batch, 1024);
    }

    #[test]
    fn missing_field_errors() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"rebalance": {"file": "x"}}"#).is_err());
    }

    #[test]
    fn parses_real_artifact_manifest_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(p).unwrap();
            assert!(m.n_cns > 0 && m.n_shards > 0 && m.hash_batch > 0);
        }
    }
}
