//! PJRT runtime bridge: load + execute the AOT-compiled XLA artifacts.
//!
//! `make artifacts` runs `python/compile/aot.py` once at build time,
//! lowering the L2 JAX model (whose hot spots are the L1 Pallas kernels)
//! to **HLO text** in `artifacts/*.hlo.txt`. With the `xla` cargo feature
//! enabled, this module loads that text with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client,
//! and executes it from the rust hot path — python never runs at
//! transaction time.
//!
//! HLO *text* (not `.serialize()`) is the interchange format because
//! jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
//! linked xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! **Offline builds.** The `xla` binding crate is not in the offline
//! vendor set, so the feature is off by default and the types below
//! degrade to stubs whose constructors return [`Error::Runtime`]. Every
//! consumer already handles that path: [`crate::balance::XlaPlanner`]
//! fails to load and the cluster harness falls back to the bit-equivalent
//! [`crate::balance::RustPlanner`] mirror.

pub mod manifest;

use std::path::Path;

use crate::{Error, Result};

pub use manifest::Manifest;

/// A typed output extracted from an executed tuple.
#[derive(Debug, Clone, PartialEq)]
pub enum OutValue {
    /// f32 tensor, flattened row-major.
    F32(Vec<f32>),
    /// i32 tensor, flattened row-major.
    I32(Vec<i32>),
    /// u32 tensor, flattened row-major.
    U32(Vec<u32>),
}

impl OutValue {
    /// Borrow as f32, panicking on type mismatch (artifact contract).
    pub fn as_f32(&self) -> &[f32] {
        match self {
            OutValue::F32(v) => v,
            other => panic!("expected f32 output, got {other:?}"),
        }
    }

    /// Borrow as i32.
    pub fn as_i32(&self) -> &[i32] {
        match self {
            OutValue::I32(v) => v,
            other => panic!("expected i32 output, got {other:?}"),
        }
    }

    /// Borrow as u32.
    pub fn as_u32(&self) -> &[u32] {
        match self {
            OutValue::U32(v) => v,
            other => panic!("expected u32 output, got {other:?}"),
        }
    }
}

/// An input literal under construction.
pub enum InValue<'a> {
    /// f32 tensor with dims.
    F32(&'a [f32], &'a [i64]),
    /// u32 tensor with dims.
    U32(&'a [u32], &'a [i64]),
}

#[cfg(feature = "xla")]
fn xerr(e: xla::Error) -> Error {
    Error::Xla(e.to_string())
}

/// A PJRT CPU client plus the compiled LOTUS artifacts.
#[cfg(feature = "xla")]
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

#[cfg(feature = "xla")]
impl XlaRuntime {
    /// Start a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu().map_err(xerr)?,
        })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<LoadedExec> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
            Error::Runtime(format!("loading {}: {e}", path.display()))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xerr)?;
        Ok(LoadedExec { exe })
    }
}

/// One compiled executable.
#[cfg(feature = "xla")]
pub struct LoadedExec {
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "xla")]
impl LoadedExec {
    /// Execute with the given inputs; returns the artifact's output tuple
    /// decomposed into typed vectors.
    pub fn run(&self, inputs: &[InValue<'_>]) -> Result<Vec<OutValue>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for inp in inputs {
            let lit = match inp {
                InValue::F32(data, dims) => {
                    xla::Literal::vec1(data).reshape(dims).map_err(xerr)?
                }
                InValue::U32(data, dims) => {
                    xla::Literal::vec1(data).reshape(dims).map_err(xerr)?
                }
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(xerr)?;
        let root = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Runtime("empty execution result".into()))?
            .to_literal_sync()
            .map_err(xerr)?;
        let parts = root.to_tuple().map_err(xerr)?;
        let mut out = Vec::with_capacity(parts.len());
        for part in parts {
            out.push(Self::typed(part)?);
        }
        Ok(out)
    }

    fn typed(lit: xla::Literal) -> Result<OutValue> {
        let ty = lit.ty().map_err(xerr)?;
        Ok(match ty {
            xla::ElementType::F32 => OutValue::F32(lit.to_vec::<f32>().map_err(xerr)?),
            xla::ElementType::S32 => OutValue::I32(lit.to_vec::<i32>().map_err(xerr)?),
            xla::ElementType::U32 => OutValue::U32(lit.to_vec::<u32>().map_err(xerr)?),
            other => {
                return Err(Error::Runtime(format!(
                    "unsupported artifact output type {other:?}"
                )))
            }
        })
    }
}

#[cfg(not(feature = "xla"))]
fn unavailable() -> Error {
    Error::Runtime(
        "built without the `xla` feature: PJRT execution unavailable \
         (the balance planner falls back to the rust mirror)"
            .into(),
    )
}

/// Stub PJRT client for builds without the `xla` feature (see module docs).
#[cfg(not(feature = "xla"))]
pub struct XlaRuntime {
    _priv: (),
}

#[cfg(not(feature = "xla"))]
impl XlaRuntime {
    /// Always fails: the PJRT client needs the `xla` feature.
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    /// Always fails: compilation needs the `xla` feature.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, _path: P) -> Result<LoadedExec> {
        Err(unavailable())
    }
}

/// Stub executable for builds without the `xla` feature.
#[cfg(not(feature = "xla"))]
pub struct LoadedExec {
    _priv: (),
}

#[cfg(not(feature = "xla"))]
impl LoadedExec {
    /// Always fails: execution needs the `xla` feature.
    pub fn run(&self, _inputs: &[InValue<'_>]) -> Result<Vec<OutValue>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "xla")]
    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = XlaRuntime::cpu().unwrap_err();
        assert!(matches!(err, Error::Runtime(_)));
        assert!(err.to_string().contains("xla"));
    }

    #[cfg(feature = "xla")]
    #[test]
    fn shard_hash_artifact_matches_rust_mix32() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let manifest = Manifest::load(dir.join("manifest.json")).unwrap();
        let rt = XlaRuntime::cpu().unwrap();
        let exe = rt.load_hlo_text(dir.join(&manifest.shard_hash_file)).unwrap();
        let n = manifest.hash_batch;
        let mut rng = crate::util::Xoshiro256::new(99);
        let hi: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let lo: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let dims = [n as i64];
        let out = exe
            .run(&[InValue::U32(&hi, &dims), InValue::U32(&lo, &dims)])
            .unwrap();
        assert_eq!(out.len(), 3);
        let fp = out[0].as_u32();
        let shard = out[2].as_u32();
        // Layer-pinning: the artifact's mix must equal rust's bit-for-bit.
        for i in 0..n {
            assert_eq!(fp[i], crate::sharding::key::mix32(hi[i], lo[i]), "i={i}");
            assert_eq!(shard[i], lo[i] & 0xFFF);
        }
    }

    #[cfg(feature = "xla")]
    #[test]
    fn rebalance_artifact_loads_and_runs() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let manifest = Manifest::load(dir.join("manifest.json")).unwrap();
        let rt = XlaRuntime::cpu().unwrap();
        let exe = rt.load_hlo_text(dir.join(&manifest.rebalance_file)).unwrap();
        let (c, s) = (manifest.n_cns, manifest.n_shards);
        let counts = vec![1.0f32; c * s];
        let prev = vec![0.0f32; c * s];
        let lat = vec![100.0f32; c * 3];
        let alpha = [0.25f32];
        let out = exe
            .run(&[
                InValue::F32(&counts, &[c as i64, s as i64]),
                InValue::F32(&prev, &[c as i64, s as i64]),
                InValue::F32(&lat, &[c as i64, 3]),
                InValue::F32(&alpha, &[1]),
            ])
            .unwrap();
        assert_eq!(out.len(), 5);
        let heat = out[0].as_f32();
        assert_eq!(heat.len(), c * s);
        assert!((heat[0] - 0.25).abs() < 1e-6);
        let load = out[1].as_f32();
        assert!((load[0] - 0.25 * s as f32).abs() < 1e-2);
        // Uniform latencies: nobody overloaded.
        assert!(out[2].as_i32().iter().all(|&v| v == 0));
    }
}
