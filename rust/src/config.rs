//! Cluster + benchmark configuration.
//!
//! One [`Config`] describes everything a [`crate::sim::Cluster`] needs:
//! topology (MN/CN counts, coordinators per CN), memory budgets (lock
//! table, version-table cache — paper 8.1 defaults 32 MB and 4.5 MB),
//! MVCC geometry (versions per record), isolation level, replication
//! factor, the calibrated network constants, and run parameters. A small
//! TOML-ish `key=value` file parser plus CLI override support back the
//! `lotus` binary; presets mirror the paper's testbed.

use crate::dm::NetConfig;
use crate::txn::api::Isolation;
use crate::{Error, Result};

/// Which transaction system to run (LOTUS or a baseline).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SystemKind {
    /// LOTUS: lock disaggregation + lock-first protocol.
    Lotus,
    /// Motor-like baseline: MVCC, MN-side CAS locks.
    Motor,
    /// FORD-like baseline: single-versioning, MN-side CAS locks.
    Ford,
    /// Motor with LOTUS's full-record store layout (fig. 14 "+Full
    /// Record Store" ablation step).
    MotorFullRecord,
    /// Motor with CAS abandoned (unsafe, fig. 3).
    MotorNoCas,
    /// FORD with CAS abandoned (unsafe, fig. 3).
    FordNoCas,
    /// Idealized RDMA lock model (fig. 17).
    IdealLock,
}

impl SystemKind {
    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "lotus" => SystemKind::Lotus,
            "motor" => SystemKind::Motor,
            "ford" => SystemKind::Ford,
            "motor-full-record" | "motorfullrecord" => SystemKind::MotorFullRecord,
            "motor-nocas" | "motornocas" => SystemKind::MotorNoCas,
            "ford-nocas" | "fordnocas" => SystemKind::FordNoCas,
            "ideal-lock" | "ideallock" => SystemKind::IdealLock,
            other => return Err(Error::Config(format!("unknown system '{other}'"))),
        })
    }

    /// Display name used in reports.
    pub fn name(self) -> &'static str {
        match self {
            SystemKind::Lotus => "lotus",
            SystemKind::Motor => "motor",
            SystemKind::Ford => "ford",
            SystemKind::MotorFullRecord => "motor-full-record",
            SystemKind::MotorNoCas => "motor-nocas",
            SystemKind::FordNoCas => "ford-nocas",
            SystemKind::IdealLock => "ideal-lock",
        }
    }
}

/// LOTUS feature toggles (the fig. 14 ablation axes).
#[derive(Debug, Clone, Copy)]
pub struct Features {
    /// Store each version as an independent full record (vs delta store).
    pub full_record_store: bool,
    /// Write commit logs + the extra write-visible RTT (no UPS reliance).
    pub log_and_visible: bool,
    /// Disaggregate locks to CNs (vs MN-side CAS).
    pub lock_sharding: bool,
    /// Two-level load balancing (hybrid routing + resharding).
    pub load_balancing: bool,
    /// Version-table cache.
    pub vt_cache: bool,
}

impl Default for Features {
    fn default() -> Self {
        Self::all()
    }
}

impl Features {
    /// Everything on (LOTUS proper).
    pub fn all() -> Self {
        Self {
            full_record_store: true,
            log_and_visible: true,
            lock_sharding: true,
            load_balancing: true,
            vt_cache: true,
        }
    }
}

/// Dataset scale knobs. The paper loads 20M KV pairs / 20M accounts /
/// 3M subscribers / 105 warehouses on 64 GB machines; the simulator keeps
/// the same *shapes* at a scale that fits one host (see EXPERIMENTS.md for
/// the scaling substitution note).
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    /// KVS key count (paper: 20M).
    pub kvs_keys: u64,
    /// SmallBank account count (paper: 20M).
    pub smallbank_accounts: u64,
    /// TATP subscriber count (paper: 3M).
    pub tatp_subscribers: u64,
    /// TPC-C warehouse count (paper: 105).
    pub tpcc_warehouses: u64,
}

impl Default for Scale {
    fn default() -> Self {
        Self {
            kvs_keys: 1_000_000,
            smallbank_accounts: 1_000_000,
            tatp_subscribers: 300_000,
            tpcc_warehouses: 8,
        }
    }
}

/// Full cluster + run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of memory nodes (paper testbed: 3).
    pub n_mns: usize,
    /// Number of compute nodes (paper testbed: 9).
    pub n_cns: usize,
    /// Coordinator threads per CN ("threads x coroutines" in the paper;
    /// each simulated coordinator is one concurrent transaction stream
    /// multiplied by `pipeline_depth` pipelined lanes).
    pub coordinators_per_cn: usize,
    /// Concurrent transaction frames (lanes) per LOTUS coordinator
    /// thread — the paper's coroutines. Each lane is a full transaction
    /// stream; the [`crate::txn::scheduler::FrameScheduler`] overlaps
    /// them in virtual time and coalesces their doorbells. `1` is the
    /// exact sequential protocol; `0` selects the legacy sequential
    /// coordinator shell (identical accounting to `1`, kept as the
    /// equivalence baseline). Baselines are unaffected.
    pub pipeline_depth: usize,
    /// Virtual-time window of the step-machine: how far apart (virtual
    /// ns) two frames' issue points may be and still share one doorbell
    /// ring. A staged plan waits at most this long for sibling lanes'
    /// plans to merge with it; a deferred fire-and-forget plan
    /// (commit-log clear) may wait this long for a doorbell to ride. `0`
    /// disables staging and coalescing entirely (every issue is direct).
    /// Only meaningful with `pipeline_depth >= 2`.
    pub coalesce_window_ns: u64,
    /// Adaptive coalescing policy (ISSUE 6): when `true`, the
    /// [`crate::txn::adaptive::AdaptiveController`] steers an *effective*
    /// window per fabric plane × destination — widening up to
    /// `coalesce_window_ns × CAP_MULT` where a destination queue is
    /// IOPS/handler-bound, shrinking toward direct issue where commits
    /// are latency-bound — with `coalesce_window_ns` as the base/anchor.
    /// `false` (the default) keeps the fixed window everywhere; fixed
    /// remains the depth-1 byte-equivalence anchor.
    pub adaptive_coalescing: bool,
    /// Lock-phase RPC retries after a lost or timed-out message, before
    /// the transaction aborts with `OwnerFailed`. `0` (the default) is
    /// the pre-retry behavior: a single timeout aborts immediately.
    pub rpc_max_retries: u32,
    /// Base of the capped exponential retry backoff (virtual ns): retry
    /// `k` backs off `rpc_backoff_base_ns << min(k, 4)` before
    /// reissuing, charged to the lane clock (and `backoff_ns`).
    pub rpc_backoff_base_ns: u64,
    /// Memory per MN in bytes.
    pub mn_capacity: u64,
    /// Lock-table budget per CN in bytes (paper default 32 MB).
    pub lock_table_bytes: usize,
    /// Version-table cache entries per CN (paper default 64K CVTs ~ 4.5 MB).
    pub vt_cache_entries: usize,
    /// Versions per record (paper default 2).
    pub n_versions: u8,
    /// Index bucket associativity (CVTs per bucket).
    pub assoc: u8,
    /// Replication factor including the primary (paper 8.1: 3-way).
    pub replicas: usize,
    /// Isolation level.
    pub isolation: Isolation,
    /// Feature toggles (ablation).
    pub features: Features,
    /// Calibrated network constants.
    pub net: NetConfig,
    /// Virtual run duration (ns).
    pub duration_ns: u64,
    /// Virtual-time skew window for the [`crate::dm::TimeGate`].
    pub gate_window_ns: u64,
    /// Epoch-batched gate publication (ISSUE 9): a coordinator pays the
    /// cross-core [`crate::dm::TimeGate`] store only per this much
    /// virtual progress (or when the skew window forces it). `0` (the
    /// small-topology/test default) publishes on every bump — the legacy
    /// byte-exact behavior; the paper preset batches, widening the
    /// realized skew bound to `gate_window_ns + gate_publish_ns`.
    pub gate_publish_ns: u64,
    /// Timeline sampling interval for recovery plots (0 = no timeline).
    pub timeline_interval_ns: u64,
    /// GC staleness threshold (ns, paper 7.1: 500 ms).
    pub gc_threshold_ns: u64,
    /// Load-balancer metrics interval (ns, paper 4.3: 100 ms).
    pub balance_interval_ns: u64,
    /// Shard transfers the balance tick may execute per sealed interval
    /// (ISSUE 10). Each move pauses one shard and charges its
    /// interruption to the coordinator clock floor, so the per-interval
    /// stall is bounded by `max_moves_per_tick` transfers instead of an
    /// arbitrary plan executed in one clock jump. `0` removes the bound
    /// (the legacy execute-the-whole-plan behavior).
    pub max_moves_per_tick: usize,
    /// Moving-skew drift (ISSUE 10): every this many virtual ns the KVS
    /// Zipf rank-to-key mapping rotates by a fixed stride, so the hot
    /// set walks across the shard space (and across CN lock ranges).
    /// `0` (the default) keeps the hot set static — the byte-inert
    /// legacy behavior. Only the KVS workload reads this knob.
    pub drift_interval_ns: u64,
    /// Flash-crowd mode (ISSUE 10, `telecom_cache`-style): at this
    /// virtual time a cold key range abruptly becomes the hot set (the
    /// rank-to-key mapping jumps by half the key space and stays
    /// there). `0` (the default) disables it. Only the KVS workload
    /// reads this knob; composes with `drift_interval_ns`.
    pub flash_crowd_at_ns: u64,
    /// Dataset scale.
    pub scale: Scale,
    /// RNG seed.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self::paper()
    }
}

impl Config {
    /// The paper's testbed scale: 3 MNs, 9 CNs.
    pub fn paper() -> Self {
        Self {
            n_mns: 3,
            n_cns: 9,
            coordinators_per_cn: 4,
            pipeline_depth: 4,
            coalesce_window_ns: 5_000,
            adaptive_coalescing: false,
            rpc_max_retries: 0,
            rpc_backoff_base_ns: 20_000,
            mn_capacity: 4 << 30,
            lock_table_bytes: 32 << 20,
            vt_cache_entries: 64 * 1024,
            n_versions: 2,
            assoc: 4,
            replicas: 3,
            isolation: Isolation::Serializable,
            features: Features::all(),
            net: NetConfig::default(),
            duration_ns: 100_000_000, // 100 ms virtual
            gate_window_ns: 1_000,
            gate_publish_ns: 20_000,
            timeline_interval_ns: 0,
            gc_threshold_ns: crate::store::gc::DEFAULT_GC_THRESHOLD_NS,
            balance_interval_ns: 100_000_000,
            max_moves_per_tick: 1,
            drift_interval_ns: 0,
            flash_crowd_at_ns: 0,
            scale: Scale::default(),
            seed: 42,
        }
    }

    /// Small topology for tests / doc examples: 2 MNs, 3 CNs, short run.
    pub fn small() -> Self {
        Self {
            n_mns: 2,
            n_cns: 3,
            coordinators_per_cn: 2,
            mn_capacity: 256 << 20,
            lock_table_bytes: 1 << 20,
            vt_cache_entries: 4096,
            replicas: 2,
            duration_ns: 10_000_000, // 10 ms virtual
            // Per-bump publication: the small topology anchors the
            // byte-exact equivalence/determinism suites (epoch batching
            // is opted into explicitly by the inertness tests and the
            // LOTUS_TEST_GATE_PUBLISH_NS CI leg).
            gate_publish_ns: 0,
            scale: Scale {
                kvs_keys: 20_000,
                smallbank_accounts: 20_000,
                tatp_subscribers: 10_000,
                tpcc_warehouses: 2,
            },
            ..Self::paper()
        }
    }

    /// Apply the CI test-matrix env overrides, if set:
    /// `LOTUS_TEST_PIPELINE_DEPTH`, `LOTUS_TEST_COALESCE_WINDOW_NS`,
    /// `LOTUS_TEST_N_CNS`, `LOTUS_TEST_ADAPTIVE` (the coalescing
    /// policy axis: `1`/`true` enables the adaptive controller) and
    /// `LOTUS_TEST_FAULTS` (the chaos axis: `1`/`true` arms
    /// `rpc_max_retries = 2`) and `LOTUS_TEST_GATE_PUBLISH_NS` (the
    /// wall-clock axis: epoch-batched gate publication). Invalid values
    /// are ignored (the defaults stand).
    ///
    /// Called by the *test suites'* config helpers (never by library
    /// constructors — a downstream user of [`Config::small`] must not be
    /// affected by ambient CI variables). Tests that assert a specific
    /// depth/window/topology behavior pin those fields explicitly after
    /// applying this; everything else must hold at every point of the
    /// `{0, 1, 4} x {0, 5000} x {1, 3}` matrix (the `n_cns` axis
    /// exercises the remote-lock RPC plane: at 1 CN every lock is local,
    /// at 3 CNs most transactions carry remote lock batches).
    pub fn apply_test_env(&mut self) {
        if let Ok(v) = std::env::var("LOTUS_TEST_PIPELINE_DEPTH") {
            if let Ok(d) = v.parse() {
                self.pipeline_depth = d;
            }
        }
        if let Ok(v) = std::env::var("LOTUS_TEST_COALESCE_WINDOW_NS") {
            if let Ok(w) = v.parse() {
                self.coalesce_window_ns = w;
            }
        }
        if let Ok(v) = std::env::var("LOTUS_TEST_N_CNS") {
            if let Ok(n) = v.parse::<usize>() {
                if n >= 1 {
                    self.n_cns = n;
                }
            }
        }
        if let Ok(v) = std::env::var("LOTUS_TEST_ADAPTIVE") {
            match v.as_str() {
                "1" | "true" => self.adaptive_coalescing = true,
                "0" | "false" => self.adaptive_coalescing = false,
                _ => {}
            }
        }
        // Chaos axis: `1`/`true` arms the retry-with-backoff machinery
        // (the fault-tolerant configuration the chaos suite exercises)
        // across every suite run under this leg. Fault *injection* stays
        // per-test — only the dedicated chaos tests install injectors —
        // so fault-free runs stay byte-identical modulo the retry path
        // never firing.
        if let Ok(v) = std::env::var("LOTUS_TEST_FAULTS") {
            match v.as_str() {
                "1" | "true" => self.rpc_max_retries = 2,
                "0" | "false" => self.rpc_max_retries = 0,
                _ => {}
            }
        }
        // Wall-clock axis (ISSUE 9): a nonzero value runs the whole
        // suite with epoch-batched gate publication armed. Tests that
        // assert byte-exact per-bump publication pin `gate_publish_ns`
        // explicitly.
        if let Ok(v) = std::env::var("LOTUS_TEST_GATE_PUBLISH_NS") {
            if let Ok(ns) = v.parse() {
                self.gate_publish_ns = ns;
            }
        }
        // Rebalance axis (ISSUE 10): `1`/`true` arms the periodic
        // balance tick (interval well under the tiny-suite durations)
        // plus the drifting KVS hot-spot, so the whole suite also holds
        // with shards migrating under load. Plan *inputs* (drained
        // request counts, sealed latency rings) race sibling OS threads
        // within the gate's skew window, so move decisions are not
        // byte-reproducible across runs — tests that byte-compare
        // reports or assert exact counts pin `balance_interval_ns` /
        // `drift_interval_ns` explicitly, exactly like the
        // gate-publish axis.
        if let Ok(v) = std::env::var("LOTUS_TEST_REBALANCE") {
            match v.as_str() {
                "1" | "true" => {
                    self.balance_interval_ns = 500_000;
                    self.drift_interval_ns = 1_000_000;
                }
                "0" | "false" => {
                    self.drift_interval_ns = 0;
                }
                _ => {}
            }
        }
    }

    /// Total coordinator count across the cluster.
    pub fn total_coordinators(&self) -> usize {
        self.n_cns * self.coordinators_per_cn
    }

    /// Validate invariants; returns self for chaining.
    pub fn validate(self) -> Result<Self> {
        if self.n_mns == 0 || self.n_cns == 0 || self.coordinators_per_cn == 0 {
            return Err(Error::Config("topology counts must be positive".into()));
        }
        if self.replicas == 0 || self.replicas > self.n_mns {
            return Err(Error::Config(format!(
                "replicas {} must be in 1..={}",
                self.replicas, self.n_mns
            )));
        }
        if self.n_versions == 0 {
            return Err(Error::Config("n_versions must be >= 1".into()));
        }
        if self.duration_ns == 0 {
            return Err(Error::Config("duration_ns must be positive".into()));
        }
        Ok(self)
    }

    /// Apply a `key=value` override (CLI / config file). Unknown keys err.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        fn p<T: std::str::FromStr>(k: &str, v: &str) -> Result<T> {
            v.parse()
                .map_err(|_| Error::Config(format!("bad value '{v}' for '{k}'")))
        }
        match key {
            "n_mns" => self.n_mns = p(key, value)?,
            "n_cns" => self.n_cns = p(key, value)?,
            "coordinators_per_cn" => self.coordinators_per_cn = p(key, value)?,
            "pipeline_depth" => self.pipeline_depth = p(key, value)?,
            "coalesce_window_ns" => self.coalesce_window_ns = p(key, value)?,
            "adaptive_coalescing" => self.adaptive_coalescing = p(key, value)?,
            "rpc_max_retries" => self.rpc_max_retries = p(key, value)?,
            "rpc_backoff_base_ns" => self.rpc_backoff_base_ns = p(key, value)?,
            "mn_capacity" => self.mn_capacity = p(key, value)?,
            "lock_table_bytes" => self.lock_table_bytes = p(key, value)?,
            "vt_cache_entries" => self.vt_cache_entries = p(key, value)?,
            "n_versions" => self.n_versions = p(key, value)?,
            "assoc" => self.assoc = p(key, value)?,
            "replicas" => self.replicas = p(key, value)?,
            "duration_ns" => self.duration_ns = p(key, value)?,
            "duration_ms" => self.duration_ns = p::<u64>(key, value)? * 1_000_000,
            "gate_window_ns" => self.gate_window_ns = p(key, value)?,
            "gate_publish_ns" => self.gate_publish_ns = p(key, value)?,
            "timeline_interval_ns" => self.timeline_interval_ns = p(key, value)?,
            "gc_threshold_ns" => self.gc_threshold_ns = p(key, value)?,
            "balance_interval_ns" => self.balance_interval_ns = p(key, value)?,
            "max_moves_per_tick" => self.max_moves_per_tick = p(key, value)?,
            "drift_interval_ns" => self.drift_interval_ns = p(key, value)?,
            "flash_crowd_at_ns" => self.flash_crowd_at_ns = p(key, value)?,
            "kvs_keys" => self.scale.kvs_keys = p(key, value)?,
            "smallbank_accounts" => self.scale.smallbank_accounts = p(key, value)?,
            "tatp_subscribers" => self.scale.tatp_subscribers = p(key, value)?,
            "tpcc_warehouses" => self.scale.tpcc_warehouses = p(key, value)?,
            "seed" => self.seed = p(key, value)?,
            "isolation" => {
                self.isolation = match value {
                    "sr" | "serializable" => Isolation::Serializable,
                    "si" | "snapshot" => Isolation::SnapshotIsolation,
                    v => return Err(Error::Config(format!("bad isolation '{v}'"))),
                }
            }
            "full_record_store" => self.features.full_record_store = p(key, value)?,
            "log_and_visible" => self.features.log_and_visible = p(key, value)?,
            "lock_sharding" => self.features.lock_sharding = p(key, value)?,
            "load_balancing" => self.features.load_balancing = p(key, value)?,
            "vt_cache" => self.features.vt_cache = p(key, value)?,
            other => return Err(Error::Config(format!("unknown config key '{other}'"))),
        }
        Ok(())
    }

    /// Parse a minimal `key = value` config file (# comments, blank lines).
    pub fn load_overrides(&mut self, text: &str) -> Result<()> {
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(Error::Config(format!("line {}: expected key=value", lineno + 1)));
            };
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        assert!(Config::paper().validate().is_ok());
        assert!(Config::small().validate().is_ok());
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = Config::small();
        c.replicas = 10; // > n_mns
        assert!(c.validate().is_err());
        let mut c = Config::small();
        c.n_versions = 0;
        assert!(c.validate().is_err());
        let mut c = Config::small();
        c.n_cns = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn pipeline_knobs_default_and_override() {
        let c = Config::paper();
        assert_eq!(c.pipeline_depth, 4, "ISSUE 2 default depth");
        assert!(c.coalesce_window_ns > 0);
        assert!(!c.adaptive_coalescing, "fixed window is the default policy");
        let mut c = Config::small();
        c.set("pipeline_depth", "1").unwrap();
        c.set("coalesce_window_ns", "0").unwrap();
        assert_eq!(c.pipeline_depth, 1);
        assert_eq!(c.coalesce_window_ns, 0);
        assert!(c.validate().is_ok(), "depth 1 / window 0 is the sequential mode");
        c.set("adaptive_coalescing", "true").unwrap();
        assert!(c.adaptive_coalescing);
        assert!(c.set("adaptive_coalescing", "maybe").is_err());
    }

    #[test]
    fn retry_knobs_default_off_and_override() {
        let c = Config::paper();
        assert_eq!(c.rpc_max_retries, 0, "retries must default off (inert)");
        assert!(c.rpc_backoff_base_ns > 0);
        let mut c = Config::small();
        c.set("rpc_max_retries", "3").unwrap();
        c.set("rpc_backoff_base_ns", "50000").unwrap();
        assert_eq!(c.rpc_max_retries, 3);
        assert_eq!(c.rpc_backoff_base_ns, 50_000);
        assert!(c.set("rpc_max_retries", "lots").is_err());
    }

    #[test]
    fn rebalance_knobs_default_inert_and_override() {
        let c = Config::paper();
        assert_eq!(c.drift_interval_ns, 0, "static skew must be the default");
        assert_eq!(c.flash_crowd_at_ns, 0, "flash crowd must default off");
        assert_eq!(c.max_moves_per_tick, 1, "tick must be bounded by default");
        let mut c = Config::small();
        c.set("drift_interval_ns", "1000000").unwrap();
        c.set("flash_crowd_at_ns", "5000000").unwrap();
        c.set("max_moves_per_tick", "0").unwrap();
        assert_eq!(c.drift_interval_ns, 1_000_000);
        assert_eq!(c.flash_crowd_at_ns, 5_000_000);
        assert_eq!(c.max_moves_per_tick, 0, "0 = unbounded legacy plan execution");
        assert!(c.set("max_moves_per_tick", "many").is_err());
    }

    #[test]
    fn set_overrides() {
        let mut c = Config::small();
        c.set("n_cns", "5").unwrap();
        c.set("isolation", "si").unwrap();
        c.set("vt_cache", "false").unwrap();
        c.set("duration_ms", "25").unwrap();
        assert_eq!(c.n_cns, 5);
        assert_eq!(c.isolation, Isolation::SnapshotIsolation);
        assert!(!c.features.vt_cache);
        assert_eq!(c.duration_ns, 25_000_000);
        assert!(c.set("nonsense", "1").is_err());
        assert!(c.set("n_cns", "abc").is_err());
    }

    #[test]
    fn config_file_parsing() {
        let mut c = Config::small();
        c.load_overrides("# comment\n n_mns = 4 \n\nseed=7 # trailing\n")
            .unwrap();
        assert_eq!(c.n_mns, 4);
        assert_eq!(c.seed, 7);
        assert!(c.load_overrides("not-an-assignment").is_err());
    }

    #[test]
    fn system_kind_parse() {
        assert_eq!(SystemKind::parse("lotus").unwrap(), SystemKind::Lotus);
        assert_eq!(SystemKind::parse("Motor").unwrap(), SystemKind::Motor);
        assert_eq!(SystemKind::parse("ford-nocas").unwrap(), SystemKind::FordNoCas);
        assert!(SystemKind::parse("mystery").is_err());
        for k in [
            SystemKind::Lotus,
            SystemKind::Motor,
            SystemKind::Ford,
            SystemKind::MotorNoCas,
            SystemKind::FordNoCas,
            SystemKind::IdealLock,
        ] {
            assert_eq!(SystemKind::parse(k.name()).unwrap(), k);
        }
    }
}
