//! The cluster harness: builds a simulated DM cluster and runs timed
//! benchmarks on it.
//!
//! [`Cluster::build`] wires MNs, CN NICs, the RPC fabric, the routing
//! layer, lock services, caches, DB tables (replicated per the config)
//! and bulk-loads the chosen workload. [`Cluster::run`] spawns one OS
//! thread per coordinator; each thread executes transactions in **virtual
//! time** (see [`crate::dm::clock`]), kept within a bounded skew window
//! by a [`TimeGate`] so contention between coordinators is faithful.
//!
//! The same harness drives LOTUS and every baseline
//! ([`crate::config::SystemKind`]), the two-level load balancer (L2/L1
//! artifact via PJRT when the compiled topology matches, rust mirror
//! otherwise), and fail-stop crash injection for the fig. 15 recovery
//! timeline.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::balance::planner::{Planner, RustPlanner, XlaPlanner};
use crate::balance::BalanceMetrics;
use crate::baselines::{ford, ideal_rdma_lock, motor, nolock, BaselineCoordinator};
use crate::cache::{AddrCache, VtCache};
use crate::config::{Config, SystemKind};
use crate::dm::clock::{TimeGate, VClock};
use crate::dm::memnode::MemNode;
use crate::dm::rnic::Rnic;
use crate::dm::rpc::RpcFabric;
use crate::dm::verbs::Endpoint;
use crate::lock::service::LockService;
use crate::metrics::{Histogram, RunReport, TxnStats};
use crate::recovery::membership::Membership;
use crate::recovery::recovery::recover_cn_failure;
use crate::sharding::key::N_SHARDS;
use crate::sharding::resharding::transfer_shard;
use crate::sharding::router::Router;
use crate::store::index::{TableSpec, TableStore};
use crate::txn::api::TxnApi;
use crate::txn::coordinator::{LotusCoordinator, SharedCluster};
use crate::txn::doomed::DoomedSet;
use crate::txn::log;
use crate::txn::scheduler::{FrameScheduler, LaneOutcome};
use crate::txn::step::expect_ready;
use crate::txn::timestamp::TimestampOracle;
use crate::workloads::{RouteCtx, Workload, WorkloadKind};
use crate::{Error, Result};

pub mod crashsweep;

/// Failure-detection lease (virtual ns) used by the crash harness.
pub const LEASE_NS: u64 = 5_000_000; // 5 ms
/// Extra virtual time a restarted CN spends re-registering MRs + QPs.
pub const RESTART_EXTRA_NS: u64 = 20_000_000; // 20 ms

/// A fail-stop crash injection (fig. 15).
#[derive(Debug, Clone)]
pub struct CrashEvent {
    /// Virtual time of the crash.
    pub at_ns: u64,
    /// CNs that fail simultaneously.
    pub cns: Vec<usize>,
}

/// A lease-suspicion window (ISSUE 7): `cn` is *suspected* (not failed)
/// over `[from_ns, until_ns)` — observers degrade gracefully (the lock
/// phase proactively aborts against it) and the CN rejoins by outliving
/// the window, with no lock rebuild.
#[derive(Debug, Clone)]
pub struct SuspicionWindow {
    /// The suspected CN.
    pub cn: usize,
    /// Window start (virtual ns, inclusive).
    pub from_ns: u64,
    /// Window end (virtual ns, exclusive).
    pub until_ns: u64,
}

/// A full deterministic fault scenario (ISSUE 7): fail-stop crash storms,
/// seeded message-level faults (drops / delays / gray slowdowns /
/// partitions, all pure functions of the message coordinates), and timed
/// suspicion windows. The same script against the same seed yields a
/// byte-identical [`RunReport`] — every fault decision is installed up
/// front and evaluated in virtual time, never toggled mid-run.
#[derive(Debug, Clone, Default)]
pub struct FaultScript {
    /// Fail-stop crash events (possibly staggered — a chaos storm).
    pub crashes: Vec<CrashEvent>,
    /// Seeded message-fault injector consulted by the RPC fabric.
    pub faults: Option<Arc<crate::dm::faults::FaultInjector>>,
    /// Lease-suspicion windows installed at run start.
    pub suspicions: Vec<SuspicionWindow>,
}

/// A built cluster, ready to run benchmarks.
pub struct Cluster {
    /// Shared state.
    pub shared: Arc<SharedCluster>,
    /// The loaded workload.
    pub workload: Arc<dyn Workload>,
}

impl Cluster {
    /// Build the shared cluster state for `specs` (no workload data).
    pub fn build_shared(cfg: &Config, specs: Vec<TableSpec>) -> Result<Arc<SharedCluster>> {
        let cfg = cfg.clone().validate()?;
        let net = Arc::new(cfg.net.clone());
        let mns: Vec<Arc<MemNode>> = (0..cfg.n_mns)
            .map(|i| Arc::new(MemNode::new(i, cfg.mn_capacity)))
            .collect();
        let cn_nics: Vec<Arc<Rnic>> = (0..cfg.n_cns).map(|_| Arc::new(Rnic::new())).collect();
        let rpc = Arc::new(RpcFabric::new(
            cn_nics.clone(),
            cfg.coordinators_per_cn,
            net.clone(),
        ));
        let router = Arc::new(Router::new(cfg.n_cns));
        let vt_caches: Vec<Arc<VtCache>> = (0..cfg.n_cns)
            .map(|_| Arc::new(VtCache::new(cfg.vt_cache_entries)))
            .collect();
        let addr_caches: Vec<Arc<AddrCache>> =
            (0..cfg.n_cns).map(|_| Arc::new(AddrCache::new())).collect();
        let lock_services: Vec<Arc<LockService>> = (0..cfg.n_cns)
            .map(|cn| {
                Arc::new(LockService::new(
                    cn,
                    cfg.lock_table_bytes,
                    vt_caches[cn].clone(),
                ))
            })
            .collect();
        // Tables: MVCC geometry from the config; replicas round-robin
        // over MNs starting at the table id (primary first).
        let mut tables = Vec::with_capacity(specs.len());
        let mut baseline_lock_bases = Vec::with_capacity(specs.len());
        for (ti, mut spec) in specs.into_iter().enumerate() {
            debug_assert_eq!(ti, spec.id as usize, "table ids must be dense");
            spec.ncells = cfg.n_versions;
            spec.assoc = cfg.assoc;
            let replica_mns: Vec<usize> = (0..cfg.replicas)
                .map(|r| (spec.id as usize + r) % cfg.n_mns)
                .collect();
            let table = TableStore::create(spec, &mns, &replica_mns)?;
            // Baseline MN-side lock words: one per CVT slot + one per
            // bucket, on the primary MN.
            let lock_words =
                table.layout.n_buckets * table.spec.assoc as u64 + table.layout.n_buckets;
            let region = mns[table.primary().mn].register(lock_words * 8)?;
            baseline_lock_bases.push(region.base);
            tables.push(Arc::new(table));
        }
        // Per-coordinator commit-log slots, spread over MNs.
        let total = cfg.total_coordinators();
        let mut log_slots = Vec::with_capacity(total);
        for gid in 0..total {
            let mn = gid % cfg.n_mns;
            let region = mns[mn].register(log::slot_size())?;
            log_slots.push((mn, region.base));
        }
        let n_cns = cfg.n_cns;
        Ok(Arc::new(SharedCluster {
            cfg,
            mns,
            cn_nics,
            rpc,
            router,
            oracle: Arc::new(TimestampOracle::new()),
            net,
            lock_services,
            vt_caches,
            addr_caches,
            tables,
            doomed: Arc::new(DoomedSet::new()),
            metrics: Arc::new(BalanceMetrics::new(n_cns)),
            membership: Arc::new(Membership::new(n_cns, LEASE_NS)),
            log_slots,
            baseline_lock_bases,
            doorbell_faults: Arc::new(crate::dm::FaultsCell::new()),
            ring_trace: crate::audit::RingTrace::default(),
            recovery_reports: Mutex::new(Vec::new()),
            txn_counter: AtomicU64::new(0),
        }))
    }

    /// Build a cluster and bulk-load `kind`'s dataset.
    pub fn build(cfg: &Config, kind: WorkloadKind) -> Result<Cluster> {
        let workload = kind.instantiate(cfg);
        Self::build_with(cfg, workload)
    }

    /// Build with an explicit workload instance.
    pub fn build_with(cfg: &Config, workload: Arc<dyn Workload>) -> Result<Cluster> {
        let shared = Self::build_shared(cfg, workload.table_specs())?;
        workload.load(&shared)?;
        Ok(Cluster { shared, workload })
    }

    /// Run a timed benchmark of `system` on this cluster.
    pub fn run(&self, system: SystemKind) -> Result<RunReport> {
        self.run_with_events(system, &[])
    }

    /// Run with fail-stop crash injections (fig. 15).
    pub fn run_with_events(&self, system: SystemKind, events: &[CrashEvent]) -> Result<RunReport> {
        self.run_with_faults(
            system,
            &FaultScript {
                crashes: events.to_vec(),
                ..FaultScript::default()
            },
        )
    }

    /// Run a full deterministic fault scenario: crash storms, seeded
    /// message faults, and suspicion windows (ISSUE 7). The injector and
    /// suspicion windows are installed before the first transaction and
    /// cleared afterwards, so later runs on the same cluster are clean.
    pub fn run_with_faults(&self, system: SystemKind, script: &FaultScript) -> Result<RunReport> {
        let events: &[CrashEvent] = &script.crashes;
        // Each run restarts virtual time at zero: drain the fabric queues
        // left by any previous run on this cluster.
        for mn in &self.shared.mns {
            mn.rnic.reset();
        }
        for nic in &self.shared.cn_nics {
            nic.reset();
        }
        self.shared.rpc.reset_queues();
        self.shared.rpc.set_faults(script.faults.clone());
        // The same injector governs both planes: RPC messages (above)
        // and one-sided doorbell rings (PR 8). Installing `None` keeps
        // the doorbell path byte-inert.
        self.shared.doorbell_faults.install(script.faults.clone());
        self.shared.recovery_reports.lock().unwrap().clear();
        for s in &script.suspicions {
            self.shared.membership.suspect(s.cn, s.from_ns, s.until_ns);
        }
        let cfg = &self.shared.cfg;
        let total = cfg.total_coordinators();
        let gate = Arc::new(
            TimeGate::new(total, cfg.gate_window_ns).with_publish(cfg.gate_publish_ns),
        );
        let hist = Arc::new(Histogram::new());
        let stats = Arc::new(TxnStats::default());
        let fatal: Arc<Mutex<Option<Error>>> = Arc::new(Mutex::new(None));
        let timeline_n = if cfg.timeline_interval_ns > 0 {
            (cfg.duration_ns / cfg.timeline_interval_ns + 1) as usize
        } else {
            0
        };
        let timeline: Arc<Vec<AtomicU64>> =
            Arc::new((0..timeline_n).map(|_| AtomicU64::new(0)).collect());
        let run = Arc::new(RunCtl {
            events: events.to_vec(),
            triggered: (0..events.len()).map(|_| AtomicBool::new(false)).collect(),
            recovered: (0..events.len()).map(|_| AtomicBool::new(false)).collect(),
            restart_at: (0..events.len()).map(|_| AtomicU64::new(u64::MAX)).collect(),
            last_interval: (0..cfg.n_cns).map(|_| AtomicU64::new(0)).collect(),
        });

        std::thread::scope(|scope| {
            for gid in 0..total {
                let shared = self.shared.clone();
                let workload = self.workload.clone();
                let gate = gate.clone();
                let hist = hist.clone();
                let stats = stats.clone();
                let fatal = fatal.clone();
                let timeline = timeline.clone();
                let run = run.clone();
                scope.spawn(move || {
                    let res = coordinator_thread(
                        shared, workload, system, gid, gate, hist, stats, timeline, run,
                    );
                    if let Err(e) = res {
                        let mut f = fatal.lock().unwrap();
                        if f.is_none() {
                            *f = Some(e);
                        }
                    }
                });
            }
        });
        // The script's faults and suspicions end with the run: clear them
        // so later runs on this cluster start clean.
        self.shared.rpc.set_faults(None);
        self.shared.doorbell_faults.install(None);
        for s in &script.suspicions {
            self.shared.membership.clear_suspicion(s.cn);
        }
        if let Some(e) = fatal.lock().unwrap().take() {
            return Err(e);
        }
        if std::env::var("LOTUS_FABRIC_STATS").is_ok() {
            for mn in &self.shared.mns {
                eprintln!(
                    "mn{} rnic: ops={} busy={}ns wait={}ns busy_until={}ns util={:.2}",
                    mn.id,
                    mn.rnic.op_count(),
                    mn.rnic.busy_ns(),
                    mn.rnic.wait_ns(),
                    mn.rnic.busy_until(),
                    mn.rnic.utilization(cfg.duration_ns)
                );
            }
            for (i, nic) in self.shared.cn_nics.iter().enumerate() {
                eprintln!(
                    "cn{i} nic: ops={} busy={}ns wait={}ns util={:.2} doorbells={} db_ops={} coalesced={} staged={} inflight_hwm={} overlap_rings={} overlap_plans={} resumed_rings={} resumed_plans={} ring_gap={}ns rpc_msgs={} rpc_reqs={} coalesced_rpc={} lock_waits={} lock_wait={}ns handler_chunks={} handler_wait={}ns rpc_retries={} rpc_dropped={} backoff={}ns false_susp={} degraded_aborts={} mn_op_faults={} torn_batches={} reshard_moves={} reshard_aborted={} reshard_interruption={}ns wrong_owner_bounces={} mean_handler_wait={:.0}ns",
                    nic.op_count(),
                    nic.busy_ns(),
                    nic.wait_ns(),
                    nic.utilization(cfg.duration_ns),
                    nic.doorbells(),
                    nic.doorbell_ops(),
                    nic.coalesced_ops(),
                    nic.staged_plans(),
                    nic.posted_wqes_hwm(),
                    nic.overlap_rings(),
                    nic.overlap_plans(),
                    nic.resumed_rings(),
                    nic.resumed_plans(),
                    nic.ring_gap_ns(),
                    nic.rpc_messages(),
                    nic.rpc_reqs(),
                    nic.coalesced_rpc_reqs(),
                    nic.lock_waits(),
                    nic.lock_wait_ns(),
                    nic.handler_chunks(),
                    nic.handler_wait_ns(),
                    nic.rpc_retries(),
                    nic.rpc_dropped(),
                    nic.backoff_ns(),
                    nic.false_suspicions(),
                    nic.degraded_aborts(),
                    nic.mn_op_faults(),
                    nic.torn_batches(),
                    nic.reshard_moves(),
                    nic.reshard_aborted_txns(),
                    nic.reshard_interruption_ns(),
                    nic.wrong_owner_bounces(),
                    self.shared.rpc.mean_handler_wait_ns(i)
                );
            }
            eprintln!(
                "rpc fabric: handler_wait_p99={}ns",
                self.shared.rpc.handler_wait_p99_ns()
            );
        }
        let mut reasons = std::collections::HashMap::new();
        for (k, v) in stats.reasons.lock().unwrap().iter() {
            reasons.insert(k.to_string(), *v);
        }
        // One-sided doorbell + in-flight accounting lives on the CN NICs
        // (reset at the top of the run, so the sums are per-run).
        let (mut doorbells, mut doorbell_ops, mut coalesced_ops) = (0u64, 0u64, 0u64);
        let (mut staged_plans, mut overlap_rings, mut overlap_plans) = (0u64, 0u64, 0u64);
        let (mut resumed_rings, mut resumed_plans, mut ring_gap_ns) = (0u64, 0u64, 0u64);
        let (mut rpc_messages, mut rpc_reqs, mut coalesced_rpc_reqs) = (0u64, 0u64, 0u64);
        let (mut lock_waits, mut lock_wait_ns) = (0u64, 0u64);
        let (mut handler_wait_ns, mut handler_chunks) = (0u64, 0u64);
        let (mut rpc_retries, mut rpc_dropped, mut backoff_ns) = (0u64, 0u64, 0u64);
        let (mut false_suspicions, mut degraded_aborts) = (0u64, 0u64);
        let (mut mn_op_faults, mut torn_batches) = (0u64, 0u64);
        let (mut reshard_moves, mut reshard_aborted_txns) = (0u64, 0u64);
        let (mut reshard_interruption_ns, mut wrong_owner_bounces) = (0u64, 0u64);
        let mut inflight_wqes_hwm = 0u64;
        for nic in &self.shared.cn_nics {
            doorbells += nic.doorbells();
            doorbell_ops += nic.doorbell_ops();
            coalesced_ops += nic.coalesced_ops();
            staged_plans += nic.staged_plans();
            overlap_rings += nic.overlap_rings();
            overlap_plans += nic.overlap_plans();
            resumed_rings += nic.resumed_rings();
            resumed_plans += nic.resumed_plans();
            ring_gap_ns += nic.ring_gap_ns();
            rpc_messages += nic.rpc_messages();
            rpc_reqs += nic.rpc_reqs();
            coalesced_rpc_reqs += nic.coalesced_rpc_reqs();
            lock_waits += nic.lock_waits();
            lock_wait_ns += nic.lock_wait_ns();
            handler_wait_ns += nic.handler_wait_ns();
            handler_chunks += nic.handler_chunks();
            rpc_retries += nic.rpc_retries();
            rpc_dropped += nic.rpc_dropped();
            backoff_ns += nic.backoff_ns();
            false_suspicions += nic.false_suspicions();
            degraded_aborts += nic.degraded_aborts();
            mn_op_faults += nic.mn_op_faults();
            torn_batches += nic.torn_batches();
            reshard_moves += nic.reshard_moves();
            reshard_aborted_txns += nic.reshard_aborted_txns();
            reshard_interruption_ns += nic.reshard_interruption_ns();
            wrong_owner_bounces += nic.wrong_owner_bounces();
            inflight_wqes_hwm = inflight_wqes_hwm.max(nic.posted_wqes_hwm());
        }
        Ok(RunReport {
            commits: stats.commits.load(Ordering::Relaxed),
            aborts: stats.aborts.load(Ordering::Relaxed),
            duration_ns: cfg.duration_ns,
            p50_ns: hist.p50(),
            p99_ns: hist.p99(),
            mean_ns: hist.mean(),
            abort_reasons: reasons,
            timeline: timeline.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            timeline_interval_ns: cfg.timeline_interval_ns,
            doorbells,
            doorbell_ops,
            coalesced_ops,
            staged_plans,
            inflight_wqes_hwm,
            overlap_rings,
            overlap_plans,
            resumed_rings,
            resumed_plans,
            ring_gap_ns,
            rpc_messages,
            rpc_reqs,
            coalesced_rpc_reqs,
            lock_waits,
            lock_wait_ns,
            handler_wait_ns,
            handler_chunks,
            handler_wait_p99_ns: self.shared.rpc.handler_wait_p99_ns(),
            rpc_retries,
            rpc_dropped,
            backoff_ns,
            false_suspicions,
            degraded_aborts,
            mn_op_faults,
            torn_batches,
            reshard_moves,
            reshard_aborted_txns,
            reshard_interruption_ns,
            wrong_owner_bounces,
        })
    }

    /// MN memory actually allocated (fig. 16 accounting), per MN.
    pub fn mn_allocated_bytes(&self) -> Vec<u64> {
        self.shared.mns.iter().map(|m| m.allocated()).collect()
    }
}

/// Shared run-loop control state.
struct RunCtl {
    events: Vec<CrashEvent>,
    triggered: Vec<AtomicBool>,
    recovered: Vec<AtomicBool>,
    restart_at: Vec<AtomicU64>,
    last_interval: Vec<AtomicU64>,
}

/// How a coordinator thread drives transactions: the sequential
/// [`TxnApi`] shell (all baselines; LOTUS with `pipeline_depth = 0`,
/// kept as the equivalence baseline for the scheduler), or the pipelined
/// [`FrameScheduler`] running `pipeline_depth` lanes.
enum Driver {
    Seq(Box<dyn TxnApi>),
    Pipe(FrameScheduler),
}

impl Driver {
    /// The thread's virtual frontier (slowest lane for the scheduler).
    fn now(&self) -> u64 {
        match self {
            Driver::Seq(api) => api.now(),
            Driver::Pipe(s) => s.now(),
        }
    }

    fn attach_gate(&mut self, gate: Arc<TimeGate>, gid: usize) {
        match self {
            Driver::Seq(api) => api.attach_gate(gate, gid),
            Driver::Pipe(s) => s.attach_gate(gate, gid),
        }
    }

    fn crash(&mut self) {
        match self {
            Driver::Seq(api) => api.crash(),
            Driver::Pipe(s) => s.crash(),
        }
    }

    fn skip_to(&mut self, t_ns: u64) {
        match self {
            Driver::Seq(api) => api.skip_to(t_ns),
            Driver::Pipe(s) => s.skip_to(t_ns),
        }
    }

    /// Pump the ready-queue event loop until at least one transaction
    /// completes (the scheduler may resume lane machines parked by
    /// earlier steps and park new ones), appending every finished
    /// transaction's [`LaneOutcome`] to `out`; the returned `Err` is a
    /// fatal (run-ending) error only.
    fn step(
        &mut self,
        workload: &Arc<dyn Workload>,
        route: &RouteCtx<'_>,
        out: &mut Vec<LaneOutcome>,
    ) -> Result<()> {
        match self {
            Driver::Seq(api) => {
                let t0 = api.now();
                // Sequential conduit: the transaction machine never
                // parks, one poll runs it end to end.
                let res = expect_ready(workload.run_one(api.as_mut(), route));
                let t1 = api.now();
                match res {
                    Err(e) if !(e.is_abort() || matches!(e, Error::NodeUnavailable(_))) => Err(e),
                    r => {
                        out.push(LaneOutcome {
                            lane: 0,
                            t_begin: t0,
                            t_end: t1,
                            result: r,
                        });
                        Ok(())
                    }
                }
            }
            Driver::Pipe(s) => s.step(workload, route, out),
        }
    }

    /// Orderly end of run: drain in-flight lane machines to completion
    /// (their outcomes are appended to `out` and accounted like any
    /// other) and ring out any doorbell plans still parked with the
    /// scheduler's coalescer.
    fn finish(&mut self, out: &mut Vec<LaneOutcome>) -> Result<()> {
        match self {
            Driver::Seq(_) => Ok(()),
            Driver::Pipe(s) => s.finish(out),
        }
    }
}

/// The balancer planner lives on the thread that runs it (the PJRT
/// executable is not `Send`).
fn make_planner(cfg: &Config, system: SystemKind) -> Option<Box<dyn Planner>> {
    if system != SystemKind::Lotus || !cfg.features.load_balancing {
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match XlaPlanner::load(&dir, cfg.n_cns, N_SHARDS) {
        Ok(p) => Some(Box::new(p)),
        Err(_) => Some(Box::new(RustPlanner::new(cfg.n_cns, N_SHARDS))),
    }
}

#[allow(clippy::too_many_arguments)]
fn coordinator_thread(
    shared: Arc<SharedCluster>,
    workload: Arc<dyn Workload>,
    system: SystemKind,
    gid: usize,
    gate: Arc<TimeGate>,
    hist: Arc<Histogram>,
    stats: Arc<TxnStats>,
    timeline: Arc<Vec<AtomicU64>>,
    run: Arc<RunCtl>,
) -> Result<()> {
    let cfg = shared.cfg.clone();
    let cn = gid / cfg.coordinators_per_cn;
    let slot = gid % cfg.coordinators_per_cn;
    let mut driver: Driver = match system {
        // LOTUS runs the pipelined frame scheduler (`pipeline_depth`
        // lanes per thread); depth 0 selects the legacy sequential shell,
        // kept as the exact-accounting baseline the depth-1 scheduler is
        // tested against.
        SystemKind::Lotus if cfg.pipeline_depth >= 1 => {
            Driver::Pipe(FrameScheduler::new(shared.clone(), cn, slot, gid))
        }
        SystemKind::Lotus => {
            Driver::Seq(Box::new(LotusCoordinator::new(shared.clone(), cn, slot, gid)))
        }
        SystemKind::Motor => Driver::Seq(Box::new(BaselineCoordinator::new(
            shared.clone(),
            cn,
            gid,
            motor::style(),
        ))),
        SystemKind::Ford => Driver::Seq(Box::new(BaselineCoordinator::new(
            shared.clone(),
            cn,
            gid,
            ford::style(),
        ))),
        SystemKind::MotorFullRecord => Driver::Seq(Box::new(BaselineCoordinator::new(
            shared.clone(),
            cn,
            gid,
            motor::full_record_style(),
        ))),
        SystemKind::MotorNoCas => Driver::Seq(Box::new(BaselineCoordinator::new(
            shared.clone(),
            cn,
            gid,
            nolock::motor_nocas_style(),
        ))),
        SystemKind::FordNoCas => Driver::Seq(Box::new(BaselineCoordinator::new(
            shared.clone(),
            cn,
            gid,
            nolock::ford_nocas_style(),
        ))),
        SystemKind::IdealLock => Driver::Seq(Box::new(BaselineCoordinator::new(
            shared.clone(),
            cn,
            gid,
            ideal_rdma_lock::style(),
        ))),
    };
    driver.attach_gate(gate.clone(), gid);
    let hybrid = system == SystemKind::Lotus && cfg.features.load_balancing;
    let mut balancer = if slot == 0 && gid == 0 {
        make_planner(&cfg, system).map(|planner| {
            (
                planner,
                vec![0f32; cfg.n_cns * N_SHARDS],
                vec![0f32; cfg.n_cns * crate::balance::metrics::N_INTERVALS],
            )
        })
    } else {
        None
    };

    let mut outcomes: Vec<LaneOutcome> = Vec::new();
    loop {
        let now = driver.now();
        if now >= cfg.duration_ns {
            break;
        }
        // Epoch-batched: per `gate_publish_ns` of virtual progress, not
        // per step (ISSUE 9); with the default 0 every step publishes.
        gate.publish(gid, now);

        // --- Crash events. ---
        for (k, ev) in run.events.iter().enumerate() {
            if now >= ev.at_ns && !run.triggered[k].load(Ordering::Acquire) {
                if run.triggered[k]
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    for &c in &ev.cns {
                        shared.membership.fail(c, ev.at_ns);
                        shared.rpc.set_failed(c, true);
                    }
                }
            }
            // Recovery driver: lowest surviving coordinator past the lease.
            if run.triggered[k].load(Ordering::Acquire)
                && !ev.cns.contains(&cn)
                && now >= ev.at_ns + LEASE_NS
                && !run.recovered[k].load(Ordering::Acquire)
                && run.recovered[k]
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                let ep = Endpoint::new(cn, shared.cn_nics[cn].clone(), shared.net.clone())
                    .with_faults(shared.doorbell_faults.clone());
                let mut rclk = VClock(ev.at_ns + LEASE_NS);
                let report = recover_cn_failure(&shared, &ev.cns, &ep, &mut rclk)?;
                shared.recovery_reports.lock().unwrap().push(report);
                let restart = rclk.now() + RESTART_EXTRA_NS;
                run.restart_at[k].store(restart, Ordering::Release);
                for &c in &ev.cns {
                    shared.membership.begin_restart(c, rclk.now());
                    shared.rpc.set_failed(c, false);
                    shared.membership.complete_restart(c, restart);
                }
            }
            // Crashed CN: park until restart.
            if run.triggered[k].load(Ordering::Acquire) && ev.cns.contains(&cn) && now >= ev.at_ns
            {
                let restart = run.restart_at[k].load(Ordering::Acquire);
                if restart == u64::MAX || now < restart {
                    driver.crash();
                    gate.finish(gid);
                    loop {
                        let r = run.restart_at[k].load(Ordering::Acquire);
                        if r != u64::MAX {
                            driver.skip_to(r);
                            break;
                        }
                        if gate.min_clock() == u64::MAX {
                            // Every live coordinator finished before the
                            // recovery driver ran — end the run.
                            return Ok(());
                        }
                        std::thread::yield_now();
                    }
                }
            }
        }

        // --- Load-balancer interval duties (slot 0 of each CN). ---
        if slot == 0 && cfg.balance_interval_ns > 0 {
            let interval = now / cfg.balance_interval_ns;
            let last = run.last_interval[cn].load(Ordering::Acquire);
            if interval > last
                && run.last_interval[cn]
                    .compare_exchange(last, interval, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                shared.metrics.seal_interval(cn);
                if let Some((planner, counts, lat)) = balancer.as_mut() {
                    shared.metrics.drain_counts(counts);
                    shared.metrics.latency_matrix(lat);
                    if let Ok(plan) = planner.plan(counts, lat) {
                        // Bounded move execution (ISSUE 10): at most
                        // `max_moves_per_tick` transfers charge this
                        // interval's clock floor (0 = the whole plan, the
                        // legacy one-jump behavior). The rest of the plan
                        // is dropped, not queued — the next sealed
                        // interval re-plans from fresh counts, so a
                        // persistent imbalance keeps moving one bounded
                        // step at a time.
                        let mut executed = 0usize;
                        for (shard, from, to) in plan.moves() {
                            if cfg.max_moves_per_tick > 0 && executed >= cfg.max_moves_per_tick {
                                break;
                            }
                            if shared.router.owner_of(shard) == from
                                && shared.membership.is_serving(from)
                                && shared.membership.is_serving(to)
                            {
                                let mut clk = VClock(driver.now());
                                if let Ok(rep) = transfer_shard(&shared, shard, from, to, &mut clk)
                                {
                                    shared.cn_nics[cn].note_reshard_move(
                                        rep.aborted_txns as u64,
                                        rep.interruption_ns,
                                    );
                                    executed += 1;
                                }
                                driver.skip_to(clk.now());
                            }
                        }
                    }
                }
            }
        }

        // --- One pump of the ready-queue event loop (lane machines may
        // park at issue points and resume in later steps); account every
        // completed transaction. ---
        let route = RouteCtx {
            router: &shared.router,
            cn,
            hybrid,
        };
        outcomes.clear();
        if let Err(e) = driver.step(&workload, &route, &mut outcomes) {
            gate.finish(gid);
            return Err(e);
        }
        if let Err(e) = account(&mut outcomes, &stats, &hist, &shared, cn, &cfg, &timeline) {
            gate.finish(gid);
            return Err(e);
        }
    }
    // Orderly shutdown: in-flight lane machines run to completion and
    // their transactions are accounted like any other.
    outcomes.clear();
    let fin = driver
        .finish(&mut outcomes)
        .and_then(|()| account(&mut outcomes, &stats, &hist, &shared, cn, &cfg, &timeline));
    gate.finish(gid);
    fin
}

/// Fold a batch of completed transactions into the run statistics
/// (draining the batch). A fatal error ends the run immediately.
fn account(
    outcomes: &mut Vec<LaneOutcome>,
    stats: &TxnStats,
    hist: &Histogram,
    shared: &SharedCluster,
    cn: usize,
    cfg: &Config,
    timeline: &[AtomicU64],
) -> Result<()> {
    for o in outcomes.drain(..) {
        let (t0, t1) = (o.t_begin, o.t_end);
        match o.result {
            Ok(()) => {
                stats.commit();
                hist.record(t1 - t0);
                shared.metrics.record_latency(cn, t1 - t0);
                if cfg.timeline_interval_ns > 0 {
                    let bucket = (t1 / cfg.timeline_interval_ns) as usize;
                    if bucket < timeline.len() {
                        timeline[bucket].fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            Err(e) if e.is_abort() => {
                stats.abort(e.abort_reason().unwrap());
            }
            Err(Error::NodeUnavailable(_)) => {
                stats.abort(crate::AbortReason::OwnerFailed);
            }
            Err(e) => {
                return Err(e);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> Config {
        let mut cfg = Config::small();
        cfg.duration_ns = 3_000_000; // 3 ms virtual
        cfg.scale.kvs_keys = 2_000;
        cfg.scale.smallbank_accounts = 2_000;
        // CI matrix hook: pipeline_depth x coalesce_window_ns overrides.
        cfg.apply_test_env();
        cfg
    }

    #[test]
    fn lotus_kvs_end_to_end() {
        let cfg = tiny_cfg();
        let cluster = Cluster::build(
            &cfg,
            WorkloadKind::Kvs {
                rw_pct: 50,
                skewed: true,
            },
        )
        .unwrap();
        let report = cluster.run(SystemKind::Lotus).unwrap();
        assert!(report.commits > 100, "commits={}", report.commits);
        assert!(report.p50_ns > 0);
        // All locks must be free after the run.
        let held: usize = cluster
            .shared
            .lock_services
            .iter()
            .map(|s| s.held_slots())
            .sum();
        assert_eq!(held, 0);
    }

    #[test]
    fn all_systems_run_smallbank() {
        let cfg = tiny_cfg();
        let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
        for system in [
            SystemKind::Lotus,
            SystemKind::Motor,
            SystemKind::Ford,
            SystemKind::MotorNoCas,
            SystemKind::FordNoCas,
            SystemKind::IdealLock,
        ] {
            let report = cluster.run(system).unwrap();
            assert!(
                report.commits > 50,
                "{}: commits={}",
                system.name(),
                report.commits
            );
        }
    }

    #[test]
    fn lotus_beats_motor_on_smallbank() {
        // The headline claim: lock disaggregation wins on the write-heavy,
        // small-record benchmark — once concurrency saturates the MN RNIC
        // atomics pipeline (the fig. 2 knee); below it the systems tie.
        let mut cfg = tiny_cfg();
        cfg.duration_ns = 5_000_000;
        cfg.n_cns = 3; // pinned: the knee needs 24 concurrent over 2 MNs
        cfg.coordinators_per_cn = 8;
        cfg.balance_interval_ns = 100_000_000; // pinned: no mid-run transfers in the margin

        let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
        let lotus = cluster.run(SystemKind::Lotus).unwrap();
        let motor = cluster.run(SystemKind::Motor).unwrap();
        assert!(
            lotus.mtps() > motor.mtps(),
            "lotus {:.3} vs motor {:.3} Mtps",
            lotus.mtps(),
            motor.mtps()
        );
    }

    #[test]
    fn pipeline_depth_one_matches_legacy_sequential_exactly() {
        // The depth-1 scheduler must reproduce the sequential
        // coordinator's commit/abort accounting exactly. A 1-CN,
        // 1-coordinator topology makes the run fully deterministic
        // (single thread, same RNG stream, same oracle order).
        let mut cfg = tiny_cfg();
        cfg.n_cns = 1;
        cfg.coordinators_per_cn = 1;
        cfg.duration_ns = 2_000_000;
        cfg.balance_interval_ns = 100_000_000; // pinned: armed rebalance races the planner
        let run = |depth: usize| {
            let mut c = cfg.clone();
            c.pipeline_depth = depth;
            let cluster = Cluster::build(&c, WorkloadKind::SmallBank).unwrap();
            cluster.run(SystemKind::Lotus).unwrap()
        };
        let legacy = run(0); // the pre-scheduler sequential shell
        let pipe1 = run(1); // one lane through the scheduler
        assert!(legacy.commits > 20, "commits={}", legacy.commits);
        assert_eq!(legacy.commits, pipe1.commits, "commit accounting differs");
        assert_eq!(legacy.aborts, pipe1.aborts, "abort accounting differs");
        assert_eq!(legacy.p50_ns, pipe1.p50_ns, "latency accounting differs");
        assert_eq!(legacy.p99_ns, pipe1.p99_ns, "tail accounting differs");
        assert_eq!(legacy.doorbells, pipe1.doorbells, "doorbell accounting differs");
        assert_eq!(
            legacy.doorbell_ops, pipe1.doorbell_ops,
            "doorbell op accounting differs"
        );
        // Depth 1 has no siblings: nothing stages, nothing resumes, and
        // neither plane coalesces.
        assert_eq!(pipe1.staged_plans, 0, "depth 1 must not stage plans");
        assert_eq!(pipe1.overlap_rings, 0);
        assert_eq!(pipe1.resumed_rings, 0, "depth 1 must never park a lane");
        assert_eq!(pipe1.resumed_plans, 0);
        assert_eq!(legacy.rpc_messages, pipe1.rpc_messages, "rpc accounting differs");
        assert_eq!(pipe1.coalesced_rpc_reqs, 0, "depth 1 must not merge RPCs");
        assert_eq!(pipe1.lock_waits, 0, "depth 1 has no siblings to wait on");
    }

    #[test]
    fn step_machine_overlaps_staged_plans_at_depth_4() {
        // ISSUE 3 + ISSUE 4: lane machines park at issue points and
        // sibling frames' staged sync plans merge into shared doorbell
        // rings; every ring re-enqueues its parked lanes (resumed_rings)
        // in completion-clock order. By the end of the run every posted
        // WQE must have been rung (the in-flight gauge drains to zero).
        let mut cfg = tiny_cfg();
        cfg.pipeline_depth = 4;
        cfg.coalesce_window_ns = 5_000;
        // The ring-gap bound below assumes the fixed window; the adaptive
        // controller may legitimately hold plans past the base window.
        cfg.adaptive_coalescing = false;
        let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
        let report = cluster.run(SystemKind::Lotus).unwrap();
        assert!(report.commits > 100, "commits={}", report.commits);
        assert!(report.staged_plans > 0, "no plan was ever staged");
        assert!(
            report.overlap_rings > 0,
            "no sibling frames shared a doorbell ring"
        );
        assert!(
            report.overlap_plans >= 2 * report.overlap_rings,
            "an overlap ring carries at least two staged plans: {} rings / {} plans",
            report.overlap_rings,
            report.overlap_plans
        );
        assert!(
            report.inflight_wqes_hwm >= 2,
            "staging never overlapped WQEs in flight (hwm={})",
            report.inflight_wqes_hwm
        );
        assert!(
            report.resumed_rings > 0,
            "no ring ever re-enqueued a parked lane continuation"
        );
        assert_eq!(
            report.resumed_plans, report.staged_plans,
            "every staged plan must be rung by a resume ring in a crash-free run"
        );
        assert!(
            report.mean_overlap_plans() >= 2.0,
            "merged rings should carry >= 2 plans on average: {:.2}",
            report.mean_overlap_plans()
        );
        assert!(
            report.mean_ring_gap_ns() <= cfg.coalesce_window_ns as f64,
            "a staged plan waited past the window: {:.0}ns",
            report.mean_ring_gap_ns()
        );
        for (i, nic) in cluster.shared.cn_nics.iter().enumerate() {
            assert_eq!(
                nic.posted_wqes(),
                0,
                "cn{i}: posted-but-unrung WQEs left at end of run"
            );
        }
    }

    #[test]
    fn deeper_pipeline_scales_throughput_and_coalesces_doorbells() {
        // ISSUE 2 acceptance: depth 4 beats depth 1 by >= 20% virtual
        // throughput on SmallBank at the same cluster config, and rings
        // fewer doorbells per committed transaction (log clears ride
        // sibling frames' doorbells instead of ringing their own).
        let mut cfg = tiny_cfg();
        cfg.duration_ns = 4_000_000;
        cfg.coalesce_window_ns = 5_000;
        // This is the fixed-window acceptance test; the adaptive policy
        // has its own saturation-study coverage in tests/integration.rs.
        cfg.adaptive_coalescing = false;
        cfg.balance_interval_ns = 100_000_000; // pinned: no mid-run transfers in the margin
        let run = |depth: usize| {
            let mut c = cfg.clone();
            c.pipeline_depth = depth;
            let cluster = Cluster::build(&c, WorkloadKind::SmallBank).unwrap();
            cluster.run(SystemKind::Lotus).unwrap()
        };
        let d1 = run(1);
        let d4 = run(4);
        assert!(
            d4.mtps() >= d1.mtps() * 1.2,
            "depth 4 ({:.3} Mtps) must beat depth 1 ({:.3} Mtps) by >= 20%",
            d4.mtps(),
            d1.mtps()
        );
        assert!(
            d4.doorbells_per_commit() < d1.doorbells_per_commit(),
            "coalescing must cut doorbells/txn: d4 {:.2} vs d1 {:.2}",
            d4.doorbells_per_commit(),
            d1.doorbells_per_commit()
        );
        assert!(d4.coalesced_ops > 0, "no ops rode a shared doorbell");
    }

    #[test]
    fn pipelined_run_releases_every_lock_slot() {
        let mut cfg = tiny_cfg();
        cfg.pipeline_depth = 4;
        let cluster = Cluster::build(&cfg, WorkloadKind::SmallBank).unwrap();
        let report = cluster.run(SystemKind::Lotus).unwrap();
        assert!(report.commits > 100, "commits={}", report.commits);
        let held: usize = cluster
            .shared
            .lock_services
            .iter()
            .map(|s| s.held_slots())
            .sum();
        assert_eq!(held, 0, "pipelined lanes must leave no held lock slots");
    }

    #[test]
    fn suspected_but_alive_cn_degrades_and_rejoins_without_lock_rebuild() {
        // ISSUE 7: a lease-suspicion window makes observers degrade
        // gracefully (proactive aborts against the suspect) while the
        // suspected-but-alive CN keeps serving; it rejoins by outliving
        // the window with NO restart, NO epoch bump and NO lock-table
        // clearing — the ephemeral-locks invariant.
        let mut cfg = tiny_cfg();
        cfg.n_cns = 3;
        cfg.duration_ns = 6_000_000;
        let cluster = Cluster::build(
            &cfg,
            WorkloadKind::Kvs {
                rw_pct: 100,
                skewed: false,
            },
        )
        .unwrap();
        let script = FaultScript {
            suspicions: vec![SuspicionWindow {
                cn: 2,
                from_ns: 1_000_000,
                until_ns: 3_000_000,
            }],
            ..FaultScript::default()
        };
        let epoch_before = cluster.shared.membership.epoch(2);
        let report = cluster.run_with_faults(SystemKind::Lotus, &script).unwrap();
        assert!(report.commits > 0);
        assert!(
            report.degraded_aborts > 0,
            "no transaction degraded against the suspect"
        );
        assert_eq!(
            report.false_suspicions, report.degraded_aborts,
            "CN 2 was alive throughout: every degradation was a false suspicion"
        );
        assert_eq!(
            cluster.shared.membership.epoch(2),
            epoch_before,
            "a mere suspicion must not bump the incarnation"
        );
        assert!(cluster.shared.membership.is_serving(2));
        let held: usize = cluster
            .shared
            .lock_services
            .iter()
            .map(|s| s.held_slots())
            .sum();
        assert_eq!(held, 0, "rejoin must not strand or clear lock slots");
        assert!(
            !cluster.shared.membership.is_suspected(2, 2_000_000),
            "the script's suspicion is cleared after the run"
        );
    }

    #[test]
    fn crash_event_dips_and_recovers() {
        let mut cfg = tiny_cfg();
        cfg.n_cns = 3; // pinned: the event crashes CN 2
        cfg.duration_ns = 60_000_000; // 60 ms
        cfg.timeline_interval_ns = 1_000_000; // 1 ms buckets
        let cluster = Cluster::build(
            &cfg,
            WorkloadKind::Kvs {
                rw_pct: 50,
                skewed: false,
            },
        )
        .unwrap();
        let events = [CrashEvent {
            at_ns: 20_000_000,
            cns: vec![2],
        }];
        let report = cluster.run_with_events(SystemKind::Lotus, &events).unwrap();
        assert!(report.commits > 0);
        // Throughput after restart must recover to a similar level.
        let t = &report.timeline;
        let before: u64 = t[5..15].iter().sum();
        let after: u64 = t[45..55].iter().sum();
        assert!(
            after * 3 > before,
            "no recovery: before={before} after={after} timeline={t:?}"
        );
        let held: usize = cluster
            .shared
            .lock_services
            .iter()
            .map(|s| s.held_slots())
            .sum();
        assert_eq!(held, 0, "recovery must leave no stale locks");
    }
}
