//! Exhaustive deterministic crash-point sweep (PR 8).
//!
//! The torn-log seal, the cv-gated recovery classifier, and the
//! doorbell-plane fault machinery each guard one crack in the commit
//! pipeline. This module tests them the only way that generalizes:
//! **crash the coordinator at every issue-point boundary a real run
//! actually visits** and assert the cluster-wide invariants
//! ([`crate::audit::Invariants`]) after recovery, every time.
//!
//! The sweep is three fully deterministic steps:
//!
//! 1. **Reference run** — one seeded transfers-only SmallBank run with
//!    [`RingTrace`](crate::audit::RingTrace) enabled records the
//!    virtual times at which the victim CN stages or completes a
//!    doorbell ring. Those boundaries are exactly where a crash can
//!    tear distributed state (WQEs posted but not rung, rings rung but
//!    lanes not resumed, commit points crossed but sweeps unfinished).
//! 2. **Crash-point enumeration** — the recorded boundaries are
//!    deduplicated, windowed (the crash must leave room for the lease
//!    to expire and recovery to run inside the same run), and evenly
//!    subsampled down to `max_points`.
//! 3. **Per-point crash runs** — for every point `T` the same seeded
//!    run is replayed on a freshly built cluster with a fail-stop
//!    [`CrashEvent`] at `T`; a second variant additionally arms a
//!    100% [`TornBatch`](crate::dm::faults::FaultMode::TornBatch) rule
//!    on the victim's doorbells over the final 60 µs before the crash,
//!    so the log write *in flight at the crash* lands torn. After each
//!    run the invariants are checked against MN-resident bytes.
//!
//! Everything is a pure function of the config seed, so running the
//! sweep twice yields equal [`SweepReport`]s — the determinism the
//! fault fabric (PR 7) and the doorbell plane (PR 8) were built to
//! preserve.
//!
//! The workload is the conserving
//! [`SmallBankWorkload::transfers_only`] mix: with no deposit/withdraw
//! class, `sum(balances)` must equal the initial total at *any* crash
//! point, with no dependence on which in-flight deposits recovery
//! happened to complete.

use std::sync::Arc;

use crate::audit::Invariants;
use crate::config::{Config, SystemKind};
use crate::dm::faults::{FaultInjector, FaultRule};
use crate::sim::{Cluster, CrashEvent, FaultScript, LEASE_NS};
use crate::workloads::smallbank::SmallBankWorkload;
use crate::{Error, Result};

/// Virtual ns of 100%-torn victim doorbells preceding each variant-B
/// crash (wide enough to catch a commit-log write in flight).
const TORN_WINDOW_NS: u64 = 60_000;

/// Sweep shape. The defaults match the acceptance scenario: a depth-4
/// pipelined, 3-CN / 2-MN cluster (from [`Config::small`]).
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Crash points kept after subsampling the reference boundaries.
    pub max_points: usize,
    /// Also run the torn-log variant at every point.
    pub torn_log: bool,
    /// SmallBank accounts (transfers-only mix).
    pub accounts: u64,
    /// Virtual run length; must exceed `window.1 + LEASE_NS` so the
    /// recovery driver fires inside the run for every point.
    pub duration_ns: u64,
    /// The CN the sweep crashes.
    pub crash_cn: usize,
    /// Crash points are drawn from `[window.0, window.1)`.
    pub window: (u64, u64),
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            max_points: 24,
            torn_log: true,
            accounts: 2_000,
            duration_ns: 9_000_000,
            crash_cn: 0,
            window: (200_000, 3_000_000),
        }
    }
}

/// One crash run's post-recovery observations (invariants already
/// passed — a violated invariant aborts the sweep with `Err` instead).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PointOutcome {
    /// The crash time (virtual ns).
    pub t_ns: u64,
    /// Whether the torn-log rule was armed for this run.
    pub torn_log: bool,
    /// Committed / aborted transactions of the run.
    pub commits: u64,
    /// Aborted transactions of the run.
    pub aborts: u64,
    /// Doorbell rings the injector tore (variant B only).
    pub torn_batches: u64,
    /// Log slots recovery discarded for a broken seal.
    pub torn_slots_discarded: usize,
    /// In-flight commits recovery rolled forward.
    pub completed: usize,
    /// In-flight commits recovery rolled back.
    pub rolled_back: usize,
    /// The audited bank total (always the initial total — conserving
    /// mix — but recorded so report equality covers the audit too).
    pub total_balance: u128,
}

/// The full sweep result; `PartialEq` so determinism is one assert.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SweepReport {
    /// The enumerated crash points (virtual ns, ascending).
    pub crash_points: Vec<u64>,
    /// One entry per (point, variant) run, in sweep order.
    pub outcomes: Vec<PointOutcome>,
}

/// The sweep's cluster config: [`Config::small`] pinned to the fixed
/// coalescing window (the adaptive controller is deterministic too,
/// but the fixed window keeps the boundary set stable and readable).
fn sweep_config(opts: &SweepOptions) -> Config {
    let mut cfg = Config::small();
    cfg.duration_ns = opts.duration_ns;
    cfg.adaptive_coalescing = false;
    cfg
}

fn build(cfg: &Config, accounts: u64) -> Result<(Cluster, Arc<SmallBankWorkload>)> {
    let bank = Arc::new(SmallBankWorkload::transfers_only(accounts));
    let cluster = Cluster::build_with(cfg, bank.clone())?;
    Ok((cluster, bank))
}

/// Step 1 + 2: replay the reference run with the ring trace enabled and
/// enumerate the victim CN's issue-point boundaries.
fn collect_crash_points(cfg: &Config, opts: &SweepOptions) -> Result<Vec<u64>> {
    let (cluster, bank) = build(cfg, opts.accounts)?;
    cluster.shared.ring_trace.enable();
    let run = cluster.run(SystemKind::Lotus);
    cluster.shared.ring_trace.disable();
    let points = cluster.shared.ring_trace.take();
    run?;
    // The reference run itself must already satisfy the invariants.
    Invariants::check(&cluster.shared, &bank)
        .map_err(|e| Error::Runtime(format!("reference run fails the audit: {e}")))?;
    let mut pts: Vec<u64> = points
        .into_iter()
        .filter(|&(cn, t)| cn == opts.crash_cn && t >= opts.window.0 && t < opts.window.1)
        .map(|(_, t)| t)
        .collect();
    pts.sort_unstable();
    pts.dedup();
    if pts.len() > opts.max_points {
        // Even subsample across the whole boundary set, ends included.
        let n = pts.len();
        let mut picked: Vec<u64> = (0..opts.max_points)
            .map(|i| pts[i * (n - 1) / (opts.max_points - 1).max(1)])
            .collect();
        picked.dedup();
        pts = picked;
    }
    Ok(pts)
}

/// Step 3: one crash run at `t_ns` (optionally torn-log), audited.
fn run_point(
    cfg: &Config,
    opts: &SweepOptions,
    t_ns: u64,
    torn_log: bool,
) -> Result<PointOutcome> {
    let (cluster, bank) = build(cfg, opts.accounts)?;
    let mut script = FaultScript {
        crashes: vec![CrashEvent {
            at_ns: t_ns,
            cns: vec![opts.crash_cn],
        }],
        ..FaultScript::default()
    };
    if torn_log {
        // Every victim doorbell in the final window before the crash
        // lands torn — including, when the timing is right, the commit
        // log write itself, exercising the seal end to end. The window
        // closes AT the crash, so recovery (at `t_ns + LEASE_NS`) rings
        // clean doorbells.
        script.faults = Some(Arc::new(FaultInjector::new(cfg.seed ^ t_ns).rule(
            FaultRule::torn_batch(1000)
                .from_src(opts.crash_cn)
                .window(t_ns.saturating_sub(TORN_WINDOW_NS), t_ns),
        )));
    }
    let report = cluster.run_with_faults(SystemKind::Lotus, &script)?;
    let audit = Invariants::check(&cluster.shared, &bank).map_err(|e| {
        Error::Runtime(format!(
            "invariant violated after crash at t={t_ns}ns (torn_log={torn_log}): {e}"
        ))
    })?;
    let recs = cluster.shared.recovery_reports.lock().unwrap();
    if recs.is_empty() {
        return Err(Error::Runtime(format!(
            "crash at t={t_ns}ns was never recovered (duration too short?)"
        )));
    }
    Ok(PointOutcome {
        t_ns,
        torn_log,
        commits: report.commits,
        aborts: report.aborts,
        torn_batches: report.torn_batches,
        torn_slots_discarded: recs.iter().map(|r| r.torn_slots_discarded).sum(),
        completed: recs.iter().map(|r| r.completed).sum(),
        rolled_back: recs.iter().map(|r| r.rolled_back).sum(),
        total_balance: audit.total_balance,
    })
}

/// Run the sweep. `Err` means an invariant was violated (the message
/// names the crash point and the failed check) or the harness could
/// not set the sweep up; `Ok` carries every run's observations.
pub fn run_sweep(opts: &SweepOptions) -> Result<SweepReport> {
    if opts.window.1 + LEASE_NS >= opts.duration_ns {
        return Err(Error::Config(format!(
            "sweep window end {} + lease {} must fit inside duration {}",
            opts.window.1, LEASE_NS, opts.duration_ns
        )));
    }
    let cfg = sweep_config(opts);
    let crash_points = collect_crash_points(&cfg, opts)?;
    if crash_points.is_empty() {
        return Err(Error::Runtime(
            "sweep found no issue-point boundaries in the crash window".to_string(),
        ));
    }
    let mut outcomes = Vec::with_capacity(crash_points.len() * 2);
    for &t in &crash_points {
        outcomes.push(run_point(&cfg, opts, t, false)?);
        if opts.torn_log {
            outcomes.push(run_point(&cfg, opts, t, true)?);
        }
    }
    Ok(SweepReport {
        crash_points,
        outcomes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Always-run smoke: two crash points, both variants, invariants
    /// hold and some recovery actually happened across the sweep.
    #[test]
    fn tiny_sweep_holds_invariants_at_every_point() {
        let opts = SweepOptions {
            max_points: 2,
            accounts: 1_000,
            duration_ns: 8_000_000,
            window: (200_000, 2_000_000),
            ..SweepOptions::default()
        };
        let rep = run_sweep(&opts).expect("sweep must pass");
        assert!(!rep.crash_points.is_empty());
        assert_eq!(rep.outcomes.len(), rep.crash_points.len() * 2);
        for o in &rep.outcomes {
            assert!(o.commits > 0, "crash at {} killed the whole run", o.t_ns);
            assert_eq!(
                o.total_balance,
                SmallBankWorkload::initial_total(opts.accounts),
                "transfers-only: the bank total never moves"
            );
        }
        // The torn variants must actually have torn something: the
        // victim rings constantly, and the 60us window tears at 100%.
        let torn: u64 = rep
            .outcomes
            .iter()
            .filter(|o| o.torn_log)
            .map(|o| o.torn_batches)
            .sum();
        assert!(torn > 0, "no doorbell was ever torn across the sweep");
    }

    /// The exhaustive sweep: env-gated (CI runs it as its own leg with
    /// `LOTUS_TEST_CRASH_SWEEP=1`; plain `cargo test` skips it).
    #[test]
    fn exhaustive_sweep_is_deterministic_and_passes() {
        if std::env::var("LOTUS_TEST_CRASH_SWEEP").as_deref() != Ok("1") {
            return;
        }
        let opts = SweepOptions {
            max_points: 12,
            ..SweepOptions::default()
        };
        let rep = run_sweep(&opts).expect("sweep must pass");
        assert!(rep.crash_points.len() >= 8, "too few boundaries enumerated");
        // Determinism: the same seed replays the identical sweep.
        let rep2 = run_sweep(&opts).expect("replay must pass");
        assert_eq!(rep, rep2, "same seed, different sweep");
        // Across a 12-point sweep, recovery must have exercised both
        // directions somewhere, and the torn variant must have torn.
        let completed: usize = rep.outcomes.iter().map(|o| o.completed).sum();
        let rolled: usize = rep.outcomes.iter().map(|o| o.rolled_back).sum();
        assert!(
            completed + rolled > 0,
            "no crash ever caught an in-flight commit"
        );
        let torn: u64 = rep
            .outcomes
            .iter()
            .filter(|o| o.torn_log)
            .map(|o| o.torn_batches)
            .sum();
        assert!(torn > 0, "no doorbell was ever torn across the sweep");
    }
}
