//! Simulated RDMA NIC: a FIFO server in virtual time.
//!
//! Each RNIC processes verbs serially at per-verb service rates; the
//! `busy_until` atomic is the virtual time at which the NIC frees up. A
//! verb arriving at `t_arrive` completes at `max(t_arrive, busy) + svc`,
//! and that completion becomes the new `busy`. This is an M/G/1-style
//! FIFO queue evaluated exactly, and is what makes MN NICs saturate under
//! CAS-heavy lock traffic (the paper's bottleneck).

use std::sync::atomic::{AtomicU64, Ordering};

/// One simulated NIC (MN-side or CN-side).
#[derive(Debug, Default)]
pub struct Rnic {
    busy_until: AtomicU64,
    /// Op counter (for utilization reporting).
    ops: AtomicU64,
    /// Cumulative service ns (for utilization reporting).
    busy_ns: AtomicU64,
    /// Cumulative queue-wait ns experienced by ops (diagnostics).
    wait_ns: AtomicU64,
    /// Doorbells actually rung on this NIC (one PCIe MMIO each).
    doorbells: AtomicU64,
    /// WQEs carried by those doorbells.
    doorbell_ops: AtomicU64,
    /// WQEs that rode a doorbell rung for *another* frame's plan
    /// (cross-transaction coalescing; subset of `doorbell_ops`).
    coalesced_ops: AtomicU64,
    /// WQEs posted to a send queue whose doorbell has not yet been rung
    /// (split-phase post/ring; gauge, returns to 0 when every staged
    /// plan has rung or died with a crashed CN).
    posted_wqes: AtomicU64,
    /// High-water mark of `posted_wqes` — the in-flight depth the
    /// step-machine reached on this NIC.
    posted_wqes_hwm: AtomicU64,
    /// Sync doorbell plans staged in-flight (each is one doorbell-plane
    /// lane park; RPC-plane parks are visible through the `rpc_*`
    /// counters).
    staged_plans: AtomicU64,
    /// Merged doorbell issues that carried >= 2 frames' staged plans.
    overlap_rings: AtomicU64,
    /// Frames' staged plans carried by those merged issues.
    overlap_plans: AtomicU64,
    /// Ring events that completed >= 1 staged plan and re-enqueued its
    /// parked lane into the scheduler's ready queue (the continuation
    /// model's resume events; 0 without staging).
    resumed_rings: AtomicU64,
    /// Staged plans completed by those ring events (parked lanes
    /// resumed).
    resumed_plans: AtomicU64,
    /// Cumulative virtual ns staged plans waited between their post time
    /// and the ring that carried them (`mean = ring_gap_ns /
    /// resumed_plans`).
    ring_gap_ns: AtomicU64,
    /// CN-to-CN RPC messages sent from this CN (one UD SEND each) — the
    /// RPC-plane mirror of `doorbells`.
    rpc_messages: AtomicU64,
    /// Lock-class requests carried by those messages (coalesced riders
    /// included) — the RPC-plane mirror of `doorbell_ops`.
    rpc_reqs: AtomicU64,
    /// Requests that rode an RPC message another lane's lock batch paid
    /// for instead of sending their own (cross-lane RPC coalescing;
    /// subset of `rpc_reqs`, 0 without the pipelined scheduler).
    coalesced_rpc_reqs: AtomicU64,
    /// Lock-wait wakeups: lanes parked at `Flight::WaitLock` behind an
    /// anachronistic sibling holder that were woken by its release.
    lock_waits: AtomicU64,
    /// Cumulative virtual ns between those waiters' park times and the
    /// holding siblings' release times (the anachronism span the waits
    /// bridged).
    lock_wait_ns: AtomicU64,
    /// RPC-handler queueing delay accumulated at *this CN as the
    /// destination*: virtual ns each handled lock batch spent between
    /// arrival at the handler queue and service start (the congestion
    /// signal the adaptive coalescing controller consumes).
    handler_wait_ns: AtomicU64,
    /// Handled lock batches those waits were measured over (one per
    /// per-owner chunk of an RPC message; `mean = handler_wait_ns /
    /// handler_chunks`).
    handler_chunks: AtomicU64,
    /// Lock-phase RPC reissues after a lost/timed-out message (the
    /// retry-with-backoff path; 0 with `rpc_max_retries = 0`).
    rpc_retries: AtomicU64,
    /// RPC messages from this CN lost by the fault injector (sync sends
    /// surface as timeouts at the caller; async sends vanish silently).
    rpc_dropped: AtomicU64,
    /// Cumulative virtual ns lanes spent in retry backoff on this CN.
    backoff_ns: AtomicU64,
    /// Lock-phase degradations where the suspected owner CN was in fact
    /// alive (the false-positive cost of lease-driven suspicion).
    false_suspicions: AtomicU64,
    /// Transactions proactively aborted because their lock owner CN was
    /// under suspicion (the paper's proactive-abort philosophy under
    /// graceful degradation).
    degraded_aborts: AtomicU64,
    /// Doorbell-plane WQEs from this CN affected by an injected MN fault
    /// (unreachable window, ring delay, or torn tail) — the one-sided
    /// mirror of `rpc_dropped`.
    mn_op_faults: AtomicU64,
    /// Doorbell rings from this CN torn by `FaultMode::TornBatch` (only
    /// a WQE prefix landed at the MN).
    torn_batches: AtomicU64,
    /// Shard transfers executed by this CN's balance tick (ISSUE 10).
    reshard_moves: AtomicU64,
    /// Transactions doomed by those transfers (lock holders force-
    /// released while their shard migrated).
    reshard_aborted_txns: AtomicU64,
    /// Cumulative virtual ns of shard-transfer interruption charged by
    /// this CN's balance tick to the coordinator clock floor.
    reshard_interruption_ns: AtomicU64,
    /// Lock acquisitions on this CN bounced with `WrongShardOwner`
    /// while racing a transfer, then retried against the fresh routing
    /// map (the park-and-retry path; a bounce that exhausts its budget
    /// aborts the transaction and still counts here once per attempt).
    wrong_owner_bounces: AtomicU64,
}

impl Rnic {
    /// Fresh idle NIC.
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a verb arriving at `t_arrive` needing `svc` ns of NIC time;
    /// returns its completion time. Linearizable via CAS loop.
    #[inline]
    pub fn charge(&self, t_arrive: u64, svc: u64) -> u64 {
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.busy_ns.fetch_add(svc, Ordering::Relaxed);
        let mut cur = self.busy_until.load(Ordering::Relaxed);
        loop {
            let start = cur.max(t_arrive);
            let done = start + svc;
            match self.busy_until.compare_exchange_weak(
                cur,
                done,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.wait_ns.fetch_add(start - t_arrive, Ordering::Relaxed);
                    return done;
                }
                Err(v) => cur = v,
            }
        }
    }

    /// Cumulative queue-wait ns (diagnostics).
    pub fn wait_ns(&self) -> u64 {
        self.wait_ns.load(Ordering::Relaxed)
    }

    /// Count one doorbell ring carrying `n_ops` WQEs.
    #[inline]
    pub fn ring(&self, n_ops: u64) {
        self.doorbells.fetch_add(1, Ordering::Relaxed);
        self.doorbell_ops.fetch_add(n_ops, Ordering::Relaxed);
    }

    /// Count `n_ops` WQEs that rode an already-rung doorbell instead of
    /// ringing their own (cross-transaction coalescing). They still count
    /// toward `doorbell_ops` — the rung doorbell carried them.
    #[inline]
    pub fn note_coalesced(&self, n_ops: u64) {
        self.doorbell_ops.fetch_add(n_ops, Ordering::Relaxed);
        self.coalesced_ops.fetch_add(n_ops, Ordering::Relaxed);
    }

    /// Count `n_ops` WQEs that joined a doorbell already counted by
    /// [`Rnic::ring`] (merged riders; bumps only the coalesced counter).
    #[inline]
    pub fn note_riders(&self, n_ops: u64) {
        self.coalesced_ops.fetch_add(n_ops, Ordering::Relaxed);
    }

    /// Doorbells rung on this NIC.
    pub fn doorbells(&self) -> u64 {
        self.doorbells.load(Ordering::Relaxed)
    }

    /// WQEs carried by rung doorbells.
    pub fn doorbell_ops(&self) -> u64 {
        self.doorbell_ops.load(Ordering::Relaxed)
    }

    /// WQEs that shared another frame's doorbell.
    pub fn coalesced_ops(&self) -> u64 {
        self.coalesced_ops.load(Ordering::Relaxed)
    }

    /// One staged plan of `n_ops` WQEs was posted to the send queue with
    /// its doorbell deferred (the step-machine's yield point).
    #[inline]
    pub fn note_posted(&self, n_ops: u64) {
        self.staged_plans.fetch_add(1, Ordering::Relaxed);
        let cur = self.posted_wqes.fetch_add(n_ops, Ordering::Relaxed) + n_ops;
        self.posted_wqes_hwm.fetch_max(cur, Ordering::Relaxed);
    }

    /// `n_ops` previously posted WQEs were covered by a doorbell ring (or
    /// died with a crashed CN): drop them from the posted gauge.
    #[inline]
    pub fn note_rung_posted(&self, n_ops: u64) {
        let mut cur = self.posted_wqes.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n_ops);
            match self.posted_wqes.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(v) => cur = v,
            }
        }
    }

    /// A merged doorbell issue carried the staged plans of `n_plans`
    /// distinct in-flight frames (intra-transaction stage overlap).
    #[inline]
    pub fn note_overlap(&self, n_plans: u64) {
        self.overlap_rings.fetch_add(1, Ordering::Relaxed);
        self.overlap_plans.fetch_add(n_plans, Ordering::Relaxed);
    }

    /// A ring event completed `n_plans` staged plans (re-enqueueing their
    /// parked lanes), which together waited `gap_ns` virtual ns between
    /// posting and the ring.
    #[inline]
    pub fn note_resumed(&self, n_plans: u64, gap_ns: u64) {
        self.resumed_rings.fetch_add(1, Ordering::Relaxed);
        self.resumed_plans.fetch_add(n_plans, Ordering::Relaxed);
        self.ring_gap_ns.fetch_add(gap_ns, Ordering::Relaxed);
    }

    /// Count one CN-to-CN RPC message carrying `n_reqs` lock-class
    /// requests (the RPC-plane mirror of [`Rnic::ring`]).
    #[inline]
    pub fn note_rpc_message(&self, n_reqs: u64) {
        self.rpc_messages.fetch_add(1, Ordering::Relaxed);
        self.rpc_reqs.fetch_add(n_reqs, Ordering::Relaxed);
    }

    /// Count `n_reqs` requests that rode an RPC message paid for by
    /// another lane's lock batch (they are already in `rpc_reqs`; this
    /// bumps only the coalescing counter — mirror of [`Rnic::note_riders`]).
    #[inline]
    pub fn note_rpc_riders(&self, n_reqs: u64) {
        self.coalesced_rpc_reqs.fetch_add(n_reqs, Ordering::Relaxed);
    }

    /// Count one lock-wait wakeup whose holder released `gap_ns` virtual
    /// ns after the waiter parked.
    #[inline]
    pub fn note_lock_wait(&self, gap_ns: u64) {
        self.lock_waits.fetch_add(1, Ordering::Relaxed);
        self.lock_wait_ns.fetch_add(gap_ns, Ordering::Relaxed);
    }

    /// Count one handled lock batch that waited `wait_ns` virtual ns in
    /// this CN's RPC-handler queue before its service started (charged to
    /// the *destination* CN's NIC — the CN whose handler CPU is loaded).
    #[inline]
    pub fn note_handler_wait(&self, wait_ns: u64) {
        self.handler_chunks.fetch_add(1, Ordering::Relaxed);
        self.handler_wait_ns.fetch_add(wait_ns, Ordering::Relaxed);
    }

    /// Count one lock-phase RPC reissue (retry after loss/timeout).
    #[inline]
    pub fn note_rpc_retry(&self) {
        self.rpc_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one RPC message lost by the fault injector.
    #[inline]
    pub fn note_rpc_dropped(&self) {
        self.rpc_dropped.fetch_add(1, Ordering::Relaxed);
    }

    /// Charge `ns` virtual ns of retry backoff spent by a lane on this CN.
    #[inline]
    pub fn note_backoff(&self, ns: u64) {
        self.backoff_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Count one degradation against a suspected-but-alive owner CN.
    #[inline]
    pub fn note_false_suspicion(&self) {
        self.false_suspicions.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one proactive abort against a suspected owner CN.
    #[inline]
    pub fn note_degraded_abort(&self) {
        self.degraded_aborts.fetch_add(1, Ordering::Relaxed);
    }

    /// Count `n_ops` doorbell-plane WQEs affected by an injected MN fault.
    #[inline]
    pub fn note_mn_op_faults(&self, n_ops: u64) {
        self.mn_op_faults.fetch_add(n_ops, Ordering::Relaxed);
    }

    /// Count one doorbell ring torn by the fault injector.
    #[inline]
    pub fn note_torn_batch(&self) {
        self.torn_batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one executed shard transfer: the transactions it doomed
    /// and the interruption (virtual ns) it charged to the clock floor.
    #[inline]
    pub fn note_reshard_move(&self, aborted_txns: u64, interruption_ns: u64) {
        self.reshard_moves.fetch_add(1, Ordering::Relaxed);
        self.reshard_aborted_txns
            .fetch_add(aborted_txns, Ordering::Relaxed);
        self.reshard_interruption_ns
            .fetch_add(interruption_ns, Ordering::Relaxed);
    }

    /// Count one `WrongShardOwner` bounce retried against the fresh map.
    #[inline]
    pub fn note_wrong_owner_bounce(&self) {
        self.wrong_owner_bounces.fetch_add(1, Ordering::Relaxed);
    }

    /// Lock-phase RPC reissues.
    pub fn rpc_retries(&self) -> u64 {
        self.rpc_retries.load(Ordering::Relaxed)
    }

    /// RPC messages lost by the fault injector.
    pub fn rpc_dropped(&self) -> u64 {
        self.rpc_dropped.load(Ordering::Relaxed)
    }

    /// Cumulative retry backoff charged to lanes on this CN (virtual ns).
    pub fn backoff_ns(&self) -> u64 {
        self.backoff_ns.load(Ordering::Relaxed)
    }

    /// Degradations whose suspected owner was in fact alive.
    pub fn false_suspicions(&self) -> u64 {
        self.false_suspicions.load(Ordering::Relaxed)
    }

    /// Proactive aborts against suspected owner CNs.
    pub fn degraded_aborts(&self) -> u64 {
        self.degraded_aborts.load(Ordering::Relaxed)
    }

    /// Doorbell-plane WQEs affected by injected MN faults.
    pub fn mn_op_faults(&self) -> u64 {
        self.mn_op_faults.load(Ordering::Relaxed)
    }

    /// Doorbell rings torn by the fault injector.
    pub fn torn_batches(&self) -> u64 {
        self.torn_batches.load(Ordering::Relaxed)
    }

    /// Shard transfers executed by this CN's balance tick.
    pub fn reshard_moves(&self) -> u64 {
        self.reshard_moves.load(Ordering::Relaxed)
    }

    /// Transactions doomed by this CN's shard transfers.
    pub fn reshard_aborted_txns(&self) -> u64 {
        self.reshard_aborted_txns.load(Ordering::Relaxed)
    }

    /// Shard-transfer interruption charged by this CN (virtual ns).
    pub fn reshard_interruption_ns(&self) -> u64 {
        self.reshard_interruption_ns.load(Ordering::Relaxed)
    }

    /// `WrongShardOwner` bounces retried against the fresh routing map.
    pub fn wrong_owner_bounces(&self) -> u64 {
        self.wrong_owner_bounces.load(Ordering::Relaxed)
    }

    /// RPC messages sent from this CN.
    pub fn rpc_messages(&self) -> u64 {
        self.rpc_messages.load(Ordering::Relaxed)
    }

    /// Lock-class requests carried by those messages.
    pub fn rpc_reqs(&self) -> u64 {
        self.rpc_reqs.load(Ordering::Relaxed)
    }

    /// Requests that shared another lane's RPC message.
    pub fn coalesced_rpc_reqs(&self) -> u64 {
        self.coalesced_rpc_reqs.load(Ordering::Relaxed)
    }

    /// Lock-wait wakeups.
    pub fn lock_waits(&self) -> u64 {
        self.lock_waits.load(Ordering::Relaxed)
    }

    /// Cumulative anachronism span bridged by lock waits (virtual ns).
    pub fn lock_wait_ns(&self) -> u64 {
        self.lock_wait_ns.load(Ordering::Relaxed)
    }

    /// Cumulative handler-queue wait at this CN as a destination (virtual ns).
    pub fn handler_wait_ns(&self) -> u64 {
        self.handler_wait_ns.load(Ordering::Relaxed)
    }

    /// Handled lock batches that wait was measured over.
    pub fn handler_chunks(&self) -> u64 {
        self.handler_chunks.load(Ordering::Relaxed)
    }

    /// WQEs currently posted but not yet rung (0 when nothing in flight).
    pub fn posted_wqes(&self) -> u64 {
        self.posted_wqes.load(Ordering::Relaxed)
    }

    /// High-water mark of posted-but-unrung WQEs.
    pub fn posted_wqes_hwm(&self) -> u64 {
        self.posted_wqes_hwm.load(Ordering::Relaxed)
    }

    /// Staged sync plans (lane yields) posted through this NIC.
    pub fn staged_plans(&self) -> u64 {
        self.staged_plans.load(Ordering::Relaxed)
    }

    /// Merged doorbell issues carrying >= 2 frames' staged plans.
    pub fn overlap_rings(&self) -> u64 {
        self.overlap_rings.load(Ordering::Relaxed)
    }

    /// Staged plans carried by those merged issues.
    pub fn overlap_plans(&self) -> u64 {
        self.overlap_plans.load(Ordering::Relaxed)
    }

    /// Ring events that resumed parked lanes.
    pub fn resumed_rings(&self) -> u64 {
        self.resumed_rings.load(Ordering::Relaxed)
    }

    /// Staged plans completed by those ring events.
    pub fn resumed_plans(&self) -> u64 {
        self.resumed_plans.load(Ordering::Relaxed)
    }

    /// Cumulative post-to-ring wait of rung staged plans (virtual ns).
    pub fn ring_gap_ns(&self) -> u64 {
        self.ring_gap_ns.load(Ordering::Relaxed)
    }

    /// Completion time if the verb were issued now, without enqueueing.
    pub fn peek(&self, t_arrive: u64, svc: u64) -> u64 {
        self.busy_until.load(Ordering::Relaxed).max(t_arrive) + svc
    }

    /// Total ops processed.
    pub fn op_count(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    /// Total busy virtual ns.
    pub fn busy_ns(&self) -> u64 {
        self.busy_ns.load(Ordering::Relaxed)
    }

    /// Virtual time at which the NIC frees up.
    pub fn busy_until(&self) -> u64 {
        self.busy_until.load(Ordering::Relaxed)
    }

    /// Utilization over a run of `duration_ns` virtual time.
    pub fn utilization(&self, duration_ns: u64) -> f64 {
        if duration_ns == 0 {
            return 0.0;
        }
        (self.busy_ns() as f64 / duration_ns as f64).min(1.0)
    }

    /// Reset counters (not the queue time).
    pub fn reset_counters(&self) {
        self.ops.store(0, Ordering::Relaxed);
        self.busy_ns.store(0, Ordering::Relaxed);
        self.wait_ns.store(0, Ordering::Relaxed);
        self.doorbells.store(0, Ordering::Relaxed);
        self.doorbell_ops.store(0, Ordering::Relaxed);
        self.coalesced_ops.store(0, Ordering::Relaxed);
        self.posted_wqes.store(0, Ordering::Relaxed);
        self.posted_wqes_hwm.store(0, Ordering::Relaxed);
        self.staged_plans.store(0, Ordering::Relaxed);
        self.overlap_rings.store(0, Ordering::Relaxed);
        self.overlap_plans.store(0, Ordering::Relaxed);
        self.resumed_rings.store(0, Ordering::Relaxed);
        self.resumed_plans.store(0, Ordering::Relaxed);
        self.ring_gap_ns.store(0, Ordering::Relaxed);
        self.rpc_messages.store(0, Ordering::Relaxed);
        self.rpc_reqs.store(0, Ordering::Relaxed);
        self.coalesced_rpc_reqs.store(0, Ordering::Relaxed);
        self.lock_waits.store(0, Ordering::Relaxed);
        self.lock_wait_ns.store(0, Ordering::Relaxed);
        self.handler_wait_ns.store(0, Ordering::Relaxed);
        self.handler_chunks.store(0, Ordering::Relaxed);
        self.rpc_retries.store(0, Ordering::Relaxed);
        self.rpc_dropped.store(0, Ordering::Relaxed);
        self.backoff_ns.store(0, Ordering::Relaxed);
        self.false_suspicions.store(0, Ordering::Relaxed);
        self.degraded_aborts.store(0, Ordering::Relaxed);
        self.mn_op_faults.store(0, Ordering::Relaxed);
        self.torn_batches.store(0, Ordering::Relaxed);
        self.reshard_moves.store(0, Ordering::Relaxed);
        self.reshard_aborted_txns.store(0, Ordering::Relaxed);
        self.reshard_interruption_ns.store(0, Ordering::Relaxed);
        self.wrong_owner_bounces.store(0, Ordering::Relaxed);
    }

    /// Reset the queue to idle at time zero (between benchmark runs —
    /// virtual time restarts per run; never call mid-run).
    pub fn reset(&self) {
        self.busy_until.store(0, Ordering::SeqCst);
        self.reset_counters();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn idle_nic_serves_immediately() {
        let n = Rnic::new();
        assert_eq!(n.charge(1000, 30), 1030);
    }

    #[test]
    fn back_to_back_ops_queue() {
        let n = Rnic::new();
        // Two ops arriving at the same instant serialize.
        let a = n.charge(0, 100);
        let b = n.charge(0, 100);
        assert_eq!(a, 100);
        assert_eq!(b, 200);
    }

    #[test]
    fn late_arrival_after_idle_gap() {
        let n = Rnic::new();
        n.charge(0, 50);
        // Arrives after the queue drained — no waiting.
        assert_eq!(n.charge(1_000, 50), 1_050);
    }

    #[test]
    fn cas_queue_grows_faster_than_write_queue() {
        // The paper's premise in miniature: same arrival pattern, CAS svc
        // (400ns) builds a queue ~14x deeper than WRITE svc (29ns).
        let writes = Rnic::new();
        let cas = Rnic::new();
        for i in 0..1000u64 {
            let t = i * 50; // arrivals every 50ns
            writes.charge(t, 29);
            cas.charge(t, 400);
        }
        let write_lag = writes.busy_until().saturating_sub(1000 * 50);
        let cas_lag = cas.busy_until().saturating_sub(1000 * 50);
        assert!(write_lag < 1_000, "writes keep up: lag={write_lag}");
        assert!(cas_lag > 300_000, "cas falls behind: lag={cas_lag}");
    }

    #[test]
    fn concurrent_charges_conserve_service_time() {
        let n = Arc::new(Rnic::new());
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let n = n.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        n.charge(0, 10);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // 8000 ops x 10ns each, all arriving at t=0 => busy_until == 80_000.
        assert_eq!(n.busy_until(), 80_000);
        assert_eq!(n.op_count(), 8000);
    }

    #[test]
    fn utilization_reporting() {
        let n = Rnic::new();
        for i in 0..10 {
            n.charge(i * 100, 50);
        }
        let u = n.utilization(1000);
        assert!((u - 0.5).abs() < 1e-9, "u={u}");
    }

    #[test]
    fn posted_gauge_tracks_split_phase_post_and_ring() {
        let n = Rnic::new();
        assert_eq!(n.posted_wqes(), 0);
        n.note_posted(3);
        n.note_posted(2);
        assert_eq!(n.posted_wqes(), 5);
        assert_eq!(n.posted_wqes_hwm(), 5);
        assert_eq!(n.staged_plans(), 2);
        n.note_rung_posted(5);
        assert_eq!(n.posted_wqes(), 0, "all posted WQEs rung");
        assert_eq!(n.posted_wqes_hwm(), 5, "high-water mark sticks");
        // Over-release saturates instead of wrapping.
        n.note_rung_posted(1);
        assert_eq!(n.posted_wqes(), 0);
        n.note_overlap(3);
        assert_eq!(n.overlap_rings(), 1);
        assert_eq!(n.overlap_plans(), 3);
        n.note_resumed(3, 4_200);
        assert_eq!(n.resumed_rings(), 1);
        assert_eq!(n.resumed_plans(), 3);
        assert_eq!(n.ring_gap_ns(), 4_200);
        n.reset_counters();
        assert_eq!(n.posted_wqes_hwm(), 0);
        assert_eq!(n.staged_plans(), 0);
        assert_eq!(n.overlap_rings(), 0);
        assert_eq!(n.resumed_rings(), 0);
        assert_eq!(n.ring_gap_ns(), 0);
    }

    #[test]
    fn rpc_plane_and_lock_wait_counters() {
        let n = Rnic::new();
        n.note_rpc_message(4);
        n.note_rpc_message(1);
        assert_eq!(n.rpc_messages(), 2);
        assert_eq!(n.rpc_reqs(), 5);
        n.note_rpc_riders(3);
        assert_eq!(n.coalesced_rpc_reqs(), 3);
        assert_eq!(n.rpc_reqs(), 5, "riders are already part of rpc_reqs");
        n.note_lock_wait(700);
        n.note_lock_wait(300);
        assert_eq!(n.lock_waits(), 2);
        assert_eq!(n.lock_wait_ns(), 1_000);
        n.note_handler_wait(2_500);
        n.note_handler_wait(0);
        assert_eq!(n.handler_chunks(), 2);
        assert_eq!(n.handler_wait_ns(), 2_500);
        n.note_rpc_retry();
        n.note_rpc_dropped();
        n.note_rpc_dropped();
        n.note_backoff(40_000);
        n.note_false_suspicion();
        n.note_degraded_abort();
        n.note_mn_op_faults(6);
        n.note_torn_batch();
        n.note_reshard_move(3, 12_000);
        n.note_reshard_move(0, 8_000);
        n.note_wrong_owner_bounce();
        assert_eq!(n.reshard_moves(), 2);
        assert_eq!(n.reshard_aborted_txns(), 3);
        assert_eq!(n.reshard_interruption_ns(), 20_000);
        assert_eq!(n.wrong_owner_bounces(), 1);
        assert_eq!(n.rpc_retries(), 1);
        assert_eq!(n.rpc_dropped(), 2);
        assert_eq!(n.backoff_ns(), 40_000);
        assert_eq!(n.false_suspicions(), 1);
        assert_eq!(n.degraded_aborts(), 1);
        assert_eq!(n.mn_op_faults(), 6);
        assert_eq!(n.torn_batches(), 1);
        n.reset_counters();
        assert_eq!(n.rpc_messages(), 0);
        assert_eq!(n.rpc_reqs(), 0);
        assert_eq!(n.coalesced_rpc_reqs(), 0);
        assert_eq!(n.lock_waits(), 0);
        assert_eq!(n.lock_wait_ns(), 0);
        assert_eq!(n.handler_wait_ns(), 0);
        assert_eq!(n.handler_chunks(), 0);
        assert_eq!(n.rpc_retries(), 0);
        assert_eq!(n.rpc_dropped(), 0);
        assert_eq!(n.backoff_ns(), 0);
        assert_eq!(n.false_suspicions(), 0);
        assert_eq!(n.degraded_aborts(), 0);
        assert_eq!(n.mn_op_faults(), 0);
        assert_eq!(n.torn_batches(), 0);
        assert_eq!(n.reshard_moves(), 0);
        assert_eq!(n.reshard_aborted_txns(), 0);
        assert_eq!(n.reshard_interruption_ns(), 0);
        assert_eq!(n.wrong_owner_bounces(), 0);
    }

    #[test]
    fn prop_completion_after_arrival_and_monotone_queue() {
        crate::testing::prop(50, |g| {
            let n = Rnic::new();
            let mut last_done = 0;
            let mut t = 0u64;
            for _ in 0..g.usize(1, 200) {
                t += g.u64(0, 500);
                let svc = g.u64(1, 600);
                let done = n.charge(t, svc);
                assert!(done >= t + svc, "completion before arrival+svc");
                assert!(done >= last_done, "FIFO completions must be monotone");
                last_done = done;
            }
        });
    }
}
