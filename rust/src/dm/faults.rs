//! Deterministic, seeded fault injection for the CN-to-CN RPC fabric.
//!
//! Real disaggregated deployments fail messier than fail-stop: lock
//! handlers go gray-slow, UD SENDs get lost, partitions cut specific
//! CN pairs. The [`FaultInjector`] models those shapes as a list of
//! [`FaultRule`]s the [`crate::dm::rpc::RpcFabric`] consults once per
//! message (`call` / `send_timed` / `send_async_at`).
//!
//! # Determinism
//!
//! Every per-message decision is a **pure function** of the injector
//! seed, the rule index, and the message coordinates
//! `(src_cn, dst_cn, slot, t_send, n_reqs)` — a SplitMix64-style hash,
//! never a shared mutable RNG consumed in arrival order. Coordinator
//! threads race in wall-clock time, but the virtual-time coordinates of
//! a message do not depend on that race, so identical seeds and fault
//! scripts yield byte-identical [`crate::metrics::RunReport`]s.
//!
//! Rules carry a virtual-time window `[from_ns, until_ns)`: timed gray
//! windows and drop storms are expressed by *installing the schedule up
//! front*, not by toggling shared flags mid-run (which would reintroduce
//! wall-clock nondeterminism).
//!
//! An injector with no rules is **byte-inert**: every message maps to
//! [`FaultAction::Deliver`] and the fabric charges exactly what it
//! charges with no injector installed.

/// What a matching rule does to a message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultMode {
    /// Lose the message. A synchronous send surfaces as a timeout at the
    /// caller; a fire-and-forget send vanishes after the send charge.
    Drop,
    /// Deliver, but the message arrives this much later (virtual ns).
    Delay(u64),
    /// Gray failure: the destination handler CPU serves this message's
    /// chunks at `mult`x the normal service time, feeding the existing
    /// `handler_wait_ns` queueing-delay signal.
    GraySlow(u64),
    /// Cut the `(src, dst)` CN pair: every matching message is lost.
    Partition(usize, usize),
}

/// The fabric-facing verdict for one message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// No fault: charge exactly the un-injected costs.
    Deliver,
    /// Message lost.
    Drop,
    /// Arrival delayed by the given virtual ns.
    Delay(u64),
    /// Handler service time multiplied by the given factor (>= 1).
    Slow(u64),
}

/// One fault shape, active over a virtual-time window, applied with a
/// per-message probability to the messages its filters select.
#[derive(Debug, Clone)]
pub struct FaultRule {
    /// The fault to inject when the rule fires.
    pub mode: FaultMode,
    /// Window start (virtual ns, inclusive).
    pub from_ns: u64,
    /// Window end (virtual ns, exclusive); `u64::MAX` = forever.
    pub until_ns: u64,
    /// Chance the rule fires per matching message, in permille (0..=1000).
    pub prob_permille: u32,
    /// Only messages sent from this CN (any source when `None`).
    pub src: Option<usize>,
    /// Only messages sent to this CN (any destination when `None`).
    pub dst: Option<usize>,
}

impl FaultRule {
    /// Lose `prob_permille`/1000 of matching messages.
    pub fn drop(prob_permille: u32) -> Self {
        Self::new(FaultMode::Drop, prob_permille)
    }

    /// Delay `prob_permille`/1000 of matching messages by `delay_ns`.
    pub fn delay(delay_ns: u64, prob_permille: u32) -> Self {
        Self::new(FaultMode::Delay(delay_ns), prob_permille)
    }

    /// Serve `prob_permille`/1000 of matching messages at `mult`x
    /// handler time (a gray-slow destination CPU).
    pub fn gray_slow(mult: u64, prob_permille: u32) -> Self {
        Self::new(FaultMode::GraySlow(mult), prob_permille)
    }

    /// Cut every message from `src` to `dst` (a one-way partition).
    pub fn partition(src: usize, dst: usize) -> Self {
        Self::new(FaultMode::Partition(src, dst), 1000)
    }

    fn new(mode: FaultMode, prob_permille: u32) -> Self {
        Self {
            mode,
            from_ns: 0,
            until_ns: u64::MAX,
            prob_permille: prob_permille.min(1000),
            src: None,
            dst: None,
        }
    }

    /// Restrict the rule to the virtual-time window `[from_ns, until_ns)`.
    pub fn window(mut self, from_ns: u64, until_ns: u64) -> Self {
        self.from_ns = from_ns;
        self.until_ns = until_ns;
        self
    }

    /// Restrict the rule to messages sent from `cn`.
    pub fn from_src(mut self, cn: usize) -> Self {
        self.src = Some(cn);
        self
    }

    /// Restrict the rule to messages sent to `cn`.
    pub fn to_dst(mut self, cn: usize) -> Self {
        self.dst = Some(cn);
        self
    }

    /// Does the rule select this message (ignoring the probability coin)?
    fn matches(&self, src: usize, dst: usize, t_send: u64) -> bool {
        if t_send < self.from_ns || t_send >= self.until_ns {
            return false;
        }
        if let FaultMode::Partition(ps, pd) = self.mode {
            return src == ps && dst == pd;
        }
        self.src.is_none_or(|s| s == src) && self.dst.is_none_or(|d| d == dst)
    }
}

/// A seeded list of [`FaultRule`]s; the first matching rule whose coin
/// lands decides the message's [`FaultAction`].
#[derive(Debug, Clone)]
pub struct FaultInjector {
    seed: u64,
    rules: Vec<FaultRule>,
}

impl FaultInjector {
    /// Injector with no rules (byte-inert until rules are added).
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            rules: Vec::new(),
        }
    }

    /// Add a rule (builder style).
    pub fn rule(mut self, rule: FaultRule) -> Self {
        self.rules.push(rule);
        self
    }

    /// True when no rule is installed (every message delivers untouched).
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// The deterministic verdict for one message. Pure in
    /// `(seed, rules, src_cn, dst_cn, slot, t_send, n_reqs)`.
    pub fn decide(
        &self,
        src_cn: usize,
        dst_cn: usize,
        slot: usize,
        t_send: u64,
        n_reqs: u64,
    ) -> FaultAction {
        for (i, r) in self.rules.iter().enumerate() {
            if !r.matches(src_cn, dst_cn, t_send) {
                continue;
            }
            if r.prob_permille < 1000
                && self.coin(i, src_cn, dst_cn, slot, t_send, n_reqs) >= r.prob_permille
            {
                continue;
            }
            return match r.mode {
                FaultMode::Drop | FaultMode::Partition(..) => FaultAction::Drop,
                FaultMode::Delay(ns) => FaultAction::Delay(ns),
                FaultMode::GraySlow(mult) => FaultAction::Slow(mult.max(1)),
            };
        }
        FaultAction::Deliver
    }

    /// Per-(rule, message) coin in 0..1000.
    fn coin(
        &self,
        rule_idx: usize,
        src_cn: usize,
        dst_cn: usize,
        slot: usize,
        t_send: u64,
        n_reqs: u64,
    ) -> u32 {
        let mut h = self
            .seed
            .wrapping_add((rule_idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        for v in [
            src_cn as u64,
            dst_cn as u64,
            slot as u64,
            t_send,
            n_reqs,
        ] {
            h = mix(h ^ v);
        }
        (h % 1000) as u32
    }
}

/// SplitMix64 finalizer (same constants as `phases::hash_ref`).
fn mix(mut z: u64) -> u64 {
    z ^= 0x9E37_79B9_7F4A_7C15;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_injector_always_delivers() {
        let inj = FaultInjector::new(7);
        assert!(inj.is_empty());
        for t in (0..100_000).step_by(997) {
            assert_eq!(inj.decide(0, 1, 0, t, 3), FaultAction::Deliver);
        }
    }

    #[test]
    fn decisions_are_pure_functions_of_the_coordinates() {
        let inj = FaultInjector::new(42).rule(FaultRule::drop(500));
        for t in (0..50_000).step_by(313) {
            let a = inj.decide(0, 2, 1, t, 4);
            let b = inj.decide(0, 2, 1, t, 4);
            assert_eq!(a, b, "same message, different verdict at t={t}");
        }
        // A clone decides identically (no hidden mutable state).
        let other = inj.clone();
        assert_eq!(inj.decide(1, 2, 0, 12_345, 2), other.decide(1, 2, 0, 12_345, 2));
    }

    #[test]
    fn drop_probability_is_roughly_respected() {
        let inj = FaultInjector::new(1).rule(FaultRule::drop(100)); // 10%
        let mut dropped = 0;
        let n = 10_000;
        for i in 0..n {
            if inj.decide(0, 1, 0, i * 37, 1) == FaultAction::Drop {
                dropped += 1;
            }
        }
        assert!(
            (500..1500).contains(&dropped),
            "10% of {n} should be ~1000, got {dropped}"
        );
    }

    #[test]
    fn window_gates_the_rule_in_virtual_time() {
        let inj = FaultInjector::new(9)
            .rule(FaultRule::gray_slow(8, 1000).window(1_000, 2_000));
        assert_eq!(inj.decide(0, 1, 0, 999, 1), FaultAction::Deliver);
        assert_eq!(inj.decide(0, 1, 0, 1_000, 1), FaultAction::Slow(8));
        assert_eq!(inj.decide(0, 1, 0, 1_999, 1), FaultAction::Slow(8));
        assert_eq!(inj.decide(0, 1, 0, 2_000, 1), FaultAction::Deliver);
    }

    #[test]
    fn partition_cuts_exactly_the_named_pair() {
        let inj = FaultInjector::new(3).rule(FaultRule::partition(0, 2));
        assert_eq!(inj.decide(0, 2, 0, 5_000, 1), FaultAction::Drop);
        assert_eq!(inj.decide(2, 0, 0, 5_000, 1), FaultAction::Deliver, "one-way");
        assert_eq!(inj.decide(0, 1, 0, 5_000, 1), FaultAction::Deliver);
        assert_eq!(inj.decide(1, 2, 0, 5_000, 1), FaultAction::Deliver);
    }

    #[test]
    fn src_dst_filters_select_messages() {
        let inj = FaultInjector::new(4)
            .rule(FaultRule::delay(7_777, 1000).from_src(1).to_dst(2));
        assert_eq!(inj.decide(1, 2, 0, 0, 1), FaultAction::Delay(7_777));
        assert_eq!(inj.decide(0, 2, 0, 0, 1), FaultAction::Deliver);
        assert_eq!(inj.decide(1, 0, 0, 0, 1), FaultAction::Deliver);
    }

    #[test]
    fn first_matching_rule_wins() {
        let inj = FaultInjector::new(5)
            .rule(FaultRule::drop(1000).to_dst(1))
            .rule(FaultRule::delay(99, 1000));
        assert_eq!(inj.decide(0, 1, 0, 0, 1), FaultAction::Drop);
        assert_eq!(inj.decide(0, 2, 0, 0, 1), FaultAction::Delay(99));
    }

    #[test]
    fn different_seeds_give_different_coin_streams() {
        let a = FaultInjector::new(100).rule(FaultRule::drop(500));
        let b = FaultInjector::new(200).rule(FaultRule::drop(500));
        let mut diff = 0;
        for i in 0..1_000 {
            if a.decide(0, 1, 0, i * 11, 1) != b.decide(0, 1, 0, i * 11, 1) {
                diff += 1;
            }
        }
        assert!(diff > 100, "seeds should decorrelate the coins: {diff}");
    }
}
