//! Deterministic, seeded fault injection for the CN-to-CN RPC fabric.
//!
//! Real disaggregated deployments fail messier than fail-stop: lock
//! handlers go gray-slow, UD SENDs get lost, partitions cut specific
//! CN pairs. The [`FaultInjector`] models those shapes as a list of
//! [`FaultRule`]s the [`crate::dm::rpc::RpcFabric`] consults once per
//! message (`call` / `send_timed` / `send_async_at`).
//!
//! # Determinism
//!
//! Every per-message decision is a **pure function** of the injector
//! seed, the rule index, and the message coordinates
//! `(src_cn, dst_cn, slot, t_send, n_reqs)` — a SplitMix64-style hash,
//! never a shared mutable RNG consumed in arrival order. Coordinator
//! threads race in wall-clock time, but the virtual-time coordinates of
//! a message do not depend on that race, so identical seeds and fault
//! scripts yield byte-identical [`crate::metrics::RunReport`]s.
//!
//! Rules carry a virtual-time window `[from_ns, until_ns)`: timed gray
//! windows and drop storms are expressed by *installing the schedule up
//! front*, not by toggling shared flags mid-run (which would reintroduce
//! wall-clock nondeterminism).
//!
//! An injector with no rules is **byte-inert**: every message maps to
//! [`FaultAction::Deliver`] and the fabric charges exactly what it
//! charges with no injector installed.
//!
//! # Doorbell plane (PR 8)
//!
//! The one-sided CN→MN verb path has its own fault vocabulary:
//! [`FaultMode::MnUnreachable`] (an MN stops answering for a window),
//! [`FaultMode::MnDelay`] (PCIe/fabric hiccup on the ring), and
//! [`FaultMode::TornBatch`] — the crash-consistency one — which lands
//! only a deterministic *prefix* of a doorbell's WQEs (plus a byte
//! prefix of the first cut WRITE, so a commit-log slot can land torn
//! mid-record). [`Endpoint::doorbell`](crate::dm::verbs::Endpoint)
//! consults [`FaultInjector::decide_doorbell`] once per ring; the three
//! doorbell modes are invisible to the RPC plane's `decide`, and vice
//! versa, so arming one plane never perturbs the other's coin stream.
//! For doorbell rules the `dst` filter selects the **MN id**, not a CN.

use std::sync::{Arc, RwLock};

/// What a matching rule does to a message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultMode {
    /// Lose the message. A synchronous send surfaces as a timeout at the
    /// caller; a fire-and-forget send vanishes after the send charge.
    Drop,
    /// Deliver, but the message arrives this much later (virtual ns).
    Delay(u64),
    /// Gray failure: the destination handler CPU serves this message's
    /// chunks at `mult`x the normal service time, feeding the existing
    /// `handler_wait_ns` queueing-delay signal.
    GraySlow(u64),
    /// Cut the `(src, dst)` CN pair: every matching message is lost.
    Partition(usize, usize),
    /// Doorbell plane: the named MN stops answering one-sided verbs.
    /// No WQE of a matching ring executes; the CN sees a timeout.
    MnUnreachable(usize),
    /// Doorbell plane: the ring's arrival at the MN is delayed by the
    /// given virtual ns (PCIe/fabric hiccup); all WQEs still execute.
    MnDelay(u64),
    /// Doorbell plane: the ring is torn — only a deterministic prefix
    /// of its WQEs lands (plus a byte prefix of the first cut WRITE),
    /// and the CN sees a timeout instead of completions.
    TornBatch,
}

/// The fabric-facing verdict for one message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// No fault: charge exactly the un-injected costs.
    Deliver,
    /// Message lost.
    Drop,
    /// Arrival delayed by the given virtual ns.
    Delay(u64),
    /// Handler service time multiplied by the given factor (>= 1).
    Slow(u64),
}

/// The verdict for one doorbell ring on the CN→MN verb plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DoorbellFault {
    /// No fault: every WQE executes and completes normally.
    Deliver,
    /// The MN never answers: no WQE executes, the CN times out.
    Unreachable,
    /// Every WQE executes, but arrival is delayed by the given ns.
    Delay(u64),
    /// Torn ring: WQEs `0..keep_ops` execute fully; the WQE at
    /// `keep_ops` (if a WRITE) lands only `partial_permille`/1000 of
    /// its payload bytes; everything after is lost; the CN times out.
    Torn {
        /// Number of leading WQEs that land completely (< ring size).
        keep_ops: usize,
        /// Byte prefix of the first cut WRITE, in permille of its len.
        partial_permille: u32,
    },
}

/// One fault shape, active over a virtual-time window, applied with a
/// per-message probability to the messages its filters select.
#[derive(Debug, Clone)]
pub struct FaultRule {
    /// The fault to inject when the rule fires.
    pub mode: FaultMode,
    /// Window start (virtual ns, inclusive).
    pub from_ns: u64,
    /// Window end (virtual ns, exclusive); `u64::MAX` = forever.
    pub until_ns: u64,
    /// Chance the rule fires per matching message, in permille (0..=1000).
    pub prob_permille: u32,
    /// Only messages sent from this CN (any source when `None`).
    pub src: Option<usize>,
    /// Only messages sent to this CN (any destination when `None`).
    pub dst: Option<usize>,
}

impl FaultRule {
    /// Lose `prob_permille`/1000 of matching messages.
    pub fn drop(prob_permille: u32) -> Self {
        Self::new(FaultMode::Drop, prob_permille)
    }

    /// Delay `prob_permille`/1000 of matching messages by `delay_ns`.
    pub fn delay(delay_ns: u64, prob_permille: u32) -> Self {
        Self::new(FaultMode::Delay(delay_ns), prob_permille)
    }

    /// Serve `prob_permille`/1000 of matching messages at `mult`x
    /// handler time (a gray-slow destination CPU).
    pub fn gray_slow(mult: u64, prob_permille: u32) -> Self {
        Self::new(FaultMode::GraySlow(mult), prob_permille)
    }

    /// Cut every message from `src` to `dst` (a one-way partition).
    pub fn partition(src: usize, dst: usize) -> Self {
        Self::new(FaultMode::Partition(src, dst), 1000)
    }

    /// Doorbell plane: MN `mn` answers no one-sided verbs (combine with
    /// [`window`](Self::window) for an outage interval).
    pub fn mn_unreachable(mn: usize) -> Self {
        Self::new(FaultMode::MnUnreachable(mn), 1000)
    }

    /// Doorbell plane: delay `prob_permille`/1000 of matching rings by
    /// `delay_ns` (the `dst` filter selects an MN id).
    pub fn mn_delay(delay_ns: u64, prob_permille: u32) -> Self {
        Self::new(FaultMode::MnDelay(delay_ns), prob_permille)
    }

    /// Doorbell plane: tear `prob_permille`/1000 of matching rings,
    /// landing only a deterministic prefix of their WQEs.
    pub fn torn_batch(prob_permille: u32) -> Self {
        Self::new(FaultMode::TornBatch, prob_permille)
    }

    /// Is this a doorbell-plane (CN→MN verbs) rule?
    fn is_doorbell(&self) -> bool {
        matches!(
            self.mode,
            FaultMode::MnUnreachable(_) | FaultMode::MnDelay(_) | FaultMode::TornBatch
        )
    }

    fn new(mode: FaultMode, prob_permille: u32) -> Self {
        Self {
            mode,
            from_ns: 0,
            until_ns: u64::MAX,
            prob_permille: prob_permille.min(1000),
            src: None,
            dst: None,
        }
    }

    /// Restrict the rule to the virtual-time window `[from_ns, until_ns)`.
    pub fn window(mut self, from_ns: u64, until_ns: u64) -> Self {
        self.from_ns = from_ns;
        self.until_ns = until_ns;
        self
    }

    /// Restrict the rule to messages sent from `cn`.
    pub fn from_src(mut self, cn: usize) -> Self {
        self.src = Some(cn);
        self
    }

    /// Restrict the rule to messages sent to `cn`.
    pub fn to_dst(mut self, cn: usize) -> Self {
        self.dst = Some(cn);
        self
    }

    /// Does the rule select this message (ignoring the probability coin)?
    fn matches(&self, src: usize, dst: usize, t_send: u64) -> bool {
        if t_send < self.from_ns || t_send >= self.until_ns {
            return false;
        }
        if let FaultMode::Partition(ps, pd) = self.mode {
            return src == ps && dst == pd;
        }
        self.src.is_none_or(|s| s == src) && self.dst.is_none_or(|d| d == dst)
    }
}

/// A seeded list of [`FaultRule`]s; the first matching rule whose coin
/// lands decides the message's [`FaultAction`].
#[derive(Debug, Clone)]
pub struct FaultInjector {
    seed: u64,
    rules: Vec<FaultRule>,
}

impl FaultInjector {
    /// Injector with no rules (byte-inert until rules are added).
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            rules: Vec::new(),
        }
    }

    /// Add a rule (builder style).
    pub fn rule(mut self, rule: FaultRule) -> Self {
        self.rules.push(rule);
        self
    }

    /// True when no rule is installed (every message delivers untouched).
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// The deterministic verdict for one message. Pure in
    /// `(seed, rules, src_cn, dst_cn, slot, t_send, n_reqs)`.
    pub fn decide(
        &self,
        src_cn: usize,
        dst_cn: usize,
        slot: usize,
        t_send: u64,
        n_reqs: u64,
    ) -> FaultAction {
        for (i, r) in self.rules.iter().enumerate() {
            // Doorbell-plane rules never touch RPC messages (and never
            // perturb this plane's coin stream — coins are per-rule).
            if r.is_doorbell() || !r.matches(src_cn, dst_cn, t_send) {
                continue;
            }
            if r.prob_permille < 1000
                && self.coin(i, src_cn, dst_cn, slot, t_send, n_reqs) >= r.prob_permille
            {
                continue;
            }
            return match r.mode {
                FaultMode::Drop | FaultMode::Partition(..) => FaultAction::Drop,
                FaultMode::Delay(ns) => FaultAction::Delay(ns),
                FaultMode::GraySlow(mult) => FaultAction::Slow(mult.max(1)),
                FaultMode::MnUnreachable(_) | FaultMode::MnDelay(_) | FaultMode::TornBatch => {
                    unreachable!("doorbell rules filtered above")
                }
            };
        }
        FaultAction::Deliver
    }

    /// The deterministic verdict for one doorbell ring of `n_ops` WQEs
    /// from CN `src_cn` to MN `mn`, rung at virtual time `t_ring`. Pure
    /// in `(seed, rules, src_cn, mn, t_ring, n_ops)`; RPC-plane rules
    /// are skipped, so arming the RPC plane leaves this plane inert.
    pub fn decide_doorbell(
        &self,
        src_cn: usize,
        mn: usize,
        t_ring: u64,
        n_ops: usize,
    ) -> DoorbellFault {
        for (i, r) in self.rules.iter().enumerate() {
            if !r.is_doorbell() || !r.matches(src_cn, mn, t_ring) {
                continue;
            }
            if let FaultMode::MnUnreachable(m) = r.mode {
                if m != mn {
                    continue;
                }
            }
            if r.prob_permille < 1000
                && self.coin(i, src_cn, mn, DOORBELL_PLANE, t_ring, n_ops as u64)
                    >= r.prob_permille
            {
                continue;
            }
            return match r.mode {
                FaultMode::MnUnreachable(_) => DoorbellFault::Unreachable,
                FaultMode::MnDelay(ns) => DoorbellFault::Delay(ns),
                FaultMode::TornBatch => {
                    // A second, independent hash picks where the tear
                    // lands: a strict WQE prefix plus a byte prefix of
                    // the first cut WRITE.
                    let h = self.hash(
                        i,
                        src_cn,
                        mn,
                        DOORBELL_PLANE + 1,
                        t_ring,
                        n_ops as u64,
                    );
                    DoorbellFault::Torn {
                        keep_ops: (h % n_ops.max(1) as u64) as usize,
                        partial_permille: ((h >> 32) % 1000) as u32,
                    }
                }
                _ => unreachable!("non-doorbell rules filtered above"),
            };
        }
        DoorbellFault::Deliver
    }

    /// Per-(rule, message) coin in 0..1000.
    fn coin(
        &self,
        rule_idx: usize,
        src_cn: usize,
        dst_cn: usize,
        slot: usize,
        t_send: u64,
        n_reqs: u64,
    ) -> u32 {
        (self.hash(rule_idx, src_cn, dst_cn, slot, t_send, n_reqs) % 1000) as u32
    }

    /// The full 64-bit pure hash behind [`coin`](Self::coin).
    fn hash(
        &self,
        rule_idx: usize,
        src_cn: usize,
        dst_cn: usize,
        slot: usize,
        t_send: u64,
        n_reqs: u64,
    ) -> u64 {
        let mut h = self
            .seed
            .wrapping_add((rule_idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        for v in [
            src_cn as u64,
            dst_cn as u64,
            slot as u64,
            t_send,
            n_reqs,
        ] {
            h = mix(h ^ v);
        }
        h
    }
}

/// Slot-coordinate salt separating the doorbell plane's coin stream
/// from the RPC plane's (which uses real slot indices).
const DOORBELL_PLANE: usize = 0xD00B_E11;

/// A late-binding slot for an injector shared by every [`Endpoint`]
/// (`crate::dm::verbs::Endpoint`) of a cluster. Endpoints are built
/// once at cluster construction; `run_with_faults` installs the run's
/// script here and clears it afterwards. An empty cell (or an installed
/// injector with no doorbell rules) leaves the plane byte-inert.
#[derive(Debug, Default)]
pub struct FaultsCell {
    inner: RwLock<Option<Arc<FaultInjector>>>,
}

impl FaultsCell {
    /// An empty (inert) cell.
    pub fn new() -> Self {
        Self::default()
    }

    /// Install (or, with `None`, clear) the active injector.
    pub fn install(&self, inj: Option<Arc<FaultInjector>>) {
        *self.inner.write().unwrap() = inj;
    }

    /// The currently installed injector, if any.
    pub fn snapshot(&self) -> Option<Arc<FaultInjector>> {
        self.inner.read().unwrap().clone()
    }
}

/// SplitMix64 finalizer (same constants as `phases::hash_ref`).
fn mix(mut z: u64) -> u64 {
    z ^= 0x9E37_79B9_7F4A_7C15;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_injector_always_delivers() {
        let inj = FaultInjector::new(7);
        assert!(inj.is_empty());
        for t in (0..100_000).step_by(997) {
            assert_eq!(inj.decide(0, 1, 0, t, 3), FaultAction::Deliver);
        }
    }

    #[test]
    fn decisions_are_pure_functions_of_the_coordinates() {
        let inj = FaultInjector::new(42).rule(FaultRule::drop(500));
        for t in (0..50_000).step_by(313) {
            let a = inj.decide(0, 2, 1, t, 4);
            let b = inj.decide(0, 2, 1, t, 4);
            assert_eq!(a, b, "same message, different verdict at t={t}");
        }
        // A clone decides identically (no hidden mutable state).
        let other = inj.clone();
        assert_eq!(inj.decide(1, 2, 0, 12_345, 2), other.decide(1, 2, 0, 12_345, 2));
    }

    #[test]
    fn drop_probability_is_roughly_respected() {
        let inj = FaultInjector::new(1).rule(FaultRule::drop(100)); // 10%
        let mut dropped = 0;
        let n = 10_000;
        for i in 0..n {
            if inj.decide(0, 1, 0, i * 37, 1) == FaultAction::Drop {
                dropped += 1;
            }
        }
        assert!(
            (500..1500).contains(&dropped),
            "10% of {n} should be ~1000, got {dropped}"
        );
    }

    #[test]
    fn window_gates_the_rule_in_virtual_time() {
        let inj = FaultInjector::new(9)
            .rule(FaultRule::gray_slow(8, 1000).window(1_000, 2_000));
        assert_eq!(inj.decide(0, 1, 0, 999, 1), FaultAction::Deliver);
        assert_eq!(inj.decide(0, 1, 0, 1_000, 1), FaultAction::Slow(8));
        assert_eq!(inj.decide(0, 1, 0, 1_999, 1), FaultAction::Slow(8));
        assert_eq!(inj.decide(0, 1, 0, 2_000, 1), FaultAction::Deliver);
    }

    #[test]
    fn partition_cuts_exactly_the_named_pair() {
        let inj = FaultInjector::new(3).rule(FaultRule::partition(0, 2));
        assert_eq!(inj.decide(0, 2, 0, 5_000, 1), FaultAction::Drop);
        assert_eq!(inj.decide(2, 0, 0, 5_000, 1), FaultAction::Deliver, "one-way");
        assert_eq!(inj.decide(0, 1, 0, 5_000, 1), FaultAction::Deliver);
        assert_eq!(inj.decide(1, 2, 0, 5_000, 1), FaultAction::Deliver);
    }

    #[test]
    fn src_dst_filters_select_messages() {
        let inj = FaultInjector::new(4)
            .rule(FaultRule::delay(7_777, 1000).from_src(1).to_dst(2));
        assert_eq!(inj.decide(1, 2, 0, 0, 1), FaultAction::Delay(7_777));
        assert_eq!(inj.decide(0, 2, 0, 0, 1), FaultAction::Deliver);
        assert_eq!(inj.decide(1, 0, 0, 0, 1), FaultAction::Deliver);
    }

    #[test]
    fn first_matching_rule_wins() {
        let inj = FaultInjector::new(5)
            .rule(FaultRule::drop(1000).to_dst(1))
            .rule(FaultRule::delay(99, 1000));
        assert_eq!(inj.decide(0, 1, 0, 0, 1), FaultAction::Drop);
        assert_eq!(inj.decide(0, 2, 0, 0, 1), FaultAction::Delay(99));
    }

    #[test]
    fn doorbell_rules_are_invisible_to_the_rpc_plane_and_vice_versa() {
        let inj = FaultInjector::new(11)
            .rule(FaultRule::mn_unreachable(0))
            .rule(FaultRule::torn_batch(1000))
            .rule(FaultRule::mn_delay(5_000, 1000));
        for t in (0..50_000).step_by(313) {
            assert_eq!(inj.decide(0, 1, 0, t, 3), FaultAction::Deliver);
        }
        let rpc_only = FaultInjector::new(11)
            .rule(FaultRule::drop(1000))
            .rule(FaultRule::gray_slow(8, 1000))
            .rule(FaultRule::partition(0, 1));
        for t in (0..50_000).step_by(313) {
            assert_eq!(rpc_only.decide_doorbell(0, 1, t, 4), DoorbellFault::Deliver);
        }
    }

    #[test]
    fn mn_unreachable_hits_only_the_named_mn_inside_its_window() {
        let inj = FaultInjector::new(2)
            .rule(FaultRule::mn_unreachable(1).window(1_000, 2_000));
        assert_eq!(inj.decide_doorbell(0, 1, 999, 2), DoorbellFault::Deliver);
        assert_eq!(inj.decide_doorbell(0, 1, 1_000, 2), DoorbellFault::Unreachable);
        assert_eq!(inj.decide_doorbell(2, 1, 1_999, 8), DoorbellFault::Unreachable);
        assert_eq!(inj.decide_doorbell(0, 0, 1_500, 2), DoorbellFault::Deliver, "other MN");
        assert_eq!(inj.decide_doorbell(0, 1, 2_000, 2), DoorbellFault::Deliver);
    }

    #[test]
    fn torn_batch_keeps_a_strict_prefix_deterministically() {
        let inj = FaultInjector::new(77).rule(FaultRule::torn_batch(1000).from_src(0));
        for t in (0..100_000).step_by(997) {
            for n in 1..=9usize {
                match inj.decide_doorbell(0, 1, t, n) {
                    DoorbellFault::Torn {
                        keep_ops,
                        partial_permille,
                    } => {
                        assert!(keep_ops < n, "tear must cut at least one WQE");
                        assert!(partial_permille < 1000);
                        // Pure function of the coordinates.
                        assert_eq!(
                            inj.decide_doorbell(0, 1, t, n),
                            DoorbellFault::Torn {
                                keep_ops,
                                partial_permille
                            }
                        );
                    }
                    other => panic!("permille 1000 must tear, got {other:?}"),
                }
            }
            // src filter: other CNs untouched.
            assert_eq!(inj.decide_doorbell(1, 1, t, 4), DoorbellFault::Deliver);
        }
    }

    #[test]
    fn mn_delay_lands_with_its_permille_coin() {
        let inj = FaultInjector::new(6).rule(FaultRule::mn_delay(9_999, 500));
        let (mut delayed, mut clean) = (0, 0);
        for i in 0..2_000u64 {
            match inj.decide_doorbell(0, 1, i * 41, 3) {
                DoorbellFault::Delay(9_999) => delayed += 1,
                DoorbellFault::Deliver => clean += 1,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(delayed > 600 && clean > 600, "~50/50: {delayed}/{clean}");
    }

    #[test]
    fn faults_cell_starts_inert_and_round_trips_an_injector() {
        let cell = FaultsCell::new();
        assert!(cell.snapshot().is_none());
        let inj = Arc::new(FaultInjector::new(1).rule(FaultRule::torn_batch(1000)));
        cell.install(Some(inj.clone()));
        assert!(cell.snapshot().is_some_and(|i| !i.is_empty()));
        cell.install(None);
        assert!(cell.snapshot().is_none());
    }

    #[test]
    fn different_seeds_give_different_coin_streams() {
        let a = FaultInjector::new(100).rule(FaultRule::drop(500));
        let b = FaultInjector::new(200).rule(FaultRule::drop(500));
        let mut diff = 0;
        for i in 0..1_000 {
            if a.decide(0, 1, 0, i * 11, 1) != b.decide(0, 1, 0, i * 11, 1) {
                diff += 1;
            }
        }
        assert!(diff > 100, "seeds should decorrelate the coins: {diff}");
    }
}
