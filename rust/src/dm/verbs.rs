//! One-sided RDMA verbs: the CN-side endpoint.
//!
//! An [`Endpoint`] is a coordinator's window onto the memory pool. Every
//! verb (a) executes against the target [`MemNode`]'s real memory and (b)
//! charges the cost model: CN NIC issue cost, half-RTT propagation, MN
//! RNIC queueing + service, half-RTT completion. Doorbell batching (paper
//! section 7.2) issues several WQEs in one PCIe doorbell and pays one RTT
//! for the batch; small writes are treated as inline (no extra DMA read,
//! folded into `cn_issue_ns`); CQ polling with selective signaling is
//! likewise folded into the issue constant.

//!
//! # Fault injection (PR 8)
//!
//! When a [`FaultsCell`] is attached, every doorbell consults
//! [`FaultInjector::decide_doorbell`](crate::dm::faults::FaultInjector::decide_doorbell)
//! once per ring: an unreachable MN times the ring out with no WQE
//! executed, a delayed ring lands late, and a **torn** ring executes
//! only a WQE prefix (plus a byte prefix of the first cut WRITE — the
//! hazard the commit log's seal defends against). Synchronous rings
//! surface faults as [`Error::NodeUnavailable`]; fire-and-forget rings
//! swallow them (the loss is discovered by recovery, not the caller).
//! With no cell attached — or no doorbell rule installed — every path
//! charges exactly what it charged before faults existed.

use std::sync::Arc;

use crate::dm::clock::{TimeGate, VClock};
use crate::dm::faults::{DoorbellFault, FaultsCell};
use crate::dm::memnode::MemNode;
use crate::dm::netconfig::NetConfig;
use crate::dm::rnic::Rnic;
use crate::{Error, Result};

/// One operation inside a doorbell batch.
#[derive(Debug)]
pub enum VerbOp {
    /// READ `len` bytes at `addr` into `out`.
    Read {
        /// MN byte address.
        addr: u64,
        /// Output buffer (its length is the read length).
        out: Vec<u8>,
    },
    /// WRITE `data` at `addr`.
    Write {
        /// MN byte address.
        addr: u64,
        /// Bytes to write.
        data: Vec<u8>,
    },
    /// 8B CAS at `addr`; `old` receives the previous value.
    Cas {
        /// MN byte address (8B aligned).
        addr: u64,
        /// Expected value.
        expect: u64,
        /// Replacement value.
        swap: u64,
        /// Out: value observed before the CAS.
        old: u64,
    },
    /// 8B FAA at `addr`; `old` receives the previous value.
    Faa {
        /// MN byte address (8B aligned).
        addr: u64,
        /// Addend.
        delta: u64,
        /// Out: value observed before the add.
        old: u64,
    },
}

impl VerbOp {
    fn svc(&self, net: &NetConfig) -> u64 {
        match self {
            VerbOp::Read { out, .. } => net.read_cost(out.len()),
            VerbOp::Write { data, .. } => net.write_cost(data.len()),
            VerbOp::Cas { .. } => net.cas_svc_ns,
            VerbOp::Faa { .. } => net.faa_svc_ns,
        }
    }

    fn execute(&mut self, mn: &MemNode) -> Result<()> {
        match self {
            VerbOp::Read { addr, out } => mn.read_bytes(*addr, out),
            VerbOp::Write { addr, data } => mn.write_bytes(*addr, data),
            VerbOp::Cas {
                addr,
                expect,
                swap,
                old,
            } => {
                *old = mn.cas_u64(*addr, *expect, *swap)?;
                Ok(())
            }
            VerbOp::Faa { addr, delta, old } => {
                *old = mn.faa_u64(*addr, *delta)?;
                Ok(())
            }
        }
    }

    /// Torn-DMA landing: a WRITE lands only `permille`/1000 of its
    /// payload bytes (prefix), rounded DOWN to a multiple of 8 — the
    /// MN RNIC delivers aligned 8-byte words atomically (the standard
    /// RDMA assumption the commit protocol leans on), so a version or
    /// state word is all-or-nothing and only multi-word payloads
    /// (records, log slots) can land genuinely torn. Non-WRITE verbs
    /// are all-or-nothing at the MN RNIC, so a torn one simply does
    /// not execute.
    fn execute_partial(&mut self, mn: &MemNode, permille: u32) -> Result<()> {
        if let VerbOp::Write { addr, data } = self {
            let keep = (data.len() * permille.min(999) as usize / 1000) & !7;
            if keep > 0 {
                return mn.write_bytes(*addr, &data[..keep]);
            }
        }
        Ok(())
    }
}

/// Per-op completion times of one (possibly faulted) doorbell ring.
#[derive(Debug)]
pub struct RingOutcome {
    /// Per-op completion times (for a faulted ring: the timeout at
    /// which the CN gives up on every op of the ring).
    pub done: Vec<u64>,
    /// True when an injected doorbell fault hit this ring — the caller
    /// must treat the whole ring as failed, whatever landed.
    pub faulted: bool,
}

/// A coordinator's verb endpoint (shares the CN NIC with its siblings).
#[derive(Clone)]
pub struct Endpoint {
    /// Owning CN id.
    pub cn: usize,
    /// The CN-side NIC (shared by all coordinators on this CN).
    pub nic: Arc<Rnic>,
    /// Cost model.
    pub net: Arc<NetConfig>,
    /// Conservative-PDES gate: synced before every fabric charge so
    /// arrivals at shared queues are (nearly) ordered in virtual time.
    gate: Option<(Arc<TimeGate>, usize)>,
    /// Late-binding doorbell-plane fault injector (empty = inert).
    faults: Option<Arc<FaultsCell>>,
}

impl Endpoint {
    /// New endpoint.
    pub fn new(cn: usize, nic: Arc<Rnic>, net: Arc<NetConfig>) -> Self {
        Self {
            cn,
            nic,
            net,
            gate: None,
            faults: None,
        }
    }

    /// Attach the run's time gate (coordinator id `gid`).
    pub fn attach_gate(&mut self, gate: Arc<TimeGate>, gid: usize) {
        self.gate = Some((gate, gid));
    }

    /// Attach the cluster's doorbell-plane fault cell (builder style).
    pub fn with_faults(mut self, cell: Arc<FaultsCell>) -> Self {
        self.faults = Some(cell);
        self
    }

    /// The deterministic fault verdict for one ring to MN `mn` at
    /// virtual time `t_ring`. [`DoorbellFault::Deliver`] when no cell
    /// is attached or no doorbell rule matches.
    fn ring_fault(&self, mn: usize, t_ring: u64, n_ops: usize) -> DoorbellFault {
        match self.faults.as_ref().and_then(|c| c.snapshot()) {
            Some(inj) => inj.decide_doorbell(self.cn, mn, t_ring, n_ops),
            None => DoorbellFault::Deliver,
        }
    }

    /// How long a CN waits on a doorbell's completions before declaring
    /// the MN unavailable (mirror of the RPC plane's timeout contract).
    pub fn doorbell_timeout_ns(&self) -> u64 {
        self.net.rtt_ns * 4
    }

    /// Publish + bound this coordinator's clock before touching a queue.
    /// Epoch-batched ([`TimeGate::publish`]): with `gate_publish_ns == 0`
    /// every call stores (the legacy per-bump behavior); with a nonzero
    /// epoch the cross-core store is paid only per `gate_publish_ns` of
    /// virtual progress or when the skew window demands it.
    #[inline]
    pub fn gate_sync(&self, clk: &VClock) {
        if let Some((gate, gid)) = &self.gate {
            gate.publish(*gid, clk.now());
        }
    }

    /// Split-phase issue, post half: `n_ops` WQEs written to the send
    /// queue with the doorbell deferred. The step-machine calls this when
    /// a frame stages a plan and yields; the NIC tracks the
    /// posted-but-unrung depth (see [`Rnic::posted_wqes`]).
    #[inline]
    pub fn post_wqes(&self, n_ops: u64) {
        self.nic.note_posted(n_ops);
    }

    /// Split-phase issue, ring half: a doorbell (set) covering `n_ops`
    /// previously posted WQEs rang — or the WQEs died with a crashed CN.
    #[inline]
    pub fn ring_posted(&self, n_ops: u64) {
        self.nic.note_rung_posted(n_ops);
    }

    /// Issue a doorbell batch of verbs to one MN; returns at batch
    /// completion (one RTT + queued service of every op). Results are in
    /// the mutated `ops`.
    pub fn doorbell(&self, mn: &MemNode, ops: &mut [VerbOp], clk: &mut VClock) -> Result<()> {
        if ops.is_empty() {
            return Ok(());
        }
        self.gate_sync(clk);
        let fault = self.ring_fault(mn.id, clk.now(), ops.len());
        self.nic.ring(ops.len() as u64);
        let t_issue = self.nic.charge(
            clk.now(),
            self.net.doorbell_ns + self.net.cn_issue_ns * ops.len() as u64,
        );
        let mut t_arrive = t_issue + self.net.rtt_ns / 2;
        match fault {
            DoorbellFault::Deliver => {}
            DoorbellFault::Delay(ns) => {
                self.nic.note_mn_op_faults(ops.len() as u64);
                t_arrive += ns;
            }
            DoorbellFault::Unreachable => {
                // The MN never serves the ring: no WQE executes and the
                // CN only learns at the completion timeout.
                self.nic.note_mn_op_faults(ops.len() as u64);
                clk.catch_up(t_issue + self.doorbell_timeout_ns());
                return Err(Error::NodeUnavailable(format!(
                    "mn{} (doorbell timeout)",
                    mn.id
                )));
            }
            DoorbellFault::Torn {
                keep_ops,
                partial_permille,
            } => {
                // A WQE prefix lands (consuming MN service), the rest is
                // lost; the CN sees missing completions and times out.
                self.nic.note_torn_batch();
                self.nic.note_mn_op_faults((ops.len() - keep_ops) as u64);
                for op in ops[..keep_ops].iter_mut() {
                    mn.rnic.charge(t_arrive, op.svc(&self.net));
                    op.execute(mn)?;
                }
                if let Some(op) = ops.get_mut(keep_ops) {
                    mn.rnic.charge(t_arrive, op.svc(&self.net));
                    op.execute_partial(mn, partial_permille)?;
                }
                clk.catch_up(t_issue + self.doorbell_timeout_ns());
                return Err(Error::NodeUnavailable(format!(
                    "mn{} (torn doorbell)",
                    mn.id
                )));
            }
        }
        let mut t_done = t_arrive;
        for op in ops.iter_mut() {
            t_done = mn.rnic.charge(t_arrive, op.svc(&self.net));
            op.execute(mn)?;
        }
        clk.catch_up(t_done + self.net.rtt_ns / 2);
        Ok(())
    }

    /// Completion-driven issue of one doorbell batch: like [`Self::doorbell`]
    /// but starts at an explicit virtual time and returns *per-op*
    /// completion times (MN service done + the return half-RTT) instead of
    /// advancing a single clock. This is the primitive cross-transaction
    /// coalescing builds on: several frames' ops share one doorbell, and
    /// each owning frame's clock advances only to the completion of its
    /// own ops (see [`crate::dm::opbatch::MergedBatch`]).
    ///
    /// `ride` marks a batch that extends a doorbell another plan already
    /// rang within the same coalescing window: the per-doorbell MMIO
    /// overhead is skipped and no new ring is counted.
    pub fn doorbell_timed(
        &self,
        mn: &MemNode,
        ops: &mut [VerbOp],
        t_start: u64,
        ride: bool,
    ) -> Result<RingOutcome> {
        if ops.is_empty() {
            return Ok(RingOutcome {
                done: Vec::new(),
                faulted: false,
            });
        }
        let fault = self.ring_fault(mn.id, t_start, ops.len());
        if ride {
            self.nic.note_coalesced(ops.len() as u64);
        } else {
            self.nic.ring(ops.len() as u64);
        }
        let overhead = if ride { 0 } else { self.net.doorbell_ns };
        let t_issue = self
            .nic
            .charge(t_start, overhead + self.net.cn_issue_ns * ops.len() as u64);
        let mut t_arrive = t_issue + self.net.rtt_ns / 2;
        match fault {
            DoorbellFault::Deliver => {}
            DoorbellFault::Delay(ns) => {
                self.nic.note_mn_op_faults(ops.len() as u64);
                t_arrive += ns;
            }
            DoorbellFault::Unreachable => {
                self.nic.note_mn_op_faults(ops.len() as u64);
                let t_out = t_issue + self.doorbell_timeout_ns();
                return Ok(RingOutcome {
                    done: vec![t_out; ops.len()],
                    faulted: true,
                });
            }
            DoorbellFault::Torn {
                keep_ops,
                partial_permille,
            } => {
                self.nic.note_torn_batch();
                self.nic.note_mn_op_faults((ops.len() - keep_ops) as u64);
                for op in ops[..keep_ops].iter_mut() {
                    mn.rnic.charge(t_arrive, op.svc(&self.net));
                    op.execute(mn)?;
                }
                if let Some(op) = ops.get_mut(keep_ops) {
                    mn.rnic.charge(t_arrive, op.svc(&self.net));
                    op.execute_partial(mn, partial_permille)?;
                }
                let t_out = t_issue + self.doorbell_timeout_ns();
                return Ok(RingOutcome {
                    done: vec![t_out; ops.len()],
                    faulted: true,
                });
            }
        }
        let mut completions = Vec::with_capacity(ops.len());
        for op in ops.iter_mut() {
            let t_done = mn.rnic.charge(t_arrive, op.svc(&self.net));
            op.execute(mn)?;
            completions.push(t_done + self.net.rtt_ns / 2);
        }
        Ok(RingOutcome {
            done: completions,
            faulted: false,
        })
    }

    /// Fire-and-forget batch: charges the NICs but advances the caller's
    /// clock only by the issue cost (used for async unlocks, paper 5.1:
    /// "returns the result immediately after issuing remote unlock
    /// requests").
    pub fn doorbell_async(&self, mn: &MemNode, ops: &mut [VerbOp], clk: &mut VClock) -> Result<()> {
        if ops.is_empty() {
            return Ok(());
        }
        self.gate_sync(clk);
        let fault = self.ring_fault(mn.id, clk.now(), ops.len());
        self.nic.ring(ops.len() as u64);
        let t_issue = self.nic.charge(
            clk.now(),
            self.net.doorbell_ns + self.net.cn_issue_ns * ops.len() as u64,
        );
        let mut t_arrive = t_issue + self.net.rtt_ns / 2;
        // Fire-and-forget: the caller never observes completions, so
        // faults are swallowed — whatever fails to land is discovered by
        // recovery (e.g. a lost commit-log clear leaves a stale PREPARED
        // slot that recovery completes idempotently).
        match fault {
            DoorbellFault::Deliver => {}
            DoorbellFault::Delay(ns) => {
                self.nic.note_mn_op_faults(ops.len() as u64);
                t_arrive += ns;
            }
            DoorbellFault::Unreachable => {
                self.nic.note_mn_op_faults(ops.len() as u64);
                clk.catch_up(t_issue);
                return Ok(());
            }
            DoorbellFault::Torn {
                keep_ops,
                partial_permille,
            } => {
                self.nic.note_torn_batch();
                self.nic.note_mn_op_faults((ops.len() - keep_ops) as u64);
                for op in ops[..keep_ops].iter_mut() {
                    mn.rnic.charge(t_arrive, op.svc(&self.net));
                    op.execute(mn)?;
                }
                if let Some(op) = ops.get_mut(keep_ops) {
                    mn.rnic.charge(t_arrive, op.svc(&self.net));
                    op.execute_partial(mn, partial_permille)?;
                }
                clk.catch_up(t_issue);
                return Ok(());
            }
        }
        for op in ops.iter_mut() {
            mn.rnic.charge(t_arrive, op.svc(&self.net));
            op.execute(mn)?;
        }
        clk.catch_up(t_issue);
        Ok(())
    }

    /// Single READ.
    pub fn read(&self, mn: &MemNode, addr: u64, len: usize, clk: &mut VClock) -> Result<Vec<u8>> {
        let mut ops = [VerbOp::Read {
            addr,
            out: vec![0u8; len],
        }];
        self.doorbell(mn, &mut ops, clk)?;
        match ops {
            [VerbOp::Read { out, .. }] => Ok(out),
            _ => unreachable!(),
        }
    }

    /// Single 8B READ.
    pub fn read_u64(&self, mn: &MemNode, addr: u64, clk: &mut VClock) -> Result<u64> {
        let b = self.read(mn, addr, 8, clk)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    /// Single WRITE.
    pub fn write(&self, mn: &MemNode, addr: u64, data: &[u8], clk: &mut VClock) -> Result<()> {
        let mut ops = [VerbOp::Write {
            addr,
            data: data.to_vec(),
        }];
        self.doorbell(mn, &mut ops, clk)
    }

    /// Single CAS; returns the old value (success iff old == expect).
    pub fn cas(
        &self,
        mn: &MemNode,
        addr: u64,
        expect: u64,
        swap: u64,
        clk: &mut VClock,
    ) -> Result<u64> {
        let mut ops = [VerbOp::Cas {
            addr,
            expect,
            swap,
            old: 0,
        }];
        self.doorbell(mn, &mut ops, clk)?;
        match ops {
            [VerbOp::Cas { old, .. }] => Ok(old),
            _ => unreachable!(),
        }
    }

    /// Single FAA; returns the old value.
    pub fn faa(&self, mn: &MemNode, addr: u64, delta: u64, clk: &mut VClock) -> Result<u64> {
        let mut ops = [VerbOp::Faa {
            addr,
            delta,
            old: 0,
        }];
        self.doorbell(mn, &mut ops, clk)?;
        match ops {
            [VerbOp::Faa { old, .. }] => Ok(old),
            _ => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Arc<MemNode>, Endpoint) {
        let mn = Arc::new(MemNode::new(0, 1 << 16));
        let ep = Endpoint::new(
            0,
            Arc::new(Rnic::new()),
            Arc::new(NetConfig::default()),
        );
        (mn, ep)
    }

    #[test]
    fn read_write_roundtrip_with_latency() {
        let (mn, ep) = setup();
        let r = mn.register(64).unwrap();
        let mut clk = VClock::zero();
        ep.write(&mn, r.base, b"hello word", &mut clk).unwrap();
        let t_after_write = clk.now();
        // One verb >= RTT.
        assert!(t_after_write >= ep.net.rtt_ns, "t={t_after_write}");
        let out = ep.read(&mn, r.base, 10, &mut clk).unwrap();
        assert_eq!(&out, b"hello word");
        assert!(clk.now() > t_after_write);
    }

    #[test]
    fn cas_verbs_cost_more_than_writes() {
        let (mn, ep) = setup();
        let r = mn.register(16).unwrap();
        let mut c1 = VClock::zero();
        ep.write(&mn, r.base, &7u64.to_le_bytes(), &mut c1).unwrap();
        let mut c2 = VClock::zero();
        // fresh node so queues are empty
        let mn2 = Arc::new(MemNode::new(1, 1 << 12));
        let r2 = mn2.register(16).unwrap();
        ep.cas(&mn2, r2.base, 0, 1, &mut c2).unwrap();
        assert!(
            c2.now() > c1.now(),
            "CAS ({}) must cost more than WRITE ({})",
            c2.now(),
            c1.now()
        );
    }

    #[test]
    fn doorbell_batch_pays_one_rtt() {
        let (mn, ep) = setup();
        let r = mn.register(256).unwrap();
        // 8 writes batched
        let mut clk_batch = VClock::zero();
        let mut ops: Vec<VerbOp> = (0..8)
            .map(|i| VerbOp::Write {
                addr: r.base + i * 8,
                data: vec![i as u8; 8],
            })
            .collect();
        ep.doorbell(&mn, &mut ops, &mut clk_batch).unwrap();

        // 8 writes sequential on a fresh fabric
        let mn2 = Arc::new(MemNode::new(1, 1 << 12));
        let ep2 = Endpoint::new(0, Arc::new(Rnic::new()), ep.net.clone());
        let r2 = mn2.register(256).unwrap();
        let mut clk_seq = VClock::zero();
        for i in 0..8u64 {
            ep2.write(&mn2, r2.base + i * 8, &[0u8; 8], &mut clk_seq).unwrap();
        }
        assert!(
            clk_batch.now() * 4 < clk_seq.now(),
            "batch {} vs seq {}",
            clk_batch.now(),
            clk_seq.now()
        );
    }

    #[test]
    fn cas_atomicity_under_contention() {
        let (mn, _) = setup();
        let r = mn.register(8).unwrap();
        let mn2 = mn.clone();
        let addr = r.base;
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let mn = mn2.clone();
                std::thread::spawn(move || {
                    let ep = Endpoint::new(
                        0,
                        Arc::new(Rnic::new()),
                        Arc::new(NetConfig::default()),
                    );
                    let mut wins = 0;
                    let mut clk = VClock::zero();
                    for _ in 0..1000 {
                        // spin-increment via CAS
                        loop {
                            let cur = ep.read_u64(&mn, addr, &mut clk).unwrap();
                            if ep.cas(&mn, addr, cur, cur + 1, &mut clk).unwrap() == cur {
                                wins += 1;
                                break;
                            }
                        }
                    }
                    wins
                })
            })
            .collect();
        let total: u64 = threads.into_iter().map(|t| t.join().unwrap()).sum();
        assert_eq!(total, 8000);
        assert_eq!(mn.load_u64(addr).unwrap(), 8000);
    }

    #[test]
    fn async_doorbell_does_not_block_caller() {
        let (mn, ep) = setup();
        let r = mn.register(64).unwrap();
        let mut clk = VClock::zero();
        let mut ops = vec![VerbOp::Write {
            addr: r.base,
            data: vec![9u8; 8],
        }];
        ep.doorbell_async(&mn, &mut ops, &mut clk).unwrap();
        // Caller clock advanced far less than an RTT...
        assert!(clk.now() < ep.net.rtt_ns / 2);
        // ...but the write really happened.
        assert_eq!(mn.load_u64(r.base).unwrap(), u64::from_le_bytes([9; 8]));
    }

    #[test]
    fn faa_returns_old() {
        let (mn, ep) = setup();
        let r = mn.register(8).unwrap();
        let mut clk = VClock::zero();
        assert_eq!(ep.faa(&mn, r.base, 2, &mut clk).unwrap(), 0);
        assert_eq!(ep.faa(&mn, r.base, 2, &mut clk).unwrap(), 2);
    }

    use crate::dm::faults::{FaultInjector, FaultRule};

    fn faulty_ep(rule: FaultRule) -> (Arc<MemNode>, Endpoint, Arc<FaultsCell>) {
        let (mn, ep) = setup();
        let cell = Arc::new(FaultsCell::new());
        cell.install(Some(Arc::new(FaultInjector::new(3).rule(rule))));
        let ep = ep.with_faults(cell.clone());
        (mn, ep, cell)
    }

    #[test]
    fn unreachable_mn_times_out_and_executes_nothing() {
        let (mn, ep, _cell) = faulty_ep(FaultRule::mn_unreachable(0));
        let r = mn.register(64).unwrap();
        let mut clk = VClock::zero();
        let err = ep.write(&mn, r.base, &7u64.to_le_bytes(), &mut clk);
        assert!(matches!(err, Err(Error::NodeUnavailable(_))), "{err:?}");
        assert_eq!(mn.load_u64(r.base).unwrap(), 0, "no byte may land");
        assert!(
            clk.now() >= ep.doorbell_timeout_ns(),
            "caller burns the timeout: t={}",
            clk.now()
        );
        assert_eq!(ep.nic.mn_op_faults(), 1);
        assert_eq!(ep.nic.torn_batches(), 0);
    }

    #[test]
    fn torn_ring_lands_a_strict_prefix_then_times_out() {
        let (mn, ep, _cell) = faulty_ep(FaultRule::torn_batch(1000));
        let r = mn.register(256).unwrap();
        let mut clk = VClock::zero();
        let mut ops: Vec<VerbOp> = (0..8)
            .map(|i| VerbOp::Write {
                addr: r.base + i * 8,
                data: vec![0xAB; 8],
            })
            .collect();
        let err = ep.doorbell(&mn, &mut ops, &mut clk);
        assert!(matches!(err, Err(Error::NodeUnavailable(_))), "{err:?}");
        assert_eq!(ep.nic.torn_batches(), 1);
        assert!(ep.nic.mn_op_faults() >= 1);
        // Landed WQEs form a prefix: once one op's bytes are missing,
        // every later op's bytes must be missing too.
        let full = u64::from_le_bytes([0xAB; 8]);
        let landed: Vec<bool> = (0..8)
            .map(|i| mn.load_u64(r.base + i * 8).unwrap() == full)
            .collect();
        let first_hole = landed.iter().position(|l| !l).expect("tear cuts >= 1 op");
        assert!(
            landed[first_hole..].iter().all(|l| !l),
            "non-prefix landing: {landed:?}"
        );
    }

    #[test]
    fn mn_delay_still_executes_everything() {
        let (mn, ep, _cell) = faulty_ep(FaultRule::mn_delay(50_000, 1000));
        let r = mn.register(64).unwrap();
        let mut clk = VClock::zero();
        ep.write(&mn, r.base, &9u64.to_le_bytes(), &mut clk).unwrap();
        assert_eq!(mn.load_u64(r.base).unwrap(), 9);
        assert!(clk.now() > 50_000, "delay must be charged: t={}", clk.now());
        assert_eq!(ep.nic.mn_op_faults(), 1);
    }

    #[test]
    fn async_ring_swallows_faults() {
        let (mn, ep, _cell) = faulty_ep(FaultRule::mn_unreachable(0));
        let r = mn.register(64).unwrap();
        let mut clk = VClock::zero();
        let mut ops = vec![VerbOp::Write {
            addr: r.base,
            data: vec![5u8; 8],
        }];
        ep.doorbell_async(&mn, &mut ops, &mut clk).unwrap();
        assert_eq!(mn.load_u64(r.base).unwrap(), 0, "nothing landed");
        assert_eq!(ep.nic.mn_op_faults(), 1, "but the loss is counted");
    }

    #[test]
    fn empty_cell_and_rpc_only_rules_leave_the_plane_byte_inert() {
        // Three endpoints: no cell, an installed empty cell, and a cell
        // holding RPC-plane rules only. All must charge identically.
        let run = |ep: &Endpoint| -> (u64, Vec<u8>) {
            let mn = Arc::new(MemNode::new(0, 1 << 16));
            let r = mn.register(64).unwrap();
            let mut clk = VClock::zero();
            ep.write(&mn, r.base, b"inertness", &mut clk).unwrap();
            let out = ep.read(&mn, r.base, 9, &mut clk).unwrap();
            (clk.now(), out)
        };
        let bare = Endpoint::new(0, Arc::new(Rnic::new()), Arc::new(NetConfig::default()));
        let empty_cell = bare.clone().with_faults(Arc::new(FaultsCell::new()));
        let rpc_cell = Arc::new(FaultsCell::new());
        rpc_cell.install(Some(Arc::new(
            FaultInjector::new(9)
                .rule(FaultRule::drop(1000))
                .rule(FaultRule::partition(0, 1)),
        )));
        let rpc_only = bare.clone().with_faults(rpc_cell);
        assert_eq!(run(&bare), run(&empty_cell));
        assert_eq!(run(&bare), run(&rpc_only));
    }

    #[test]
    fn timed_ring_reports_faulted_with_timeout_completions() {
        let (mn, ep, cell) = faulty_ep(FaultRule::mn_unreachable(0).window(0, 1_000_000));
        let r = mn.register(64).unwrap();
        let mut ops = vec![VerbOp::Write {
            addr: r.base,
            data: vec![1u8; 8],
        }];
        let out = ep.doorbell_timed(&mn, &mut ops, 0, false).unwrap();
        assert!(out.faulted);
        assert_eq!(out.done.len(), 1);
        assert!(out.done[0] >= ep.doorbell_timeout_ns());
        assert_eq!(mn.load_u64(r.base).unwrap(), 0);
        // Past the window the same endpoint delivers normally.
        cell.install(Some(Arc::new(FaultInjector::new(3))));
        let mut ops = vec![VerbOp::Write {
            addr: r.base,
            data: vec![1u8; 8],
        }];
        let out = ep.doorbell_timed(&mn, &mut ops, 2_000_000, false).unwrap();
        assert!(!out.faulted);
        assert_ne!(mn.load_u64(r.base).unwrap(), 0);
    }
}
